"""Serving engine: batched prefill + decode with per-family caches, greedy /
temperature sampling, and optional VUSA-packed decode execution — MLP-only
or the whole decode step, see ``ServeConfig.packed_weights`` and DESIGN.md
§7 (the paper's technique on the inference path, where weight-byte savings
pay off).

The decode loop is *fused on device* (DESIGN.md §4): one jitted
``lax.scan`` steps the model ``max_new - 1`` times, deriving per-token
sampling keys on device and stacking tokens into a pre-allocated output
buffer, so generation costs a single dispatch and a single
``block_until_ready`` — no per-token host round-trip.  The seed per-token
host loop is kept behind ``ServeConfig.fused = False`` as the measured
baseline (benchmarks/run.py bench_decode_fused) and as a parity oracle:
both paths split the PRNG key identically, so for a fixed seed they emit
identical tokens.
"""

from __future__ import annotations

import contextlib
import dataclasses
import time
from typing import Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..configs.base import ArchConfig
from ..models import build_model
from .faults import FaultConfig

__all__ = ["ServeConfig", "Engine"]


@dataclasses.dataclass
class ServeConfig:
    max_len: int = 256
    temperature: float = 0.0  # 0 => greedy
    seed: int = 0
    # VUSA-packed decode (dense family, DESIGN.md §7): False = dense, "mlp"
    # packs the per-layer MLP trio, "all" (or True) additionally packs
    # wq/wk/wv/wo and the untied LM head — the whole dense-family decode step
    packed_weights: bool | str = False
    packed_mlp: bool = False  # deprecated alias for packed_weights="mlp"
    fused_mlp: bool = True  # megakernel MLP (False = 3-dispatch measured baseline)
    # packed value precision (DESIGN.md §10): "bf16" keeps the pack's native
    # float values (byte-identical program to before the knob existed);
    # "int8"/"int4" quantize value slots with per-(window, row) fp32 scales
    # and fuse dequant into the kernels' VMEM reconstruction
    packed_values: str = "bf16"
    vusa_m: int = 128  # window lanes (kernel tile)
    vusa_a: int = 16  # physical slots per row per job
    fused: bool = True  # on-device lax.scan decode loop (False = seed host loop)
    # prompt-length buckets for batched masked prefill (DESIGN.md §6); empty
    # tuple = powers of two from 8 up to max_len.  One compiled prefill
    # program per (bucket, batch-bucket) serves any prompt length.
    prefill_buckets: tuple = ()
    # paged KV pool (DESIGN.md §11): 0 = slot-stacked contiguous pool (the
    # pre-§11 layout); > 0 = fixed-size blocks of this many rows in a shared
    # arena with per-request block tables.  Must divide max_len (the gathered
    # block view must equal the slot-pool cache shape for bit-parity).
    page_size: int = 0
    # share identical prompt prefixes between requests: full pages by
    # refcounted block reuse, partial tail pages by copy-on-write.  Only
    # meaningful with page_size > 0.
    prefix_cache: bool = True
    # arena capacity in user blocks; 0 = worst case (slots * max_len/page,
    # no oversubscription).  Smaller values oversubscribe: admission checks
    # the worst case per request, mid-flight exhaustion preempts.
    arena_blocks: int = 0
    # chunked prefill (Sarathi-style, DESIGN.md §11): > 0 = split prompts
    # longer than this into chunks of this many tokens, co-scheduled with
    # decode segments so a long admission never stalls decoding slots.
    # Requires page_size > 0 and a page-multiple chunk.  0 = whole-prompt
    # prefill (the pre-§11 behaviour).
    prefill_chunk: int = 0
    # seeded fault-injection plan (DESIGN.md §9); None = no faults.  Pack
    # corruption is applied at Engine init (position flips before load
    # validation, value NaNs after); cache poisoning and admission stalls
    # are consumed by the Scheduler per admitted request.
    faults: Optional[FaultConfig] = None
    # self-speculative decoding via sparsity tiers (DESIGN.md §13): the SAME
    # weights magnitude-pruned at ``draft_sparsity`` and packed at
    # scope="all" draft ``draft_k`` greedy tokens per round; the configured
    # full-quality path verifies all of them in ONE multi-token dispatch and
    # the longest matching prefix is accepted.  Greedy speculative decode is
    # token-bit-identical to non-speculative decode.
    speculative: bool = False
    draft_k: int = 4
    draft_sparsity: float = 0.99

    def __post_init__(self):
        if self.packed_weights is True:
            self.packed_weights = "all"
        if self.packed_mlp and not self.packed_weights:
            self.packed_weights = "mlp"  # legacy spelling keeps its MLP-only scope
        if self.packed_weights not in (False, "mlp", "all"):
            raise ValueError(
                f"packed_weights must be False, 'mlp' or 'all', got {self.packed_weights!r}"
            )
        if self.packed_values not in ("bf16", "int8", "int4"):
            raise ValueError(
                f"packed_values must be 'bf16', 'int8' or 'int4', got {self.packed_values!r}"
            )
        if self.page_size < 0 or (self.page_size and self.max_len % self.page_size):
            raise ValueError(
                f"page_size {self.page_size} must be 0 (slot pool) or divide "
                f"max_len {self.max_len} (DESIGN.md §11 bit-parity contract)"
            )
        if self.prefill_chunk:
            if not self.page_size:
                raise ValueError("prefill_chunk requires page_size > 0 "
                                 "(chunks write through block tables)")
            if self.prefill_chunk % self.page_size:
                raise ValueError(
                    f"prefill_chunk {self.prefill_chunk} must be a multiple of "
                    f"page_size {self.page_size}"
                )
        if self.speculative:
            if self.draft_k < 1:
                raise ValueError(f"draft_k must be >= 1, got {self.draft_k}")
            if not (0.0 <= self.draft_sparsity < 1.0):
                raise ValueError(
                    f"draft_sparsity must be in [0, 1), got {self.draft_sparsity}"
                )
            if not self.fused:
                raise ValueError(
                    "speculative decoding requires the fused decode path"
                )


class Engine:
    def __init__(
        self, cfg: ArchConfig, params, sc: Optional[ServeConfig] = None, mesh=None,
        clock=None,
    ):
        """``mesh`` makes the whole decode/serve path mesh-aware (DESIGN.md
        §8): parameters are placed under ``dist.sharding.params_shardings``
        (TP on ``model``, FSDP on ``data``), decode caches shard their batch
        dim over ``data``, and VUSA packs shard their window axis over
        ``model`` with the kernels running per-shard under ``shard_map``.  A
        1x1 mesh (or ``mesh=None``) is the degenerate single-device path —
        same program, bit-identical tokens.

        ``clock`` injects the timing source (default ``time.monotonic`` —
        never wall clock, which jumps under NTP adjustment).  The Scheduler
        inherits it, so engine and scheduler timings share one timeline."""
        sc = ServeConfig() if sc is None else sc
        self.cfg, self.sc = cfg, sc
        self.model = build_model(cfg)
        self.mesh = mesh
        self._clock = clock or time.monotonic
        self._packed = None
        self._quarantined = False
        if sc.packed_weights:
            self._packed = self._build_pack(params, faults=sc.faults)
        self._draft_packed = None
        if sc.speculative:
            if cfg.family != "dense":
                raise ValueError(
                    "speculative decoding requires the dense family "
                    "(the drafter is a VUSA pack of the same weights)"
                )
            self._draft_packed = self._build_draft_pack(params)
        if mesh is not None:
            from ..dist.sharding import act_rules, params_shardings

            self._act_rules = act_rules(mesh)
            self._cache_axes = self.model.cache_batch_axes(sc.max_len)
            params = jax.device_put(params, params_shardings(self.model.specs(), mesh))
        self.params = params
        self._decode = jax.jit(self._decode_fn)
        self._decode_loop = jax.jit(self._decode_loop_fn, static_argnums=(4,))
        self._spec_loop = jax.jit(self._spec_loop_fn, static_argnums=(4,))
        self._prime_loop = jax.jit(self._prime_loop_fn)
        self._prefill = jax.jit(self._prefill_fn) if cfg.family in (
            "dense", "moe", "vlm", "encdec") else None
        # masked bucketed prefill — dense, and moe only when dropless:
        # capacity-bounded MoE dispatch couples co-batched rows (padding and
        # neighbour tokens consume shared expert capacity, changing which
        # tokens drop), so batching is only bit-exact when no token can ever
        # drop (moe_cf >= n_experts/top_k).  encdec consumes frames, and vlm
        # needs per-request patch extras prime_many has no way to carry (and
        # whose patch-prefix KV rows the token-length slot ``pos`` would
        # disown).  Everything else falls back to per-request admission.
        batchable = cfg.family == "dense" or (
            cfg.family == "moe" and cfg.moe_cf >= cfg.n_experts / cfg.top_k
        )
        self._prefill_masked = jax.jit(self._prefill_masked_fn) if batchable else None
        # chunked-prefill entry for the paged pool (DESIGN.md §11): donates
        # the arena; jax.jit re-specializes per static chunk length, so one
        # wrapper serves every configured chunk/bucket size
        self._chunk = (
            jax.jit(self._chunk_fn, donate_argnums=(2,)) if batchable else None
        )
        self._buckets = self._make_buckets(sc)

    def _build_pack(self, params, faults: Optional[FaultConfig] = None):
        """Build (and optionally fault-corrupt, validate, and shard) a VUSA
        pack from host ``params`` per the engine's ServeConfig.  Used at init
        and by :meth:`reload_packed` for hot weight swaps."""
        from ..kernels.ops import mesh_axis_size  # local import: needs kernels
        from .packed import pack_lm_weights, shard_packed, validate_packed

        sc = self.sc
        # pack from the host params before any device placement, then
        # split the window axes over the model mesh axis
        packed = pack_lm_weights(
            self.cfg, params, sc.vusa_m, sc.vusa_a,
            scope=sc.packed_weights, fused_mlp=sc.fused_mlp,
            shards=mesh_axis_size(self.mesh, "model"),
            # "bf16" = unquantized passthrough: the pack keeps the native
            # param dtype, same program as before the knob existed
            value_dtype="dense" if sc.packed_values == "bf16" else sc.packed_values,
        )
        f = faults
        if f is not None and (f.pack_position_flips or f.pack_value_nans):
            from .faults import corrupt_pack_positions, corrupt_pack_values

            # position flips land *before* load validation — a corrupted
            # metadata byte must make the Engine refuse the pack here,
            # never serve from it.  Value NaNs land *after* validation,
            # modelling post-load in-memory corruption that only the
            # runtime isfinite guard can catch.
            packed = corrupt_pack_positions(packed, f)
            validate_packed(packed)
            packed = corrupt_pack_values(packed, f)
        if self.mesh is not None:
            packed = shard_packed(packed, self.mesh)
        return packed

    def _build_draft_pack(self, params):
        """Build the drafter: the SAME weights magnitude-pruned at
        ``draft_sparsity`` and packed whole (scope="all") — a fraction of the
        verifier pack's bytes, since the job count per window row scales
        with the surviving nonzeros (the paper's virtual upscaling).
        Magnitude pruning nests, so the drafter's weights are a subset of an
        already-pruned verifier's.  Values stay unquantized: drafter
        precision only moves the acceptance rate, never correctness (every
        emitted token comes out of the verifier), and no fault corruption is
        ever applied — the drafter is not the pack the fault plan targets."""
        from ..core.pruning import prune_tree
        from ..kernels.ops import mesh_axis_size
        from .packed import pack_lm_weights, shard_packed

        sc = self.sc
        drafted = prune_tree(params, sc.draft_sparsity)
        packed = pack_lm_weights(
            self.cfg, drafted, sc.vusa_m, sc.vusa_a,
            scope="all", fused_mlp=sc.fused_mlp,
            shards=mesh_axis_size(self.mesh, "model"),
            value_dtype="dense",
        )
        if self.mesh is not None:
            packed = shard_packed(packed, self.mesh)
        return packed

    # -- mesh helpers ---------------------------------------------------------
    def _mesh_ctx(self):
        """Activation-sharding context for the jitted bodies: installs the
        mesh + act_rules so ``models.common.shard`` constraints bind during
        tracing; a no-op context without a mesh."""
        if self.mesh is None:
            return contextlib.nullcontext()
        from ..models.common import mesh_context

        return mesh_context(self.mesh, self._act_rules)

    def shard_cache(self, cache, batch: int):
        """Place a decode cache on the mesh: batch dim over the DP axes
        (structurally located per leaf via ``cache_batch_axes``), everything
        else replicated.  No-op without a mesh."""
        if self.mesh is None:
            return cache
        from ..dist.sharding import serve_shardings

        return jax.device_put(
            cache, serve_shardings(cache, self.mesh, batch, batch_axes=self._cache_axes)
        )

    def _shard_batch(self, arr):
        """Shard an input's leading batch dim over the DP axes (no-op without
        a mesh; replicates when the batch does not divide)."""
        if self.mesh is None:
            return arr
        from ..dist.sharding import batch_sharding

        return jax.device_put(
            arr, batch_sharding(self.mesh, arr.shape[0], arr.ndim)
        )

    # -- jitted bodies --------------------------------------------------------
    def _decode_impl(self, params, token, cache, key, packed):
        """One decode step through ``packed`` (or dense when None).  Returns
        ``(next_token (B, 1), cache, ok (B,))`` where ``ok`` is the per-row
        integrity guard — ``isfinite`` over the fp32 logits (DESIGN.md §9).
        Computed on device and carried through the fused scan, it costs no
        extra host sync: the scheduler fetches it with the segment tokens."""
        with self._mesh_ctx():
            if packed is not None:
                from .packed import lm_decode_step_packed

                logits, cache = lm_decode_step_packed(
                    params, packed, token, cache, self.cfg, mesh=self.mesh
                )
            else:
                logits, cache = self.model.decode_step(params, token, cache)
        logits = logits[:, -1].astype(jnp.float32)
        ok = jnp.isfinite(logits).all(axis=-1)
        if self.mesh is not None:
            # Pin the sampling computation replicated.  Under the default
            # (non-partitionable) threefry lowering, random bits generated
            # for a *sharded* (B, V) block differ from the single-device
            # stream — GSPMD offsets each shard's counter — so a sharded
            # categorical would emit different tokens than mesh=None for the
            # same seed.  Replicating the tiny logits block first keeps the
            # whole draw bit-identical at every mesh shape (DESIGN.md §8).
            from jax.sharding import NamedSharding, PartitionSpec

            logits = jax.lax.with_sharding_constraint(
                logits, NamedSharding(self.mesh, PartitionSpec())
            )
        if self.sc.temperature > 0:
            nxt = jax.random.categorical(key, logits / self.sc.temperature)
        else:
            nxt = jnp.argmax(logits, axis=-1)
        return nxt.astype(jnp.int32)[:, None], cache, ok

    def _decode_fn(self, params, token, cache, key):
        """Decode step on the engine's configured path: packed when a pack is
        loaded and not quarantined, dense otherwise.  The branch binds at
        trace time; ``quarantine_packed`` re-jits so it re-binds."""
        packed = None if self._quarantined else self._packed
        return self._decode_impl(params, token, cache, key, packed)

    def _decode_dense_fn(self, params, token, cache, key):
        """Decode step forced onto the dense path regardless of pack state —
        the fallback the scheduler re-serves guard-tripped requests on."""
        return self._decode_impl(params, token, cache, key, None)

    def _decode_loop_fn(self, params, token, cache, key, steps: int):
        """Fused decode: ``steps`` model steps in one on-device scan.

        The scan's stacked output is the pre-allocated (steps, B) token
        buffer plus the per-step (B,) integrity flags; sampling keys are
        split on device each step, mirroring the host loop's
        ``jax.random.split`` sequence exactly.
        """

        def body(carry, _):
            token, cache, key = carry
            key, sub = jax.random.split(key)
            token, cache, ok = self._decode_fn(params, token, cache, sub)
            return (token, cache, key), (token[:, 0], ok)

        (token, cache, key), (toks, okg) = jax.lax.scan(
            body, (token, cache, key), None, length=steps
        )
        return toks.T, okg.T, token, cache, key  # (B, steps) each

    # -- self-speculative decoding (DESIGN.md §13) ----------------------------
    def _spec_round_impl(self, params, token, cache, kd, packed):
        """One draft/verify round at B=1: draft ``draft_k`` greedy tokens
        with the cheap high-sparsity pack, verify the whole draft (pending
        token + k drafts) in ONE multi-token dispatch of the configured
        full-quality path, accept the longest matching prefix.

        Returns ``(pending (1,1), cache, kd, emit (S,), nem (), okp (S,))``
        with ``S = draft_k + 1``: ``emit[:nem]`` are the tokens emitted this
        round (1 <= nem <= S; the final one is the verifier's own sample
        past the matched prefix and becomes the next pending token), ``okp``
        the per-position verifier integrity flags.

        Bit-parity with non-speculative decode is by construction:

        * The drafter writes its KV rows at ``pos..pos+k-1``, but the
          verifier — after rewinding ``pos`` — rewrites ALL of rows
          ``pos..pos+k`` before attending, so verifier logits are provably
          independent of drafter cache content (a corrupt drafter can only
          lower the acceptance rate, never change an emitted token).
        * Rejected positions need no KV rollback: setting the new ``pos`` to
          ``pos + nem`` masks rows past it via the ``slots <= pos`` validity
          (stale rows are finite and get overwritten when reached again).
        * The PRNG key splits once per EMITTED token — exactly the
          non-speculative sequence — so sampled decode is bit-identical too:
          position i's logits equal the sequential step's (multi-token
          parity) and its draw consumes the same subkey.
        """
        from .packed import lm_decode_step_packed

        k = self.sc.draft_k
        S = k + 1
        pos0 = cache["pos"]
        with self._mesh_ctx():

            def draft_body(carry, _):
                tok, c = carry
                logits, c = lm_decode_step_packed(
                    params, self._draft_packed, tok, c, self.cfg, mesh=self.mesh
                )
                nxt = jnp.argmax(
                    logits[:, -1].astype(jnp.float32), axis=-1
                ).astype(jnp.int32)
                return (nxt[:, None], c), nxt

            (_, cache), drafts = jax.lax.scan(
                draft_body, (token, cache), None, length=k
            )
            seq = jnp.concatenate([token, jnp.moveaxis(drafts, 0, 1)], axis=1)
            cache = {**cache, "pos": pos0}  # rewind: verifier rewrites rows pos0..pos0+k
            if packed is not None:
                logits, cache = lm_decode_step_packed(
                    params, packed, seq, cache, self.cfg, mesh=self.mesh
                )
            else:
                logits, cache = self.model.decode_step(params, seq, cache)
        logits = logits.astype(jnp.float32)  # (1, S, V)
        okp = jnp.isfinite(logits).all(axis=-1)[0]  # (S,)
        if self.mesh is not None:
            # same replication pin as _decode_impl: sampling must stay
            # bit-identical at every mesh shape (DESIGN.md §8)
            from jax.sharding import NamedSharding, PartitionSpec

            logits = jax.lax.with_sharding_constraint(
                logits, NamedSharding(self.mesh, PartitionSpec())
            )
        # sequential accept loop (unrolled, S is small): position i is
        # emitted iff drafts 1..i all matched; the key only advances for
        # emitted positions, so the surviving stream replays the
        # non-speculative split sequence exactly
        emit = jnp.zeros((S,), jnp.int32)
        accept = jnp.bool_(True)
        nem = jnp.int32(0)
        for i in range(S):
            nk, sub = jax.random.split(jax.random.wrap_key_data(kd))
            if self.sc.temperature > 0:
                v = jax.random.categorical(sub, logits[0, i] / self.sc.temperature)
            else:
                v = jnp.argmax(logits[0, i], axis=-1)
            v = v.astype(jnp.int32)
            emit = emit.at[i].set(jnp.where(accept, v, 0))
            kd = jnp.where(accept, jax.random.key_data(nk), kd)
            nem = nem + accept.astype(jnp.int32)
            if i < k:
                accept = jnp.logical_and(accept, v == seq[0, i + 1])
            else:
                accept = jnp.bool_(False)
        cache = {**cache, "pos": pos0 + nem}
        pending = jnp.take(emit, nem - 1)[None, None]  # (1, 1)
        return pending, cache, kd, emit, nem, okp

    def _spec_round_fn(self, params, token, cache, kd):
        """Speculative round on the engine's configured verifier path:
        packed when loaded and not quarantined, dense otherwise."""
        packed = None if self._quarantined else self._packed
        return self._spec_round_impl(params, token, cache, kd, packed)

    def _spec_round_dense_fn(self, params, token, cache, kd):
        """Speculative round with the verifier forced dense (quarantine
        fallback).  The drafter keeps its own pack — it was built and
        validated separately, and verification guards every emission —
        so fallback tokens stay dense-bit-identical while still drafting."""
        return self._spec_round_impl(params, token, cache, kd, None)

    def _spec_loop_fn(self, params, token, cache, kd, budget: int):
        """Fused speculative decode: while_loop over draft/verify rounds
        until ``budget`` tokens are emitted — ONE dispatch for the whole
        generation, like the non-speculative fused scan.  Each round emits
        at least one token, so the loop is bounded by ``budget`` rounds.

        The emit/ok buffers carry ``budget + S`` entries: a round writes its
        full S-wide window at the current count and only the first ``nem``
        entries are valid — the next round's window starts there and
        overwrites the rejected tail, so garbage only ever lives past the
        final count, beyond what the host reads."""
        S = self.sc.draft_k + 1
        buf = jnp.zeros((budget + S,), jnp.int32)
        okb = jnp.ones((budget + S,), bool)

        def cond(st):
            return st[4] < budget

        def body(st):
            token, cache, kd, buf, count, okb, rounds = st
            token, cache, kd, emit, nem, okp = self._spec_round_fn(
                params, token, cache, kd
            )
            buf = jax.lax.dynamic_update_slice(buf, emit, (count,))
            okb = jax.lax.dynamic_update_slice(okb, okp, (count,))
            return (token, cache, kd, buf, count + nem, okb, rounds + 1)

        st = (token, cache, kd, buf, jnp.int32(0), okb, jnp.int32(0))
        token, cache, kd, buf, count, okb, rounds = jax.lax.while_loop(cond, body, st)
        return buf, okb, count, rounds, token, cache, kd

    def _prime_loop_fn(self, params, prompts, cache, key):
        """Recurrent-family prompt priming: scan the prompt through decode
        steps on device (state capture is O(1) per token)."""

        def body(carry, tok):
            _, cache, key = carry
            key, sub = jax.random.split(key)
            nxt, cache, _ = self._decode_fn(params, tok[:, None], cache, sub)
            return (nxt, cache, key), None

        init = (prompts[:, :1], cache, key)
        (nxt, cache, key), _ = jax.lax.scan(body, init, prompts.T)
        return nxt, cache, key

    def _prefill_fn(self, params, batch):
        with self._mesh_ctx():
            return self.model.prefill(params, batch, self.sc.max_len)

    def _prefill_masked_fn(self, params, batch, lengths):
        """Masked bucketed prefill: right-padded (B, bucket) tokens with true
        ``lengths`` (B,) — per-row logits/KV bit-identical to unpadded
        prefill (DESIGN.md §6).  Returns the greedy first token too, so
        admission needs no extra dispatch."""
        with self._mesh_ctx():
            logits, cache = self.model.prefill(params, batch, self.sc.max_len, lengths=lengths)
        nxt = jnp.argmax(logits.astype(jnp.float32), axis=-1)[:, None].astype(jnp.int32)
        return nxt, cache

    def _chunk_fn(self, params, tokens, arena, table_row, start, true_len, write_from):
        with self._mesh_ctx():
            return self.model.prefill_chunk(
                params, tokens, arena, table_row, start, true_len, write_from
            )

    # -- prompt-length buckets -------------------------------------------------
    @staticmethod
    def _make_buckets(sc: ServeConfig):
        if sc.prefill_buckets:
            bks = sorted(set(int(b) for b in sc.prefill_buckets))
            if bks[0] < 1 or bks[-1] > sc.max_len:
                raise ValueError(f"prefill_buckets {bks} outside [1, max_len={sc.max_len}]")
            if bks[-1] < sc.max_len:
                # always cover max_len: a prompt longer than the largest
                # bucket would otherwise fall back to exact-length compiles,
                # silently unbounding the compile count under ragged traffic
                bks.append(sc.max_len)
            return bks
        bks, b = [], 8
        while b < sc.max_len:
            bks.append(b)
            b *= 2
        bks.append(sc.max_len)
        return bks

    @property
    def prefill_buckets(self):
        return tuple(self._buckets)

    @property
    def batched_prefill(self) -> bool:
        """True when the family supports one-dispatch bucketed admission."""
        return self._prefill_masked is not None

    @property
    def paged_supported(self) -> bool:
        """True when the family can serve from a paged KV pool (DESIGN.md
        §11): a KV-shaped cache *and* batching-exact prefill (dense, or
        dropless moe — the same condition as bucketed admission, because
        prefix-suffix recompute and chunking re-batch prompt tokens).
        Recurrent/vlm families silently keep the dense per-slot pool."""
        return (
            self._prefill_masked is not None
            and self.model.paged_seq_len(self.sc.max_len) is not None
        )

    def prefill_chunk(self, tokens, arena, table_row, start, true_len, write_from):
        """One chunk of a paged chunked prefill (B=1): see
        ``families.lm_prefill_chunk``.  Donates ``arena``; returns
        ``(logits (1, V), arena')``."""
        self._validate_tokens(tokens)
        return self._chunk(
            self.params, jnp.asarray(tokens, jnp.int32), arena, table_row,
            jnp.int32(start), jnp.int32(true_len), jnp.int32(write_from),
        )

    def bucket_len(self, n: int) -> int:
        """Smallest configured bucket >= n (the bucket set always covers
        max_len, and prime/prime_many reject prompts past it)."""
        for b in self._buckets:
            if b >= n:
                return b
        return n  # unreachable for admitted prompts; keeps the helper total

    # -- integrity / degradation ----------------------------------------------
    @property
    def quarantined(self) -> bool:
        return self._quarantined

    @property
    def packed_active(self) -> bool:
        """True while decode actually runs through the packed path."""
        return self._packed is not None and not self._quarantined

    def quarantine_packed(self) -> bool:
        """Permanently drop the packed decode path for this engine (called by
        the scheduler when a slot trips the non-finite guard under packed
        weights — DESIGN.md §9).  Dense weights are always resident, so the
        dense path needs no reload; the jitted entry points are re-wrapped so
        the trace-time packed/dense branch re-binds.  Returns True if the
        engine transitioned, False if there was nothing to quarantine."""
        if not self.packed_active:
            return False
        self._quarantined = True
        self._rejit_decode()
        return True

    def _rejit_decode(self) -> None:
        """Re-wrap the jitted decode entry points so the trace-time pack
        binding (the pack's arrays are closed over as constants) re-binds to
        the engine's current ``_packed`` / ``_quarantined`` state."""
        self._decode = jax.jit(self._decode_fn)
        self._decode_loop = jax.jit(self._decode_loop_fn, static_argnums=(4,))
        self._spec_loop = jax.jit(self._spec_loop_fn, static_argnums=(4,))
        self._prime_loop = jax.jit(self._prime_loop_fn)

    def reload_packed(self, params=None) -> bool:
        """Hot-swap the packed decode path (DESIGN.md §12): rebuild the pack
        from ``params`` (default: the engine's current params — e.g. after a
        quarantine, to re-arm the packed path from known-good weights),
        validate it, clear any quarantine, and re-jit the decode entry points
        so the new pack binds.  No fault corruption is applied — swapped-in
        packs are presumed clean; the runtime isfinite guard still covers
        them.  The caller must ensure no segment is in flight (the async
        engine drains first).  Returns False when the engine is not
        configured for packed weights (nothing to swap)."""
        if not self.sc.packed_weights:
            return False
        from .packed import validate_packed

        if params is not None:
            if self.mesh is not None:
                from ..dist.sharding import params_shardings

                params = jax.device_put(
                    params, params_shardings(self.model.specs(), self.mesh)
                )
            self.params = params
        host_params = jax.device_get(self.params)
        packed = self._build_pack(host_params)
        validate_packed(packed)
        self._packed = packed
        self._quarantined = False
        self._rejit_decode()
        return True

    def _validate_tokens(self, tokens) -> None:
        """Reject out-of-range token ids before they reach the embedding
        gather.  ``params["embed"][tokens]`` silently wraps negative ids and
        clamps ids >= vocab on accelerator backends, so a malformed prompt
        would otherwise generate from the wrong embedding row with no error
        anywhere downstream."""
        toks = np.asarray(tokens)
        bad = (toks < 0) | (toks >= self.cfg.vocab)
        if bad.any():
            idx = tuple(int(x) for x in np.argwhere(bad)[0])
            raise ValueError(
                f"token id {int(toks[idx])} at position {idx} is outside "
                f"[0, vocab={self.cfg.vocab})"
            )

    # -- reusable entry points (used by generate and serve/scheduler.py) ------
    def prime(self, prompts, key, extras: Optional[Dict] = None):
        """Run the prompt through the model: returns ``(first_token, cache,
        key)`` ready for decode.  ``prompts``: (B, S) int32.

        Prefill families (dense/moe/vlm/encdec) bulk-fill the KV cache and
        emit the argmax first token without consuming the key; recurrent
        families scan the prompt through decode steps, splitting the key per
        prompt token — both exactly as the seed host loop did, so the key
        stream stays bit-compatible across paths.
        """
        if self._prefill is not None and prompts.shape[1] > self.sc.max_len:
            raise ValueError(
                f"prompt length {prompts.shape[1]} exceeds max_len {self.sc.max_len}"
            )
        self._validate_tokens(prompts)
        batch = {"tokens": self._shard_batch(jnp.asarray(prompts))}
        if extras:
            batch.update({k: self._shard_batch(jnp.asarray(v)) for k, v in extras.items()})
        if self._prefill is not None:
            logits, cache = self._prefill(self.params, batch)
            nxt = jnp.argmax(logits.astype(jnp.float32), axis=-1)[:, None].astype(jnp.int32)
            # the recurrent paths below place their cache at init and keep
            # that placement through the loop; only the prefill output needs
            # an explicit move onto the serve shardings
            cache = self.shard_cache(cache, prompts.shape[0])
        elif self.sc.fused:
            cache = self.shard_cache(
                self.model.init_cache(prompts.shape[0], self.sc.max_len), prompts.shape[0]
            )
            nxt, cache, key = self._prime_loop(self.params, batch["tokens"], cache, key)
        else:
            # seed path: prime the state by stepping through the prompt
            cache = self.shard_cache(
                self.model.init_cache(prompts.shape[0], self.sc.max_len), prompts.shape[0]
            )
            nxt = jnp.asarray(prompts[:, :1])
            for t in range(prompts.shape[1]):
                key, sub = jax.random.split(key)
                tok = jnp.asarray(prompts[:, t : t + 1])
                nxt, cache, _ = self._decode(self.params, tok, cache, sub)
        return nxt, cache, key

    def prime_many(self, prompts, lengths):
        """Batched masked prefill of one length bucket: ``prompts`` (N, Sb)
        int32 right-padded to a shared bucket length, ``lengths`` (N,) true
        prompt lengths.  Returns ``(first_tokens (N, 1), batched cache)`` in a
        single dispatch; each row is bit-identical to ``prime`` of that row's
        unpadded prompt.  The cache's scalar ``pos`` holds the padded bucket
        length — scatter it with ``write_slots`` (which sets per-slot true
        ``pos``) before decoding.  Prefill LM families only (prefill ignores
        the PRNG key there; recurrent families prime per request)."""
        if self._prefill_masked is None:
            raise NotImplementedError(
                f"batched masked prefill unsupported for family {self.cfg.family!r}"
            )
        prompts = np.asarray(prompts, np.int32)
        if prompts.shape[1] > self.sc.max_len:
            raise ValueError(
                f"bucket length {prompts.shape[1]} exceeds max_len {self.sc.max_len}"
            )
        self._validate_tokens(prompts)
        return self._prefill_masked(
            self.params,
            {"tokens": self._shard_batch(jnp.asarray(prompts))},
            self._shard_batch(jnp.asarray(lengths, jnp.int32)),
        )

    def decode_segment(self, token, cache, key, steps: int):
        """``steps`` fused decode steps in one dispatch: returns
        ``(tokens (B, steps), ok (B, steps), last_token, cache, key)`` where
        ``ok[b, t]`` is the on-device integrity flag for row ``b`` at step
        ``t`` (False once logits go non-finite)."""
        return self._decode_loop(self.params, token, cache, key, steps)

    # -- public API -----------------------------------------------------------
    def generate(self, prompts: np.ndarray, max_new: int = 32, extras: Optional[Dict] = None):
        """prompts: (B, S) int32.  Returns dict with tokens and timing.

        Thin wrapper over ``prime`` + one full-length ``decode_segment``
        (a single-request schedule with one segment); the seed per-token
        host loop survives behind ``ServeConfig.fused = False`` as the
        parity oracle.  ``tok_per_s`` is the canonical serve metric
        (``serve.metrics.tok_per_s``): ACCEPTED tokens beyond the first
        (prefill-billed) one over decode wall time — identical on every
        path, speculative included.

        With ``ServeConfig.speculative`` (B=1 only) decode runs the fused
        draft/verify while_loop — still one dispatch — and the result dict
        additionally reports ``spec_rounds`` / ``spec_proposed`` /
        ``spec_accepted`` / ``acceptance_rate``.
        """
        from .metrics import acceptance_rate, tok_per_s

        b = prompts.shape[0]
        headroom = self.sc.draft_k if self.sc.speculative else 0
        if self._prefill is not None and (
            prompts.shape[1] + max_new + headroom > self.sc.max_len
        ):
            # without this, decode past max_len silently overwrites the last
            # KV row (attention_decode's dynamic_update_slice clamps its
            # write index) and corrupts every later token; a speculative
            # round additionally writes up to draft_k rows past the budget
            raise ValueError(
                f"prompt({prompts.shape[1]}) + max_new({max_new}) + "
                f"spec headroom({headroom}) = "
                f"{prompts.shape[1] + max_new + headroom} exceeds max_len "
                f"{self.sc.max_len}"
            )
        if self.sc.speculative and b != 1:
            raise ValueError(
                f"speculative generate serves B=1 (got batch {b}); the "
                "accept length is per-request — batch through the Scheduler"
            )
        key = jax.random.key(self.sc.seed)
        t0 = self._clock()
        nxt, cache, key = self.prime(prompts, key, extras)
        jax.block_until_ready(nxt)
        t_prefill = self._clock() - t0

        t0 = self._clock()
        if self.sc.speculative:
            buf, okb, count, rounds, *_ = self._spec_loop(
                self.params, nxt, cache, jax.random.key_data(key), max_new - 1
            )
            jax.block_until_ready(buf)
            t_decode = self._clock() - t0
            toks = np.asarray(buf)[: max_new - 1]
            tokens = np.concatenate([np.asarray(nxt), toks[None]], axis=1)
            finite = bool(np.asarray(okb)[: max_new - 1].all())
            count, rounds = int(count), int(rounds)
            k = self.sc.draft_k
            return {
                "tokens": tokens,
                "finite": finite,
                "prefill_s": t_prefill,
                "decode_s": t_decode,
                "tok_per_s": tok_per_s(max_new - 1, t_decode),
                "spec_rounds": rounds,
                "spec_proposed": rounds * k,
                # each round emits 1 verifier token + (nem-1) accepted drafts
                "spec_accepted": count - rounds,
                "acceptance_rate": acceptance_rate(count - rounds, rounds * k),
            }
        if self.sc.fused:
            toks, okg, _, cache, key = self.decode_segment(nxt, cache, key, max_new - 1)
            jax.block_until_ready(toks)
            t_decode = self._clock() - t0
            tokens = np.concatenate([np.asarray(nxt), np.asarray(toks)], axis=1)
            finite = bool(np.asarray(okg).all())
        else:
            out, finite = [np.asarray(nxt)], True
            for _ in range(max_new - 1):
                key, sub = jax.random.split(key)
                nxt, cache, ok = self._decode(self.params, nxt, cache, sub)
                out.append(np.asarray(nxt))
                finite = finite and bool(np.asarray(ok).all())
            jax.block_until_ready(nxt)
            t_decode = self._clock() - t0
            tokens = np.concatenate(out, axis=1)
        return {
            "tokens": tokens,
            "finite": finite,
            "prefill_s": t_prefill,
            "decode_s": t_decode,
            "tok_per_s": tok_per_s(b * (max_new - 1), t_decode),
        }
