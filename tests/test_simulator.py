"""Cycle-model tests: WS analytical formula invariants and the VUSA-vs-
standard relationships the paper's Tables II/III rest on."""

import numpy as np
import pytest

from repro.core.simulator import (
    Gemm,
    gemm_cycles_standard,
    gemm_cycles_vusa,
    model_cycles_vusa,
    ws_cycles,
)
from repro.core.workloads import mobilenetv1_gemms, resnet18_gemms


def test_ws_cycles_formula():
    # fill R + stream B + drain R + C - 2
    assert ws_cycles(B=1, R=1, C_arr=1) == 2  # 1 load + 1 compute
    assert ws_cycles(B=10, R=3, C_arr=3) == 2 * 3 + 3 + 10 - 2


def test_bigger_array_never_slower():
    g = Gemm(B=100, K=64, C=64)
    c = [gemm_cycles_standard(g, 3, m) for m in (3, 4, 5, 6)]
    assert c[0] > c[1] > c[2] > c[3]


def test_vusa_dense_equals_standard_na():
    """With zero sparsity VUSA degenerates to an N x A standard array."""
    rng = np.random.default_rng(0)
    g = Gemm(B=50, K=12, C=24)
    mask = np.ones((12, 24), dtype=bool)
    vusa_cycles, _ = gemm_cycles_vusa(g, mask, N=3, M=6, A=3)
    assert vusa_cycles == gemm_cycles_standard(g, 3, 3)


def test_vusa_high_sparsity_approaches_standard_nm():
    rng = np.random.default_rng(1)
    g = Gemm(B=50, K=12, C=24)
    mask = rng.random((12, 24)) > 0.97
    vusa_cycles, _ = gemm_cycles_vusa(g, mask, N=3, M=6, A=3)
    std_3x6 = gemm_cycles_standard(g, 3, 6)
    assert vusa_cycles <= 1.1 * std_3x6


def test_vusa_between_bounds():
    """VUSA cycles always within [standard N x M, standard N x A]."""
    rng = np.random.default_rng(2)
    g = Gemm(B=32, K=24, C=30)
    for sp in (0.3, 0.6, 0.85):
        mask = rng.random((24, 30)) > (1 - sp) if False else rng.random((24, 30)) < (1 - sp)
        cycles, _ = gemm_cycles_vusa(g, mask, N=3, M=6, A=3)
        assert gemm_cycles_standard(g, 3, 6) <= cycles <= gemm_cycles_standard(g, 3, 3)


def test_workload_shapes():
    rg = resnet18_gemms()
    mg = mobilenetv1_gemms()
    # ResNet-18: ~1.8 GMACs at 224x224; MobileNetV1: ~0.57 GMACs
    assert sum(g.macs for g in rg) / 1e9 == pytest.approx(1.81, abs=0.15)
    assert sum(g.macs for g in mg) / 1e9 == pytest.approx(0.57, abs=0.12)


def test_model_cycles_aggregate():
    gemms = [Gemm(B=10, K=6, C=12), Gemm(B=4, K=3, C=6)]
    masks = [np.ones((6, 12), bool), np.zeros((3, 6), bool)]
    stats = model_cycles_vusa(gemms, masks, 3, 6, 3)
    assert stats.cycles > 0 and stats.jobs > 0
    split = stats.load_split()
    assert split.sum() == pytest.approx(1.0)
