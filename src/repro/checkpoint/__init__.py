from .ckpt import Checkpointer, latest_step, restore, save  # noqa: F401
