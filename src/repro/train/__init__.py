from .step import TrainHParams, make_train_step  # noqa: F401
from .trainer import TrainConfig, Trainer  # noqa: F401
