"""Assemble EXPERIMENTS.md from the experiment artifacts (dry-run records,
roofline JSONs, benchmark tables, training log) + the hand-written §Perf
iteration narrative."""

import json
import sys
from pathlib import Path

sys.path.insert(0, "src")

ROOT = Path(__file__).resolve().parents[1]
PEAK, HBM, LINK = 197e12, 819e9, 50e9


def load(p):
    return json.loads((ROOT / p).read_text())


def roofline_rows(mesh="single", suffix=""):
    from repro.launch.roofline import analyze_record

    rows = []
    for f in sorted((ROOT / "experiments/dryrun").glob(f"*__{mesh}{suffix}.json")):
        rec = json.loads(f.read_text())
        if rec.get("status") == "ok" and "weighted" in rec:
            rows.append(analyze_record(rec))
        elif rec.get("status") == "skip":
            rows.append({"arch": rec["arch"], "shape": rec["shape"], "dominant": "skip"})
    return rows


def fmt_roofline(rows):
    out = ["| arch | shape | compute (s) | memory (s) | collective (s) | dominant | MODEL/HLO | roofline frac |",
           "|---|---|---|---|---|---|---|---|"]
    for r in sorted(rows, key=lambda r: (r["arch"], r["shape"])):
        if r["dominant"] == "skip":
            out.append(f"| {r['arch']} | {r['shape']} | — | — | — | *skip (full attn @500k)* | — | — |")
        else:
            out.append(
                f"| {r['arch']} | {r['shape']} | {r['compute_s']:.4f} | {r['memory_s']:.4f} | "
                f"{r['collective_s']:.4f} | {r['dominant']} | {r['useful_ratio']} | {r['roofline_frac']:.4f} |")
    return "\n".join(out)


def dryrun_summary(mesh):
    recs = [json.loads(f.read_text()) for f in sorted((ROOT / "experiments/dryrun").glob(f"*__{mesh}.json"))]
    ok = [r for r in recs if r["status"] == "ok"]
    skip = [r for r in recs if r["status"] == "skip"]
    fail = [r for r in recs if r["status"] == "fail"]
    lines = [f"**{mesh} mesh:** {len(ok)} cells compiled OK, {len(skip)} documented skips, {len(fail)} failures.", ""]
    lines += ["| arch | shape | compile (s) | temp bytes/dev | args bytes/dev | per-dev dot FLOPs | collective bytes/dev |",
              "|---|---|---|---|---|---|---|"]
    for r in sorted(ok, key=lambda r: (r["arch"], r["shape"])):
        cb = sum(e["bytes"] for e in r["weighted"]["collectives"].values())
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['compile_s']} | "
            f"{r['memory']['temp_size_in_bytes']/2**30:.2f} GiB | "
            f"{r['memory']['argument_size_in_bytes']/2**30:.2f} GiB | "
            f"{r['weighted']['dot_flops']:.3g} | {cb:.3g} |")
    return "\n".join(lines)


def opt_vs_baseline():
    cells = [("qwen2_0_5b", "train_4k"), ("llama3_2_1b", "decode_32k"), ("olmoe_1b_7b", "train_4k"),
             ("qwen3_8b", "train_4k")]
    out = ["| cell | variant | compute (s) | memory (s) | collective (s) | bound (s) | bound speedup |",
           "|---|---|---|---|---|---|---|"]
    for arch, shape in cells:
        vals = {}
        for suf, name in (("", "baseline"), ("_opt", "optimized")):
            p = ROOT / f"experiments/dryrun/{arch}__{shape}__single{suf}.json"
            if not p.exists():
                continue
            w = json.loads(p.read_text())["weighted"]
            cb = sum(e["bytes"] for e in w["collectives"].values())
            t = (w["dot_flops"] / PEAK, w["bytes"] / HBM, cb / LINK)
            vals[name] = t
        if "baseline" not in vals or "optimized" not in vals:
            continue
        b, o = max(vals["baseline"]), max(vals["optimized"])
        for name in ("baseline", "optimized"):
            t = vals[name]
            out.append(f"| {arch} {shape} | {name} | {t[0]:.4f} | {t[1]:.4f} | {t[2]:.4f} | "
                       f"{max(t):.4f} | {'—' if name == 'baseline' else f'{b/o:.1f}x'} |")
    return "\n".join(out)


def bench_tables():
    t2 = load("experiments/benchmarks/table2_resnet18.json")
    t3 = load("experiments/benchmarks/table3_mobilenet.json")

    def fmt(t, paper):
        out = ["| design | cycles | GOP/s | perf/area | perf/power | energy |", "|---|---|---|---|---|---|"]
        for k in ("standard_3x3", "standard_3x4", "standard_3x5", "standard_3x6", "vusa_3x6"):
            r = t[k]
            out.append(f"| {k} | {r['cycles']:.3g} | {r['gops']:.2f} | {r['perf_per_area']:.2f} | "
                       f"{r['perf_per_power']:.2f} | {r['energy']:.2f} |")
        p = t["paper_vusa"]
        out.append(f"| *paper VUSA* | *{p['cycles']:.3g}* | *{p['gops']}* | *{p['perf_per_area']}* | "
                   f"*{p['perf_per_power']}* | *{p['energy']}* |")
        out.append("")
        out.append(f"Load split (ours): width-6 share {t['vusa_3x6']['load_split'][6]:.3f} "
                   f"(paper {p['load6']}).")
        return "\n".join(out)

    return fmt(t2, None), fmt(t3, None)


def train_metrics():
    p = ROOT / "experiments/train_run/metrics.json"
    if not p.exists():
        return "*(training run still in progress at document build time — see experiments/train_run/train.log)*"
    m = load("experiments/train_run/metrics.json")
    first = m["log"][0]["loss"]
    return (f"vusa-edge (~{m['params_m']:.0f}M params): {m['steps']} steps, loss {first:.2f} -> "
            f"{m['final_loss']:.2f}, final sparsity {m['final_sparsity']:.1%}, "
            f"{m['tokens_per_s']:.0f} tok/s on 1 CPU core, checkpoint/restart exercised.")


def main():
    t1 = load("experiments/benchmarks/table1_area_power.json")
    fig6 = load("experiments/benchmarks/fig6_growth.json")["anchors"]
    sweep = load("experiments/benchmarks/fig89_pruning_sweep.json")
    kern = load("experiments/benchmarks/kernel_vusa_packed.json")
    t2md, t3md = bench_tables()

    t1md = ["| design | #MACs | area (ours) | area (paper) | power (ours) | power (paper) |",
            "|---|---|---|---|---|---|"]
    for k, r in t1.items():
        t1md.append(f"| {k} | {r['macs']} | {r['area']:.3f} | {r['area_paper']} | "
                    f"{r['power']:.3f} | {r['power_paper']} |")
    t1md = "\n".join(t1md)

    doc = TEMPLATE.format(
        fig6=", ".join(f"{k} = {v:.3f}" for k, v in fig6.items()),
        table1=t1md,
        table2=t2md,
        table3=t3md,
        sweep_area=sweep["area_eff"][-1], sweep_power=sweep["power_eff"][-1],
        a_cross=sweep["area_crossover"], p_cross=sweep["power_crossover"],
        kern85=kern["sparsity_0.85"]["byte_ratio"], kern95=kern["sparsity_0.95"]["byte_ratio"],
        kern0=kern["sparsity_0.0"]["byte_ratio"],
        dryrun_single=dryrun_summary("single"),
        dryrun_multi=dryrun_summary("multi"),
        roofline=fmt_roofline(roofline_rows("single")),
        roofline_opt=fmt_roofline(roofline_rows("single", "_opt")),
        opt_vs_base=opt_vs_baseline(),
        train=train_metrics(),
    )
    (ROOT / "EXPERIMENTS.md").write_text(doc)
    print("EXPERIMENTS.md written", len(doc), "chars")


TEMPLATE = open(Path(__file__).resolve().parent / "experiments_template.md").read()

if __name__ == "__main__":
    main()
