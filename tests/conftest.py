"""Test-process environment: force a multi-device CPU backend.

The sharded-serving tests (tests/test_serve_sharded.py, DESIGN.md §8) need a
real device mesh, and CI has no accelerators — so the suite runs under
``--xla_force_host_platform_device_count=8`` (2x4 is the largest mesh the
differential tests drive).  The flag must be set *before* jax initialises its
backend, and pytest imports conftest.py before any test module, so this is
the one reliable place to set it without spawning every mesh test into a
subprocess.

``REPRO_SINGLE_DEVICE=1`` opts out (the CI matrix runs one such leg to cover
the single-device degenerate path); tests that need a mesh skip themselves
via :func:`requires_devices`.  Unrelated tests are unaffected either way:
un-sharded computations run on device 0 regardless of how many host devices
exist.
"""

import os
import sys

if os.environ.get("REPRO_SINGLE_DEVICE") != "1":
    _flags = os.environ.get("XLA_FLAGS", "")
    if "xla_force_host_platform_device_count" not in _flags:
        assert "jax" not in sys.modules, (
            "conftest.py must run before jax is imported to force the "
            "multi-device CPU backend (a plugin imported jax too early?)"
        )
        os.environ["XLA_FLAGS"] = (
            _flags + " --xla_force_host_platform_device_count=8"
        ).strip()

import pytest  # noqa: E402


def requires_devices(n: int):
    """Skip-marker for tests that need at least ``n`` devices (e.g. under
    REPRO_SINGLE_DEVICE=1, or a hand-set XLA_FLAGS without the force flag)."""
    import jax

    return pytest.mark.skipif(
        len(jax.devices()) < n, reason=f"needs >= {n} devices, have {len(jax.devices())}"
    )
