"""End-to-end behaviour tests for the paper's system: the full VUSA loop —
train with iterative pruning -> pack weights into the VUSA format -> serve
with the packed kernel -> identical greedy outputs, at the efficiency the
growth model predicts."""

import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.core.growth import p_row_gain
from repro.serve import Engine, ServeConfig
from repro.train import TrainConfig, Trainer, TrainHParams


@pytest.fixture(scope="module")
def trained():
    cfg = get_smoke_config("vusa_edge")
    tc = TrainConfig(
        steps=12,
        global_batch=4,
        seq_len=32,
        prune_begin=4,
        prune_end=10,
        prune_every=2,
        hp=TrainHParams(lr=1e-3, warmup=2, total_steps=12),
        log_every=100,
    )
    out = Trainer(cfg, tc).train()
    return cfg, out


def test_end_to_end_sparsity(trained):
    _, out = trained
    assert out["sparsity"] == pytest.approx(0.85, abs=0.02)


def test_end_to_end_packed_serving_matches_dense(trained):
    cfg, out = trained
    prompts = np.ones((2, 8), np.int32)
    dense = Engine(cfg, out["params"], ServeConfig(max_len=64)).generate(prompts, max_new=8)
    packed = Engine(cfg, out["params"], ServeConfig(max_len=64, packed_mlp=True)).generate(
        prompts, max_new=8
    )
    np.testing.assert_array_equal(dense["tokens"], packed["tokens"])


def test_end_to_end_byte_savings_track_growth_model(trained):
    """The packed model's byte ratio should be consistent with the growth
    model's prediction at the trained sparsity level."""
    cfg, out = trained
    from repro.serve.packed import pack_lm_mlps

    packed = pack_lm_mlps(cfg, out["params"], m=128, a=32)
    total_packed = total_dense = 0
    for name in ("w_gate", "w_up", "w_down"):
        v = packed[name]["values"]  # (L, T, K, S)
        total_packed += v.size * (v.dtype.itemsize + 1)
        total_dense += v.shape[0] * packed[name]["k"] * packed[name]["c"] * v.dtype.itemsize
    ratio = total_packed / total_dense
    # at 85% sparsity, P(row fits 32 slots of 128) ~ 1 -> 1 job -> ratio ~
    # 32*(4+1)/(128*4) = 0.3125 with fp32 values
    assert ratio < 0.5, ratio
    assert p_row_gain(128, 32, 0.15) > 0.99
