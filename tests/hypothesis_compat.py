"""Optional-``hypothesis`` shim for the property-based tests.

``from hypothesis_compat import given, settings, st`` behaves exactly like
the real hypothesis when it is installed; otherwise the ``@given`` tests are
collected but skipped (the example-based tests in the same modules still
run).  Keeps the suite collectable on minimal images (see requirements.txt).
"""

try:
    from hypothesis import given, settings  # noqa: F401
    from hypothesis import strategies as st  # noqa: F401

    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover - exercised only without hypothesis
    import functools

    import pytest

    HAVE_HYPOTHESIS = False

    def given(*_args, **_kwargs):
        def deco(fn):
            @pytest.mark.skip(reason="hypothesis not installed")
            @functools.wraps(fn)
            def stub(*a, **k):
                pass

            return stub

        return deco

    def settings(*_args, **_kwargs):
        return lambda fn: fn

    class _Strategies:
        """Stand-in for hypothesis.strategies: strategy builders return None
        (they are only ever passed to the skipping ``given`` above)."""

        def __getattr__(self, name):
            return lambda *a, **k: None

    st = _Strategies()
