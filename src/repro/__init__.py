"""VUSA reproduction package.

One process-global knob lives here: partitionable threefry.  The serving
stack samples tokens *inside* sharded programs (DESIGN.md §8), and under the
legacy threefry lowering the random bits an op produces depend on the
sharding GSPMD picks for it — the same ``jax.random.categorical(sub, logits)``
emits different tokens in a mesh-partitioned decode loop than in the
single-device one, for the same key.  ``jax_threefry_partitionable`` is the
upstream fix (and the default in newer jax): bits become a pure function of
key and position, invariant to sharding, so sharded and single-device decode
are bit-identical stream-for-stream.  It must be set process-wide before any
key is used — flipping it per-engine would make token streams depend on
construction order — which is why it lives in the package root and not in
``serve.Engine``.
"""

import jax

jax.config.update("jax_threefry_partitionable", True)
