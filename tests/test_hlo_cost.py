"""Roofline harness unit tests: the weighted HLO cost parser must count
loop-trip-multiplied dot flops / bytes / collectives exactly on known
programs (this is what the whole §Roofline table rests on)."""

import jax
import jax.numpy as jnp

from repro.launch.hlo_cost import hlo_cost, parse_hlo


def _compile(f, *args):
    return jax.jit(f).lower(*args).compile().as_text()


def test_single_matmul_flops():
    a = jax.ShapeDtypeStruct((64, 128), jnp.float32)
    b = jax.ShapeDtypeStruct((128, 256), jnp.float32)
    r = hlo_cost(_compile(lambda x, y: x @ y, a, b))
    assert r["dot_flops"] == 2 * 64 * 128 * 256


def test_scan_multiplies_trip_count():
    def g(x, w):
        def body(c, _):
            return jnp.tanh(c @ w), None

        y, _ = jax.lax.scan(body, x, None, length=10)
        return y

    a = jax.ShapeDtypeStruct((128, 128), jnp.float32)
    r = hlo_cost(_compile(g, a, a))
    assert r["dot_flops"] == 10 * 2 * 128**3
    assert r["transcendentals"] == 10 * 128 * 128


def test_nested_scans_multiply():
    def g(x, w):
        def outer(c, _):
            def inner(ci, _):
                return ci @ w, None

            ci, _ = jax.lax.scan(inner, c, None, length=3)
            return ci, None

        y, _ = jax.lax.scan(outer, x, None, length=5)
        return y

    a = jax.ShapeDtypeStruct((64, 64), jnp.float32)
    r = hlo_cost(_compile(g, a, a))
    assert r["dot_flops"] == 15 * 2 * 64**3


def test_remat_counted():
    """jax.checkpoint recompute must show up as extra flops (this is the
    MODEL_FLOPS / HLO_FLOPS 'useful fraction' signal)."""

    def loss(w, x):
        h = jax.checkpoint(lambda x: jnp.tanh(x @ w))(x)
        return jnp.sum(jnp.tanh(h @ w))

    w = jax.ShapeDtypeStruct((128, 128), jnp.float32)
    x = jax.ShapeDtypeStruct((128, 128), jnp.float32)
    base = hlo_cost(_compile(lambda w, x: jax.grad(loss)(w, x), w, x))["dot_flops"]
    # fwd 2 dots + bwd >= 3 dots (XLA CSE may dedupe the remat recompute);
    # the point is that backward dots ARE counted, not just the forward
    assert base >= 5 * 2 * 128**3


def test_parse_hlo_finds_computations():
    t = _compile(lambda x: x + 1, jax.ShapeDtypeStruct((8,), jnp.float32))
    comps = parse_hlo(t)
    assert len(comps) >= 1
