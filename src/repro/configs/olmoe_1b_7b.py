"""olmoe-1b-7b [moe]: 16L d_model=2048 16H (GQA kv=16) d_ff=1024
vocab=50304, 64 experts top-8 [arXiv:2409.02060]."""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="olmoe-1b-7b", family="moe",
    n_layers=16, d_model=2048, n_heads=16, kv_heads=16, d_ff=1024,
    vocab=50304, n_experts=64, top_k=8, sparsity=0.85,
)

SMOKE = ArchConfig(
    name="olmoe-smoke", family="moe",
    n_layers=2, d_model=64, n_heads=4, kv_heads=4, d_ff=32,
    vocab=512, n_experts=8, top_k=2, moe_cf=4.0, sparsity=0.85, dtype="float32",
    remat=False,
)
