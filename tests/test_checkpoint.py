"""Checkpoint subsystem: roundtrip, retention, atomicity, latest-step, and
corruption detection (truncated / bit-flipped leaf files must raise, never
restore garbage params — DESIGN.md §9)."""

import json

from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import Checkpointer, latest_step, restore, save


def _tree(seed=0):
    k = jax.random.key(seed)
    return {
        "a": jax.random.normal(k, (4, 8)),
        "nested": {"b": jnp.arange(10, dtype=jnp.int32), "c": jnp.float32(3.5)},
    }


def test_roundtrip(tmp_path):
    t = _tree()
    save(tmp_path, 3, t)
    assert latest_step(tmp_path) == 3
    got = restore(tmp_path, 3, jax.tree_util.tree_map(jnp.zeros_like, t))
    for a, b in zip(jax.tree_util.tree_leaves(t), jax.tree_util.tree_leaves(got)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_retention_and_latest(tmp_path):
    ck = Checkpointer(tmp_path, keep=2, async_save=False)
    for s in (1, 2, 3, 4):
        ck.save(s, _tree(s))
    steps = sorted(int(p.name[5:]) for p in Path(tmp_path).glob("step_*"))
    assert steps == [3, 4]


def test_restore_latest_empty(tmp_path):
    ck = Checkpointer(tmp_path)
    step, tree = ck.restore_latest({"x": jnp.zeros(3)})
    assert step is None


def test_async_save_then_wait(tmp_path):
    ck = Checkpointer(tmp_path, async_save=True)
    ck.save(7, _tree())
    ck.wait()
    assert latest_step(tmp_path) == 7


def test_partial_write_is_invisible(tmp_path):
    """A crashed (tmp) write must not be picked up as a checkpoint."""
    save(tmp_path, 1, _tree())
    bad = Path(tmp_path) / ".tmp_step_00000002"
    bad.mkdir()
    (bad / "leaf_00000.npy").write_bytes(b"junk")
    assert latest_step(tmp_path) == 1


def test_missing_leaf_raises(tmp_path):
    save(tmp_path, 1, {"a": jnp.zeros(3)})
    with pytest.raises(KeyError):
        restore(tmp_path, 1, {"a": jnp.zeros(3), "extra": jnp.zeros(2)})


# ---------------------------------------------------------------------------
# corruption detection
# ---------------------------------------------------------------------------


def test_truncated_leaf_raises(tmp_path):
    t = _tree()
    d = save(tmp_path, 1, t)
    f = d / "leaf_00000.npy"
    f.write_bytes(f.read_bytes()[:-8])
    with pytest.raises(ValueError, match="truncated"):
        restore(tmp_path, 1, jax.tree_util.tree_map(jnp.zeros_like, t))


def test_bit_flip_raises(tmp_path):
    t = _tree()
    d = save(tmp_path, 1, t)
    f = d / "leaf_00000.npy"
    raw = bytearray(f.read_bytes())
    raw[-1] ^= 0x40  # flip one payload bit: same length, wrong bytes
    f.write_bytes(bytes(raw))
    with pytest.raises(ValueError, match="CRC"):
        restore(tmp_path, 1, jax.tree_util.tree_map(jnp.zeros_like, t))


def test_deleted_leaf_file_raises(tmp_path):
    t = _tree()
    d = save(tmp_path, 1, t)
    (d / "leaf_00000.npy").unlink()
    with pytest.raises(ValueError, match="missing"):
        restore(tmp_path, 1, jax.tree_util.tree_map(jnp.zeros_like, t))


def test_legacy_manifest_without_crc_restores(tmp_path):
    """Checkpoints written before the CRC field existed must stay readable:
    strip the integrity keys from the manifest and restore anyway."""
    t = _tree()
    d = save(tmp_path, 1, t)
    mf = d / "manifest.json"
    manifest = json.loads(mf.read_text())
    for entry in manifest["leaves"].values():
        entry.pop("crc32", None)
        entry.pop("nbytes", None)
    mf.write_text(json.dumps(manifest))
    got = restore(tmp_path, 1, jax.tree_util.tree_map(jnp.zeros_like, t))
    for a, b in zip(jax.tree_util.tree_leaves(t), jax.tree_util.tree_leaves(got)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
