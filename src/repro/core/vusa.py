"""VUSA window scheduler — the paper's core contribution (Section III).

A VUSA row has ``M`` SPEs (pipeline registers) but only ``A`` MAC units.
MAC ``j`` (``j in [0, A)``) can be multiplexed onto SPEs ``[j, j + M - A]``
(a one-directional shifter of ``M - A`` positions; Fig. 5 of the paper).

A column *window* of width ``w`` (``A <= w <= M``) is feasible for an
``N``-row weight tile iff every row has at most ``A`` non-zero weights inside
the window **and** an injective MAC->SPE assignment within shift range exists
for each row.  The scheduler walks the columns left to right, greedily taking
the widest feasible window (paper: "starting with an N x (M-1) window, then
N x (M-2), and so on down to N x A, at which the conditions are guaranteed").

Everything here is plain numpy — this is the *semantic* layer used by the
cycle simulator, the packing code and the tests.  The TPU-adapted block
variant lives in :mod:`repro.core.packing`.
"""

from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence

import numpy as np

__all__ = [
    "mac_assignment",
    "row_feasible",
    "window_feasible",
    "schedule_row_tile",
    "schedule_matrix",
    "Job",
    "Schedule",
    "load_split",
    "virtual_speedup",
]


def mac_assignment(positions: Sequence[int], M: int, A: int) -> Optional[np.ndarray]:
    """Assign MAC units to non-zero SPE positions of one row window.

    ``positions`` are the non-zero column offsets inside the window
    (``0 <= p < w <= M``).  MAC ``j`` may serve SPEs ``[j, j + M - A]``.
    Returns an int array ``macs`` with ``macs[i]`` = MAC index for
    ``positions[i]``, or ``None`` when no injective in-range assignment
    exists.  Greedy smallest-feasible-MAC on ascending positions is optimal
    for interval constraints of this staircase form.
    """
    if len(positions) > A:
        return None
    shift = M - A
    macs = np.empty(len(positions), dtype=np.int64)
    next_free = 0
    for i, p in enumerate(sorted(positions)):
        lo = max(next_free, p - shift)
        if lo > min(p, A - 1):
            return None
        macs[i] = lo
        next_free = lo + 1
    return macs


def row_feasible(row_mask: np.ndarray, M: int, A: int) -> bool:
    """True iff one row window (bool mask of width ``w <= M``) fits A MACs."""
    positions = np.flatnonzero(row_mask)
    return mac_assignment(positions, M, A) is not None


def window_feasible(mask: np.ndarray, M: int, A: int) -> bool:
    """True iff every row of an (N, w) bool window is feasible."""
    counts = mask.sum(axis=1)
    if (counts > A).any():
        return False
    # Per-row shifter feasibility.  For windows narrower than M the shifter
    # condition is weaker (positions < w <= M), so checking against M is exact.
    return all(row_feasible(mask[r], M, A) for r in np.flatnonzero(counts > 0))


@dataclasses.dataclass(frozen=True)
class Job:
    """One VUSA job: an ``N x width`` window starting at column ``start``."""

    start: int
    width: int


@dataclasses.dataclass
class Schedule:
    """Full schedule for a weight matrix on a (N, M, A) VUSA."""

    N: int
    M: int
    A: int
    rows: int
    cols: int
    # jobs[t] = list of Jobs for row-tile t (rows t*N:(t+1)*N)
    jobs: List[List[Job]]

    @property
    def n_jobs(self) -> int:
        return sum(len(j) for j in self.jobs)

    def widths(self) -> np.ndarray:
        return np.array([job.width for tile in self.jobs for job in tile], dtype=np.int64)


def schedule_row_tile(mask: np.ndarray, M: int, A: int) -> List[Job]:
    """Greedy widest-window partition of an (N, C) bool mask into jobs."""
    n, c = mask.shape
    jobs: List[Job] = []
    start = 0
    while start < c:
        w = min(M, c - start)
        while w > A and not window_feasible(mask[:, start : start + w], M, A):
            w -= 1
        jobs.append(Job(start, w))
        start += w
    return jobs


def schedule_matrix(mask: np.ndarray, N: int, M: int, A: int) -> Schedule:
    """Schedule a full (K, C) weight mask on an (N, M, A) VUSA.

    The matrix is split into row tiles of N (the physical array height); each
    tile is independently partitioned into column windows.
    """
    k, c = mask.shape
    jobs = []
    for t0 in range(0, k, N):
        jobs.append(schedule_row_tile(mask[t0 : t0 + N], M, A))
    return Schedule(N=N, M=M, A=A, rows=k, cols=c, jobs=jobs)


def load_split(schedule: Schedule) -> np.ndarray:
    """Fraction of the matrix *columns covered* per window width.

    Returns an array ``split`` of length ``M + 1`` with ``split[w]`` = fraction
    of total (row-tile, column) load processed by windows of width ``w``.
    This is the paper's "load split" column of Tables II/III.
    """
    split = np.zeros(schedule.M + 1)
    total = 0
    for tile in schedule.jobs:
        for job in tile:
            split[job.width] += job.width
            total += job.width
    return split / max(total, 1)


def virtual_speedup(schedule: Schedule) -> float:
    """Throughput gain vs. running the same matrix on a plain N x A array.

    A plain N x A array needs ``ceil(C / A)`` jobs per row tile; VUSA needs
    ``len(jobs)``.  (Job *duration* is width-independent to first order — the
    stream length dominates — so job count is the right ratio; the cycle-exact
    comparison lives in :mod:`repro.core.simulator`.)
    """
    import math

    dense_jobs = math.ceil(schedule.cols / schedule.A) * len(schedule.jobs)
    return dense_jobs / max(schedule.n_jobs, 1)


def schedule_widths_fast(mask: np.ndarray, N: int, M: int, A: int):
    """Vectorised scheduler for large matrices: returns (width histogram,
    jobs per tile).  Uses the count-only feasibility condition — exact,
    because the shifter assignment is always feasible when every row has
    <= A non-zeros (property-tested in tests/test_vusa_core.py; staircase
    Hall condition)."""
    k, c = mask.shape
    hist = np.zeros(M + 1, dtype=np.int64)
    per_tile_jobs = []
    cs = np.zeros((k, c + 1), dtype=np.int32)
    np.cumsum(mask, axis=1, out=cs[:, 1:])
    for t0 in range(0, k, N):
        tile = cs[t0 : t0 + N]
        start = 0
        n_jobs = 0
        while start < c:
            w = min(M, c - start)
            base = tile[:, start]
            while w > A and int((tile[:, start + w] - base).max()) > A:
                w -= 1
            hist[w] += 1
            n_jobs += 1
            start += w
        per_tile_jobs.append(n_jobs)
    return hist, per_tile_jobs
