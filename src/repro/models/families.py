"""Model families: decoder-LM (dense / MoE / VLM), hybrid (Griffin),
SSM (Mamba-2), encoder-decoder (Whisper).

A family provides:
  specs(cfg)                          ParamSpec tree (layer-stacked)
  forward(params, batch, cfg)         logits for teacher-forced tokens
  loss(params, batch, cfg)            scalar LM loss (+ MoE aux)
  init_cache(cfg, batch, max_len)     decode cache pytree (zeros)
  cache_specs(cfg, batch, max_len)    ShapeDtypeStruct twin of init_cache
  prefill(params, tokens, cfg)        run prompt, return (logits_last, cache)
  decode_step(params, token, cache, cfg)  one-token step

Layer parameters carry a leading "layers" axis and run under ``lax.scan``
(small HLO, fast multi-pod compiles); ``cfg.remat`` wraps the layer body in
``jax.checkpoint`` for training.
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from . import ssm as ssm_mod
from .common import ParamSpec, rms_norm, shard
from .layers import (
    MaskSpec,
    attention,
    attention_decode,
    attention_specs,
    cross_attention,
    encode_cross_kv,
    mlp,
    mlp_specs,
    moe,
    moe_specs,
)

# --------------------------------------------------------------------------
# helpers
# --------------------------------------------------------------------------


def _stack_specs(tree, n: int):
    """Add a leading `layers` axis of size n to every ParamSpec leaf."""
    return jax.tree_util.tree_map(
        lambda s: ParamSpec((n,) + s.shape, (None,) + s.axes, s.dtype, s.init, s.scale),
        tree,
        is_leaf=lambda x: isinstance(x, ParamSpec),
    )


def _act_dtype(cfg):
    return jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32


def _xent(logits: jax.Array, labels: jax.Array, vocab: int) -> jax.Array:
    """Mean next-token cross-entropy; ids >= vocab (padding) are masked."""
    from .opt_flags import FLAGS

    mask = (labels >= 0) & (labels < vocab)
    labels = jnp.clip(labels, 0, vocab - 1)
    if FLAGS["xent_lse"]:
        # logsumexp form: no fp32 (B,S,V) log-softmax tensor; picked logits
        # and the reduction run in fp32, the big tensor stays in model dtype
        lse = jax.nn.logsumexp(logits.astype(jnp.float32), axis=-1)
        picked = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
        ll = picked.astype(jnp.float32) - lse
    else:
        logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
        ll = jnp.take_along_axis(logp, labels[..., None], axis=-1)[..., 0]
    return -(ll * mask).sum() / jnp.maximum(mask.sum(), 1)


# --------------------------------------------------------------------------
# Decoder-only LM (dense / moe / vlm)
# --------------------------------------------------------------------------


def _lm_layer_specs(cfg) -> dict:
    d = cfg.d_model
    specs = {
        "norm1": ParamSpec((d,), ("embed",), init="zeros"),
        "attn": attention_specs(cfg),
        "norm2": ParamSpec((d,), ("embed",), init="zeros"),
    }
    specs["ffn"] = moe_specs(cfg) if cfg.family == "moe" else mlp_specs(cfg)
    return specs


def lm_specs(cfg) -> dict:
    d, v = cfg.d_model, cfg.padded_vocab
    specs = {
        "embed": ParamSpec((v, d), ("vocab", "embed"), scale=1.0),
        "layers": _stack_specs(_lm_layer_specs(cfg), cfg.n_layers),
        "final_norm": ParamSpec((d,), ("embed",), init="zeros"),
    }
    if not cfg.tie_embeddings:
        specs["lm_head"] = ParamSpec((d, v), ("embed", "vocab"))
    if cfg.family == "vlm":
        specs["patch_proj"] = ParamSpec((d, d), ("embed", "embed2"))
    return specs


def _lm_layer(lp, x, cfg, mask: MaskSpec, positions, kv_valid=None):
    h = rms_norm(x, lp["norm1"])
    x = x + attention(lp["attn"], h, cfg, mask, positions, kv_valid=kv_valid)
    x = shard(x, "batch", None, "embed")
    h = rms_norm(x, lp["norm2"])
    if cfg.family == "moe":
        y, aux = moe(lp["ffn"], h, cfg)
    else:
        y, aux = mlp(lp["ffn"], h), 0.0
    return x + y, aux


def _lm_backbone(params, x, cfg, mask: MaskSpec, positions):
    layer = partial(_lm_layer, cfg=cfg, mask=mask, positions=positions)
    if cfg.remat:
        layer = jax.checkpoint(layer)

    def body(carry, lp):
        x, aux = carry
        x, a = layer(lp, x)
        return (x, aux + a), None

    (x, aux), _ = jax.lax.scan(body, (x, 0.0), params["layers"])
    return rms_norm(x, params["final_norm"]), aux


def _embed_tokens(params, tokens, cfg):
    x = params["embed"][tokens].astype(_act_dtype(cfg))
    return x * (cfg.d_model ** 0.5 if cfg.family in ("vlm",) else 1.0)


def _lm_inputs(params, batch, cfg):
    """Build (x, mask, positions) from a batch; handles the VLM patch prefix."""
    tokens = batch["tokens"]
    x = _embed_tokens(params, tokens, cfg)
    if cfg.family == "vlm":
        patches = batch["patches"].astype(_act_dtype(cfg))  # (B, P, d) stub frontend
        patches = patches @ params["patch_proj"].astype(patches.dtype)
        x = jnp.concatenate([patches, x], axis=1)
        mask = MaskSpec("prefix", prefix_len=cfg.patch_tokens)
    else:
        mask = MaskSpec("causal")
    positions = jnp.arange(x.shape[1])
    return x, mask, positions


def lm_forward(params, batch, cfg):
    x, mask, positions = _lm_inputs(params, batch, cfg)
    x = shard(x, "batch", None, "embed")
    x, aux = _lm_backbone(params, x, cfg, mask, positions)
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = jnp.einsum("bsd,dv->bsv", x, head.astype(x.dtype))
    if cfg.family == "vlm":  # only text positions produce logits
        logits = logits[:, cfg.patch_tokens :]
    return logits, aux


def lm_loss(params, batch, cfg):
    logits, aux = lm_forward(params, batch, cfg)
    return _xent(logits[:, :-1], batch["tokens"][:, 1:], cfg.vocab) + 0.01 * aux


# ---- decode ----------------------------------------------------------------


def lm_cache_specs(cfg, batch: int, max_len: int):
    kvh, hd = cfg.kv_heads, cfg.hd
    kv = jax.ShapeDtypeStruct((cfg.n_layers, batch, max_len, kvh, hd), _act_dtype(cfg))
    return {
        "k": kv,
        "v": kv,
        "pos": jax.ShapeDtypeStruct((), jnp.int32),
    }


def lm_init_cache(cfg, batch: int, max_len: int):
    return jax.tree_util.tree_map(
        lambda s: jnp.zeros(s.shape, s.dtype), lm_cache_specs(cfg, batch, max_len)
    )


def _lm_decode_layer(lp, x, cache_l, cfg, pos):
    h = rms_norm(x, lp["norm1"])
    y, new_cache = attention_decode(lp["attn"], h, cfg, {**cache_l, "pos": pos})
    x = x + y
    h = rms_norm(x, lp["norm2"])
    if cfg.family == "moe":
        y, _ = moe(lp["ffn"], h, cfg)
    else:
        y = mlp(lp["ffn"], h)
    if "k_new" in new_cache:  # paged: pending row writes, not a full cache
        return x + y, {"k_new": new_cache["k_new"], "v_new": new_cache["v_new"]}
    return x + y, {"k": new_cache["k"], "v": new_cache["v"]}


def lm_decode_step(params, token, cache, cfg):
    """token: (B, s) int32 (s = 1 normal decode; s > 1 speculative verify).
    Returns (logits (B, s, V), new cache).

    With ``s > 1`` the step runs as an unrolled chain of the exact
    single-token step inside the one dispatch.  This is deliberate: a
    batched ``(B, s)`` pass through the layers is NOT bit-identical to
    ``s`` sequential steps — XLA picks different gemm accumulation orders
    for different row counts (measured: the lm-head gemm with M=6 vs M=1
    under jit differs in the last ulp) — while chaining the identical
    ``s = 1`` graph is parity by construction.  ``s`` is small and static
    (``draft_k + 1``), so the unroll is cheap to trace; the speculative win
    is one host dispatch per *round* instead of per token (DESIGN.md §13).
    Multi-token mode requires a contiguous cache (not paged) — the paged
    scheduler gathers a contiguous per-slot view first.

    ``cache`` may be the paged per-slot view (DESIGN.md §11): ``{"k"/"v":
    (L, n_blocks, page, ...) arena leaves, "table": (n_pages,), "pos": ()}``.
    The layer scan then slices the arena per layer exactly as it slices the
    dense cache, and the returned tree carries the pending KV rows
    (``k_new``/``v_new``, stacked (L, 1, 1, ...)) for the caller to scatter
    into the shared arena — the step itself never writes arena state."""
    if token.shape[1] > 1:
        assert "table" not in cache, (
            "multi-token decode needs a contiguous cache; gather the paged "
            "view first (serve/scheduler.py)"
        )
        logits = []
        for i in range(token.shape[1]):
            lg, cache = lm_decode_step(params, token[:, i : i + 1], cache, cfg)
            logits.append(lg)
        return jnp.concatenate(logits, axis=1), cache
    x = _embed_tokens(params, token, cfg)
    pos = cache["pos"]
    table = cache.get("table")

    def body(x, layer_in):
        lp, cache_l = layer_in
        if table is not None:
            cache_l = {**cache_l, "table": table}
        x, new_c = _lm_decode_layer(lp, x, cache_l, cfg, pos)
        return x, new_c

    x, new_kv = jax.lax.scan(body, x, (params["layers"], {"k": cache["k"], "v": cache["v"]}))
    x = rms_norm(x, params["final_norm"])
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = jnp.einsum("bsd,dv->bsv", x, head.astype(x.dtype))
    if table is not None:
        return logits, {**new_kv, "table": table, "pos": pos + 1}
    return logits, {**new_kv, "pos": pos + 1}


def lm_prefill(params, batch, cfg, max_len: int, lengths=None):
    """Run the prompt through the train path, then bulk-write the KV cache.

    For lowering/runtime simplicity we recompute K/V per layer into the cache
    (prefill is compute-bound anyway; the flash path already produced the
    hidden states).

    ``lengths`` (B,) enables *masked* bucketed prefill (DESIGN.md §6):
    ``tokens`` are right-padded to a shared bucket length and each row's true
    prompt length is given instead.  Logits are gathered at each row's last
    real token and are bit-identical to an unpadded prefill of that row —
    right-padding keeps every real token's causal window unchanged, and
    ``kv_valid`` masks padded keys to exactly-zero probability.  Cache rows at
    positions >= length hold garbage the decode-side occupancy mask
    (``slots <= pos``) never reads, so callers must set each row's true
    ``pos`` (``cache["pos"]`` stays the scalar padded length; the serve
    scheduler overrides it per slot via ``write_slots``).

    Caveat (moe): capacity-bounded dispatch couples rows — padding and
    co-batched tokens consume shared expert capacity — so bit-exactness
    additionally requires a dropless capacity factor
    (``moe_cf >= n_experts / top_k``); the serve engine only enables
    batched admission for moe under that condition."""
    tokens = batch["tokens"]
    b, s = tokens.shape
    cache = lm_init_cache(cfg, b, max_len)
    x, mask, positions = _lm_inputs(params, batch, cfg)
    kv_valid = None
    patch_off = cfg.patch_tokens if cfg.family == "vlm" else 0
    if lengths is not None:
        kv_valid = jnp.arange(x.shape[1])[None, :] < (lengths[:, None] + patch_off)

    from .layers import _project_qkv  # noqa: PLC0415

    def body(carry, lp):
        x, ks, vs = carry
        h = rms_norm(x, lp["norm1"])
        _, k, v = _project_qkv(lp["attn"], h, cfg, positions)
        x, _ = _lm_layer(lp, x, cfg, mask, positions, kv_valid)
        return (x, ks, vs), (k, v)

    (xf, _, _), (ks, vs) = jax.lax.scan(body, (x, 0, 0), params["layers"])
    xf = rms_norm(xf, params["final_norm"])
    cache["k"] = jax.lax.dynamic_update_slice(
        cache["k"], ks.astype(cache["k"].dtype), (0, 0, 0, 0, 0)
    )
    cache["v"] = jax.lax.dynamic_update_slice(
        cache["v"], vs.astype(cache["v"].dtype), (0, 0, 0, 0, 0)
    )
    cache["pos"] = jnp.int32(x.shape[1])
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    if lengths is None:
        last = xf[:, -1]
    else:  # each row's last real token (bucket padding sits after it)
        idx = (lengths - 1 + patch_off)[:, None, None]
        last = jnp.take_along_axis(xf, idx, axis=1)[:, 0]
    logits = jnp.einsum("bd,dv->bv", last, head.astype(xf.dtype))
    return logits, cache


def lm_prefill_chunk(params, tokens, cfg, arena, table_row, start, true_len,
                     write_from):
    """One chunk of a paged chunked prefill (Sarathi-style, DESIGN.md §11).

    ``tokens`` (1, C) is a chunk of a single prompt whose first token sits at
    absolute position ``start`` (traced — one compiled program per static C);
    ``true_len`` counts real tokens (the final chunk is right-padded to C).
    Each layer attends the chunk causally over everything resident in
    ``table_row``'s blocks plus itself, then the chunk's KV rows scatter into
    ``arena`` at block-table addresses.  Rows below ``write_from`` are
    *not* written — prefix-shared pages are already resident and must stay
    read-only (setting ``write_from = start + true_len`` turns the call into
    a pure re-peek, e.g. recovering the first-token logits after a
    fully-matched prefix hit without touching shared blocks).

    Returns ``(logits (1, V), arena')`` — logits at the chunk's last real
    token, meaningful on the final chunk only."""
    from .layers import attention_chunk  # noqa: PLC0415

    x = _embed_tokens(params, tokens, cfg)
    c = x.shape[1]

    def body(x, layer_in):
        lp, ak, av = layer_in
        h = rms_norm(x, lp["norm1"])
        y, k_c, v_c = attention_chunk(
            lp["attn"], h, cfg, ak, av, table_row, start, true_len
        )
        x = x + y
        h = rms_norm(x, lp["norm2"])
        if cfg.family == "moe":
            y, _ = moe(lp["ffn"], h, cfg)
        else:
            y = mlp(lp["ffn"], h)
        return x + y, (k_c, v_c)

    x, (ks, vs) = jax.lax.scan(body, x, (params["layers"], arena["k"], arena["v"]))
    page, n_blocks = arena["k"].shape[2], arena["k"].shape[1]
    rows = start + jnp.arange(c)  # absolute positions of chunk tokens
    writable = (jnp.arange(c) < true_len) & (rows >= write_from)
    pg = jnp.clip(rows // page, 0, table_row.shape[0] - 1)
    blk = jnp.where(writable, table_row[pg], n_blocks)  # sentinel -> dropped
    off = rows % page
    new_arena = {}
    for name, stacked in (("k", ks), ("v", vs)):
        a = arena[name]
        new_arena[name] = a.at[:, blk, off].set(
            stacked[:, 0].astype(a.dtype), mode="drop"
        )
    xf = rms_norm(x, params["final_norm"])
    last = xf[0, jnp.clip(true_len - 1, 0, c - 1)][None]  # (1, d)
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = jnp.einsum("bd,dv->bv", last, head.astype(xf.dtype))
    return logits, new_arena


# --------------------------------------------------------------------------
# Hybrid (Griffin / recurrentgemma): pattern of RG-LRU and local-attention
# blocks, each followed by an MLP block.
# --------------------------------------------------------------------------


def _hybrid_layer_specs(cfg, kind: str) -> dict:
    d = cfg.d_model
    mixer = ssm_mod.rglru_specs(cfg) if kind == "rglru" else attention_specs(cfg)
    return {
        "norm1": ParamSpec((d,), ("embed",), init="zeros"),
        "mixer": mixer,
        "norm2": ParamSpec((d,), ("embed",), init="zeros"),
        "ffn": mlp_specs(cfg),
    }


def _hybrid_pattern(cfg):
    reps = (cfg.n_layers + len(cfg.block_pattern) - 1) // len(cfg.block_pattern)
    return (cfg.block_pattern * reps)[: cfg.n_layers]


def hybrid_specs(cfg) -> dict:
    d, v = cfg.d_model, cfg.padded_vocab
    pat = cfg.block_pattern
    n_groups = cfg.n_layers // len(pat)
    tail = _hybrid_pattern(cfg)[n_groups * len(pat) :]
    specs = {
        "embed": ParamSpec((v, d), ("vocab", "embed")),
        "groups": {
            f"p{i}_{kind}": _stack_specs(_hybrid_layer_specs(cfg, kind), n_groups)
            for i, kind in enumerate(pat)
        },
        "tail": {
            f"t{i}_{kind}": _hybrid_layer_specs(cfg, kind) for i, kind in enumerate(tail)
        },
        "final_norm": ParamSpec((d,), ("embed",), init="zeros"),
        "lm_head": ParamSpec((d, v), ("embed", "vocab")),
    }
    return specs


def _hybrid_layer(lp, x, kind, cfg, positions):
    h = rms_norm(x, lp["norm1"])
    if kind == "rglru":
        y = ssm_mod.rglru_block(lp["mixer"], h, cfg)
    else:
        y = attention(lp["mixer"], h, cfg, MaskSpec("local", window=cfg.local_window), positions)
    x = x + y
    x = shard(x, "batch", None, "embed")
    h = rms_norm(x, lp["norm2"])
    return x + mlp(lp["ffn"], h)


def hybrid_forward(params, batch, cfg):
    tokens = batch["tokens"]
    x = params["embed"][tokens].astype(_act_dtype(cfg))
    positions = jnp.arange(x.shape[1])
    pat = cfg.block_pattern

    def group_body(x, gp):
        for i, kind in enumerate(pat):
            fn = partial(_hybrid_layer, kind=kind, cfg=cfg, positions=positions)
            if cfg.remat:
                fn = jax.checkpoint(fn)
            x = fn(gp[f"p{i}_{kind}"], x)
        return x, None

    x, _ = jax.lax.scan(group_body, x, params["groups"])
    for name, lp in params["tail"].items():
        kind = name.split("_", 1)[1]
        x = _hybrid_layer(lp, x, kind, cfg, positions)
    x = rms_norm(x, params["final_norm"])
    logits = jnp.einsum("bsd,dv->bsv", x, params["lm_head"].astype(x.dtype))
    return logits, 0.0


def hybrid_loss(params, batch, cfg):
    logits, _ = hybrid_forward(params, batch, cfg)
    return _xent(logits[:, :-1], batch["tokens"][:, 1:], cfg.vocab)


def hybrid_cache_specs(cfg, batch: int, max_len: int):
    """Per pattern-position caches (stacked over groups) + tail caches.

    Attention layers keep a ring cache bounded by the local window — this is
    what makes long_500k decode O(window), not O(seq)."""
    kvh, hd, r = cfg.kv_heads, cfg.hd, cfg.rglru_dim
    ring = min(cfg.local_window, max_len)
    n_groups = cfg.n_layers // len(cfg.block_pattern)
    adt = _act_dtype(cfg)

    def mixer_cache(kind, n=None):
        lead = (n,) if n else ()
        if kind == "rglru":
            return {
                "conv": jax.ShapeDtypeStruct(lead + (batch, 3, r), adt),
                "h": jax.ShapeDtypeStruct(lead + (batch, r), jnp.float32),
            }
        return {
            "k": jax.ShapeDtypeStruct(lead + (batch, ring, kvh, hd), adt),
            "v": jax.ShapeDtypeStruct(lead + (batch, ring, kvh, hd), adt),
        }

    pat = cfg.block_pattern
    tail = _hybrid_pattern(cfg)[n_groups * len(pat) :]
    return {
        "groups": {
            f"p{i}_{kind}": mixer_cache(kind, n_groups) for i, kind in enumerate(pat)
        },
        "tail": {f"t{i}_{kind}": mixer_cache(kind) for i, kind in enumerate(tail)},
        "pos": jax.ShapeDtypeStruct((), jnp.int32),
    }


def hybrid_init_cache(cfg, batch: int, max_len: int):
    return jax.tree_util.tree_map(
        lambda s: jnp.zeros(s.shape, s.dtype), hybrid_cache_specs(cfg, batch, max_len)
    )


def _hybrid_decode_layer(lp, x, cache_l, kind, cfg, pos):
    h = rms_norm(x, lp["norm1"])
    if kind == "rglru":
        y, new_c = ssm_mod.rglru_decode(lp["mixer"], h, cfg, cache_l)
    else:
        y, new_c = attention_decode(
            lp["mixer"], h, cfg, {**cache_l, "pos": pos}, window=cfg.local_window
        )
        new_c = {"k": new_c["k"], "v": new_c["v"]}
    x = x + y
    h = rms_norm(x, lp["norm2"])
    return x + mlp(lp["ffn"], h), new_c


def hybrid_decode_step(params, token, cache, cfg):
    x = params["embed"][token].astype(_act_dtype(cfg))
    pos = cache["pos"]
    pat = cfg.block_pattern

    def group_body(x, inp):
        gp, gc = inp
        new_caches = {}
        for i, kind in enumerate(pat):
            key = f"p{i}_{kind}"
            x, new_caches[key] = _hybrid_decode_layer(gp[key], x, gc[key], kind, cfg, pos)
        return x, new_caches

    x, new_group_cache = jax.lax.scan(group_body, x, (params["groups"], cache["groups"]))
    new_tail = {}
    for name, lp in params["tail"].items():
        kind = name.split("_", 1)[1]
        x, new_tail[name] = _hybrid_decode_layer(lp, x, cache["tail"][name], kind, cfg, pos)
    x = rms_norm(x, params["final_norm"])
    logits = jnp.einsum("bsd,dv->bsv", x, params["lm_head"].astype(x.dtype))
    return logits, {"groups": new_group_cache, "tail": new_tail, "pos": pos + 1}


# --------------------------------------------------------------------------
# SSM (Mamba-2)
# --------------------------------------------------------------------------


def ssm_specs(cfg) -> dict:
    d, v = cfg.d_model, cfg.padded_vocab
    layer = {
        "norm": ParamSpec((d,), ("embed",), init="zeros"),
        "mixer": ssm_mod.mamba2_specs(cfg),
    }
    return {
        "embed": ParamSpec((v, d), ("vocab", "embed")),
        "layers": _stack_specs(layer, cfg.n_layers),
        "final_norm": ParamSpec((d,), ("embed",), init="zeros"),
        "lm_head": ParamSpec((d, v), ("embed", "vocab")),
    }


def ssm_forward(params, batch, cfg):
    x = params["embed"][batch["tokens"]].astype(_act_dtype(cfg))

    def layer(lp, x):
        return x + ssm_mod.mamba2_block(lp["mixer"], rms_norm(x, lp["norm"]), cfg)

    fn = jax.checkpoint(layer) if cfg.remat else layer

    def body(x, lp):
        return fn(lp, x), None

    x, _ = jax.lax.scan(body, x, params["layers"])
    x = rms_norm(x, params["final_norm"])
    return jnp.einsum("bsd,dv->bsv", x, params["lm_head"].astype(x.dtype)), 0.0


def ssm_loss(params, batch, cfg):
    logits, _ = ssm_forward(params, batch, cfg)
    return _xent(logits[:, :-1], batch["tokens"][:, 1:], cfg.vocab)


def ssm_cache_specs(cfg, batch: int, max_len: int):
    din = cfg.expand * cfg.d_model
    n, h = cfg.ssm_state, cfg.ssm_heads
    conv_dim = din + 2 * n
    adt = _act_dtype(cfg)
    return {
        "conv": jax.ShapeDtypeStruct((cfg.n_layers, batch, cfg.d_conv - 1, conv_dim), adt),
        "state": jax.ShapeDtypeStruct((cfg.n_layers, batch, h, din // h, n), jnp.float32),
        "pos": jax.ShapeDtypeStruct((), jnp.int32),
    }


def ssm_init_cache(cfg, batch: int, max_len: int):
    return jax.tree_util.tree_map(
        lambda s: jnp.zeros(s.shape, s.dtype), ssm_cache_specs(cfg, batch, max_len)
    )


def ssm_decode_step(params, token, cache, cfg):
    x = params["embed"][token].astype(_act_dtype(cfg))

    def body(x, inp):
        lp, conv_c, state_c = inp
        y, new_c = ssm_mod.mamba2_decode(
            lp["mixer"], rms_norm(x, lp["norm"]), cfg, {"conv": conv_c, "state": state_c}
        )
        return x + y, (new_c["conv"], new_c["state"])

    x, (conv, state) = jax.lax.scan(body, x, (params["layers"], cache["conv"], cache["state"]))
    x = rms_norm(x, params["final_norm"])
    logits = jnp.einsum("bsd,dv->bsv", x, params["lm_head"].astype(x.dtype))
    return logits, {"conv": conv, "state": state, "pos": cache["pos"] + 1}


# --------------------------------------------------------------------------
# Encoder-decoder (Whisper): stub conv frontend — the encoder consumes
# precomputed frame embeddings (assignment spec), then full self-attention.
# --------------------------------------------------------------------------


def encdec_specs(cfg) -> dict:
    d, v = cfg.d_model, cfg.padded_vocab
    enc_layer = {
        "norm1": ParamSpec((d,), ("embed",), init="zeros"),
        "attn": attention_specs(cfg),
        "norm2": ParamSpec((d,), ("embed",), init="zeros"),
        "ffn": mlp_specs(cfg),
    }
    dec_layer = {
        "norm1": ParamSpec((d,), ("embed",), init="zeros"),
        "self_attn": attention_specs(cfg),
        "norm_x": ParamSpec((d,), ("embed",), init="zeros"),
        "cross_attn": attention_specs(cfg),
        "norm2": ParamSpec((d,), ("embed",), init="zeros"),
        "ffn": mlp_specs(cfg),
    }
    return {
        "embed": ParamSpec((v, d), ("vocab", "embed")),
        "enc_pos": ParamSpec((cfg.enc_frames, d), (None, "embed"), scale=0.02),
        "enc_layers": _stack_specs(enc_layer, cfg.enc_layers),
        "enc_norm": ParamSpec((d,), ("embed",), init="zeros"),
        "dec_layers": _stack_specs(dec_layer, cfg.n_layers),
        "final_norm": ParamSpec((d,), ("embed",), init="zeros"),
    }  # lm_head tied to embed (Whisper convention)


def encdec_encode(params, frames, cfg):
    """frames: (B, F, d) stub frame embeddings -> encoder states."""
    x = frames.astype(_act_dtype(cfg)) + params["enc_pos"][None, : frames.shape[1]].astype(
        _act_dtype(cfg)
    )
    positions = jnp.arange(x.shape[1])

    def layer(lp, x):
        h = rms_norm(x, lp["norm1"])
        x = x + attention(lp["attn"], h, cfg, MaskSpec("full"), positions)
        h = rms_norm(x, lp["norm2"])
        return x + mlp(lp["ffn"], h)

    fn = jax.checkpoint(layer) if cfg.remat else layer

    def body(x, lp):
        return fn(lp, x), None

    x, _ = jax.lax.scan(body, x, params["enc_layers"])
    return rms_norm(x, params["enc_norm"])


def encdec_forward(params, batch, cfg):
    """batch: {"frames": (B,F,d), "tokens": (B,S)}."""
    enc = encdec_encode(params, batch["frames"], cfg)
    tokens = batch["tokens"]
    x = params["embed"][tokens].astype(_act_dtype(cfg))
    positions = jnp.arange(x.shape[1])

    def layer(lp, x):
        h = rms_norm(x, lp["norm1"])
        x = x + attention(lp["self_attn"], h, cfg, MaskSpec("causal"), positions)
        h = rms_norm(x, lp["norm_x"])
        kv = encode_cross_kv(lp["cross_attn"], enc, cfg)
        x = x + cross_attention(lp["cross_attn"], h, kv, cfg)
        h = rms_norm(x, lp["norm2"])
        return x + mlp(lp["ffn"], h)

    fn = jax.checkpoint(layer) if cfg.remat else layer

    def body(x, lp):
        return fn(lp, x), None

    x, _ = jax.lax.scan(body, x, params["dec_layers"])
    x = rms_norm(x, params["final_norm"])
    logits = jnp.einsum("bsd,dv->bsv", x, params["embed"].T.astype(x.dtype))
    return logits, 0.0


def encdec_loss(params, batch, cfg):
    logits, _ = encdec_forward(params, batch, cfg)
    return _xent(logits[:, :-1], batch["tokens"][:, 1:], cfg.vocab)


def encdec_cache_specs(cfg, batch: int, max_len: int):
    kvh, hd = cfg.kv_heads, cfg.hd
    adt = _act_dtype(cfg)
    L = cfg.n_layers
    return {
        "k": jax.ShapeDtypeStruct((L, batch, max_len, kvh, hd), adt),
        "v": jax.ShapeDtypeStruct((L, batch, max_len, kvh, hd), adt),
        # precomputed cross-attention K/V over encoder frames
        "xk": jax.ShapeDtypeStruct((L, batch, cfg.enc_frames, kvh, hd), adt),
        "xv": jax.ShapeDtypeStruct((L, batch, cfg.enc_frames, kvh, hd), adt),
        "pos": jax.ShapeDtypeStruct((), jnp.int32),
    }


def encdec_init_cache(cfg, batch: int, max_len: int):
    return jax.tree_util.tree_map(
        lambda s: jnp.zeros(s.shape, s.dtype), encdec_cache_specs(cfg, batch, max_len)
    )


def encdec_decode_step(params, token, cache, cfg):
    """Decode one token; cross K/V must have been filled by encdec_prefill."""
    x = params["embed"][token].astype(_act_dtype(cfg))
    pos = cache["pos"]

    def body(x, inp):
        lp, k, v, xk, xv = inp
        h = rms_norm(x, lp["norm1"])
        y, new_c = attention_decode(lp["self_attn"], h, cfg, {"k": k, "v": v, "pos": pos})
        x = x + y
        h = rms_norm(x, lp["norm_x"])
        x = x + cross_attention(lp["cross_attn"], h, (xk, xv), cfg)
        h = rms_norm(x, lp["norm2"])
        return x + mlp(lp["ffn"], h), (new_c["k"], new_c["v"])

    x, (k, v) = jax.lax.scan(
        body, x, (params["dec_layers"], cache["k"], cache["v"], cache["xk"], cache["xv"])
    )
    x = rms_norm(x, params["final_norm"])
    logits = jnp.einsum("bsd,dv->bsv", x, params["embed"].T.astype(x.dtype))
    return logits, {**cache, "k": k, "v": v, "pos": pos + 1}


def encdec_prefill(params, batch, cfg, max_len: int):
    """Encode frames, fill cross-attn K/V, return cache ready for decode."""
    enc = encdec_encode(params, batch["frames"], cfg)
    b = enc.shape[0]
    cache = encdec_init_cache(cfg, b, max_len)

    def body(_, lp):
        return None, encode_cross_kv(lp["cross_attn"], enc, cfg)

    _, (xk, xv) = jax.lax.scan(body, None, params["dec_layers"])
    cache["xk"], cache["xv"] = xk.astype(cache["xk"].dtype), xv.astype(cache["xv"].dtype)
    logits = jnp.zeros((b, cfg.padded_vocab), _act_dtype(cfg))
    return logits, cache
