"""Slot-cache helpers for continuous-batching serving.

The scheduler (serve/scheduler.py) keeps one independent B=1 decode cache
per in-flight slot, stacked on a leading ``slots`` axis, and steps them with
``jax.vmap`` over that axis.  Because every slot carries its *own* scalar
``pos`` leaf, slots can sit at ragged sequence positions — the property that
lets retired slots be re-primed mid-stream without touching their
neighbours.  These helpers are family-agnostic pytree ops over the cache
trees defined by :mod:`repro.models.families` (every family's
``*_cache_specs`` works unchanged).

All helpers preserve leaf dtypes (e.g. the hybrid family's fp32 ``h`` state
next to bf16 KV rings) and never assume a particular tree structure.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = [
    "init_slot_cache",
    "read_slot",
    "write_slot",
    "reset_slot",
    "slot_count",
]


def init_slot_cache(cache_specs, slots: int):
    """Zero-initialised slot-stacked cache: each leaf gains a leading
    ``slots`` axis over the per-slot (B=1) shape described by
    ``cache_specs`` (a ShapeDtypeStruct tree from ``Model.cache_specs``)."""
    return jax.tree_util.tree_map(
        lambda s: jnp.zeros((slots,) + s.shape, s.dtype), cache_specs
    )


def slot_count(slot_cache) -> int:
    """Number of slots in a slot-stacked cache."""
    return jax.tree_util.tree_leaves(slot_cache)[0].shape[0]


def read_slot(slot_cache, i: int):
    """Extract slot ``i`` as a standalone per-slot (B=1) cache."""
    return jax.tree_util.tree_map(lambda leaf: leaf[i], slot_cache)


def write_slot(slot_cache, i: int, sub_cache):
    """Return a slot-stacked cache with slot ``i`` replaced by ``sub_cache``
    (a per-slot cache, e.g. fresh out of prefill)."""
    return jax.tree_util.tree_map(
        lambda leaf, sub: leaf.at[i].set(sub.astype(leaf.dtype)), slot_cache, sub_cache
    )


def reset_slot(slot_cache, i: int):
    """Zero slot ``i`` in place (functionally): KV rows, recurrent states and
    the slot's ``pos`` all return to the init state, so the next admitted
    request starts from a clean cache."""
    return jax.tree_util.tree_map(
        lambda leaf: leaf.at[i].set(jnp.zeros(leaf.shape[1:], leaf.dtype)), slot_cache
    )
