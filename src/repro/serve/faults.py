"""Seeded, deterministic fault injection for the serve stack (DESIGN.md §9).

Production serving sees faults the benign-world scheduler never models: a
flipped bit in packed weight metadata sitting in HBM, a NaN creeping into a
slot's KV/state cache, an admission path that stalls.  This module provides
injectors for each, all derived from a :class:`FaultConfig` seed so chaos
tests and the goodput-under-faults benchmark are bit-reproducible:

* ``corrupt_pack_positions``  — flip packed *position* metadata out of range.
  These are the faults ``serve.packed.validate_packed`` catches at load time
  (the Engine refuses to serve a pack that fails validation).
* ``corrupt_pack_values``     — set packed *values* to NaN, simulating
  post-load in-memory corruption.  Applied after validation; detected at
  runtime by the per-slot ``isfinite`` guard carried through the decode scan.
* cache poisoning             — ``FaultConfig.wants_cache_nan`` tells the
  Scheduler which admitted requests get one NaN poked into their slot cache
  (``models.cache.poison_slot``); the NaN propagates to the logits within
  one step and trips the same runtime guard.
* admission stalls            — ``wants_stall``/``stall_s`` make the
  Scheduler sleep inside the admission path, modelling a slow host.
* decode stalls / hangs       — ``wants_decode_stall``/``wants_decode_hang``
  stall (bounded) or hang (unbounded) the decode loop right before a
  segment dispatch, modelling a wedged device or collective.  The stall
  wait is interruptible, so these are what the async engine's watchdog
  (DESIGN.md §12) trains against: a hang must convert to ``STALLED``
  within the watchdog timeout instead of freezing the event loop.

Every decision is a pure function of ``(seed, rid)`` (or an explicit rid
list), never of wall-clock or global RNG state, so a faulted run can be
replayed exactly.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Dict, Tuple

import numpy as np

__all__ = [
    "FaultConfig",
    "corrupt_pack_positions",
    "corrupt_pack_values",
]


@dataclasses.dataclass
class FaultConfig:
    """Reproducible fault plan, wired through ``ServeConfig.faults``.

    ``pack_position_flips`` corrupt packed metadata *before* load validation
    (the Engine must refuse the pack); ``pack_value_nans`` corrupt packed
    values *after* validation (the runtime guard must catch them).
    ``cache_nan_rate`` poisons each admitted request's slot cache with
    probability drawn from ``(seed, rid)``; ``cache_nan_rids`` names faulted
    requests explicitly (union of both applies).  ``cache_nan_once`` makes a
    per-rid fault transient — the dense/fallback retry of that request runs
    clean — while ``False`` models a persistent fault that also kills the
    bounded retry.  ``stall_s`` sleeps the admission path for each request
    selected by ``stall_rate``/``stall_rids``.

    ``decode_stall_s`` stalls the *decode* loop (right before a segment
    dispatch) for each in-flight request selected by ``decode_stall_rate``/
    ``decode_stall_rids``; ``decode_hang_rids`` hang it outright (unbounded
    — only a watchdog abort escapes).  ``decode_stall_once`` makes each
    rid's stall/hang one-shot: after a watchdog re-queue the re-execution
    runs clean, modelling a transient wedge; ``False`` models a persistent
    one that exhausts the bounded re-queue into terminal ``STALLED``."""

    seed: int = 0
    pack_position_flips: int = 0
    pack_value_nans: int = 0
    cache_nan_rate: float = 0.0
    cache_nan_rids: Tuple[int, ...] = ()
    cache_nan_once: bool = True
    stall_s: float = 0.0
    stall_rate: float = 0.0
    stall_rids: Tuple[int, ...] = ()
    decode_stall_s: float = 0.0
    decode_stall_rate: float = 0.0
    decode_stall_rids: Tuple[int, ...] = ()
    decode_hang_rids: Tuple[int, ...] = ()
    decode_stall_once: bool = True

    def _draw(self, rid: int, salt: int) -> float:
        return float(np.random.default_rng((self.seed, salt, rid)).random())

    def wants_cache_nan(self, rid: int) -> bool:
        if rid in self.cache_nan_rids:
            return True
        return self.cache_nan_rate > 0 and self._draw(rid, 1) < self.cache_nan_rate

    def wants_stall(self, rid: int) -> bool:
        if self.stall_s <= 0:
            return False
        if rid in self.stall_rids:
            return True
        return self.stall_rate > 0 and self._draw(rid, 2) < self.stall_rate

    def stalls_decode(self) -> bool:
        """Cheap gate: does this plan inject any decode stall/hang at all?"""
        return bool(
            self.decode_hang_rids
            or (
                self.decode_stall_s > 0
                and (self.decode_stall_rate > 0 or self.decode_stall_rids)
            )
        )

    def wants_decode_stall(self, rid: int) -> bool:
        if self.decode_stall_s <= 0:
            return False
        if rid in self.decode_stall_rids:
            return True
        return self.decode_stall_rate > 0 and self._draw(rid, 3) < self.decode_stall_rate

    def wants_decode_hang(self, rid: int) -> bool:
        return rid in self.decode_hang_rids


# --------------------------------------------------------------------------
# packed-weight corruption
# --------------------------------------------------------------------------


def _pack_entries(packed: Dict):
    """Yield (group, name, entry) for every pack entry of a
    ``pack_lm_weights`` dict (legacy flat dicts iterate as one group)."""
    if "mlp" not in packed:
        for name, e in packed.items():
            yield packed, name, e
        return
    for name, e in packed["mlp"].items():
        yield packed["mlp"], name, e
    if packed.get("attn"):
        for name, e in packed["attn"].items():
            yield packed["attn"], name, e
    if packed.get("head") is not None:
        yield packed, "head", packed["head"]


def _corrupt(packed: Dict, n: int, seed: int, leaf: str, value, occupied_only=False) -> Dict:
    """Return a copy of ``packed`` with ``n`` seeded single-element flips of
    ``leaf`` ("values" or "positions").  With ``occupied_only`` the flip
    lands on a slot whose position is >= 0 — an idle slot's value is masked
    out of the reconstruction (``where(pos == lane, v, 0)``), so corrupting
    one would be a silent no-op rather than a detectable fault.  The copy is
    shallow except along the corrupted entries, so the uncorrupted arrays
    are shared, not duplicated."""
    rng = np.random.default_rng(seed)
    out = {
        k: (dict(v) if isinstance(v, dict) else v) for k, v in packed.items()
    }
    # list entries over the copied dict so mutation stays local to `out`
    for _ in range(n):
        targets = list(_pack_entries(out))
        gi = int(rng.integers(len(targets)))
        group, name, e = targets[gi]
        e = dict(e)
        arr = e[leaf]
        if occupied_only:
            occ = np.argwhere(np.asarray(e["positions"]) >= 0)
            if not len(occ):  # fully idle entry: no live slot to corrupt
                continue
            idx = tuple(int(x) for x in occ[int(rng.integers(len(occ)))])
        else:
            flat = int(rng.integers(arr.size))
            idx = np.unravel_index(flat, arr.shape)
        if leaf == "values" and e.get("value_dtype", "dense") != "dense":
            # quantized values are int8 bytes — NaN is unrepresentable there
            # (and int4 value shape differs from the position slot shape).
            # The float that corrupts instead is the occupied slot's dequant
            # scale: its NaN propagates to every value it rescales, reaching
            # the logits the same way a NaN value slot would.
            e["scales"] = e["scales"].at[idx[:-1]].set(value)
        else:
            e[leaf] = arr.at[idx].set(value)
        group[name] = e
    return out


def corrupt_pack_values(packed: Dict, cfg: FaultConfig) -> Dict:
    """NaN-flip ``cfg.pack_value_nans`` packed value slots (post-load
    corruption — the runtime isfinite guard's job to catch)."""
    if cfg.pack_value_nans <= 0:
        return packed
    return _corrupt(
        packed, cfg.pack_value_nans, cfg.seed, "values", math.nan, occupied_only=True
    )


def corrupt_pack_positions(packed: Dict, cfg: FaultConfig) -> Dict:
    """Flip ``cfg.pack_position_flips`` packed position bytes out of range
    (pre-validation corruption — ``validate_packed`` must refuse the pack).
    The corrupt value is ``-2``: valid positions live in ``[-1, m)``, so
    ``-2`` is out of range at every window width and always representable
    in the int8 metadata (``m`` itself may not be, e.g. ``m=128``)."""
    if cfg.pack_position_flips <= 0:
        return packed
    return _corrupt(
        packed, cfg.pack_position_flips, cfg.seed + 1, "positions", np.int8(-2)
    )
