"""Fused on-device decode loop: parity with the seed per-token host loop,
packed-vs-dense logits parity across dtypes, and vusa_a plumbing."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.core.pruning import prune_tree
from repro.models import build_model
from repro.serve import Engine, ServeConfig


def _params(cfg, seed=0):
    return build_model(cfg).init(jax.random.key(seed))


# ---------------------------------------------------------------------------
# fused loop == seed host loop
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("arch", ["llama3_2_1b", "mamba2_2_7b", "recurrentgemma_9b"])
def test_fused_matches_seed_loop_greedy(arch):
    """Same seed, greedy: the lax.scan loop must emit the seed loop's exact
    tokens (prefill families and recurrent prompt-priming families both)."""
    cfg = get_smoke_config(arch)
    params = _params(cfg)
    prompts = np.ones((2, 6), np.int32)
    outs = {}
    for fused in (False, True):
        eng = Engine(cfg, params, ServeConfig(max_len=64, fused=fused))
        outs[fused] = eng.generate(prompts, max_new=12)["tokens"]
    np.testing.assert_array_equal(outs[False], outs[True])


def test_fused_matches_seed_loop_sampled():
    """The fused loop splits PRNG keys in the host loop's exact order, so
    even temperature sampling is bit-identical."""
    cfg = get_smoke_config("llama3_2_1b")
    params = _params(cfg)
    prompts = np.ones((3, 5), np.int32)
    outs = {}
    for fused in (False, True):
        eng = Engine(cfg, params, ServeConfig(max_len=64, fused=fused, temperature=1.0))
        outs[fused] = eng.generate(prompts, max_new=10)["tokens"]
    np.testing.assert_array_equal(outs[False], outs[True])


def test_fused_tok_s_smoke():
    """tok/s smoke: fused decode must produce identical tokens and not be
    slower than the per-token host loop (after a matched-shape warmup)."""
    cfg = get_smoke_config("llama3_2_1b")
    params = _params(cfg)
    prompts = np.ones((2, 6), np.int32)
    max_new = 48
    best = {}
    toks = {}
    for fused in (False, True):
        eng = Engine(cfg, params, ServeConfig(max_len=64, fused=fused))
        eng.generate(prompts, max_new=max_new)  # compile
        best[fused] = max(
            eng.generate(prompts, max_new=max_new)["tok_per_s"] for _ in range(3)
        )
        toks[fused] = eng.generate(prompts, max_new=max_new)["tokens"]
    np.testing.assert_array_equal(toks[False], toks[True])
    # loose smoke bound (noisy CI runners): the fused loop must not be
    # meaningfully slower; the real A/B lives in benchmarks/run.py
    assert best[True] > 0.5 * best[False], (best[True], best[False])


# ---------------------------------------------------------------------------
# packed decode: logits parity across dtypes
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("dtype,tol", [("float32", 2e-4), ("bfloat16", 5e-2)])
def test_packed_logits_parity_dtypes(dtype, tol):
    """VUSA-packed MLP decode step == dense decode step at dtype tolerance."""
    from repro.models.families import lm_decode_step
    from repro.serve.packed import lm_decode_step_packed, pack_lm_mlps

    cfg = dataclasses.replace(get_smoke_config("vusa_edge"), dtype=dtype)
    params = prune_tree(_params(cfg), 0.85)
    packed = pack_lm_mlps(cfg, params, m=128, a=16)
    b = 2
    model = build_model(cfg)
    cache = model.init_cache(b, 16)
    token = jnp.ones((b, 1), jnp.int32)
    logits_d, _ = jax.jit(lambda p, t, c: lm_decode_step(p, t, c, cfg))(params, token, cache)
    logits_p, _ = jax.jit(lambda p, t, c: lm_decode_step_packed(p, packed, t, c, cfg))(
        params, token, cache
    )
    scale = float(jnp.max(jnp.abs(logits_d.astype(jnp.float32)))) + 1e-6
    err = float(jnp.max(jnp.abs(logits_d.astype(jnp.float32) - logits_p.astype(jnp.float32))))
    assert err / scale < tol, (err, scale)


def test_fused_packed_engine_matches_dense_engine():
    """End to end through Engine: packed + fused == dense + fused tokens."""
    cfg = get_smoke_config("vusa_edge")
    params = prune_tree(_params(cfg), 0.85)
    prompts = np.ones((2, 8), np.int32)
    dense = Engine(cfg, params, ServeConfig(max_len=64)).generate(prompts, max_new=8)
    packed = Engine(cfg, params, ServeConfig(max_len=64, packed_mlp=True)).generate(
        prompts, max_new=8
    )
    np.testing.assert_array_equal(dense["tokens"], packed["tokens"])


# ---------------------------------------------------------------------------
# vusa_a plumbing (regression: papply used to hardcode a=16)
# ---------------------------------------------------------------------------


def test_vusa_a_is_plumbed_through_pack_metadata():
    from repro.serve.packed import pack_lm_mlps

    cfg = get_smoke_config("vusa_edge")
    params = prune_tree(_params(cfg), 0.85)
    packed = pack_lm_mlps(cfg, params, m=128, a=8)
    for name in ("w_gate", "w_up", "w_down"):
        assert packed[name]["a"] == 8
        # slots axis is a whole number of a-wide jobs
        assert packed[name]["values"].shape[-1] % 8 == 0


def test_engine_respects_vusa_a():
    """A non-default vusa_a must reach the packer and still serve exactly."""
    cfg = get_smoke_config("vusa_edge")
    params = prune_tree(_params(cfg), 0.85)
    prompts = np.ones((2, 6), np.int32)
    dense = Engine(cfg, params, ServeConfig(max_len=64)).generate(prompts, max_new=6)
    eng = Engine(cfg, params, ServeConfig(max_len=64, packed_mlp=True, vusa_a=8))
    assert eng._packed["mlp"]["w_gate"]["a"] == 8
    packed = eng.generate(prompts, max_new=6)
    np.testing.assert_array_equal(dense["tokens"], packed["tokens"])
