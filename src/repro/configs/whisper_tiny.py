"""whisper-tiny [audio enc-dec]: 4L enc + 4L dec, d_model=384 6H d_ff=1536
vocab=51865; conv frontend STUB (input_specs provides frame embeddings)
[arXiv:2212.04356]."""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="whisper-tiny", family="encdec",
    n_layers=4, enc_layers=4, d_model=384, n_heads=6, kv_heads=6, d_ff=1536,
    vocab=51865, enc_frames=1500, sparsity=0.85,
)

SMOKE = ArchConfig(
    name="whisper-smoke", family="encdec",
    n_layers=2, enc_layers=2, d_model=64, n_heads=4, kv_heads=4, d_ff=128,
    vocab=512, enc_frames=16, sparsity=0.85, dtype="float32", remat=False,
)
