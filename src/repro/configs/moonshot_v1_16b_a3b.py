"""moonshot-v1-16b-a3b [moe]: 48L d_model=2048 16H (GQA kv=16) d_ff=1408
vocab=163840, 64 experts top-6 [hf:moonshotai/Moonlight-16B-A3B]."""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="moonshot-v1-16b-a3b", family="moe",
    n_layers=48, d_model=2048, n_heads=16, kv_heads=16, d_ff=1408,
    vocab=163840, n_experts=64, top_k=6, sparsity=0.85,
)

SMOKE = ArchConfig(
    name="moonshot-smoke", family="moe",
    n_layers=2, d_model=64, n_heads=4, kv_heads=4, d_ff=32,
    vocab=512, n_experts=8, top_k=2, moe_cf=4.0, sparsity=0.85, dtype="float32",
    remat=False,
)
