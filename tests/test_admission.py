"""Bucketed batched-prefill admission (DESIGN.md §6): masked-prefill
bit-parity with unpadded prefill, multi-slot cache scatter, bounded compile
counts under ragged traffic, head-of-line fixes, and the max_len overflow
guard."""

import dataclasses
import math

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs import get_smoke_config
from repro.models import build_model
from repro.serve import Engine, Request, Scheduler, ServeConfig


def _params(cfg, seed=0):
    return build_model(cfg).init(jax.random.key(seed))


# ---------------------------------------------------------------------------
# masked bucketed prefill == unpadded prefill, bit for bit
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("arch", ["llama3_2_1b", "olmoe_1b_7b"])
@pytest.mark.parametrize("dtype", ["float32", "bfloat16"])
def test_masked_prefill_bitexact(arch, dtype):
    """Right-padding to a bucket with true lengths must not change a row's
    last-token logits or its first ``length`` KV rows — across dense/moe
    families and dtypes (the invariant bucketed admission rests on)."""
    cfg = dataclasses.replace(get_smoke_config(arch), dtype=dtype)
    model = build_model(cfg)
    params = _params(cfg)
    rng = np.random.default_rng(0)
    lens = [3, 5, 7]
    prompts = [rng.integers(0, 100, n).astype(np.int32) for n in lens]
    bucket, max_len = 8, 32
    padded = np.zeros((len(lens), bucket), np.int32)
    for i, p in enumerate(prompts):
        padded[i, : len(p)] = p
    logits_b, cache_b = model.prefill(
        params, {"tokens": jnp.asarray(padded)}, max_len,
        lengths=jnp.asarray(lens, jnp.int32),
    )
    for i, p in enumerate(prompts):
        logits_1, cache_1 = model.prefill(params, {"tokens": jnp.asarray(p[None])}, max_len)
        np.testing.assert_array_equal(
            np.asarray(logits_b[i], np.float32), np.asarray(logits_1[0], np.float32),
            err_msg=f"row {i} logits",
        )
        for leaf in ("k", "v"):  # real KV rows bit-identical; garbage rows masked by pos
            np.testing.assert_array_equal(
                np.asarray(cache_b[leaf][:, i, : lens[i]], np.float32),
                np.asarray(cache_1[leaf][:, 0, : lens[i]], np.float32),
                err_msg=f"row {i} cache {leaf}",
            )


def test_prime_many_matches_prime():
    """Engine.prime_many (one batched dispatch) must emit each row's exact
    ``prime`` first token."""
    cfg = get_smoke_config("llama3_2_1b")
    eng = Engine(cfg, _params(cfg), ServeConfig(max_len=64))
    rng = np.random.default_rng(1)
    lens = [4, 6, 6, 5]
    prompts = [rng.integers(0, 100, n).astype(np.int32) for n in lens]
    bucket = eng.bucket_len(max(lens))
    padded = np.zeros((len(lens), bucket), np.int32)
    for i, p in enumerate(prompts):
        padded[i, : len(p)] = p
    nxt, _ = eng.prime_many(padded, np.asarray(lens))
    for i, p in enumerate(prompts):
        one, _, _ = eng.prime(p[None], jax.random.key(0))
        np.testing.assert_array_equal(np.asarray(nxt[i]), np.asarray(one[0]),
                                      err_msg=f"row {i}")


def test_custom_buckets_always_cover_max_len():
    """Custom prefill_buckets that stop short of max_len get max_len appended
    — a longer prompt must map to a bucket, never to an exact-length compile
    (the unbounded-recompile regression this PR removes)."""
    cfg = get_smoke_config("llama3_2_1b")
    eng = Engine(cfg, _params(cfg), ServeConfig(max_len=64, prefill_buckets=(8, 16)))
    assert eng.prefill_buckets == (8, 16, 64)
    assert eng.bucket_len(17) == 64
    with pytest.raises(ValueError, match="prefill_buckets"):
        Engine(cfg, _params(cfg), ServeConfig(max_len=64, prefill_buckets=(8, 128)))


def test_prime_many_rejects_recurrent_family():
    cfg = get_smoke_config("mamba2_2_7b")
    eng = Engine(cfg, _params(cfg), ServeConfig(max_len=64))
    assert not eng.batched_prefill
    with pytest.raises(NotImplementedError, match="masked prefill"):
        eng.prime_many(np.ones((2, 8), np.int32), np.asarray([4, 8]))


def test_moe_batched_prefill_requires_dropless_capacity():
    """Capacity-bounded MoE dispatch couples co-batched rows (shared expert
    capacity decides which tokens drop), so batched admission is only
    bit-exact — and only enabled — when no token can ever drop."""
    smoke = get_smoke_config("olmoe_1b_7b")
    assert smoke.moe_cf >= smoke.n_experts / smoke.top_k  # dropless smoke config
    eng = Engine(smoke, _params(smoke), ServeConfig(max_len=64))
    assert eng.batched_prefill
    droppy = dataclasses.replace(smoke, moe_cf=1.25)
    eng = Engine(droppy, build_model(droppy).init(jax.random.key(0)),
                 ServeConfig(max_len=64))
    assert not eng.batched_prefill  # falls back to per-request admission


# ---------------------------------------------------------------------------
# multi-slot scatter (models/cache.py write_slots)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("arch", ["llama3_2_1b", "mamba2_2_7b", "recurrentgemma_9b"])
def test_write_slots_scatter_roundtrip(arch):
    """One donated write_slots of a batched (B=N) cache lands each row in its
    slot with its own ``pos``, drops out-of-range (padding) rows, and leaves
    other slots untouched — across cache families (batch axes differ per
    leaf, located structurally via cache_batch_axes)."""
    model = build_model(get_smoke_config(arch))
    max_len, slots = 32, 4
    axes = model.cache_batch_axes(max_len)
    subs = [
        jax.tree.map(lambda leaf: (jnp.zeros_like(leaf) + val).astype(leaf.dtype),
                     model.init_cache(1, max_len))
        for val in (1, 2, 3)
    ]
    batched = jax.tree.map(
        lambda ax, *leaves: leaves[0] if ax < 0 else jnp.concatenate(leaves, axis=ax),
        axes, *subs,
    )
    stacked = model.init_slot_cache(slots, max_len)
    idx = jnp.asarray([2, 0, slots], jnp.int32)  # last row = padding, dropped
    pos = jnp.asarray([5, 7, 9], jnp.int32)
    out = model.write_slots(stacked, idx, batched, axes, pos)
    for slot_i, (row, want_pos) in {2: (0, 5), 0: (1, 7)}.items():
        got = model.read_slot(out, slot_i)
        jax.tree.map(
            lambda ax, g, s: np.testing.assert_array_equal(
                np.asarray(g, np.float32),
                np.full_like(np.asarray(g, np.float32), want_pos) if ax < 0
                else np.asarray(s, np.float32),
            ),
            axes, got, subs[row],
        )
    for untouched in (1, 3):  # neither slot targeted (the dropped row aimed out of range)
        jax.tree.map(
            lambda g, s: np.testing.assert_array_equal(np.asarray(g, np.float32),
                                                       np.asarray(s, np.float32)),
            model.read_slot(out, untouched), model.read_slot(stacked, untouched),
        )


# ---------------------------------------------------------------------------
# compile count: one static program set serves any traffic shape
# ---------------------------------------------------------------------------


def test_prefill_compile_count_bounded_by_buckets():
    """~10 distinct prompt lengths through the scheduler must compile at most
    (length buckets used) x (batch buckets) masked-prefill programs — not one
    per distinct length — and never touch the exact-length prefill."""
    cfg = get_smoke_config("llama3_2_1b")
    eng = Engine(cfg, _params(cfg), ServeConfig(max_len=64))
    sched = Scheduler(eng, slots=4, segment=4)
    rng = np.random.default_rng(2)
    lens = list(range(3, 13))  # 10 distinct lengths
    reqs = [Request(prompt=rng.integers(0, 100, n).astype(np.int32), max_new=6, seed=i)
            for i, n in enumerate(lens)]
    done = sched.run(reqs)
    assert len(done) == len(reqs)
    len_buckets = {eng.bucket_len(n) for n in lens}
    batch_buckets = 1 + math.ceil(math.log2(sched.slots))  # nb in {1, 2, 4, ...}
    n_compiles = eng._prefill_masked._cache_size()
    assert n_compiles <= len(len_buckets) * batch_buckets, (
        f"{n_compiles} prefill compiles for {len(len_buckets)} length buckets"
    )
    assert n_compiles < len(lens)  # strictly better than one-per-length
    assert eng._prefill._cache_size() == 0  # exact-length path never taken


# ---------------------------------------------------------------------------
# ragged-traffic smoke: out-of-order arrivals, mixed lengths, EOS-heavy
# ---------------------------------------------------------------------------


def _one_shot(eng, prompt, max_new, seed):
    eng.sc.seed = seed
    return eng.generate(prompt[None], max_new=max_new)["tokens"][0]


@pytest.mark.parametrize("admission", ["batched", "sequential"])
def test_ragged_traffic_parity(admission):
    """Mixed prompt lengths + out-of-order arrivals + EOS-heavy retirement:
    every completion stays bit-identical to one-shot generate, in both
    admission modes (the bench_admission A/B arms)."""
    cfg = get_smoke_config("llama3_2_1b")
    params = _params(cfg)
    sc = ServeConfig(max_len=64)
    ref = Engine(cfg, params, dataclasses.replace(sc))
    rng = np.random.default_rng(7)
    lens = [3, 9, 5, 12, 4, 7, 6, 10]
    prompts = [rng.integers(0, 100, n).astype(np.int32) for n in lens]
    arrivals = [0.02, 0.0, 0.01, 0.0, 0.03, 0.0, 0.02, 0.01]  # out of submit order
    reqs = []
    for i, p in enumerate(prompts):
        eos = None
        if i % 2 == 0:  # EOS-heavy: half the requests stop early on a real token
            one = _one_shot(ref, p, 8, seed=i)
            eos = int(one[2])
        reqs.append(Request(prompt=p, max_new=8, eos_id=eos, seed=i,
                            arrival_s=arrivals[i]))
    sched = Scheduler(Engine(cfg, params, dataclasses.replace(sc)),
                      slots=3, segment=4, admission=admission)
    done = sched.run(reqs)
    assert sorted(done) == list(range(len(reqs)))
    for rid, c in done.items():
        one = _one_shot(ref, prompts[rid], 8, seed=rid)
        if reqs[rid].eos_id is not None and (one == reqs[rid].eos_id).any():
            one = one[: int(np.argmax(one == reqs[rid].eos_id)) + 1]
        np.testing.assert_array_equal(c.tokens, one, err_msg=f"rid {rid}")


def test_admission_coalesces_same_bucket_dispatches():
    """N same-bucket arrivals admitted in one round must cost O(1) batched
    prefill dispatches, not N — measured via the masked-prefill compile
    cache (all four land in one (bucket, batch-bucket) program)."""
    cfg = get_smoke_config("llama3_2_1b")
    eng = Engine(cfg, _params(cfg), ServeConfig(max_len=64))
    sched = Scheduler(eng, slots=4, segment=4)
    rng = np.random.default_rng(8)
    reqs = [Request(prompt=rng.integers(0, 100, 5 + i % 3).astype(np.int32),
                    max_new=4, seed=i) for i in range(4)]
    done = sched.run(reqs)
    assert len(done) == 4
    assert eng._prefill_masked._cache_size() == 1  # one (8, nb=4) program


# ---------------------------------------------------------------------------
# bugfix regressions
# ---------------------------------------------------------------------------


def test_generate_overflow_raises():
    """Decode past max_len used to clamp the KV write index and silently
    overwrite the last cache row; now it fails loudly up front."""
    cfg = get_smoke_config("llama3_2_1b")
    eng = Engine(cfg, _params(cfg), ServeConfig(max_len=32))
    with pytest.raises(ValueError, match="max_len"):
        eng.generate(np.ones((1, 8), np.int32), max_new=30)
    with pytest.raises(ValueError, match="max_len"):
        eng.prime(np.ones((1, 40), np.int32), jax.random.key(0))
    # the boundary case still serves
    out = eng.generate(np.ones((1, 8), np.int32), max_new=24)
    assert out["tokens"].shape == (1, 24)


def test_generate_overflow_allows_recurrent():
    """SSM state is O(1) in sequence length — no KV cache to overflow, so the
    guard must not fire for recurrent families."""
    cfg = get_smoke_config("mamba2_2_7b")
    eng = Engine(cfg, _params(cfg), ServeConfig(max_len=16))
    out = eng.generate(np.ones((1, 8), np.int32), max_new=12)
    assert out["tokens"].shape == (1, 12)


def test_no_head_of_line_blocking_on_future_arrival():
    """A free slot must serve the earliest *arrived* request: the strict-FIFO
    head (arriving far in the future) used to idle the whole pool."""
    cfg = get_smoke_config("llama3_2_1b")
    params = _params(cfg)
    sched = Scheduler(Engine(cfg, params, ServeConfig(max_len=64)), slots=1, segment=4)
    rng = np.random.default_rng(9)
    late = Request(prompt=rng.integers(0, 100, 5).astype(np.int32), max_new=4,
                   seed=0, arrival_s=0.35)
    early = Request(prompt=rng.integers(0, 100, 5).astype(np.int32), max_new=4,
                    seed=1, arrival_s=0.0)
    done = sched.run([late, early])  # head (rid 0) arrives last
    assert done[1].admit_s < late.arrival_s, "later-submitted arrival was blocked"
    assert done[1].finish_s <= done[0].admit_s
    assert done[0].admit_s >= late.arrival_s


@pytest.mark.parametrize("seed", [2**31 + 5, 2**40 + 9, -7])
def test_batched_admission_accepts_wide_seeds(seed):
    """Seeds past int32 range (and negative ones) must survive batched
    admission — derived via jax.random.key's own folding, never squeezed
    through an int32 array — and stay bit-identical to one-shot generate
    (2**31+5 takes the vmapped uint32 path; 2**40+9 and -7 the eager
    fallback — negative seeds fold differently under jax_enable_x64)."""
    cfg = get_smoke_config("llama3_2_1b")
    params = _params(cfg)
    sc = ServeConfig(max_len=64, temperature=1.0)
    rng = np.random.default_rng(11)
    p = rng.integers(0, 100, 5).astype(np.int32)
    sched = Scheduler(Engine(cfg, params, dataclasses.replace(sc)), slots=2, segment=4)
    done = sched.run([Request(prompt=p, max_new=8, seed=seed)])
    ref = Engine(cfg, params, dataclasses.replace(sc))
    np.testing.assert_array_equal(done[0].tokens, _one_shot(ref, p, 8, seed=seed))


def test_stats_nan_when_nothing_completed():
    """An empty run must report NaN latency percentiles, not a fabricated 0.0
    (which reads as an infinitely fast server)."""
    cfg = get_smoke_config("llama3_2_1b")
    sched = Scheduler(Engine(cfg, _params(cfg), ServeConfig(max_len=64)),
                      slots=1, segment=4)
    sched.run([])
    s = sched.stats()
    assert s["requests"] == 0
    assert math.isnan(s["latency_p50_s"]) and math.isnan(s["latency_p95_s"])
    assert s["sustained_tok_per_s"] == 0.0
