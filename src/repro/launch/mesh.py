"""Mesh construction.  Functions, not module constants — importing this
module never touches jax device state.
"""

from __future__ import annotations

import jax

__all__ = ["make_production_mesh", "make_local_mesh", "make_serve_mesh"]


def make_production_mesh(*, multi_pod: bool = False):
    """Production mesh: 16x16 (one 256-chip pod) or 2x16x16 (two pods).

    The ``pod`` axis is pure data-parallel; ``data`` carries DP+FSDP and
    ``model`` carries TP/EP (see repro.dist.sharding).
    """
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_local_mesh(data: int = 1, model: int = 1):
    """Mesh over whatever devices exist locally (tests / examples)."""
    return jax.make_mesh((data, model), ("data", "model"))


def make_serve_mesh(spec: str):
    """Build a serving mesh from a ``"dp,tp"`` CLI spec (e.g. ``"2,4"`` =
    data-parallel 2 x tensor-parallel 4 — the layout
    ``launch/serve.py --mesh`` and the sharded-serve tests use).  ``"1,1"``
    is the degenerate single-device mesh; the serve stack treats it exactly
    like no mesh at all (DESIGN.md §8).  Raises with an actionable message
    when the spec asks for more devices than exist (on CPU, set
    ``XLA_FLAGS=--xla_force_host_platform_device_count=N``)."""
    try:
        data, model = (int(p) for p in spec.split(","))
    except ValueError as e:
        raise ValueError(f"--mesh expects 'dp,tp' (e.g. '2,4'), got {spec!r}") from e
    if data < 1 or model < 1:
        raise ValueError(f"mesh axes must be >= 1, got data={data}, model={model}")
    have = len(jax.devices())
    if data * model > have:
        raise ValueError(
            f"mesh {data}x{model} needs {data * model} devices but only {have} "
            f"exist; on CPU, force virtual devices with "
            f"XLA_FLAGS=--xla_force_host_platform_device_count={data * model}"
        )
    return make_local_mesh(data, model)
