"""Serving-layer tests: engines across families, sampling, batching."""

import numpy as np
import pytest

import jax

from repro.configs import get_smoke_config
from repro.models import build_model
from repro.serve import Engine, ServeConfig


def _engine(arch, **kw):
    cfg = get_smoke_config(arch)
    params = build_model(cfg).init(jax.random.key(0))
    return Engine(cfg, params, ServeConfig(max_len=64, **kw)), cfg


@pytest.mark.parametrize("arch", ["llama3_2_1b", "mamba2_2_7b", "recurrentgemma_9b"])
def test_generate_families(arch):
    eng, cfg = _engine(arch)
    out = eng.generate(np.ones((2, 6), np.int32), max_new=6)
    assert out["tokens"].shape == (2, 6)
    assert (out["tokens"] >= 0).all() and (out["tokens"] < cfg.padded_vocab).all()


def test_greedy_is_deterministic():
    eng, _ = _engine("llama3_2_1b")
    a = eng.generate(np.ones((2, 6), np.int32), max_new=6)["tokens"]
    b = eng.generate(np.ones((2, 6), np.int32), max_new=6)["tokens"]
    np.testing.assert_array_equal(a, b)


def test_temperature_sampling_varies():
    eng, _ = _engine("llama3_2_1b", temperature=5.0)
    out = eng.generate(np.ones((4, 6), np.int32), max_new=8)["tokens"]
    # with hot sampling, rows should not all be identical
    assert len({tuple(r) for r in out.tolist()}) > 1


def test_batch_isolation():
    """A request's output must not depend on its batch neighbours."""
    eng, _ = _engine("llama3_2_1b")
    rng = np.random.default_rng(0)
    p1 = rng.integers(0, 100, (1, 6)).astype(np.int32)
    p2 = rng.integers(0, 100, (1, 6)).astype(np.int32)
    solo = eng.generate(p1, max_new=5)["tokens"]
    pair = eng.generate(np.concatenate([p1, p2]), max_new=5)["tokens"]
    np.testing.assert_array_equal(solo[0], pair[0])
