"""VUSA design-space explorer: sweep (N, M, A) against a target sparsity and
report PPA-efficiency using the Table-I-calibrated component model + the
Eq. 1-4 growth model — the tool a hardware team would use to pick the
virtual-growth factor for their workload.

Run:  PYTHONPATH=src python examples/vusa_explorer.py --sparsity 0.85
"""

import argparse

from repro.core.growth import expected_width_distribution
from repro.core.hwmodel import HwModel
from repro.core.simulator import ws_cycles


def evaluate(n, m, a, p1, hw, b=64):
    """Expected throughput per area/power at weight density p1."""
    dist = expected_width_distribution(n, m, a, p1)
    # expected cycles per scheduled window, and columns covered per window
    exp_cycles = sum(dist[w] * ws_cycles(b, n, w) for w in range(a, m + 1))
    exp_cols = sum(dist[w] * w for w in range(a, m + 1))
    throughput = exp_cols / exp_cycles  # columns per cycle (per row-tile)
    area = hw.area_vusa(n, m, a)
    power = hw.power_vusa(n, m, a)
    return throughput, throughput / area, throughput / power


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--sparsity", type=float, default=0.85)
    ap.add_argument("--n", type=int, default=3)
    args = ap.parse_args()
    p1 = 1.0 - args.sparsity
    hw = HwModel()

    print(f"design space at {args.sparsity:.0%} sparsity (N={args.n}):")
    print(f"{'M':>3} {'A':>3} {'M/A':>5} {'thpt':>8} {'thpt/area':>10} {'thpt/power':>11}")
    best = None
    for a in (2, 3, 4, 6, 8):
        for growth in (1, 2, 3, 4, 6, 8):
            m = a * growth
            if m > 32:
                continue
            t, ta, tp = evaluate(args.n, m, a, p1, hw)
            std_t, std_ta, std_tp = evaluate(args.n, a, a, p1, hw)  # standard NxA
            print(f"{m:3d} {a:3d} {growth:5d} {t:8.4f} {ta:10.4f} {tp:11.4f}")
            if best is None or ta > best[0]:
                best = (ta, m, a)
    print(f"\nbest perf/area: M={best[1]}, A={best[2]} "
          f"(virtual growth {best[1]//best[2]}x) at {args.sparsity:.0%} sparsity")
    # paper's pick
    t, ta, tp = evaluate(3, 6, 3, p1, hw)
    print(f"paper's (3,6,3): thpt/area {ta:.4f}, thpt/power {tp:.4f}")


if __name__ == "__main__":
    main()
