"""Crash-safe write-ahead request journal for the serving stack (DESIGN.md
§12).

Every externally visible serving event is appended to an append-only log of
CRC32-framed JSON records (framing from ``checkpoint.ckpt``):

  submit   the request itself — prompt, budget, seed, deadline, priority
  admit    rid entered a slot (observability; recovery does not need it)
  tokens   a batch of tokens emitted for rid at a segment sync
  retire   rid reached a terminal status with its final token count
  recover  a recovery epoch began: partial token state of every non-retired
           rid is reset, because those requests re-execute from scratch
  swap     the engine hot-swapped its packed weights (fingerprint logged)
  close    clean shutdown marker (a journal without one crashed)

Durability contract: records are buffered in-process and flushed+fsync'd
ONLY at segment syncs (``Journal.sync``), piggybacking on the scheduler's
existing one-sync-per-segment cadence — journaling adds zero extra host
transfers and zero extra syncs.  Consequently a crash loses at most the
events since the last segment sync: tokens past the last fsync are
*re-decoded* on recovery (same request seed => bit-identical stream), never
lost; submissions past the last fsync are gone and must be re-submitted by
the client (the submit ack races the crash — classic WAL semantics).

Replay (:func:`replay`) is a pure function of the file: the same journal
always rebuilds the same state, and a torn or CRC-corrupt tail ends replay
cleanly at the last good record.  :func:`recover_into` re-queues every
non-retired request into a fresh Scheduler under its ORIGINAL rid and seed,
so the re-executed token streams are bit-identical to a crash-free run —
the differential tests in tests/test_streaming.py assert exactly that
across dense / packed / quantized / paged modes.
"""

from __future__ import annotations

import dataclasses
import json
import os
import threading
from pathlib import Path
from typing import Dict, List, Optional, Tuple

import numpy as np

from ..checkpoint.ckpt import append_record, read_records
from .scheduler import Completion, Request, Scheduler, Status

__all__ = ["Journal", "JournalState", "JournalTap", "replay", "recover_into"]


class Journal:
    """Append-only journal writer.  Thread-safe (the async engine appends
    submit records from the event-loop thread while the scheduler worker
    appends token batches); every mutation happens under one lock."""

    def __init__(self, path: str | Path, truncate_at: Optional[int] = None):
        self.path = Path(path)
        self.path.parent.mkdir(parents=True, exist_ok=True)
        self._lock = threading.Lock()
        if truncate_at is not None and self.path.exists():
            # recovery reopens after a crash: drop the torn tail so new
            # records append to the clean prefix (replay stops at the first
            # bad frame — bytes after it would be unreachable forever)
            with open(self.path, "r+b") as fh:
                fh.truncate(truncate_at)
        self._fh = open(self.path, "ab")
        self.records_written = 0
        self.syncs = 0

    def append(self, rec: dict) -> None:
        """Buffer one record (durable only after the next :meth:`sync`)."""
        payload = json.dumps(rec, separators=(",", ":")).encode()
        with self._lock:
            append_record(self._fh, payload)
            self.records_written += 1

    def sync(self) -> None:
        """Flush + fsync — the durability point, called at segment syncs."""
        with self._lock:
            self._fh.flush()
            os.fsync(self._fh.fileno())
            self.syncs += 1

    def close(self, clean: bool = True) -> None:
        with self._lock:
            if self._fh.closed:
                return
        if clean:
            self.append({"t": "close"})
        self.sync()
        with self._lock:
            self._fh.close()

    # -- record constructors ------------------------------------------------

    @staticmethod
    def submit_record(rid: int, req: Request) -> dict:
        return {
            "t": "submit",
            "rid": rid,
            "prompt": np.asarray(req.prompt).reshape(-1).tolist(),
            "max_new": int(req.max_new),
            "eos_id": None if req.eos_id is None else int(req.eos_id),
            "seed": int(req.seed),
            "arrival_s": float(req.arrival_s),
            "deadline_s": None if req.deadline_s is None else float(req.deadline_s),
            "priority": int(req.priority),
        }

    @staticmethod
    def admit_record(rid: int) -> dict:
        return {"t": "admit", "rid": rid}

    @staticmethod
    def tokens_record(rid: int, toks) -> dict:
        return {"t": "tokens", "rid": rid, "toks": [int(t) for t in toks]}

    @staticmethod
    def retire_record(rid: int, status: Status, n_tokens: int) -> dict:
        return {"t": "retire", "rid": rid, "status": status.value, "n": int(n_tokens)}


@dataclasses.dataclass
class JournalState:
    """Result of :func:`replay`: what the journal proves happened."""

    completed: Dict[int, Tuple[Status, np.ndarray]]  # rid -> (status, tokens)
    pending: Dict[int, Request]  # submitted, never retired — re-execute
    partial: Dict[int, List[int]]  # journaled-but-unretired token prefixes
    next_rid: int
    clean_bytes: int  # truncate the file here before appending again
    clean: bool  # False = torn/corrupt tail (the expected crash artifact)
    closed: bool  # True = a clean-shutdown close record was replayed


def _req_from_record(rec: dict) -> Request:
    return Request(
        prompt=np.asarray(rec["prompt"], np.int32),
        max_new=rec["max_new"],
        eos_id=rec["eos_id"],
        seed=rec["seed"],
        # the original arrival offset was relative to a run() epoch that died
        # with the process; on recovery the request is simply due now
        arrival_s=0.0,
        deadline_s=rec["deadline_s"],
        priority=rec["priority"],
    )


def replay(path: str | Path) -> JournalState:
    """Rebuild serving state from a journal.  Pure and idempotent: replaying
    the same file twice yields the same state; a truncated or CRC-corrupt
    tail ends replay at the last good record (``clean=False``) instead of
    raising.  Records for unknown rids (their submit record died after the
    last fsync) are ignored — a journal can never prove more than it holds."""
    records, clean_bytes, clean = read_records(path)
    pending: Dict[int, Request] = {}
    partial: Dict[int, List[int]] = {}
    completed: Dict[int, Tuple[Status, np.ndarray]] = {}
    next_rid = 0
    closed = False
    for payload in records:
        rec = json.loads(payload)
        t = rec.get("t")
        if t == "submit":
            rid = rec["rid"]
            pending[rid] = _req_from_record(rec)
            partial[rid] = []
            next_rid = max(next_rid, rid + 1)
        elif t == "tokens":
            if rec["rid"] in pending:
                partial[rec["rid"]].extend(rec["toks"])
        elif t == "retire":
            rid = rec["rid"]
            if rid in pending:
                toks = np.asarray(partial.pop(rid, []), np.int32)
                completed[rid] = (Status(rec["status"]), toks[: rec["n"]])
                del pending[rid]
        elif t == "recover":
            # a recovery epoch re-executes every non-retired request from
            # scratch: their re-journaled streams restart at token 0, so the
            # pre-crash partials must not be prepended to them
            for rid in pending:
                partial[rid] = []
        elif t == "close":
            closed = True
        # admit / swap records carry no recovery state
    return JournalState(
        completed=completed,
        pending=pending,
        partial=partial,
        next_rid=next_rid,
        clean_bytes=clean_bytes,
        clean=clean,
        closed=closed,
    )


def recover_into(
    path: str | Path, sched: Scheduler
) -> Tuple[Journal, Dict[int, Completion], List[int]]:
    """Crash recovery: replay ``path``, re-queue every non-retired request
    into ``sched`` under its ORIGINAL rid (and therefore its original seed —
    the re-executed stream is bit-identical to what a crash-free run would
    have produced), and reopen the journal for appending with the torn tail
    truncated and a ``recover`` marker fsync'd.

    Returns ``(journal, completed, recovered_rids)``: completions the
    journal already proves (their token streams need no recompute), and the
    rids now back in the queue."""
    state = replay(path)
    journal = Journal(path, truncate_at=state.clean_bytes)
    journal.append({"t": "recover"})
    journal.sync()
    completed = {
        rid: Completion(
            rid=rid,
            tokens=toks,
            arrival_s=float("nan"),
            admit_s=float("nan"),
            finish_s=float("nan"),
            status=status,
        )
        for rid, (status, toks) in state.completed.items()
    }
    recovered = sorted(state.pending)
    for rid in recovered:
        sched.submit(state.pending[rid], rid=rid)
    return journal, completed, recovered


class JournalTap:
    """Bridges scheduler events to a :class:`Journal`.

    One instance rides a Scheduler run via the existing ``on_sync`` hook:
    at every segment sync it diffs per-rid emitted-token counts against what
    it already journaled, appends the deltas (admits, token batches,
    retirements) and fsyncs ONCE — the journal's only durability point, on
    the sync the scheduler was paying for anyway.  The same diffing makes
    re-execution after recovery or a watchdog re-queue transparent: a rid
    whose tokens restart from scratch only journals (and only streams) the
    tokens beyond what was already delivered, and the already-delivered
    prefix is bit-identical by the scheduler's same-seed replay contract.

    After recovery the tap starts with empty counts on purpose: the
    ``recover`` marker told replay to reset every non-retired rid's partial
    tokens, so re-executed streams re-journal (and re-stream) from token 0
    — the journal stays self-contained and a consumer re-attaching after
    the crash sees the whole stream.  ``emitted`` seeds the counts for
    callers that want pure-tail semantics instead; ``on_new_tokens`` /
    ``on_retire`` are the streaming callbacks the async engine hangs its
    per-request token queues on.
    """

    def __init__(
        self,
        journal: Optional[Journal],
        emitted: Optional[Dict[int, int]] = None,
        on_new_tokens=None,
        on_retire=None,
    ):
        self.journal = journal
        self._emitted: Dict[int, int] = dict(emitted or {})
        self._admitted: set = set()
        self._retired: set = set()
        self.on_new_tokens = on_new_tokens
        self.on_retire = on_retire

    def note_submit(self, rid: int, req: Request) -> None:
        if self.journal is not None:
            self.journal.append(Journal.submit_record(rid, req))

    def emitted(self, rid: int) -> int:
        return self._emitted.get(rid, 0)

    def _push(self, rid: int, toks: List[int]) -> None:
        n0 = self._emitted.get(rid, 0)
        new = toks[n0:]
        if not new:
            return
        if self.journal is not None:
            self.journal.append(Journal.tokens_record(rid, new))
        self._emitted[rid] = len(toks)
        if self.on_new_tokens is not None:
            self.on_new_tokens(rid, new)

    def on_sync(self, sched: Scheduler) -> None:
        """The scheduler's ``on_sync`` hook: journal this sync's deltas and
        fsync once.  Also usable as a manual harvest after ``run`` returns
        (completions recorded without a sync — rejections, deadline sheds,
        abort retirements — land here)."""
        inflight = sched.inflight_tokens()
        for rid in inflight:
            if rid not in self._admitted:
                self._admitted.add(rid)
                if self.journal is not None:
                    self.journal.append(Journal.admit_record(rid))
        for rid, toks in inflight.items():
            self._push(rid, toks)
        for rid, comp in sched.completions_so_far().items():
            if rid in self._retired:
                continue
            self._retired.add(rid)
            self._push(rid, [int(t) for t in comp.tokens])
            if self.journal is not None:
                self.journal.append(
                    Journal.retire_record(rid, comp.status, len(comp.tokens))
                )
            if self.on_retire is not None:
                self.on_retire(rid, comp)
        if self.journal is not None:
            self.journal.sync()
