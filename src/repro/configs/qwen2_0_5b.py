"""qwen2-0.5b [dense]: 24L d_model=896 14H (GQA kv=2) d_ff=4864
vocab=151936; QKV bias [arXiv:2407.10671]."""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="qwen2-0.5b", family="dense",
    n_layers=24, d_model=896, n_heads=14, kv_heads=2, d_ff=4864,
    vocab=151936, qkv_bias=True, rope_theta=1000000.0, tie_embeddings=True,
    sparsity=0.85,
)

SMOKE = ArchConfig(
    name="qwen2-smoke", family="dense",
    n_layers=2, d_model=64, n_heads=4, kv_heads=2, d_ff=128,
    vocab=512, qkv_bias=True, tie_embeddings=True, sparsity=0.85,
    dtype="float32", remat=False,
)
