"""paligemma-3b [vlm]: gemma backbone 18L d_model=2048 8H (MQA kv=1)
d_ff=16384 vocab=257216; SigLIP tower STUB (input_specs provides 256 patch
embeddings) [arXiv:2407.07726]."""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="paligemma-3b", family="vlm",
    n_layers=18, d_model=2048, n_heads=8, kv_heads=1, d_ff=16384,
    vocab=257216, head_dim=256, patch_tokens=256, sparsity=0.85,
)

SMOKE = ArchConfig(
    name="paligemma-smoke", family="vlm",
    n_layers=2, d_model=64, n_heads=4, kv_heads=1, d_ff=128,
    vocab=512, head_dim=16, patch_tokens=8, sparsity=0.85,
    dtype="float32", remat=False,
)
