"""Property-based tests for the VUSA pack formats (core/packing.py):
pack/unpack roundtrips, window-count invariants and the shard_windows view,
across random shapes, sparsities in [0, 0.99] and non-divisible edges.

Uses the optional-hypothesis shim (tests/hypothesis_compat.py): with
hypothesis installed (CI) the @given tests fuzz; without it they skip and the
example-based edge tests below still pin the invariants.
"""

import numpy as np
from hypothesis_compat import given, settings, st

from repro.core.packing import (
    pack_blocks,
    pack_exact,
    pack_rows,
    pack_rows_t,
    shard_windows,
    unpack_blocks,
    unpack_exact,
    unpack_rows,
)


def _sparse(seed, k, c, sparsity):
    rng = np.random.default_rng(seed)
    w = rng.normal(size=(k, c)) * (rng.random((k, c)) > sparsity)
    return w.astype(np.float32)


# ---------------------------------------------------------------------------
# row format (the serving path's format)
# ---------------------------------------------------------------------------


@given(
    k=st.integers(1, 48),
    c=st.integers(1, 300),
    m=st.sampled_from([8, 32, 128]),
    a=st.sampled_from([4, 8, 16]),
    sp=st.floats(0.0, 0.99),
    seed=st.integers(0, 100),
)
@settings(max_examples=40, deadline=None)
def test_pack_rows_roundtrip_prop(k, c, m, a, sp, seed):
    """unpack(pack(w)) == w exactly, any shape/sparsity (c % m free)."""
    w = _sparse(seed, k, c, sp)
    p = pack_rows(w, m=m, a=a)
    np.testing.assert_array_equal(unpack_rows(p), w)
    # window-count invariant: windows tile the (padded) column dim
    assert p.values.shape[0] == -(-c // m)
    # job invariant: slots = a * ceil(max row-nnz per window / a)
    max_nnz = 1
    for t in range(p.values.shape[0]):
        blk = w[:, t * m : (t + 1) * m]
        max_nnz = max(max_nnz, int((blk != 0).sum(axis=1).max(initial=1)))
    assert p.values.shape[2] == a * -(-max_nnz // a)


@given(
    ff=st.integers(1, 200),
    d=st.integers(1, 48),
    m=st.sampled_from([8, 32]),
    sp=st.floats(0.0, 0.99),
    seed=st.integers(0, 100),
)
@settings(max_examples=30, deadline=None)
def test_pack_rows_t_roundtrip_prop(ff, d, m, sp, seed):
    """pack_rows_t windows the *leading* dim: unpack == w.T (the fused
    megakernel's w_down contract, DESIGN.md §7)."""
    w = _sparse(seed, ff, d, sp)
    p = pack_rows_t(w, m=m, a=4)
    np.testing.assert_array_equal(unpack_rows(p), w.T)
    assert p.values.shape[0] == -(-ff // m)  # windows cover ff


@given(
    k=st.integers(1, 32),
    c=st.integers(1, 200),
    n=st.integers(1, 8),
    sp=st.floats(0.0, 0.99),
    seed=st.integers(0, 50),
)
@settings(max_examples=30, deadline=None)
def test_shard_windows_prop(k, c, n, sp, seed):
    """shard_windows pads to a divisible window count with exact no-ops:
    unpack unchanged, pad windows all zero-value / -1-position."""
    p = pack_rows(_sparse(seed, k, c, sp), m=32, a=4)
    q = shard_windows(p, n)
    assert q.values.shape[0] % n == 0
    assert q.values.shape[0] - p.values.shape[0] < n
    np.testing.assert_array_equal(unpack_rows(q), unpack_rows(p))
    pad = q.values[p.values.shape[0] :]
    assert (pad == 0).all()
    assert (q.row_positions[p.values.shape[0] :] == -1).all()


# ---------------------------------------------------------------------------
# example-based edges (always run, hypothesis or not)
# ---------------------------------------------------------------------------


def test_pack_rows_roundtrip_edges():
    for k, c, m, a, sp in [
        (1, 1, 128, 16, 0.0),  # single scalar
        (7, 130, 128, 16, 0.85),  # c % m != 0 (the non-divisible ff edge)
        (16, 128, 128, 4, 0.0),  # dense fallback: J = ceil(m/a) jobs
        (5, 96, 32, 8, 0.99),  # near-empty
        (3, 64, 32, 8, 1.0),  # fully zero: one all-idle job
    ]:
        w = _sparse(0, k, c, sp) if sp < 1.0 else np.zeros((k, c), np.float32)
        p = pack_rows(w, m=m, a=a)
        np.testing.assert_array_equal(unpack_rows(p), w)
        assert p.values.shape[0] == -(-c // m)


def test_pack_rows_t_matches_transpose():
    w = _sparse(1, 80, 48, 0.85)  # ff=80 not divisible by m=32
    p = pack_rows_t(w, m=32, a=8)
    np.testing.assert_array_equal(unpack_rows(p), w.T)


def test_shard_windows_edges():
    p = pack_rows(_sparse(2, 8, 5 * 32 - 7, 0.8), m=32, a=8)  # 5 windows
    assert shard_windows(p, 1) is p  # divisible: view is the pack itself
    assert shard_windows(p, 5) is p
    q = shard_windows(p, 4)  # 5 -> 8 windows
    assert q.values.shape[0] == 8
    np.testing.assert_array_equal(unpack_rows(q), unpack_rows(p))
    try:
        shard_windows(p, 0)
    except ValueError:
        pass
    else:
        raise AssertionError("shard_windows(p, 0) must raise")


def test_shard_windows_twins_agree():
    """core.packing.shard_windows (host/numpy) and its device twin
    kernels.ops.shard_linear_windows must implement the *same* pad semantics
    (tail windows, value 0, position -1, k/c/m/a unchanged) — the serve path
    runs on the ops twin while the invariants are property-tested here, so
    drift between them must fail loudly."""
    from repro.kernels.ops import pack_linear_rows, shard_linear_windows

    w = _sparse(5, 12, 5 * 32 - 3, 0.8)  # 5 windows
    for n in (1, 2, 3, 4, 8):
        host = shard_windows(pack_rows(w, m=32, a=8), n)
        dev = shard_linear_windows(pack_linear_rows(w, m=32, a=8), n)
        np.testing.assert_array_equal(np.asarray(dev.values), host.values)
        np.testing.assert_array_equal(np.asarray(dev.positions), host.row_positions)
        assert (dev.k, dev.c, dev.m, dev.a) == (host.k, host.c, host.m, host.a)


def test_pack_blocks_roundtrip():
    w = _sparse(3, 64, 256, 0.9)
    p = pack_blocks(w, m_blk=16, a_blk=8, tile_n=128)
    np.testing.assert_array_equal(unpack_blocks(p), w)


def test_pack_exact_roundtrip():
    w = _sparse(4, 9, 12, 0.6)
    p = pack_exact(w, N=3, M=6, A=3)
    np.testing.assert_array_equal(unpack_exact(p), w)
