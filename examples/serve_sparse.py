"""Serve a pruned LM with batched requests: dense path vs VUSA-packed path.

Shows the paper's headline on the inference side: same outputs, packed
weight bytes ~ (1 - sparsity) of dense, dense fallback still correct.

Run:  PYTHONPATH=src python examples/serve_sparse.py --sparsity 0.85
"""

import argparse

import jax
import numpy as np

from repro.configs import get_smoke_config
from repro.core.pruning import prune_tree
from repro.models import build_model
from repro.serve import Engine, ServeConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="vusa_edge")
    ap.add_argument("--sparsity", type=float, default=0.85)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--new", type=int, default=16)
    ap.add_argument(
        "--mesh", default=None, metavar="DP,TP",
        help="serve sharded on a data x model mesh (DESIGN.md §8), e.g. "
        "'1,2'; outputs stay identical to the single-device path.  On CPU "
        "set XLA_FLAGS=--xla_force_host_platform_device_count=N first",
    )
    args = ap.parse_args()

    mesh = None
    if args.mesh:
        from repro.launch.mesh import make_serve_mesh

        mesh = make_serve_mesh(args.mesh)
        print(f"serving on mesh {dict(mesh.shape)}")

    cfg = get_smoke_config(args.arch)
    model = build_model(cfg)
    params = prune_tree(model.init(jax.random.key(0)), args.sparsity)
    prompts = np.tile(np.arange(8, dtype=np.int32), (args.batch, 1)) % cfg.vocab

    from repro.serve.packed import packed_byte_ratios

    tokens = {}
    for packed in (False, "all"):
        eng = Engine(cfg, params, ServeConfig(max_len=128, packed_weights=packed), mesh=mesh)
        out = eng.generate(prompts, max_new=args.new)
        tokens[packed] = out["tokens"]
        label = "VUSA-packed" if packed else "dense      "
        print(
            f"{label}: prefill {out['prefill_s']*1e3:6.1f}ms  "
            f"decode {out['decode_s']*1e3:6.1f}ms  {out['tok_per_s']:6.0f} tok/s"
        )
        if packed:
            ratios = packed_byte_ratios(eng._packed)
            print(f"             decode-step weight bytes packed/dense = "
                  f"{ratios['total']:.3f} @ {args.sparsity:.0%} sparsity "
                  f"(whole model: mlp + qkv/o + head)")
    assert (tokens[False] == tokens["all"]).all(), "packed serving diverged!"
    print("outputs identical: True")

    # continuous batching over ragged traffic (DESIGN.md §5-§6): same packed
    # engine, per-request budgets/seeds/arrivals; each round's arrivals are
    # bucket-padded and prefilled in one batched dispatch, and slots are
    # backfilled as requests retire
    from repro.serve import Request, Scheduler

    eng = Engine(cfg, params, ServeConfig(max_len=128, packed_weights="all"), mesh=mesh)
    sched = Scheduler(eng, slots=args.batch, segment=8)
    rng = np.random.default_rng(0)
    budget_cap = 128 - 8 - 8  # max_len - longest prompt - segment
    reqs = [
        Request(prompt=rng.integers(0, cfg.vocab, 4 + 2 * (i % 3)).astype(np.int32),
                max_new=int(rng.integers(4, min(2 * args.new, budget_cap) + 1)), seed=i,
                arrival_s=float(rng.exponential(0.002)))
        for i in range(2 * args.batch)
    ]
    done = sched.run(reqs)
    s = sched.stats()
    print(f"scheduler  : {len(done)} requests  {s['sustained_tok_per_s']:6.0f} tok/s  "
          f"p95 {s['latency_p95_s']*1e3:.0f}ms  occupancy {s['slot_occupancy']:.2f}")


if __name__ == "__main__":
    main()
