"""Model facade: one object per architecture with a uniform API, backed by
the family implementations in :mod:`repro.models.families`.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict

import jax
import jax.numpy as jnp

from ..configs.base import ArchConfig
from . import families as F
from .common import abstract_params, init_params


@dataclasses.dataclass(frozen=True)
class Model:
    cfg: ArchConfig

    # ---- params ----
    def specs(self):
        fam = self.cfg.family
        if fam in ("dense", "moe", "vlm"):
            return F.lm_specs(self.cfg)
        if fam == "hybrid":
            return F.hybrid_specs(self.cfg)
        if fam == "ssm":
            return F.ssm_specs(self.cfg)
        if fam == "encdec":
            return F.encdec_specs(self.cfg)
        raise ValueError(fam)

    def init(self, key: jax.Array):
        return init_params(self.specs(), key)

    def abstract_params(self):
        return abstract_params(self.specs())

    # ---- train ----
    def loss(self, params, batch):
        fam = self.cfg.family
        if fam in ("dense", "moe", "vlm"):
            return F.lm_loss(params, batch, self.cfg)
        if fam == "hybrid":
            return F.hybrid_loss(params, batch, self.cfg)
        if fam == "ssm":
            return F.ssm_loss(params, batch, self.cfg)
        if fam == "encdec":
            return F.encdec_loss(params, batch, self.cfg)
        raise ValueError(fam)

    def forward(self, params, batch):
        fam = self.cfg.family
        if fam in ("dense", "moe", "vlm"):
            return F.lm_forward(params, batch, self.cfg)
        if fam == "hybrid":
            return F.hybrid_forward(params, batch, self.cfg)
        if fam == "ssm":
            return F.ssm_forward(params, batch, self.cfg)
        if fam == "encdec":
            return F.encdec_forward(params, batch, self.cfg)
        raise ValueError(fam)

    # ---- serve ----
    def cache_specs(self, batch: int, max_len: int):
        fam = self.cfg.family
        if fam in ("dense", "moe", "vlm"):
            return F.lm_cache_specs(self.cfg, batch, max_len)
        if fam == "hybrid":
            return F.hybrid_cache_specs(self.cfg, batch, max_len)
        if fam == "ssm":
            return F.ssm_cache_specs(self.cfg, batch, max_len)
        if fam == "encdec":
            return F.encdec_cache_specs(self.cfg, batch, max_len)
        raise ValueError(fam)

    def init_cache(self, batch: int, max_len: int):
        return jax.tree_util.tree_map(
            lambda s: jnp.zeros(s.shape, s.dtype), self.cache_specs(batch, max_len)
        )

    # ---- slot caches (continuous batching; see serve/scheduler.py) ----
    def init_slot_cache(self, slots: int, max_len: int):
        """Slot-stacked decode cache: ``slots`` independent B=1 caches with a
        leading slot axis, each with its own scalar ``pos``."""
        from .cache import init_slot_cache

        return init_slot_cache(self.cache_specs(1, max_len), slots)

    def write_slot(self, slot_cache, i: int, sub_cache):
        from .cache import write_slot

        return write_slot(slot_cache, i, sub_cache)

    def cache_batch_axes(self, max_len: int):
        """Per-leaf batch-axis tree (see :func:`repro.models.cache.batch_axes`)
        for scattering batched prefill caches into slots with
        :meth:`write_slots`."""
        from .cache import batch_axes

        return batch_axes(self.cache_specs(1, max_len), self.cache_specs(2, max_len))

    def write_slots(self, slot_cache, idx, batched_cache, axes, pos):
        """Scatter a batched (B=N) cache into slots ``idx`` (one dispatch);
        ``pos`` (N,) sets each slot's true sequence position."""
        from .cache import write_slots

        return write_slots(slot_cache, idx, batched_cache, axes, pos)

    def reset_slot(self, slot_cache, i: int):
        from .cache import reset_slot

        return reset_slot(slot_cache, i)

    def read_slot(self, slot_cache, i: int):
        from .cache import read_slot

        return read_slot(slot_cache, i)

    # ---- paged pool (DESIGN.md §11) ----
    def paged_seq_len(self, max_len: int):
        """``max_len`` if this family's cache is KV-shaped and can be paged,
        else None (recurrent families keep the dense per-slot pool)."""
        from .cache import paged_seq_len

        if self.cfg.family == "vlm":
            return None  # patch-prefix rows complicate block addressing
        return paged_seq_len(self.cache_specs(1, max_len))

    def init_paged_pool(self, layout, max_len: int):
        from .cache import init_paged_pool

        return init_paged_pool(self.cache_specs(1, max_len), layout)

    def prefill_chunk(self, params, tokens, arena, table_row, start, true_len,
                      write_from):
        """One chunk of a paged chunked prefill (LM families only)."""
        fam = self.cfg.family
        if fam in ("dense", "moe"):
            return F.lm_prefill_chunk(
                params, tokens, self.cfg, arena, table_row, start, true_len,
                write_from,
            )
        raise NotImplementedError(f"chunked prefill for family {fam}")

    def decode_step(self, params, token, cache):
        fam = self.cfg.family
        if fam in ("dense", "moe", "vlm"):
            return F.lm_decode_step(params, token, cache, self.cfg)
        if fam == "hybrid":
            return F.hybrid_decode_step(params, token, cache, self.cfg)
        if fam == "ssm":
            return F.ssm_decode_step(params, token, cache, self.cfg)
        if fam == "encdec":
            return F.encdec_decode_step(params, token, cache, self.cfg)
        raise ValueError(fam)

    def prefill(self, params, batch, max_len: int, lengths=None):
        """``lengths`` (B,) enables masked bucketed prefill for the LM
        families (right-padded tokens, per-row true lengths; DESIGN.md §6)."""
        fam = self.cfg.family
        if fam in ("dense", "moe", "vlm"):
            return F.lm_prefill(params, batch, self.cfg, max_len, lengths=lengths)
        if fam == "encdec":
            if lengths is not None:
                raise NotImplementedError(
                    "masked prefill: encdec consumes frames, not ragged tokens"
                )
            return F.encdec_prefill(params, batch, self.cfg, max_len)
        raise NotImplementedError(f"prefill for {fam} uses forward+state capture")

    # ---- input specs (for AOT lowering; ShapeDtypeStruct only) ----
    def input_specs(self, batch: int, seq: int, kind: str = "train") -> Dict[str, Any]:
        """Stand-ins for every model input (no allocation)."""
        cfg = self.cfg
        i32 = jnp.int32
        if kind in ("train", "prefill"):
            spec: Dict[str, Any] = {"tokens": jax.ShapeDtypeStruct((batch, seq), i32)}
            if cfg.family == "vlm":
                spec["tokens"] = jax.ShapeDtypeStruct((batch, seq - cfg.patch_tokens), i32)
                spec["patches"] = jax.ShapeDtypeStruct(
                    (batch, cfg.patch_tokens, cfg.d_model), jnp.float32
                )
            if cfg.family == "encdec":
                spec["frames"] = jax.ShapeDtypeStruct(
                    (batch, min(seq, cfg.enc_frames), cfg.d_model), jnp.float32
                )
            return spec
        if kind == "decode":
            return {"token": jax.ShapeDtypeStruct((batch, 1), i32)}
        raise ValueError(kind)


def build_model(cfg: ArchConfig) -> Model:
    return Model(cfg)
