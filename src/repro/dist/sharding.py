"""Logical-axis sharding rules: ParamSpec trees -> NamedSharding trees.

One table maps each *logical* axis name (the strings in every
:class:`repro.models.common.ParamSpec`) to a *mesh* axis.  The policy is the
standard 2D TP x FSDP layout:

* ``model`` carries tensor/expert parallelism — vocab, ff, attention heads,
  experts, SSM inner dims are split so each device holds a slice of every
  layer's wide matmuls;
* ``data`` carries data parallelism and, for parameters, FSDP — the
  ``embed`` (d_model) axis of weights is sharded over ``data`` so optimizer
  state and parameters scale out with the DP degree;
* an optional ``pod`` axis (multi-pod meshes) is pure data parallelism:
  parameters are replicated across pods, batches are split.

Every rule degrades gracefully: a dimension is only sharded when the mesh
axis exists, has size > 1, is not already used by an earlier dimension of
the same tensor, and divides the dimension evenly.  Anything else falls
back to replication — never an error (see tests/test_dist.py).
"""

from __future__ import annotations

import math
from typing import Dict, Optional

import jax
from jax.sharding import NamedSharding, PartitionSpec

from ..models.common import ParamSpec

__all__ = [
    "act_rules",
    "param_sharding",
    "params_shardings",
    "batch_sharding",
    "batch_shardings",
    "serve_shardings",
    "window_sharding",
    "block_sharding",
]


# logical parameter axis -> mesh axis (None = always replicate)
PARAM_RULES: Dict[str, Optional[str]] = {
    # tensor parallel (wide matmul dims)
    "vocab": "model",
    "ff": "model",
    "heads": "model",
    "kv_heads": "model",
    "experts": "model",
    "ssm_inner": "model",
    "rglru": "model",
    # FSDP: shard the shared d_model axis over the data axis
    "embed": "data",
    # deliberately replicated (second occurrence of an already-used dim
    # family, or too small to matter)
    "rglru_out": None,
    "embed2": None,
}


def act_rules(mesh) -> Dict[str, object]:
    """Activation-sharding rules consumed by ``models.common.shard``.

    Activations stay replicated on the embed axis (TP shards the weights and
    all-reduces the products); the batch axis spans every pure-DP mesh axis.
    """
    batch_axes = tuple(a for a in ("pod", "data") if a in mesh.shape)
    return {
        "batch": batch_axes if len(batch_axes) > 1 else (batch_axes[0] if batch_axes else None),
        "ff": "model",
        "heads": "model",
        "kv_heads": "model",
        "vocab": "model",
        "ssm_inner": "model",
        "rglru": "model",
        "embed": None,
    }


def _divisible(dim: int, mesh, axes) -> bool:
    size = math.prod(mesh.shape[a] for a in axes)
    return size > 1 and dim % size == 0


def param_sharding(spec: ParamSpec, mesh) -> NamedSharding:
    """NamedSharding for one ParamSpec under PARAM_RULES (with fallback)."""
    used = set()
    parts = []
    for dim, name in zip(spec.shape, spec.axes):
        axis = PARAM_RULES.get(name) if name else None
        if (
            axis is not None
            and axis in mesh.shape
            and axis not in used
            and _divisible(dim, mesh, (axis,))
        ):
            parts.append(axis)
            used.add(axis)
        else:
            parts.append(None)
    return NamedSharding(mesh, PartitionSpec(*parts))


def params_shardings(spec_tree, mesh):
    """Map a ParamSpec tree to a NamedSharding tree."""
    return jax.tree_util.tree_map(
        lambda s: param_sharding(s, mesh),
        spec_tree,
        is_leaf=lambda x: isinstance(x, ParamSpec),
    )


def _batch_axes(mesh):
    return tuple(a for a in ("pod", "data") if a in mesh.shape)


def batch_sharding(mesh, batch_size: int, ndim: int) -> NamedSharding:
    """Shard dim 0 (the batch) over the DP mesh axes, replicate the rest."""
    axes = _batch_axes(mesh)
    if ndim == 0 or not axes or not _divisible(batch_size, mesh, axes):
        return NamedSharding(mesh, PartitionSpec(*([None] * ndim)))
    first = axes if len(axes) > 1 else axes[0]
    return NamedSharding(mesh, PartitionSpec(first, *([None] * (ndim - 1))))


def batch_shardings(mesh, batch: Dict[str, object]) -> Dict[str, NamedSharding]:
    """Per-entry batch shardings for a dict of arrays / ShapeDtypeStructs."""
    return {
        k: batch_sharding(mesh, v.shape[0] if len(v.shape) else 1, len(v.shape))
        for k, v in batch.items()
    }


def serve_shardings(cache_tree, mesh, batch_size: int, batch_axes=None):
    """Shardings for a decode-cache pytree: shard the batch dim over DP.

    ``batch_axes`` (a tree of per-leaf batch-axis ints, -1 for per-sequence
    scalars — see :func:`repro.models.cache.batch_axes`) pins each leaf's
    batch dim structurally.  Without it the batch dim is guessed as whichever
    of the first two dims equals ``batch_size`` — ambiguous when another
    leading dim (e.g. the layer stack) happens to equal the batch size, so
    callers that know their cache family should pass the axes tree.  Scalars
    like ``pos`` stay replicated either way.
    """
    axes = _batch_axes(mesh)
    first = (axes if len(axes) > 1 else axes[0]) if axes else None
    shardable = first is not None and _divisible(batch_size, mesh, axes)

    def guess(s):
        parts = [None] * len(s.shape)
        if shardable:
            for i, d in enumerate(s.shape[:2]):
                if d == batch_size:
                    parts[i] = first
                    break
        return NamedSharding(mesh, PartitionSpec(*parts))

    if batch_axes is None:
        return jax.tree_util.tree_map(guess, cache_tree)

    def structural(s, ax):
        parts = [None] * len(s.shape)
        if shardable and ax >= 0:
            parts[ax] = first
        return NamedSharding(mesh, PartitionSpec(*parts))

    return jax.tree_util.tree_map(structural, cache_tree, batch_axes)


def window_sharding(mesh, n_windows: int, ndim: int, axis: int = 0) -> NamedSharding:
    """Sharding for a packed-weight array along its window axis (DESIGN.md §8).

    The row-wise VUSA pack stacks windows on one axis (``values``/``positions``
    are ``(T, K, S)``, layer-stacked packs ``(L, T, K, S)``); TP splits that
    axis over the ``model`` mesh axis so each device reconstructs only its
    windows.  Same fallback contract as every other rule here: a missing or
    size-1 ``model`` axis, or a window count it does not divide (packs are
    normally padded to divide at pack time — see
    ``core.packing.shard_windows`` — but hand-built packs may not be),
    replicates instead of erroring.  The int8 ``positions`` metadata arrays
    take the identical spec: metadata must never be sharded differently from
    the values it indexes.
    """
    parts = [None] * ndim
    if "model" in mesh.shape and _divisible(n_windows, mesh, ("model",)):
        parts[axis] = "model"
    return NamedSharding(mesh, PartitionSpec(*parts))


def block_sharding(mesh, n_blocks: int, ndim: int, axis: int = 1) -> NamedSharding:
    """Sharding for a paged-arena leaf along its block axis (DESIGN.md §11).

    Blocks are the paged pool's batch dim — the arena ``(L, n_blocks, page,
    ...)`` shards its block axis over the data-parallel mesh axes exactly as
    the slot pool sharded its leading slots axis, so KV bytes keep scaling
    out with DP after the paged refactor.  Per-slot block-table gathers and
    token scatters cross shard boundaries; GSPMD inserts the collectives.
    Usual fallback contract: an absent/size-1 DP axis or an indivisible
    block count replicates instead of erroring."""
    axes = _batch_axes(mesh)
    parts = [None] * ndim
    if axes and _divisible(n_blocks, mesh, axes):
        parts[axis] = axes if len(axes) > 1 else axes[0]
    return NamedSharding(mesh, PartitionSpec(*parts))
