"""Pure-jnp oracles for the Pallas kernels (the correctness ground truth).

``vusa_spmm_ref`` consumes the *packed* operands, so kernel-vs-ref equality
checks the kernel, and ``unpack_blocks``-vs-dense checks the packer — the two
composed give end-to-end ``x @ W`` equality (see tests/test_kernels.py).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["dense_matmul_ref", "vusa_spmm_ref", "vusa_packed_ref", "vusa_fused_mlp_ref"]


def dense_matmul_ref(x: jnp.ndarray, w: jnp.ndarray) -> jnp.ndarray:
    """(B, K) @ (K, C) in fp32 accumulation."""
    return jnp.einsum("bk,kc->bc", x, w, preferred_element_type=jnp.float32).astype(x.dtype)


def vusa_spmm_ref(x: jnp.ndarray, values: jnp.ndarray, row_idx: jnp.ndarray) -> jnp.ndarray:
    """Block-VUSA packed matmul, pure jnp.

    x:       (B, K)
    values:  (T, J, A, Tn)  packed non-zero weight rows per output tile
    row_idx: (T, J, A)      absolute K index per packed row (padding -> 0
                            with zero values)
    returns  (B, T * Tn)
    """
    t, j, a, tn = values.shape
    xg = x[:, row_idx]  # (B, T, J, A) gather — the SPE->MAC shifter
    y = jnp.einsum("btja,tjan->btn", xg.astype(jnp.float32), values.astype(jnp.float32))
    return y.reshape(x.shape[0], t * tn).astype(x.dtype)


def vusa_packed_ref(x: jnp.ndarray, values: jnp.ndarray, positions: jnp.ndarray) -> jnp.ndarray:
    """Row-wise VUSA packed matmul oracle, pure jnp.

    x: (B, K); values/positions: (T, K, S) with int8 lane positions
    (-1 = idle slot).  Returns (B, T*128) fp32.
    """
    return jnp.einsum(
        "bk,kc->bc", x.astype(jnp.float32), _unpack_dense(values, positions)
    )


def _unpack_dense(values: jnp.ndarray, positions: jnp.ndarray, m: int = 128) -> jnp.ndarray:
    """Row-pack -> dense (K, T*m) fp32 (shared by both oracles)."""
    t, k, _ = values.shape
    lanes = jnp.arange(m, dtype=jnp.int32)
    onehot = (positions.astype(jnp.int32)[..., None] == lanes).astype(jnp.float32)
    w = jnp.einsum("tks,tksm->tkm", values.astype(jnp.float32), onehot)
    return w.transpose(1, 0, 2).reshape(k, t * m)


def vusa_fused_mlp_ref(
    x: jnp.ndarray,
    gate_values: jnp.ndarray,
    gate_positions: jnp.ndarray,
    up_values: jnp.ndarray,
    up_positions: jnp.ndarray,
    down_values: jnp.ndarray,
    down_positions: jnp.ndarray,
    m: int = 128,
) -> jnp.ndarray:
    """Fused SwiGLU MLP oracle over row-packed operands, pure jnp.

    ``gate``/``up`` pack (K, ff); ``down`` packs ``w_down`` *transposed*
    (D, ff) so the ff reduction dim is the windowed one — exactly the
    operands of ``vusa_fused_mlp_matmul``.  Returns (B, D) fp32.
    """
    wg = _unpack_dense(gate_values, gate_positions, m)  # (K, T*m)
    wu = _unpack_dense(up_values, up_positions, m)
    wdt = _unpack_dense(down_values, down_positions, m)  # (D, T*m) = w_down.T padded
    xf = x.astype(jnp.float32)
    h = jax.nn.silu(xf @ wg) * (xf @ wu)  # (B, T*m)
    return h @ wdt.T
