"""Slot-cache helpers for continuous-batching serving.

The scheduler (serve/scheduler.py) keeps one independent B=1 decode cache
per in-flight slot, stacked on a leading ``slots`` axis, and steps them with
``jax.vmap`` over that axis.  Because every slot carries its *own* scalar
``pos`` leaf, slots can sit at ragged sequence positions — the property that
lets retired slots be re-primed mid-stream without touching their
neighbours.  These helpers are family-agnostic pytree ops over the cache
trees defined by :mod:`repro.models.families` (every family's
``*_cache_specs`` works unchanged).

All helpers preserve leaf dtypes (e.g. the hybrid family's fp32 ``h`` state
next to bf16 KV rings) and never assume a particular tree structure.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = [
    "init_slot_cache",
    "read_slot",
    "write_slot",
    "write_slots",
    "batch_axes",
    "poison_slot",
    "reset_slot",
    "slot_count",
    "slot_shardings",
]


def init_slot_cache(cache_specs, slots: int):
    """Zero-initialised slot-stacked cache: each leaf gains a leading
    ``slots`` axis over the per-slot (B=1) shape described by
    ``cache_specs`` (a ShapeDtypeStruct tree from ``Model.cache_specs``)."""
    return jax.tree_util.tree_map(
        lambda s: jnp.zeros((slots,) + s.shape, s.dtype), cache_specs
    )


def slot_count(slot_cache) -> int:
    """Number of slots in a slot-stacked cache."""
    return jax.tree_util.tree_leaves(slot_cache)[0].shape[0]


def read_slot(slot_cache, i: int):
    """Extract slot ``i`` as a standalone per-slot (B=1) cache."""
    return jax.tree_util.tree_map(lambda leaf: leaf[i], slot_cache)


def write_slot(slot_cache, i: int, sub_cache):
    """Return a slot-stacked cache with slot ``i`` replaced by ``sub_cache``
    (a per-slot cache, e.g. fresh out of prefill)."""
    return jax.tree_util.tree_map(
        lambda leaf, sub: leaf.at[i].set(sub.astype(leaf.dtype)), slot_cache, sub_cache
    )


def batch_axes(specs_b1, specs_b2):
    """Locate each cache leaf's batch axis, family-agnostically: diff the
    ShapeDtypeStruct trees for two batch sizes and record, per leaf, the one
    axis whose extent changed (-1 for per-sequence scalars such as ``pos``,
    which carry no batch axis).  This is what lets :func:`write_slots`
    scatter a *batched* prefill cache — whose batch axis sits at a different
    position per leaf (e.g. axis 1 under a leading ``layers`` axis) — without
    hardcoding any family's tree structure."""

    def one(s1, s2):
        diffs = [i for i, (a, b) in enumerate(zip(s1.shape, s2.shape)) if a != b]
        if not diffs:
            return -1
        if len(diffs) != 1:
            raise ValueError(f"ambiguous batch axis: {s1.shape} vs {s2.shape}")
        return diffs[0]

    return jax.tree_util.tree_map(one, specs_b1, specs_b2)


def write_slots(slot_cache, idx, batched_cache, axes, pos):
    """Scatter a batched (B=N) cache into slots ``idx`` in one donated
    dispatch — the multi-slot twin of :func:`write_slot` used by bucketed
    admission (DESIGN.md §6).

    ``idx`` (N,) int32 picks the destination slot per batch row; rows whose
    index is out of range (e.g. batch-bucket padding rows) are dropped.
    ``axes`` is the :func:`batch_axes` tree; batched leaves are split along
    their batch axis (keeping a size-1 batch dim, matching the per-slot B=1
    shape).  Per-sequence scalar leaves (axis -1, i.e. ``pos``) are written
    from ``pos`` (N,) — the true per-row lengths under masked prefill, where
    the batched cache's own scalar ``pos`` holds the padded bucket length."""

    def one(leaf, sub, ax):
        if ax < 0:
            return leaf.at[idx].set(pos.astype(leaf.dtype), mode="drop")
        rows = jnp.expand_dims(jnp.moveaxis(sub, ax, 0), ax + 1)  # (N,) + B=1 shape
        return leaf.at[idx].set(rows.astype(leaf.dtype), mode="drop")

    return jax.tree_util.tree_map(one, slot_cache, batched_cache, axes)


def slot_shardings(slot_cache, mesh):
    """NamedSharding tree for a slot-stacked cache: the leading ``slots``
    axis — every leaf's, including the per-slot scalar ``pos`` — is sharded
    over the data-parallel mesh axes, everything else replicated (DESIGN.md
    §8).  Slots are the serve path's batch dim, so this is what scales the
    KV pool's bytes out with DP.  Falls back to replication when the slot
    count does not divide the DP degree — sharding degrades, never errors."""
    from ..dist.sharding import batch_sharding

    n = slot_count(slot_cache)
    return jax.tree_util.tree_map(
        lambda leaf: batch_sharding(mesh, n, leaf.ndim), slot_cache
    )


def poison_slot(slot_cache, i, value=jnp.nan):
    """Write ``value`` (NaN by default) into element ``(i, 0, ..., 0)`` of
    every inexact-dtype leaf of slot ``i`` — the fault-injection hook behind
    ``FaultConfig.cache_nan_rate`` (DESIGN.md §9).  One poisoned element of
    the KV/state cache reaches the logits within a single decode step (every
    family's step reads its full state), so this models in-cache bit rot with
    the smallest possible footprint.  Integer leaves (``pos``) are left
    untouched: NaN has no integer encoding and corrupting ``pos`` would
    change control flow rather than numerics."""

    def one(leaf):
        if not jnp.issubdtype(leaf.dtype, jnp.inexact):
            return leaf
        idx = (i,) + (0,) * (leaf.ndim - 1)
        return leaf.at[idx].set(jnp.asarray(value, leaf.dtype))

    return jax.tree_util.tree_map(one, slot_cache)


def reset_slot(slot_cache, i: int):
    """Zero slot ``i`` in place (functionally): KV rows, recurrent states and
    the slot's ``pos`` all return to the init state, so the next admitted
    request starts from a clean cache."""
    return jax.tree_util.tree_map(
        lambda leaf: leaf.at[i].set(jnp.zeros(leaf.shape[1:], leaf.dtype)), slot_cache
    )
