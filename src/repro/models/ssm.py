"""Attention-free temporal mixers.

* Mamba-2 SSD (state-space duality, arXiv:2405.21060): chunked matrix form
  for train/prefill (parallel, MXU-friendly) + O(1)-state decode step.
* RG-LRU (Griffin / recurrentgemma, arXiv:2402.19427): gated linear
  recurrence via ``jax.lax.associative_scan`` + decode step, with the
  Griffin recurrent block wrapper (conv1d + GELU gate).
"""

from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from .common import ParamSpec, rms_norm
from .opt_flags import FLAGS

# --------------------------------------------------------------------------
# Mamba-2 (SSD)
# --------------------------------------------------------------------------


def mamba2_specs(cfg) -> dict:
    d = cfg.d_model
    din = cfg.expand * d
    n, h = cfg.ssm_state, cfg.ssm_heads
    conv_dim = din + 2 * n
    return {
        # order: z (din) | x (din) | B (n) | C (n) | dt (h)
        "in_proj": ParamSpec((d, 2 * din + 2 * n + h), ("embed", "ssm_inner")),
        "conv_w": ParamSpec((cfg.d_conv, conv_dim), (None, "ssm_inner"), scale=0.5),
        "conv_b": ParamSpec((conv_dim,), ("ssm_inner",), init="zeros"),
        "A_log": ParamSpec((h,), (None,), init="ones"),
        "D": ParamSpec((h,), (None,), init="ones"),
        "dt_bias": ParamSpec((h,), (None,), init="zeros"),
        "norm": ParamSpec((din,), ("ssm_inner",), init="zeros"),
        "out_proj": ParamSpec((din, d), ("ssm_inner", "embed")),
    }


def _causal_conv(x: jax.Array, w: jax.Array, b: jax.Array) -> jax.Array:
    """Depthwise causal conv along seq. x: (B,S,C), w: (K,C)."""
    k = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (k - 1, 0), (0, 0)))
    out = sum(xp[:, i : i + x.shape[1], :] * w[i][None, None] for i in range(k))
    return out + b[None, None]


def _segsum(x: jax.Array) -> jax.Array:
    """Lower-triangular pairwise segment sums: out[..., i, j] = sum_{j<t<=i} x_t."""
    t = x.shape[-1]
    cs = jnp.cumsum(x, axis=-1)
    out = cs[..., :, None] - cs[..., None, :]
    mask = jnp.tril(jnp.ones((t, t), bool), k=0)
    return jnp.where(mask, out, -jnp.inf)


def _ssd_chunked(x, dt, a, b_mat, c_mat, chunk: int, init_state=None):
    """Chunked SSD scan.

    x: (B,S,H,P)  dt: (B,S,H)  a: (H,) negative  b_mat/c_mat: (B,S,N)
    Returns (y: (B,S,H,P), final_state: (B,H,P,N)).
    """
    bsz, s, h, p = x.shape
    n = b_mat.shape[-1]
    pad = (-s) % chunk
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        b_mat = jnp.pad(b_mat, ((0, 0), (0, pad), (0, 0)))
        c_mat = jnp.pad(c_mat, ((0, 0), (0, pad), (0, 0)))
    nc = (s + pad) // chunk
    xs = x.reshape(bsz, nc, chunk, h, p)
    dts = dt.reshape(bsz, nc, chunk, h)
    bs = b_mat.reshape(bsz, nc, chunk, n)
    cs = c_mat.reshape(bsz, nc, chunk, n)

    da = dts * a[None, None, None]  # (B,NC,Q,H)
    da_cum = jnp.cumsum(da, axis=2)
    da_total = da_cum[:, :, -1]  # (B,NC,H)

    # --- intra-chunk (diagonal blocks): Y[i] += sum_{j<=i} (C_i.B_j) L_ij dt_j x_j
    L = jnp.exp(_segsum(da.transpose(0, 1, 3, 2)))  # (B,NC,H,Q,Q)
    cb = jnp.einsum("bcin,bcjn->bcij", cs, bs)  # (B,NC,Q,Q)
    w = cb[:, :, None] * L  # (B,NC,H,Q,Q)
    y_diag = jnp.einsum("bchij,bcjh,bcjhp->bcihp", w, dts, xs)

    # --- chunk states: S_c = sum_j exp(da_total - da_cum_j) dt_j B_j x_j^T
    decay = jnp.exp(da_total[:, :, None] - da_cum)  # (B,NC,Q,H)
    states = jnp.einsum("bcqh,bcqh,bcqn,bcqhp->bchpn", decay, dts, bs, xs)

    # --- inter-chunk recurrence over NC
    def step(s_prev, inp):
        st, dtot = inp  # (B,H,P,N), (B,H)
        s_new = s_prev * jnp.exp(dtot)[..., None, None] + st
        return s_new, s_prev

    s0 = (
        init_state
        if init_state is not None
        else jnp.zeros((bsz, h, p, n), jnp.float32)
    )
    final_state, prev_states = jax.lax.scan(
        step, s0, (states.transpose(1, 0, 2, 3, 4), da_total.transpose(1, 0, 2))
    )
    prev_states = prev_states.transpose(1, 0, 2, 3, 4)  # (B,NC,H,P,N)

    # --- inter-chunk output: Y[i] += C_i . (exp(da_cum_i) * S_prev)
    y_off = jnp.einsum("bcqn,bchpn,bcqh->bcqhp", cs, prev_states, jnp.exp(da_cum))

    y = (y_diag + y_off).reshape(bsz, s + pad, h, p)[:, :s]
    return y, final_state


def mamba2_block(p: dict, x: jax.Array, cfg) -> jax.Array:
    """Full-sequence Mamba-2 mixer. x: (B,S,d) -> (B,S,d)."""
    bsz, s, d = x.shape
    din = cfg.expand * d
    n, h = cfg.ssm_state, cfg.ssm_heads
    hp = din // h
    zxbcdt = x @ p["in_proj"].astype(x.dtype)
    z, xbc, dt = jnp.split(zxbcdt, [din, 2 * din + 2 * n], axis=-1)
    xbc = _causal_conv(xbc, p["conv_w"].astype(x.dtype), p["conv_b"].astype(x.dtype))
    xbc = jax.nn.silu(xbc)
    xin, b_mat, c_mat = jnp.split(xbc, [din, din + n], axis=-1)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"][None, None])
    a = -jnp.exp(p["A_log"].astype(jnp.float32))
    chunk = min(64, cfg.ssm_chunk) if FLAGS["ssd_small_chunk"] else cfg.ssm_chunk
    y, _ = _ssd_chunked(
        xin.reshape(bsz, s, h, hp).astype(jnp.float32),
        dt,
        a,
        b_mat.astype(jnp.float32),
        c_mat.astype(jnp.float32),
        chunk,
    )
    xr = xin.reshape(bsz, s, h, hp).astype(jnp.float32)
    y = y + p["D"].astype(jnp.float32)[None, None, :, None] * xr
    y = y.reshape(bsz, s, din).astype(x.dtype)
    y = rms_norm(y * jax.nn.silu(z), p["norm"])
    return y @ p["out_proj"].astype(x.dtype)


def mamba2_decode(p: dict, x: jax.Array, cfg, cache: dict) -> Tuple[jax.Array, dict]:
    """Single-token decode. x: (B,1,d); cache: {"conv": (B,K-1,C), "state": (B,H,P,N)}."""
    bsz, _, d = x.shape
    din = cfg.expand * d
    n, h = cfg.ssm_state, cfg.ssm_heads
    hp = din // h
    zxbcdt = x[:, 0] @ p["in_proj"].astype(x.dtype)  # (B, ...)
    z, xbc, dt = jnp.split(zxbcdt, [din, 2 * din + 2 * n], axis=-1)
    # conv over cached window
    win = jnp.concatenate([cache["conv"], xbc[:, None]], axis=1)  # (B,K,C)
    w = p["conv_w"].astype(x.dtype)
    xbc_c = jnp.einsum("bkc,kc->bc", win, w) + p["conv_b"].astype(x.dtype)
    xbc_c = jax.nn.silu(xbc_c)
    xin, b_mat, c_mat = jnp.split(xbc_c, [din, din + n], axis=-1)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"][None])  # (B,H)
    a = -jnp.exp(p["A_log"].astype(jnp.float32))
    xh = xin.reshape(bsz, h, hp).astype(jnp.float32)
    decay = jnp.exp(dt * a[None])  # (B,H)
    state = cache["state"] * decay[..., None, None] + jnp.einsum(
        "bh,bn,bhp->bhpn", dt, b_mat.astype(jnp.float32), xh
    )
    y = jnp.einsum("bn,bhpn->bhp", c_mat.astype(jnp.float32), state)
    y = y + p["D"].astype(jnp.float32)[None, :, None] * xh
    y = y.reshape(bsz, din).astype(x.dtype)
    y = rms_norm(y * jax.nn.silu(z), p["norm"])
    out = (y @ p["out_proj"].astype(x.dtype))[:, None]
    return out, {"conv": win[:, 1:], "state": state}


# --------------------------------------------------------------------------
# RG-LRU (Griffin recurrent block)
# --------------------------------------------------------------------------

_RGLRU_C = 8.0


def rglru_specs(cfg) -> dict:
    d, r = cfg.d_model, cfg.rglru_dim
    return {
        "w_x": ParamSpec((d, r), ("embed", "rglru")),
        "w_gate_branch": ParamSpec((d, r), ("embed", "rglru")),
        "conv_w": ParamSpec((4, r), (None, "rglru"), scale=0.5),
        "conv_b": ParamSpec((r,), ("rglru",), init="zeros"),
        "w_a": ParamSpec((r, r), ("rglru", "rglru_out"), scale=0.5),
        "b_a": ParamSpec((r,), ("rglru",), init="zeros"),
        "w_i": ParamSpec((r, r), ("rglru", "rglru_out"), scale=0.5),
        "b_i": ParamSpec((r,), ("rglru",), init="zeros"),
        "lam": ParamSpec((r,), (None,), init="ones"),
        "w_out": ParamSpec((r, d), ("rglru", "embed")),
    }


def _rglru_gates(p, xr):
    """Per-step gate computation. xr: (..., r)."""
    r_gate = jax.nn.sigmoid(xr @ p["w_a"].astype(xr.dtype) + p["b_a"].astype(xr.dtype))
    i_gate = jax.nn.sigmoid(xr @ p["w_i"].astype(xr.dtype) + p["b_i"].astype(xr.dtype))
    log_a = -_RGLRU_C * jax.nn.softplus(p["lam"].astype(jnp.float32)) * r_gate.astype(jnp.float32)
    a = jnp.exp(log_a)
    b = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12)) * (
        i_gate.astype(jnp.float32) * xr.astype(jnp.float32)
    )
    return a, b


def rglru_block(p: dict, x: jax.Array, cfg) -> jax.Array:
    """Griffin recurrent block, full sequence. x: (B,S,d)."""
    gate = jax.nn.gelu(x @ p["w_gate_branch"].astype(x.dtype))
    xr = x @ p["w_x"].astype(x.dtype)
    xr = _causal_conv(xr, p["conv_w"].astype(x.dtype), p["conv_b"].astype(x.dtype))
    a, b = _rglru_gates(p, xr)

    def combine(c1, c2):
        a1, b1 = c1
        a2, b2 = c2
        return a1 * a2, a2 * b1 + b2

    _, h = jax.lax.associative_scan(combine, (a, b), axis=1)
    h = h.astype(x.dtype) * gate
    return h @ p["w_out"].astype(x.dtype)


def rglru_decode(p: dict, x: jax.Array, cfg, cache: dict) -> Tuple[jax.Array, dict]:
    """Single-token decode. cache: {"conv": (B,3,r), "h": (B,r)}."""
    gate = jax.nn.gelu(x[:, 0] @ p["w_gate_branch"].astype(x.dtype))
    xr = x[:, 0] @ p["w_x"].astype(x.dtype)
    win = jnp.concatenate([cache["conv"], xr[:, None]], axis=1)  # (B,4,r)
    xr = jnp.einsum("bkr,kr->br", win, p["conv_w"].astype(x.dtype)) + p["conv_b"].astype(x.dtype)
    a, b = _rglru_gates(p, xr)
    h = a * cache["h"] + b
    y = h.astype(x.dtype) * gate
    return (y @ p["w_out"].astype(x.dtype))[:, None], {"conv": win[:, 1:], "h": h}
