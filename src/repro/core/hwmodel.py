"""Silicon PPA model calibrated to the paper's Table I (16-nm, 1 GHz).

The paper synthesizes standard ``3x3 .. 3x6`` arrays and a ``VUSA 3x6``
(N=3, M=6, A=3) and reports area/power normalized to the VUSA.  We cannot
re-synthesize offline, so we fit a *component* model

    area  = N*M_phys * a_mac  +  N*M * a_spe  +  N*A*(M-A) * a_mux
    power = p_base + N*M_phys * p_mac + N*M * p_spe + N*A*(M-A) * p_mux

(where a standard array has ``M_phys = M`` MACs, no extra SPEs beyond the
registers folded into ``a_mac``/``p_mac``, and no muxes) to the four standard
points and the VUSA point of Table I.  The standard points pin the per-PE
slope; the VUSA point pins the SPE/mux split, using the paper's observation
that the MAC (not the muxing) dominates timing/power as a prior.

All outputs are normalized to VUSA(3, 6, 3) = 1.0, exactly as Table I.
"""

from __future__ import annotations

import dataclasses


__all__ = ["HwModel", "TABLE1_PAPER", "table1"]

# Paper Table I (normalized to VUSA 3x6).
TABLE1_PAPER = {
    # design               #MACs  area   power
    "standard_3x3": (9, 0.69, 0.86),
    "standard_3x4": (12, 0.91, 1.15),
    "standard_3x5": (15, 1.14, 1.41),
    "standard_3x6": (18, 1.37, 1.68),
    "vusa_3x6": (9, 1.00, 1.00),
}


@dataclasses.dataclass(frozen=True)
class HwModel:
    """Component PPA model (units: fraction of VUSA-3x6 area/power)."""

    # Area components -------------------------------------------------------
    a_pe: float = 0.69 / 9  # full PE (MAC + pipeline regs) from standard fit
    a_spe_frac: float = 0.26  # fraction of a PE that is pipeline registers
    a_mux_pos: float = 0.0  # per (MAC x reachable-extra-SPE) mux area
    # Power components ------------------------------------------------------
    p_base: float = 0.04  # clock tree / control
    p_pe: float = 0.0911  # per-PE slope from the standard fit
    p_spe_frac: float = 0.11
    p_mux_pos: float = 0.0

    def __post_init__(self):
        # Calibrate mux terms so VUSA(3,6,3) lands exactly on 1.0 / 1.0.
        a_spe = self.a_pe * self.a_spe_frac
        a_mac = self.a_pe - a_spe
        amux = (1.0 - (9 * a_mac + 18 * a_spe)) / (3 * 3 * (6 - 3))
        object.__setattr__(self, "a_mux_pos", amux)
        p_spe = self.p_pe * self.p_spe_frac
        p_mac = self.p_pe - p_spe
        pmux = (1.0 - (self.p_base + 9 * p_mac + 18 * p_spe)) / (3 * 3 * (6 - 3))
        object.__setattr__(self, "p_mux_pos", pmux)

    # -- standard arrays ----------------------------------------------------
    def area_standard(self, N: int, M: int) -> float:
        return N * M * self.a_pe

    def power_standard(self, N: int, M: int) -> float:
        return self.p_base + N * M * self.p_pe

    # -- VUSA ---------------------------------------------------------------
    def area_vusa(self, N: int, M: int, A: int) -> float:
        a_spe = self.a_pe * self.a_spe_frac
        a_mac = self.a_pe - a_spe
        return N * A * a_mac + N * M * a_spe + N * A * (M - A) * self.a_mux_pos

    def power_vusa(self, N: int, M: int, A: int) -> float:
        p_spe = self.p_pe * self.p_spe_frac
        p_mac = self.p_pe - p_spe
        return (
            self.p_base
            + N * A * p_mac
            + N * M * p_spe
            + N * A * (M - A) * self.p_mux_pos
        )


def table1(model: HwModel | None = None) -> dict:
    """Reproduce Table I from the fitted component model."""
    m = model or HwModel()
    out = {}
    for M in (3, 4, 5, 6):
        out[f"standard_3x{M}"] = (3 * M, m.area_standard(3, M), m.power_standard(3, M))
    out["vusa_3x6"] = (9, m.area_vusa(3, 6, 3), m.power_vusa(3, 6, 3))
    return out
