"""mamba2-2.7b [ssm]: 64L d_model=2560 attn-free, ssm_state=128; SSD
(state-space duality) [arXiv:2405.21060]."""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="mamba2-2.7b", family="ssm",
    n_layers=64, d_model=2560, n_heads=0, kv_heads=0, d_ff=0,
    vocab=50280, ssm_state=128, ssm_heads=80, ssm_chunk=256,
    expand=2, d_conv=4, sparsity=0.85,
)

SMOKE = ArchConfig(
    name="mamba2-smoke", family="ssm",
    n_layers=2, d_model=64, n_heads=0, kv_heads=0, d_ff=0,
    vocab=512, ssm_state=16, ssm_heads=4, ssm_chunk=32,
    expand=2, d_conv=4, sparsity=0.85, dtype="float32", remat=False,
)
