"""Shared serving-metric definitions.

There is exactly ONE notion of decode throughput in this repo (DESIGN.md
§13): tokens *accepted* — i.e. actually delivered to the caller — divided by
decode wall time.  For non-speculative decode every decoded token is
accepted, so the definition degenerates to the old ``decoded / decode_s``;
speculative decode *proposes* more tokens than it delivers, and those
rejected drafts must never inflate a throughput number.  Both
``Engine.generate`` and ``Scheduler.stats`` report through this helper so
the two can never drift apart again.
"""

from __future__ import annotations

__all__ = ["tok_per_s", "acceptance_rate"]


def tok_per_s(accepted_tokens: int, decode_s: float) -> float:
    """Canonical decode throughput: accepted tokens per decode wall second.

    ``accepted_tokens`` counts tokens delivered to the caller beyond the
    first (prefill-billed) token; ``decode_s`` is decode wall time only —
    prefill/admission time is accounted separately.
    """
    return accepted_tokens / max(decode_s, 1e-9)


def acceptance_rate(accepted_drafts: int, proposed_drafts: int) -> float:
    """Fraction of drafter-proposed tokens the verifier accepted.  NaN when
    nothing was proposed (non-speculative runs must not read as 0% or
    100%)."""
    return accepted_drafts / proposed_drafts if proposed_drafts else float("nan")
