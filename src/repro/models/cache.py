"""Slot-cache helpers for continuous-batching serving.

The scheduler (serve/scheduler.py) keeps one independent B=1 decode cache
per in-flight slot, stacked on a leading ``slots`` axis, and steps them with
``jax.vmap`` over that axis.  Because every slot carries its *own* scalar
``pos`` leaf, slots can sit at ragged sequence positions — the property that
lets retired slots be re-primed mid-stream without touching their
neighbours.  These helpers are family-agnostic pytree ops over the cache
trees defined by :mod:`repro.models.families` (every family's
``*_cache_specs`` works unchanged).

All helpers preserve leaf dtypes (e.g. the hybrid family's fp32 ``h`` state
next to bf16 KV rings) and never assume a particular tree structure.

The second half of this module is the **paged pool** (DESIGN.md §11): fixed
``page``-row KV blocks in a shared arena, per-slot block tables, a host-side
:class:`BlockAllocator` with prefix-hash sharing (refcounts, cached-free
reuse, copy-on-write at the divergence boundary).  Only KV-shaped cache
families (leaves ``(L, 1, max_len, ...)`` plus a scalar ``pos``) can be
paged — recurrent families (SSM conv/state, RG-LRU) keep the dense per-slot
pool above, which stays fully supported.
"""

from __future__ import annotations

import hashlib
from collections import OrderedDict
from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np

__all__ = [
    "init_slot_cache",
    "read_slot",
    "write_slot",
    "write_slots",
    "batch_axes",
    "poison_slot",
    "reset_slot",
    "slot_count",
    "slot_shardings",
    # paged pool (DESIGN.md §11)
    "PagedLayout",
    "BlockAllocator",
    "paged_seq_len",
    "init_paged_pool",
    "paged_view",
    "paged_in_axes",
    "paged_scatter_token",
    "write_prefill_pages",
    "bind_slot_pages",
    "zero_blocks",
    "copy_block",
    "paged_read_slot",
    "paged_reset_slot",
    "paged_poison_block",
    "paged_shardings",
    "paged_pool_bytes",
    "paged_block_bytes",
    "paged_host_mirror",
    "prefix_page_digests",
    "prefix_tail_digests",
]


def init_slot_cache(cache_specs, slots: int):
    """Zero-initialised slot-stacked cache: each leaf gains a leading
    ``slots`` axis over the per-slot (B=1) shape described by
    ``cache_specs`` (a ShapeDtypeStruct tree from ``Model.cache_specs``)."""
    return jax.tree_util.tree_map(
        lambda s: jnp.zeros((slots,) + s.shape, s.dtype), cache_specs
    )


def slot_count(slot_cache) -> int:
    """Number of slots in a slot-stacked cache."""
    return jax.tree_util.tree_leaves(slot_cache)[0].shape[0]


def read_slot(slot_cache, i: int):
    """Extract slot ``i`` as a standalone per-slot (B=1) cache."""
    return jax.tree_util.tree_map(lambda leaf: leaf[i], slot_cache)


def write_slot(slot_cache, i: int, sub_cache):
    """Return a slot-stacked cache with slot ``i`` replaced by ``sub_cache``
    (a per-slot cache, e.g. fresh out of prefill)."""
    return jax.tree_util.tree_map(
        lambda leaf, sub: leaf.at[i].set(sub.astype(leaf.dtype)), slot_cache, sub_cache
    )


def batch_axes(specs_b1, specs_b2):
    """Locate each cache leaf's batch axis, family-agnostically: diff the
    ShapeDtypeStruct trees for two batch sizes and record, per leaf, the one
    axis whose extent changed (-1 for per-sequence scalars such as ``pos``,
    which carry no batch axis).  This is what lets :func:`write_slots`
    scatter a *batched* prefill cache — whose batch axis sits at a different
    position per leaf (e.g. axis 1 under a leading ``layers`` axis) — without
    hardcoding any family's tree structure."""

    def one(path, s1, s2):
        diffs = [i for i, (a, b) in enumerate(zip(s1.shape, s2.shape)) if a != b]
        if not diffs:
            return -1
        if len(diffs) != 1:
            raise ValueError(
                f"ambiguous batch axis at cache leaf "
                f"{jax.tree_util.keystr(path) or '<root>'}: "
                f"axes {diffs} all change between {s1.shape} and {s2.shape}"
            )
        return diffs[0]

    return jax.tree_util.tree_map_with_path(one, specs_b1, specs_b2)


def write_slots(slot_cache, idx, batched_cache, axes, pos):
    """Scatter a batched (B=N) cache into slots ``idx`` in one donated
    dispatch — the multi-slot twin of :func:`write_slot` used by bucketed
    admission (DESIGN.md §6).

    ``idx`` (N,) int32 picks the destination slot per batch row; rows whose
    index is out of range (e.g. batch-bucket padding rows) are dropped.
    ``axes`` is the :func:`batch_axes` tree; batched leaves are split along
    their batch axis (keeping a size-1 batch dim, matching the per-slot B=1
    shape).  Per-sequence scalar leaves (axis -1, i.e. ``pos``) are written
    from ``pos`` (N,) — the true per-row lengths under masked prefill, where
    the batched cache's own scalar ``pos`` holds the padded bucket length."""

    def one(leaf, sub, ax):
        if ax < 0:
            return leaf.at[idx].set(pos.astype(leaf.dtype), mode="drop")
        rows = jnp.expand_dims(jnp.moveaxis(sub, ax, 0), ax + 1)  # (N,) + B=1 shape
        return leaf.at[idx].set(rows.astype(leaf.dtype), mode="drop")

    return jax.tree_util.tree_map(one, slot_cache, batched_cache, axes)


def slot_shardings(slot_cache, mesh):
    """NamedSharding tree for a slot-stacked cache: the leading ``slots``
    axis — every leaf's, including the per-slot scalar ``pos`` — is sharded
    over the data-parallel mesh axes, everything else replicated (DESIGN.md
    §8).  Slots are the serve path's batch dim, so this is what scales the
    KV pool's bytes out with DP.  Falls back to replication when the slot
    count does not divide the DP degree — sharding degrades, never errors."""
    from ..dist.sharding import batch_sharding

    n = slot_count(slot_cache)
    return jax.tree_util.tree_map(
        lambda leaf: batch_sharding(mesh, n, leaf.ndim), slot_cache
    )


def poison_slot(slot_cache, i, value=jnp.nan):
    """Write ``value`` (NaN by default) into element ``(i, 0, ..., 0)`` of
    every inexact-dtype leaf of slot ``i`` — the fault-injection hook behind
    ``FaultConfig.cache_nan_rate`` (DESIGN.md §9).  One poisoned element of
    the KV/state cache reaches the logits within a single decode step (every
    family's step reads its full state), so this models in-cache bit rot with
    the smallest possible footprint.  Integer leaves (``pos``) are left
    untouched: NaN has no integer encoding and corrupting ``pos`` would
    change control flow rather than numerics."""

    def one(leaf):
        if not jnp.issubdtype(leaf.dtype, jnp.inexact):
            return leaf
        idx = (i,) + (0,) * (leaf.ndim - 1)
        return leaf.at[idx].set(jnp.asarray(value, leaf.dtype))

    return jax.tree_util.tree_map(one, slot_cache)


def reset_slot(slot_cache, i: int):
    """Zero slot ``i`` in place (functionally): KV rows, recurrent states and
    the slot's ``pos`` all return to the init state, so the next admitted
    request starts from a clean cache."""
    return jax.tree_util.tree_map(
        lambda leaf: leaf.at[i].set(jnp.zeros(leaf.shape[1:], leaf.dtype)), slot_cache
    )


# ==========================================================================
# Paged pool (DESIGN.md §11)
#
# Device state (``pstate``) is a plain pytree:
#
#   {"arena": {"k": (L, n_blocks, page, kvh, hd), "v": ...},   # shared blocks
#    "table": (slots, n_pages) int32,                          # block tables
#    "pos":   (slots,) int32}                                  # per-slot pos
#
# Block id space: block 0 is the *null* block (permanently zero; nothing
# ever writes it), blocks 1..slots are per-slot *scratch* blocks that absorb
# the drifting writes of free slots riding along in the vmapped segment, and
# blocks ``slots+1..n_blocks-1`` are the user pool managed by the host-side
# BlockAllocator.  ``n_blocks`` itself is the out-of-bounds sentinel: every
# scatter here uses ``mode="drop"``, so an entry of ``n_blocks`` is a no-op.
#
# Bit-parity contract: ``page`` must divide ``max_len``, so a slot's gathered
# view ``arena[table_row]`` reshapes to exactly the (1, max_len, ...) cache
# the slot pool holds.  Unwritten gathered rows are masked by the same
# ``slots <= pos`` validity the slot pool uses; they contribute exactly-zero
# probability as long as they are *finite*, which the zero-on-free /
# scrub-on-realloc discipline below guarantees.
# ==========================================================================


@dataclass(frozen=True)
class PagedLayout:
    """Static geometry of a paged pool."""

    slots: int
    page: int
    n_pages: int  # block-table width = max_len // page
    n_blocks: int  # total arena blocks, incl. null + scratch

    @classmethod
    def build(cls, slots: int, max_len: int, page: int, blocks: int = 0):
        if page <= 0 or max_len % page:
            raise ValueError(
                f"page_size {page} must be positive and divide max_len {max_len} "
                "(the gathered block view must equal the slot-pool cache shape "
                "for bit-parity, DESIGN.md §11)"
            )
        n_pages = max_len // page
        user = blocks if blocks > 0 else slots * n_pages
        return cls(slots=slots, page=page, n_pages=n_pages,
                   n_blocks=1 + slots + user)

    @property
    def null_block(self) -> int:
        return 0

    def scratch_block(self, slot: int) -> int:
        return 1 + slot

    @property
    def reserved(self) -> int:
        return 1 + self.slots

    @property
    def user_blocks(self) -> int:
        return self.n_blocks - self.reserved

    @property
    def oob(self) -> int:
        # out-of-range sentinel for mode="drop" scatters / unmapped table slots
        return self.n_blocks


def paged_seq_len(cache_specs):
    """Return the common sequence length ``max_len`` if ``cache_specs`` is a
    KV-shaped family (every non-scalar leaf ``(L, 1, S, ...)`` with one
    shared ``S``, plus a scalar ``pos``), else None — the predicate gating
    paged serving.  Recurrent families (hybrid conv/h state, SSM) fail it
    and keep the dense per-slot pool."""
    if not isinstance(cache_specs, dict) or "pos" not in cache_specs:
        return None
    seq = None
    for name, s in cache_specs.items():
        if name == "pos":
            if s.shape != ():
                return None
            continue
        if s.ndim < 3 or s.shape[1] != 1:
            return None
        if seq is None:
            seq = s.shape[2]
        elif s.shape[2] != seq:
            return None
    return seq


def init_paged_pool(cache_specs, layout: PagedLayout):
    """Zero arena + scratch-pointing tables.  Every table entry starts at the
    slot's own scratch block so free slots' drifting decode writes land in
    private scratch, never in user blocks."""
    arena = {
        name: jnp.zeros(
            (s.shape[0], layout.n_blocks, layout.page) + s.shape[3:], s.dtype
        )
        for name, s in cache_specs.items()
        if name != "pos"
    }
    scratch = 1 + jnp.arange(layout.slots, dtype=jnp.int32)
    table = jnp.broadcast_to(scratch[:, None], (layout.slots, layout.n_pages))
    return {
        "arena": arena,
        "table": table.astype(jnp.int32),
        "pos": jnp.zeros((layout.slots,), jnp.int32),
    }


def paged_view(pstate):
    """Per-slot cache tree for the vmapped decode step: arena leaves are
    shared (vmap constants), ``table``/``pos`` carry the slots axis.  The
    layer scan inside the family step slices the leading L axis off the
    arena leaves, handing attention the per-layer paged cache
    ``{"k": (n_blocks, page, kvh, hd), ..., "table": (n_pages,), "pos": ()}``."""
    return {**pstate["arena"], "table": pstate["table"], "pos": pstate["pos"]}


def paged_in_axes(pstate):
    """vmap in_axes tree matching :func:`paged_view`."""
    return {**{k: None for k in pstate["arena"]}, "table": 0, "pos": 0}


def paged_scatter_token(pstate, new_rows):
    """Scatter one decoded KV row per slot into the arena — the write half of
    the decode step, hoisted *outside* the slot vmap so the shared arena is
    updated once per step.  ``new_rows`` holds, per arena leaf ``name``, a
    ``f"{name}_new"`` entry of shape ``(slots, L, 1, 1, ...)`` (the vmapped
    pending-write stacks the decode step returns); the row for
    slot ``i`` lands at block ``table[i, pos_i // page]``, offset
    ``pos_i % page``.  Distinct slots always target distinct blocks (the
    allocator never maps one user block writable into two tables, and
    scratch blocks are per-slot), so the scatter is conflict-free."""
    table, pos = pstate["table"], pstate["pos"]
    n_pages = table.shape[1]
    pg = jnp.clip(pos // _page_of(pstate), 0, n_pages - 1)
    blk = jnp.take_along_axis(table, pg[:, None], axis=1)[:, 0]  # (slots,)
    off = pos % _page_of(pstate)
    arena = {}
    for name, a in pstate["arena"].items():
        rows = jnp.moveaxis(new_rows[name + "_new"][:, :, 0, 0], 0, 1)  # (L, slots, ...)
        arena[name] = a.at[:, blk, off].set(rows.astype(a.dtype), mode="drop")
    return {"arena": arena, "table": table, "pos": pos + 1}


def paged_scatter_rows(pstate, new_rows, start, advance):
    """Scatter ``S`` consecutive KV rows per slot into the arena — the write
    half of a *speculative* round (DESIGN.md §13), hoisted outside the slot
    vmap like :func:`paged_scatter_token`.  ``new_rows`` holds, per arena
    leaf ``name``, a ``f"{name}_new"`` entry of shape ``(slots, L, 1, S,
    ...)`` — the verifier rows for positions ``start[i] .. start[i]+S-1``.
    All S rows are written (the rejected tail mirrors the contiguous pool,
    where stale-but-finite rows sit masked past ``pos`` until overwritten);
    ``advance`` (slots,) is each slot's accepted count ``nem``, so the new
    position is ``start + advance``.  Rows past a slot's table coverage
    drop — identical clamp semantics to the single-token scatter."""
    table, pos = pstate["table"], pstate["pos"]
    n_pages = table.shape[1]
    page = _page_of(pstate)
    S = next(iter(new_rows.values())).shape[3]
    q = start[:, None] + jnp.arange(S)[None, :]  # (slots, S) absolute rows
    pg = jnp.clip(q // page, 0, n_pages - 1)
    blk = jnp.take_along_axis(table, pg, axis=1)  # (slots, S)
    off = q % page
    arena = {}
    for name, a in pstate["arena"].items():
        rows = jnp.moveaxis(new_rows[name + "_new"][:, :, 0], 0, 1)  # (L, slots, S, ...)
        arena[name] = a.at[:, blk, off].set(rows.astype(a.dtype), mode="drop")
    return {"arena": arena, "table": table, "pos": pos + advance}


def _page_of(pstate) -> int:
    return next(iter(pstate["arena"].values())).shape[2]


def write_prefill_pages(arena, page_tables, primed):
    """Scatter a primed contiguous cache (B=N, leaves ``(L, N, S_b, ...)``)
    into arena blocks: sequence rows regroup into ``ceil(S_b/page)`` pages
    per row, page ``p`` of batch row ``r`` lands in block
    ``page_tables[r, p]``.  Sentinel (out-of-range) entries drop — that is
    how batch-bucket padding rows, pages beyond a short prompt, and
    prefix-shared pages (already resident, must not be rewritten) are all
    skipped with one mechanism."""
    page = next(iter(arena.values())).shape[2]
    out = {}
    for name, a in arena.items():
        sub = primed[name]
        pad = (-sub.shape[2]) % page
        if pad:
            sub = jnp.pad(sub, ((0, 0), (0, 0), (0, pad)) + ((0, 0),) * (sub.ndim - 3))
        pages = sub.reshape(
            sub.shape[0], sub.shape[1], sub.shape[2] // page, page, *sub.shape[3:]
        )
        out[name] = a.at[:, page_tables].set(pages.astype(a.dtype), mode="drop")
    return out


def bind_slot_pages(table, pos, idx, rows, lengths):
    """Point admitted slots at their blocks: write full table rows ``rows``
    ``(N, n_pages)`` and positions ``lengths`` ``(N,)`` at slot indices
    ``idx`` (out-of-range = padding, dropped)."""
    return (
        table.at[idx].set(rows.astype(table.dtype), mode="drop"),
        pos.at[idx].set(lengths.astype(pos.dtype), mode="drop"),
    )


def zero_blocks(arena, ids):
    """Zero arena blocks ``ids`` (a fixed-width int32 vector; out-of-range
    entries are no-ops).  Load-bearing for both parity and fault containment:
    a freed block re-entering circulation must read as zeros (masked-row
    garbage stays finite) and must not leak a quarantined request's NaN
    poison to the next owner."""
    return {
        name: a.at[:, ids].set(jnp.zeros((), a.dtype), mode="drop")
        for name, a in arena.items()
    }


def copy_block(arena, src, dst):
    """Copy-on-write: duplicate block ``src`` into ``dst`` byte-for-byte.
    Used at the prefix divergence boundary — the sharer keeps reading the
    original, the new request writes its divergent rows into the private
    copy — and to privatize a block before fault injection so poison never
    reaches shared state."""
    return {name: a.at[:, dst].set(a[:, src]) for name, a in arena.items()}


def paged_read_slot(pstate, i, max_len: int):
    """Materialize slot ``i`` as a dense per-slot (B=1) cache — the paged
    twin of :func:`read_slot`, used by parity tests and debugging."""
    row = pstate["table"][i]
    out = {}
    for name, a in pstate["arena"].items():
        g = a[:, row]  # (L, n_pages, page, ...)
        out[name] = g.reshape(a.shape[0], 1, -1, *a.shape[3:])[:, :, :max_len]
    out["pos"] = pstate["pos"][i]
    return out


def paged_reset_slot(pstate, i, scratch_id):
    """Detach slot ``i``: table row back to its scratch block, pos to 0.
    Freeing/zeroing the blocks the row pointed at is the allocator's call
    (shared blocks may have other readers) — see Scheduler retirement."""
    table = pstate["table"].at[i].set(
        jnp.full((pstate["table"].shape[1],), scratch_id, jnp.int32)
    )
    return {"arena": pstate["arena"], "table": table, "pos": pstate["pos"].at[i].set(0)}


def paged_poison_block(arena, blk, value=jnp.nan):
    """Paged fault injection: NaN element ``(layer 0, blk, 0, ..., 0)`` of
    every inexact arena leaf — the §9 ``poison_slot`` ported to the paged
    layout.  Callers must pass a *private* block of the target slot (COW
    guarantees one exists) so the blast radius stays one request even under
    prefix sharing."""
    out = {}
    for name, a in arena.items():
        if jnp.issubdtype(a.dtype, jnp.inexact):
            idx = (0, blk) + (0,) * (a.ndim - 2)
            out[name] = a.at[idx].set(jnp.asarray(value, a.dtype))
        else:
            out[name] = a
    return out


def paged_shardings(pstate, mesh):
    """NamedSharding tree for a paged pool: the arena's *block* axis shards
    over the data-parallel mesh axes (blocks are the paged pool's batch dim —
    this is what scales KV bytes out with DP, the §8 story transposed to the
    paged layout), block tables and positions shard over slots.  Same
    degrade-to-replication contract as every rule in dist.sharding."""
    from ..dist.sharding import batch_sharding, block_sharding

    slots = pstate["pos"].shape[0]
    return {
        "arena": {
            name: block_sharding(mesh, a.shape[1], a.ndim, axis=1)
            for name, a in pstate["arena"].items()
        },
        "table": batch_sharding(mesh, slots, pstate["table"].ndim),
        "pos": batch_sharding(mesh, slots, 1),
    }


def paged_pool_bytes(pstate) -> int:
    """Total arena bytes (all blocks, live or not)."""
    return sum(int(np.prod(a.shape)) * a.dtype.itemsize
               for a in pstate["arena"].values())


def paged_block_bytes(pstate) -> int:
    """Bytes one block occupies across all arena leaves (all layers)."""
    return sum(
        int(np.prod(a.shape)) // a.shape[1] * a.dtype.itemsize
        for a in pstate["arena"].values()
    )


def paged_host_mirror(pstate):
    """Host snapshot of the pool's control plane — ``(table (slots, n_pages),
    pos (slots,))`` as numpy.  The scheduler keeps exact host mirrors of both
    (every mutation is host-driven); this fetches the device truth in one
    tiny transfer so recovery and tests can verify the mirrors never
    diverged (DESIGN.md §12).  The arena payload itself stays on device."""
    table, pos = jax.device_get((pstate["table"], pstate["pos"]))
    return np.asarray(table), np.asarray(pos)


# --------------------------------------------------------------------------
# Host-side prefix hashing + block allocator
# --------------------------------------------------------------------------


def _chain(digest: bytes, tokens: np.ndarray) -> bytes:
    return hashlib.blake2b(
        digest + np.asarray(tokens, np.int32).tobytes(), digest_size=16
    ).digest()


def prefix_page_digests(tokens, page: int) -> list:
    """Chained per-page digests of a prompt: ``h_p = H(h_{p-1} || page_p)``.
    Chaining makes each digest position- and prefix-dependent, so equal
    digests mean equal *full prefixes*, not just equal page contents.
    Returns one digest per fully-covered page (``len(tokens) // page``);
    the last digest (or ``b""``) seeds :func:`prefix_tail_digests`."""
    tokens = np.asarray(tokens, np.int32)
    out, h = [], b""
    for p in range(len(tokens) // page):
        h = _chain(h, tokens[p * page:(p + 1) * page])
        out.append(h)
    return out


class BlockAllocator:
    """Host-side bookkeeping for the user-block pool: free list, refcounts,
    prefix-hash registry with a cached-free LRU (refcount-0 blocks whose
    bytes are worth keeping for future prefix hits), and the COW registry
    for partial tail pages.

    Invariants (property-tested in tests/test_packing_props.py):
      * every block is in exactly one of {free, cached, live};
      * refcounts are >= 1 for live blocks and never go negative;
      * ``alloc`` never returns a live or reserved block;
      * blocks surfaced from the free list hold zeros (callers zero on free /
        scrub on cached-eviction, as instructed by the return values here).
    """

    def __init__(self, layout: PagedLayout):
        self.layout = layout
        # pop() from the tail → ascending allocation order
        self._free = list(range(layout.n_blocks - 1, layout.reserved - 1, -1))
        self._ref: dict = {}
        self._key_of: dict = {}  # blk -> registry key
        self._blk_of: dict = {}  # registry key -> blk
        self._tail_rows: dict = {}  # partial-tail key -> row count
        self._cached: OrderedDict = OrderedDict()  # key -> blk, refcount-0, LRU
        self.hits = 0
        self.lookups = 0
        self.cow_copies = 0
        self.evictions = 0

    # -- accounting ---------------------------------------------------------
    @property
    def free_blocks(self) -> int:
        return len(self._free)

    @property
    def cached_blocks(self) -> int:
        return len(self._cached)

    @property
    def live_blocks(self) -> int:
        return len(self._ref)

    @property
    def available(self) -> int:
        return len(self._free) + len(self._cached)

    def refcount(self, blk: int) -> int:
        return self._ref.get(blk, 0)

    # -- alloc / free -------------------------------------------------------
    def alloc(self, n: int):
        """Take ``n`` fresh blocks (refcount 1).  Prefers the zeroed free
        list, then evicts cached prefix blocks LRU-first.  Returns
        ``(ids, scrub)`` where ``scrub`` lists evicted blocks the caller
        must zero before use, or ``None`` if the pool cannot cover ``n``."""
        if n > self.available:
            return None
        ids, scrub = [], []
        for _ in range(n):
            if self._free:
                b = self._free.pop()
            else:
                key, b = self._cached.popitem(last=False)
                self._unregister(b, key)
                self.evictions += 1
                scrub.append(b)
            self._ref[b] = 1
            ids.append(b)
        return ids, scrub

    def free(self, ids):
        """Drop one reference per id.  Returns the blocks that fully died
        *unhashed* — the caller must zero exactly those (hashed blocks keep
        their bytes in the cached pool for future prefix hits)."""
        dead = []
        for b in ids:
            r = self._ref.get(b, 0) - 1
            if r < 0:
                raise ValueError(f"refcount underflow freeing block {b}")
            if r == 0:
                del self._ref[b]
                key = self._key_of.get(b)
                if key is not None:
                    self._cached[key] = b
                else:
                    self._free.append(b)
                    dead.append(b)
            else:
                self._ref[b] = r
        return dead

    # -- prefix registry ----------------------------------------------------
    def _unregister(self, blk, key=None):
        key = self._key_of.pop(blk, None) or key
        if key is not None:
            self._blk_of.pop(key, None)
            self._tail_rows.pop(key, None)
            self._cached.pop(key, None)

    def register_page(self, digest: bytes, blk: int) -> bool:
        """Hash a fully-written prompt page.  First writer wins; a block can
        carry at most one registration."""
        key = ("F", digest)
        if key in self._blk_of or blk in self._key_of:
            return False
        self._blk_of[key] = blk
        self._key_of[blk] = key
        return True

    def register_tail(self, digest: bytes, blk: int, rows: int) -> bool:
        """Hash a *partial* final prompt page (``rows`` valid rows) — the COW
        seed: later prompts sharing those rows copy this block and write
        their divergent rows into the copy."""
        key = ("P", digest)
        if key in self._blk_of or blk in self._key_of or rows <= 0:
            return False
        self._blk_of[key] = blk
        self._key_of[blk] = key
        self._tail_rows[key] = rows
        return True

    def match_pages(self, digests) -> list:
        """Longest run of registered full-page digests; matched blocks gain
        a reference (resurrecting cached blocks as needed)."""
        ids = []
        for d in digests:
            self.lookups += 1
            b = self._blk_of.get(("F", d))
            if b is None:
                break
            self.hits += 1
            self._retain(b, ("F", d))
            ids.append(b)
        return ids

    def match_tail(self, digests):
        """Longest registered partial-tail match among token-chain ``digests``
        (index i = digest over the first i+1 tail tokens).  Returns
        ``(blk, rows)`` for the COW source or None.  The source block is NOT
        ref-bumped: the caller copies its bytes into a fresh block and the
        two diverge immediately."""
        best = None
        for i, d in enumerate(digests):
            key = ("P", d)
            b = self._blk_of.get(key)
            if b is not None and self._tail_rows.get(key) == i + 1:
                best = (b, i + 1)
        self.lookups += 1
        if best is not None:
            self.hits += 1
            self.cow_copies += 1
        return best

    def _retain(self, blk, key):
        if blk in self._ref:
            self._ref[blk] += 1
        else:
            self._ref[blk] = 1
            self._cached.pop(key, None)

    def forget(self, blk: int):
        """Drop a block's hash registration (without touching refcounts) —
        used when its bytes stop being trustworthy (fault injection) so no
        future prompt can match into it.  Returns blocks the caller must
        zero (a cached block demoted to the plain free list)."""
        key = self._key_of.get(blk)
        if key is None:
            return []
        self._unregister(blk, key)
        if blk not in self._ref:
            # was parked in the cached pool: demote to plain free
            self._free.append(blk)
            return [blk]
        return []

    @property
    def hit_rate(self) -> float:
        return self.hits / self.lookups if self.lookups else float("nan")


def prefix_tail_digests(seed: bytes, tail_tokens) -> list:
    """Token-wise chain digests of a prompt's partial final page, seeded by
    the full-page chain digest: element ``i`` hashes the first ``i+1`` tail
    tokens.  Probing every prefix of the tail against the allocator's
    partial registry finds the longest COW match in O(page) hashes."""
    out, h = [], seed
    for t in np.asarray(tail_tokens, np.int32).ravel():
        h = _chain(h, np.asarray([t], np.int32))
        out.append(h)
    return out
