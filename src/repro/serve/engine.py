"""Serving engine: batched prefill + decode with per-family caches, greedy /
temperature sampling, and optional VUSA-packed MLP execution (the paper's
technique on the inference path, where weight-byte savings pay off).

The decode loop is *fused on device* (DESIGN.md §4): one jitted
``lax.scan`` steps the model ``max_new - 1`` times, deriving per-token
sampling keys on device and stacking tokens into a pre-allocated output
buffer, so generation costs a single dispatch and a single
``block_until_ready`` — no per-token host round-trip.  The seed per-token
host loop is kept behind ``ServeConfig.fused = False`` as the measured
baseline (benchmarks/run.py bench_decode_fused) and as a parity oracle:
both paths split the PRNG key identically, so for a fixed seed they emit
identical tokens.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..configs.base import ArchConfig
from ..models import build_model

__all__ = ["ServeConfig", "Engine"]


@dataclasses.dataclass
class ServeConfig:
    max_len: int = 256
    temperature: float = 0.0  # 0 => greedy
    seed: int = 0
    packed_mlp: bool = False  # run MLP matmuls VUSA-packed (dense family)
    vusa_m: int = 128  # window lanes (kernel tile)
    vusa_a: int = 16  # physical slots per row per job
    fused: bool = True  # on-device lax.scan decode loop (False = seed host loop)


class Engine:
    def __init__(self, cfg: ArchConfig, params, sc: Optional[ServeConfig] = None):
        sc = ServeConfig() if sc is None else sc
        self.cfg, self.sc = cfg, sc
        self.model = build_model(cfg)
        self.params = params
        self._packed = None
        if sc.packed_mlp:
            from .packed import pack_lm_mlps  # local import: needs kernels

            self._packed = pack_lm_mlps(cfg, params, sc.vusa_m, sc.vusa_a)
        self._decode = jax.jit(self._decode_fn)
        self._decode_loop = jax.jit(self._decode_loop_fn, static_argnums=(4,))
        self._prime_loop = jax.jit(self._prime_loop_fn)
        self._prefill = jax.jit(self._prefill_fn) if cfg.family in (
            "dense", "moe", "vlm", "encdec") else None

    # -- jitted bodies --------------------------------------------------------
    def _decode_fn(self, params, token, cache, key):
        if self._packed is not None:
            from .packed import lm_decode_step_packed

            logits, cache = lm_decode_step_packed(
                params, self._packed, token, cache, self.cfg
            )
        else:
            logits, cache = self.model.decode_step(params, token, cache)
        logits = logits[:, -1].astype(jnp.float32)
        if self.sc.temperature > 0:
            nxt = jax.random.categorical(key, logits / self.sc.temperature)
        else:
            nxt = jnp.argmax(logits, axis=-1)
        return nxt.astype(jnp.int32)[:, None], cache

    def _decode_loop_fn(self, params, token, cache, key, steps: int):
        """Fused decode: ``steps`` model steps in one on-device scan.

        The scan's stacked output is the pre-allocated (steps, B) token
        buffer; sampling keys are split on device each step, mirroring the
        host loop's ``jax.random.split`` sequence exactly.
        """

        def body(carry, _):
            token, cache, key = carry
            key, sub = jax.random.split(key)
            token, cache = self._decode_fn(params, token, cache, sub)
            return (token, cache, key), token[:, 0]

        (token, cache, key), toks = jax.lax.scan(
            body, (token, cache, key), None, length=steps
        )
        return toks.T, token, cache, key  # (B, steps)

    def _prime_loop_fn(self, params, prompts, cache, key):
        """Recurrent-family prompt priming: scan the prompt through decode
        steps on device (state capture is O(1) per token)."""

        def body(carry, tok):
            _, cache, key = carry
            key, sub = jax.random.split(key)
            nxt, cache = self._decode_fn(params, tok[:, None], cache, sub)
            return (nxt, cache, key), None

        init = (prompts[:, :1], cache, key)
        (nxt, cache, key), _ = jax.lax.scan(body, init, prompts.T)
        return nxt, cache, key

    def _prefill_fn(self, params, batch):
        return self.model.prefill(params, batch, self.sc.max_len)

    # -- reusable entry points (used by generate and serve/scheduler.py) ------
    def prime(self, prompts, key, extras: Optional[Dict] = None):
        """Run the prompt through the model: returns ``(first_token, cache,
        key)`` ready for decode.  ``prompts``: (B, S) int32.

        Prefill families (dense/moe/vlm/encdec) bulk-fill the KV cache and
        emit the argmax first token without consuming the key; recurrent
        families scan the prompt through decode steps, splitting the key per
        prompt token — both exactly as the seed host loop did, so the key
        stream stays bit-compatible across paths.
        """
        batch = {"tokens": jnp.asarray(prompts)}
        if extras:
            batch.update({k: jnp.asarray(v) for k, v in extras.items()})
        if self._prefill is not None:
            logits, cache = self._prefill(self.params, batch)
            nxt = jnp.argmax(logits.astype(jnp.float32), axis=-1)[:, None].astype(jnp.int32)
        elif self.sc.fused:
            cache = self.model.init_cache(prompts.shape[0], self.sc.max_len)
            nxt, cache, key = self._prime_loop(self.params, jnp.asarray(prompts), cache, key)
        else:
            # seed path: prime the state by stepping through the prompt
            cache = self.model.init_cache(prompts.shape[0], self.sc.max_len)
            nxt = jnp.asarray(prompts[:, :1])
            for t in range(prompts.shape[1]):
                key, sub = jax.random.split(key)
                tok = jnp.asarray(prompts[:, t : t + 1])
                nxt, cache = self._decode(self.params, tok, cache, sub)
        return nxt, cache, key

    def decode_segment(self, token, cache, key, steps: int):
        """``steps`` fused decode steps in one dispatch: returns
        ``(tokens (B, steps), last_token, cache, key)``."""
        return self._decode_loop(self.params, token, cache, key, steps)

    # -- public API -----------------------------------------------------------
    def generate(self, prompts: np.ndarray, max_new: int = 32, extras: Optional[Dict] = None):
        """prompts: (B, S) int32.  Returns dict with tokens and timing.

        Thin wrapper over ``prime`` + one full-length ``decode_segment``
        (a single-request schedule with one segment); the seed per-token
        host loop survives behind ``ServeConfig.fused = False`` as the
        parity oracle.  ``tok_per_s`` counts only the ``max_new - 1``
        decoded tokens on both paths (the first token comes out of prime
        and is billed to ``prefill_s``).
        """
        b = prompts.shape[0]
        key = jax.random.key(self.sc.seed)
        t0 = time.time()
        nxt, cache, key = self.prime(prompts, key, extras)
        jax.block_until_ready(nxt)
        t_prefill = time.time() - t0

        t0 = time.time()
        if self.sc.fused:
            toks, _, cache, key = self.decode_segment(nxt, cache, key, max_new - 1)
            jax.block_until_ready(toks)
            t_decode = time.time() - t0
            tokens = np.concatenate([np.asarray(nxt), np.asarray(toks)], axis=1)
        else:
            out = [np.asarray(nxt)]
            for _ in range(max_new - 1):
                key, sub = jax.random.split(key)
                nxt, cache = self._decode(self.params, nxt, cache, sub)
                out.append(np.asarray(nxt))
            jax.block_until_ready(nxt)
            t_decode = time.time() - t0
            tokens = np.concatenate(out, axis=1)
        return {
            "tokens": tokens,
            "prefill_s": t_prefill,
            "decode_s": t_decode,
            "tok_per_s": b * (max_new - 1) / max(t_decode, 1e-9),
        }
