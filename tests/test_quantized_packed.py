"""Quantized packed decode (DESIGN.md §10): int8 / int4-nibble window values
with per-window fp32 scales and dequant fused into VMEM reconstruction.

The correctness contract, layer by layer:
* kernels      — quantized ``apply_row_packed``/``apply_fused_mlp`` match the
  jnp dequant oracle (same qdq grid, fp32 accumulation) and the dense matmul
  over the host-side quantize-dequantize matrix.
* serve        — ``packed_values="bf16"`` is byte-identical to the pre-§10
  dense-value path; ``packed_values="int8"`` greedy tokens are bit-exact vs
  a quantize-dequantize-then-dense oracle (``qdq_lm_params``), one-shot and
  through the Scheduler; byte ratios meet the §10 ceilings.
* validation   — ``validate_packed`` refuses quantized packs with missing /
  malformed / non-finite / non-positive scales.
* chaos        — value-corruption faults on quantized packs NaN the dequant
  scale (int8 bytes can't hold a NaN) and still reach the runtime guard.
* sharding     — window-sharded quantized packs match the single-device
  kernel across real multi-device meshes.
* N:M arm      — the S2TA-style structured pack rides the same kernel.
"""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs import get_smoke_config
from repro.core.packing import nm_mask, pack_rows, quantize_rows, unpack_rows
from repro.core.pruning import prune_tree
from repro.kernels.ops import (
    _KBLK_CACHE,
    apply_fused_mlp,
    apply_fused_mlp_ref,
    apply_fused_mlp_sharded,
    apply_row_packed,
    apply_row_packed_ref,
    apply_row_packed_sharded,
    autotune_row_packed,
    dequantize_linear_values,
    pack_linear_rows,
    pack_linear_rows_nm,
    pack_linear_rows_t,
)
from repro.models import build_model
from repro.serve import Engine, FaultConfig, Request, Scheduler, ServeConfig
from repro.serve.faults import corrupt_pack_values
from repro.serve.packed import (
    pack_lm_weights,
    packed_byte_ratios,
    qdq_lm_params,
    validate_packed,
)


def _sparse(rng, k, c, sparsity, dtype=np.float32):
    w = rng.normal(size=(k, c)) * (rng.random((k, c)) > sparsity)
    return w.astype(dtype)


def _qdq_dense(w, m, a, value_dtype):
    """Host-side quantize-dequantize of a dense matrix under pack geometry."""
    from repro.core.packing import dequantize_rows

    return unpack_rows(dequantize_rows(quantize_rows(pack_rows(w, m=m, a=a), value_dtype)))


# ---------------------------------------------------------------------------
# kernels: fused dequant vs oracle
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("dt", ["int8", "int4"])
def test_quantized_kernel_matches_dequant_oracle(dt):
    """The in-kernel nibble/scale dequant reproduces the jnp dequant oracle
    and the dense matmul over the host qdq matrix (fp32 accumulation both
    sides; tolerance is accumulation order only)."""
    rng = np.random.default_rng(0)
    k, c, b = 64, 256, 4
    w = _sparse(rng, k, c, 0.85)
    p = pack_linear_rows(w, a=8, value_dtype=dt)
    assert p.value_dtype == dt and p.scales is not None
    x = jnp.asarray(rng.normal(size=(b, k)), jnp.float32)
    got = np.asarray(apply_row_packed(x, p), np.float32)
    ref = np.asarray(apply_row_packed_ref(x, p), np.float32)
    np.testing.assert_allclose(got, ref, rtol=1e-5, atol=1e-5)
    dense = np.asarray(x, np.float32) @ _qdq_dense(w, 128, 8, dt)
    np.testing.assert_allclose(got, dense, rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("dt", ["int8", "int4"])
def test_quantized_fused_mlp_matches_ref(dt):
    rng = np.random.default_rng(1)
    d, ff = 64, 256
    pg = pack_linear_rows(_sparse(rng, d, ff, 0.85), a=8, value_dtype=dt)
    pu = pack_linear_rows(_sparse(rng, d, ff, 0.85), a=8, value_dtype=dt)
    pd = pack_linear_rows_t(_sparse(rng, ff, d, 0.85), a=8, value_dtype=dt)
    x = jnp.asarray(rng.normal(size=(3, d)), jnp.float32)
    got = np.asarray(apply_fused_mlp(x, pg, pu, pd), np.float32)
    ref = np.asarray(apply_fused_mlp_ref(x, pg, pu, pd), np.float32)
    np.testing.assert_allclose(got, ref, rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("dt", ["int8", "int4"])
@pytest.mark.parametrize("k,c", [(48, 200), (100, 130), (64, 96)])
def test_quantized_kernel_nondivisible_shapes(dt, k, c):
    """Ragged dims: padded lanes / nibble-padded slots must be exact no-ops."""
    rng = np.random.default_rng(2)
    w = _sparse(rng, k, c, 0.9)
    p = pack_linear_rows(w, m=32, a=4, value_dtype=dt)
    x = jnp.asarray(rng.normal(size=(2, k)), jnp.float32)
    got = np.asarray(apply_row_packed(x, p, k_blk=32), np.float32)
    dense = np.asarray(x, np.float32) @ _qdq_dense(w, 32, 4, dt)
    np.testing.assert_allclose(got, dense, rtol=1e-4, atol=1e-4)


def test_quantized_all_zero_matrix_exact_zero():
    for dt in ("int8", "int4"):
        p = pack_linear_rows(np.zeros((32, 64), np.float32), m=32, a=4, value_dtype=dt)
        x = jnp.asarray(np.random.default_rng(3).normal(size=(2, 32)), jnp.float32)
        got = np.asarray(apply_row_packed(x, p), np.float32)
        np.testing.assert_array_equal(got, np.zeros_like(got))


def test_dequantize_linear_values_matches_host():
    """The jnp dequant twin (ref path) agrees with the numpy codec."""
    rng = np.random.default_rng(4)
    w = _sparse(rng, 32, 100, 0.8)
    for dt in ("int8", "int4"):
        p = pack_linear_rows(w, m=32, a=4, value_dtype=dt)
        from repro.core.packing import dequantize_rows

        host = dequantize_rows(quantize_rows(pack_rows(w, m=32, a=4), dt)).values
        np.testing.assert_array_equal(np.asarray(dequantize_linear_values(p)), host)


def test_tune_key_separates_value_dtypes():
    """int8 and int4 packs share the jnp int8 value dtype, so the autotune
    cache must key on the explicit value_dtype tag, not the array dtype."""
    rng = np.random.default_rng(5)
    w = _sparse(rng, 64, 128, 0.85)
    x = jnp.asarray(rng.normal(size=(4, 64)), jnp.float32)
    before = dict(_KBLK_CACHE)
    try:
        _KBLK_CACHE.clear()
        for dt in ("dense", "int8", "int4"):
            autotune_row_packed(x, pack_linear_rows(w, a=8, value_dtype=dt), iters=1)
        assert len(_KBLK_CACHE) == 3
    finally:
        _KBLK_CACHE.clear()
        _KBLK_CACHE.update(before)


# ---------------------------------------------------------------------------
# N:M structured comparison arm (S2TA DBB) through the same kernel
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("dt", ["dense", "int8"])
def test_nm_pack_through_kernel(dt):
    rng = np.random.default_rng(6)
    k, c = 64, 160
    w = _sparse(rng, k, c, 0.0)  # dense input: N:M does all the pruning
    p = pack_linear_rows_nm(w, n=2, block=4, m=32, a=4, value_dtype=dt)
    masked = np.where(nm_mask(w, 2, 4), w, 0.0)
    x = jnp.asarray(rng.normal(size=(2, k)), jnp.float32)
    got = np.asarray(apply_row_packed(x, p), np.float32)
    if dt == "dense":
        dense = np.asarray(x, np.float32) @ masked
    else:
        dense = np.asarray(x, np.float32) @ _qdq_dense(masked, 32, 4, dt)
    np.testing.assert_allclose(got, dense, rtol=1e-4, atol=1e-4)
    # structural slot bound: n * ceil(m / block), rounded up to a
    assert p.slots <= -(-(2 * -(-32 // 4)) // 4) * 4


# ---------------------------------------------------------------------------
# sharded quantized parity (real multi-device meshes via conftest's 8 CPUs)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("dt", ["int8", "int4"])
def test_quantized_sharded_matches_single(dt):
    from repro.launch.mesh import make_serve_mesh

    rng = np.random.default_rng(7)
    w = _sparse(rng, 48, 5 * 32 - 3, 0.85)  # 5 windows -> padded to 8
    p = pack_linear_rows(w, m=32, a=4, value_dtype=dt)
    x = jnp.asarray(rng.normal(size=(2, 48)), jnp.float32)
    ref = np.asarray(apply_row_packed(x, p), np.float32)
    mesh = make_serve_mesh("1,4")
    got = np.asarray(apply_row_packed_sharded(x, p, mesh), np.float32)
    np.testing.assert_allclose(got, ref, rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("dt", ["int8", "int4"])
def test_quantized_fused_sharded_matches_single(dt):
    from repro.launch.mesh import make_serve_mesh

    rng = np.random.default_rng(8)
    d, ff = 48, 4 * 32  # 4 ff windows over a 4-way model axis
    pg = pack_linear_rows(_sparse(rng, d, ff, 0.85), m=32, a=4, value_dtype=dt)
    pu = pack_linear_rows(_sparse(rng, d, ff, 0.85), m=32, a=4, value_dtype=dt)
    pd = pack_linear_rows_t(_sparse(rng, ff, d, 0.85), m=32, a=4, value_dtype=dt)
    x = jnp.asarray(rng.normal(size=(2, d)), jnp.float32)
    ref = np.asarray(apply_fused_mlp(x, pg, pu, pd), np.float32)
    mesh = make_serve_mesh("1,4")
    got = np.asarray(apply_fused_mlp_sharded(x, pg, pu, pd, mesh), np.float32)
    np.testing.assert_allclose(got, ref, rtol=1e-5, atol=2e-5)


# ---------------------------------------------------------------------------
# serve: bf16 byte-identity, int8 oracle bit-parity, ratios, validation
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def vusa_pruned():
    cfg = get_smoke_config("vusa_edge")
    params = prune_tree(build_model(cfg).init(jax.random.key(0)), 0.85)
    return cfg, params


def test_serveconfig_packed_values_validation():
    assert ServeConfig().packed_values == "bf16"  # default: pre-§10 behaviour
    assert ServeConfig(packed_values="int8").packed_values == "int8"
    with pytest.raises(ValueError):
        ServeConfig(packed_values="fp8")


def test_bf16_pack_byte_identity(vusa_pruned):
    """``packed_values="bf16"`` must be the pre-§10 dense-value path exactly:
    same tokens as the dense engine, and the pack carries no quant metadata."""
    cfg, params = vusa_pruned
    prompts = np.ones((2, 8), np.int32)
    dense = Engine(cfg, params, ServeConfig(max_len=64))
    eng = Engine(
        cfg, params,
        ServeConfig(max_len=64, packed_weights="all", packed_values="bf16"),
    )
    for _, e in _flat(eng._packed):
        assert e.get("value_dtype", "dense") == "dense"
        assert "scales" not in e
    np.testing.assert_array_equal(
        eng.generate(prompts, max_new=8)["tokens"],
        dense.generate(prompts, max_new=8)["tokens"],
    )


def _flat(packed):
    from repro.serve.packed import _flat_entries

    return _flat_entries(packed).items()


def test_int8_tokens_match_qdq_dense_oracle(vusa_pruned):
    """The §10 acceptance bar: greedy tokens under int8 packs are bit-exact
    vs a dense engine running on quantize-dequantize'd weights."""
    cfg, params = vusa_pruned
    prompts = np.ones((2, 8), np.int32)
    eng = Engine(
        cfg, params,
        ServeConfig(max_len=64, packed_weights="all", packed_values="int8"),
    )
    oracle = Engine(cfg, qdq_lm_params(cfg, params, value_dtype="int8"),
                    ServeConfig(max_len=64))
    np.testing.assert_array_equal(
        eng.generate(prompts, max_new=8)["tokens"],
        oracle.generate(prompts, max_new=8)["tokens"],
    )


def test_int8_scheduler_tokens_match_qdq_dense_oracle(vusa_pruned):
    """Same bar through the Scheduler's vmapped slot axis (greedy)."""
    cfg, params = vusa_pruned
    rng = np.random.default_rng(9)
    prompts = [rng.integers(0, 100, n).astype(np.int32) for n in (4, 6, 5)]

    def run(engine):
        sched = Scheduler(engine, slots=2, segment=4)
        return sched.run([
            Request(prompt=prompts[i], max_new=8, seed=50 + i)
            for i in range(len(prompts))
        ])

    got = run(Engine(
        cfg, params,
        ServeConfig(max_len=64, packed_weights="all", packed_values="int8"),
    ))
    ref = run(Engine(cfg, qdq_lm_params(cfg, params, value_dtype="int8"),
                     ServeConfig(max_len=64)))
    assert sorted(got) == sorted(ref)
    for rid in ref:
        np.testing.assert_array_equal(got[rid].tokens, ref[rid].tokens,
                                      err_msg=f"rid {rid}")


def test_int4_engine_serves_and_validates(vusa_pruned):
    """int4 is gated on kernel closeness + ratios (token parity vs the qdq
    oracle is not promised: the oracle *prefills* on qdq weights while the
    packed engine prefills dense, so near-tie argmaxes may flip).  The engine
    must still validate, serve, and emit finite in-vocab tokens."""
    cfg, params = vusa_pruned
    eng = Engine(
        cfg, params,
        ServeConfig(max_len=64, packed_weights="all", packed_values="int4"),
    )
    validate_packed(eng._packed)
    toks = eng.generate(np.ones((2, 8), np.int32), max_new=8)["tokens"]
    assert toks.shape == (2, 8)
    assert (toks >= 0).all() and (toks < cfg.vocab).all()


@pytest.mark.parametrize("dt,ceiling", [("int8", 0.25), ("int4", 0.15)])
def test_quantized_byte_ratio_ceilings(vusa_pruned, dt, ceiling):
    """§10 HBM budget at 85% sparsity: int8 total <= 0.25x dense, int4 <=
    0.15x (measured ~0.162 / ~0.124 on the smoke model; bf16-pack ~0.38)."""
    cfg, params = vusa_pruned
    packed = pack_lm_weights(cfg, params, scope="all", value_dtype=dt)
    ratios = packed_byte_ratios(packed)
    assert ratios["total"] <= ceiling, ratios
    dense_ratios = packed_byte_ratios(pack_lm_weights(cfg, params, scope="all"))
    assert ratios["total"] < dense_ratios["total"]


def test_validate_packed_quantized_rejections(vusa_pruned):
    cfg, params = vusa_pruned
    base = pack_lm_weights(cfg, params, scope="all", value_dtype="int8")
    validate_packed(base)  # the clean pack must pass

    def mutate(fn, match):
        packed = {k: (dict(v) if isinstance(v, dict) else v) for k, v in base.items()}
        e = dict(packed["mlp"]["w_gate"])
        fn(e)
        packed["mlp"]["w_gate"] = e
        with pytest.raises(ValueError, match=match):
            validate_packed(packed)

    mutate(lambda e: e.pop("scales"), "missing its scales")
    mutate(lambda e: e.update(scales=e["scales"][..., :-1]), "scales shape")
    mutate(lambda e: e.update(scales=e["scales"].at[0, 0, 0].set(np.nan)),
           "non-finite dequant scale")
    mutate(lambda e: e.update(scales=e["scales"].at[0, 0, 0].set(0.0)),
           "non-positive dequant scale")
    mutate(lambda e: e.update(values=e["values"].astype(jnp.float32)),
           "values dtype must be int8")
    mutate(lambda e: e.update(values=e["values"][..., :-1]), "does not decode")


def test_fault_injection_nans_scale_for_quantized(vusa_pruned):
    """Post-load value corruption on a quantized pack lands on the dequant
    scale (int8 bytes can't encode NaN); values/positions stay untouched so
    the fault is runtime-guard territory, not validate_packed's."""
    cfg, params = vusa_pruned
    packed = pack_lm_weights(cfg, params, scope="all", value_dtype="int8")
    out = corrupt_pack_values(packed, FaultConfig(seed=3, pack_value_nans=4))
    nan_scales = 0
    for (_, e), (_, e0) in zip(_flat(out), _flat(packed)):
        nan_scales += int((~np.isfinite(np.asarray(e["scales"]))).sum())
        np.testing.assert_array_equal(np.asarray(e["values"]), np.asarray(e0["values"]))
        np.testing.assert_array_equal(
            np.asarray(e["positions"]), np.asarray(e0["positions"])
        )
    assert nan_scales >= 1  # seeded flips may collide, but at least one lands


def test_quantized_fault_reaches_runtime_guard(vusa_pruned):
    """End to end: a NaN'd scale propagates to the logits and the Scheduler's
    guard + dense fallback still delivers every request."""
    cfg, params = vusa_pruned
    sc = ServeConfig(
        max_len=64, packed_weights="all", packed_values="int8",
        faults=FaultConfig(seed=5, pack_value_nans=3),
    )
    sched = Scheduler(Engine(cfg, params, sc), slots=2, segment=4)
    done = sched.run([
        Request(prompt=np.arange(1, 7, dtype=np.int32), max_new=6, seed=i)
        for i in range(3)
    ])
    assert len(done) == 3
    for rid, c in done.items():
        assert c.status.value in ("OK", "FAILED_FALLBACK_OK"), (rid, c.status)
        assert len(c.tokens) == 6
