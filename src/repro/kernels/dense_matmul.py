"""Pallas TPU kernel: dense tiled matmul — the "standard weight-stationary
systolic array" baseline the paper compares VUSA against (Table I-III).

Classic MXU tiling: grid over (M/bm, N/bn, K/bk); the K axis is the
innermost (sequential) grid dimension so the fp32 accumulator lives in the
output block across K steps.  Block shapes are MXU-aligned (multiples of
8 x 128).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["dense_matmul"]


def _kernel(x_ref, w_ref, y_ref):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        y_ref[...] = jnp.zeros_like(y_ref)

    y_ref[...] += jnp.dot(
        x_ref[...].astype(jnp.float32),
        w_ref[...].astype(jnp.float32),
        preferred_element_type=jnp.float32,
    ).astype(y_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk", "interpret"))
def dense_matmul(
    x: jax.Array,  # (M, K)
    w: jax.Array,  # (K, N)
    *,
    bm: int = 128,
    bn: int = 128,
    bk: int = 128,
    interpret: bool = True,
) -> jax.Array:
    m, k = x.shape
    _, n = w.shape
    bm, bn, bk = min(bm, m), min(bn, n), min(bk, k)
    assert m % bm == 0 and n % bn == 0 and k % bk == 0, (m, n, k, bm, bn, bk)
    grid = (m // bm, n // bn, k // bk)
    return pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, l: (i, l)),
            pl.BlockSpec((bk, bn), lambda i, j, l: (l, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, l: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=interpret,
    )(x, w)
