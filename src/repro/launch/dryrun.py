import os

os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 " + os.environ.get("XLA_FLAGS", "")
)

"""Multi-pod dry-run: AOT lower + compile every (architecture x input shape)
cell on the production meshes, and record memory / cost / collective
statistics for the roofline analysis.

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun --arch qwen3-8b --shape train_4k
    PYTHONPATH=src python -m repro.launch.dryrun --all            # every cell
    PYTHONPATH=src python -m repro.launch.dryrun --all --mesh multi

Results are appended incrementally to experiments/dryrun/*.json so the sweep
is resumable and partial results survive crashes.
"""

import argparse  # noqa: E402
import json  # noqa: E402
import re  # noqa: E402
import time  # noqa: E402
import traceback  # noqa: E402
from pathlib import Path  # noqa: E402

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402

from ..configs import ARCH_IDS, SHAPES, get_config  # noqa: E402
from ..dist.sharding import (  # noqa: E402
    act_rules,
    batch_shardings,
    params_shardings,
    serve_shardings,
)
from ..models import build_model  # noqa: E402
from ..models.common import abstract_params, mesh_context  # noqa: E402
from ..optim import AdamState  # noqa: E402
from ..train.step import TrainHParams, make_train_step  # noqa: E402
from .mesh import make_production_mesh  # noqa: E402

OUT_DIR = Path(__file__).resolve().parents[3] / "experiments" / "dryrun"

# long_500k requires sub-quadratic attention; these archs run it, the pure
# full-attention ones are recorded as explicit skips (DESIGN.md §Shape notes).
LONG_OK = {"recurrentgemma_9b", "mamba2_2_7b"}

_COLL_RE = re.compile(
    r"^\s*(?:%?\S+\s*=\s*)?"
    r"((?:\([^)]*\)|\S+?))\s+"  # result type (may be a tuple)
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(",
    re.M,
)
_SHAPE_RE = re.compile(r"(f64|f32|bf16|f16|f8\w*|s64|s32|s16|s8|u64|u32|u16|u8|pred)\[([\d,]*)\]")
_BYTES = {"f64": 8, "s64": 8, "u64": 8, "f32": 4, "s32": 4, "u32": 4, "bf16": 2,
          "f16": 2, "s16": 2, "u16": 2, "s8": 1, "u8": 1, "pred": 1}
_GROUPS_RE = re.compile(r"replica_groups=\{?\{([\d,]+)\}")


def collective_stats(hlo_text: str) -> dict:
    """Sum result-operand bytes per collective type from (S)HLO text.

    Sizes are per-device (the module is the SPMD per-device program)."""
    stats: dict = {}
    for m in _COLL_RE.finditer(hlo_text):
        rtype, op = m.group(1), m.group(2)
        nbytes = 0
        for sm in _SHAPE_RE.finditer(rtype):
            dt, dims = sm.group(1), sm.group(2)
            n = 1
            for d in dims.split(","):
                if d:
                    n *= int(d)
            nbytes += n * _BYTES.get(dt.split("e")[0] if dt.startswith("f8") else dt, 4)
        # group size (participants) for this collective, if printed on the line
        line = hlo_text[m.start() : hlo_text.find("\n", m.start())]
        gm = _GROUPS_RE.search(line)
        gsize = len(gm.group(1).split(",")) if gm else 0
        e = stats.setdefault(op, {"count": 0, "bytes": 0, "group_sizes": {}})
        e["count"] += 1
        e["bytes"] += nbytes
        if gsize:
            e["group_sizes"][str(gsize)] = e["group_sizes"].get(str(gsize), 0) + 1
    return stats


def _abstract_adam(params_abs) -> AdamState:
    f32 = lambda t: jax.tree_util.tree_map(
        lambda s: jax.ShapeDtypeStruct(s.shape, jnp.float32), t
    )
    return AdamState(
        step=jax.ShapeDtypeStruct((), jnp.int32), mu=f32(params_abs), nu=f32(params_abs)
    )


def lower_cell(arch: str, shape_name: str, mesh, rules, variant: str = "baseline") -> dict:
    """Lower + compile one (arch, shape, mesh) cell; return the record.

    variant: "baseline" = paper-faithful first implementation (flash-chunked
    decode attention, scatter MoE, default sharding); "opt" = the hillclimbed
    lowering (EXPERIMENTS.md §Perf records the A/B)."""
    from ..models import opt_flags

    (opt_flags.set_baseline if variant == "baseline" else opt_flags.set_opt)()
    # fine-grained overrides for hypothesis-level A/B: REPRO_FLAGS="name=0,name=1"
    for kv in filter(None, os.environ.get("REPRO_FLAGS", "").split(",")):
        name, val = kv.split("=")
        opt_flags.FLAGS[name.strip()] = bool(int(val))
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    model = build_model(cfg)
    params_abs = abstract_params(model.specs())
    p_shard = params_shardings(model.specs(), mesh)

    if shape.kind == "train":
        hp = TrainHParams(microbatches=1)
        step_fn = make_train_step(model.loss, hp)
        batch_abs = model.input_specs(shape.global_batch, shape.seq_len, "train")
        opt_abs = _abstract_adam(params_abs)
        opt_shard = AdamState(
            step=jax.sharding.NamedSharding(mesh, jax.sharding.PartitionSpec()),
            mu=p_shard,
            nu=p_shard,
        )
        b_shard = batch_shardings(mesh, batch_abs)
        with mesh_context(mesh, rules):
            jitted = jax.jit(
                step_fn,
                in_shardings=(p_shard, opt_shard, b_shard),
                donate_argnums=(0, 1),
            )
            lowered = jitted.lower(params_abs, opt_abs, batch_abs)
    elif shape.kind == "prefill":
        batch_abs = model.input_specs(shape.global_batch, shape.seq_len, "prefill")
        b_shard = batch_shardings(mesh, batch_abs)

        if cfg.family in ("dense", "moe", "vlm", "encdec"):
            fn = lambda p, b: model.prefill(p, b, shape.seq_len)
        else:  # hybrid/ssm prefill == scoring pass (state capture is O(1))
            fn = lambda p, b: model.forward(p, b)
        with mesh_context(mesh, rules):
            jitted = jax.jit(fn, in_shardings=(p_shard, b_shard))
            lowered = jitted.lower(params_abs, batch_abs)
    else:  # decode
        cache_abs = model.cache_specs(shape.global_batch, shape.seq_len)
        c_shard = serve_shardings(cache_abs, mesh, shape.global_batch)
        tok_abs = model.input_specs(shape.global_batch, shape.seq_len, "decode")["token"]
        t_shard = batch_shardings(mesh, {"token": tok_abs})["token"]

        def serve_step(p, tok, cache):
            return model.decode_step(p, tok, cache)

        with mesh_context(mesh, rules):
            jitted = jax.jit(
                serve_step, in_shardings=(p_shard, t_shard, c_shard), donate_argnums=(2,)
            )
            lowered = jitted.lower(params_abs, tok_abs, cache_abs)

    t0 = time.time()
    compiled = lowered.compile()
    compile_s = time.time() - t0
    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    if os.environ.get("DRYRUN_PRINT", "1") != "0":
        print(mem)  # proves it fits
        keep = ("flops", "bytes accessed", "transcendentals")
        print({k: v for k, v in (cost or {}).items() if k in keep})
    hlo_text = compiled.as_text()
    # keep the optimized HLO for hillclimb diffing / re-analysis
    import gzip

    hlo_dir = OUT_DIR.parent / "hlo"
    hlo_dir.mkdir(parents=True, exist_ok=True)
    mesh_kind = "multi" if "pod" in mesh.shape else "single"
    with gzip.open(hlo_dir / f"{arch}__{shape_name}__{mesh_kind}__{variant}.txt.gz", "wt") as fh:
        fh.write(hlo_text)
    colls = collective_stats(hlo_text)
    from .hlo_cost import hlo_cost  # loop-trip-weighted per-device costs

    weighted = hlo_cost(hlo_text)
    record = {
        "arch": arch,
        "shape": shape_name,
        "mesh": dict(mesh.shape),
        "kind": shape.kind,
        "compile_s": round(compile_s, 1),
        "memory": {
            k: int(getattr(mem, k))
            for k in (
                "argument_size_in_bytes",
                "output_size_in_bytes",
                "temp_size_in_bytes",
                "generated_code_size_in_bytes",
            )
            if hasattr(mem, k)
        },
        "cost": {
            k: v
            for k, v in (cost or {}).items()
            if isinstance(v, (int, float)) and k in ("flops", "transcendentals", "bytes accessed")
        },
        # loop-trip-weighted, per-device (see launch/hlo_cost.py)
        "weighted": {
            "dot_flops": weighted["dot_flops"],
            "bytes": weighted["bytes"],
            "transcendentals": weighted["transcendentals"],
            "collectives": weighted["collectives"],
        },
        "collectives_unweighted": colls,
        "n_devices": mesh.devices.size,
    }
    return record


def cells(mesh_kind: str):
    for arch in ARCH_IDS:
        if arch == "vusa_edge":
            continue  # paper's own config benched separately, not a pool cell
        for shape_name in SHAPES:
            yield arch, shape_name, mesh_kind


def run_cell(arch: str, shape_name: str, mesh_kind: str, force: bool = False,
             variant: str = "baseline") -> dict:
    OUT_DIR.mkdir(parents=True, exist_ok=True)
    suffix = "" if variant == "baseline" else f"_{variant}"
    out = OUT_DIR / f"{arch}__{shape_name}__{mesh_kind}{suffix}.json"
    if out.exists() and not force:
        return json.loads(out.read_text())
    if shape_name == "long_500k" and arch not in LONG_OK:
        rec = {
            "arch": arch,
            "shape": shape_name,
            "mesh": mesh_kind,
            "status": "skip",
            "reason": "pure full-attention arch: 500k decode is quadratic-class; "
            "see DESIGN.md shape notes",
        }
        out.write_text(json.dumps(rec, indent=1))
        return rec
    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    rules = act_rules(mesh)
    try:
        rec = lower_cell(arch, shape_name, mesh, rules, variant=variant)
        rec["status"] = "ok"
        rec["variant"] = variant
    except Exception as e:  # record failures — they are bugs to fix
        rec = {
            "arch": arch,
            "shape": shape_name,
            "mesh": mesh_kind,
            "status": "fail",
            "error": f"{type(e).__name__}: {e}",
            "trace": traceback.format_exc()[-2000:],
        }
    out.write_text(json.dumps(rec, indent=1))
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="single", choices=["single", "multi", "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--variant", default="baseline",
                    help="baseline | opt | opt<suffix> (suffix for flag A/Bs via REPRO_FLAGS)")
    args = ap.parse_args()

    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]
    if args.all:
        todo = [(a, s, m) for m in meshes for (a, s, _) in cells(m)]
    else:
        assert args.arch and args.shape, "--arch/--shape or --all"
        todo = [(args.arch.replace("-", "_").replace(".", "_"), args.shape, m) for m in meshes]

    for arch, shape_name, mesh_kind in todo:
        t0 = time.time()
        rec = run_cell(arch, shape_name, mesh_kind, force=args.force, variant=args.variant)
        status = rec.get("status")
        extra = ""
        if status == "ok":
            extra = f"compile={rec['compile_s']}s flops={rec['cost'].get('flops', 0):.3g}"
        elif status == "fail":
            extra = rec["error"][:120]
        print(f"[{time.strftime('%H:%M:%S')}] {arch:22s} {shape_name:12s} {mesh_kind:6s} "
              f"{status:5s} ({time.time()-t0:.0f}s) {extra}", flush=True)


if __name__ == "__main__":
    main()
