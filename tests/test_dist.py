"""Distribution-layer tests.

The main test process itself runs on a forced 8-device CPU backend
(tests/conftest.py), so rule/fallback tests use real 2x4 meshes in-process —
prefer that for new tests.  The subprocess harness (`_run`) survives for the
*training* integration tests, which want a 16-device mesh and an isolated
backend (and predate the conftest hook)."""

import subprocess
import sys
import textwrap
from pathlib import Path

import jax

REPO_ROOT = Path(__file__).resolve().parent.parent

from repro.dist.sharding import param_sharding  # noqa: E402
from repro.models.common import ParamSpec  # noqa: E402


def _run(code: str) -> str:
    out = subprocess.run(
        [sys.executable, "-c", code],
        capture_output=True,
        text=True,
        timeout=600,
        env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin",
             # pin the backend: without it, plugin discovery in the bare
             # subprocess env can stall for minutes probing accelerators
             "JAX_PLATFORMS": "cpu",
             "XLA_FLAGS": "--xla_force_host_platform_device_count=16"},
        cwd=str(REPO_ROOT),
    )
    assert out.returncode == 0, out.stderr[-3000:]
    return out.stdout


import pytest  # noqa: E402


@pytest.mark.slow
def test_param_rules_multi_device():
    code = textwrap.dedent("""
        import jax
        from repro.configs import get_config
        from repro.dist.sharding import params_shardings
        from repro.models import build_model

        mesh = jax.make_mesh((4, 4), ("data", "model"))
        for arch in ("qwen3_8b", "olmoe_1b_7b", "mamba2_2_7b"):
            cfg = get_config(arch)
            model = build_model(cfg)
            sh = params_shardings(model.specs(), mesh)
            leaves = jax.tree_util.tree_leaves(sh)
            def uses(spec, axis):
                return any(
                    e == axis or (isinstance(e, tuple) and axis in e)
                    for e in spec if e is not None
                )
            n_model = sum(1 for s in leaves if uses(s.spec, "model"))
            n_data = sum(1 for s in leaves if uses(s.spec, "data"))
            assert n_model > 0, arch  # TP actually engaged
            assert n_data > 0, arch   # FSDP actually engaged
            print(arch, "ok", n_model, "TP +", n_data, "FSDP of", len(leaves))
    """)
    out = _run(code)
    assert out.count("ok") == 3


@pytest.mark.slow
def test_train_step_runs_sharded():
    """A real sharded train step on a 4x4 host-device mesh (tiny model)."""
    code = textwrap.dedent("""
        import jax, numpy as np
        from repro.configs import get_smoke_config
        from repro.train import TrainConfig, Trainer, TrainHParams
        mesh = jax.make_mesh((4, 4), ("data", "model"))
        cfg = get_smoke_config("llama3_2_1b")
        tc = TrainConfig(steps=3, global_batch=8, seq_len=32, prune_begin=100,
                         hp=TrainHParams(lr=1e-3, total_steps=3), log_every=100)
        out = Trainer(cfg, tc, mesh=mesh).train()
        assert np.isfinite(out["final_loss"])
        print("sharded loss", out["final_loss"])
    """)
    out = _run(code)
    assert "sharded loss" in out


@pytest.mark.slow
def test_sharded_matches_single_device():
    """Same seed, same data: 16-device mesh loss == single-device loss."""
    code = textwrap.dedent("""
        import jax, numpy as np
        from repro.configs import get_smoke_config
        from repro.train import TrainConfig, Trainer, TrainHParams
        tc = TrainConfig(steps=2, global_batch=8, seq_len=16, prune_begin=100,
                         hp=TrainHParams(lr=1e-3, total_steps=2), log_every=100)
        cfg = get_smoke_config("qwen2_0_5b")
        from jax.sharding import Mesh
        mesh = jax.make_mesh((4, 4), ("data", "model"))
        l_multi = Trainer(cfg, tc, mesh=mesh).train()["final_loss"]
        mesh1 = Mesh(np.array(jax.devices()[:1]).reshape(1, 1), ("data", "model"))
        l_single = Trainer(cfg, tc, mesh=mesh1).train()["final_loss"]
        print("multi", l_multi, "single", l_single)
        assert abs(l_multi - l_single) < 2e-3, (l_multi, l_single)
    """)
    _run(code)


def test_param_sharding_divisibility_fallback():
    """Non-divisible dims must fall back to replication, never error."""
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    spec = ParamSpec((7, 13), ("embed", "ff"))  # nothing divides
    s = param_sharding(spec, mesh)
    assert s.spec == jax.sharding.PartitionSpec(None, None)


def test_batch_sharding_non_divisible_batch():
    from repro.dist.sharding import batch_sharding

    mesh = jax.make_mesh((1, 1), ("data", "model"))
    s = batch_sharding(mesh, batch_size=1, ndim=2)  # long_500k case
    assert s.spec[0] in (None, "data")  # batch=1 on 1-dev mesh: either is valid


# ---------------------------------------------------------------------------
# odd-dim fallbacks on a real multi-device mesh (tests/conftest.py forces 8
# host devices, so these run against actual 2x4 shardings, not 1x1 stubs)
# ---------------------------------------------------------------------------


def _mesh24():
    import pytest

    if len(jax.devices()) < 8:
        pytest.skip("needs 8 forced host devices")
    return jax.make_mesh((2, 4), ("data", "model"))


def test_param_sharding_odd_dims_replicate_on_real_mesh():
    """e.g. a vocab the model axis does not divide: replicate, never error —
    and dims that do divide still shard (partial fallback, per-dim)."""
    mesh = _mesh24()
    P = jax.sharding.PartitionSpec
    # vocab 151 not divisible by model=4 -> replicated; embed 6 not divisible
    # by data=2? 6 % 2 == 0 -> sharded
    s = param_sharding(ParamSpec((151, 6), ("vocab", "embed")), mesh)
    assert s.spec == P(None, "data")
    # both odd -> fully replicated
    s = param_sharding(ParamSpec((151, 7), ("vocab", "embed")), mesh)
    assert s.spec == P(None, None)
    # zero-size and size-1 dims never error
    s = param_sharding(ParamSpec((1, 3), ("vocab", "embed")), mesh)
    assert s.spec == P(None, None)


def test_window_sharding_fallback():
    """Packed-weight window axes (values AND the int8 positions metadata):
    divisible counts shard over `model`, odd counts replicate, a mesh without
    a model axis replicates — never an error."""
    from jax.sharding import Mesh

    from repro.dist.sharding import window_sharding

    mesh = _mesh24()
    P = jax.sharding.PartitionSpec
    assert window_sharding(mesh, 8, 3, axis=0).spec == P("model", None, None)
    assert window_sharding(mesh, 8, 4, axis=1).spec == P(None, "model", None, None)
    assert window_sharding(mesh, 7, 3, axis=0).spec == P(None, None, None)  # odd
    import numpy as np

    data_only = Mesh(np.array(jax.devices()[:2]).reshape(2), ("data",))
    assert window_sharding(data_only, 8, 3).spec == P(None, None, None)


def test_shard_packed_odd_windows_replicate():
    """A pack whose window count the model axis does not divide (packed
    without shards=tp) must land fully replicated — values and positions
    alike — and still serve correct results (the applier re-pads on the
    fly, tests/test_serve_sharded.py)."""
    import numpy as np

    from repro.kernels.ops import pack_linear_rows
    from repro.serve.packed import _pack_one, shard_packed

    mesh = _mesh24()
    rng = np.random.default_rng(0)
    w = rng.normal(size=(16, 3 * 32)).astype(np.float32)  # 3 windows, tp=4
    entry = _pack_one(pack_linear_rows(w, m=32, a=8))
    packed = {"mlp": {"w_gate": {**entry, "values": entry["values"][None],
                                 "positions": entry["positions"][None]}},
              "attn": None, "head": entry, "scope": "all", "fused_mlp": False}
    out = shard_packed(packed, mesh)
    for leaf in ("values", "positions"):
        assert out["head"][leaf].sharding.spec == jax.sharding.PartitionSpec(None, None, None)
        spec = out["mlp"]["w_gate"][leaf].sharding.spec
        assert all(p is None for p in spec)


def test_serve_shardings_structural_axes():
    """With a batch_axes tree, serve_shardings shards exactly the located
    axis — immune to the 'another leading dim equals the batch size' guess
    ambiguity (e.g. n_layers == batch)."""
    from repro.dist.sharding import serve_shardings

    mesh = _mesh24()
    P = jax.sharding.PartitionSpec
    cache = {
        "k": jax.ShapeDtypeStruct((2, 2, 16, 4, 8), jax.numpy.float32),
        "pos": jax.ShapeDtypeStruct((), jax.numpy.int32),
    }
    # guess path would shard axis 0 (n_layers == batch == 2); structural
    # axes pin axis 1
    sh = serve_shardings(cache, mesh, 2, batch_axes={"k": 1, "pos": -1})
    assert sh["k"].spec == P(None, "data", None, None, None)
    assert sh["pos"].spec == P()
    # odd batch falls back to replication, never errors
    sh = serve_shardings(cache, mesh, 3, batch_axes={"k": 1, "pos": -1})
    assert all(p is None for p in sh["k"].spec)
