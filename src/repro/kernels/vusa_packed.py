"""Pallas TPU kernel: VUSA row-wise packed matmul (the paper's format, exact).

Per output *window* of ``M`` lanes (M <= 128, one MXU tile of columns), each
reduction row ``k`` stores at most ``A`` non-zero weights as ``A`` value
slots + ``A`` int8 *position* slots — precisely the paper's VUSA row: the
positions are the SPE indices the physical MACs are shifted onto (Fig. 5).
Rows with more than ``A`` non-zeros spill into additional *jobs* of the same
window — the dense-fallback guarantee of Section III-C ("down to N x A, at
which the conditions are guaranteed").

On TPU the fixed 128x128 MXU plays the role of the physical MAC array, so
virtual growth cannot reduce issued MACs; what it does reduce — exactly as
in the paper — is what must be *moved* for a given logical matmul: HBM
weight bytes shrink from ``K*M*dtype`` to ``K*J*A*(dtype + 1)``.  At 85 %
sparsity with (M=128, A=16, J=2) that is ~2.4x less weight traffic, which is
the whole game for memory-bound decode (Edge-AI inference, the paper's
target).

Dense-tile reconstruction (DESIGN.md §3) has two implementations, selected
by the static ``reconstruct`` argument:

* ``"onehot"`` (default) — a single vectorized contraction over all ``J*A``
  slots at once: ``positions == lanes[..., None]`` builds the one-hot
  scatter tensor and one multiply-reduce produces the dense (K_blk, M)
  tile.  One VPU pass regardless of slot count; this is the fast path.
* ``"loop"`` — the original per-slot ``fori_loop`` select-accumulate
  (``J*A`` sequential VPU passes).  Kept as the measured baseline for
  ``benchmarks/run.py kernel_vusa_packed``.

Values may be fp32 or bf16; accumulation is always fp32 (both the one-hot
contraction and the MXU matmul run with ``preferred_element_type=float32``)
and the kernel output is fp32.

Quantized value slots (DESIGN.md §10): with ``value_dtype="int8"`` or
``"int4"`` the value operand is raw int8 bytes (two nibble slots per byte
for int4) plus a per-(window, row) fp32 ``scales`` operand, and dequant is
fused into the VMEM reconstruction — HBM only ever moves quantized bytes.
Positions stay full-resolution int8 either way.

Grid: (output windows, K blocks); K innermost for output-block accumulation.
VMEM per step: x (B, K_blk), vals (K_blk, J*A), pos (K_blk, J*A),
one-hot scratch (K_blk, J*A, M) for "onehot", reconstructed W (K_blk, M)
fp32, acc (B, M) fp32.  ``k_blk`` is the knob that bounds the scratch —
see ``repro.kernels.ops.choose_k_blk``.

``vusa_fused_mlp_matmul`` is the whole-MLP megakernel (DESIGN.md §7): one
``pallas_call`` whose grid walks the ff windows.  Each step reconstructs
that window's ``w_gate`` and ``w_up`` tiles, forms ``silu(gate) * up`` in
VMEM, reconstructs the matching ``w_down`` *rows* (``w_down`` is packed
transposed, so its reduction dim is the windowed one) and accumulates
straight into the ``(B, d_model)`` output — the ``(B, ff)`` intermediate
never touches HBM and the per-layer dispatch count drops from three to one.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = [
    "vusa_packed_matmul",
    "vusa_fused_mlp_matmul",
    "RECONSTRUCT_MODES",
    "DEFAULT_SLOT_CHUNK",
]

RECONSTRUCT_MODES = ("onehot", "loop")
DEFAULT_SLOT_CHUNK = 24  # slots per one-hot pass; bounds the scatter scratch


def _reconstruct_onehot(vals, pos, m: int, slot_chunk: int):
    """Vectorized scatter: slots in wide select-reduce chunks.

    vals: (K_blk, S) fp32, pos: (K_blk, S) int32 (-1 = idle slot).
    Returns the dense (K_blk, M) tile in fp32.  Idle slots compare unequal
    to every lane, so they contribute exact zeros.  ``slot_chunk`` bounds
    the (K_blk, chunk, M) scatter tensor; the chunk loop is a static
    unroll, so a chunk covering all S slots is a single VPU pass.
    """
    k_blk, s = vals.shape
    chunk = min(slot_chunk, s)
    w = jnp.zeros((k_blk, m), jnp.float32)
    for s0 in range(0, s, chunk):
        width = min(chunk, s - s0)
        v = jax.lax.dynamic_slice_in_dim(vals, s0, width, axis=1)
        q = jax.lax.dynamic_slice_in_dim(pos, s0, width, axis=1)
        lanes = jax.lax.broadcasted_iota(jnp.int32, (k_blk, width, m), 2)
        w += jnp.sum(jnp.where(q[..., None] == lanes, v[..., None], 0.0), axis=1)
    return w


def _reconstruct_loop(vals, pos, m: int):
    """Seed baseline: one VPU select-accumulate pass per slot."""
    k_blk, slots = vals.shape
    lanes = jax.lax.broadcasted_iota(jnp.int32, (k_blk, m), 1)

    def slot(a, w):
        v = jax.lax.dynamic_slice_in_dim(vals, a, 1, axis=1)  # (K_blk, 1)
        p = jax.lax.dynamic_slice_in_dim(pos, a, 1, axis=1)
        return w + jnp.where(lanes == p, v, 0.0)

    return jax.lax.fori_loop(0, slots, slot, jnp.zeros((k_blk, m), jnp.float32))


def _reconstruct(vals, pos, m: int, reconstruct: str, slot_chunk: int):
    if reconstruct == "onehot":
        return _reconstruct_onehot(vals, pos, m, slot_chunk)
    return _reconstruct_loop(vals, pos, m)


def _dequant(raw, scales, value_dtype: str):
    """Fused VMEM dequant: raw int8 slots (R, Sb) + per-row scales (R,)
    -> fp32 values (R, S).

    ``int4`` decodes two slots per byte with arithmetic shifts — the low
    nibble via ``(b << 4) >> 4`` (sign-extend), the high via ``b >> 4`` —
    interleaved back to slot order before scaling.  HBM only ever moved the
    quantized bytes; the fp32 expansion exists only in VMEM."""
    if value_dtype == "int4":
        lo = jnp.right_shift(jnp.left_shift(raw, 4), 4)
        hi = jnp.right_shift(raw, 4)
        raw = jnp.stack([lo, hi], axis=-1).reshape(raw.shape[0], -1)
    return raw.astype(jnp.float32) * scales.astype(jnp.float32)[:, None]


def _kernel(x_ref, val_ref, pos_ref, y_ref, *, m: int, reconstruct: str, slot_chunk: int):
    @pl.when(pl.program_id(1) == 0)
    def _init():
        y_ref[...] = jnp.zeros_like(y_ref)

    vals = val_ref[0].astype(jnp.float32)  # (K_blk, S)
    pos = pos_ref[0].astype(jnp.int32)
    w = _reconstruct(vals, pos, m, reconstruct, slot_chunk)
    y_ref[...] += jnp.dot(
        x_ref[...].astype(jnp.float32), w, preferred_element_type=jnp.float32
    ).astype(y_ref.dtype)


def _qkernel(
    x_ref, val_ref, pos_ref, scale_ref, y_ref,
    *, m: int, reconstruct: str, slot_chunk: int, value_dtype: str,
):
    """Quantized-values variant of :func:`_kernel`: the value block arrives
    as raw int8 (nibble-packed for int4), dequant happens in VMEM right
    before the one-hot reconstruction.  fp32 accumulation unchanged."""

    @pl.when(pl.program_id(1) == 0)
    def _init():
        y_ref[...] = jnp.zeros_like(y_ref)

    vals = _dequant(val_ref[0], scale_ref[0], value_dtype)  # (K_blk, S) fp32
    pos = pos_ref[0].astype(jnp.int32)
    w = _reconstruct(vals, pos, m, reconstruct, slot_chunk)
    y_ref[...] += jnp.dot(
        x_ref[...].astype(jnp.float32), w, preferred_element_type=jnp.float32
    ).astype(y_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("interpret", "k_blk", "m", "reconstruct", "slot_chunk", "value_dtype"),
)
def vusa_packed_matmul(
    x: jax.Array,  # (B, K)
    values: jax.Array,  # (T, K, J*A)  per window: A slots x J jobs per row
    positions: jax.Array,  # (T, K, J*A) int8 lane index per slot (-1 = idle)
    scales: jax.Array | None = None,  # (T, K) fp32, quantized packs only
    *,
    m: int = 128,
    k_blk: int = 256,
    interpret: bool = True,
    reconstruct: str = "onehot",
    slot_chunk: int = DEFAULT_SLOT_CHUNK,
    value_dtype: str = "dense",
) -> jax.Array:
    b, k = x.shape
    t, kk, vslots = values.shape
    slots = positions.shape[2]
    assert kk == k, (kk, k)
    assert m <= 128, m  # int8 positions index lanes within one MXU tile
    assert reconstruct in RECONSTRUCT_MODES, reconstruct
    k_blk = min(k_blk, k)
    assert k % k_blk == 0, (k, k_blk)
    grid = (t, k // k_blk)
    if value_dtype == "dense":
        assert scales is None and vslots == slots, (value_dtype, vslots, slots)
        return pl.pallas_call(
            functools.partial(_kernel, m=m, reconstruct=reconstruct, slot_chunk=slot_chunk),
            grid=grid,
            in_specs=[
                pl.BlockSpec((b, k_blk), lambda i, l: (0, l)),
                pl.BlockSpec((1, k_blk, slots), lambda i, l: (i, l, 0)),
                pl.BlockSpec((1, k_blk, slots), lambda i, l: (i, l, 0)),
            ],
            out_specs=pl.BlockSpec((b, m), lambda i, l: (0, i)),
            out_shape=jax.ShapeDtypeStruct((b, t * m), jnp.float32),
            interpret=interpret,
        )(x, values, positions)
    assert scales is not None and scales.shape == (t, k), (value_dtype, None if scales is None else scales.shape)
    # int4 packs two slots per byte; either way the decode must cover exactly
    # the position slots
    assert vslots * (2 if value_dtype == "int4" else 1) == slots, (value_dtype, vslots, slots)
    return pl.pallas_call(
        functools.partial(
            _qkernel, m=m, reconstruct=reconstruct, slot_chunk=slot_chunk, value_dtype=value_dtype
        ),
        grid=grid,
        in_specs=[
            pl.BlockSpec((b, k_blk), lambda i, l: (0, l)),
            pl.BlockSpec((1, k_blk, vslots), lambda i, l: (i, l, 0)),
            pl.BlockSpec((1, k_blk, slots), lambda i, l: (i, l, 0)),
            pl.BlockSpec((1, k_blk), lambda i, l: (i, l)),
        ],
        out_specs=pl.BlockSpec((b, m), lambda i, l: (0, i)),
        out_shape=jax.ShapeDtypeStruct((b, t * m), jnp.float32),
        interpret=interpret,
    )(x, values, positions, scales)


# --------------------------------------------------------------------------
# Fused packed-MLP megakernel (DESIGN.md §7)
# --------------------------------------------------------------------------


def _matmul_packed_window(
    x, val_ref, pos_ref, m, k_blk, reconstruct, slot_chunk,
    scale_ref=None, value_dtype="dense",
):
    """``x @ W_window`` for one window's packed block ref, chunked over K rows.

    ``x``: (B, K) fp32; ``val_ref``/``pos_ref``: (1, K, S) block refs.
    Reconstructs the dense tile ``k_blk`` rows at a time (bounding the
    one-hot scratch at ``k_blk * slot_chunk * m`` fp32) and accumulates the
    partial products in fp32.  With ``scale_ref`` (a (1, K) fp32 block ref)
    the value chunk is raw quantized bytes and dequant is fused into the
    chunk load.  Returns (B, m) fp32.
    """
    k = x.shape[1]
    acc = jnp.zeros((x.shape[0], m), jnp.float32)
    for k0 in range(0, k, k_blk):
        width = min(k_blk, k - k0)
        raw = val_ref[0, k0 : k0 + width]
        if scale_ref is None:
            vals = raw.astype(jnp.float32)
        else:
            vals = _dequant(raw, scale_ref[0, k0 : k0 + width], value_dtype)
        pos = pos_ref[0, k0 : k0 + width].astype(jnp.int32)
        w = _reconstruct(vals, pos, m, reconstruct, slot_chunk)
        acc += jnp.dot(x[:, k0 : k0 + width], w, preferred_element_type=jnp.float32)
    return acc


def _fused_mlp_kernel(
    x_ref,
    gv_ref,
    gp_ref,
    uv_ref,
    up_ref,
    dv_ref,
    dp_ref,
    y_ref,
    *,
    m: int,
    k_blk: int,
    reconstruct: str,
    slot_chunk: int,
):
    """One ff window of the fused MLP: gate/up reconstruct + matmul,
    ``silu(gate) * up`` in VMEM, then the window's ``w_down`` rows
    (transposed pack: ``dv``/``dp`` are (1, D, Sd) over the same window)
    accumulate into the full (B, D) output block."""

    @pl.when(pl.program_id(0) == 0)
    def _init():
        y_ref[...] = jnp.zeros_like(y_ref)

    x = x_ref[...].astype(jnp.float32)  # (B, K)
    gate = _matmul_packed_window(x, gv_ref, gp_ref, m, k_blk, reconstruct, slot_chunk)
    up = _matmul_packed_window(x, uv_ref, up_ref, m, k_blk, reconstruct, slot_chunk)
    h = jax.nn.silu(gate) * up  # (B, m) — the (B, ff) intermediate, one window of it
    d_out = y_ref.shape[1]
    for c0 in range(0, d_out, k_blk):
        width = min(k_blk, d_out - c0)
        vals = dv_ref[0, c0 : c0 + width].astype(jnp.float32)
        pos = dp_ref[0, c0 : c0 + width].astype(jnp.int32)
        # (width, m) rows of w_down.T — lanes are this window's ff rows
        wd = _reconstruct(vals, pos, m, reconstruct, slot_chunk)
        y_ref[:, c0 : c0 + width] += jnp.dot(h, wd.T, preferred_element_type=jnp.float32)


def _fused_mlp_qkernel(
    x_ref,
    gv_ref,
    gp_ref,
    gs_ref,
    uv_ref,
    up_ref,
    us_ref,
    dv_ref,
    dp_ref,
    ds_ref,
    y_ref,
    *,
    m: int,
    k_blk: int,
    reconstruct: str,
    slot_chunk: int,
    value_dtype: str,
):
    """Quantized-values variant of :func:`_fused_mlp_kernel`: each of the
    three packs carries raw int8 (nibble-packed for int4) value slots plus a
    per-(window, row) fp32 scale block; dequant is fused into every chunked
    reconstruction so only quantized bytes ever stream from HBM."""

    @pl.when(pl.program_id(0) == 0)
    def _init():
        y_ref[...] = jnp.zeros_like(y_ref)

    x = x_ref[...].astype(jnp.float32)  # (B, K)
    gate = _matmul_packed_window(
        x, gv_ref, gp_ref, m, k_blk, reconstruct, slot_chunk, gs_ref, value_dtype
    )
    up = _matmul_packed_window(
        x, uv_ref, up_ref, m, k_blk, reconstruct, slot_chunk, us_ref, value_dtype
    )
    h = jax.nn.silu(gate) * up  # (B, m)
    d_out = y_ref.shape[1]
    for c0 in range(0, d_out, k_blk):
        width = min(k_blk, d_out - c0)
        vals = _dequant(dv_ref[0, c0 : c0 + width], ds_ref[0, c0 : c0 + width], value_dtype)
        pos = dp_ref[0, c0 : c0 + width].astype(jnp.int32)
        wd = _reconstruct(vals, pos, m, reconstruct, slot_chunk)
        y_ref[:, c0 : c0 + width] += jnp.dot(h, wd.T, preferred_element_type=jnp.float32)


@functools.partial(
    jax.jit,
    static_argnames=("interpret", "k_blk", "m", "reconstruct", "slot_chunk", "value_dtype"),
)
def vusa_fused_mlp_matmul(
    x: jax.Array,  # (B, K)
    gate_values: jax.Array,  # (T, K, Sg)   w_gate row-pack
    gate_positions: jax.Array,  # (T, K, Sg) int8
    up_values: jax.Array,  # (T, K, Su)     w_up row-pack
    up_positions: jax.Array,  # (T, K, Su) int8
    down_values: jax.Array,  # (T, D, Sd)   w_down.T row-pack (ff windowed)
    down_positions: jax.Array,  # (T, D, Sd) int8
    gate_scales: jax.Array | None = None,  # (T, K) fp32, quantized packs only
    up_scales: jax.Array | None = None,  # (T, K) fp32
    down_scales: jax.Array | None = None,  # (T, D) fp32
    *,
    m: int = 128,
    k_blk: int = 256,
    interpret: bool = True,
    reconstruct: str = "onehot",
    slot_chunk: int = DEFAULT_SLOT_CHUNK,
    value_dtype: str = "dense",
) -> jax.Array:
    """Whole SwiGLU MLP in one ``pallas_call``: ``silu(x@Wg) * (x@Wu) @ Wd``.

    All three weights are row-packed over the *same* ff windows: ``w_gate``
    and ``w_up`` as (K=d_model, C=ff) with ff the lane dim, ``w_down``
    *transposed* as (K=d_model out, C=ff) so its reduction dim is windowed
    too.  The grid walks the T ff windows; each step finishes one window's
    ``(B, m)`` slice of the hidden state and scatters its contribution into
    the full ``(B, D)`` output, which accumulates across the grid in fp32.
    Zero-padded ff lanes (C % m != 0) are exact no-ops: gate/up reconstruct
    to zero columns there (``silu(0) * 0 = 0``) and the transposed down pack
    holds no slots pointing at them.  Returns (B, D) fp32.
    """
    b, k = x.shape
    t, kk, _ = gate_values.shape
    tu, ku, _ = up_values.shape
    td, d_out, _ = down_values.shape
    assert kk == k and ku == k, (kk, ku, k)
    assert tu == t and td == t, (t, tu, td)
    assert m <= 128, m
    assert reconstruct in RECONSTRUCT_MODES, reconstruct
    k_blk = max(1, min(k_blk, max(k, d_out)))
    if value_dtype == "dense":
        assert gate_scales is None and up_scales is None and down_scales is None
        sg, su, sd = gate_values.shape[2], up_values.shape[2], down_values.shape[2]
        return pl.pallas_call(
            functools.partial(
                _fused_mlp_kernel, m=m, k_blk=k_blk, reconstruct=reconstruct, slot_chunk=slot_chunk
            ),
            grid=(t,),
            in_specs=[
                pl.BlockSpec((b, k), lambda i: (0, 0)),
                pl.BlockSpec((1, k, sg), lambda i: (i, 0, 0)),
                pl.BlockSpec((1, k, sg), lambda i: (i, 0, 0)),
                pl.BlockSpec((1, k, su), lambda i: (i, 0, 0)),
                pl.BlockSpec((1, k, su), lambda i: (i, 0, 0)),
                pl.BlockSpec((1, d_out, sd), lambda i: (i, 0, 0)),
                pl.BlockSpec((1, d_out, sd), lambda i: (i, 0, 0)),
            ],
            out_specs=pl.BlockSpec((b, d_out), lambda i: (0, 0)),
            out_shape=jax.ShapeDtypeStruct((b, d_out), jnp.float32),
            interpret=interpret,
        )(x, gate_values, gate_positions, up_values, up_positions, down_values, down_positions)
    assert gate_scales is not None and up_scales is not None and down_scales is not None
    assert gate_scales.shape == (t, k) and up_scales.shape == (t, k), (gate_scales.shape, up_scales.shape)
    assert down_scales.shape == (t, d_out), (down_scales.shape, t, d_out)
    nib = 2 if value_dtype == "int4" else 1
    # value slot dims may be nibble-packed; position slot dims are the truth
    vg, vu, vd = gate_values.shape[2], up_values.shape[2], down_values.shape[2]
    sg, su, sd = gate_positions.shape[2], up_positions.shape[2], down_positions.shape[2]
    assert (vg * nib, vu * nib, vd * nib) == (sg, su, sd), (value_dtype, (vg, vu, vd), (sg, su, sd))
    return pl.pallas_call(
        functools.partial(
            _fused_mlp_qkernel,
            m=m, k_blk=k_blk, reconstruct=reconstruct, slot_chunk=slot_chunk,
            value_dtype=value_dtype,
        ),
        grid=(t,),
        in_specs=[
            pl.BlockSpec((b, k), lambda i: (0, 0)),
            pl.BlockSpec((1, k, vg), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, k, sg), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, k), lambda i: (i, 0)),
            pl.BlockSpec((1, k, vu), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, k, su), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, k), lambda i: (i, 0)),
            pl.BlockSpec((1, d_out, vd), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, d_out, sd), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, d_out), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((b, d_out), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, d_out), jnp.float32),
        interpret=interpret,
    )(
        x,
        gate_values, gate_positions, gate_scales,
        up_values, up_positions, up_scales,
        down_values, down_positions, down_scales,
    )
