"""Pallas TPU kernel: block-VUSA packed sparse matmul.

TPU adaptation of the paper's virtually-upscaled systolic array (DESIGN.md
§2): per output tile of ``tile_n`` lanes, the reduction dimension is covered
by ``n_jobs`` jobs of ``a_blk`` packed rows + an int32 row-index map (the
"shifter setting").  Each job issues one dense ``(B, a_blk) @ (a_blk,
tile_n)`` MXU matmul after gathering the matching activation rows, so HBM
weight traffic and issued MACs scale with the *non-zero* rows only — the
M/A virtual growth realised as bytes and MACs saved.

Grid: one step per output tile.  VMEM working set per step:
    x          (B, K)            — activations resident (decode-sized B)
    values     (n_jobs, a_blk, tile_n)
    row_idx    (n_jobs, a_blk)
    y          (B, tile_n) accumulator (fp32)
``a_blk`` is a multiple of 8 (sublanes) and ``tile_n`` a multiple of 128
(lanes) so every matmul is MXU-aligned.

The in-kernel gather runs along the lane dimension of ``x``; on TPU this
lowers to a dynamic-gather, on CPU we validate with ``interpret=True``.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["vusa_spmm"]


def _kernel(x_ref, val_ref, idx_ref, y_ref):
    b = x_ref.shape[0]
    _, n_jobs, a_blk, tile_n = val_ref.shape  # leading 1: one tile per step
    x = x_ref[...]

    def job(j, acc):
        idx = idx_ref[0, j, :]  # (a_blk,) absolute K indices
        xg = jnp.take(x, idx, axis=1)  # (B, a_blk) — the shifter/gather
        vals = val_ref[0, j, :, :]  # (a_blk, tile_n)
        return acc + jnp.dot(
            xg.astype(jnp.float32), vals.astype(jnp.float32),
            preferred_element_type=jnp.float32,
        )

    acc = jax.lax.fori_loop(0, n_jobs, job, jnp.zeros((b, tile_n), jnp.float32))
    y_ref[...] = acc.astype(y_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret",))
def vusa_spmm(
    x: jax.Array,  # (B, K)
    values: jax.Array,  # (T, J, A, Tn)
    row_idx: jax.Array,  # (T, J, A) int32
    *,
    interpret: bool = True,  # CPU container: interpret; set False on TPU
) -> jax.Array:
    b, k = x.shape
    t, j, a, tn = values.shape
    grid = (t,)
    return pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((b, k), lambda i: (0, 0)),  # x resident across tiles
            pl.BlockSpec((1, j, a, tn), lambda i: (i, 0, 0, 0)),
            pl.BlockSpec((1, j, a), lambda i: (i, 0, 0)),
        ],
        out_specs=pl.BlockSpec((b, tn), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((b, t * tn), x.dtype),
        interpret=interpret,
    )(x, values, row_idx)
