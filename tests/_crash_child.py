"""Paced streaming server child for the SIGKILL crash-recovery test
(tests/test_streaming.py::test_sigkill_crash_recovery).  Not a test.

Serves a deterministic synthetic workload through the AsyncEngine with a
write-ahead journal, decode-paced by the seeded stall injector
(``FaultConfig.decode_stall_s``) so the parent has a wide window to SIGKILL
it mid-stream: after jit warmup the smoke-config decode finishes in
milliseconds, far too fast to hit reliably with a signal.  The stall only
sleeps the host loop — the emitted tokens are bit-identical to an unpaced
run, which is exactly what the parent's recovery differential asserts.

Usage: python tests/_crash_child.py JOURNAL_PATH SEED N_REQUESTS [PACE_S]
"""

import asyncio
import sys

import numpy as np

import jax

from repro.configs import get_smoke_config
from repro.core.pruning import prune_tree
from repro.models import build_model
from repro.serve import (
    AsyncEngine,
    Engine,
    FaultConfig,
    Journal,
    Request,
    Scheduler,
    ServeConfig,
)

# the prompt-length cycle shared with tests/test_streaming.py: requests are
# a pure function of (seed, index), so parent and child build identical ones
PROMPT_LENS = (6, 13, 9, 17, 5, 24)


def mk_reqs(n, seed=7, max_new=8):
    rng = np.random.default_rng(seed)
    return [
        Request(
            prompt=rng.integers(1, 90, size=PROMPT_LENS[i % len(PROMPT_LENS)]).astype(
                np.int32
            ),
            max_new=max_new,
            seed=i,
        )
        for i in range(n)
    ]


def build_engine(faults=None):
    """The canonical engine of the streaming tests: pruned vusa_edge smoke,
    dense decode, temperature sampling (seeds matter)."""
    cfg = get_smoke_config("vusa_edge")
    params = prune_tree(build_model(cfg).init(jax.random.key(0)), 0.85)
    return Engine(cfg, params, ServeConfig(max_len=64, temperature=1.0, faults=faults))


async def _serve(path, seed, n, pace):
    eng = build_engine(
        faults=FaultConfig(
            decode_stall_s=pace, decode_stall_rate=1.0, decode_stall_once=False
        )
    )
    sched = Scheduler(eng, slots=3)
    async with AsyncEngine(sched, journal=Journal(path)) as engine:
        streams = [engine.submit(r) for r in mk_reqs(n, seed=seed)]
        for s in streams:
            await s.completion()


def main():
    path, seed, n = sys.argv[1], int(sys.argv[2]), int(sys.argv[3])
    pace = float(sys.argv[4]) if len(sys.argv) > 4 else 0.25
    asyncio.run(_serve(path, seed, n, pace))
    print("child finished cleanly", flush=True)  # the parent expects to kill us first


if __name__ == "__main__":
    main()
