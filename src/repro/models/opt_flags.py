"""Beyond-paper performance switches (EXPERIMENTS.md §Perf).

Every flag defaults to the optimized value for production use; the dry-run
driver flips them to the paper-faithful baseline to record the A/B.  Each
flag is one hypothesis->change->measure cycle documented in §Perf.
"""

from __future__ import annotations

FLAGS = {
    # flash attention custom VJP: recompute scores in backward instead of
    # letting scan-autodiff stack every per-chunk probability tensor as a
    # residual (the dominant HBM term of every train cell at baseline)
    "flash_custom_vjp": True,
    # decode attention: direct (seq stays sharded) vs flash-chunked scan
    "decode_direct": True,
    # flash attention: carry the probability matrix in bf16 between the QK
    # and AV einsums (fp32 accumulation preserved via preferred_element_type)
    "attn_bf16_probs": True,
    # cross-entropy via logsumexp on bf16 logits (no fp32 log_softmax tensor)
    "xent_lse": True,
    # sequence-parallel attention (shard_map over the model axis on the
    # q-sequence dim) for archs whose head count does not divide TP — keeps
    # score compute/memory sharded with near-zero collectives
    "attn_seq_shard": True,
    # SSD (mamba2): smaller chunk length.  REFUTED (§Perf P7): the measured
    # bytes ROSE 156->284 s at Q=64 — the scan-residual/state path (prop. to
    # S/Q chunks) outweighs the O(S*Q) decay-matrix saving under autodiff.
    # The real fix is an SSD custom VJP (flash-style recompute), future work.
    "ssd_small_chunk": False,
    # MoE: sort-based position-in-expert (O(T log T) int32) instead of the
    # (T*k, E) one-hot cumsum (O(T*E) int32 traffic)
    "moe_sort_positions": True,
    # MoE: shard the dispatch buffers over (experts x data).  REFUTED on the
    # 16x16 mesh (EXPERIMENTS.md §Perf iteration O2/O3): GSPMD lowers the
    # scatter to full-replica all-reduces even behind optimization barriers;
    # net bound got worse than leaving the capacity replicated.  Kept as a
    # flag for meshes where a ragged all-to-all dispatch lands in JAX.
    "moe_shard_capacity": False,
}

_OPT_PROFILE = dict(FLAGS)


def set_baseline():
    for k in FLAGS:
        FLAGS[k] = False


def set_opt():
    FLAGS.update(_OPT_PROFILE)
