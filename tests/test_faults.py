"""Seeded fault-injection (chaos) suite — DESIGN.md §9.

Load-time guards: ``validate_rows``/``validate_packed`` must refuse packs
with corrupt position metadata (the corruption class the runtime guard can
never see).  Runtime guard + graceful degradation: NaN faults injected into
packed values or slot caches must never produce a ``status=OK`` completion
with corrupt tokens — affected requests finish ``FAILED_FALLBACK_OK`` with
tokens bit-identical to a clean dense run (the VUSA property: a dense path
exists for every packed weight), and the bounded retry never loops."""

import dataclasses
import os

import numpy as np
import pytest

import jax

from repro.configs import get_smoke_config
from repro.core.packing import pack_rows, validate_rows
from repro.core.pruning import prune_tree
from repro.models import build_model
from repro.serve import (
    Engine,
    FaultConfig,
    Request,
    Scheduler,
    ServeConfig,
    Status,
)
from repro.serve.faults import corrupt_pack_positions, corrupt_pack_values
from repro.serve.packed import pack_lm_weights, validate_packed


@pytest.fixture(scope="module")
def llama():
    cfg = get_smoke_config("llama3_2_1b")
    params = build_model(cfg).init(jax.random.key(0))
    return cfg, params


@pytest.fixture(scope="module")
def vusa_pruned():
    cfg = get_smoke_config("vusa_edge")
    params = prune_tree(build_model(cfg).init(jax.random.key(0)), 0.85)
    return cfg, params


def _one_shot_dense(cfg, params, req: Request, sc: ServeConfig):
    """Clean dense reference for a request: the tokens a fallback retry must
    reproduce bit-for-bit."""
    dense = dataclasses.replace(sc, packed_weights=False, packed_mlp=False,
                                faults=None, seed=req.seed)
    eng = Engine(cfg, params, dense)
    return eng.generate(np.asarray(req.prompt)[None], max_new=req.max_new)["tokens"][0]


def _reqs(n, rng, max_new=8):
    return [
        Request(prompt=rng.integers(1, 100, 6).astype(np.int32), max_new=max_new, seed=i)
        for i in range(n)
    ]


# ---------------------------------------------------------------------------
# load-time validation
# ---------------------------------------------------------------------------


def test_validate_rows_accepts_clean_pack():
    rng = np.random.default_rng(0)
    w = rng.normal(size=(16, 256)).astype(np.float32)
    w[rng.random(w.shape) < 0.8] = 0.0
    validate_rows(pack_rows(w, m=128, a=4))


def test_validate_rows_rejects_corruption():
    rng = np.random.default_rng(1)
    w = rng.normal(size=(16, 256)).astype(np.float32)
    w[rng.random(w.shape) < 0.8] = 0.0
    p = pack_rows(w, m=128, a=4)
    q = np.array(p.row_positions)
    q[0, 0, 0] = -2  # out of [-1, m)
    with pytest.raises(ValueError, match="outside"):
        validate_rows(dataclasses.replace(p, row_positions=q))
    with pytest.raises(ValueError, match="int8"):
        validate_rows(
            dataclasses.replace(p, row_positions=p.row_positions.astype(np.int16))
        )
    v = np.array(p.values)
    v[0, 0, 0] = np.nan
    with pytest.raises(ValueError, match="non-finite"):
        validate_rows(dataclasses.replace(p, values=v))


def test_validate_packed_rejects_position_flip(vusa_pruned):
    cfg, params = vusa_pruned
    packed = pack_lm_weights(cfg, params, 128, 16, scope="mlp")  # self-validates
    bad = corrupt_pack_positions(packed, FaultConfig(seed=0, pack_position_flips=1))
    with pytest.raises(ValueError, match="outside"):
        validate_packed(bad)
    # injection is seeded: the same plan corrupts the same byte
    again = corrupt_pack_positions(packed, FaultConfig(seed=0, pack_position_flips=1))
    for name in bad["mlp"]:
        np.testing.assert_array_equal(
            np.asarray(bad["mlp"][name]["positions"]),
            np.asarray(again["mlp"][name]["positions"]),
        )


def test_engine_refuses_corrupt_pack(vusa_pruned):
    """A position bit-flip must make Engine init fail loudly — the pack is
    never served."""
    cfg, params = vusa_pruned
    sc = ServeConfig(
        max_len=64, packed_mlp=True,
        faults=FaultConfig(seed=0, pack_position_flips=1),
    )
    with pytest.raises(ValueError, match="outside"):
        Engine(cfg, params, sc)


# ---------------------------------------------------------------------------
# runtime guard + dense fallback (the tentpole acceptance path)
# ---------------------------------------------------------------------------


def test_packed_value_nan_quarantines_and_falls_back_dense(vusa_pruned):
    """NaN corruption in packed values (post-load, so only the runtime guard
    can see it): every affected request must finish FAILED_FALLBACK_OK with
    tokens bit-identical to a clean dense run, the pack must be quarantined,
    and no completion may read OK with corrupt tokens."""
    cfg, params = vusa_pruned
    sc = ServeConfig(
        max_len=64, packed_mlp=True, faults=FaultConfig(seed=0, pack_value_nans=2)
    )
    eng = Engine(cfg, params, sc)
    assert eng.packed_active
    sched = Scheduler(eng, slots=3, segment=4)
    rng = np.random.default_rng(2)
    reqs = _reqs(3, rng)
    done = sched.run(reqs)
    assert eng.quarantined and not eng.packed_active
    assert set(done) == {0, 1, 2}
    for rid, c in done.items():
        assert c.status is Status.FAILED_FALLBACK_OK, (rid, c.status)
        np.testing.assert_array_equal(
            c.tokens, _one_shot_dense(cfg, params, reqs[rid], sc), err_msg=f"rid {rid}"
        )
    st = sched.stats()
    assert st["fallback"] == 3 and st["quarantined"] == 1 and st["failed"] == 0


def test_cache_poison_falls_back_without_quarantine(llama):
    """A transient slot-cache NaN on a dense engine: the afflicted request
    retries once (clean) and finishes FAILED_FALLBACK_OK bit-identical to
    its clean run; neighbours are untouched; nothing is quarantined."""
    cfg, params = llama
    sc = ServeConfig(max_len=64, faults=FaultConfig(cache_nan_rids=(1,)))
    eng = Engine(cfg, params, sc)
    sched = Scheduler(eng, slots=2, segment=4)
    rng = np.random.default_rng(3)
    reqs = _reqs(3, rng)
    done = sched.run(reqs)
    assert not eng.quarantined
    assert done[1].status is Status.FAILED_FALLBACK_OK
    for rid in (0, 2):
        assert done[rid].status is Status.OK
    for rid, c in done.items():
        np.testing.assert_array_equal(
            c.tokens, _one_shot_dense(cfg, params, reqs[rid], sc), err_msg=f"rid {rid}"
        )
    st = sched.stats()
    assert st["fallback"] == 1 and st["quarantined"] == 0 and st["failed"] == 0


def test_persistent_cache_fault_bounded_retry(llama):
    """``cache_nan_once=False`` re-poisons the retry: the request must fail
    terminally (FAILED) after exactly one retry — bounded, never a loop —
    and neighbours still finish bit-identical."""
    cfg, params = llama
    sc = ServeConfig(
        max_len=64, faults=FaultConfig(cache_nan_rids=(1,), cache_nan_once=False)
    )
    sched = Scheduler(Engine(cfg, params, sc), slots=2, segment=4)
    rng = np.random.default_rng(4)
    reqs = _reqs(3, rng)
    done = sched.run(reqs)
    assert done[1].status is Status.FAILED
    for rid in (0, 2):
        assert done[rid].status is Status.OK
        np.testing.assert_array_equal(
            done[rid].tokens, _one_shot_dense(cfg, params, reqs[rid], sc)
        )
    st = sched.stats()
    assert st["fallback"] == 1 and st["failed"] == 1


def test_admission_stall_injection(llama):
    """Seeded admission stalls land in the admit-time accounting (and the
    run still completes correctly)."""
    cfg, params = llama
    sc = ServeConfig(max_len=64, faults=FaultConfig(stall_s=0.05, stall_rids=(0,)))
    sched = Scheduler(Engine(cfg, params, sc), slots=2, segment=4)
    rng = np.random.default_rng(5)
    reqs = _reqs(2, rng)
    done = sched.run(reqs)
    assert all(c.status is Status.OK for c in done.values())
    assert sched.stats()["admit_s"] >= 0.05


# the nightly workflow widens the sweep (REPRO_CHAOS_SEEDS=0,1,...,7); the
# default 3 seeds keep the slow CI leg bounded
_CHAOS_SEEDS = [int(s) for s in os.environ.get("REPRO_CHAOS_SEEDS", "0,1,2").split(",")]


@pytest.mark.slow
@pytest.mark.parametrize("seed", _CHAOS_SEEDS)
def test_chaos_sweep_no_corrupt_ok(llama, seed):
    """Full sweep: at a 30% seeded cache-fault rate, every completion is
    either OK or FAILED_FALLBACK_OK and every delivered token sequence is
    bit-identical to the clean run — no injected fault ever yields corrupt
    tokens under an OK-ish status."""
    cfg, params = llama
    sc = ServeConfig(
        max_len=64, faults=FaultConfig(seed=seed, cache_nan_rate=0.3)
    )
    sched = Scheduler(Engine(cfg, params, sc), slots=4, segment=4)
    rng = np.random.default_rng(seed)
    reqs = _reqs(8, rng)
    done = sched.run(reqs)
    assert set(done) == set(range(8))
    n_fallback = 0
    for rid, c in done.items():
        assert c.status in (Status.OK, Status.FAILED_FALLBACK_OK), (rid, c.status)
        n_fallback += c.status is Status.FAILED_FALLBACK_OK
        np.testing.assert_array_equal(
            c.tokens, _one_shot_dense(cfg, params, reqs[rid], sc), err_msg=f"rid {rid}"
        )
    assert sched.stats()["fallback"] == n_fallback
