"""Training launcher.

    PYTHONPATH=src python -m repro.launch.train --arch vusa_edge --steps 100 \
        [--smoke] [--batch 8] [--seq 128] [--ckpt DIR] [--data N --model M]

On a real fleet this binary runs once per host (jax.distributed initializes
from the cluster env); here it sizes the mesh to the local devices.
"""

import argparse

import jax

from ..configs import get_config, get_smoke_config
from ..train import TrainConfig, Trainer, TrainHParams


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt", default=None)
    ap.add_argument("--data", type=int, default=1, help="data-parallel mesh axis")
    ap.add_argument("--model", type=int, default=1, help="model-parallel mesh axis")
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--grad-compress", action="store_true")
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    mesh = jax.make_mesh((args.data, args.model), ("data", "model"))
    tc = TrainConfig(
        steps=args.steps,
        global_batch=args.batch,
        seq_len=args.seq,
        ckpt_dir=args.ckpt,
        token_range=256,
        hp=TrainHParams(
            lr=args.lr,
            warmup=max(args.steps // 10, 1),
            total_steps=args.steps,
            microbatches=args.microbatches,
            grad_compress=args.grad_compress,
        ),
    )
    out = Trainer(cfg, tc, mesh=mesh).train()
    print(f"final loss {out['final_loss']:.4f}  sparsity {out['sparsity']:.2%}")


if __name__ == "__main__":
    main()
