"""Magnitude pruning — the sparsity source for VUSA (paper Section II-B).

Works on plain arrays and on whole parameter pytrees.  The iterative schedule
(`polynomial_sparsity`) follows Zhu & Gupta's cubic ramp, the standard used to
reach the paper's 85-95 % regimes without accuracy loss.
"""

from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

__all__ = [
    "magnitude_mask",
    "prune",
    "polynomial_sparsity",
    "prune_tree",
    "tree_sparsity",
]


def magnitude_mask(w: jax.Array, sparsity: float) -> jax.Array:
    """Boolean keep-mask zeroing the ``sparsity`` fraction of smallest |w|."""
    if sparsity <= 0.0:
        return jnp.ones_like(w, dtype=bool)
    if sparsity >= 1.0:
        return jnp.zeros_like(w, dtype=bool)
    k = int(round((1.0 - sparsity) * w.size))
    k = max(k, 1)
    flat = jnp.abs(w).reshape(-1)
    # threshold = k-th largest magnitude; keep >= threshold (ties keep extra)
    thresh = jax.lax.top_k(flat, k)[0][-1]
    return jnp.abs(w) >= thresh


def prune(w: jax.Array, sparsity: float) -> jax.Array:
    return jnp.where(magnitude_mask(w, sparsity), w, jnp.zeros_like(w))


def polynomial_sparsity(
    step: int, begin: int, end: int, final_sparsity: float, initial_sparsity: float = 0.0
) -> float:
    """Zhu-Gupta cubic sparsity ramp s(t) (host-side schedule)."""
    if step <= begin:
        return initial_sparsity
    if step >= end:
        return final_sparsity
    frac = (step - begin) / max(end - begin, 1)
    return final_sparsity + (initial_sparsity - final_sparsity) * (1.0 - frac) ** 3


def _prunable(path: tuple, leaf) -> bool:
    """Prune 2-D+ weight matrices; never biases/norm scales/embeddings."""
    if getattr(leaf, "ndim", 0) < 2:
        return False
    name = "/".join(str(p) for p in path).lower()
    return not any(s in name for s in ("embed", "norm", "scale", "bias", "router"))


def prune_tree(params, sparsity: float, prunable: Callable = _prunable):
    """Magnitude-prune every prunable leaf of a parameter pytree."""
    def f(path, leaf):
        return prune(leaf, sparsity) if prunable(path, leaf) else leaf

    return jax.tree_util.tree_map_with_path(f, params)


def masks_tree(params, sparsity: float, prunable: Callable = _prunable):
    """Keep-masks for every prunable leaf (non-prunable leaves -> None)."""
    def f(path, leaf):
        return magnitude_mask(leaf, sparsity) if prunable(path, leaf) else None

    return jax.tree_util.tree_map_with_path(f, params)


def apply_masks(params, masks):
    """Re-apply persistent keep-masks (after each optimizer update, so
    pruned weights stay exactly zero through training)."""
    return jax.tree_util.tree_map(
        lambda p, m: p if m is None else jnp.where(m, p, jnp.zeros_like(p)),
        params,
        masks,
        is_leaf=lambda x: x is None,
    )


def tree_sparsity(params) -> float:
    """Global fraction of exactly-zero entries across prunable leaves."""
    zeros, total = 0, 0
    for path, leaf in jax.tree_util.tree_flatten_with_path(params)[0]:
        if _prunable(path, leaf):
            zeros += int(np.sum(np.asarray(leaf) == 0))
            total += leaf.size
    return zeros / max(total, 1)
