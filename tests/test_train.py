"""Trainer integration: loss goes down, pruning reaches target, checkpoint
resume is bit-exact, preemption-style restart continues the data stream."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.train import TrainConfig, Trainer, TrainHParams


def _tc(**kw):
    base = dict(
        steps=10,
        global_batch=4,
        seq_len=32,
        prune_begin=4,
        prune_end=8,
        prune_every=2,
        hp=TrainHParams(lr=1e-3, warmup=2, total_steps=10),
        log_every=100,
    )
    base.update(kw)
    return TrainConfig(**base)


def test_loss_decreases_without_pruning():
    cfg = get_smoke_config("vusa_edge")
    cfg = type(cfg)(**{**cfg.__dict__, "sparsity": 0.0})
    # narrow token distribution => learnable (unigram floor ln(16) ~ 2.77)
    tr = Trainer(
        cfg,
        _tc(steps=30, token_range=16, hp=TrainHParams(lr=3e-3, warmup=2, total_steps=30)),
    )
    out = tr.train()
    first = tr.metrics_log[0]["loss"]
    assert out["final_loss"] < first - 0.5, (first, out["final_loss"])


def test_pruning_reaches_target_sparsity():
    cfg = get_smoke_config("vusa_edge")  # sparsity 0.85
    out = Trainer(cfg, _tc()).train()
    assert out["sparsity"] == pytest.approx(0.85, abs=0.02)


def test_microbatched_grads_match_full_batch():
    cfg = get_smoke_config("llama3_2_1b")
    from repro.models import build_model
    from repro.train.step import make_train_step
    from repro.optim import adamw_init

    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    ids = np.random.default_rng(0).integers(0, cfg.vocab, (4, 32))
    batch = {"tokens": jnp.asarray(ids, jnp.int32)}
    hp1 = TrainHParams(lr=1e-3, microbatches=1)
    hp2 = TrainHParams(lr=1e-3, microbatches=2)
    p1, _, m1 = jax.jit(make_train_step(model.loss, hp1))(params, adamw_init(params), batch)
    p2, _, m2 = jax.jit(make_train_step(model.loss, hp2))(params, adamw_init(params), batch)
    # microbatch split changes the *mean-of-means* only when micro losses
    # differ; with equal-size microbatches gradients should match closely
    d = max(
        float(jnp.max(jnp.abs(a - b)))
        for a, b in zip(jax.tree_util.tree_leaves(p1), jax.tree_util.tree_leaves(p2))
    )
    assert d < 5e-3, d


def test_checkpoint_resume_exact(tmp_path):
    """Run 8 steps straight vs 4 + restart + 4: identical final params."""
    cfg = get_smoke_config("qwen2_0_5b")
    tc_full = _tc(steps=8, ckpt_dir=None, prune_begin=100)
    t_full = Trainer(cfg, tc_full)
    out_full = t_full.train()

    ck = str(tmp_path / "ck")
    tc_half = _tc(steps=4, ckpt_dir=ck, ckpt_every=4, prune_begin=100)
    Trainer(cfg, tc_half).train()
    tc_resume = _tc(steps=8, ckpt_dir=ck, ckpt_every=100, prune_begin=100)
    out_resumed = Trainer(cfg, tc_resume).train()
    assert out_resumed["steps_run"] == 4  # resumed from step 4

    for a, b in zip(
        jax.tree_util.tree_leaves(out_full["params"]),
        jax.tree_util.tree_leaves(out_resumed["params"]),
    ):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-5, atol=1e-6)


def test_grad_compression_trains():
    cfg = get_smoke_config("vusa_edge")
    tc = _tc(steps=6, hp=TrainHParams(lr=1e-3, grad_compress=True, total_steps=6))
    out = Trainer(cfg, tc).train()
    assert np.isfinite(out["final_loss"])


def test_data_determinism():
    from repro.data import SyntheticDataset

    cfg = get_smoke_config("llama3_2_1b")
    a = SyntheticDataset(cfg, 4, 16, seed=7).skip_to(5)
    b = SyntheticDataset(cfg, 4, 16, seed=7).skip_to(5)
    ba, bb = next(iter(a)), next(iter(b))
    np.testing.assert_array_equal(ba["tokens"], bb["tokens"])
    # host sharding partitions the global batch
    h0 = SyntheticDataset(cfg, 4, 16, seed=7, host_index=0, host_count=2)
    assert h0.local_batch == 2
