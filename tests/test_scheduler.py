"""Continuous-batching scheduler: per-request parity with one-shot generate
(greedy/sampled, packed/dense, across families), EOS retirement, mid-stream
admission, ragged prompts, and slot-cache reset on reuse."""

import dataclasses

import numpy as np
import pytest

import jax

from repro.configs import get_smoke_config
from repro.core.pruning import prune_tree
from repro.models import build_model
from repro.serve import Engine, Request, Scheduler, ServeConfig


@pytest.fixture(scope="module")
def llama():
    cfg = get_smoke_config("llama3_2_1b")
    params = build_model(cfg).init(jax.random.key(0))
    return cfg, params


@pytest.fixture(scope="module")
def vusa_pruned():
    cfg = get_smoke_config("vusa_edge")
    params = prune_tree(build_model(cfg).init(jax.random.key(0)), 0.85)
    return cfg, params


def _one_shot(eng, prompt, max_new, seed):
    """Reference: one-shot B=1 generate with the request's seed (reusing the
    engine's jit cache — the seed enters via the key argument, not the
    trace)."""
    eng.sc.seed = seed
    return eng.generate(prompt[None], max_new=max_new)["tokens"][0]


def _check_parity(cfg, params, done, reqs, sc: ServeConfig):
    ref_eng = Engine(cfg, params, dataclasses.replace(sc))
    assert sorted(done) == list(range(len(reqs)))
    for rid, c in sorted(done.items()):
        one = _one_shot(ref_eng, reqs[rid].prompt, reqs[rid].max_new, reqs[rid].seed)
        if reqs[rid].eos_id is not None and (one == reqs[rid].eos_id).any():
            one = one[: int(np.argmax(one == reqs[rid].eos_id)) + 1]
        np.testing.assert_array_equal(c.tokens, one, err_msg=f"rid {rid}")


# ---------------------------------------------------------------------------
# parity: scheduler tokens == one-shot generate tokens, bit for bit
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("temperature", [0.0, 1.0])
def test_parity_dense(llama, temperature):
    cfg, params = llama
    sc = ServeConfig(max_len=64, temperature=temperature)
    sched = Scheduler(Engine(cfg, params, dataclasses.replace(sc)), slots=2, segment=4)
    rng = np.random.default_rng(0)
    reqs = [
        Request(prompt=rng.integers(0, 100, 6).astype(np.int32), max_new=10, seed=i)
        for i in range(5)
    ]
    done = sched.run(reqs)
    _check_parity(cfg, params, done, reqs, sc)


@pytest.mark.parametrize("temperature", [0.0, 1.0])
def test_parity_packed(vusa_pruned, temperature):
    """The VUSA-packed MLP path must keep working under the scheduler."""
    cfg, params = vusa_pruned
    sc = ServeConfig(max_len=64, temperature=temperature, packed_mlp=True)
    sched = Scheduler(Engine(cfg, params, dataclasses.replace(sc)), slots=2, segment=4)
    rng = np.random.default_rng(1)
    reqs = [
        Request(prompt=rng.integers(0, 100, 5).astype(np.int32), max_new=8, seed=20 + i)
        for i in range(3)
    ]
    done = sched.run(reqs)
    _check_parity(cfg, params, done, reqs, sc)


def test_parity_recurrent_family():
    """Slot caches are family-agnostic: Mamba-2 conv/SSM state slots work."""
    cfg = get_smoke_config("mamba2_2_7b")
    params = build_model(cfg).init(jax.random.key(0))
    sc = ServeConfig(max_len=64)
    sched = Scheduler(Engine(cfg, params, dataclasses.replace(sc)), slots=2, segment=4)
    rng = np.random.default_rng(2)
    reqs = [
        Request(prompt=rng.integers(0, 100, 6).astype(np.int32), max_new=8, seed=i)
        for i in range(3)
    ]
    done = sched.run(reqs)
    _check_parity(cfg, params, done, reqs, sc)


def test_parity_ragged_prompts(llama):
    """Slots at ragged positions (different prompt lengths, admitted at
    different times) must not perturb each other."""
    cfg, params = llama
    sc = ServeConfig(max_len=64)
    sched = Scheduler(Engine(cfg, params, dataclasses.replace(sc)), slots=3, segment=4)
    rng = np.random.default_rng(3)
    reqs = [
        Request(prompt=rng.integers(0, 100, n).astype(np.int32), max_new=m, seed=i)
        for i, (n, m) in enumerate([(4, 12), (9, 6), (6, 10), (4, 8), (9, 9)])
    ]
    done = sched.run(reqs)
    _check_parity(cfg, params, done, reqs, sc)


# ---------------------------------------------------------------------------
# slot lifecycle
# ---------------------------------------------------------------------------


def test_eos_retires_and_frees_slot(llama):
    """EOS mid-stream retires the request, truncates its tokens just after
    the EOS, and frees the slot for the queued request — whose bit-exact
    output proves the slot cache was fully reset."""
    cfg, params = llama
    sc = ServeConfig(max_len=64)
    ref_eng = Engine(cfg, params, dataclasses.replace(sc))
    rng = np.random.default_rng(4)
    p0 = rng.integers(0, 100, 6).astype(np.int32)
    p1 = rng.integers(0, 100, 6).astype(np.int32)
    one0 = _one_shot(ref_eng, p0, 12, seed=3)
    eos = int(one0[3])  # 4th generated token becomes the stop token
    sched = Scheduler(Engine(cfg, params, dataclasses.replace(sc)), slots=1, segment=4)
    reqs = [
        Request(prompt=p0, max_new=12, eos_id=eos, seed=3),
        Request(prompt=p1, max_new=8, seed=7),
    ]
    done = sched.run(reqs)
    assert len(done[0].tokens) == 4 and done[0].tokens[-1] == eos
    np.testing.assert_array_equal(done[0].tokens, one0[:4])
    np.testing.assert_array_equal(done[1].tokens, _one_shot(ref_eng, p1, 8, seed=7))
    # the second request could only run after the first retired its slot
    assert done[1].admit_s >= done[0].finish_s


def test_queued_request_admitted_mid_stream(llama):
    """With a long and a short request in flight, the queued third request
    must be admitted into the short one's slot while the long one is still
    decoding — not after the whole pool drains."""
    cfg, params = llama
    sc = ServeConfig(max_len=96)
    sched = Scheduler(Engine(cfg, params, dataclasses.replace(sc)), slots=2, segment=4)
    rng = np.random.default_rng(5)
    reqs = [
        Request(prompt=rng.integers(0, 100, 6).astype(np.int32), max_new=40, seed=0),
        Request(prompt=rng.integers(0, 100, 6).astype(np.int32), max_new=6, seed=1),
        Request(prompt=rng.integers(0, 100, 6).astype(np.int32), max_new=6, seed=2),
    ]
    done = sched.run(reqs)
    _check_parity(cfg, params, done, reqs, sc)
    # rid 2 entered after rid 1 retired but before the long rid 0 finished
    assert done[1].finish_s <= done[2].admit_s <= done[0].finish_s
    assert sched.stats()["slot_occupancy"] > 0.5


def test_instant_completion_at_admission(llama):
    """max_new=1 (and first-token EOS) requests complete with just their
    deferred first token — retired at the first segment sync, with no
    admission-time host transfer."""
    cfg, params = llama
    sc = ServeConfig(max_len=64)
    ref_eng = Engine(cfg, params, dataclasses.replace(sc))
    rng = np.random.default_rng(6)
    p = rng.integers(0, 100, 6).astype(np.int32)
    sched = Scheduler(Engine(cfg, params, dataclasses.replace(sc)), slots=1, segment=4)
    done = sched.run([Request(prompt=p, max_new=1, seed=0)])
    np.testing.assert_array_equal(done[0].tokens, _one_shot(ref_eng, p, 1, seed=0))


def test_submit_validates_budget(llama):
    cfg, params = llama
    sched = Scheduler(Engine(cfg, params, ServeConfig(max_len=32)), slots=1, segment=8)
    with pytest.raises(ValueError, match="max_len"):
        sched.submit(Request(prompt=np.ones(8, np.int32), max_new=30))
    with pytest.raises(ValueError, match="fused"):
        Scheduler(Engine(cfg, params, ServeConfig(max_len=32, fused=False)))


# ---------------------------------------------------------------------------
# models cache API: slot slicing / reset round-trips
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("arch", ["llama3_2_1b", "mamba2_2_7b", "recurrentgemma_9b"])
def test_slot_cache_roundtrip(arch):
    """write_slot/read_slot round-trip one slot without touching neighbours;
    reset_slot returns the slot to the init state — across cache families."""
    from repro.models.cache import slot_count

    model = build_model(get_smoke_config(arch))
    stacked = model.init_slot_cache(3, 32)
    assert slot_count(stacked) == 3
    sub = jax.tree.map(
        lambda leaf: (jax.numpy.zeros_like(leaf) + 1).astype(leaf.dtype),
        model.init_cache(1, 32),
    )
    written = model.write_slot(stacked, 1, sub)
    jax.tree.map(
        lambda a, b: np.testing.assert_array_equal(np.asarray(a), np.asarray(b)),
        model.read_slot(written, 1), sub,
    )
    for other in (0, 2):  # neighbours untouched
        jax.tree.map(
            lambda a, b: np.testing.assert_array_equal(np.asarray(a), np.asarray(b)),
            model.read_slot(written, other), model.read_slot(stacked, other),
        )
    cleared = model.reset_slot(written, 1)
    jax.tree.map(
        lambda a, b: np.testing.assert_array_equal(np.asarray(a), np.asarray(b)),
        cleared, stacked,
    )


# ---------------------------------------------------------------------------
# ServeConfig default regression (shared mutable default)
# ---------------------------------------------------------------------------


def test_engine_default_config_not_shared(llama):
    cfg, params = llama
    a = Engine(cfg, params)
    b = Engine(cfg, params)
    a.sc.seed = 123
    assert b.sc.seed == 0
