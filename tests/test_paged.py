"""Paged KV-cache pool (DESIGN.md §11): bit-parity with the slot pool across
dense / fully-packed / quantized / sharded engines, prefix-cache sharing that
skips re-prefill, copy-on-write at the divergence boundary, chunked prefill
co-scheduled with live decode, preemption under arena pressure, and the §9
fault paths ported to the paged layout (poison lands in a *private* block, so
prefix sharers never see it).

The correctness bar is the one the repo has pinned since §5: the paged pool
changes *where* KV bytes live, never *what* decode computes — per-request
tokens bit-identical to the slot-pool scheduler, greedy and sampled."""

import dataclasses
import math

import numpy as np
import pytest

import jax
from conftest import requires_devices

from repro.configs import get_smoke_config
from repro.core.pruning import prune_tree
from repro.models import build_model
from repro.serve import Engine, FaultConfig, Request, Scheduler, ServeConfig, Status


@pytest.fixture(scope="module")
def llama():
    cfg = get_smoke_config("llama3_2_1b")
    params = build_model(cfg).init(jax.random.key(0))
    return cfg, params


@pytest.fixture(scope="module")
def vusa_pruned():
    cfg = get_smoke_config("vusa_edge")
    params = prune_tree(build_model(cfg).init(jax.random.key(0)), 0.85)
    return cfg, params


def _run(cfg, params, sc, reqs, slots=3, segment=4, mesh=None):
    sched = Scheduler(
        Engine(cfg, params, dataclasses.replace(sc), mesh=mesh),
        slots=slots, segment=segment,
    )
    done = sched.run([dataclasses.replace(r) for r in reqs])
    return sched, done


def _assert_same_tokens(a, b):
    assert sorted(a) == sorted(b)
    for rid in a:
        np.testing.assert_array_equal(a[rid].tokens, b[rid].tokens,
                                      err_msg=f"rid {rid}")


def _ragged_reqs(rng, spec):
    return [
        Request(prompt=rng.integers(1, 100, n).astype(np.int32), max_new=m, seed=i)
        for i, (n, m) in enumerate(spec)
    ]


# ---------------------------------------------------------------------------
# parity: paged scheduler == slot scheduler, bit for bit
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("temperature", [0.0, 1.0])
def test_paged_parity_dense(llama, temperature):
    cfg, params = llama
    rng = np.random.default_rng(0)
    reqs = _ragged_reqs(rng, [(6, 10), (13, 8), (9, 12), (17, 6), (5, 9), (24, 7)])
    sc = ServeConfig(max_len=64, temperature=temperature)
    _, ref = _run(cfg, params, sc, reqs)
    sp, got = _run(cfg, params, dataclasses.replace(sc, page_size=8), reqs)
    assert sp.paged
    _assert_same_tokens(ref, got)


@pytest.mark.parametrize("temperature", [0.0, 1.0])
def test_paged_parity_packed_all(vusa_pruned, temperature):
    """Whole-model VUSA packing (§7) under the paged pool: the gathered block
    view must be shape-identical to the slot cache, so the packed decode
    kernels see the same operands."""
    cfg, params = vusa_pruned
    rng = np.random.default_rng(1)
    reqs = _ragged_reqs(rng, [(5, 8), (11, 6), (7, 8)])
    sc = ServeConfig(max_len=64, temperature=temperature, packed_weights="all")
    _, ref = _run(cfg, params, sc, reqs, slots=2)
    _, got = _run(cfg, params, dataclasses.replace(sc, page_size=8), reqs, slots=2)
    _assert_same_tokens(ref, got)


def test_paged_parity_quantized_int8(vusa_pruned):
    """Quantized packed values (§10) ride along unchanged: dequant touches
    weights, not the KV arena."""
    cfg, params = vusa_pruned
    rng = np.random.default_rng(2)
    reqs = _ragged_reqs(rng, [(6, 8), (9, 6)])
    sc = ServeConfig(max_len=64, packed_weights="all", packed_values="int8")
    _, ref = _run(cfg, params, sc, reqs, slots=2)
    _, got = _run(cfg, params, dataclasses.replace(sc, page_size=8), reqs, slots=2)
    _assert_same_tokens(ref, got)


@requires_devices(8)
def test_paged_parity_sharded(vusa_pruned):
    """2x4 DP x TP mesh: the arena's block axis shards over 'data'
    (dist.sharding.block_sharding) — tokens must still match the
    single-device slot scheduler bit for bit."""
    from repro.launch.mesh import make_serve_mesh

    cfg, params = vusa_pruned
    rng = np.random.default_rng(3)
    reqs = _ragged_reqs(rng, [(6, 8), (11, 6), (8, 7)])
    sc = ServeConfig(max_len=48, packed_weights="all", vusa_m=32, vusa_a=8)
    _, ref = _run(cfg, params, sc, reqs, slots=2)
    sp, got = _run(cfg, params, dataclasses.replace(sc, page_size=8), reqs,
                   slots=2, mesh=make_serve_mesh("2,4"))
    assert sp.paged
    _assert_same_tokens(ref, got)


# ---------------------------------------------------------------------------
# prefix sharing: hits skip re-prefill, COW splits partial tails
# ---------------------------------------------------------------------------


def test_prefix_cache_hit_skips_reprefill(llama):
    """Serving the same page-aligned prompt twice: the second run matches
    every page, never dispatches a prefill (prime_many), and produces
    identical tokens off the shared blocks."""
    cfg, params = llama
    rng = np.random.default_rng(4)
    p = rng.integers(1, 100, 16).astype(np.int32)  # 2 full pages
    sched = Scheduler(
        Engine(cfg, params, ServeConfig(max_len=64, page_size=8)),
        slots=2, segment=4,
    )
    calls = []
    inner = sched.eng.prime_many
    sched.eng.prime_many = lambda *a, **k: (calls.append(1), inner(*a, **k))[1]
    d1 = sched.run([Request(prompt=p, max_new=8, seed=5)])
    assert calls and sched.stats()["prefix_hits"] == 0
    calls.clear()
    d2 = sched.run([Request(prompt=p.copy(), max_new=8, seed=5)])
    st = sched.stats()
    assert not calls, "full prefix hit must not re-prefill"
    assert st["prefix_hits"] > 0 and st["prefix_hit_rate"] == 1.0
    np.testing.assert_array_equal(d1[0].tokens, d2[1].tokens)


def test_prefix_cow_partial_tail(llama):
    """A prompt whose tail only part-fills its last page: the second serve
    shares the full pages, COW-copies the registered tail block, and still
    matches bit for bit."""
    cfg, params = llama
    rng = np.random.default_rng(5)
    p = rng.integers(1, 100, 21).astype(np.int32)  # 2 full pages + 5-row tail
    sched = Scheduler(
        Engine(cfg, params, ServeConfig(max_len=64, page_size=8)),
        slots=2, segment=4,
    )
    d1 = sched.run([Request(prompt=p, max_new=8, seed=6)])
    d2 = sched.run([Request(prompt=p.copy(), max_new=8, seed=6)])
    st = sched.stats()
    assert st["prefix_hits"] > 0 and st["cow_copies"] >= 1
    np.testing.assert_array_equal(d1[0].tokens, d2[1].tokens)


def test_prefix_cache_off_never_shares(llama):
    cfg, params = llama
    rng = np.random.default_rng(6)
    p = rng.integers(1, 100, 16).astype(np.int32)
    sched = Scheduler(
        Engine(cfg, params, ServeConfig(max_len=64, page_size=8, prefix_cache=False)),
        slots=2, segment=4,
    )
    d1 = sched.run([Request(prompt=p, max_new=6, seed=0)])
    d2 = sched.run([Request(prompt=p.copy(), max_new=6, seed=0)])
    st = sched.stats()
    assert st["prefix_hits"] == 0 and st["prefix_lookups"] == 0
    np.testing.assert_array_equal(d1[0].tokens, d2[1].tokens)


# ---------------------------------------------------------------------------
# chunked prefill: co-scheduled with decode, parity preserved
# ---------------------------------------------------------------------------


def test_chunked_prefill_parity_and_liveness(llama):
    """A 70-token admission chunked at 16 tokens/segment: the in-flight decode
    slot must keep emitting tokens *while* the long prompt prefills (Sarathi
    co-scheduling — no decode stall), and both requests' tokens must match
    the unchunked slot-pool run."""
    cfg, params = llama
    rng = np.random.default_rng(7)
    reqs = [
        Request(prompt=rng.integers(1, 100, 9).astype(np.int32), max_new=40, seed=0),
        Request(prompt=rng.integers(1, 100, 70).astype(np.int32), max_new=10, seed=1,
                arrival_s=0.0),
    ]
    _, ref = _run(cfg, params, ServeConfig(max_len=128), reqs, slots=2)
    sched = Scheduler(
        Engine(cfg, params, ServeConfig(max_len=128, page_size=8, prefill_chunk=16)),
        slots=2, segment=4,
    )
    snaps = []

    def on_sync(s):
        snaps.append([(sl.rid, len(sl.tokens or []), sl.prefill is not None)
                      for sl in s._slot])

    got = sched.run([dataclasses.replace(r) for r in reqs], on_sync=on_sync)
    _assert_same_tokens(ref, got)
    # liveness: find consecutive syncs where one slot was mid-chunked-prefill
    # while another slot's token count advanced
    # some slot prefilling at both syncs while another slot emitted tokens
    overlapped = any(
        any(pf_a and pf_b for (_, _, pf_a), (_, _, pf_b) in zip(a, b))
        and any(tb > ta for (_, ta, pa), (_, tb, pb) in zip(a, b) if not (pa or pb))
        for a, b in zip(snaps, snaps[1:])
    )
    assert overlapped, "decode slots must keep stepping during chunked admission"


# ---------------------------------------------------------------------------
# arena pressure: lazy allocation, preemption, admission guard
# ---------------------------------------------------------------------------


def test_preemption_parity_tiny_arena(llama):
    """arena_blocks far below slots*n_pages: mid-flight extensions must
    preempt the latest admission (never the earliest — guaranteed progress)
    and re-served requests still produce identical tokens (same seed)."""
    cfg, params = llama
    rng = np.random.default_rng(8)
    reqs = _ragged_reqs(rng, [(6, 10), (13, 8), (9, 12), (17, 6)])
    _, ref = _run(cfg, params, ServeConfig(max_len=64), reqs, slots=4)
    sp, got = _run(cfg, params,
                   ServeConfig(max_len=64, page_size=8, arena_blocks=10),
                   reqs, slots=4)
    _assert_same_tokens(ref, got)
    assert sp.stats()["preempted"] >= 1


def test_submit_rejects_impossible_arena_budget(llama):
    cfg, params = llama
    sched = Scheduler(
        Engine(cfg, params, ServeConfig(max_len=64, page_size=8, arena_blocks=2)),
        slots=1, segment=4,
    )
    with pytest.raises(ValueError, match="arena"):
        sched.submit(Request(prompt=np.ones(30, np.int32), max_new=20))


# ---------------------------------------------------------------------------
# §9 fault paths on the paged layout
# ---------------------------------------------------------------------------


def test_paged_cache_poison_falls_back(llama):
    """Admission-time NaN poison on a paged slot: the guard trips, the request
    retries clean and finishes FAILED_FALLBACK_OK bit-identical to its clean
    run; neighbours stay OK."""
    cfg, params = llama
    rng = np.random.default_rng(9)
    reqs = _ragged_reqs(rng, [(6, 8), (9, 8), (7, 8)])
    sc = ServeConfig(max_len=64, page_size=8,
                     faults=FaultConfig(cache_nan_rids=(1,)))
    _, clean = _run(cfg, params, ServeConfig(max_len=64), reqs)
    _, done = _run(cfg, params, sc, reqs)
    assert done[1].status is Status.FAILED_FALLBACK_OK
    assert done[0].status is Status.OK and done[2].status is Status.OK
    _assert_same_tokens(clean, done)


def test_paged_poison_contained_under_prefix_sharing(llama):
    """Poisoning a request whose prompt is fully prefix-shared must first
    COW-privatize the page — the sharer reads the original bytes and stays
    OK with clean tokens; the poisoned block is forgotten (never matchable)
    and zeroed on release."""
    cfg, params = llama
    rng = np.random.default_rng(10)
    p = rng.integers(1, 100, 16).astype(np.int32)
    # rid 0 registers the prefix clean; rids 1 (poisoned) and 2 share it
    sc = ServeConfig(max_len=64, page_size=8,
                     faults=FaultConfig(cache_nan_rids=(1,)))
    sched = Scheduler(Engine(cfg, params, dataclasses.replace(sc)),
                      slots=2, segment=4)
    d0 = sched.run([Request(prompt=p, max_new=8, seed=0)])
    dd = sched.run([Request(prompt=p.copy(), max_new=8, seed=0),
                    Request(prompt=p.copy(), max_new=8, seed=0)])
    assert d0[0].status is Status.OK
    assert dd[1].status is Status.FAILED_FALLBACK_OK
    assert dd[2].status is Status.OK
    # every delivered stream equals the clean one — poison never crossed the
    # COW boundary into shared state
    np.testing.assert_array_equal(dd[1].tokens, d0[0].tokens)
    np.testing.assert_array_equal(dd[2].tokens, d0[0].tokens)


def test_paged_chunked_admission_poison(llama):
    """Fault injection on a chunked admission defers to prefill completion
    (chunks would overwrite earlier poison): the request still trips the
    guard and falls back clean."""
    cfg, params = llama
    rng = np.random.default_rng(11)
    reqs = [Request(prompt=rng.integers(1, 100, 40).astype(np.int32),
                    max_new=8, seed=0)]
    sc = ServeConfig(max_len=64, page_size=8, prefill_chunk=16,
                     faults=FaultConfig(cache_nan_rids=(0,)))
    _, clean = _run(cfg, params, ServeConfig(max_len=64), reqs, slots=1)
    _, done = _run(cfg, params, sc, reqs, slots=1)
    assert done[0].status is Status.FAILED_FALLBACK_OK
    np.testing.assert_array_equal(done[0].tokens, clean[0].tokens)


# ---------------------------------------------------------------------------
# observability: stats() NaN-safe, gauges sane
# ---------------------------------------------------------------------------


def test_stats_nan_safe_on_empty_run(llama):
    cfg, params = llama
    for sc in (ServeConfig(max_len=64), ServeConfig(max_len=64, page_size=8)):
        sched = Scheduler(Engine(cfg, params, sc), slots=2, segment=4)
        st = sched.stats()
        assert math.isnan(st["prefix_hit_rate"])
        assert math.isnan(st["hbm_bytes_per_active_request"])
        assert st["kv_pool_bytes"] > 0 and st["kv_block_bytes"] > 0
    # slot mode reports NaN block gauges (no blocks to count)
    assert math.isnan(st["blocks_total"]) is False  # paged: real number
    sched_slot = Scheduler(Engine(cfg, params, ServeConfig(max_len=64)),
                           slots=2, segment=4)
    assert math.isnan(sched_slot.stats()["blocks_total"])


def test_stats_paged_gauges_after_traffic(llama):
    cfg, params = llama
    rng = np.random.default_rng(12)
    sp, _ = _run(cfg, params, ServeConfig(max_len=64, page_size=8),
                 _ragged_reqs(rng, [(6, 8), (9, 6)]), slots=2)
    st = sp.stats()
    assert st["hbm_bytes_per_active_request"] > 0
    assert st["blocks_total"] == sp._layout.user_blocks
    assert (st["blocks_live"] + st["blocks_free"] + st["blocks_cached"]
            == st["blocks_total"])
    # paged per-request KV footprint beats one whole slot-pool slot
    slot_bytes = Scheduler(
        Engine(cfg, params, ServeConfig(max_len=64)), slots=2, segment=4
    ).stats()["kv_block_bytes"]
    assert st["hbm_bytes_per_active_request"] < slot_bytes


# ---------------------------------------------------------------------------
# COW block copy preserves bytes (device-level; host invariants are
# property-tested in test_packing_props.py)
# ---------------------------------------------------------------------------


def test_copy_block_preserves_bytes(llama):
    from repro.models.cache import PagedLayout, copy_block

    cfg, params = llama
    model = build_model(cfg)
    lay = PagedLayout.build(2, 64, 8)
    pool = model.init_paged_pool(lay, 64)
    rng = np.random.default_rng(13)
    arena = {
        name: jax.numpy.asarray(
            rng.normal(size=a.shape).astype(np.asarray(a).dtype)
        )
        for name, a in pool["arena"].items()
    }
    src, dst = lay.reserved, lay.reserved + 1
    out = copy_block(arena, src, dst)
    for name, a in out.items():
        np.testing.assert_array_equal(np.asarray(a[:, dst]),
                                      np.asarray(arena[name][:, src]))
        # untouched blocks identical
        others = [b for b in range(a.shape[1]) if b != dst]
        np.testing.assert_array_equal(np.asarray(a[:, others]),
                                      np.asarray(arena[name][:, others]))
