"""Benchmark harness — one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV to stdout and writes full tables to
``experiments/benchmarks/*.json``.

Paper artifacts:
  fig6_growth           Fig. 6   growth probability curves (N=3, M=6, A=3)
  table1_area_power     Table I  16-nm PPA, component model vs paper values
  table2_resnet18       Table II ResNet-18 @ 85% unstructured sparsity
  table3_mobilenet      Table III MobileNetV1 @ 75%
  fig89_pruning_sweep   Fig. 8/9 area/power efficiency vs pruning rate
Framework micro-benchmarks:
  kernel_vusa_packed    packed-vs-dense matmul (bytes + wall time, CPU jnp)
  bench_spec_decode     self-speculative decode: accepted-tok/s vs baseline
  bench_scheduler       host-side schedule throughput
  bench_train_decode    smoke-model jitted train/decode step wall time
  bench_admission       bucketed batched admission vs per-request admission
"""

from __future__ import annotations

import json
import time
from pathlib import Path

import numpy as np

OUT = Path(__file__).resolve().parent.parent / "experiments" / "benchmarks"

VUSA = (3, 6, 3)  # the paper's (N, M, A)
FREQ_HZ = 1e9


RESULTS = {}  # bench name -> saved table (for the regression gate)


def _emit(name, us, derived):
    print(f"{name},{us:.1f},{derived}")


def _save(name, obj):
    OUT.mkdir(parents=True, exist_ok=True)
    (OUT / f"{name}.json").write_text(json.dumps(obj, indent=1, default=float))
    RESULTS[name] = obj


# ---------------------------------------------------------------------------


def fig6_growth():
    from repro.core.growth import growth_curves

    t0 = time.time()
    sparsity = np.linspace(0, 1, 101)
    curves = growth_curves(3, 6, 3, sparsity)
    us = (time.time() - t0) * 1e6
    anchors = {
        "P(3x6)@s=0.9": float(curves[6][90]),
        "P(3x6)@s=0.6": float(curves[6][60]),
        "P(3x4)@s=0.3": float(curves[4][30]),
    }
    _save("fig6_growth", {"sparsity": sparsity.tolist(),
                          **{f"w{w}": c.tolist() for w, c in curves.items()},
                          "anchors": anchors})
    _emit("fig6_growth", us, ";".join(f"{k}={v:.3f}" for k, v in anchors.items()))


def table1_area_power():
    from repro.core.hwmodel import TABLE1_PAPER, table1

    t0 = time.time()
    model = table1()
    us = (time.time() - t0) * 1e6
    rows = {}
    max_err = 0.0
    for k, (macs, area, power) in model.items():
        pm, pa, pp = TABLE1_PAPER[k]
        rows[k] = {"macs": macs, "area": area, "area_paper": pa,
                   "power": power, "power_paper": pp}
        max_err = max(max_err, abs(area - pa), abs(power - pp))
    _save("table1_area_power", rows)
    _emit("table1_area_power", us, f"max_abs_err_vs_paper={max_err:.3f}")


# ---------------------------------------------------------------------------


def _prune_masks(gemms, rate, seed=0):
    """Magnitude-prune random-init weights per layer (DESIGN.md: SparseZoo
    is offline; iid random init + magnitude pruning = unstructured sparsity)."""
    rng = np.random.default_rng(seed)
    masks = []
    for g in gemms:
        w = rng.normal(size=(g.K, g.C))
        thresh = np.quantile(np.abs(w), rate)
        masks.append(np.abs(w) > thresh)
    return masks


def _evaluate_model(gemms, masks, label, paper_row=None):
    """Full Section V-C methodology: standard 3x3..3x6 + VUSA cycles,
    GOP/s @1 GHz, PPA efficiency normalized to standard 3x6."""
    from repro.core.hwmodel import HwModel
    from repro.core.simulator import gemm_cycles_standard, ws_cycles
    from repro.core.vusa import schedule_widths_fast

    n, m, a = VUSA
    hw = HwModel()
    total_ops = sum(g.ops for g in gemms)

    cycles_std = {w: sum(gemm_cycles_standard(g, n, w) for g in gemms) for w in range(a, m + 1)}

    hist_total = np.zeros(m + 1, dtype=np.int64)
    load = np.zeros(m + 1)
    cycles_vusa = 0
    for g, mask in zip(gemms, masks):
        hist, _ = schedule_widths_fast(mask, n, m, a)
        hist_total += hist
        for w in range(a, m + 1):
            cycles_vusa += int(hist[w]) * ws_cycles(g.B, n, w)
            load[w] += hist[w] * w * g.B
    load_split = (load / load.sum()).tolist()

    def perf(cycles):
        return total_ops / (cycles / FREQ_HZ) / 1e9  # GOP/s

    area6, power6 = hw.area_standard(n, m), hw.power_standard(n, m)
    t6 = cycles_std[m]
    table = {}
    for w in range(a, m + 1):
        cyc = cycles_std[w]
        aw, pw = hw.area_standard(n, w), hw.power_standard(n, w)
        table[f"standard_3x{w}"] = {
            "cycles": cyc,
            "time_ms": cyc / FREQ_HZ * 1e3,
            "gops": perf(cyc),
            "perf_per_area": (perf(cyc) / aw) / (perf(t6) / area6),
            "perf_per_power": (perf(cyc) / pw) / (perf(t6) / power6),
            "energy": (pw * cyc) / (power6 * t6),
        }
    av, pv = hw.area_vusa(n, m, a), hw.power_vusa(n, m, a)
    table["vusa_3x6"] = {
        "cycles": cycles_vusa,
        "time_ms": cycles_vusa / FREQ_HZ * 1e3,
        "gops": perf(cycles_vusa),
        "perf_per_area": (perf(cycles_vusa) / av) / (perf(t6) / area6),
        "perf_per_power": (perf(cycles_vusa) / pv) / (perf(t6) / power6),
        "energy": (pv * cycles_vusa) / (power6 * t6),
        "load_split": load_split,
    }
    if paper_row:
        table["paper_vusa"] = paper_row
    return table


_PAPER_T2 = {"cycles": 9.65e7, "gops": 16.02, "perf_per_area": 1.27,
             "perf_per_power": 1.56, "energy": 0.64, "load6": 0.8685}
_PAPER_T3 = {"cycles": 4.43e7, "gops": 12.86, "perf_per_area": 1.18,
             "perf_per_power": 1.45, "energy": 0.69, "load6": 0.6864}


def table2_resnet18():
    from repro.core.workloads import resnet18_gemms

    t0 = time.time()
    gemms = resnet18_gemms()
    masks = _prune_masks(gemms, 0.85)
    table = _evaluate_model(gemms, masks, "resnet18@85", _PAPER_T2)
    us = (time.time() - t0) * 1e6
    _save("table2_resnet18", table)
    v = table["vusa_3x6"]
    _emit(
        "table2_resnet18",
        us,
        f"vusa_gops={v['gops']:.2f}(paper {_PAPER_T2['gops']});"
        f"pp_area={v['perf_per_area']:.2f}(paper {_PAPER_T2['perf_per_area']});"
        f"pp_power={v['perf_per_power']:.2f}(paper {_PAPER_T2['perf_per_power']});"
        f"energy={v['energy']:.2f}(paper {_PAPER_T2['energy']});"
        f"load6={v['load_split'][6]:.3f}(paper {_PAPER_T2['load6']})",
    )


def table3_mobilenet():
    from repro.core.workloads import mobilenetv1_gemms

    t0 = time.time()
    gemms = mobilenetv1_gemms()
    masks = _prune_masks(gemms, 0.75)
    table = _evaluate_model(gemms, masks, "mobilenetv1@75", _PAPER_T3)
    us = (time.time() - t0) * 1e6
    _save("table3_mobilenet", table)
    v = table["vusa_3x6"]
    _emit(
        "table3_mobilenet",
        us,
        f"vusa_gops={v['gops']:.2f}(paper {_PAPER_T3['gops']});"
        f"pp_area={v['perf_per_area']:.2f}(paper {_PAPER_T3['perf_per_area']});"
        f"pp_power={v['perf_per_power']:.2f}(paper {_PAPER_T3['perf_per_power']});"
        f"energy={v['energy']:.2f}(paper {_PAPER_T3['energy']});"
        f"load6={v['load_split'][6]:.3f}(paper {_PAPER_T3['load6']})",
    )


def fig89_pruning_sweep():
    from repro.core.workloads import resnet18_gemms

    t0 = time.time()
    gemms = resnet18_gemms()
    rates = [0.0, 0.15, 0.3, 0.45, 0.55, 0.65, 0.75, 0.85, 0.95]
    area_eff, power_eff = [], []
    for r in rates:
        masks = _prune_masks(gemms, r)
        table = _evaluate_model(gemms, masks, f"sweep@{r}")
        area_eff.append(table["vusa_3x6"]["perf_per_area"])
        power_eff.append(table["vusa_3x6"]["perf_per_power"])
    us = (time.time() - t0) * 1e6
    # crossover rates (efficiency > 1 vs standard 3x6)
    a_cross = next((r for r, e in zip(rates, area_eff) if e >= 1.0), None)
    p_cross = next((r for r, e in zip(rates, power_eff) if e >= 1.0), None)
    _save("fig89_pruning_sweep", {"rates": rates, "area_eff": area_eff,
                                  "power_eff": power_eff,
                                  "area_crossover": a_cross, "power_crossover": p_cross,
                                  "paper": {"area_crossover": 0.55, "power_crossover": 0.30,
                                            "area_eff@95": 1.36, "power_eff@95": 1.67}})
    _emit("fig89_pruning_sweep", us,
          f"area_eff@95={area_eff[-1]:.2f}(paper 1.36);power_eff@95={power_eff[-1]:.2f}(paper 1.67);"
          f"area_cross={a_cross}(paper ~0.55);power_cross={p_cross}(paper ~0.30)")


# ---------------------------------------------------------------------------


def kernel_vusa_packed():
    """Packed vs dense matmul: HBM byte ratio (the TPU-side VUSA gain) and
    a before/after of the Pallas kernel's dense-tile reconstruction —
    "before" is the seed per-slot fori_loop at its default k_blk=256,
    "after" is the vectorized one-hot contraction with the autotuned k_blk
    (repro.kernels.ops.choose_k_blk/autotune_row_packed)."""
    import jax
    import jax.numpy as jnp

    from repro.kernels.ops import (
        apply_row_packed,
        autotune_row_packed,
        pack_linear_rows,
    )
    from repro.kernels.ref import dense_matmul_ref, vusa_packed_ref
    from repro.kernels.vusa_packed import vusa_packed_matmul

    rng = np.random.default_rng(0)
    k = c = 1024
    b = 64
    iters = 10
    results = {}
    x = jnp.asarray(rng.normal(size=(b, k)), jnp.float32)

    def best_of(f, reps=3):
        f(x).block_until_ready()  # compile
        best = float("inf")
        for _ in range(reps):
            t0 = time.perf_counter()
            for _ in range(iters):
                f(x).block_until_ready()
            best = min(best, (time.perf_counter() - t0) / iters)
        return best

    for sp in (0.0, 0.5, 0.85, 0.95):
        w = rng.normal(size=(k, c)) * (rng.random((k, c)) > sp)
        w = w.astype(np.float32)
        p = pack_linear_rows(w, a=16)
        wj = jnp.asarray(w)
        entry = {
            "byte_ratio": p.byte_ratio,
            "n_jobs": int(p.values.shape[2] // p.a),
        }
        if sp == 0.0:
            # N:M structured comparison arm (S2TA-style density-bound blocks):
            # 2:4 prunes the dense matrix itself, then rides the same kernel
            from repro.core.packing import nm_mask
            from repro.kernels.ops import pack_linear_rows_nm

            pnm = pack_linear_rows_nm(w, n=2, block=4, a=16)
            masked = np.where(nm_mask(w, 2, 4), w, 0.0)
            got = np.asarray(apply_row_packed(x, pnm), np.float32)
            np.testing.assert_allclose(
                got, np.asarray(x, np.float32) @ masked, rtol=1e-4, atol=1e-4
            )
            f_nm = jax.jit(lambda a: apply_row_packed(a, pnm))
            results["nm_2of4"] = {
                "byte_ratio": pnm.byte_ratio,
                "n_jobs": int(pnm.values.shape[2] // pnm.a),
                "kernel_vec_us": best_of(f_nm) * 1e6,
            }
        if sp in (0.85, 0.95):  # wall-time A/B on the interesting points
            ref = np.asarray(vusa_packed_ref(x, p.values, p.positions))[:, : p.c]
            got = np.asarray(apply_row_packed(x, p), np.float32)
            np.testing.assert_allclose(got, ref, rtol=1e-4, atol=1e-4)
            k_blk = autotune_row_packed(x, p)
            from repro.kernels.ops import on_tpu

            interp = not on_tpu()  # both arms on the same execution mode
            f_dense = jax.jit(lambda a: dense_matmul_ref(a, wj))
            f_before = jax.jit(
                lambda a: vusa_packed_matmul(
                    a, p.values, p.positions, m=p.m, k_blk=256,
                    interpret=interp, reconstruct="loop",
                )
            )
            f_after = jax.jit(lambda a: apply_row_packed(a, p))
            entry.update(
                dense_us=best_of(f_dense) * 1e6,
                kernel_loop_us=best_of(f_before) * 1e6,
                kernel_vec_us=best_of(f_after) * 1e6,
                k_blk=k_blk,
            )
            entry["kernel_speedup"] = entry["kernel_loop_us"] / entry["kernel_vec_us"]
        results[f"sparsity_{sp}"] = entry
    _save("kernel_vusa_packed", results)
    r85 = results["sparsity_0.85"]
    _emit("kernel_vusa_packed", r85["kernel_vec_us"],
          f"byte_ratio@85={r85['byte_ratio']:.3f};jobs@85={r85['n_jobs']};"
          f"loop_us@85={r85['kernel_loop_us']:.0f};vec_us@85={r85['kernel_vec_us']:.0f};"
          f"speedup@85={r85['kernel_speedup']:.2f}x;"
          f"byte_ratio@95={results['sparsity_0.95']['byte_ratio']:.3f}")


def bench_decode_fused():
    """Fused on-device decode loop vs the seed per-token host loop: same
    smoke model, same prompts, greedy — identical tokens required, tokens/s
    compared (best of 3 after a matched-shape compile warmup)."""
    import jax

    from repro.configs import get_smoke_config
    from repro.models import build_model
    from repro.serve import Engine, ServeConfig

    cfg = get_smoke_config("llama3_2_1b")
    params = build_model(cfg).init(jax.random.key(0))
    prompts = np.ones((2, 6), np.int32)
    max_new = 64
    runs = {}
    for fused in (False, True):
        eng = Engine(cfg, params, ServeConfig(max_len=128, fused=fused))
        eng.generate(prompts, max_new=max_new)  # compile (same steps shape)
        best = None
        for _ in range(3):
            out = eng.generate(prompts, max_new=max_new)
            if best is None or out["tok_per_s"] > best["tok_per_s"]:
                best = out
        runs[fused] = best
    assert (runs[False]["tokens"] == runs[True]["tokens"]).all(), "fused decode diverged"
    us = runs[True]["decode_s"] * 1e6  # per-generate decode time of the fused arm
    speedup = runs[True]["tok_per_s"] / runs[False]["tok_per_s"]
    _save("bench_decode_fused", {
        "seed_tok_per_s": runs[False]["tok_per_s"],
        "fused_tok_per_s": runs[True]["tok_per_s"],
        "speedup": speedup,
        "batch": int(prompts.shape[0]),
        "max_new": max_new,
    })
    _emit("bench_decode_fused", us,
          f"seed_tok_s={runs[False]['tok_per_s']:.0f};"
          f"fused_tok_s={runs[True]['tok_per_s']:.0f};speedup={speedup:.2f}x")


def bench_packed_decode():
    """Fused packed-MLP megakernel vs the 3-dispatch packed path, and
    whole-model packing vs MLP-only, on the decode hot loop (DESIGN.md §7).

    Three packed engines serve the same pruned smoke model: ``split3`` is
    the seed 3-dispatch MLP-only path (one Pallas call per MLP matrix, the
    (B, ff) intermediate round-trips between them), ``fused`` runs the
    megakernel (one call per layer), ``whole`` additionally packs qkv/o and
    the untied LM head.  Token streams must be identical to the dense
    engine; arms are interleaved best-of-N so machine noise hits them
    alike.  Also reports the packed/dense weight-byte ratios, the paper's
    actual currency."""
    import jax

    from repro.configs import get_smoke_config
    from repro.core.pruning import prune_tree
    from repro.models import build_model
    from repro.serve import Engine, ServeConfig
    from repro.serve.packed import packed_byte_ratios

    cfg = get_smoke_config("vusa_edge")
    params = prune_tree(build_model(cfg).init(jax.random.key(0)), 0.85)
    prompts = np.ones((2, 6), np.int32)
    max_new = 64
    engines = {
        "dense": Engine(cfg, params, ServeConfig(max_len=128)),
        "split3": Engine(
            cfg, params, ServeConfig(max_len=128, packed_weights="mlp", fused_mlp=False)
        ),
        "fused": Engine(cfg, params, ServeConfig(max_len=128, packed_weights="mlp")),
        "whole": Engine(cfg, params, ServeConfig(max_len=128, packed_weights="all")),
        "int8": Engine(
            cfg, params,
            ServeConfig(max_len=128, packed_weights="all", packed_values="int8"),
        ),
        "int4": Engine(
            cfg, params,
            ServeConfig(max_len=128, packed_weights="all", packed_values="int4"),
        ),
    }
    # tune the fused shape before the engines trace: apply_fused_mlp consults
    # the autotune cache at trace time, so the winner reaches the megakernel
    import jax.numpy as jnp
    from repro.kernels.ops import RowPackedLinear, autotune_fused_mlp

    mlp = engines["fused"]._packed["mlp"]

    def layer0(e):  # one layer of the stacked pack, job-padding included
        return RowPackedLinear(
            values=e["values"][0], positions=e["positions"][0],
            k=e["k"], c=e["c"], a=e["a"], m=e["m"],
        )

    k_blk = autotune_fused_mlp(
        jnp.ones((prompts.shape[0], cfg.d_model), jnp.float32),
        layer0(mlp["w_gate"]), layer0(mlp["w_up"]), layer0(mlp["w_down_t"]),
    )
    toks = {}
    for name, eng in engines.items():  # compile + parity check
        toks[name] = eng.generate(prompts, max_new=max_new)["tokens"]
        if name in ("int8", "int4"):
            continue  # quantized arms gate against the qdq oracle below
        assert (toks[name] == toks["dense"]).all(), f"{name} decode diverged from dense"
    # int8 correctness bar (DESIGN.md §10): greedy tokens bit-exact vs a
    # dense engine running on quantize-dequantize'd weights.  int4 is gated
    # on same-cache decode-step logits instead: the oracle *prefills* on qdq
    # weights while the packed engine prefills dense, so near-tie argmaxes
    # may flip a token without any kernel error.
    from repro.serve.packed import lm_decode_step_packed, qdq_lm_params

    oracle8 = Engine(cfg, qdq_lm_params(cfg, params, value_dtype="int8"),
                     ServeConfig(max_len=128))
    otoks = oracle8.generate(prompts, max_new=max_new)["tokens"]
    assert (toks["int8"] == otoks).all(), "int8 decode diverged from qdq-dense oracle"
    # same-cache decode step: prefill is dense in every packed arm, so one
    # prime supplies the shared cache; quantized logits must stay within the
    # quantization error of the bf16-pack logits and within accumulation
    # noise of their own qdq-dense step
    nxt, cache, _ = engines["whole"].prime(prompts, jax.random.key(0))
    step_logits = {}
    for name in ("whole", "int8", "int4"):
        lg, _ = lm_decode_step_packed(
            engines[name].params, engines[name]._packed, nxt, cache, cfg
        )
        step_logits[name] = np.asarray(lg, np.float32)
    for dt in ("int8", "int4"):
        qdq = qdq_lm_params(cfg, params, value_dtype=dt)
        lg, _ = engines["dense"].model.decode_step(qdq, nxt, cache)
        np.testing.assert_allclose(
            step_logits[dt], np.asarray(lg, np.float32), rtol=1e-4, atol=1e-4,
            err_msg=f"{dt} kernel dequant diverged from its qdq-dense step",
        )
        span = float(np.abs(step_logits["whole"]).max())
        err = float(np.abs(step_logits[dt] - step_logits["whole"]).max())
        assert err <= 0.35 * max(span, 1.0), (
            f"{dt} logits drifted {err:.3f} from bf16 pack (span {span:.3f})"
        )
    best = {n: 0.0 for n in engines}
    for _ in range(6):  # interleave trials so noise hits every arm alike
        for name, eng in engines.items():
            out = eng.generate(prompts, max_new=max_new)
            best[name] = max(best[name], out["tok_per_s"])
    fused_speedup = best["fused"] / best["split3"]
    whole_vs_mlp = best["whole"] / best["fused"]
    ratios = packed_byte_ratios(engines["whole"]._packed)
    qratios = {dt: packed_byte_ratios(engines[dt]._packed) for dt in ("int8", "int4")}
    # §10 HBM budget at 85% sparsity: quantized packs must beat these totals
    assert qratios["int8"]["total"] <= 0.18, qratios["int8"]
    assert qratios["int4"]["total"] <= 0.15, qratios["int4"]
    _save("bench_packed_decode", {
        "split3_tok_per_s": best["split3"],
        "fused_tok_per_s": best["fused"],
        "whole_tok_per_s": best["whole"],
        "dense_tok_per_s": best["dense"],
        "int8_tok_per_s": best["int8"],
        "int4_tok_per_s": best["int4"],
        "fused_speedup": fused_speedup,
        "whole_vs_mlp": whole_vs_mlp,
        "byte_ratio_total": ratios["total"],
        "byte_ratio_int8": qratios["int8"]["total"],
        "byte_ratio_int4": qratios["int4"]["total"],
        "byte_ratios": ratios,
        "fused_k_blk": k_blk,
        "batch": int(prompts.shape[0]),
        "max_new": max_new,
    })
    _emit("bench_packed_decode", 1e6 / max(best["fused"], 1e-9),
          f"split3_tok_s={best['split3']:.0f};fused_tok_s={best['fused']:.0f};"
          f"whole_tok_s={best['whole']:.0f};int8_tok_s={best['int8']:.0f};"
          f"int4_tok_s={best['int4']:.0f};fused_speedup={fused_speedup:.2f}x;"
          f"whole_vs_mlp={whole_vs_mlp:.2f}x;bytes={ratios['total']:.3f};"
          f"bytes_int8={qratios['int8']['total']:.3f};"
          f"bytes_int4={qratios['int4']['total']:.3f}")


def bench_spec_decode():
    """Self-speculative decoding via sparsity tiers (DESIGN.md §13):
    accepted-tokens/s at draft lengths k in {2, 4, 8} vs the non-speculative
    packed baseline, same weights, same 85%-sparsity verifier pack.

    The weights carry the tier structure the mechanism exploits — a dense
    core (top 1% of magnitudes), a detail tier (next 14%, scaled down), and
    zeros — so the 99%-sparsity drafter keeps exactly the core that drives
    most argmax decisions.  Every speculative arm's greedy tokens must be
    bit-identical to the baseline's (the accept rule guarantees it; the
    bench enforces it), so the only thing speculation can change is wall
    time.  Arms are interleaved best-of-N; tok/s is the unified accounting:
    accepted tokens / decode wall time."""
    import dataclasses

    import jax

    from repro.configs import get_smoke_config
    from repro.models import build_model
    from repro.serve import Engine, ServeConfig

    # the smoke config is too small for speculation to pay (drafter and
    # verifier dispatches cost the same at d_model=64) — widen it to where
    # the drafter's 99%-sparsity pack is genuinely cheaper per token
    cfg = dataclasses.replace(
        get_smoke_config("vusa_edge"),
        d_model=256, d_ff=1024, vocab=2048, n_heads=4, kv_heads=4,
    )

    def tiered(w):
        w = np.asarray(w)
        if w.ndim < 2:
            return w
        a = np.abs(w)
        srt = np.sort(a.ravel())[::-1]
        t1 = srt[max(int(0.01 * a.size) - 1, 0)]
        t2 = srt[max(int(0.15 * a.size) - 1, 0)]
        return np.where(a >= t1, w, np.where(a >= t2, w * 0.01, 0.0)).astype(w.dtype)

    import jax.tree_util as jtu

    params = jtu.tree_map(tiered, build_model(cfg).init(jax.random.key(0)))
    rng = np.random.default_rng(0)
    prompt = rng.integers(1, cfg.vocab, (1, 6)).astype(np.int32)  # spec = B=1
    max_new, ks = 64, (2, 4, 8)
    engines = {
        "base": Engine(cfg, params, ServeConfig(max_len=128, packed_weights="all")),
        **{
            f"k{k}": Engine(cfg, params, ServeConfig(
                max_len=128, packed_weights="all",
                speculative=True, draft_k=k, draft_sparsity=0.99,
            ))
            for k in ks
        },
    }
    outs = {}
    for name, eng in engines.items():  # compile + greedy parity check
        outs[name] = eng.generate(prompt, max_new=max_new)
        assert (outs[name]["tokens"] == outs["base"]["tokens"]).all(), (
            f"{name} speculative decode diverged from the non-speculative stream"
        )
    best = {n: 0.0 for n in engines}
    for _ in range(5):  # interleave trials so noise hits every arm alike
        for name, eng in engines.items():
            out = eng.generate(prompt, max_new=max_new)
            best[name] = max(best[name], out["tok_per_s"])
            outs[name] = out
    speedups = {k: best[f"k{k}"] / best["base"] for k in ks}
    acc = {k: outs[f"k{k}"]["acceptance_rate"] for k in ks}
    # the SLO the feature exists for: >= 1.3x accepted-tok/s at k=4 on
    # tier-structured weights (observed ~2.7x idle; 1.3 leaves co-tenant room)
    assert speedups[4] >= 1.3, (
        f"speculative k=4 speedup {speedups[4]:.2f}x below the 1.3x SLO "
        f"(acceptance {acc[4]:.2f})"
    )
    _save("bench_spec_decode", {
        "base_tok_per_s": best["base"],
        **{f"k{k}_tok_per_s": best[f"k{k}"] for k in ks},
        **{f"k{k}_speedup": speedups[k] for k in ks},
        **{f"k{k}_acceptance": float(acc[k]) for k in ks},
        "draft_sparsity": 0.99,
        "max_new": max_new,
    })
    _emit("bench_spec_decode", 1e6 / max(best["k4"], 1e-9),
          f"base_tok_s={best['base']:.0f};" +
          ";".join(f"k{k}_tok_s={best[f'k{k}']:.0f}" for k in ks) + ";" +
          ";".join(f"k{k}_speedup={speedups[k]:.2f}x" for k in ks) + ";" +
          f"k4_acc={acc[4]:.2f}")


def bench_continuous_batching():
    """Continuous-batching scheduler vs one-shot fused batches at equal slot
    count: 16 requests, ragged Poisson arrivals, ragged prompt lengths and
    budgets.  The one-shot baseline serves the same requests in FIFO batches
    of ``slots``, each batch padded to its longest budget (the padding waste
    continuous batching exists to recover).  Reports sustained useful tok/s,
    p50/p95 request latency and slot occupancy."""
    import jax

    from repro.configs import get_smoke_config
    from repro.models import build_model
    from repro.serve import Engine, Request, Scheduler, ServeConfig

    cfg = get_smoke_config("llama3_2_1b")
    params = build_model(cfg).init(jax.random.key(0))
    slots, segment, max_len = 4, 8, 160
    rng = np.random.default_rng(0)
    n_req = 24
    lens = [(4, 6, 8)[i % 3] for i in range(n_req)]
    prompts = [rng.integers(0, 100, n).astype(np.int32) for n in lens]
    # heavy-tailed budgets (most generations short, a few long) — the ragged
    # regime where one-shot batches burn the most padding
    budgets = np.minimum(4 + rng.geometric(1.0 / 24, n_req), 128)
    arrivals = np.cumsum(rng.exponential(0.0008, n_req))

    def requests(with_arrivals):
        return [
            Request(prompt=prompts[i], max_new=int(budgets[i]), seed=i,
                    arrival_s=float(arrivals[i]) if with_arrivals else 0.0)
            for i in range(n_req)
        ]

    def run_sched(sched):
        t0 = time.time()
        done = sched.run(requests(True))
        # stats() reports NaN percentiles when nothing completed (instead of
        # a fabricated 0.0 that reads as infinitely fast); the assert keeps
        # this bench from ever publishing numbers for such a hollow run
        assert len(done) == n_req, "scheduler lost requests"
        return sched.stats(), (time.time() - t0) * 1e6

    def run_baseline(eng):
        """FIFO batches of `slots`, padded to the batch max; busy time
        includes prefill, matching the scheduler's admit accounting."""
        busy_s, decoded = 0.0, 0
        for g in range(0, n_req, slots):
            idx = range(g, min(g + slots, n_req))
            batch = np.stack([
                np.pad(prompts[i], (0, max(lens[j] for j in idx) - lens[i]),
                       constant_values=1) for i in idx
            ])
            out = eng.generate(batch, max_new=int(max(budgets[i] for i in idx)))
            busy_s += out["decode_s"] + out["prefill_s"]
            decoded += sum(int(budgets[i]) - 1 for i in idx)
        return decoded / max(busy_s, 1e-9)

    sched = Scheduler(Engine(cfg, params, ServeConfig(max_len=max_len)),
                      slots=slots, segment=segment)
    eng = Engine(cfg, params, ServeConfig(max_len=max_len))
    sched.run(requests(False))  # warmup: compiles segment + per-length prefill
    run_baseline(eng)  # warmup: compiles each batch's step count
    # interleave trials so machine noise hits both systems alike
    stats, us, base_tok_s = None, 0.0, 0.0
    for _ in range(3):
        s, t = run_sched(sched)
        if stats is None or s["sustained_tok_per_s"] > stats["sustained_tok_per_s"]:
            stats, us = s, t
        base_tok_s = max(base_tok_s, run_baseline(eng))
    speedup = stats["sustained_tok_per_s"] / base_tok_s
    _save("bench_continuous_batching", {
        "sched_tok_per_s": stats["sustained_tok_per_s"],
        "oneshot_tok_per_s": base_tok_s,
        "speedup_vs_oneshot": speedup,
        "latency_p50_s": stats["latency_p50_s"],
        "latency_p95_s": stats["latency_p95_s"],
        "slot_occupancy": stats["slot_occupancy"],
        "requests": n_req,
        "slots": slots,
        "segment": segment,
        "decoded_tokens": stats["decoded_tokens"],
    })
    _emit("bench_continuous_batching", us,
          f"sched_tok_s={stats['sustained_tok_per_s']:.0f};"
          f"oneshot_tok_s={base_tok_s:.0f};speedup={speedup:.2f}x;"
          f"occ={stats['slot_occupancy']:.2f};"
          f"p50={stats['latency_p50_s'] * 1e3:.0f}ms;"
          f"p95={stats['latency_p95_s'] * 1e3:.0f}ms")


def bench_admission():
    """Bucketed batched admission vs per-request admission (DESIGN.md §6) on
    an admission-bound workload: many short ragged prompts (10 distinct
    lengths), out-of-order sub-ms arrivals, EOS-heavy early retirement.  The
    sequential arm primes one request per dispatch at its exact length; the
    batched arm coalesces each round's arrivals into one masked-prefill
    dispatch per length bucket + one multi-slot scatter.  Identical tokens
    required; sustained useful tok/s and prefill compile counts compared."""
    import jax

    from repro.configs import get_smoke_config
    from repro.models import build_model
    from repro.serve import Engine, Request, Scheduler, ServeConfig

    cfg = get_smoke_config("llama3_2_1b")
    params = build_model(cfg).init(jax.random.key(0))
    slots, segment, max_len = 4, 4, 64
    rng = np.random.default_rng(0)
    n_req = 32
    lens = [3 + i % 10 for i in range(n_req)]  # 10 distinct lengths, all short
    prompts = [rng.integers(0, 100, n).astype(np.int32) for n in lens]
    arrivals = rng.permutation(np.linspace(0, 0.002, n_req))  # out of submit order
    # EOS-heavy: a third of the requests stop early on a token they really emit
    ref = Engine(cfg, params, ServeConfig(max_len=max_len))  # greedy: seed unused
    eos_ids = {}
    for i in range(0, n_req, 3):
        eos_ids[i] = int(ref.generate(prompts[i][None], max_new=8)["tokens"][0, 2])

    def requests():
        return [
            Request(prompt=prompts[i], max_new=8, eos_id=eos_ids.get(i), seed=i,
                    arrival_s=float(arrivals[i]))
            for i in range(n_req)
        ]

    stats, scheds = {}, {}
    for mode in ("sequential", "batched"):
        sched = Scheduler(Engine(cfg, params, ServeConfig(max_len=max_len)),
                          slots=slots, segment=segment, admission=mode)
        scheds[mode] = sched
        done = sched.run(requests())  # warmup: compiles every program the mode needs
        tokens = {rid: c.tokens for rid, c in done.items()}
        best = None
        for _ in range(3):
            done = sched.run(requests())
            assert len(done) == n_req, "scheduler lost requests"
            s = sched.stats()
            if best is None or s["sustained_tok_per_s"] > best["sustained_tok_per_s"]:
                best = s
        stats[mode] = best
        stats[mode]["tokens"] = tokens
    for rid in range(n_req):  # batching must not change a single token
        np.testing.assert_array_equal(stats["batched"]["tokens"][rid],
                                      stats["sequential"]["tokens"][rid])
    b, s = stats["batched"], stats["sequential"]
    speedup = b["sustained_tok_per_s"] / s["sustained_tok_per_s"]
    compiles = {
        "batched": scheds["batched"].eng._prefill_masked._cache_size(),
        "sequential": scheds["sequential"].eng._prefill._cache_size(),
    }
    _save("bench_admission", {
        "batched_tok_per_s": b["sustained_tok_per_s"],
        "sequential_tok_per_s": s["sustained_tok_per_s"],
        "speedup_vs_sequential": speedup,
        "batched_admit_s": b["admit_s"],
        "sequential_admit_s": s["admit_s"],
        "prefill_compiles_batched": compiles["batched"],
        "prefill_compiles_sequential": compiles["sequential"],
        "requests": n_req,
        "slots": slots,
        "segment": segment,
    })
    _emit("bench_admission", b["admit_s"] * 1e6,
          f"batched_tok_s={b['sustained_tok_per_s']:.0f};"
          f"sequential_tok_s={s['sustained_tok_per_s']:.0f};"
          f"speedup={speedup:.2f}x;"
          f"compiles={compiles['batched']}vs{compiles['sequential']};"
          f"admit_s={b['admit_s']:.3f}vs{s['admit_s']:.3f}")


def bench_faults():
    """Goodput under faults (DESIGN.md §9): the same request stream served
    clean and with a seeded ~1% request-fault rate (slot-cache NaN poisoning
    — the guard + bounded dense-retry path).  Every faulted-arm completion
    must be OK or FAILED_FALLBACK_OK with tokens bit-identical to the clean
    arm, and sustained delivered tok/s must hold >= 90% of the clean run:
    recovery costs one re-prime + re-decode of the afflicted request, never
    a stall of the pool."""
    import jax

    from repro.configs import get_smoke_config
    from repro.models import build_model
    from repro.serve import Engine, FaultConfig, Request, Scheduler, ServeConfig, Status

    cfg = get_smoke_config("llama3_2_1b")
    params = build_model(cfg).init(jax.random.key(0))
    slots, segment, max_len = 4, 4, 64
    n_req = 64
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, 100, 6).astype(np.int32) for _ in range(n_req)]

    def requests():
        return [
            Request(prompt=prompts[i], max_new=24, seed=i) for i in range(n_req)
        ]

    # rids keep incrementing across the warmup + 3 timed runs (the scheduler
    # is reused to keep its compiled programs); seed 17 at this rate faults
    # exactly one rid in every 64-rid block, so each run serves a ~1%
    # request-fault rate
    arms = {"clean": None, "faulted": FaultConfig(seed=17, cache_nan_rate=0.013)}
    stats, tokens = {}, {}
    for arm, faults in arms.items():
        sched = Scheduler(
            Engine(cfg, params, ServeConfig(max_len=max_len, faults=faults)),
            slots=slots, segment=segment,
        )
        done = sched.run(requests())  # warmup: compiles + first fault/retry
        best = None
        for _ in range(3):
            done = sched.run(requests())
            assert len(done) == n_req, "scheduler lost requests"
            s = sched.stats()
            if best is None or s["sustained_tok_per_s"] > best["sustained_tok_per_s"]:
                best = s
        stats[arm] = best
        # rids run on across runs; rid % n_req recovers the prompt index
        tokens[arm] = {rid % n_req: c.tokens for rid, c in done.items()}
        for rid, c in done.items():
            assert c.status in (Status.OK, Status.FAILED_FALLBACK_OK), (
                f"{arm}: rid {rid} finished {c.status}"
            )
    n_fallback = stats["faulted"]["fallback"]
    assert n_fallback >= 1, "fault plan injected nothing — bench is vacuous"
    for rid in range(n_req):  # faults must never corrupt delivered tokens
        np.testing.assert_array_equal(tokens["faulted"][rid], tokens["clean"][rid])
    ratio = (
        stats["faulted"]["sustained_tok_per_s"] / stats["clean"]["sustained_tok_per_s"]
    )
    assert ratio >= 0.9, f"goodput under faults collapsed: {ratio:.2f}x of clean"
    _save("bench_faults", {
        "clean_tok_per_s": stats["clean"]["sustained_tok_per_s"],
        "faulted_tok_per_s": stats["faulted"]["sustained_tok_per_s"],
        "goodput_ratio": ratio,
        "fallback_requests": n_fallback,
        "requests": n_req,
        "slots": slots,
        "segment": segment,
    })
    _emit("bench_faults", stats["faulted"]["decode_s"] * 1e6,
          f"clean_tok_s={stats['clean']['sustained_tok_per_s']:.0f};"
          f"faulted_tok_s={stats['faulted']['sustained_tok_per_s']:.0f};"
          f"goodput={ratio:.3f};fallbacks={n_fallback}")


def bench_paged_serving():
    """Paged KV pool vs the slot pool (DESIGN.md §11) on mixed traffic: long
    prompts with short generation budgets interleaved with short chatty
    requests — the regime where the slot pool's max_len-per-slot reservation
    burns the most HBM.  Both pools must emit bit-identical tokens; gated are
    paged sustained tok/s (conservative floor) and the time-averaged
    HBM-bytes-per-active-request reduction, which must hold >= 2x (asserted
    here too, so a lazy-allocation regression fails the bench before the
    gate).  A repeat pass of the same prompts measures the prefix-cache hit
    rate and COW splits (deterministic, reported not gated)."""
    import jax

    from repro.configs import get_smoke_config
    from repro.models import build_model
    from repro.serve import Engine, Request, Scheduler, ServeConfig

    cfg = get_smoke_config("llama3_2_1b")
    params = build_model(cfg).init(jax.random.key(0))
    slots, segment, max_len, page = 4, 8, 256, 16
    n_req = 16
    rng = np.random.default_rng(0)
    # even rids: long document prompts, short budgets; odd rids: short chat
    # prompts, longer budgets — nobody comes close to max_len rows
    lens = [int(rng.integers(64, 121)) if i % 2 == 0 else int(rng.integers(4, 9))
            for i in range(n_req)]
    budgets = [int(rng.integers(8, 17)) if i % 2 == 0 else int(rng.integers(16, 33))
               for i in range(n_req)]
    prompts = [rng.integers(1, 100, n).astype(np.int32) for n in lens]

    def requests():
        return [Request(prompt=prompts[i], max_new=budgets[i], seed=i)
                for i in range(n_req)]

    arms = {
        "slot": ServeConfig(max_len=max_len),
        "paged": ServeConfig(max_len=max_len, page_size=page),
    }
    stats, tokens, scheds = {}, {}, {}
    for arm, sc in arms.items():
        sched = Scheduler(Engine(cfg, params, sc), slots=slots, segment=segment)
        scheds[arm] = sched
        done = sched.run(requests())  # warmup: compiles segment + prefills
        tokens[arm] = {rid % n_req: c.tokens for rid, c in done.items()}
        best = None
        for _ in range(3):
            done = sched.run(requests())
            assert len(done) == n_req, "scheduler lost requests"
            s = sched.stats()
            if best is None or s["sustained_tok_per_s"] > best["sustained_tok_per_s"]:
                best = s
        stats[arm] = best
    for rid in range(n_req):  # paging must not change a single token
        np.testing.assert_array_equal(tokens["paged"][rid], tokens["slot"][rid])
    hbm_slot = stats["slot"]["hbm_bytes_per_active_request"]
    hbm_paged = stats["paged"]["hbm_bytes_per_active_request"]
    reduction = hbm_slot / hbm_paged
    assert reduction >= 2.0, (
        f"paged pool only cut HBM/request {reduction:.2f}x (< 2x): lazy "
        "allocation is broken or the traffic mix degenerated"
    )
    # repeat pass: identical prompts → prefix hits skip re-prefill entirely
    done = scheds["paged"].run(requests())
    assert len(done) == n_req
    rs = scheds["paged"].stats()
    _save("bench_paged_serving", {
        "paged_tok_per_s": stats["paged"]["sustained_tok_per_s"],
        "slot_tok_per_s": stats["slot"]["sustained_tok_per_s"],
        "hbm_bytes_per_req_paged": hbm_paged,
        "hbm_bytes_per_req_slot": hbm_slot,
        "hbm_reduction_vs_slot": reduction,
        "prefix_hit_rate_repeat": rs["prefix_hit_rate"],
        "cow_copies_repeat": rs["cow_copies"],
        "arena_bytes": stats["paged"]["kv_pool_bytes"],
        "block_bytes": stats["paged"]["kv_block_bytes"],
        "requests": n_req,
        "slots": slots,
        "segment": segment,
        "page_size": page,
    })
    _emit("bench_paged_serving", stats["paged"]["decode_s"] * 1e6,
          f"paged_tok_s={stats['paged']['sustained_tok_per_s']:.0f};"
          f"slot_tok_s={stats['slot']['sustained_tok_per_s']:.0f};"
          f"hbm_per_req={hbm_paged / 2**10:.0f}KiBvs{hbm_slot / 2**10:.0f}KiB;"
          f"reduction={reduction:.2f}x;"
          f"repeat_hit_rate={rs['prefix_hit_rate']:.2f}")


def bench_streaming():
    """Async streaming serving (DESIGN.md §12): delivered tok/s through the
    AsyncEngine's per-sync token streams, goodput under seeded transient
    decode stalls with the watchdog armed (must hold >= 0.9x of the clean
    arm — asserted here and gated), and crash recovery: a journaled run
    killed mid-stream must recover to completions bit-identical to the
    clean arm (asserted; replay wall time reported, compile included).

    The scheduler is reused across reps to keep its compiled programs, so
    each rep pins a fresh rid block (rids never reuse) — the transient-stall
    injectors are one-shot per rid and must fire in the *timed* rep, not be
    used up by the warmup."""
    import asyncio
    import tempfile

    import jax

    from repro.configs import get_smoke_config
    from repro.models import build_model
    from repro.serve import (
        AsyncEngine,
        Engine,
        FaultConfig,
        Journal,
        JournalTap,
        Request,
        Scheduler,
        ServeConfig,
        Status,
    )
    from repro.serve.journal import recover_into, replay

    cfg = get_smoke_config("llama3_2_1b")
    params = build_model(cfg).init(jax.random.key(0))
    slots, segment, max_len = 4, 8, 64
    n_req, max_new = 96, 48
    rng = np.random.default_rng(0)
    prompts = [rng.integers(1, 100, 6).astype(np.int32) for _ in range(n_req)]

    def requests():
        return [
            Request(prompt=prompts[i], max_new=max_new, seed=i) for i in range(n_req)
        ]

    async def serve(engine, rid0):
        """Submit one rid block and stream every token back; returns
        ({index: tokens}, delivered token count, wall seconds)."""
        t0 = time.perf_counter()
        streams = [engine.submit(r, rid=rid0 + i) for i, r in enumerate(requests())]
        outs, total = {}, 0
        for i, s in enumerate(streams):
            toks = [t async for t in s]
            comp = await s.completion()
            assert comp.status is Status.OK, f"rid {comp.rid} finished {comp.status}"
            assert toks == [int(t) for t in comp.tokens]
            outs[i] = toks
            total += len(toks)
        return outs, total, time.perf_counter() - t0

    def stall_plan(rid0):
        # three deterministic one-shot 2 ms stalls per rep — a transient
        # wedge the pool must absorb, sized a few percent of the clean wall
        # so >= 0.9x goodput is headroom, not luck
        return FaultConfig(
            decode_stall_s=0.002,
            decode_stall_rids=(rid0 + 5, rid0 + 23, rid0 + 41),
        )

    arms = ("clean", "stalled")
    engines = {
        arm: Engine(cfg, params, ServeConfig(max_len=max_len)) for arm in arms
    }
    scheds = {
        arm: Scheduler(engines[arm], slots=slots, segment=segment) for arm in arms
    }
    tokens, best = {}, {}
    rid0 = 0

    async def one_rep(arm):
        nonlocal rid0
        block, rid0 = rid0, rid0 + n_req
        if arm == "stalled":
            engines[arm].sc.faults = stall_plan(block)
        engine = AsyncEngine(
            scheds[arm], watchdog_s=None if arm == "clean" else 10.0
        )
        async with engine:
            outs, total, wall = await serve(engine, block)
        assert total == n_req * max_new
        if arm == "stalled":
            fired = [r for r in stall_plan(block).decode_stall_rids
                     if r in scheds[arm]._stall_fired]
            assert len(fired) == 3, "stall plan injected nothing"
        return outs, total / wall

    for arm in arms:  # warmup rep per arm (compiles) — untimed
        tokens[arm], _ = asyncio.run(one_rep(arm))
    # interleave the timed reps so host noise (GC pauses, scheduler jitter a
    # few hundred ms wide on shared runners) hits both arms alike; best-of-4
    # per arm makes the ratio a property of the stalls, not the noise
    for _ in range(4):
        for arm in arms:
            tokens[arm], rate = asyncio.run(one_rep(arm))
            best[arm] = max(best.get(arm, 0.0), rate)
    for i in range(n_req):  # stalls delay tokens, never change them
        np.testing.assert_array_equal(tokens["stalled"][i], tokens["clean"][i])
    goodput = best["stalled"] / best["clean"]
    assert goodput >= 0.9, f"goodput under stalls collapsed: {goodput:.2f}x of clean"

    # crash + recover differential: journal a run, kill it mid-stream (the
    # exception fires before the sync's tap, so everything past the last
    # fsync is lost), recover into a fresh scheduler, require bit-parity
    class _Boom(Exception):
        pass

    with tempfile.TemporaryDirectory() as td:
        path = Path(td) / "journal"
        journal = Journal(path)
        tap = JournalTap(journal)
        sched = scheds["clean"]  # warm programs; crashed state is discarded
        for i, r in enumerate(requests()):
            tap.note_submit(sched.submit(r, rid=rid0 + i), r)
        journal.sync()
        syncs = 0

        def crash(s):
            nonlocal syncs
            syncs += 1
            if syncs > 3:
                raise _Boom()
            tap.on_sync(s)

        try:
            sched.run(on_sync=crash)
            raise AssertionError("crash hook never fired")
        except _Boom:
            pass
        journal._fh.close()  # no close marker: the journal reads as a crash
        t0 = time.perf_counter()
        sched2 = Scheduler(engines["clean"], slots=slots, segment=segment)
        journal2, completed, recovered = recover_into(path, sched2)
        tap2 = JournalTap(journal2)
        done = sched2.run(on_sync=tap2.on_sync)
        tap2.on_sync(sched2)
        journal2.close()
        recovery_wall = time.perf_counter() - t0
        assert recovered, "crash landed after the run finished — nothing recovered"
        merged = {**completed, **done}
        for i in range(n_req):
            np.testing.assert_array_equal(
                merged[rid0 + i].tokens, tokens["clean"][i]
            )
        final = replay(path)
        assert final.closed and not final.pending

    _save("bench_streaming", {
        "stream_tok_per_s": best["clean"],
        "stalled_tok_per_s": best["stalled"],
        "stall_goodput": goodput,
        "recovered_requests": len(recovered),
        "journal_completions": len(completed),
        "recovery_wall_s": recovery_wall,
        "requests": n_req,
        "max_new": max_new,
        "slots": slots,
        "segment": segment,
    })
    _emit("bench_streaming", (n_req * max_new / best["clean"]) * 1e6,
          f"stream_tok_s={best['clean']:.0f};stalled_tok_s={best['stalled']:.0f};"
          f"goodput={goodput:.3f};recovered={len(recovered)};"
          f"recovery_s={recovery_wall:.2f}")


_SHARDED_BENCH_CODE = """
import json, time
import jax, numpy as np
from repro.configs import get_smoke_config
from repro.core.pruning import prune_tree
from repro.models import build_model
from repro.serve import Engine, ServeConfig
from repro.launch.mesh import make_serve_mesh

cfg = get_smoke_config("vusa_edge")
params = prune_tree(build_model(cfg).init(jax.random.key(0)), 0.85)
prompts = np.ones((4, 6), np.int32)
max_new = 48
sc = dict(max_len=64, packed_weights="all", vusa_m=32, vusa_a=8)
engines = {
    "single": Engine(cfg, params, ServeConfig(**sc)),
    "dp": Engine(cfg, params, ServeConfig(**sc), mesh=make_serve_mesh("2,1")),
    "tp": Engine(cfg, params, ServeConfig(**sc), mesh=make_serve_mesh("1,2")),
    "dp_tp": Engine(cfg, params, ServeConfig(**sc), mesh=make_serve_mesh("2,4")),
}
toks = {}
for name, eng in engines.items():  # compile + parity check
    toks[name] = eng.generate(prompts, max_new=max_new)["tokens"]
    assert (toks[name] == toks["single"]).all(), name + " decode diverged from single-device"
best = {n: 0.0 for n in engines}
for _ in range(4):  # interleave trials so noise hits every arm alike
    for name, eng in engines.items():
        best[name] = max(best[name], eng.generate(prompts, max_new=max_new)["tok_per_s"])
print("RESULT " + json.dumps(best))
"""


def bench_sharded_decode():
    """Mesh-sharded whole-model packed decode vs the single-device engine on
    a forced 8-device CPU backend (DESIGN.md §8): 2x1 (DP), 1x2 (TP) and 2x4
    meshes must emit bit-identical tokens, throughput reported per arm.

    Runs in a subprocess with its own XLA_FLAGS: the device count is fixed at
    backend init, and forcing 8 host devices on the *parent* process would
    perturb every other bench's numbers (they share the committed baselines).
    On virtual CPU devices the collectives are pure overhead — the gated
    floor guards the sharded path *working and not collapsing*, the real
    speedup story needs real chips."""
    import os
    import subprocess
    import sys
    from pathlib import Path as _P

    t0 = time.time()
    env = dict(os.environ)
    env["XLA_FLAGS"] = (
        env.get("XLA_FLAGS", "") + " --xla_force_host_platform_device_count=8"
    ).strip()
    env["JAX_PLATFORMS"] = "cpu"
    env["PYTHONPATH"] = "src" + os.pathsep + env.get("PYTHONPATH", "")
    out = subprocess.run(
        [sys.executable, "-c", _SHARDED_BENCH_CODE],
        capture_output=True, text=True, timeout=1200, env=env,
        cwd=str(_P(__file__).resolve().parent.parent),
    )
    assert out.returncode == 0, out.stderr[-3000:]
    line = [ln for ln in out.stdout.splitlines() if ln.startswith("RESULT ")][-1]
    best = json.loads(line[len("RESULT "):])
    us = (time.time() - t0) * 1e6
    table = {
        "single_tok_per_s": best["single"],
        "dp_tok_per_s": best["dp"],
        "tp_tok_per_s": best["tp"],
        "dp_tp_tok_per_s": best["dp_tp"],
        "tp_vs_single": best["tp"] / max(best["single"], 1e-9),
        "devices": 8,
        "meshes": ["2,1", "1,2", "2,4"],
    }
    _save("bench_sharded_decode", table)
    _emit("bench_sharded_decode", us,
          f"single_tok_s={best['single']:.0f};dp_tok_s={best['dp']:.0f};"
          f"tp_tok_s={best['tp']:.0f};dp_tp_tok_s={best['dp_tp']:.0f};"
          "parity=identical")


def bench_scheduler():
    from repro.core.vusa import schedule_widths_fast

    rng = np.random.default_rng(0)
    mask = rng.random((4608, 512)) > 0.85
    t0 = time.time()
    hist, jobs = schedule_widths_fast(mask, *VUSA)
    us = (time.time() - t0) * 1e6
    cols_per_s = mask.size / (us / 1e6)
    _emit("bench_scheduler", us, f"elements_per_s={cols_per_s:.3g};jobs={sum(jobs)}")


def bench_train_decode():
    import jax
    import jax.numpy as jnp

    from repro.configs import get_smoke_config
    from repro.models import build_model
    from repro.optim import adamw_init
    from repro.train.step import TrainHParams, make_train_step

    cfg = get_smoke_config("llama3_2_1b")
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    batch = {"tokens": jnp.ones((4, 64), jnp.int32)}
    step = jax.jit(make_train_step(model.loss, TrainHParams()))
    opt = adamw_init(params)
    params2, opt2, m = step(params, opt, batch)
    jax.block_until_ready(m["loss"])
    t0 = time.time()
    for _ in range(5):
        params2, opt2, m = step(params2, opt2, batch)
        jax.block_until_ready(m["loss"])
    tt = (time.time() - t0) / 5 * 1e6

    cache = model.init_cache(4, 128)
    dec = jax.jit(model.decode_step)
    tok = jnp.ones((4, 1), jnp.int32)
    _, cache = dec(params, tok, cache)
    t0 = time.time()
    for _ in range(20):
        logits, cache = dec(params, tok, cache)
    jax.block_until_ready(logits)
    td = (time.time() - t0) / 20 * 1e6
    _emit("bench_train_step", tt, "smoke llama 4x64")
    _emit("bench_decode_step", td, "smoke llama batch4")


def table_lm_vusa():
    """Beyond-paper: the paper's Table-II methodology applied to the LM we
    actually trained to 85% sparsity (examples/train_sparse_lm.py) — VUSA
    efficiency on transformer GEMMs instead of CNN im2col GEMMs."""
    import numpy as np
    from pathlib import Path

    from repro.checkpoint import latest_step, restore
    from repro.configs import get_config
    from repro.core.simulator import Gemm
    from repro.models import build_model

    ck = Path("experiments/train_run/ckpt")
    step = latest_step(ck) if ck.exists() else None
    t0 = time.time()
    cfg = get_config("vusa_edge")
    if step is None:  # no trained run available: prune random init instead
        import jax
        from repro.core.pruning import prune_tree

        params = prune_tree(build_model(cfg).init(jax.random.key(0)), cfg.sparsity)
        src = "random-init pruned"
    else:
        import jax

        model = build_model(cfg)
        like = {"params": model.init(jax.random.key(0))}
        params = restore(ck, step, like)["params"]
        src = f"trained ckpt step {step}"

    # every pruned matmul becomes a GEMM job streamed over the batch dim
    gemms, masks = [], []
    seq = 64
    layers = params["layers"]
    for name in ("w_gate", "w_up", "w_down"):
        w = np.asarray(layers["ffn"][name])
        for l in range(cfg.n_layers):
            gemms.append(Gemm(B=seq, K=w.shape[1], C=int(np.prod(w.shape[2:])), name=f"{name}{l}"))
            masks.append(np.asarray(w[l]).reshape(w.shape[1], -1) != 0)
    table = _evaluate_model(gemms, masks, "vusa_edge_lm")
    us = (time.time() - t0) * 1e6
    _save("table_lm_vusa", {**table, "weights": src})
    v = table["vusa_3x6"]
    _emit("table_lm_vusa", us,
          f"src={src.replace(' ', '_')};pp_area={v['perf_per_area']:.2f};"
          f"pp_power={v['perf_per_power']:.2f};energy={v['energy']:.2f};"
          f"load6={v['load_split'][6]:.3f}")


# ---------------------------------------------------------------------------


BENCHES = {
    "fig6_growth": fig6_growth,
    "table1_area_power": table1_area_power,
    "table2_resnet18": table2_resnet18,
    "table3_mobilenet": table3_mobilenet,
    "fig89_pruning_sweep": fig89_pruning_sweep,
    "table_lm_vusa": table_lm_vusa,
    "kernel_vusa_packed": kernel_vusa_packed,
    "bench_scheduler": bench_scheduler,
    "bench_train_decode": bench_train_decode,
    "bench_decode_fused": bench_decode_fused,
    "bench_packed_decode": bench_packed_decode,
    "bench_spec_decode": bench_spec_decode,
    "bench_continuous_batching": bench_continuous_batching,
    "bench_admission": bench_admission,
    "bench_faults": bench_faults,
    "bench_paged_serving": bench_paged_serving,
    "bench_streaming": bench_streaming,
    "bench_sharded_decode": bench_sharded_decode,
}

# Metrics protected by the CI regression gate.  All are higher-is-better;
# "/" indexes into the bench's saved JSON table.  Throughput baselines are
# machine-relative — regenerate with --write-baseline when the runner class
# changes (CI uploads the fresh JSON as an artifact for exactly that).  In
# the committed BENCH_BASELINE.json, high-variance entries (absolute tok/s,
# and the fused-vs-seed speedup whose host-loop arm is dispatch-bound)
# record a conservative noise floor (~0.85x of a best-of-N measurement) so
# run-to-run variance does not trip the gate while a real perf loss still
# does; the interleaved ratios (speedup_vs_oneshot, kernel_speedup) are
# stable and committed as measured.  Both bench_admission entries are such
# floors (its sequential arm is dispatch-bound and the noisiest measurement
# here): a structural loss of admission batching still lands well below
# them, while scheduler-level jitter does not.  bench_packed_decode's three
# entries are likewise conservative floors of idle best-of-N measurements
# (fused_speedup observed 1.26-1.50x idle, committed 1.25, gate floor
# 0.94 at the CI-wide 0.25 tolerance): the floor catches an *inversion* —
# the megakernel running slower than the 3-dispatch path it replaces —
# while co-tenant noise (observed down to 1.11 under load) does not trip
# it; a mere loss of the fused advantage to ~1.0x needs the idle-machine
# bench run, not CI, to show up.
BASELINE_METRICS = {
    "bench_decode_fused": ["fused_tok_per_s", "speedup"],
    "kernel_vusa_packed": ["sparsity_0.85/kernel_speedup"],
    # the quantized arms' tok/s floors sit beside the bf16 whole-model floor:
    # fused dequant must not cost the packed path its throughput (correctness
    # and byte ratios are asserted inside the bench itself)
    "bench_packed_decode": [
        "fused_tok_per_s", "fused_speedup", "whole_tok_per_s",
        "int8_tok_per_s", "int4_tok_per_s",
    ],
    # self-speculative decoding (§13): the k=4 speedup baseline holds the
    # 1.3x SLO the bench itself asserts (observed ~2.7x idle), so the gate
    # also sees the speculative advantage collapsing; the tok/s entry is a
    # conservative machine-relative floor like the other throughput gates
    "bench_spec_decode": ["k4_speedup", "k4_tok_per_s"],
    "bench_continuous_batching": ["sched_tok_per_s", "speedup_vs_oneshot"],
    "bench_admission": ["batched_tok_per_s", "speedup_vs_sequential"],
    # sharded decode on 8 forced CPU devices: collectives are pure overhead
    # there, so the gate holds a conservative tok/s floor per mesh arm (DP,
    # TP, and DP x TP) — it catches the sharded path breaking or collapsing
    # (e.g. an accidental all-gather of the weights per step), not CPU
    # "speedups"
    "bench_sharded_decode": ["dp_tok_per_s", "tp_tok_per_s", "dp_tp_tok_per_s"],
    # goodput under a ~1% seeded request-fault rate: the ratio is the SLO
    # (>= 0.9 asserted in-bench; the committed baseline holds 0.9 so the
    # gate also sees a drop), faulted_tok_per_s is a conservative floor
    "bench_faults": ["goodput_ratio", "faulted_tok_per_s"],
    # paged pool (§11): tok/s is a conservative floor; the HBM-per-request
    # reduction is a deterministic allocation ratio (no timing in it) — the
    # committed baseline holds the 2.0 SLO the bench itself asserts, so the
    # gate also sees lazy allocation regressing
    "bench_paged_serving": ["paged_tok_per_s", "hbm_reduction_vs_slot"],
    # async streaming (§12): delivered tok/s is a conservative floor; the
    # stall-goodput ratio is the SLO (>= 0.9 asserted in-bench, and the
    # committed baseline holds 0.9 so the gate also sees a drop)
    "bench_streaming": ["stream_tok_per_s", "stall_goodput"],
}


def _lookup(table, path: str):
    for part in path.split("/"):
        table = table[part]
    return float(table)


def write_baseline(path: str) -> None:
    """Snapshot the gated metrics of the benches that just ran."""
    base = {
        name: {m: _lookup(RESULTS[name], m) for m in metrics}
        for name, metrics in BASELINE_METRICS.items()
        if name in RESULTS
    }
    Path(path).write_text(json.dumps(base, indent=1) + "\n")
    print(f"wrote baseline for {list(base)} to {path}")


GATE_ROWS = []  # (bench, metric, baseline, fresh, status) — for --summary-md


def check_against(path: str, tolerance: float) -> bool:
    """Compare the benches that just ran against a committed baseline.
    A metric regresses when fresh < baseline * (1 - tolerance).  Returns
    True when everything held."""
    base = json.loads(Path(path).read_text())
    ok = True
    GATE_ROWS.clear()
    for name, metrics in base.items():
        if name not in RESULTS:
            # a gated bench that silently stops running is itself a
            # regression — the gate must not go green while blind
            print(f"gate: {name} MISSING (baseline-gated but not run)")
            GATE_ROWS.append((name, "*", None, None, "MISSING"))
            ok = False
            continue
        for metric, ref in metrics.items():
            try:
                fresh = _lookup(RESULTS[name], metric)
            except (KeyError, TypeError):
                # the bench ran but no longer reports a gated metric — name
                # it instead of crashing (or silently passing): a metric the
                # baseline protects must exist in every fresh run
                print(f"gate: {name}.{metric} MISSING (gated metric absent "
                      f"from the fresh {name} results)")
                GATE_ROWS.append((name, metric, ref, None, "MISSING"))
                ok = False
                continue
            floor = ref * (1.0 - tolerance)
            status = "ok" if fresh >= floor else "REGRESSION"
            if fresh < floor:
                ok = False
            print(f"gate: {name}.{metric} = {fresh:.3f} vs baseline {ref:.3f}"
                  f" (floor {floor:.3f}) {status}")
            GATE_ROWS.append((name, metric, ref, fresh, status))
    # inverse check: every declared gated metric of a bench that ran must be
    # in the baseline file, else newly added metrics silently go unprotected
    for name, metrics in BASELINE_METRICS.items():
        if name not in RESULTS:
            continue
        if name not in base:
            print(f"gate: {name} UNGATED (ran, declared in BASELINE_METRICS, "
                  f"but absent from {path} — regenerate with --write-baseline)")
            GATE_ROWS.append((name, "*", None, None, "UNGATED"))
            ok = False
            continue
        for metric in metrics:
            if metric not in base[name]:
                print(f"gate: {name}.{metric} UNGATED (declared in "
                      f"BASELINE_METRICS but absent from {path} — "
                      f"regenerate with --write-baseline)")
                GATE_ROWS.append((name, metric, None, None, "UNGATED"))
                ok = False
    return ok


def write_summary_md(path: str) -> None:
    """Render the gate comparison as a GitHub-flavored markdown table —
    CI cats this into ``$GITHUB_STEP_SUMMARY`` so the fresh-vs-baseline
    numbers are readable on the job page without digging through logs."""

    def fmt(v):
        return "—" if v is None else f"{v:.3f}"

    lines = [
        "### Bench gate: fresh vs committed baseline",
        "",
        "| bench | metric | baseline | fresh | delta | status |",
        "|---|---|---:|---:|---:|---|",
    ]
    for name, metric, ref, fresh, status in GATE_ROWS:
        delta = (
            f"{(fresh - ref) / ref * 100:+.1f}%"
            if ref not in (None, 0) and fresh is not None else "—"
        )
        mark = {"ok": "✅", "REGRESSION": "❌", "MISSING": "❌", "UNGATED": "❌"}[status]
        lines.append(
            f"| {name} | {metric} | {fmt(ref)} | {fmt(fresh)} | {delta} | {mark} {status} |"
        )
    if not GATE_ROWS:
        lines.append("| _no gated benches ran_ | | | | | |")
    Path(path).write_text("\n".join(lines) + "\n")
    print(f"wrote gate summary to {path}")


def main(argv=None) -> None:
    """Run all benchmarks, or only the ones named on the command line
    (``python benchmarks/run.py kernel_vusa_packed bench_decode_fused``).
    ``--check-against BENCH_BASELINE.json --tolerance 0.25`` turns the run
    into a regression gate; ``--write-baseline`` refreshes the snapshot."""
    import argparse
    import sys

    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("names", nargs="*",
                    help="benchmarks to run (default: all)")
    ap.add_argument("--check-against", metavar="FILE",
                    help="fail if gated metrics regress vs this baseline JSON")
    ap.add_argument("--tolerance", type=float, default=0.25,
                    help="allowed fractional regression (default 0.25)")
    ap.add_argument("--write-baseline", metavar="FILE",
                    help="write a fresh baseline JSON after the run")
    ap.add_argument("--summary-md", metavar="FILE",
                    help="with --check-against: also write the gate table as "
                    "markdown (for $GITHUB_STEP_SUMMARY)")
    args = ap.parse_args(argv)
    names = args.names or list(BENCHES)
    unknown = [n for n in names if n not in BENCHES]
    if unknown:
        ap.error(f"unknown benchmarks {unknown}; known: {list(BENCHES)}")
    print("name,us_per_call,derived")
    for n in names:
        BENCHES[n]()
    if args.write_baseline:
        write_baseline(args.write_baseline)
    if args.check_against:
        held = check_against(args.check_against, args.tolerance)
        if args.summary_md:
            # write the table even on failure — the job summary is most
            # valuable exactly when the gate trips
            write_summary_md(args.summary_md)
        if not held:
            sys.exit(1)


if __name__ == "__main__":
    main()
