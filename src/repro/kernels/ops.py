"""Jit'd public wrappers around the Pallas kernels.

* auto-selects interpret mode off-TPU (this container is CPU-only);
* hosts the pack/apply glue so a model layer can swap a dense matmul for a
  VUSA-packed one in a single call.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from ..core.packing import BlockPacked, pack_blocks
from .dense_matmul import dense_matmul
from .ref import vusa_spmm_ref
from .vusa_spmm import vusa_spmm

__all__ = [
    "on_tpu",
    "PackedLinear",
    "pack_linear",
    "apply_packed",
    "apply_packed_ref",
    "matmul",
    "RowPackedLinear",
    "pack_linear_rows",
    "pack_linear_rows_t",
    "apply_row_packed",
    "apply_row_packed_ref",
    "choose_k_blk",
    "autotune_row_packed",
    "apply_fused_mlp",
    "apply_fused_mlp_ref",
    "autotune_fused_mlp",
    "shard_linear_windows",
    "mesh_axis_size",
    "apply_row_packed_sharded",
    "apply_fused_mlp_sharded",
]


def on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@dataclasses.dataclass
class PackedLinear:
    """Device-resident VUSA-packed weight (K, C) -> jobs of a_blk rows."""

    values: jax.Array  # (T, J, A, Tn)
    row_idx: jax.Array  # (T, J, A) int32
    k: int  # logical K (pre-padding)
    c: int  # logical C (pre-padding)
    k_padded: int = 0

    @property
    def compression(self) -> float:
        dense = self.k * self.c * self.values.dtype.itemsize
        packed = self.values.size * self.values.dtype.itemsize + self.row_idx.size * 4
        return packed / dense


def pack_linear(
    w: np.ndarray, m_blk: int = 32, a_blk: int = 8, tile_n: int = 128
) -> PackedLinear:
    """Host-side pack of a sparse (K, C) weight matrix (pads C to tile_n)."""
    k, c = w.shape
    w = np.asarray(w)
    c_pad = (-c) % tile_n
    k_pad = (-k) % m_blk
    if c_pad or k_pad:
        w = np.pad(w, ((0, k_pad), (0, c_pad)))
    bp: BlockPacked = pack_blocks(w, m_blk=m_blk, a_blk=a_blk, tile_n=tile_n)
    return PackedLinear(
        values=jnp.asarray(bp.values),
        row_idx=jnp.asarray(bp.row_idx),
        k=k,
        c=c,
        k_padded=k + k_pad,
    )


def apply_packed(x: jax.Array, p: PackedLinear, *, interpret: bool | None = None) -> jax.Array:
    """y = x @ W for packed W.  x: (..., K) -> (..., C)."""
    interp = (not on_tpu()) if interpret is None else interpret
    lead = x.shape[:-1]
    xf = x.reshape(-1, x.shape[-1])
    if p.k_padded > p.k:  # weight was K-padded at pack time
        xf = jnp.pad(xf, ((0, 0), (0, p.k_padded - p.k)))
    y = vusa_spmm(xf, p.values, p.row_idx, interpret=interp)
    y = y[..., : p.c]
    return y.reshape(*lead, p.c)


def apply_packed_ref(x: jax.Array, p: PackedLinear) -> jax.Array:
    lead = x.shape[:-1]
    xf = x.reshape(-1, x.shape[-1])
    if p.k_padded > p.k:
        xf = jnp.pad(xf, ((0, 0), (0, p.k_padded - p.k)))
    y = vusa_spmm_ref(xf, p.values, p.row_idx)[..., : p.c]
    return y.reshape(*lead, p.c)


def matmul(x: jax.Array, w: jax.Array, *, interpret: bool | None = None) -> jax.Array:
    """Dense baseline kernel wrapper (pads to MXU-aligned tiles)."""
    interp = (not on_tpu()) if interpret is None else interpret
    m, k = x.shape
    _, n = w.shape
    bm = 128 if m % 128 == 0 else (8 if m % 8 == 0 else 1)
    y = dense_matmul(x, w, bm=bm, interpret=interp)
    return y


# --------------------------------------------------------------------------
# Row-wise (paper-format) packed linear
# --------------------------------------------------------------------------

import os  # noqa: E402
import time  # noqa: E402

from ..core.packing import RowPacked, pack_rows, pack_rows_t  # noqa: E402
from .ref import vusa_fused_mlp_ref, vusa_packed_ref  # noqa: E402
from .vusa_packed import (  # noqa: E402
    DEFAULT_SLOT_CHUNK,
    vusa_fused_mlp_matmul,
    vusa_packed_matmul,
)


@dataclasses.dataclass
class RowPackedLinear:
    """Device-resident row-wise VUSA pack (see kernels/vusa_packed.py)."""

    values: jax.Array  # (T, K, J*A)
    positions: jax.Array  # (T, K, J*A) int8
    k: int
    c: int
    a: int
    m: int = 128  # window width (lanes)

    @property
    def byte_ratio(self) -> float:
        t, k, s = self.values.shape
        dense = self.k * t * self.m * self.values.dtype.itemsize
        return t * k * s * (self.values.dtype.itemsize + 1) / dense


def pack_linear_rows(w: np.ndarray, m: int = 128, a: int = 16) -> RowPackedLinear:
    rp: RowPacked = pack_rows(np.asarray(w), m=m, a=a)
    return RowPackedLinear(
        values=jnp.asarray(rp.values),
        positions=jnp.asarray(rp.row_positions),
        k=rp.k,
        c=rp.c,
        a=a,
        m=m,
    )


def pack_linear_rows_t(w: np.ndarray, m: int = 128, a: int = 16) -> RowPackedLinear:
    """Row-pack ``w`` *transposed* — windows cover ``w``'s leading (reduction)
    dim, the operand shape ``vusa_fused_mlp_matmul`` wants for ``w_down``."""
    rp: RowPacked = pack_rows_t(np.asarray(w), m=m, a=a)
    return RowPackedLinear(
        values=jnp.asarray(rp.values),
        positions=jnp.asarray(rp.row_positions),
        k=rp.k,
        c=rp.c,
        a=a,
        m=m,
    )


# -- k_blk / m tuning ------------------------------------------------------
#
# The kernel's only free parameters are the K block (bounds the one-hot
# scratch: k_blk * min(slots, slot_chunk) * m * 4 bytes) and the window
# width m (fixed at pack time, <= 128).  ``choose_k_blk`` is the heuristic;
# ``autotune_row_packed`` measures the candidates once per shape and caches
# the winner so subsequent ``apply_row_packed`` calls use it.

_KBLK_CACHE: dict = {}  # (k, slots, m, b, backend) -> k_blk
_VMEM_SCRATCH_BUDGET = 2 * 1024 * 1024  # bytes for the one-hot scatter tensor


def _kblk_candidates(k: int):
    c = [blk for blk in (64, 128, 256, 512, 1024) if k % blk == 0 and blk <= k]
    if k <= 2048 and k not in c:
        c.append(k)
    return c or [k]


def _largest_divisor_leq(k: int, blk: int) -> int:
    """Largest divisor of ``k`` that is <= ``blk``, in O(sqrt k).

    The seed snapped ``REPRO_VUSA_KBLK`` down one step at a time
    (``while k % blk: blk -= 1``) — O(k) when the override lands just above
    a small divisor of a large prime-ish K."""
    blk = max(1, min(blk, k))
    best = 1
    for i in range(1, int(k**0.5) + 1):
        if k % i == 0:
            if i <= blk:
                best = max(best, i)
            if k // i <= blk:
                best = max(best, k // i)
    return best


def choose_k_blk(k: int, slots: int, m: int) -> int:
    """Pick the K block without measuring.

    On TPU the one-hot scatter scratch — k_blk * min(slots, slot_chunk) *
    m * 4 bytes, since reconstruction runs at most slot_chunk slots per
    pass — must fit VMEM, so take the largest candidate under the budget.
    Off-TPU (interpret mode) there is no VMEM wall and fewer, larger grid
    steps win (measured in benchmarks/run.py kernel_vusa_packed), so take
    the largest candidate outright.
    """
    env = os.environ.get("REPRO_VUSA_KBLK")
    if env:
        try:
            blk = int(env)
        except ValueError as e:
            raise ValueError(f"REPRO_VUSA_KBLK must be an integer, got {env!r}") from e
        return _largest_divisor_leq(k, blk)  # snap down to a divisor of k
    cands = _kblk_candidates(k)
    if not on_tpu():
        return cands[-1]
    best = 1
    for blk in cands:
        if blk * min(slots, DEFAULT_SLOT_CHUNK) * m * 4 <= _VMEM_SCRATCH_BUDGET:
            best = max(best, blk)
    return best


def _tune_key(
    xf: jax.Array, p: RowPackedLinear, interp: bool, reconstruct: str, slot_chunk: int
):
    # reconstruct/slot_chunk are part of the key: a k_blk tuned for the
    # one-pass "onehot" reconstruction is generally wrong for the per-slot
    # "loop" baseline (and vice versa) — the seed omitted both, so a cache
    # entry from one mode silently drove the other
    return (
        xf.shape[-1], p.values.shape[2], p.m, xf.shape[0],
        str(p.values.dtype), interp, jax.default_backend(),
        reconstruct, slot_chunk,
    )


def autotune_row_packed(
    x: jax.Array,
    p: RowPackedLinear,
    *,
    interpret: bool | None = None,
    iters: int = 5,
    reconstruct: str = "onehot",
    slot_chunk: int = DEFAULT_SLOT_CHUNK,
) -> int:
    """Time the kernel over k_blk candidates; cache + return the winner."""
    interp = (not on_tpu()) if interpret is None else interpret
    xf = x.reshape(-1, x.shape[-1])
    key = _tune_key(xf, p, interp, reconstruct, slot_chunk)
    if key in _KBLK_CACHE:
        return _KBLK_CACHE[key]
    best_blk, best_t = None, float("inf")
    for blk in _kblk_candidates(xf.shape[-1]):
        f = lambda a: vusa_packed_matmul(
            a, p.values, p.positions, m=p.m, k_blk=blk, interpret=interp,
            reconstruct=reconstruct, slot_chunk=slot_chunk,
        )
        f(xf).block_until_ready()  # compile
        t0 = time.perf_counter()
        for _ in range(iters):
            f(xf).block_until_ready()
        dt = (time.perf_counter() - t0) / iters
        if dt < best_t:
            best_blk, best_t = blk, dt
    _KBLK_CACHE[key] = best_blk
    return best_blk


def apply_row_packed(
    x: jax.Array,
    p: RowPackedLinear,
    *,
    interpret: bool | None = None,
    k_blk: int | None = None,
    reconstruct: str = "onehot",
    slot_chunk: int = DEFAULT_SLOT_CHUNK,
) -> jax.Array:
    """y = x @ W for row-packed W.  x: (..., K) -> (..., C).

    ``k_blk=None`` consults the autotune cache (populated by
    ``autotune_row_packed``), falling back to the ``choose_k_blk`` heuristic.
    """
    interp = (not on_tpu()) if interpret is None else interpret
    lead = x.shape[:-1]
    xf = x.reshape(-1, x.shape[-1])
    k = xf.shape[-1]
    slots = p.values.shape[2]
    if k_blk is None:
        if os.environ.get("REPRO_VUSA_KBLK"):  # explicit override beats the cache
            k_blk = choose_k_blk(k, slots, p.m)
        else:
            k_blk = _KBLK_CACHE.get(
                _tune_key(xf, p, interp, reconstruct, slot_chunk)
            ) or choose_k_blk(k, slots, p.m)
    k_blk = min(k_blk, k)
    while k % k_blk:
        k_blk //= 2
    y = vusa_packed_matmul(
        xf,
        p.values,
        p.positions,
        m=p.m,
        k_blk=max(k_blk, 1),
        interpret=interp,
        reconstruct=reconstruct,
        slot_chunk=slot_chunk,
    )
    return y[..., : p.c].reshape(*lead, p.c).astype(x.dtype)


def apply_row_packed_ref(x: jax.Array, p: RowPackedLinear) -> jax.Array:
    lead = x.shape[:-1]
    xf = x.reshape(-1, x.shape[-1])
    y = vusa_packed_ref(xf, p.values, p.positions)
    return y[..., : p.c].reshape(*lead, p.c).astype(x.dtype)


# --------------------------------------------------------------------------
# Fused packed MLP (DESIGN.md §7): silu(x@Wg) * (x@Wu) @ Wd in one kernel
# --------------------------------------------------------------------------


def _check_fused_packs(
    k: int, gate: RowPackedLinear, up: RowPackedLinear, down_t: RowPackedLinear
) -> None:
    assert gate.k == k and up.k == k, (gate.k, up.k, k)
    assert gate.m == up.m == down_t.m, (gate.m, up.m, down_t.m)
    assert gate.c == up.c == down_t.c, (gate.c, up.c, down_t.c)  # all windowed over ff
    t = gate.values.shape[0]
    assert up.values.shape[0] == t and down_t.values.shape[0] == t


def _fused_tune_key(
    xf: jax.Array,
    gate: RowPackedLinear,
    up: RowPackedLinear,
    down_t: RowPackedLinear,
    interp: bool,
    reconstruct: str,
    slot_chunk: int,
):
    return (
        "fused", xf.shape[-1], down_t.k, xf.shape[0],
        gate.values.shape[2], up.values.shape[2], down_t.values.shape[2], gate.m,
        str(gate.values.dtype), interp, jax.default_backend(), reconstruct, slot_chunk,
    )


def autotune_fused_mlp(
    x: jax.Array,
    gate: RowPackedLinear,
    up: RowPackedLinear,
    down_t: RowPackedLinear,
    *,
    interpret: bool | None = None,
    iters: int = 5,
    reconstruct: str = "onehot",
    slot_chunk: int = DEFAULT_SLOT_CHUNK,
) -> int:
    """Time the fused megakernel over k_blk candidates; cache the winner.

    The fused shape is its own tuning problem — its k_blk chunks *both* the
    d_model reduction of gate/up and the d_model output rows of the down
    accumulation, so the row-packed winner does not transfer."""
    interp = (not on_tpu()) if interpret is None else interpret
    xf = x.reshape(-1, x.shape[-1])
    _check_fused_packs(xf.shape[-1], gate, up, down_t)
    key = _fused_tune_key(xf, gate, up, down_t, interp, reconstruct, slot_chunk)
    if key in _KBLK_CACHE:
        return _KBLK_CACHE[key]
    best_blk, best_t = None, float("inf")
    for blk in sorted(set(_kblk_candidates(xf.shape[-1]) + _kblk_candidates(down_t.k))):
        f = lambda a: vusa_fused_mlp_matmul(
            a, gate.values, gate.positions, up.values, up.positions,
            down_t.values, down_t.positions, m=gate.m, k_blk=blk,
            interpret=interp, reconstruct=reconstruct, slot_chunk=slot_chunk,
        )
        f(xf).block_until_ready()  # compile
        t0 = time.perf_counter()
        for _ in range(iters):
            f(xf).block_until_ready()
        dt = (time.perf_counter() - t0) / iters
        if dt < best_t:
            best_blk, best_t = blk, dt
    _KBLK_CACHE[key] = best_blk
    return best_blk


def apply_fused_mlp(
    x: jax.Array,
    gate: RowPackedLinear,
    up: RowPackedLinear,
    down_t: RowPackedLinear,
    *,
    interpret: bool | None = None,
    k_blk: int | None = None,
    reconstruct: str = "onehot",
    slot_chunk: int = DEFAULT_SLOT_CHUNK,
) -> jax.Array:
    """Whole SwiGLU MLP through the fused megakernel.

    ``gate``/``up`` row-pack (K, ff); ``down_t`` row-packs ``w_down``
    transposed (``pack_linear_rows_t``) so the ff reduction is windowed.
    x: (..., K) -> (..., D) where D = ``down_t.k``.  One ``pallas_call``
    replaces the gate/up/down dispatch triple and the (..., ff) intermediate
    stays in VMEM.  ``k_blk=None`` consults the autotune cache (populated by
    ``autotune_fused_mlp``), falling back to ``choose_k_blk``; unlike the
    plain row-packed kernel the chunk size need not divide K."""
    interp = (not on_tpu()) if interpret is None else interpret
    lead = x.shape[:-1]
    xf = x.reshape(-1, x.shape[-1])
    k = xf.shape[-1]
    _check_fused_packs(k, gate, up, down_t)
    if k_blk is None:
        slots = max(gate.values.shape[2], up.values.shape[2], down_t.values.shape[2])
        if os.environ.get("REPRO_VUSA_KBLK"):  # explicit override beats the cache
            k_blk = choose_k_blk(k, slots, gate.m)
        else:
            k_blk = _KBLK_CACHE.get(
                _fused_tune_key(xf, gate, up, down_t, interp, reconstruct, slot_chunk)
            ) or choose_k_blk(k, slots, gate.m)
    y = vusa_fused_mlp_matmul(
        xf,
        gate.values,
        gate.positions,
        up.values,
        up.positions,
        down_t.values,
        down_t.positions,
        m=gate.m,
        k_blk=max(int(k_blk), 1),
        interpret=interp,
        reconstruct=reconstruct,
        slot_chunk=slot_chunk,
    )
    return y.reshape(*lead, down_t.k).astype(x.dtype)


def apply_fused_mlp_ref(
    x: jax.Array, gate: RowPackedLinear, up: RowPackedLinear, down_t: RowPackedLinear
) -> jax.Array:
    lead = x.shape[:-1]
    xf = x.reshape(-1, x.shape[-1])
    _check_fused_packs(xf.shape[-1], gate, up, down_t)
    y = vusa_fused_mlp_ref(
        xf, gate.values, gate.positions, up.values, up.positions,
        down_t.values, down_t.positions, m=gate.m,
    )
    return y.reshape(*lead, down_t.k).astype(x.dtype)


# --------------------------------------------------------------------------
# Mesh-sharded appliers (DESIGN.md §8): the pack's window axis is split over
# the `model` mesh axis and each device runs the *single-device* kernel on
# its window shard — the virtually upscaled array spans devices, not just
# one chip's lanes.  mesh=None (or a size-1 model axis) is the degenerate
# case and routes straight to the plain appliers, byte-identical program.
# --------------------------------------------------------------------------

from jax.experimental.shard_map import shard_map  # noqa: E402
from jax.sharding import PartitionSpec as _P  # noqa: E402


def mesh_axis_size(mesh, axis_name: str = "model") -> int:
    """Size of a mesh axis; 1 for no mesh / absent axis (degenerate case)."""
    if mesh is None or axis_name not in mesh.shape:
        return 1
    return int(mesh.shape[axis_name])


def shard_linear_windows(p: RowPackedLinear, n_shards: int) -> RowPackedLinear:
    """Pad the window axis to a multiple of ``n_shards`` with no-op windows
    (value 0, position -1) — the device-array twin of
    ``core.packing.shard_windows``.  ``k``/``c`` metadata is unchanged: pad
    windows reconstruct zero tiles past the real column range."""
    t = p.values.shape[0]
    pad = (-t) % n_shards
    if pad == 0:
        return p
    values = jnp.pad(p.values, ((0, pad), (0, 0), (0, 0)))
    positions = jnp.pad(p.positions, ((0, pad), (0, 0), (0, 0)), constant_values=-1)
    return RowPackedLinear(values=values, positions=positions, k=p.k, c=p.c, a=p.a, m=p.m)


def _local_view(p: RowPackedLinear, values, positions, t_local: int) -> RowPackedLinear:
    """Per-shard view: same geometry, ``c`` covering only the local windows."""
    return RowPackedLinear(
        values=values, positions=positions, k=p.k, c=t_local * p.m, a=p.a, m=p.m
    )


def apply_row_packed_sharded(
    x: jax.Array,
    p: RowPackedLinear,
    mesh=None,
    axis_name: str = "model",
    *,
    interpret: bool | None = None,
) -> jax.Array:
    """``apply_row_packed`` with the window axis sharded over ``axis_name``.

    Windows tile the *output* columns, so each shard's kernel emits a
    contiguous ``(B, T_loc*m)`` column slice; a tiled all-gather over the
    mesh axis reassembles the full width on every device (column-parallel
    output, the tensor-parallel twin of the fused kernel's psum).  Values
    and positions enter the shard_map split on their leading window axis —
    pre-placing them with ``dist.sharding.window_sharding`` makes that split
    free.  Degenerate mesh (None or size-1 axis) runs the plain kernel."""
    tp = mesh_axis_size(mesh, axis_name)
    if tp == 1:
        return apply_row_packed(x, p, interpret=interpret)
    p = shard_linear_windows(p, tp)
    t = p.values.shape[0]
    t_local = t // tp
    lead = x.shape[:-1]
    xf = x.reshape(-1, x.shape[-1])

    def local(xf, values, positions):
        y = apply_row_packed(
            xf, _local_view(p, values, positions, t_local), interpret=interpret
        )
        return jax.lax.all_gather(y, axis_name, axis=1, tiled=True)

    y = shard_map(
        local,
        mesh=mesh,
        in_specs=(_P(), _P(axis_name), _P(axis_name)),
        out_specs=_P(),
        check_rep=False,
    )(xf, p.values, p.positions)
    return y[..., : p.c].reshape(*lead, p.c).astype(x.dtype)


def apply_fused_mlp_sharded(
    x: jax.Array,
    gate: RowPackedLinear,
    up: RowPackedLinear,
    down_t: RowPackedLinear,
    mesh=None,
    axis_name: str = "model",
    *,
    interpret: bool | None = None,
) -> jax.Array:
    """``apply_fused_mlp`` with the ff-window axis sharded over ``axis_name``.

    All three packs window the same ff dim, so one shard owns a slab of ff:
    it reconstructs its ``w_gate``/``w_up`` windows, forms that slab of
    ``silu(gate) * up`` in VMEM, and folds it through its ``w_down`` rows
    into a *partial* ``(B, d_model)`` output; a psum over the mesh axis sums
    the shards — ff is ``w_down``'s reduction dim, so partial outputs add.
    Degenerate mesh runs the plain megakernel."""
    tp = mesh_axis_size(mesh, axis_name)
    if tp == 1:
        return apply_fused_mlp(x, gate, up, down_t, interpret=interpret)
    _check_fused_packs(x.shape[-1], gate, up, down_t)
    gate = shard_linear_windows(gate, tp)
    up = shard_linear_windows(up, tp)
    down_t = shard_linear_windows(down_t, tp)
    t_local = gate.values.shape[0] // tp
    lead = x.shape[:-1]
    xf = x.reshape(-1, x.shape[-1])

    def local(xf, gv, gp, uv, upp, dv, dp):
        y = apply_fused_mlp(
            xf,
            _local_view(gate, gv, gp, t_local),
            _local_view(up, uv, upp, t_local),
            _local_view(down_t, dv, dp, t_local),
            interpret=interpret,
        )
        return jax.lax.psum(y.astype(jnp.float32), axis_name)

    y = shard_map(
        local,
        mesh=mesh,
        in_specs=(_P(),) + (_P(axis_name),) * 6,
        out_specs=_P(),
        check_rep=False,
    )(xf, gate.values, gate.positions, up.values, up.positions,
      down_t.values, down_t.positions)
    return y.reshape(*lead, down_t.k).astype(x.dtype)
