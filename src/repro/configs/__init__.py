"""Config registry: ``get_config("<arch-id>")`` -> ArchConfig."""

from __future__ import annotations

import importlib

from .base import SHAPES, ArchConfig, ShapeConfig  # noqa: F401

ARCH_IDS = [
    "recurrentgemma_9b",
    "llama3_2_1b",
    "qwen2_0_5b",
    "internlm2_1_8b",
    "qwen3_8b",
    "olmoe_1b_7b",
    "moonshot_v1_16b_a3b",
    "mamba2_2_7b",
    "whisper_tiny",
    "paligemma_3b",
    "vusa_edge",  # the paper's own Edge-AI scale config
]


def _norm(arch: str) -> str:
    return arch.replace("-", "_").replace(".", "_")


def get_config(arch: str) -> ArchConfig:
    key = _norm(arch)
    if key not in ARCH_IDS:
        raise KeyError(f"unknown arch {arch!r}; known: {ARCH_IDS}")
    return importlib.import_module(f"repro.configs.{key}").CONFIG


def get_smoke_config(arch: str) -> ArchConfig:
    """Reduced same-family config for CPU smoke tests."""
    key = _norm(arch)
    if key not in ARCH_IDS:
        raise KeyError(f"unknown arch {arch!r}; known: {ARCH_IDS}")
    return importlib.import_module(f"repro.configs.{key}").SMOKE
