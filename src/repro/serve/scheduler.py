"""Continuous-batching scheduler over the fused decode loop.

``Engine.generate`` serves one fixed batch of equal-length prompts for a
fixed ``max_new``; real traffic is ragged.  :class:`Scheduler` keeps a fixed
pool of in-flight *slots* and alternates two phases (DESIGN.md §5, §6):

  admission   free slots are filled with queued requests whose arrival time
              has passed, earliest arrival first.  Arrivals are coalesced
              per round and grouped into prompt-length buckets: each bucket
              is primed in ONE batched masked-prefill dispatch
              (``Engine.prime_many``) and scattered into its slots with ONE
              donated multi-slot write (``models.cache.write_slots``) —
              admission of N same-bucket requests costs O(1) dispatches and
              zero host syncs.  Recurrent families (and
              ``admission="sequential"``, the measured baseline) fall back
              to per-request exact-length priming.
  decode      one jitted *segment* — ``segment`` fused ``lax.scan`` steps
              of the whole pool, vmapped over the slot axis — runs on
              device, then syncs once; finished slots (EOS or budget)
              retire and free up for the next admission round.  First-token
              EOS/budget checks are deferred to this sync too, so admission
              itself never blocks on a device->host transfer.

Each slot is an independent B=1 decode cache stacked on a leading slot axis
(:mod:`repro.models.cache`), with its own scalar ``pos`` and its own PRNG
key stream seeded from the request.  That makes every completed request's
tokens bit-identical to a one-shot ``Engine.generate`` of the same prompt,
seed and temperature at batch 1 — the scheduler changes *when* work runs,
never *what* it computes.  Bucketed prefill preserves this bit-for-bit:
right-padding keeps every real token's causal window unchanged and padded
keys are masked to exactly-zero probability (DESIGN.md §6).  Free slots
decode along with the pool (cheaper than masking the hot path); their
output is discarded and their state is replaced wholesale at the next
admission.

The segment length trades sync overhead against retirement latency: the
pool only retires/admits at segment boundaries, so a slot whose request
finished mid-segment decodes (and discards) at most ``segment - 1`` extra
tokens.  The segment shape is static — one compiled program serves the
whole run regardless of arrival pattern, and the bucketed prefill programs
(one per length bucket x batch bucket) serve any traffic shape without
recompiling.
"""

from __future__ import annotations

import bisect
import dataclasses
import time
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from .engine import Engine

__all__ = ["Request", "Completion", "Scheduler"]


@dataclasses.dataclass
class Request:
    """One generation request.  ``arrival_s`` is an offset from ``run()``
    start (0 = already queued); ``seed`` seeds this request's private PRNG
    stream, mirroring ``ServeConfig.seed`` in one-shot generate."""

    prompt: np.ndarray  # (S,) int32
    max_new: int = 32
    eos_id: Optional[int] = None
    seed: int = 0
    arrival_s: float = 0.0


@dataclasses.dataclass
class Completion:
    rid: int
    tokens: np.ndarray  # (<= max_new,) int32, truncated just after eos_id
    arrival_s: float
    admit_s: float
    finish_s: float

    @property
    def latency_s(self) -> float:
        return self.finish_s - self.arrival_s


@dataclasses.dataclass
class _Slot:
    """Host-side bookkeeping for one in-flight slot."""

    rid: int = -1
    tokens: Optional[List[int]] = None
    first: Optional[jax.Array] = None  # deferred first token (device, (1, 1))
    remaining: int = 0
    eos_id: Optional[int] = None
    arrival_s: float = 0.0
    admit_s: float = 0.0

    @property
    def active(self) -> bool:
        return self.rid >= 0


class Scheduler:
    """Continuous-batching run loop over a fused-decode :class:`Engine`."""

    def __init__(
        self,
        engine: Engine,
        slots: int = 4,
        segment: int = 8,
        admission: str = "batched",
    ):
        if not engine.sc.fused:
            raise ValueError("Scheduler requires a fused-decode engine (ServeConfig.fused)")
        if slots < 1 or segment < 1:
            raise ValueError(f"need slots >= 1 and segment >= 1, got {slots}, {segment}")
        if admission not in ("batched", "sequential"):
            raise ValueError(f"admission must be 'batched' or 'sequential', got {admission!r}")
        self.eng = engine
        self.model = engine.model
        self.slots = slots
        self.segment = segment
        # "batched" coalesces arrivals into bucketed one-dispatch prefills
        # (when the family supports masked prefill); "sequential" keeps the
        # per-request exact-length path as the measured baseline
        self.admission = admission
        # (arrival_s, rid, Request), kept sorted by (arrival_s, rid) at
        # submit time so arrived requests are always a front prefix —
        # admission pops O(k) per round instead of re-scanning the backlog
        self._queue: List[tuple] = []
        self._completions: Dict[int, Completion] = {}
        self._next_rid = 0
        self._slot: List[_Slot] = [_Slot() for _ in range(slots)]
        # device state: slot-stacked cache, per-slot tokens and raw key data.
        # Under a mesh the slot axis — the serve path's batch dim — is
        # sharded over the DP mesh axes (DESIGN.md §8): the KV pool's bytes
        # scale out with ``data`` while the packed weights scale out with
        # ``model`` inside the engine's decode step.
        kshape = jax.random.key_data(jax.random.key(0)).shape
        self._cache = self.model.init_slot_cache(slots, engine.sc.max_len)
        self._token = jnp.zeros((slots, 1, 1), jnp.int32)
        self._kdata = jnp.zeros((slots,) + kshape, jnp.uint32)
        if engine.mesh is not None:
            from ..dist.sharding import batch_sharding
            from ..models.cache import slot_shardings

            self._cache = jax.device_put(
                self._cache, slot_shardings(self._cache, engine.mesh)
            )
            self._token = jax.device_put(
                self._token, batch_sharding(engine.mesh, slots, self._token.ndim)
            )
            self._kdata = jax.device_put(
                self._kdata, batch_sharding(engine.mesh, slots, self._kdata.ndim)
            )
        self._batch_axes = self.model.cache_batch_axes(engine.sc.max_len)
        # donate the pool state: segments and admissions update it in place
        self._seg = jax.jit(
            self._segment_fn, static_argnums=(4,), donate_argnums=(1, 2, 3)
        )
        self._write = jax.jit(self._write_fn, donate_argnums=(0, 1, 2))
        self._write_many = jax.jit(self._write_many_fn, donate_argnums=(0, 1, 2))
        self._derive_keys = jax.jit(
            jax.vmap(lambda s: jax.random.key_data(jax.random.key(s)))
        )
        # run stats
        self._seg_steps = 0
        self._active_slot_steps = 0
        self._decode_s = 0.0
        self._admit_s = 0.0

    # -- submission -----------------------------------------------------------

    def submit(self, req: Request) -> int:
        """Queue a request; returns its request id."""
        prompt = np.asarray(req.prompt, np.int32).reshape(-1)
        if req.max_new < 1:  # before the budget check: a negative max_new
            raise ValueError("max_new must be >= 1")  # could slip past it
        budget = prompt.shape[0] + req.max_new + self.segment
        if budget > self.eng.sc.max_len:
            raise ValueError(
                f"prompt({prompt.shape[0]}) + max_new({req.max_new}) + "
                f"segment({self.segment}) = {budget} exceeds max_len "
                f"{self.eng.sc.max_len}"
            )
        rid = self._next_rid
        self._next_rid += 1
        bisect.insort(
            self._queue, (req.arrival_s, rid, dataclasses.replace(req, prompt=prompt))
        )
        return rid

    # -- jitted segment body --------------------------------------------------

    def _segment_fn(self, params, token, kdata, cache, steps: int):
        """``steps`` decode steps of all slots; returns the emitted token grid
        ``(steps, slots)`` plus the advanced state.  Each slot splits its own
        key and samples at batch 1, exactly as one-shot generate does.

        Free slots decode along with the pool (their output is discarded and
        their whole state is replaced at the next admission), so the hot
        path carries no per-slot masking — a free slot's ``pos`` merely
        drifts until re-admission, and ``attention_decode`` clamps its cache
        writes at ``max_len``."""

        def body(carry, _):
            token, kdata, cache = carry

            def one(tok, kd, c):
                key = jax.random.wrap_key_data(kd)
                key, sub = jax.random.split(key)
                nxt, c2 = self.eng._decode_fn(params, tok, c, sub)
                return nxt, jax.random.key_data(key), c2

            token, kdata, cache = jax.vmap(one)(token, kdata, cache)
            return (token, kdata, cache), token[:, 0, 0]

        (token, kdata, cache), toks = jax.lax.scan(
            body, (token, kdata, cache), None, length=steps
        )
        return token, kdata, cache, toks

    # -- admission / retirement ----------------------------------------------

    @staticmethod
    def _write_fn(cache, token, kdata, i, sub, nxt, kd):
        """Donated single-dispatch write of a primed request into slot ``i``
        (cache + first token + key data in one go); ``i`` is traced, so one
        compilation covers every slot."""
        from ..models.cache import write_slot

        return write_slot(cache, i, sub), token.at[i].set(nxt), kdata.at[i].set(kd)

    def _write_many_fn(self, cache, token, kdata, idx, sub, nxt, kds, lengths):
        """Donated one-dispatch scatter of a whole primed bucket into slots
        ``idx``: batched caches (per-slot true ``pos`` = lengths), first
        tokens, and per-request PRNG key data ``kds``.  Batch-bucket padding
        rows carry an out-of-range index and are dropped; one compilation
        covers every batch bucket."""
        from ..models.cache import write_slots

        cache = write_slots(cache, idx, sub, self._batch_axes, lengths)
        token = token.at[idx].set(nxt[:, :, None], mode="drop")
        kdata = kdata.at[idx].set(kds.astype(kdata.dtype), mode="drop")
        return cache, token, kdata

    def _bind_slot(self, i: int, rid: int, req: Request, first, now: float) -> None:
        slot = self._slot[i]
        slot.rid, slot.tokens, slot.first = rid, [], first
        slot.remaining = req.max_new - 1
        slot.arrival_s, slot.admit_s = req.arrival_s, now
        slot.eos_id = req.eos_id

    def _admit(self, i: int, rid: int, req: Request, now: float) -> None:
        """Per-request exact-length admission (recurrent families, and the
        ``admission="sequential"`` baseline): B=1 prime + single-slot write.
        First-token EOS/budget checks are deferred to the segment sync, so
        no device->host transfer happens here."""
        t0 = time.monotonic()
        key = jax.random.key(req.seed)
        nxt, cache, key = self.eng.prime(req.prompt[None], key)
        self._cache, self._token, self._kdata = self._write(
            self._cache, self._token, self._kdata,
            jnp.int32(i), cache, nxt, jax.random.key_data(key),
        )
        self._bind_slot(i, rid, req, nxt, now)
        self._admit_s += time.monotonic() - t0

    def _admit_batched(self, free: List[int], picked, now: float) -> None:
        """Coalesced bucketed admission: group this round's arrivals by
        prompt-length bucket, prime each bucket in one batched masked
        prefill, scatter each into its slots in one donated write.  The
        batch dim is padded to a power of two so compile count stays
        O(len buckets x log2 slots), not O(distinct traffic shapes)."""
        t0 = time.monotonic()
        groups: Dict[int, list] = {}
        for i, (rid, req) in zip(free, picked):
            groups.setdefault(self.eng.bucket_len(len(req.prompt)), []).append((i, rid, req))
        for blen, group in groups.items():
            nb = 1 << (len(group) - 1).bit_length()
            tokens = np.zeros((nb, blen), np.int32)
            lengths = np.ones(nb, np.int32)  # padding rows: 1-token dummy
            idx = np.full(nb, self.slots, np.int32)  # OOB -> dropped by the scatter
            for j, (i, rid, req) in enumerate(group):
                tokens[j, : len(req.prompt)] = req.prompt
                lengths[j] = len(req.prompt)
                idx[j] = i
            # per-request PRNG keys: one vmapped derivation when every seed
            # fits the uint32 word jax.random.key folds it into (bit-exact
            # there, verified in tests); anything else — wide seeds an int32
            # array would overflow on, negative seeds whose x64 folding
            # differs from the uint32 cast — falls back to eager per-request
            # key creation (still no host sync)
            seeds = [req.seed for _, _, req in group]
            if all(0 <= s < 2**32 for s in seeds):
                packed = np.asarray(
                    seeds + [0] * (nb - len(group)), np.uint32
                )
                kds = self._derive_keys(jnp.asarray(packed))
            else:
                zero = jnp.zeros(self._kdata.shape[1:], self._kdata.dtype)
                kds = jnp.stack(
                    [jax.random.key_data(jax.random.key(s)) for s in seeds]
                    + [zero] * (nb - len(group))
                )
            nxt, cache = self.eng.prime_many(tokens, lengths)
            self._cache, self._token, self._kdata = self._write_many(
                self._cache, self._token, self._kdata,
                jnp.asarray(idx), cache, nxt, kds, jnp.asarray(lengths),
            )
            for j, (i, rid, req) in enumerate(group):
                self._bind_slot(i, rid, req, nxt[j : j + 1], now)
        self._admit_s += time.monotonic() - t0

    def _pop_arrived(self, k: int, now: float) -> list:
        """Take up to ``k`` queued requests whose arrival time has passed,
        earliest ``arrival_s`` first (submit order breaks ties).  A strict
        FIFO-by-submit pop would head-of-line block: a free slot would sit
        idle behind a queue head whose ``arrival_s`` is still in the future
        even though later-submitted requests have already arrived.  The
        queue is arrival-sorted, so the arrived set is a front prefix."""
        n = 0
        while n < k and n < len(self._queue) and self._queue[n][0] <= now:
            n += 1
        picked = [(rid, req) for _, rid, req in self._queue[:n]]
        del self._queue[:n]
        return picked

    def _retire(self, i: int, now: float) -> Completion:
        slot = self._slot[i]
        done = Completion(
            rid=slot.rid,
            tokens=np.asarray(slot.tokens, np.int32),
            arrival_s=slot.arrival_s,
            admit_s=slot.admit_s,
            finish_s=now,
        )
        self._completions[slot.rid] = done
        self._slot[i] = _Slot()
        return done

    # -- run loop -------------------------------------------------------------

    def run(self, requests: Optional[List[Request]] = None) -> Dict[int, Completion]:
        """Drain the queue (plus ``requests``), honouring arrival times.
        Returns ``{rid: Completion}``; aggregate numbers via :meth:`stats`."""
        for r in requests or []:
            self.submit(r)
        self._completions = {}
        self._seg_steps = 0
        self._active_slot_steps = 0
        self._decode_s = self._admit_s = 0.0
        t_start = time.monotonic()

        def now() -> float:
            return time.monotonic() - t_start

        while self._queue or any(s.active for s in self._slot):
            # admission: coalesce this round's arrived requests into free slots
            t = now()
            free = [i for i, s in enumerate(self._slot) if not s.active]
            if free and self._queue:
                picked = self._pop_arrived(len(free), t)
                if picked:
                    if self.admission == "batched" and self.eng.batched_prefill:
                        self._admit_batched(free[: len(picked)], picked, t)
                    else:
                        for i, (rid, req) in zip(free, picked):
                            self._admit(i, rid, req, t)
            active_idx = [i for i, s in enumerate(self._slot) if s.active]
            if not active_idx:
                if not self._queue:
                    continue  # drained; loop condition exits
                # nothing in flight: sleep until the next request arrives
                # (the queue head, since the queue is arrival-sorted)
                wait = self._queue[0][0] - now()
                if wait > 0:
                    time.sleep(wait)
                continue
            # decode one segment and sync once
            t0 = time.monotonic()
            self._token, self._kdata, self._cache, toks = self._seg(
                self.eng.params, self._token, self._kdata, self._cache,
                self.segment,
            )
            toks_np = np.asarray(toks)  # (segment, slots) — the one sync
            self._decode_s += time.monotonic() - t0
            self._seg_steps += self.segment
            self._active_slot_steps += len(active_idx) * self.segment
            t = now()
            for i in active_idx:
                slot = self._slot[i]
                if slot.first is not None:
                    # deferred first token: EOS/budget checked here, at the
                    # segment sync, never in the admission path
                    first = int(np.asarray(slot.first).reshape(-1)[0])
                    slot.tokens.append(first)
                    slot.first = None
                    if slot.remaining == 0 or (
                        slot.eos_id is not None and first == slot.eos_id
                    ):
                        self._retire(i, t)
                        continue
                for tok in toks_np[: min(slot.remaining, self.segment), i]:
                    slot.tokens.append(int(tok))
                    slot.remaining -= 1
                    if (slot.eos_id is not None and tok == slot.eos_id) or slot.remaining == 0:
                        self._retire(i, t)
                        break
        return self._completions

    def stats(self) -> Dict[str, float]:
        """Aggregate serve metrics for the most recent :meth:`run`.  Latency
        percentiles are NaN when nothing completed — an empty run must not
        read as an infinitely fast one."""
        done = sorted(self._completions.values(), key=lambda c: c.rid)
        lat = np.asarray([c.latency_s for c in done])
        decoded = sum(max(len(c.tokens) - 1, 0) for c in done)
        busy = self._decode_s + self._admit_s
        return {
            "requests": len(done),
            "decoded_tokens": decoded,
            "sustained_tok_per_s": decoded / max(busy, 1e-9),
            "decode_s": self._decode_s,
            "admit_s": self._admit_s,
            "latency_p50_s": float(np.percentile(lat, 50)) if done else float("nan"),
            "latency_p95_s": float(np.percentile(lat, 95)) if done else float("nan"),
            "slot_occupancy": self._active_slot_steps / max(self.slots * self._seg_steps, 1),
        }
