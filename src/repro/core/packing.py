"""VUSA packed sparse-weight formats.

Two granularities:

* ``pack_exact`` — the paper's scalar-granularity format: per row-tile, the
  greedy scheduler's jobs with per-row MAC<->SPE assignments (Section III).
  Used by the simulator and to property-test the wiring claim.

* ``pack_blocks`` — the TPU adaptation (DESIGN.md §2): the reduction dim is
  cut into windows of ``m_blk`` rows; per output tile of ``tile_n`` columns,
  only rows containing any non-zero are kept and packed into jobs of
  ``a_blk`` rows + an int32 row-index map (the "shifter setting").  This is
  what ``repro.kernels.vusa_spmm`` consumes.
"""

from __future__ import annotations

import dataclasses
from typing import List, Tuple

import numpy as np

from .vusa import Job, mac_assignment, schedule_matrix

__all__ = [
    "ExactPacked", "pack_exact", "unpack_exact",
    "BlockPacked", "pack_blocks", "unpack_blocks",
    "RowPacked", "pack_rows", "pack_rows_t", "unpack_rows", "shard_windows",
    "validate_rows",
    "QUANT_DTYPES", "QMAX", "QuantizedRowPacked",
    "quantize_rows", "dequantize_rows", "pack_nibbles", "unpack_nibbles",
    "nm_mask", "pack_rows_nm",
]


# --------------------------------------------------------------------------
# Exact (scalar) VUSA format
# --------------------------------------------------------------------------


@dataclasses.dataclass
class ExactPacked:
    """Scalar VUSA pack of a (K, C) matrix on an (N, M, A) array."""

    N: int
    M: int
    A: int
    rows: int
    cols: int
    # Per row-tile: list of (job, values (N, A), spe_positions (N, A) int, -1 = idle MAC)
    tiles: List[List[Tuple[Job, np.ndarray, np.ndarray]]]

    @property
    def n_jobs(self) -> int:
        return sum(len(t) for t in self.tiles)


def pack_exact(w: np.ndarray, N: int, M: int, A: int) -> ExactPacked:
    k, c = w.shape
    sched = schedule_matrix(w != 0, N, M, A)
    tiles = []
    for t, jobs in enumerate(sched.jobs):
        r0 = t * N
        rows = min(N, k - r0)
        packed_jobs = []
        for job in jobs:
            vals = np.zeros((N, A), dtype=w.dtype)
            pos = np.full((N, A), -1, dtype=np.int64)
            for r in range(rows):
                row = w[r0 + r, job.start : job.start + job.width]
                nz = np.flatnonzero(row)
                macs = mac_assignment(nz, M, A)
                assert macs is not None, "scheduler produced an infeasible window"
                for p, j in zip(nz, macs):
                    vals[r, j] = row[p]
                    pos[r, j] = p
            packed_jobs.append((job, vals, pos))
        tiles.append(packed_jobs)
    return ExactPacked(N=N, M=M, A=A, rows=k, cols=c, tiles=tiles)


def unpack_exact(p: ExactPacked) -> np.ndarray:
    w = np.zeros((p.rows, p.cols), dtype=p.tiles[0][0][1].dtype if p.tiles else np.float32)
    for t, jobs in enumerate(p.tiles):
        r0 = t * p.N
        for job, vals, pos in jobs:
            for r in range(min(p.N, p.rows - r0)):
                for j in range(p.A):
                    if pos[r, j] >= 0:
                        w[r0 + r, job.start + pos[r, j]] = vals[r, j]
    return w


# --------------------------------------------------------------------------
# Block (TPU) VUSA format
# --------------------------------------------------------------------------


@dataclasses.dataclass
class BlockPacked:
    """Block-VUSA pack of a (K, C) matrix.

    values : (n_tiles, n_jobs, a_blk, tile_n) — packed non-zero weight rows
    row_idx: (n_tiles, n_jobs, a_blk) int32   — absolute K index per packed
             row (padding rows point at 0 with zero values, so the gathered
             contribution is exactly zero)
    """

    k: int
    c: int
    m_blk: int
    a_blk: int
    tile_n: int
    values: np.ndarray
    row_idx: np.ndarray

    @property
    def n_tiles(self) -> int:
        return self.values.shape[0]

    @property
    def n_jobs(self) -> int:
        return self.values.shape[1]

    @property
    def compression(self) -> float:
        """Packed weight bytes / dense weight bytes (index bytes included)."""
        dense = self.k * self.c * self.values.dtype.itemsize
        packed = self.values.size * self.values.dtype.itemsize + self.row_idx.size * 4
        return packed / dense

    @property
    def virtual_growth(self) -> float:
        """Mean K-rows covered per physical a_blk-row job (the M/A analogue)."""
        return self.k * self.n_tiles / (self.n_jobs * self.a_blk * self.n_tiles)


def pack_blocks(
    w: np.ndarray, m_blk: int, a_blk: int, tile_n: int
) -> BlockPacked:
    """Pack (K, C) sparse ``w``; K % m_blk == 0, C % tile_n == 0, m_blk % a_blk == 0."""
    k, c = w.shape
    assert k % m_blk == 0 and c % tile_n == 0 and m_blk % a_blk == 0, (k, c, m_blk, a_blk, tile_n)
    n_tiles = c // tile_n
    n_win = k // m_blk

    # Per (tile, window): rows with any non-zero -> ceil(nnz_rows/a_blk) jobs.
    jobs_per_tile = np.zeros(n_tiles, dtype=np.int64)
    tile_jobs: List[List[Tuple[int, np.ndarray]]] = [[] for _ in range(n_tiles)]
    for t in range(n_tiles):
        for wi in range(n_win):
            blk = w[wi * m_blk : (wi + 1) * m_blk, t * tile_n : (t + 1) * tile_n]
            nz_rows = np.flatnonzero((blk != 0).any(axis=1)) + wi * m_blk
            if len(nz_rows) == 0:
                continue  # fully-zero window: no job at all (MAC gating)
            for j0 in range(0, len(nz_rows), a_blk):
                rows = nz_rows[j0 : j0 + a_blk]
                tile_jobs[t].append((wi, rows))
        jobs_per_tile[t] = len(tile_jobs[t])

    n_jobs = int(jobs_per_tile.max())
    values = np.zeros((n_tiles, n_jobs, a_blk, tile_n), dtype=w.dtype)
    row_idx = np.zeros((n_tiles, n_jobs, a_blk), dtype=np.int32)
    for t in range(n_tiles):
        for j, (wi, rows) in enumerate(tile_jobs[t]):
            if len(rows):
                values[t, j, : len(rows)] = w[rows, t * tile_n : (t + 1) * tile_n]
                row_idx[t, j, : len(rows)] = rows
    return BlockPacked(
        k=k, c=c, m_blk=m_blk, a_blk=a_blk, tile_n=tile_n, values=values, row_idx=row_idx
    )


def unpack_blocks(p: BlockPacked) -> np.ndarray:
    w = np.zeros((p.k, p.c), dtype=p.values.dtype)
    for t in range(p.n_tiles):
        for j in range(p.n_jobs):
            for a in range(p.a_blk):
                # padding rows have zero values; adding is safe and exact
                w[p.row_idx[t, j, a], t * p.tile_n : (t + 1) * p.tile_n] += p.values[t, j, a]
    return w


# --------------------------------------------------------------------------
# Row-wise (exact paper format) VUSA pack for the TPU kernel
# --------------------------------------------------------------------------


@dataclasses.dataclass
class RowPacked:
    """Row-wise VUSA pack of a (K, C) matrix over windows of ``m`` lanes.

    values:    (T, K, J*A)       value slots (0 = idle)
    positions: (T, K, J*A) int8  lane index within window (-1 = idle)

    Job ``j`` slot block ``[j*A, (j+1)*A)`` is one pass of the physical
    N x A array over window ``t`` (paper Section III-C: overflow rows force
    extra passes; fully-dense still works at J = ceil(M/A)).
    """

    k: int
    c: int
    m: int
    a: int
    values: np.ndarray
    row_positions: np.ndarray

    @property
    def n_jobs(self) -> int:
        return self.values.shape[2] // self.a

    def byte_ratio(self, value_bytes: int = 2) -> float:
        """Packed / dense HBM bytes (int8 positions)."""
        dense = self.k * self.c * value_bytes
        packed = self.values.shape[0] * self.k * self.values.shape[2] * (value_bytes + 1)
        return packed / dense


def pack_rows(w: np.ndarray, m: int = 128, a: int = 16) -> RowPacked:
    """Pack (K, C) into the row-wise VUSA format (C padded to m)."""
    k, c = w.shape
    c_pad = (-c) % m
    if c_pad:
        w = np.pad(w, ((0, 0), (0, c_pad)))
    t = w.shape[1] // m
    # jobs needed per window = ceil(max row-nnz / a)
    n_jobs = 1
    per_window_nnz = []
    for ti in range(t):
        blk = w[:, ti * m : (ti + 1) * m]
        nnz = (blk != 0).sum(axis=1)
        per_window_nnz.append(nnz)
        n_jobs = max(n_jobs, int(np.ceil(nnz.max(initial=1) / a)))
    slots = n_jobs * a
    values = np.zeros((t, k, slots), dtype=w.dtype)
    positions = np.full((t, k, slots), -1, dtype=np.int8)
    for ti in range(t):
        blk = w[:, ti * m : (ti + 1) * m]
        for r in range(k):
            pos = np.flatnonzero(blk[r])
            if len(pos):
                values[ti, r, : len(pos)] = blk[r, pos]
                positions[ti, r, : len(pos)] = pos.astype(np.int8)
    return RowPacked(k=k, c=c, m=m, a=a, values=values, row_positions=positions)


def pack_rows_t(w: np.ndarray, m: int = 128, a: int = 16) -> RowPacked:
    """Row-pack ``w`` *transposed*: windows cover ``w``'s leading dim.

    For a down-projection ``w_down`` of shape (ff, d) the fused MLP kernel
    (DESIGN.md §7) needs ff — ``w_down``'s *reduction* dim — to be the
    windowed lane dim, so the window that produced a ``(B, m)`` slice of the
    hidden state can immediately consume it: ``pack_rows_t(w_down)`` packs
    the (d, ff) transpose, and reconstructing window ``t`` yields the dense
    ``(d, m)`` tile whose lanes are ``w_down`` rows ``[t*m, (t+1)*m)``.
    ``unpack_rows`` of the result therefore returns ``w.T``."""
    return pack_rows(np.ascontiguousarray(np.asarray(w).T), m=m, a=a)


def shard_windows(p: RowPacked, n_shards: int) -> RowPacked:
    """Pad the window axis so ``n_shards`` devices can each hold a contiguous
    block of windows (the mesh ``model``-axis view used by sharded serving,
    DESIGN.md §8).

    Padded windows are exact no-op jobs — value 0, position -1 — so
    ``unpack_rows`` of the result is unchanged: window ``t`` still covers
    columns ``[t*m, (t+1)*m)`` and the pad windows reconstruct all-zero tiles
    past the real column range.  Shard ``s`` of the result owns windows
    ``[s*T/n, (s+1)*T/n)``, a contiguous column slice of the output, so the
    shards' partial outputs reassemble by concatenation (or, zero-extended,
    by sum).  ``n_shards`` that already divides the window count returns the
    pack unchanged."""
    if n_shards < 1:
        raise ValueError(f"n_shards must be >= 1, got {n_shards}")
    t = p.values.shape[0]
    pad = (-t) % n_shards
    if pad == 0:
        return p
    values = np.concatenate(
        [p.values, np.zeros((pad,) + p.values.shape[1:], p.values.dtype)]
    )
    positions = np.concatenate(
        [p.row_positions, np.full((pad,) + p.row_positions.shape[1:], -1, np.int8)]
    )
    return RowPacked(k=p.k, c=p.c, m=p.m, a=p.a, values=values, row_positions=positions)


def validate_rows(p: RowPacked) -> None:
    """Check a :class:`RowPacked`'s structural invariants; raise ``ValueError``
    naming the first violation (DESIGN.md §9).

    This is the pack/load-time integrity guard: a bit flip in the position
    metadata (the "shifter setting") would silently scatter weight values
    into the wrong lanes — finite, plausible-looking, and wrong — which the
    runtime ``isfinite`` guard can never catch.  Bounds/dtype/shape checks
    here are the only place such corruption is detectable, so every consumer
    validates before serving a pack."""
    v, q = np.asarray(p.values), np.asarray(p.row_positions)
    if v.shape != q.shape:
        raise ValueError(f"values shape {v.shape} != positions shape {q.shape}")
    if q.dtype != np.int8:
        raise ValueError(f"positions dtype must be int8, got {q.dtype}")
    if v.ndim != 3:
        raise ValueError(f"expected (T, K, S) pack, got shape {v.shape}")
    if p.m < 1 or p.a < 1 or p.m > 128:
        raise ValueError(f"window m={p.m} / slots a={p.a} out of range (int8 lanes)")
    t, k, slots = v.shape
    if k != p.k:
        raise ValueError(f"pack rows {k} != declared k={p.k}")
    if slots % p.a:
        raise ValueError(f"slot count {slots} not a multiple of a={p.a}")
    if t * p.m < p.c:
        raise ValueError(f"{t} windows of {p.m} lanes cover {t * p.m} < c={p.c} columns")
    # widen before comparing: m=128 does not fit int8, and int8 promotion
    # would wrap it, corrupting the bound itself
    q = q.astype(np.int32)
    bad = (q < -1) | (q >= p.m)
    if bad.any():
        i = tuple(int(x) for x in np.argwhere(bad)[0])
        raise ValueError(
            f"position {int(q[i])} at {i} outside [-1, {p.m}) — corrupt metadata"
        )
    if not np.isfinite(v).all():
        i = tuple(int(x) for x in np.argwhere(~np.isfinite(v))[0])
        raise ValueError(f"non-finite packed value at {i}")


def unpack_rows(p: RowPacked) -> np.ndarray:
    t, k, slots = p.values.shape
    w = np.zeros((k, t * p.m), dtype=p.values.dtype)
    for ti in range(t):
        for r in range(k):
            for s in range(slots):
                pos = int(p.row_positions[ti, r, s])
                if pos >= 0:
                    w[r, ti * p.m + pos] += p.values[ti, r, s]
    return w[:, : p.c]


# --------------------------------------------------------------------------
# Quantized row-wise pack: int8 / int4-nibble values + per-window fp32 scales
# --------------------------------------------------------------------------

QUANT_DTYPES = ("int8", "int4")
QMAX = {"int8": 127, "int4": 7}


@dataclasses.dataclass
class QuantizedRowPacked:
    """Row-wise VUSA pack with integer-quantized value slots (DESIGN.md §10).

    values:    (T, K, S) int8 for ``int8``; (T, K, S//2) int8 for ``int4``
               (two slots per byte: slot 2i in the low nibble, 2i+1 high)
    positions: (T, K, S) int8  lane index within window (-1 = idle) —
               always full-resolution regardless of value dtype
    scales:    (T, K) float32  per-(window, row) dequant scale; all-zero
               rows carry scale 1.0 so dequant stays finite
    dense_itemsize: bytes per element of the *original* dense matrix — the
               honest denominator for byte-ratio accounting (quantization
               changes the pack's bytes, not the dense baseline it replaces)
    """

    k: int
    c: int
    m: int
    a: int
    value_dtype: str
    values: np.ndarray
    row_positions: np.ndarray
    scales: np.ndarray
    dense_itemsize: int

    @property
    def slots(self) -> int:
        return self.row_positions.shape[2]

    @property
    def n_jobs(self) -> int:
        return self.slots // self.a


def pack_nibbles(q: np.ndarray) -> np.ndarray:
    """Pack int4-range int8 values (..., S) into (..., S//2) bytes, S even.

    Slot ``2i`` lands in the low nibble, ``2i+1`` in the high nibble, so the
    kernel's shift/mask decode walks slots in order."""
    if q.shape[-1] % 2:
        raise ValueError(f"slot count {q.shape[-1]} must be even to nibble-pack")
    u = q.astype(np.uint8)
    lo, hi = u[..., 0::2], u[..., 1::2]
    return (((hi & 0xF) << 4) | (lo & 0xF)).astype(np.int8)


def unpack_nibbles(b: np.ndarray) -> np.ndarray:
    """Inverse of :func:`pack_nibbles`: (..., S//2) bytes -> (..., S) int8.

    ``(b << 4) >> 4`` sign-extends the low nibble, ``b >> 4`` the high one
    (int8 arithmetic shifts) — the same decode the kernel does in VMEM."""
    b = b.astype(np.int8)
    lo = ((b << 4) >> 4).astype(np.int8)
    hi = (b >> 4).astype(np.int8)
    out = np.empty(b.shape[:-1] + (b.shape[-1] * 2,), dtype=np.int8)
    out[..., 0::2] = lo
    out[..., 1::2] = hi
    return out


def quantize_rows(p: RowPacked, value_dtype: str) -> QuantizedRowPacked:
    """Quantize a :class:`RowPacked`'s value slots to ``int8`` or ``int4``.

    Symmetric per-(window, row) scaling: scale = amax / qmax over the row's
    slots within the window, q = clip(round(v / scale)).  For ``int4`` the
    slot axis is first padded to even (value 0, position -1 — an exact idle
    slot) and then nibble-packed two slots per byte."""
    if value_dtype not in QUANT_DTYPES:
        raise ValueError(f"value_dtype must be one of {QUANT_DTYPES}, got {value_dtype!r}")
    qmax = QMAX[value_dtype]
    vals = np.asarray(p.values, dtype=np.float32)
    positions = np.asarray(p.row_positions)
    amax = np.abs(vals).max(axis=2)
    scales = np.where(amax > 0, amax / qmax, 1.0).astype(np.float32)
    q = np.clip(np.rint(vals / scales[:, :, None]), -qmax, qmax).astype(np.int8)
    if value_dtype == "int4":
        if q.shape[2] % 2:
            q = np.pad(q, ((0, 0), (0, 0), (0, 1)))
            positions = np.pad(positions, ((0, 0), (0, 0), (0, 1)), constant_values=-1)
        q = pack_nibbles(q)
    return QuantizedRowPacked(
        k=p.k, c=p.c, m=p.m, a=p.a, value_dtype=value_dtype,
        values=q, row_positions=np.ascontiguousarray(positions),
        scales=scales, dense_itemsize=int(np.asarray(p.values).dtype.itemsize),
    )


def dequantize_rows(q: QuantizedRowPacked) -> RowPacked:
    """Expand a quantized pack back to a float32 :class:`RowPacked`.

    The reconstruction is exact w.r.t. the stored integers — ``q * scale``
    in float32 — which is precisely what the fused kernel computes in VMEM,
    so this is the oracle for kernel-vs-reference bit-equality."""
    raw = np.asarray(q.values)
    if q.value_dtype == "int4":
        raw = unpack_nibbles(raw)
    vals = raw.astype(np.float32) * np.asarray(q.scales, np.float32)[:, :, None]
    return RowPacked(
        k=q.k, c=q.c, m=q.m, a=q.a,
        values=vals, row_positions=np.asarray(q.row_positions),
    )


# --------------------------------------------------------------------------
# N:M structured pack (S2TA DBB blocks) — comparison arm
# --------------------------------------------------------------------------


def nm_mask(w: np.ndarray, n: int, block: int) -> np.ndarray:
    """Boolean keep-mask enforcing N:M structure along each row: in every
    block of ``block`` consecutive columns keep the ``n`` largest-magnitude
    entries (S2TA's density-bound block, PAPERS.md).  Columns past the last
    full block are kept as-is."""
    if not 1 <= n <= block:
        raise ValueError(f"need 1 <= n <= block, got n={n} block={block}")
    k, c = w.shape
    c_full = (c // block) * block
    mask = np.ones_like(w, dtype=bool)
    if c_full:
        blk = np.abs(w[:, :c_full]).reshape(k, c_full // block, block)
        # keep the top-n magnitudes per block; argpartition is O(block)
        kth = np.argpartition(blk, block - n, axis=2)[:, :, : block - n]
        bm = np.ones_like(blk, dtype=bool)
        np.put_along_axis(bm, kth, False, axis=2)
        mask[:, :c_full] = bm.reshape(k, c_full)
    return mask


def pack_rows_nm(
    w: np.ndarray, n: int = 2, block: int = 4, m: int = 128, a: int = 16
) -> RowPacked:
    """Prune ``w`` to N:M structure, then row-pack it.  The result is an
    ordinary :class:`RowPacked` — same kernel interface — but with a hard
    per-window slot bound of ``n * ceil(m / block)``, i.e. job count is
    data-independent, the property structured sparsity buys."""
    w = np.asarray(w)
    return pack_rows(np.where(nm_mask(w, n, block), w, 0), m=m, a=a)
