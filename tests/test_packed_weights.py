"""Whole-model row-packed decode (DESIGN.md §7): fused packed-MLP megakernel
vs the jnp oracle across sparsities/dtypes/edge shapes, whole-model
``packed_weights`` serving bit-parity (one-shot and through the Scheduler's
vmapped slot axis), and the kernels/ops autotune-cache bugfixes."""

import dataclasses
import os

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs import get_smoke_config
from repro.core.packing import pack_rows, pack_rows_t, unpack_rows
from repro.core.pruning import prune_tree
from repro.kernels.ops import (
    _KBLK_CACHE,
    _largest_divisor_leq,
    apply_fused_mlp,
    apply_fused_mlp_ref,
    autotune_row_packed,
    choose_k_blk,
    pack_linear_rows,
    pack_linear_rows_t,
)
from repro.models import build_model
from repro.serve import Engine, Request, Scheduler, ServeConfig


def _sparse(rng, k, c, sparsity, dtype=np.float32):
    w = rng.normal(size=(k, c)) * (rng.random((k, c)) > sparsity)
    return w.astype(dtype)


def _mlp_trio(rng, d, ff, sp, a=8):
    wg = _sparse(rng, d, ff, sp)
    wu = _sparse(rng, d, ff, sp)
    wd = _sparse(rng, ff, d, sp)
    return wg, wu, wd, (
        pack_linear_rows(wg, a=a),
        pack_linear_rows(wu, a=a),
        pack_linear_rows_t(wd, a=a),
    )


def _dense_mlp(x, wg, wu, wd):
    xf = np.asarray(x, np.float32)
    return (jax.nn.silu(xf @ wg) * (xf @ wu)) @ wd


# ---------------------------------------------------------------------------
# fused megakernel vs oracle vs dense
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("sp", [0.0, 0.85, 0.99])
@pytest.mark.parametrize("dtype", [np.float32, jnp.bfloat16])
def test_fused_mlp_vs_oracle_sparsity_dtype(sp, dtype):
    """Kernel == jnp oracle == dense SwiGLU at every sparsity, fp32 + bf16
    values (fp32 accumulation either way)."""
    rng = np.random.default_rng(0)
    d, ff, b = 64, 256, 4
    wg, wu, wd, _ = _mlp_trio(rng, d, ff, sp)
    # pack the dtype-rounded weights so kernel and dense reference agree
    wgq, wuq, wdq = (np.asarray(jnp.asarray(w, dtype), np.float32) for w in (wg, wu, wd))
    pg = pack_linear_rows(np.asarray(jnp.asarray(wgq, dtype)), a=8)
    pu = pack_linear_rows(np.asarray(jnp.asarray(wuq, dtype)), a=8)
    pd = pack_linear_rows_t(np.asarray(jnp.asarray(wdq, dtype)), a=8)
    x = jnp.asarray(rng.normal(size=(b, d)), jnp.float32)
    got = np.asarray(apply_fused_mlp(x, pg, pu, pd), np.float32)
    ref = np.asarray(apply_fused_mlp_ref(x, pg, pu, pd), np.float32)
    tol = 1e-4 if dtype == np.float32 else 5e-2
    np.testing.assert_allclose(got, ref, rtol=tol, atol=tol)
    dense = _dense_mlp(x, wgq, wuq, wdq)
    np.testing.assert_allclose(got, dense, rtol=max(tol, 1e-3), atol=max(tol, 1e-3))


@pytest.mark.parametrize("reconstruct", ["onehot", "loop"])
def test_fused_mlp_reconstruct_modes_agree(reconstruct):
    rng = np.random.default_rng(1)
    d, ff = 48, 200  # non-divisible ff: windows padded to 256
    wg, wu, wd, (pg, pu, pd) = _mlp_trio(rng, d, ff, 0.85)
    x = jnp.asarray(rng.normal(size=(3, d)), jnp.float32)
    got = np.asarray(apply_fused_mlp(x, pg, pu, pd, reconstruct=reconstruct), np.float32)
    np.testing.assert_allclose(got, _dense_mlp(x, wg, wu, wd), rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize(
    "d,ff",
    [
        (48, 200),  # ff % 128 != 0: zero-padded lanes must be exact no-ops
        (100, 130),  # both dims ragged
        (64, 96),  # ff < window width
    ],
)
def test_fused_mlp_nondivisible_shapes(d, ff):
    rng = np.random.default_rng(2)
    wg, wu, wd, (pg, pu, pd) = _mlp_trio(rng, d, ff, 0.9)
    x = jnp.asarray(rng.normal(size=(2, d)), jnp.float32)
    got = np.asarray(apply_fused_mlp(x, pg, pu, pd, k_blk=32), np.float32)
    np.testing.assert_allclose(got, _dense_mlp(x, wg, wu, wd), rtol=1e-4, atol=1e-4)


def test_fused_mlp_all_zero_rows():
    """Rows with no non-zeros (empty jobs, position -1 throughout) and even a
    fully-zero gate matrix contribute exact zeros."""
    rng = np.random.default_rng(3)
    d, ff = 64, 128
    wg = _sparse(rng, d, ff, 0.85)
    wg[10:30] = 0.0  # dead reduction rows
    wu = _sparse(rng, d, ff, 0.85)
    wu[:, 40:80] = 0.0  # dead ff lanes
    wd = _sparse(rng, ff, d, 0.85)
    wd[5:60] = 0.0  # dead ff rows of the down projection
    pg, pu, pd = (
        pack_linear_rows(wg, a=8),
        pack_linear_rows(wu, a=8),
        pack_linear_rows_t(wd, a=8),
    )
    x = jnp.asarray(rng.normal(size=(2, d)), jnp.float32)
    got = np.asarray(apply_fused_mlp(x, pg, pu, pd), np.float32)
    np.testing.assert_allclose(got, _dense_mlp(x, wg, wu, wd), rtol=1e-4, atol=1e-4)
    # fully-zero gate: the whole MLP output is exactly zero
    pz = pack_linear_rows(np.zeros_like(wg), a=8)
    got = np.asarray(apply_fused_mlp(x, pz, pu, pd), np.float32)
    np.testing.assert_array_equal(got, np.zeros_like(got))


def test_pack_rows_t_roundtrip():
    """pack_rows_t windows the leading (reduction) dim: unpack gives w.T."""
    rng = np.random.default_rng(4)
    w = _sparse(rng, 130, 64, 0.8)
    np.testing.assert_array_equal(unpack_rows(pack_rows_t(w, a=8)), w.T)
    np.testing.assert_array_equal(unpack_rows(pack_rows(w, a=8)), w)


# ---------------------------------------------------------------------------
# whole-model packed serving: one-shot + Scheduler bit-parity vs dense
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def vusa_pruned():
    cfg = get_smoke_config("vusa_edge")
    params = prune_tree(build_model(cfg).init(jax.random.key(0)), 0.85)
    return cfg, params


@pytest.mark.parametrize("temperature", [0.0, 1.0])
def test_packed_weights_engine_matches_dense(vusa_pruned, temperature):
    """Whole-model packing (qkv/o + fused MLP + untied head): same tokens as
    the dense engine, greedy and sampled."""
    cfg, params = vusa_pruned
    prompts = np.ones((2, 8), np.int32)
    outs = {}
    for packed in (False, "all"):
        sc = ServeConfig(max_len=64, temperature=temperature, packed_weights=packed)
        outs[packed] = Engine(cfg, params, sc).generate(prompts, max_new=8)["tokens"]
    np.testing.assert_array_equal(outs[False], outs["all"])


@pytest.mark.parametrize("temperature", [0.0, 1.0])
def test_packed_weights_scheduler_bit_parity_vs_dense(vusa_pruned, temperature):
    """End to end through the Scheduler (vmapped slot axis): the
    ``packed_weights`` pool must emit the dense pool's exact token streams
    per request/seed, greedy + sampled."""
    cfg, params = vusa_pruned
    rng = np.random.default_rng(7)
    prompts = [rng.integers(0, 100, n).astype(np.int32) for n in (4, 5, 6, 5)]

    def reqs():
        return [
            Request(prompt=prompts[i], max_new=8, seed=30 + i) for i in range(len(prompts))
        ]

    done = {}
    for packed in (False, "all"):
        sc = ServeConfig(max_len=64, temperature=temperature, packed_weights=packed)
        sched = Scheduler(Engine(cfg, params, sc), slots=2, segment=4)
        done[packed] = sched.run(reqs())
    assert sorted(done[False]) == sorted(done["all"])
    for rid in done[False]:
        np.testing.assert_array_equal(
            done["all"][rid].tokens, done[False][rid].tokens, err_msg=f"rid {rid}"
        )


def test_packed_weights_fused_matches_split3(vusa_pruned):
    """Megakernel and 3-dispatch MLP paths emit identical tokens (the perf
    A/B in bench_packed_decode never trades correctness)."""
    cfg, params = vusa_pruned
    prompts = np.ones((2, 6), np.int32)
    outs = {}
    for fused in (True, False):
        sc = ServeConfig(max_len=64, packed_weights="mlp", fused_mlp=fused)
        outs[fused] = Engine(cfg, params, sc).generate(prompts, max_new=8)["tokens"]
    np.testing.assert_array_equal(outs[True], outs[False])


def test_serveconfig_packed_aliases():
    """packed_mlp=True -> scope "mlp"; True -> "all"; junk rejected."""
    assert ServeConfig(packed_mlp=True).packed_weights == "mlp"
    assert ServeConfig(packed_weights=True).packed_weights == "all"
    assert ServeConfig().packed_weights is False
    # an explicit packed_weights wins over the legacy alias
    assert ServeConfig(packed_mlp=True, packed_weights="all").packed_weights == "all"
    with pytest.raises(ValueError):
        ServeConfig(packed_weights="everything")


def test_packed_head_only_when_untied(vusa_pruned):
    from repro.serve.packed import pack_lm_weights

    cfg, params = vusa_pruned
    packed = pack_lm_weights(cfg, params, scope="all")
    assert (packed["head"] is not None) == (not cfg.tie_embeddings)
    assert set(packed["attn"]) == {"wq", "wk", "wv", "wo"}
    tied = dataclasses.replace(cfg, tie_embeddings=True)
    params_tied = {k: v for k, v in params.items() if k != "lm_head"}
    assert pack_lm_weights(tied, params_tied, scope="all")["head"] is None


# ---------------------------------------------------------------------------
# kernels/ops satellite bugfixes
# ---------------------------------------------------------------------------


def test_largest_divisor_snap():
    """REPRO_VUSA_KBLK snaps to the largest divisor <= blk in O(sqrt k) —
    the seed walked down one step at a time (O(k) for prime-ish K)."""
    assert _largest_divisor_leq(1024, 300) == 256
    assert _largest_divisor_leq(360, 100) == 90
    assert _largest_divisor_leq(7919, 100) == 1  # prime K
    assert _largest_divisor_leq(7919, 7919) == 7919
    assert _largest_divisor_leq(100, 1) == 1
    os.environ["REPRO_VUSA_KBLK"] = "300"
    try:
        assert choose_k_blk(1024, 16, 128) == 256
        assert choose_k_blk(7919, 16, 128) == 1
    finally:
        del os.environ["REPRO_VUSA_KBLK"]


def test_tune_key_separates_reconstruct_modes():
    """A k_blk autotuned for "onehot" must not drive "loop" calls: the cache
    key includes reconstruct and slot_chunk (the seed omitted both)."""
    rng = np.random.default_rng(5)
    p = pack_linear_rows(_sparse(rng, 64, 128, 0.85), a=8)
    x = jnp.asarray(rng.normal(size=(4, 64)), jnp.float32)
    before = dict(_KBLK_CACHE)
    try:
        _KBLK_CACHE.clear()
        autotune_row_packed(x, p, iters=1)
        autotune_row_packed(x, p, iters=1, reconstruct="loop")
        autotune_row_packed(x, p, iters=1, slot_chunk=8)
        assert len(_KBLK_CACHE) == 3  # three distinct cache entries
        keys = list(_KBLK_CACHE)
        assert {k[-3] for k in keys} == {"onehot", "loop"}
        assert {k[-2] for k in keys} == {8, 24}
    finally:
        _KBLK_CACHE.clear()
        _KBLK_CACHE.update(before)


def test_tune_key_separates_kblk_env():
    """A k_blk autotuned without REPRO_VUSA_KBLK must not be served after the
    override changes mid-process (and vice versa): the env value is part of
    the cache key — the seed's key omitted it, so a pre-override entry
    silently shadowed an explicit operator override."""
    rng = np.random.default_rng(6)
    p = pack_linear_rows(_sparse(rng, 64, 128, 0.85), a=8)
    x = jnp.asarray(rng.normal(size=(4, 64)), jnp.float32)
    before = dict(_KBLK_CACHE)
    assert "REPRO_VUSA_KBLK" not in os.environ
    try:
        _KBLK_CACHE.clear()
        autotune_row_packed(x, p, iters=1)
        assert len(_KBLK_CACHE) == 1
        os.environ["REPRO_VUSA_KBLK"] = "16"
        autotune_row_packed(x, p, iters=1)
        assert len(_KBLK_CACHE) == 2, (
            "the env override must key its own autotune entry, not reuse "
            "the pre-override one"
        )
        assert {k[-1] for k in _KBLK_CACHE} == {"", "16"}
    finally:
        del os.environ["REPRO_VUSA_KBLK"]
        _KBLK_CACHE.clear()
        _KBLK_CACHE.update(before)
