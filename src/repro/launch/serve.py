"""Serving launcher: load a checkpoint (or random init), optionally prune +
VUSA-pack, and serve batched synthetic requests.

    PYTHONPATH=src python -m repro.launch.serve --arch vusa_edge --smoke --packed
"""

import argparse

import jax
import numpy as np

from ..checkpoint import latest_step, restore
from ..configs import get_config, get_smoke_config
from ..core.pruning import prune_tree
from ..models import build_model
from ..serve import Engine, ServeConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--ckpt", default=None)
    ap.add_argument(
        "--packed", nargs="?", const="mlp", default=False, choices=("mlp", "all"),
        help="VUSA-pack the decode step: bare flag or 'mlp' = MLP trio only "
        "(the pre-§7 behaviour), 'all' = + qkv/o and untied LM head",
    )
    ap.add_argument("--sparsity", type=float, default=None)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--max-new", type=int, default=32)
    ap.add_argument(
        "--mesh", default=None, metavar="DP,TP",
        help="serve on a data x model device mesh (e.g. '2,4'): params/KV "
        "shard over 'data', packed-weight windows over 'model'; '1,1' (or "
        "omitting the flag) is the single-device path",
    )
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    if args.ckpt:
        step = latest_step(args.ckpt)
        if step is not None:
            params = restore(args.ckpt, step, {"params": params})["params"]
            print(f"restored step {step} from {args.ckpt}")
    sp = cfg.sparsity if args.sparsity is None else args.sparsity
    if sp > 0:
        params = prune_tree(params, sp)
    mesh = None
    if args.mesh:
        from .mesh import make_serve_mesh

        mesh = make_serve_mesh(args.mesh)
        print(f"mesh {dict(mesh.shape)} over {len(mesh.devices.flat)} devices")
    eng = Engine(cfg, params, ServeConfig(max_len=args.prompt_len + args.max_new + 8,
                                          packed_weights=args.packed), mesh=mesh)
    prompts = np.ones((args.batch, args.prompt_len), np.int32)
    out = eng.generate(prompts, max_new=args.max_new)
    print(f"prefill {out['prefill_s']*1e3:.1f}ms  decode {out['decode_s']*1e3:.1f}ms  "
          f"{out['tok_per_s']:.0f} tok/s")


if __name__ == "__main__":
    main()
