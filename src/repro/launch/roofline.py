"""Roofline analysis over the dry-run records (EXPERIMENTS.md §Roofline).

Three terms per (arch x shape) cell, all in seconds per step, derived from
the loop-trip-weighted per-device HLO costs (launch/hlo_cost.py):

    compute    = dot_flops / PEAK_FLOPS
    memory     = bytes / HBM_BW
    collective = collective_bytes / LINK_BW

Per-device numbers divided by per-chip peaks == the assignment's
``global / (chips x peak)`` convention.  MODEL_FLOPS uses 6*N*D (train),
2*N*D (prefill) or 2*N*B (decode), with N_active for MoE.

Usage:
    PYTHONPATH=src python -m repro.launch.roofline [--mesh single] [--md]
"""

from __future__ import annotations

import argparse
import json
from pathlib import Path

# TPU v5e per-chip constants (assignment-specified)
PEAK_FLOPS = 197e12  # bf16 FLOP/s
HBM_BW = 819e9  # B/s
LINK_BW = 50e9  # B/s per ICI link

DRYRUN = Path(__file__).resolve().parents[3] / "experiments" / "dryrun"


def model_flops(cfg, shape, n_devices: int) -> float:
    """Per-device useful model FLOPs for one step of this cell."""
    n_active = cfg.active_param_count() if cfg.family == "moe" else cfg.param_count()
    seq = shape.seq_len
    if cfg.family == "encdec" and shape.kind == "prefill":
        seq = min(seq, cfg.enc_frames)  # prefill encodes frames only
    tokens = shape.global_batch * seq
    if shape.kind == "train":
        total = 6.0 * n_active * tokens
    elif shape.kind == "prefill":
        total = 2.0 * n_active * tokens
    else:  # decode: one new token per sequence
        total = 2.0 * n_active * shape.global_batch
    return total / n_devices


def analyze_record(rec: dict) -> dict:
    from ..configs import SHAPES, get_config

    cfg = get_config(rec["arch"])
    shape = SHAPES[rec["shape"]]
    w = rec["weighted"]
    coll_bytes = sum(e["bytes"] for e in w["collectives"].values())
    terms = {
        "compute_s": w["dot_flops"] / PEAK_FLOPS,
        "memory_s": w["bytes"] / HBM_BW,
        "collective_s": coll_bytes / LINK_BW,
    }
    dominant = max(terms, key=terms.get)
    mf = model_flops(cfg, shape, rec["n_devices"])
    bound = max(terms.values())
    out = {
        "arch": rec["arch"],
        "shape": rec["shape"],
        **{k: round(v, 6) for k, v in terms.items()},
        "dominant": dominant.replace("_s", ""),
        "model_flops_dev": mf,
        "hlo_flops_dev": w["dot_flops"],
        "useful_ratio": round(mf / w["dot_flops"], 3) if w["dot_flops"] else None,
        # roofline fraction: useful-compute time / bound time (MFU at the bound)
        "roofline_frac": round((mf / PEAK_FLOPS) / bound, 4) if bound else None,
        "collective_bytes_dev": coll_bytes,
        "temp_bytes_dev": rec["memory"].get("temp_size_in_bytes", 0),
    }
    return out


_ADVICE = {
    "compute": "cut redundant flops: remat policy / flash-backward recompute / replicated-head compute",
    "memory": "cut bytes: fuse elementwise chains, bf16 master-compute path, larger matmul tiles",
    "collective": "cut comm: reshard attention (head/seq axis), reduce-scatter grads, overlap with compute",
}


def build_table(mesh: str = "single", suffix: str = "") -> list:
    rows = []
    for f in sorted(DRYRUN.glob(f"*__{mesh}{suffix}.json")):
        rec = json.loads(f.read_text())
        if rec.get("status") == "ok" and "weighted" in rec:
            rows.append(analyze_record(rec))
        elif rec.get("status") == "skip":
            rows.append({"arch": rec["arch"], "shape": rec["shape"], "dominant": "skip",
                         "note": rec.get("reason", "")[:60]})
    return rows


def to_markdown(rows: list) -> str:
    hdr = ("| arch | shape | compute (s) | memory (s) | collective (s) | dominant | "
           "MODEL/HLO flops | roofline frac | next lever |")
    sep = "|" + "---|" * 9
    lines = [hdr, sep]
    for r in rows:
        if r["dominant"] == "skip":
            lines.append(f"| {r['arch']} | {r['shape']} | — | — | — | skip | — | — | {r.get('note','')} |")
            continue
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['compute_s']:.4f} | {r['memory_s']:.4f} | "
            f"{r['collective_s']:.4f} | **{r['dominant']}** | {r['useful_ratio']} | "
            f"{r['roofline_frac']:.3f} | {_ADVICE[r['dominant']]} |"
        )
    return "\n".join(lines)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--mesh", default="single")
    ap.add_argument("--suffix", default="", help="record suffix, e.g. _opt for hillclimbed")
    ap.add_argument("--md", action="store_true")
    args = ap.parse_args()
    rows = build_table(args.mesh, args.suffix)
    out = Path("experiments") / f"roofline_{args.mesh}{args.suffix}.json"
    out.write_text(json.dumps(rows, indent=1))
    if args.md:
        print(to_markdown(rows))
    else:
        ok = [r for r in rows if r["dominant"] != "skip"]
        ok.sort(key=lambda r: r["roofline_frac"] or 0)
        print(f"{len(ok)} cells analyzed -> {out}")
        print("\nWorst roofline fraction:")
        for r in ok[:5]:
            print(f"  {r['arch']:22s} {r['shape']:12s} frac={r['roofline_frac']:.4f} dom={r['dominant']}")
        coll = sorted(
            ok,
            key=lambda r: -(r["collective_s"] / max(max(r["compute_s"], r["memory_s"]), 1e-12)),
        )
        print("\nMost collective-bound:")
        for r in coll[:5]:
            print(f"  {r['arch']:22s} {r['shape']:12s} coll={r['collective_s']:.4f}s vs "
                  f"max(comp,mem)={max(r['compute_s'], r['memory_s']):.4f}s")


if __name__ == "__main__":
    main()
