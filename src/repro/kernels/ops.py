"""Jit'd public wrappers around the Pallas kernels.

* auto-selects interpret mode off-TPU (this container is CPU-only);
* hosts the pack/apply glue so a model layer can swap a dense matmul for a
  VUSA-packed one in a single call.
"""

from __future__ import annotations

import dataclasses
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..core.packing import BlockPacked, pack_blocks
from .dense_matmul import dense_matmul
from .ref import dense_matmul_ref, vusa_spmm_ref
from .vusa_spmm import vusa_spmm

__all__ = [
    "on_tpu",
    "PackedLinear",
    "pack_linear",
    "apply_packed",
    "apply_packed_ref",
    "matmul",
]


def on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@dataclasses.dataclass
class PackedLinear:
    """Device-resident VUSA-packed weight (K, C) -> jobs of a_blk rows."""

    values: jax.Array  # (T, J, A, Tn)
    row_idx: jax.Array  # (T, J, A) int32
    k: int  # logical K (pre-padding)
    c: int  # logical C (pre-padding)
    k_padded: int = 0

    @property
    def compression(self) -> float:
        dense = self.k * self.c * self.values.dtype.itemsize
        packed = self.values.size * self.values.dtype.itemsize + self.row_idx.size * 4
        return packed / dense


def pack_linear(
    w: np.ndarray, m_blk: int = 32, a_blk: int = 8, tile_n: int = 128
) -> PackedLinear:
    """Host-side pack of a sparse (K, C) weight matrix (pads C to tile_n)."""
    k, c = w.shape
    w = np.asarray(w)
    c_pad = (-c) % tile_n
    k_pad = (-k) % m_blk
    if c_pad or k_pad:
        w = np.pad(w, ((0, k_pad), (0, c_pad)))
    bp: BlockPacked = pack_blocks(w, m_blk=m_blk, a_blk=a_blk, tile_n=tile_n)
    return PackedLinear(
        values=jnp.asarray(bp.values),
        row_idx=jnp.asarray(bp.row_idx),
        k=k,
        c=c,
        k_padded=k + k_pad,
    )


def apply_packed(x: jax.Array, p: PackedLinear, *, interpret: bool | None = None) -> jax.Array:
    """y = x @ W for packed W.  x: (..., K) -> (..., C)."""
    interp = (not on_tpu()) if interpret is None else interpret
    lead = x.shape[:-1]
    xf = x.reshape(-1, x.shape[-1])
    if p.k_padded > p.k:  # weight was K-padded at pack time
        xf = jnp.pad(xf, ((0, 0), (0, p.k_padded - p.k)))
    y = vusa_spmm(xf, p.values, p.row_idx, interpret=interp)
    y = y[..., : p.c]
    return y.reshape(*lead, p.c)


def apply_packed_ref(x: jax.Array, p: PackedLinear) -> jax.Array:
    lead = x.shape[:-1]
    xf = x.reshape(-1, x.shape[-1])
    if p.k_padded > p.k:
        xf = jnp.pad(xf, ((0, 0), (0, p.k_padded - p.k)))
    y = vusa_spmm_ref(xf, p.values, p.row_idx)[..., : p.c]
    return y.reshape(*lead, p.c)


def matmul(x: jax.Array, w: jax.Array, *, interpret: bool | None = None) -> jax.Array:
    """Dense baseline kernel wrapper (pads to MXU-aligned tiles)."""
    interp = (not on_tpu()) if interpret is None else interpret
    m, k = x.shape
    _, n = w.shape
    bm = 128 if m % 128 == 0 else (8 if m % 8 == 0 else 1)
    y = dense_matmul(x, w, bm=bm, interpret=interp)
    return y


# --------------------------------------------------------------------------
# Row-wise (paper-format) packed linear
# --------------------------------------------------------------------------

from ..core.packing import RowPacked, pack_rows  # noqa: E402
from .ref import vusa_packed_ref  # noqa: E402
from .vusa_packed import vusa_packed_matmul  # noqa: E402


@dataclasses.dataclass
class RowPackedLinear:
    """Device-resident row-wise VUSA pack (see kernels/vusa_packed.py)."""

    values: jax.Array  # (T, K, J*A)
    positions: jax.Array  # (T, K, J*A) int8
    k: int
    c: int
    a: int
    m: int = 128  # window width (lanes)

    @property
    def byte_ratio(self) -> float:
        t, k, s = self.values.shape
        dense = self.k * t * self.m * self.values.dtype.itemsize
        return t * k * s * (self.values.dtype.itemsize + 1) / dense


def pack_linear_rows(w: np.ndarray, m: int = 128, a: int = 16) -> RowPackedLinear:
    rp: RowPacked = pack_rows(np.asarray(w), m=m, a=a)
    return RowPackedLinear(
        values=jnp.asarray(rp.values),
        positions=jnp.asarray(rp.row_positions),
        k=rp.k,
        c=rp.c,
        a=a,
        m=m,
    )


def apply_row_packed(
    x: jax.Array, p: RowPackedLinear, *, interpret: bool | None = None, k_blk: int = 256
) -> jax.Array:
    """y = x @ W for row-packed W.  x: (..., K) -> (..., C)."""
    interp = (not on_tpu()) if interpret is None else interpret
    lead = x.shape[:-1]
    xf = x.reshape(-1, x.shape[-1])
    k_blk = min(k_blk, xf.shape[-1])
    while xf.shape[-1] % k_blk:
        k_blk //= 2
    y = vusa_packed_matmul(xf, p.values, p.positions, m=p.m, k_blk=max(k_blk, 1), interpret=interp)
    return y[..., : p.c].reshape(*lead, p.c).astype(x.dtype)


def apply_row_packed_ref(x: jax.Array, p: RowPackedLinear) -> jax.Array:
    lead = x.shape[:-1]
    xf = x.reshape(-1, x.shape[-1])
    y = vusa_packed_ref(xf, p.values, p.positions)
    return y[..., : p.c].reshape(*lead, p.c).astype(x.dtype)
