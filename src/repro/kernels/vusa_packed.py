"""Pallas TPU kernel: VUSA row-wise packed matmul (the paper's format, exact).

Per output *window* of ``M`` lanes (M = 128, one MXU tile of columns), each
reduction row ``k`` stores at most ``A`` non-zero weights as ``A`` value
slots + ``A`` int8 *position* slots — precisely the paper's VUSA row: the
positions are the SPE indices the physical MACs are shifted onto (Fig. 5).
Rows with more than ``A`` non-zeros spill into additional *jobs* of the same
window — the dense-fallback guarantee of Section III-C ("down to N x A, at
which the conditions are guaranteed").

On TPU the fixed 128x128 MXU plays the role of the physical MAC array, so
virtual growth cannot reduce issued MACs; what it does reduce — exactly as
in the paper — is what must be *moved* for a given logical matmul: HBM
weight bytes shrink from ``K*M*dtype`` to ``K*J*A*(dtype + 1)``.  At 85 %
sparsity with (M=128, A=16, J=2) that is ~2.4x less weight traffic, which is
the whole game for memory-bound decode (Edge-AI inference, the paper's
target).  The kernel reconstructs the dense tile in VMEM with ``A*J``
VPU select-accumulate passes (iota==pos one-hot), then issues the dense
MXU matmul — HBM never sees the zeros.

Grid: (output windows, K blocks); K innermost for output-block accumulation.
VMEM per step: x (B, K_blk), vals (K_blk, J*A), pos (K_blk, J*A),
reconstructed W (K_blk, 128) fp32, acc (B, 128) fp32.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["vusa_packed_matmul"]


def _kernel(x_ref, val_ref, pos_ref, y_ref, *, m: int):
    _, k_blk, slots = val_ref.shape

    @pl.when(pl.program_id(1) == 0)
    def _init():
        y_ref[...] = jnp.zeros_like(y_ref)

    lanes = jax.lax.broadcasted_iota(jnp.int32, (k_blk, m), 1)

    def slot(a, w):
        vals = val_ref[0, :, a][:, None].astype(jnp.float32)  # (K_blk, 1)
        pos = pos_ref[0, :, a][:, None].astype(jnp.int32)  # (K_blk, 1)
        return w + jnp.where(lanes == pos, vals, 0.0)  # scatter into lanes

    w = jax.lax.fori_loop(0, slots, slot, jnp.zeros((k_blk, m), jnp.float32))
    y_ref[...] += jnp.dot(
        x_ref[...].astype(jnp.float32), w, preferred_element_type=jnp.float32
    ).astype(y_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret", "k_blk", "m"))
def vusa_packed_matmul(
    x: jax.Array,  # (B, K)
    values: jax.Array,  # (T, K, J*A)  per window: A slots x J jobs per row
    positions: jax.Array,  # (T, K, J*A) int8 lane index per slot (-1 = idle)
    *,
    m: int = 128,
    k_blk: int = 256,
    interpret: bool = True,
) -> jax.Array:
    b, k = x.shape
    t, kk, slots = values.shape
    assert kk == k, (kk, k)
    k_blk = min(k_blk, k)
    assert k % k_blk == 0, (k, k_blk)
    grid = (t, k // k_blk)
    return pl.pallas_call(
        functools.partial(_kernel, m=m),
        grid=grid,
        in_specs=[
            pl.BlockSpec((b, k_blk), lambda i, l: (0, l)),
            pl.BlockSpec((1, k_blk, slots), lambda i, l: (i, l, 0)),
            pl.BlockSpec((1, k_blk, slots), lambda i, l: (i, l, 0)),
        ],
        out_specs=pl.BlockSpec((b, m), lambda i, l: (0, i)),
        out_shape=jax.ShapeDtypeStruct((b, t * m), jnp.float32),
        interpret=interpret,
    )(x, values, positions)
