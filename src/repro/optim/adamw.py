"""AdamW + global-norm clipping, pure JAX pytrees (no optax in-container).

Optimizer state mirrors the parameter tree, so it inherits the parameter
shardings (ZeRO-style: FSDP-sharded params => FSDP-sharded moments).
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

__all__ = ["AdamState", "adamw_init", "adamw_update", "clip_by_global_norm"]


class AdamState(NamedTuple):
    step: jax.Array  # int32 scalar
    mu: dict
    nu: dict


def adamw_init(params) -> AdamState:
    zeros = lambda t: jax.tree_util.tree_map(lambda p: jnp.zeros_like(p, dtype=jnp.float32), t)
    return AdamState(step=jnp.zeros((), jnp.int32), mu=zeros(params), nu=zeros(params))


def clip_by_global_norm(grads, max_norm: float):
    leaves = jax.tree_util.tree_leaves(grads)
    gnorm = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32))) for g in leaves))
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(gnorm, 1e-9))
    return jax.tree_util.tree_map(lambda g: g * scale, grads), gnorm


def adamw_update(
    params,
    grads,
    state: AdamState,
    lr: jax.Array | float,
    *,
    b1: float = 0.9,
    b2: float = 0.95,
    eps: float = 1e-8,
    weight_decay: float = 0.1,
    max_grad_norm: float = 1.0,
):
    grads, gnorm = clip_by_global_norm(grads, max_grad_norm)
    step = state.step + 1
    c1 = 1.0 - b1 ** step.astype(jnp.float32)
    c2 = 1.0 - b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32)
        m = b1 * m + (1 - b1) * g
        v = b2 * v + (1 - b2) * jnp.square(g)
        mhat = m / c1
        vhat = v / c2
        delta = mhat / (jnp.sqrt(vhat) + eps)
        if p.ndim >= 2:  # decoupled weight decay on matrices only
            delta = delta + weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m, v

    flat = jax.tree_util.tree_map(upd, params, grads, state.mu, state.nu)
    new_params = jax.tree_util.tree_map(
        lambda t: t[0], flat, is_leaf=lambda x: isinstance(x, tuple)
    )
    new_mu = jax.tree_util.tree_map(lambda t: t[1], flat, is_leaf=lambda x: isinstance(x, tuple))
    new_nu = jax.tree_util.tree_map(lambda t: t[2], flat, is_leaf=lambda x: isinstance(x, tuple))
    return new_params, AdamState(step=step, mu=new_mu, nu=new_nu), gnorm
