"""Continuous-batching scheduler over the fused decode loop.

``Engine.generate`` serves one fixed batch of equal-length prompts for a
fixed ``max_new``; real traffic is ragged.  :class:`Scheduler` keeps a fixed
pool of in-flight *slots* and alternates two phases (DESIGN.md §5):

  admission   free slots are primed host-side with queued requests whose
              arrival time has passed (per-slot B=1 prefill, per-request
              PRNG key), and the primed cache/key/token are written into
              the slot-stacked state;
  decode      one jitted *segment* — ``segment`` fused ``lax.scan`` steps
              of the whole pool, vmapped over the slot axis — runs on
              device, then syncs once; finished slots (EOS or budget)
              retire and free up for the next admission round.

Each slot is an independent B=1 decode cache stacked on a leading slot axis
(:mod:`repro.models.cache`), with its own scalar ``pos`` and its own PRNG
key stream seeded from the request.  That makes every completed request's
tokens bit-identical to a one-shot ``Engine.generate`` of the same prompt,
seed and temperature at batch 1 — the scheduler changes *when* work runs,
never *what* it computes.  Free slots decode along with the pool (cheaper
than masking the hot path); their output is discarded and their state is
replaced wholesale at the next admission.

The segment length trades sync overhead against retirement latency: the
pool only retires/admits at segment boundaries, so a slot whose request
finished mid-segment decodes (and discards) at most ``segment - 1`` extra
tokens.  The segment shape is static — one compiled program serves the
whole run regardless of arrival pattern.
"""

from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from .engine import Engine

__all__ = ["Request", "Completion", "Scheduler"]


@dataclasses.dataclass
class Request:
    """One generation request.  ``arrival_s`` is an offset from ``run()``
    start (0 = already queued); ``seed`` seeds this request's private PRNG
    stream, mirroring ``ServeConfig.seed`` in one-shot generate."""

    prompt: np.ndarray  # (S,) int32
    max_new: int = 32
    eos_id: Optional[int] = None
    seed: int = 0
    arrival_s: float = 0.0


@dataclasses.dataclass
class Completion:
    rid: int
    tokens: np.ndarray  # (<= max_new,) int32, truncated just after eos_id
    arrival_s: float
    admit_s: float
    finish_s: float

    @property
    def latency_s(self) -> float:
        return self.finish_s - self.arrival_s


@dataclasses.dataclass
class _Slot:
    """Host-side bookkeeping for one in-flight slot."""

    rid: int = -1
    tokens: Optional[List[int]] = None
    first: Optional[jax.Array] = None  # deferred first token (device, (1,1))
    remaining: int = 0
    eos_id: Optional[int] = None
    arrival_s: float = 0.0
    admit_s: float = 0.0

    @property
    def active(self) -> bool:
        return self.rid >= 0


class Scheduler:
    """Continuous-batching run loop over a fused-decode :class:`Engine`."""

    def __init__(self, engine: Engine, slots: int = 4, segment: int = 8):
        if not engine.sc.fused:
            raise ValueError("Scheduler requires a fused-decode engine (ServeConfig.fused)")
        if slots < 1 or segment < 1:
            raise ValueError(f"need slots >= 1 and segment >= 1, got {slots}, {segment}")
        self.eng = engine
        self.model = engine.model
        self.slots = slots
        self.segment = segment
        self._queue: deque = deque()  # (rid, Request), FIFO by submit order
        self._completions: Dict[int, Completion] = {}
        self._next_rid = 0
        self._slot: List[_Slot] = [_Slot() for _ in range(slots)]
        # device state: slot-stacked cache, per-slot tokens and raw key data
        kshape = jax.random.key_data(jax.random.key(0)).shape
        self._cache = self.model.init_slot_cache(slots, engine.sc.max_len)
        self._token = jnp.zeros((slots, 1, 1), jnp.int32)
        self._kdata = jnp.zeros((slots,) + kshape, jnp.uint32)
        # donate the pool state: segments and admissions update it in place
        self._seg = jax.jit(
            self._segment_fn, static_argnums=(4,), donate_argnums=(1, 2, 3)
        )
        self._write = jax.jit(self._write_fn, donate_argnums=(0, 1, 2))
        # run stats
        self._seg_steps = 0
        self._active_slot_steps = 0
        self._decode_s = 0.0
        self._admit_s = 0.0

    # -- submission -----------------------------------------------------------

    def submit(self, req: Request) -> int:
        """Queue a request; returns its request id."""
        prompt = np.asarray(req.prompt, np.int32).reshape(-1)
        budget = prompt.shape[0] + req.max_new + self.segment
        if budget > self.eng.sc.max_len:
            raise ValueError(
                f"prompt({prompt.shape[0]}) + max_new({req.max_new}) + "
                f"segment({self.segment}) = {budget} exceeds max_len "
                f"{self.eng.sc.max_len}"
            )
        if req.max_new < 1:
            raise ValueError("max_new must be >= 1")
        rid = self._next_rid
        self._next_rid += 1
        self._queue.append((rid, dataclasses.replace(req, prompt=prompt)))
        return rid

    # -- jitted segment body --------------------------------------------------

    def _segment_fn(self, params, token, kdata, cache, steps: int):
        """``steps`` decode steps of all slots; returns the emitted token grid
        ``(steps, slots)`` plus the advanced state.  Each slot splits its own
        key and samples at batch 1, exactly as one-shot generate does.

        Free slots decode along with the pool (their output is discarded and
        their whole state is replaced at the next admission), so the hot
        path carries no per-slot masking — a free slot's ``pos`` merely
        drifts until re-admission, and ``attention_decode`` clamps its cache
        writes at ``max_len``."""

        def body(carry, _):
            token, kdata, cache = carry

            def one(tok, kd, c):
                key = jax.random.wrap_key_data(kd)
                key, sub = jax.random.split(key)
                nxt, c2 = self.eng._decode_fn(params, tok, c, sub)
                return nxt, jax.random.key_data(key), c2

            token, kdata, cache = jax.vmap(one)(token, kdata, cache)
            return (token, kdata, cache), token[:, 0, 0]

        (token, kdata, cache), toks = jax.lax.scan(
            body, (token, kdata, cache), None, length=steps
        )
        return token, kdata, cache, toks

    # -- admission / retirement ----------------------------------------------

    @staticmethod
    def _write_fn(cache, token, kdata, i, sub, nxt, kd):
        """Donated single-dispatch write of a primed request into slot ``i``
        (cache + first token + key data in one go); ``i`` is traced, so one
        compilation covers every slot."""
        from ..models.cache import write_slot

        return write_slot(cache, i, sub), token.at[i].set(nxt), kdata.at[i].set(kd)

    def _admit(self, i: int, rid: int, req: Request, now: float) -> bool:
        """Prime request ``rid`` into slot ``i``.  Returns True if the slot is
        now in flight (False = the request completed at admission: max_new
        is 1, or the very first token was EOS)."""
        t0 = time.monotonic()
        key = jax.random.key(req.seed)
        nxt, cache, key = self.eng.prime(req.prompt[None], key)
        self._cache, self._token, self._kdata = self._write(
            self._cache, self._token, self._kdata,
            jnp.int32(i), cache, nxt, jax.random.key_data(key),
        )
        slot = self._slot[i]
        slot.rid, slot.tokens, slot.first = rid, [], nxt
        slot.remaining = req.max_new - 1
        slot.arrival_s, slot.admit_s = req.arrival_s, now
        slot.eos_id = req.eos_id
        if req.max_new == 1 or req.eos_id is not None:
            # these need the first token on the host now; everyone else
            # collects it at the next segment sync, keeping admission async
            slot.tokens = [int(np.asarray(nxt)[0, 0])]
            slot.first = None
            if slot.remaining == 0 or slot.tokens[0] == req.eos_id:
                self._admit_s += time.monotonic() - t0
                self._retire(i, now)
                return False
        self._admit_s += time.monotonic() - t0
        return True

    def _retire(self, i: int, now: float) -> Completion:
        slot = self._slot[i]
        done = Completion(
            rid=slot.rid,
            tokens=np.asarray(slot.tokens, np.int32),
            arrival_s=slot.arrival_s,
            admit_s=slot.admit_s,
            finish_s=now,
        )
        self._completions[slot.rid] = done
        self._slot[i] = _Slot()
        return done

    # -- run loop -------------------------------------------------------------

    def run(self, requests: Optional[List[Request]] = None) -> Dict[int, Completion]:
        """Drain the queue (plus ``requests``), honouring arrival times.
        Returns ``{rid: Completion}``; aggregate numbers via :meth:`stats`."""
        for r in requests or []:
            self.submit(r)
        self._completions = {}
        self._seg_steps = 0
        self._active_slot_steps = 0
        self._decode_s = self._admit_s = 0.0
        t_start = time.monotonic()

        def now() -> float:
            return time.monotonic() - t_start

        while self._queue or any(s.active for s in self._slot):
            # admission: fill free slots with arrived requests, FIFO
            for i, slot in enumerate(self._slot):
                if not self._queue:
                    break
                if slot.active or self._queue[0][1].arrival_s > now():
                    continue
                rid, req = self._queue.popleft()
                while not self._admit(i, rid, req, now()):
                    if not self._queue or self._queue[0][1].arrival_s > now():
                        rid = None
                        break
                    rid, req = self._queue.popleft()
                if rid is None:
                    continue
            active_idx = [i for i, s in enumerate(self._slot) if s.active]
            if not active_idx:
                if not self._queue:  # everything completed at admission
                    continue
                # nothing in flight: sleep until the head request arrives
                wait = self._queue[0][1].arrival_s - now()
                if wait > 0:
                    time.sleep(wait)
                continue
            # decode one segment and sync once
            t0 = time.monotonic()
            self._token, self._kdata, self._cache, toks = self._seg(
                self.eng.params, self._token, self._kdata, self._cache,
                self.segment,
            )
            toks_np = np.asarray(toks)  # (segment, slots) — the one sync
            self._decode_s += time.monotonic() - t0
            self._seg_steps += self.segment
            self._active_slot_steps += len(active_idx) * self.segment
            t = now()
            for i in active_idx:
                slot = self._slot[i]
                if slot.first is not None:  # deferred first token, now free
                    slot.tokens.append(int(np.asarray(slot.first)[0, 0]))
                    slot.first = None
                for tok in toks_np[: min(slot.remaining, self.segment), i]:
                    slot.tokens.append(int(tok))
                    slot.remaining -= 1
                    if (slot.eos_id is not None and tok == slot.eos_id) or slot.remaining == 0:
                        self._retire(i, t)
                        break
        return self._completions

    def stats(self) -> Dict[str, float]:
        """Aggregate serve metrics for the most recent :meth:`run`."""
        done = sorted(self._completions.values(), key=lambda c: c.rid)
        lat = np.asarray([c.latency_s for c in done]) if done else np.zeros(1)
        decoded = sum(max(len(c.tokens) - 1, 0) for c in done)
        busy = self._decode_s + self._admit_s
        return {
            "requests": len(done),
            "decoded_tokens": decoded,
            "sustained_tok_per_s": decoded / max(busy, 1e-9),
            "decode_s": self._decode_s,
            "admit_s": self._admit_s,
            "latency_p50_s": float(np.percentile(lat, 50)),
            "latency_p95_s": float(np.percentile(lat, 95)),
            "slot_occupancy": self._active_slot_steps / max(self.slots * self._seg_steps, 1),
        }
