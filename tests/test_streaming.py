"""Crash-safe async streaming serving tests (DESIGN.md §12).

Covers the AsyncEngine stack end to end:

* streaming parity — tokens streamed per segment sync are bit-identical to
  a blocking ``Scheduler.run`` of the same requests, and to each request's
  terminal Completion;
* crash-recovery differential — a run killed mid-stream (journal holding
  only the fsync'd prefix) recovers into completions bit-identical to a
  crash-free run, across dense / packed / int8-quantized / paged modes;
* watchdog — an injected decode hang converts to one bounded re-queue
  (re-execution bit-identical) and, when persistent, to terminal STALLED
  within the timeout instead of wedging the event loop;
* drain / hot swap — a mid-traffic pack swap drops nothing: in-flight work
  finishes, queued requests ride through, streams stay bit-identical;
* the injectable engine clock (one injection point for engine timings and
  scheduler deadlines) and NaN-safe p99/ITL stats on empty series.

The real-SIGKILL differential (a paced subprocess child killed mid-stream,
see tests/_crash_child.py) is ``slow``; set ``REPRO_CRASH_SEEDS=0,1,2`` to
sweep workload seeds (the nightly chaos sweep does).
"""

import asyncio
import itertools
import json
import os
import signal
import subprocess
import sys
import time

import numpy as np
import pytest

import jax

from _crash_child import mk_reqs  # the workload shared with the SIGKILL child
from repro.configs import get_smoke_config
from repro.core.pruning import prune_tree
from repro.models import build_model
from repro.serve import (
    AsyncEngine,
    Engine,
    FaultConfig,
    Journal,
    JournalTap,
    Request,
    Scheduler,
    ServeConfig,
    Status,
    replay,
)

MODES = {
    "dense": dict(),
    "packed": dict(packed_weights="all"),
    "int8": dict(packed_weights="all", packed_values="int8"),
    "paged": dict(page_size=8),
}


@pytest.fixture(scope="module")
def vusa_pruned():
    cfg = get_smoke_config("vusa_edge")
    params = prune_tree(build_model(cfg).init(jax.random.key(0)), 0.85)
    return cfg, params


@pytest.fixture(scope="module")
def mk_engine(vusa_pruned):
    """Lazy per-mode engine cache: each serve mode pays its jit warmup once
    for the whole module."""
    cfg, params = vusa_pruned
    cache = {}

    def get(mode):
        if mode not in cache:
            cache[mode] = Engine(
                cfg, params, ServeConfig(max_len=64, temperature=1.0, **MODES[mode])
            )
        return cache[mode]

    return get


def _run_ref(eng, reqs, slots=3):
    """Crash-free blocking reference: the token streams every streaming /
    recovery path must reproduce bit-for-bit."""
    sched = Scheduler(eng, slots=slots)
    rids = [sched.submit(r) for r in reqs]
    done = sched.run()
    assert all(done[r].status is Status.OK for r in rids)
    return {r: [int(t) for t in done[r].tokens] for r in rids}


@pytest.fixture(scope="module")
def ref_tokens(mk_engine):
    cache = {}

    def get(mode, n=6, seed=7):
        key = (mode, n, seed)
        if key not in cache:
            cache[key] = _run_ref(mk_engine(mode), mk_reqs(n, seed=seed))
        return cache[key]

    return get


def _go(coro, timeout=300):
    return asyncio.run(asyncio.wait_for(coro, timeout))


async def _consume(stream):
    toks = [t async for t in stream]
    comp = await stream.completion()
    return toks, comp


# ---------------------------------------------------------------------------
# streaming parity + SLO stats
# ---------------------------------------------------------------------------


def test_async_streaming_matches_blocking_run(mk_engine, ref_tokens):
    """Streamed tokens == Completion tokens == a blocking run's tokens, per
    request; lifetime SLO stats are populated and ordered."""
    eng, ref = mk_engine("dense"), ref_tokens("dense")

    async def go():
        # segment < max_new so every stream spans >1 sync and therefore has
        # at least one real inter-emission interval — ITL samples observed
        # gaps only (§13), so a stream that surfaces whole reads NaN
        sched = Scheduler(eng, slots=3, segment=4)
        async with AsyncEngine(sched) as engine:
            streams = [engine.submit(r) for r in mk_reqs(6)]
            outs = [await _consume(s) for s in streams]
            st = engine.stats()
        return outs, st

    outs, st = _go(go())
    for rid, (toks, comp) in enumerate(outs):
        assert comp.status is Status.OK
        assert toks == [int(t) for t in comp.tokens]  # stream == completion
        assert toks == ref[rid]  # stream == blocking run
    assert st["requests_completed"] == 6
    assert st["journal_records"] == 0  # memory-only engine
    for k in ("ttft", "latency", "itl"):
        p50, p99 = st[f"{k}_p50_s"], st[f"{k}_p99_s"]
        assert np.isfinite(p50) and np.isfinite(p99) and 0 <= p50 <= p99


def test_stats_nan_safe_on_empty():
    """p50/p95/p99 series must read NaN when nothing completed — an idle
    server is not an infinitely fast one.  No engine needed: the stats path
    never touches the device."""

    class _NullSched:
        _clock = staticmethod(time.monotonic)

        def stats(self):
            # the engine's own (NaN) series must win over merged sched keys
            return {"itl_p99_s": 0.0}

        def itl_samples(self):
            return []

    engine = AsyncEngine(_NullSched())
    st = engine.stats()
    assert st["requests_completed"] == 0
    for k in ("ttft_p99_s", "latency_p99_s", "itl_p50_s", "itl_p99_s"):
        assert np.isnan(st[k])


def test_scheduler_stats_have_p99_and_itl(mk_engine, ref_tokens):
    eng = mk_engine("dense")
    ref_tokens("dense")  # ensure at least one run's warmup happened
    sched = Scheduler(eng, slots=2, segment=4)
    for r in mk_reqs(3):
        sched.submit(r)
    sched.run()
    st = sched.stats()
    for k in ("latency_p99_s", "ttft_p99_s", "itl_p50_s", "itl_p95_s", "itl_p99_s"):
        assert k in st and np.isfinite(st[k])
    assert st["ttft_p50_s"] <= st["ttft_p99_s"]
    # every emission EVENT after a stream's first carries exactly one ITL
    # sample (tokens surfacing together at one sync share a wall-clock
    # instant; the first event's latency is the TTFT, not an ITL) — here
    # each 8-token stream surfaces as two 4-token syncs, so one sample each
    assert len(sched.itl_samples()) == len(sched._completions)


# ---------------------------------------------------------------------------
# injectable clock (engine + scheduler share one injection point)
# ---------------------------------------------------------------------------


def test_engine_clock_injectable(vusa_pruned, mk_engine, monkeypatch):
    cfg, params = vusa_pruned
    ticks = itertools.count()

    def clk():
        return float(next(ticks))

    eng2 = Engine(cfg, params, ServeConfig(max_len=64), clock=clk)
    assert eng2._clock is clk
    # the scheduler defaults to the ENGINE's clock: one injection point
    assert Scheduler(eng2, slots=1)._clock is clk
    assert Scheduler(eng2, slots=1, clock=time.monotonic)._clock is time.monotonic

    # generate() timings come from the injected clock, not wall time: with a
    # unit-step clock every measured phase is an exact whole number >= 1
    eng = mk_engine("dense")
    monkeypatch.setattr(eng, "_clock", clk)
    out = eng.generate(np.ones((1, 8), np.int32), max_new=4)
    assert out["prefill_s"] >= 1.0 and out["prefill_s"] == int(out["prefill_s"])
    assert out["decode_s"] >= 1.0 and out["decode_s"] == int(out["decode_s"])


# ---------------------------------------------------------------------------
# crash-recovery differential (the §12 acceptance bar)
# ---------------------------------------------------------------------------


class _Boom(RuntimeError):
    """Stands in for the process dying mid-run."""


def _crash_run(eng, reqs, path, crash_at_sync):
    """Journal a run and kill it after ``crash_at_sync`` fsync'd syncs — the
    exception fires BEFORE the next sync's journal tap, so everything after
    the last fsync is lost, exactly like a real crash.  Returns nothing
    useful: the scheduler state dies with the 'process'.  segment=2 keeps
    syncs frequent so the crash lands mid-stream (tokens are segment-
    independent by the parity invariant, so the reference still applies)."""
    journal = Journal(path)
    tap = JournalTap(journal)
    sched = Scheduler(eng, slots=3, segment=2)
    for r in reqs:
        tap.note_submit(sched.submit(r), r)
    journal.sync()  # models: the submits' durability point already passed
    syncs = 0

    def crash(s):
        nonlocal syncs
        syncs += 1
        if syncs > crash_at_sync:
            raise _Boom()
        tap.on_sync(s)

    with pytest.raises(_Boom):
        sched.run(on_sync=crash)
    journal._fh.close()  # no close marker, no sync: the journal reads as a crash


@pytest.mark.parametrize("mode", list(MODES))
def test_crash_recovery_bit_parity(mk_engine, ref_tokens, tmp_path, mode):
    """Kill a journaled run mid-stream, recover into a fresh scheduler, and
    require every completion — journal-proven and re-executed alike — to be
    bit-identical to a crash-free run.  Streams re-attach via ``stream_for``
    and replay in full."""
    eng, ref = mk_engine(mode), ref_tokens(mode)
    reqs = mk_reqs(6)
    path = tmp_path / "journal"
    _crash_run(eng, reqs, path, crash_at_sync=5)

    mid = replay(path)
    assert mid.pending, "crash too late: nothing left in flight"
    assert mid.completed, "crash too early: no journal-proven completions"
    assert not mid.closed  # no close marker: reads as a crash

    async def recover_and_drain():
        sched2 = Scheduler(eng, slots=3)
        engine = AsyncEngine.recover(path, sched2)
        assert set(engine.recovered_rids) == set(mid.pending)
        async with engine:
            outs = {}
            for rid in range(len(reqs)):
                toks, comp = await _consume(engine.stream_for(rid))
                outs[rid] = (toks, comp)
            st = engine.stats()
        return outs, st

    outs, st = _go(recover_and_drain())
    for rid in range(len(reqs)):
        toks, comp = outs[rid]
        assert comp.status is Status.OK
        assert toks == ref[rid], f"{mode}: rid {rid} diverged after recovery"
    assert st["recovered_requests"] == len(mid.pending)
    # the closed journal now proves the full crash-free history by itself
    final = replay(path)
    assert final.closed and final.clean and not final.pending
    assert {rid: list(t) for rid, (_, t) in final.completed.items()} == ref
    assert all(s is Status.OK for s, _ in final.completed.values())


def test_recovery_from_submits_only(mk_engine, ref_tokens, tmp_path):
    """Crash before the first post-admission sync: the journal holds only
    submit records, recovery re-executes everything from scratch."""
    eng, ref = mk_engine("dense"), ref_tokens("dense")
    reqs = mk_reqs(6)
    path = tmp_path / "journal"
    _crash_run(eng, reqs, path, crash_at_sync=0)
    mid = replay(path)
    assert sorted(mid.pending) == list(range(6)) and not mid.completed

    sched2 = Scheduler(eng, slots=3)
    engine = AsyncEngine.recover(path, sched2)
    assert engine.recovered_rids == list(range(6))

    async def go():
        async with engine:
            return {r: await _consume(engine.stream_for(r)) for r in range(6)}

    outs = _go(go())
    assert {r: toks for r, (toks, _) in outs.items()} == ref


def test_paged_mirror_verified_at_every_sync(mk_engine):
    """The paged host mirror (block table + positions) must agree with the
    device arena at every segment sync — the invariant recovery re-admission
    relies on (DESIGN.md §12)."""
    eng = mk_engine("paged")
    sched = Scheduler(eng, slots=3)
    for r in mk_reqs(6):
        sched.submit(r)
    checks = []

    def hook(s):
        checks.append(s.verify_paged_mirror())

    done = sched.run(on_sync=hook)
    assert checks and all(checks)
    assert all(c.status is Status.OK for c in done.values())


# ---------------------------------------------------------------------------
# watchdog: injected hangs -> bounded re-queue -> terminal STALLED
# ---------------------------------------------------------------------------


def _uniform_reqs(seeds, plen=8, max_new=8):
    rng = np.random.default_rng(11)
    prompts = {s: rng.integers(1, 90, size=plen).astype(np.int32) for s in seeds}
    return [Request(prompt=prompts[s], max_new=max_new, seed=s) for s in seeds]


def _warm(eng, n):
    """Pre-compile the prefill/segment programs a fresh scheduler will need
    so watchdog timeouts measure stalls, not jit compiles.  Returns the
    scheduler with ``n`` warmup rids consumed."""
    sched = Scheduler(eng, slots=2)
    for r in _uniform_reqs(range(100, 100 + n)):
        sched.submit(r)
    done = sched.run()
    assert all(c.status is Status.OK for c in done.values())
    return sched


def test_watchdog_transient_hang_requeues_bit_identical(mk_engine, monkeypatch):
    """A one-shot decode hang: the watchdog aborts, every in-flight request
    gets its single bounded re-queue, the re-execution emits bit-identical
    streams, and all requests end OK."""
    eng = mk_engine("dense")
    reqs = _uniform_reqs([0, 1])
    ref = _run_ref(eng, reqs, slots=2)  # rids 0,1 on a clean scheduler
    sched = _warm(eng, 2)
    # the AsyncEngine allocates rids from 0 (the warmup epoch's completions
    # were reset), so the hang targets the first submitted request
    monkeypatch.setattr(eng.sc, "faults", FaultConfig(decode_hang_rids=(0,)))

    async def go():
        async with AsyncEngine(sched, watchdog_s=0.75) as engine:
            streams = [engine.submit(r) for r in reqs]
            return [await _consume(s) for s in streams]

    t0 = time.monotonic()
    outs = _go(go(), timeout=120)
    for (toks, comp), want in zip(outs, ref.values()):
        assert comp.status is Status.OK
        assert toks == want  # the re-queued execution replayed bit-identically
    assert 0 in sched._stall_retried  # the hang really fired and re-queued
    assert time.monotonic() - t0 < 60


def test_watchdog_persistent_hang_is_terminal_stalled(mk_engine, monkeypatch):
    """A persistent hang exhausts the bounded re-queue: terminal STALLED
    within ~2 watchdog windows, and the engine keeps serving afterwards."""
    eng = mk_engine("dense")
    (hang_req,) = _uniform_reqs([0])
    (after_req,) = _uniform_reqs([5])
    (ref_after,) = _run_ref(eng, _uniform_reqs([5]), slots=2).values()
    sched = _warm(eng, 1)
    # hang the first async-submitted request (rid 0; see transient test)
    monkeypatch.setattr(
        eng.sc,
        "faults",
        FaultConfig(decode_hang_rids=(0,), decode_stall_once=False),
    )

    async def go():
        async with AsyncEngine(sched, watchdog_s=0.5) as engine:
            toks, comp = await _consume(engine.submit(hang_req))
            stalled = engine.stats()["stalled"]
            # the stall is contained: fresh traffic still serves cleanly
            toks2, comp2 = await _consume(engine.submit(after_req))
        return toks, comp, stalled, toks2, comp2

    t0 = time.monotonic()
    toks, comp, stalled, toks2, comp2 = _go(go(), timeout=120)
    assert comp.status is Status.STALLED
    assert toks == []  # a STALLED request never streamed unproven tokens
    assert stalled >= 1
    assert comp2.status is Status.OK and toks2 == ref_after
    assert time.monotonic() - t0 < 60


# ---------------------------------------------------------------------------
# drain / zero-downtime hot swap
# ---------------------------------------------------------------------------


def test_hot_swap_drops_nothing(mk_engine, ref_tokens, tmp_path):
    """A pack hot-swap mid-traffic: in-flight requests finish, queued ones
    ride through the swap, admission is closed only while draining, and
    every stream is bit-identical to a swap-free run (same params => same
    pack => same tokens).  The swap fingerprint lands in the journal."""
    eng, ref = mk_engine("packed"), ref_tokens("packed")
    reqs = mk_reqs(6)
    path = tmp_path / "journal"

    async def go():
        sched = Scheduler(eng, slots=3)
        async with AsyncEngine(sched, journal=Journal(path)) as engine:
            streams = [engine.submit(r) for r in reqs]
            first0 = await streams[0].__anext__()  # wave 1 is mid-flight now
            swap = asyncio.ensure_future(engine.hot_swap(timeout_s=120))
            await asyncio.sleep(0)  # let hot_swap close admission
            if engine.sched.draining:
                with pytest.raises(RuntimeError, match="draining"):
                    engine.submit(mk_reqs(7)[6])
            assert await swap is True  # a pack was really rebuilt + re-jitted
            outs = [await _consume(s) for s in streams]
            outs[0] = ([first0] + outs[0][0], outs[0][1])  # re-attach the peeked token
            late = [engine.submit(r) for r in mk_reqs(8)[6:]]  # post-swap traffic
            outs += [await _consume(s) for s in late]
        return outs

    outs = _go(go())
    assert all(comp.status is Status.OK for _, comp in outs)
    for rid, (toks, _) in enumerate(outs[:6]):
        assert toks == ref[rid], f"rid {rid} changed across the hot swap"
    state = replay(path)
    assert state.closed and sorted(state.completed) == list(range(8))
    swaps = [
        r
        for r in _raw_records(path)
        if r.get("t") == "swap" and isinstance(r.get("fp"), int)
    ]
    assert len(swaps) == 1


def _raw_records(path):
    from repro.checkpoint.ckpt import read_records

    payloads, _, _ = read_records(path)
    return [json.loads(p) for p in payloads]


def test_drain_and_resume_preserves_queue(mk_engine, ref_tokens):
    """drain() finishes in-flight work and parks the queue; resume() serves
    the parked requests untouched."""
    eng, ref = mk_engine("dense"), ref_tokens("dense")
    reqs = mk_reqs(6)

    async def go():
        sched = Scheduler(eng, slots=3)
        async with AsyncEngine(sched) as engine:
            streams = [engine.submit(r) for r in reqs]
            first0 = await streams[0].__anext__()
            assert await engine.drain(timeout_s=120) is True
            # drained: nothing in flight, but undelivered requests survive
            assert not any(s.active for s in sched._slot)
            engine.resume()
            outs = [await _consume(s) for s in streams]
            outs[0] = ([first0] + outs[0][0], outs[0][1])  # re-attach the peeked token
        return outs

    outs = _go(go())
    assert all(comp.status is Status.OK for _, comp in outs)
    assert [toks for toks, _ in outs] == [ref[r] for r in range(6)]


# ---------------------------------------------------------------------------
# real SIGKILL differential (slow; REPRO_CRASH_SEEDS sweeps workloads)
# ---------------------------------------------------------------------------


def _crash_seeds():
    return [int(s) for s in os.environ.get("REPRO_CRASH_SEEDS", "7").split(",")]


@pytest.mark.slow
@pytest.mark.parametrize("seed", _crash_seeds())
def test_sigkill_crash_recovery(mk_engine, ref_tokens, tmp_path, seed):
    """The no-simulation version: a subprocess server (decode-paced so the
    kill window is wide) is SIGKILLed once the journal proves tokens are
    durable; this process recovers the journal and must reproduce the
    crash-free streams bit-for-bit."""
    path = tmp_path / "journal"
    child = os.path.join(os.path.dirname(__file__), "_crash_child.py")
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env = dict(os.environ)
    env["PYTHONPATH"] = os.pathsep.join(
        p for p in (os.path.join(root, "src"), env.get("PYTHONPATH")) if p
    )
    proc = subprocess.Popen(
        [sys.executable, child, str(path), str(seed), "6"],
        cwd=root,
        env=env,
        stdout=subprocess.PIPE,
        stderr=subprocess.STDOUT,
        text=True,
    )
    try:
        deadline = time.monotonic() + 300
        while True:
            if proc.poll() is not None:
                pytest.fail(
                    "child exited before the kill "
                    f"(rc={proc.returncode}):\n{proc.communicate()[0]}"
                )
            if path.exists():
                state = replay(path)
                durable = sum(len(t) for t in state.partial.values()) + sum(
                    len(t) for _, t in state.completed.values()
                )
                if durable >= 4:  # tokens provably on disk: kill mid-stream
                    break
            if time.monotonic() > deadline:
                proc.kill()
                pytest.fail(
                    "journal never accumulated tokens:\n" + proc.communicate()[0]
                )
            time.sleep(0.25)
        proc.send_signal(signal.SIGKILL)
        proc.wait(timeout=60)
    finally:
        if proc.poll() is None:
            proc.kill()
            proc.wait(timeout=60)

    mid = replay(path)
    assert not mid.closed and mid.pending  # killed mid-stream, for real
    ref = ref_tokens("dense", n=6, seed=seed)

    async def go():
        sched = Scheduler(mk_engine("dense"), slots=3)
        engine = AsyncEngine.recover(path, sched)
        async with engine:
            return {r: await _consume(engine.stream_for(r)) for r in range(6)}

    outs = _go(go())
    for rid in range(6):
        toks, comp = outs[rid]
        assert comp.status is Status.OK
        assert toks == ref[rid], f"seed {seed}: rid {rid} diverged after SIGKILL"
    final = replay(path)
    assert final.closed and not final.pending
    assert {rid: list(t) for rid, (_, t) in final.completed.items()} == ref
