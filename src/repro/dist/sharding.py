"""Logical-axis sharding rules: ParamSpec trees -> NamedSharding trees.

One table maps each *logical* axis name (the strings in every
:class:`repro.models.common.ParamSpec`) to a *mesh* axis.  The policy is the
standard 2D TP x FSDP layout:

* ``model`` carries tensor/expert parallelism — vocab, ff, attention heads,
  experts, SSM inner dims are split so each device holds a slice of every
  layer's wide matmuls;
* ``data`` carries data parallelism and, for parameters, FSDP — the
  ``embed`` (d_model) axis of weights is sharded over ``data`` so optimizer
  state and parameters scale out with the DP degree;
* an optional ``pod`` axis (multi-pod meshes) is pure data parallelism:
  parameters are replicated across pods, batches are split.

Every rule degrades gracefully: a dimension is only sharded when the mesh
axis exists, has size > 1, is not already used by an earlier dimension of
the same tensor, and divides the dimension evenly.  Anything else falls
back to replication — never an error (see tests/test_dist.py).
"""

from __future__ import annotations

import math
from typing import Dict, Optional

import jax
from jax.sharding import NamedSharding, PartitionSpec

from ..models.common import ParamSpec

__all__ = [
    "act_rules",
    "param_sharding",
    "params_shardings",
    "batch_sharding",
    "batch_shardings",
    "serve_shardings",
]


# logical parameter axis -> mesh axis (None = always replicate)
PARAM_RULES: Dict[str, Optional[str]] = {
    # tensor parallel (wide matmul dims)
    "vocab": "model",
    "ff": "model",
    "heads": "model",
    "kv_heads": "model",
    "experts": "model",
    "ssm_inner": "model",
    "rglru": "model",
    # FSDP: shard the shared d_model axis over the data axis
    "embed": "data",
    # deliberately replicated (second occurrence of an already-used dim
    # family, or too small to matter)
    "rglru_out": None,
    "embed2": None,
}


def act_rules(mesh) -> Dict[str, object]:
    """Activation-sharding rules consumed by ``models.common.shard``.

    Activations stay replicated on the embed axis (TP shards the weights and
    all-reduces the products); the batch axis spans every pure-DP mesh axis.
    """
    batch_axes = tuple(a for a in ("pod", "data") if a in mesh.shape)
    return {
        "batch": batch_axes if len(batch_axes) > 1 else (batch_axes[0] if batch_axes else None),
        "ff": "model",
        "heads": "model",
        "kv_heads": "model",
        "vocab": "model",
        "ssm_inner": "model",
        "rglru": "model",
        "embed": None,
    }


def _divisible(dim: int, mesh, axes) -> bool:
    size = math.prod(mesh.shape[a] for a in axes)
    return size > 1 and dim % size == 0


def param_sharding(spec: ParamSpec, mesh) -> NamedSharding:
    """NamedSharding for one ParamSpec under PARAM_RULES (with fallback)."""
    used = set()
    parts = []
    for dim, name in zip(spec.shape, spec.axes):
        axis = PARAM_RULES.get(name) if name else None
        if (
            axis is not None
            and axis in mesh.shape
            and axis not in used
            and _divisible(dim, mesh, (axis,))
        ):
            parts.append(axis)
            used.add(axis)
        else:
            parts.append(None)
    return NamedSharding(mesh, PartitionSpec(*parts))


def params_shardings(spec_tree, mesh):
    """Map a ParamSpec tree to a NamedSharding tree."""
    return jax.tree_util.tree_map(
        lambda s: param_sharding(s, mesh),
        spec_tree,
        is_leaf=lambda x: isinstance(x, ParamSpec),
    )


def _batch_axes(mesh):
    return tuple(a for a in ("pod", "data") if a in mesh.shape)


def batch_sharding(mesh, batch_size: int, ndim: int) -> NamedSharding:
    """Shard dim 0 (the batch) over the DP mesh axes, replicate the rest."""
    axes = _batch_axes(mesh)
    if ndim == 0 or not axes or not _divisible(batch_size, mesh, axes):
        return NamedSharding(mesh, PartitionSpec(*([None] * ndim)))
    first = axes if len(axes) > 1 else axes[0]
    return NamedSharding(mesh, PartitionSpec(first, *([None] * (ndim - 1))))


def batch_shardings(mesh, batch: Dict[str, object]) -> Dict[str, NamedSharding]:
    """Per-entry batch shardings for a dict of arrays / ShapeDtypeStructs."""
    return {
        k: batch_sharding(mesh, v.shape[0] if len(v.shape) else 1, len(v.shape))
        for k, v in batch.items()
    }


def serve_shardings(cache_tree, mesh, batch_size: int):
    """Shardings for a decode-cache pytree: shard the batch dim over DP.

    Cache leaves are layer-stacked — the batch dim is whichever of the first
    two dims equals ``batch_size`` (scalars like ``pos`` stay replicated).
    """
    axes = _batch_axes(mesh)
    first = (axes if len(axes) > 1 else axes[0]) if axes else None

    def one(s):
        parts = [None] * len(s.shape)
        if first is not None and _divisible(batch_size, mesh, axes):
            for i, d in enumerate(s.shape[:2]):
                if d == batch_size:
                    parts[i] = first
                    break
        return NamedSharding(mesh, PartitionSpec(*parts))

    return jax.tree_util.tree_map(one, cache_tree)
