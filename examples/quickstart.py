"""Quickstart: the full VUSA loop in two minutes on CPU.

1. train a tiny LM with iterative magnitude pruning to 85 % sparsity,
2. pack its whole decode step (MLP + qkv/o + LM head) into the paper's
   row-wise VUSA format,
3. serve it with the Pallas kernels (fused packed-MLP megakernel),
4. check: identical greedy outputs, ~2.5x fewer weight bytes.

Run:  PYTHONPATH=src python examples/quickstart.py
"""

import numpy as np

from repro.configs import get_smoke_config
from repro.core.growth import p_grow
from repro.serve import Engine, ServeConfig
from repro.train import TrainConfig, Trainer, TrainHParams


def main():
    cfg = get_smoke_config("vusa_edge")
    print(f"== training {cfg.name} to {cfg.sparsity:.0%} unstructured sparsity ==")
    tc = TrainConfig(
        steps=20,
        global_batch=4,
        seq_len=32,
        prune_begin=6,
        prune_end=16,
        prune_every=2,
        token_range=32,
        hp=TrainHParams(lr=2e-3, warmup=2, total_steps=20),
        log_every=5,
    )
    out = Trainer(cfg, tc).train()
    print(f"final loss {out['final_loss']:.3f}, sparsity {out['sparsity']:.2%}")

    print("\n== serving: dense vs whole-model VUSA-packed ==")
    prompts = np.ones((2, 8), np.int32)
    dense = Engine(cfg, out["params"], ServeConfig(max_len=64)).generate(prompts, max_new=12)
    packed_eng = Engine(cfg, out["params"], ServeConfig(max_len=64, packed_weights="all"))
    packed = packed_eng.generate(prompts, max_new=12)

    match = (dense["tokens"] == packed["tokens"]).all()
    print(f"greedy outputs identical: {match}")
    assert match

    from repro.serve.packed import packed_byte_ratios

    ratios = packed_byte_ratios(packed_eng._packed)
    print(f"decode-step weight bytes: packed/dense = {ratios['total']:.3f} "
          f"(mlp {ratios['w_gate']:.2f}, attn {ratios['wq']:.2f}, head "
          f"{ratios.get('lm_head', float('nan')):.2f})")
    print(
        f"growth model check: P(row of 128 fits 16 slots @ 85% sparsity) = "
        f"{p_grow(1, 128, 16, 0.15):.3f} (1 job almost never suffices -> expect ~2-3 jobs)"
    )
    print("\nquickstart OK")


if __name__ == "__main__":
    main()
