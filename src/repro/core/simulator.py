"""Cycle-accurate-analytical simulator for WS systolic arrays (SCALE-Sim-like)
and for the VUSA composite (paper Section V-C methodology).

SCALE-Sim's analytical weight-stationary model for one R x C_arr fold:

    fill   = R                 (weights trickle down row-by-row)
    stream = B                 (B input rows enter from the left)
    drain  = R + C_arr - 2     (last partial sum exits bottom-right)

    cycles_per_fold = 2R + C_arr + B - 2

A GEMM ``(B x K) @ (K x C)`` needs ``ceil(K/R) * ceil(C/C_arr)`` folds.

For VUSA, the folds over the output-column dimension are replaced by the
scheduler's jobs: a job of width ``w`` behaves like one fold of a standard
``N x w`` array (fill is still N — weights load per-row — and drain scales
with the *virtual* width ``w``):

    cycles_job(w) = 2N + w + B - 2
"""

from __future__ import annotations

import dataclasses
import math
from typing import Iterable, List, Sequence, Tuple

import numpy as np

from .vusa import Schedule, schedule_matrix

__all__ = [
    "Gemm",
    "ws_cycles",
    "gemm_cycles_standard",
    "gemm_cycles_vusa",
    "model_cycles_standard",
    "model_cycles_vusa",
    "conv2d_gemm",
    "VusaRunStats",
]


@dataclasses.dataclass(frozen=True)
class Gemm:
    """One (B x K) @ (K x C) matmul job; ``macs`` = B*K*C."""

    B: int  # streamed dimension (output pixels / tokens)
    K: int  # reduction dimension (rows of the stationary weight tile)
    C: int  # output features   (columns of the stationary weight tile)
    name: str = ""

    @property
    def macs(self) -> int:
        return self.B * self.K * self.C

    @property
    def ops(self) -> int:
        return 2 * self.macs


def conv2d_gemm(
    out_h: int, out_w: int, in_ch: int, out_ch: int, kh: int, kw: int, name: str = "",
    groups: int = 1,
) -> List[Gemm]:
    """im2col lowering of a conv layer to GEMM(s).

    Depthwise/grouped convs lower to ``groups`` independent GEMMs with
    ``in_ch/groups`` reduction channels and ``out_ch/groups`` filters each.
    """
    if groups == 1:
        return [Gemm(B=out_h * out_w, K=in_ch * kh * kw, C=out_ch, name=name)]
    gic, goc = in_ch // groups, out_ch // groups
    return [
        Gemm(B=out_h * out_w, K=gic * kh * kw, C=goc, name=f"{name}.g{g}")
        for g in range(groups)
    ]


def ws_cycles(B: int, R: int, C_arr: int) -> int:
    """Cycles for one weight-stationary fold on an R x C_arr array."""
    return 2 * R + C_arr + B - 2


def gemm_cycles_standard(g: Gemm, R: int, C_arr: int) -> int:
    folds = math.ceil(g.K / R) * math.ceil(g.C / C_arr)
    return folds * ws_cycles(g.B, R, C_arr)


@dataclasses.dataclass
class VusaRunStats:
    """Aggregated VUSA execution statistics for a workload."""

    cycles: int = 0
    jobs: int = 0
    # columns of load covered per achieved window width (index = width)
    load_by_width: np.ndarray | None = None

    def load_split(self) -> np.ndarray:
        t = self.load_by_width.sum()
        return self.load_by_width / max(t, 1)


def gemm_cycles_vusa(
    g: Gemm, mask: np.ndarray, N: int, M: int, A: int
) -> Tuple[int, Schedule]:
    """Cycles to run one GEMM with weight mask ``mask`` (K x C bool) on VUSA."""
    assert mask.shape == (g.K, g.C), (mask.shape, (g.K, g.C))
    sched = schedule_matrix(mask, N, M, A)
    cycles = 0
    for tile in sched.jobs:
        for job in tile:
            cycles += ws_cycles(g.B, N, job.width)
    return cycles, sched


def model_cycles_standard(gemms: Iterable[Gemm], R: int, C_arr: int) -> int:
    return sum(gemm_cycles_standard(g, R, C_arr) for g in gemms)


def model_cycles_vusa(
    gemms: Sequence[Gemm],
    masks: Sequence[np.ndarray],
    N: int,
    M: int,
    A: int,
) -> VusaRunStats:
    stats = VusaRunStats(load_by_width=np.zeros(M + 1))
    for g, mask in zip(gemms, masks):
        cycles, sched = gemm_cycles_vusa(g, mask, N, M, A)
        stats.cycles += cycles
        stats.jobs += sched.n_jobs
        for tile in sched.jobs:
            for job in tile:
                stats.load_by_width[job.width] += job.width * g.B  # weight by work
    return stats
