"""Mesh construction.  Functions, not module constants — importing this
module never touches jax device state.
"""

from __future__ import annotations

import jax

__all__ = ["make_production_mesh", "make_local_mesh"]


def make_production_mesh(*, multi_pod: bool = False):
    """Production mesh: 16x16 (one 256-chip pod) or 2x16x16 (two pods).

    The ``pod`` axis is pure data-parallel; ``data`` carries DP+FSDP and
    ``model`` carries TP/EP (see repro.dist.sharding).
    """
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_local_mesh(data: int = 1, model: int = 1):
    """Mesh over whatever devices exist locally (tests / examples)."""
    return jax.make_mesh((data, model), ("data", "model"))
