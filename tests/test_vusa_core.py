"""Core VUSA algorithm tests: scheduler, MAC assignment (the paper's wiring
claim), growth model (Eq. 1-4) vs Monte-Carlo, packing roundtrips, and the
Table-I-calibrated PPA model."""

import numpy as np
import pytest
from hypothesis_compat import given, settings, st

from repro.core.growth import expected_width_distribution, p_grow
from repro.core.hwmodel import TABLE1_PAPER, HwModel, table1
from repro.core.packing import (
    pack_blocks,
    pack_exact,
    pack_rows,
    unpack_blocks,
    unpack_exact,
    unpack_rows,
)
from repro.core.vusa import (
    load_split,
    mac_assignment,
    schedule_matrix,
    virtual_speedup,
    window_feasible,
)

# ---------------------------------------------------------------------------
# MAC assignment / wiring claim
# ---------------------------------------------------------------------------


@given(
    m=st.integers(2, 12),
    a_frac=st.floats(0.2, 1.0),
    data=st.data(),
)
@settings(max_examples=200, deadline=None)
def test_wiring_claim_any_leq_a_nonzeros_is_feasible(m, a_frac, data):
    """Paper Section III-C: each MAC connected to M-A+1 adjacent SPEs suffices
    for ALL distributions of <= A non-zeros in a window of M."""
    a = max(1, int(round(a_frac * m)))
    t = data.draw(st.integers(0, a))
    positions = sorted(data.draw(st.sets(st.integers(0, m - 1), min_size=t, max_size=t)))
    macs = mac_assignment(positions, m, a)
    assert macs is not None, (positions, m, a)
    # injective + in shift range
    assert len(set(macs.tolist())) == len(positions)
    for p, j in zip(positions, macs):
        assert j <= p <= j + (m - a)


def test_overflow_is_infeasible():
    assert mac_assignment([0, 1, 2, 3], M=6, A=3) is None


@given(st.integers(1, 6), st.integers(1, 8), st.data())
@settings(max_examples=100, deadline=None)
def test_scheduler_windows_always_feasible(n, a, data):
    m = a + data.draw(st.integers(0, 4))
    cols = data.draw(st.integers(1, 40))
    mask = np.array(
        data.draw(
            st.lists(st.lists(st.booleans(), min_size=cols, max_size=cols), min_size=n, max_size=n)
        )
    )
    sched = schedule_matrix(mask, n, m, a)
    for tile_jobs in sched.jobs:
        covered = 0
        for job in tile_jobs:
            assert a <= job.width <= m or job.width == min(m, cols - job.start)
            assert window_feasible(mask[:, job.start : job.start + job.width], m, a) or (
                job.width <= a
            )
            assert job.start == covered
            covered += job.width
        assert covered == cols


def test_dense_degenerates_to_na():
    """No sparsity => every window is width A (the paper's dense fallback)."""
    mask = np.ones((3, 30), dtype=bool)
    sched = schedule_matrix(mask, 3, 6, 3)
    assert all(j.width == 3 for t in sched.jobs for j in t)
    assert virtual_speedup(sched) == pytest.approx(1.0)


def test_full_sparsity_grows_to_m():
    rng = np.random.default_rng(0)
    mask = rng.random((9, 60)) > 0.95  # 95% sparse
    sched = schedule_matrix(mask, 3, 6, 3)
    split = load_split(sched)
    assert split[6] > 0.9  # nearly all load at full virtual width


# ---------------------------------------------------------------------------
# Growth model (Eq. 1-4) vs Monte Carlo
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("p1,w", [(0.15, 6), (0.4, 5), (0.05, 6), (0.7, 4)])
def test_growth_formula_vs_monte_carlo(p1, w):
    n, a = 3, 3
    rng = np.random.default_rng(1)
    trials = 4000
    rows_ok = (rng.random((trials, n, w)) < p1).sum(axis=2) <= a
    mc = rows_ok.all(axis=1).mean()
    assert p_grow(n, w, a, p1) == pytest.approx(mc, abs=0.03)


def test_growth_monotone_in_sparsity():
    probs = [p_grow(3, 6, 3, 1 - s) for s in np.linspace(0, 1, 21)]
    assert all(b >= a - 1e-12 for a, b in zip(probs, probs[1:]))


def test_fig6_anchors():
    """Paper Fig. 6 qualitative anchors."""
    assert p_grow(3, 6, 3, 1 - 0.9) > 0.95  # >=90% sparsity -> ~1
    assert p_grow(3, 6, 3, 1 - 0.6) > 0.5  # 60% sparsity -> >50%
    assert p_grow(3, 4, 3, 1 - 0.35) > 0.5  # ~30-35% -> 3x4 >50%


def test_width_distribution_sums_to_one():
    d = expected_width_distribution(3, 6, 3, 0.15)
    assert d.sum() == pytest.approx(1.0)
    assert d[6] == pytest.approx(p_grow(3, 6, 3, 0.15))


# ---------------------------------------------------------------------------
# Packing roundtrips
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("sparsity", [0.0, 0.5, 0.9])
def test_exact_pack_roundtrip(sparsity):
    rng = np.random.default_rng(2)
    w = rng.normal(size=(12, 30)) * (rng.random((12, 30)) > sparsity)
    p = pack_exact(w, N=3, M=6, A=3)
    np.testing.assert_allclose(unpack_exact(p), w)


@pytest.mark.parametrize("sparsity", [0.5, 0.95])
def test_block_pack_roundtrip(sparsity):
    rng = np.random.default_rng(3)
    w = (rng.normal(size=(64, 32)) * (rng.random((64, 32)) > sparsity)).astype(np.float32)
    p = pack_blocks(w, m_blk=16, a_blk=4, tile_n=8)
    np.testing.assert_allclose(unpack_blocks(p), w)


@given(st.floats(0.0, 0.99), st.integers(1, 3))
@settings(max_examples=20, deadline=None)
def test_row_pack_roundtrip(sparsity, seed):
    rng = np.random.default_rng(seed)
    w = (rng.normal(size=(32, 130)) * (rng.random((32, 130)) > sparsity)).astype(np.float32)
    p = pack_rows(w, m=128, a=8)
    np.testing.assert_allclose(unpack_rows(p), np.pad(w, ((0, 0), (0, 126)))[:, :130])


def test_row_pack_byte_ratio_improves_with_sparsity():
    rng = np.random.default_rng(4)
    dense = pack_rows((rng.normal(size=(256, 256))).astype(np.float32), a=16)
    sparse = pack_rows(
        (rng.normal(size=(256, 256)) * (rng.random((256, 256)) > 0.9)).astype(np.float32), a=16
    )
    assert sparse.byte_ratio() < 0.4 < 1.0 <= dense.byte_ratio()


# ---------------------------------------------------------------------------
# PPA model vs Table I
# ---------------------------------------------------------------------------


def test_table1_reproduction():
    t = table1()
    for k, (macs, area, power) in t.items():
        pm, pa, pp = TABLE1_PAPER[k]
        assert macs == pm
        assert area == pytest.approx(pa, abs=0.03), k
        assert power == pytest.approx(pp, abs=0.03), k


def test_vusa_cheaper_than_standard_3x6():
    m = HwModel()
    assert m.area_vusa(3, 6, 3) < m.area_standard(3, 6)
    assert m.power_vusa(3, 6, 3) < m.power_standard(3, 6)
