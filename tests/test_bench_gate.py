"""The bench regression gate itself (benchmarks/run.py): check_against must
name every hole it finds — a gated metric missing from the fresh run, a
gated bench that didn't run, a declared metric absent from the baseline —
instead of crashing or silently passing, and the --summary-md writer must
render the same comparison as a markdown table for $GITHUB_STEP_SUMMARY.

benchmarks/ is off PYTHONPATH by design (it's a script, not a package), so
the module loads via importlib from its file path; RESULTS is populated
directly so no actual benchmark runs.
"""

import importlib.util
import json
from pathlib import Path

import pytest

_RUN_PY = Path(__file__).resolve().parent.parent / "benchmarks" / "run.py"


@pytest.fixture()
def run_mod():
    spec = importlib.util.spec_from_file_location("bench_run", _RUN_PY)
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


def _gate(run_mod, tmp_path, baseline, results, tolerance=0.25):
    path = tmp_path / "base.json"
    path.write_text(json.dumps(baseline))
    run_mod.RESULTS.clear()
    run_mod.RESULTS.update(results)
    return run_mod.check_against(str(path), tolerance)


def test_gate_passes_clean(run_mod, tmp_path, capsys):
    base = {"bench_packed_decode": {"int8_tok_per_s": 400.0}}
    fresh = {"bench_packed_decode": {"int8_tok_per_s": 500.0}}
    # keep the inverse (UNGATED) check out of the way: declare only the
    # metric under test
    run_mod.BASELINE_METRICS = {"bench_packed_decode": ["int8_tok_per_s"]}
    assert _gate(run_mod, tmp_path, base, fresh) is True
    assert "ok" in capsys.readouterr().out


def test_gate_catches_regression(run_mod, tmp_path, capsys):
    base = {"bench_packed_decode": {"int8_tok_per_s": 400.0}}
    fresh = {"bench_packed_decode": {"int8_tok_per_s": 100.0}}
    run_mod.BASELINE_METRICS = {"bench_packed_decode": ["int8_tok_per_s"]}
    assert _gate(run_mod, tmp_path, base, fresh) is False
    assert "REGRESSION" in capsys.readouterr().out


def test_gate_names_missing_metric(run_mod, tmp_path, capsys):
    """The PR-7 bugfix: a baseline-gated metric absent from the fresh run
    used to raise a bare KeyError from _lookup; now it fails the gate with
    the metric named."""
    base = {"bench_packed_decode": {"int8_tok_per_s": 400.0, "gone_metric": 1.0}}
    fresh = {"bench_packed_decode": {"int8_tok_per_s": 500.0}}
    run_mod.BASELINE_METRICS = {"bench_packed_decode": ["int8_tok_per_s"]}
    assert _gate(run_mod, tmp_path, base, fresh) is False
    out = capsys.readouterr().out
    assert "bench_packed_decode.gone_metric MISSING" in out


def test_gate_names_missing_nested_metric(run_mod, tmp_path, capsys):
    """Slash-path metrics ("table/metric") hit _lookup's nested indexing —
    a missing intermediate must be named too, not TypeError out."""
    base = {"kernel_vusa_packed": {"sparsity_0.85/kernel_speedup": 1.5}}
    fresh = {"kernel_vusa_packed": {"sparsity_0.85": 3.0}}  # not a dict
    run_mod.BASELINE_METRICS = {}
    assert _gate(run_mod, tmp_path, base, fresh) is False
    assert "kernel_vusa_packed.sparsity_0.85/kernel_speedup MISSING" in (
        capsys.readouterr().out
    )


def test_gate_names_bench_that_did_not_run(run_mod, tmp_path, capsys):
    base = {"bench_faults": {"goodput_ratio": 0.9}}
    run_mod.BASELINE_METRICS = {}
    assert _gate(run_mod, tmp_path, base, {}) is False
    assert "bench_faults MISSING" in capsys.readouterr().out


def test_gate_names_unprotected_declared_metric(run_mod, tmp_path, capsys):
    """A metric declared in BASELINE_METRICS but absent from the committed
    baseline would ship unprotected — the gate flags it per metric."""
    base = {"bench_packed_decode": {"int8_tok_per_s": 400.0}}
    fresh = {"bench_packed_decode": {"int8_tok_per_s": 500.0, "int4_tok_per_s": 500.0}}
    run_mod.BASELINE_METRICS = {
        "bench_packed_decode": ["int8_tok_per_s", "int4_tok_per_s"]
    }
    assert _gate(run_mod, tmp_path, base, fresh) is False
    assert "bench_packed_decode.int4_tok_per_s UNGATED" in capsys.readouterr().out


def test_committed_baseline_covers_declared_metrics(run_mod):
    """The repo's own BENCH_BASELINE.json must gate exactly what
    BASELINE_METRICS declares (the inverse check makes extra declared
    metrics fail CI, so catch the drift here first)."""
    committed = json.loads((_RUN_PY.parent.parent / "BENCH_BASELINE.json").read_text())
    for name, metrics in run_mod.BASELINE_METRICS.items():
        assert name in committed, f"{name} declared but not in BENCH_BASELINE.json"
        for m in metrics:
            assert m in committed[name], f"{name}.{m} declared but not gated"


def test_summary_md_table(run_mod, tmp_path):
    base = {
        "bench_packed_decode": {"int8_tok_per_s": 400.0, "gone_metric": 1.0},
        "bench_faults": {"goodput_ratio": 0.9},
    }
    fresh = {"bench_packed_decode": {"int8_tok_per_s": 500.0}}
    run_mod.BASELINE_METRICS = {}
    _gate(run_mod, tmp_path, base, fresh)
    out = tmp_path / "summary.md"
    run_mod.write_summary_md(str(out))
    text = out.read_text()
    lines = text.splitlines()
    assert "| bench | metric | baseline | fresh | delta | status |" in lines
    # fresh-vs-baseline row with the delta percentage rendered
    assert any(
        "int8_tok_per_s" in ln and "400.000" in ln and "500.000" in ln
        and "+25.0%" in ln and "ok" in ln
        for ln in lines
    )
    assert any("gone_metric" in ln and "MISSING" in ln for ln in lines)
    assert any("bench_faults" in ln and "MISSING" in ln for ln in lines)
    # every table row keeps the 6-column shape (renders as a GFM table)
    for ln in lines:
        if ln.startswith("|"):
            assert ln.count("|") == 7, ln


def test_summary_md_empty(run_mod, tmp_path):
    run_mod.GATE_ROWS.clear()
    out = tmp_path / "summary.md"
    run_mod.write_summary_md(str(out))
    assert "no gated benches ran" in out.read_text()
