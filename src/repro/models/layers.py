"""Transformer layer substrate: GQA attention (flash-style chunked softmax,
causal / local / prefix / full masks, KV + ring caches), SwiGLU MLP, MoE.

All functions are pure; parameters are pytrees described by ParamSpec (see
``common.py``).  Layer-stacked parameters carry a leading "layers" axis and
are consumed through ``jax.lax.scan`` by the model families.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from .common import ParamSpec, apply_rope, rms_norm, rope, shard

# --------------------------------------------------------------------------
# Specs
# --------------------------------------------------------------------------


def attention_specs(cfg, cross: bool = False) -> dict:
    """Head-granular parameter shapes: TP shards the *head* axis, so the
    divisibility check in dist.sharding degrades gracefully — archs whose
    head counts don't divide the model axis get replicated attention weights
    (data-parallel attention) instead of sub-head shards that force GSPMD to
    emit per-chunk collectives inside the flash loops (§Perf iteration 2)."""
    d, nh, kvh, hd = cfg.d_model, cfg.n_heads, cfg.kv_heads, cfg.hd
    specs = {
        "wq": ParamSpec((d, nh, hd), ("embed", "heads", None)),
        "wk": ParamSpec((d, kvh, hd), ("embed", "kv_heads", None)),
        "wv": ParamSpec((d, kvh, hd), ("embed", "kv_heads", None)),
        "wo": ParamSpec((nh, hd, d), ("heads", None, "embed")),
    }
    if cfg.qkv_bias:
        specs["bq"] = ParamSpec((nh, hd), ("heads", None), init="zeros")
        specs["bk"] = ParamSpec((kvh, hd), ("kv_heads", None), init="zeros")
        specs["bv"] = ParamSpec((kvh, hd), ("kv_heads", None), init="zeros")
    if cfg.qk_norm:
        specs["q_norm"] = ParamSpec((hd,), (None,), init="zeros")
        specs["k_norm"] = ParamSpec((hd,), (None,), init="zeros")
    return specs


def mlp_specs(cfg) -> dict:
    d, f = cfg.d_model, cfg.d_ff
    return {
        "w_gate": ParamSpec((d, f), ("embed", "ff")),
        "w_up": ParamSpec((d, f), ("embed", "ff")),
        "w_down": ParamSpec((f, d), ("ff", "embed")),
    }


def moe_specs(cfg) -> dict:
    d, f, e = cfg.d_model, cfg.d_ff, cfg.n_experts
    return {
        "router": ParamSpec((d, e), ("embed", None)),
        "w_gate": ParamSpec((e, d, f), ("experts", "embed", "ff")),
        "w_up": ParamSpec((e, d, f), ("experts", "embed", "ff")),
        "w_down": ParamSpec((e, f, d), ("experts", "ff", "embed")),
    }


# --------------------------------------------------------------------------
# Masks
# --------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class MaskSpec:
    """Declarative attention mask: evaluated blockwise inside the kernel."""

    kind: str  # causal | local | prefix | full
    window: int = 0  # for local
    prefix_len: int = 0  # for prefix (first prefix_len tokens attend fully)

    def __call__(self, q_pos: jax.Array, k_pos: jax.Array) -> jax.Array:
        """(Q,) x (K,) int positions -> (Q, K) bool allow-mask."""
        q = q_pos[:, None]
        k = k_pos[None, :]
        if self.kind == "full":
            return jnp.ones((q_pos.shape[0], k_pos.shape[0]), bool)
        causal = k <= q
        if self.kind == "causal":
            return causal
        if self.kind == "local":
            return causal & (k > q - self.window)
        if self.kind == "prefix":
            return causal | (k < self.prefix_len)
        raise ValueError(self.kind)


# --------------------------------------------------------------------------
# Flash-style chunked attention (pure JAX; the Pallas twin lives in
# repro/kernels — this version is the oracle and the CPU/compile path)
# --------------------------------------------------------------------------

_NEG_INF = -1e30

from .opt_flags import FLAGS  # noqa: E402  beyond-paper perf switches (see §Perf)


def _flash_attend(
    q: jax.Array,  # (B, Sq, KVH, G, hd)
    k: jax.Array,  # (B, Sk, KVH, hd)
    v: jax.Array,  # (B, Sk, KVH, hd)
    mask: MaskSpec,
    q_pos: jax.Array,  # (Sq,)
    k_pos: jax.Array,  # (Sk,)
    kv_valid: Optional[jax.Array] = None,  # (Sk,) or (B, Sk) bool; cache occupancy / padding
    q_chunk: int = 512,
    kv_chunk: int = 512,
) -> jax.Array:
    """Online-softmax attention, O(chunk^2) memory.  Returns (B,Sq,KVH,G,hd).

    ``kv_valid`` may be shared across the batch ``(Sk,)`` (cache occupancy)
    or per-row ``(B, Sk)`` (ragged true lengths under bucketed prefill,
    DESIGN.md §6) — invalid keys get exactly-zero probability either way."""
    b, sq, kvh, g, hd = q.shape
    sk = k.shape[1]
    q_chunk = min(q_chunk, sq)
    kv_chunk = min(kv_chunk, sk)
    scale = hd ** -0.5

    # Pad both sequence dims to chunk multiples; padded KV is masked invalid,
    # padded Q rows are sliced off at the end.
    sq_pad = (-sq) % q_chunk
    sk_pad = (-sk) % kv_chunk
    if kv_valid is None:
        kv_valid = jnp.ones((sk,), bool)
    per_row_valid = kv_valid.ndim == 2
    if sq_pad:
        q = jnp.pad(q, ((0, 0), (0, sq_pad), (0, 0), (0, 0), (0, 0)))
        q_pos = jnp.pad(q_pos, (0, sq_pad))
    if sk_pad:
        k = jnp.pad(k, ((0, 0), (0, sk_pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, sk_pad), (0, 0), (0, 0)))
        k_pos = jnp.pad(k_pos, (0, sk_pad))
        kv_valid = jnp.pad(
            kv_valid, ((0, 0), (0, sk_pad)) if per_row_valid else (0, sk_pad)
        )
    sq_full, sk_full = sq + sq_pad, sk + sk_pad

    qs = q.reshape(b, sq_full // q_chunk, q_chunk, kvh, g, hd)
    ks = k.reshape(b, sk_full // kv_chunk, kv_chunk, kvh, hd)
    vs = v.reshape(b, sk_full // kv_chunk, kv_chunk, kvh, hd)
    qps = q_pos.reshape(sq_full // q_chunk, q_chunk)
    kps = k_pos.reshape(sk_full // kv_chunk, kv_chunk)
    if per_row_valid:
        # scan axis leads: (nk, B, kv_chunk)
        valid = kv_valid.reshape(b, sk_full // kv_chunk, kv_chunk).swapaxes(0, 1)
    else:
        valid = kv_valid.reshape(sk_full // kv_chunk, kv_chunk)

    def q_step(_, qc):
        qi, qp = qc  # (b, qc, kvh, g, hd), (qc,)

        def kv_step(carry, kc):
            m, l, acc = carry
            ki, vi, kp, va = kc
            s = jnp.einsum("bqhgd,bkhd->bhgqk", qi, ki, preferred_element_type=jnp.float32)
            s = s * scale
            if per_row_valid:
                allow = mask(qp, kp)[None] & va[:, None, :]  # (B, Q, K)
                s = jnp.where(allow[:, None, None], s, _NEG_INF)
            else:
                allow = mask(qp, kp) & va[None, :]
                s = jnp.where(allow[None, None, None], s, _NEG_INF)
            m_new = jnp.maximum(m, s.max(axis=-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = l * corr + p.sum(axis=-1)
            if FLAGS["attn_bf16_probs"]:
                # halve the largest flash intermediate: P and V stream through
                # the MXU in bf16, accumulation stays fp32
                av = jnp.einsum(
                    "bhgqk,bkhd->bhgqd",
                    p.astype(jnp.bfloat16),
                    vi.astype(jnp.bfloat16),
                    preferred_element_type=jnp.float32,
                )
            else:
                av = jnp.einsum("bhgqk,bkhd->bhgqd", p, vi.astype(jnp.float32))
            acc_new = acc * corr[..., None] + av
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((b, kvh, g, qi.shape[1]), _NEG_INF, jnp.float32)
        l0 = jnp.zeros((b, kvh, g, qi.shape[1]), jnp.float32)
        a0 = jnp.zeros((b, kvh, g, qi.shape[1], hd), jnp.float32)
        (m, l, acc), _ = jax.lax.scan(
            kv_step, (m0, l0, a0), (ks.swapaxes(0, 1), vs.swapaxes(0, 1), kps, valid)
        )
        out = acc / jnp.maximum(l, 1e-30)[..., None]  # (b, kvh, g, qc, hd)
        return None, out.transpose(0, 3, 1, 2, 4)  # (b, qc, kvh, g, hd)

    _, outs = jax.lax.scan(q_step, None, (qs.swapaxes(0, 1), qps))
    # outs: (nq, b, qc, kvh, g, hd)
    out = outs.swapaxes(0, 1).reshape(b, sq_full, kvh, g, hd)
    return out[:, :sq].astype(q.dtype)


# --------------------------------------------------------------------------
# Flash attention with a hand-written VJP (perf flag "flash_custom_vjp").
#
# Plain jax.grad of the chunked scan stores every per-chunk probability
# tensor (B,H,G,Qc,Kc) as a scan residual — O(Sq*Sk) HBM, exactly what flash
# attention exists to avoid.  The custom VJP saves only (out, m, l) and
# recomputes scores chunk-by-chunk in the backward, the standard
# flash-attention-2 derivation.
# --------------------------------------------------------------------------


def _flash_fwd_chunks(q, k, v, mask, q_pos, k_pos, kv_valid, q_chunk, kv_chunk):
    """Chunked forward that also returns the log-sum-exp stats (m, l)."""
    b, sq, kvh, g, hd = q.shape
    sk = k.shape[1]
    scale = hd**-0.5
    nq, nk = sq // q_chunk, sk // kv_chunk
    qs = q.reshape(b, nq, q_chunk, kvh, g, hd)
    ks = k.reshape(b, nk, kv_chunk, kvh, hd)
    vs = v.reshape(b, nk, kv_chunk, kvh, hd)
    qps = q_pos.reshape(nq, q_chunk)
    kps = k_pos.reshape(nk, kv_chunk)
    valid = kv_valid.reshape(nk, kv_chunk)

    def q_step(_, qc):
        qi, qp = qc

        def kv_step(carry, kc):
            m, l, acc = carry
            ki, vi, kp, va = kc
            s = jnp.einsum("bqhgd,bkhd->bhgqk", qi, ki, preferred_element_type=jnp.float32) * scale
            allow = mask(qp, kp) & va[None, :]
            s = jnp.where(allow[None, None, None], s, _NEG_INF)
            m_new = jnp.maximum(m, s.max(axis=-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = l * corr + p.sum(axis=-1)
            if FLAGS["attn_bf16_probs"]:
                av = jnp.einsum("bhgqk,bkhd->bhgqd", p.astype(jnp.bfloat16),
                                vi.astype(jnp.bfloat16), preferred_element_type=jnp.float32)
            else:
                av = jnp.einsum("bhgqk,bkhd->bhgqd", p, vi.astype(jnp.float32))
            return (m_new, l_new, acc * corr[..., None] + av), None

        m0 = jnp.full((b, kvh, g, q_chunk), _NEG_INF, jnp.float32)
        l0 = jnp.zeros((b, kvh, g, q_chunk), jnp.float32)
        a0 = jnp.zeros((b, kvh, g, q_chunk, hd), jnp.float32)
        (m, l, acc), _ = jax.lax.scan(
            kv_step, (m0, l0, a0), (ks.swapaxes(0, 1), vs.swapaxes(0, 1), kps, valid)
        )
        out = acc / jnp.maximum(l, 1e-30)[..., None]
        return None, (out.transpose(0, 3, 1, 2, 4), m, l)  # (b,qc,kvh,g,hd)

    _, (outs, ms, ls) = jax.lax.scan(q_step, None, (qs.swapaxes(0, 1), qps))
    out = outs.swapaxes(0, 1).reshape(b, sq, kvh, g, hd)
    # stats shaped (nq, b, kvh, g, q_chunk)
    return out, ms, ls


def _make_flash_vjp(mask, q_chunk, kv_chunk):
    """Build the custom-VJP flash attention for a static (mask, chunking).

    Positions/validity are array *arguments* (zero float0 cotangents), never
    closure captures — closures over tracers leak out of custom_vjp."""

    import numpy as _np

    @jax.custom_vjp
    def flash(q, k, v, q_pos, k_pos, kv_valid):
        out, _, _ = _flash_fwd_chunks(q, k, v, mask, q_pos, k_pos, kv_valid, q_chunk, kv_chunk)
        return out

    def fwd(q, k, v, q_pos, k_pos, kv_valid):
        out, m, l = _flash_fwd_chunks(q, k, v, mask, q_pos, k_pos, kv_valid, q_chunk, kv_chunk)
        return out, (q, k, v, q_pos, k_pos, kv_valid, out, m, l)

    def bwd(res, dout):
        q, k, v, q_pos, k_pos, kv_valid, out, ms, ls = res
        b, sq, kvh, g, hd = q.shape
        sk = k.shape[1]
        scale = hd**-0.5
        nq, nk = sq // q_chunk, sk // kv_chunk
        qs = q.reshape(b, nq, q_chunk, kvh, g, hd).swapaxes(0, 1)
        ks = k.reshape(b, nk, kv_chunk, kvh, hd).swapaxes(0, 1)
        vs = v.reshape(b, nk, kv_chunk, kvh, hd).swapaxes(0, 1)
        dos = dout.reshape(b, nq, q_chunk, kvh, g, hd).swapaxes(0, 1)
        outs = out.reshape(b, nq, q_chunk, kvh, g, hd).swapaxes(0, 1)
        qps = q_pos.reshape(nq, q_chunk)
        kps = k_pos.reshape(nk, kv_chunk)
        valid = kv_valid.reshape(nk, kv_chunk)
        # D_i = rowsum(dO * O): (nq, b, kvh, g, q_chunk)
        ds_stat = jnp.einsum(
            "nbqhgd,nbqhgd->nbhgq", dos.astype(jnp.float32), outs.astype(jnp.float32)
        )

        def kv_step(dq_acc, kc):
            ki, vi, kp, va = kc

            def q_step(carry, qc):
                dkj, dvj = carry
                qi, doi, m, l, di, qp, dqi_prev = qc
                s = scale * jnp.einsum(
                    "bqhgd,bkhd->bhgqk", qi, ki, preferred_element_type=jnp.float32
                )
                allow = mask(qp, kp) & va[None, :]
                s = jnp.where(allow[None, None, None], s, _NEG_INF)
                p = jnp.exp(s - m[..., None]) / jnp.maximum(l, 1e-30)[..., None]
                dp = jnp.einsum(
                    "bqhgd,bkhd->bhgqk", doi.astype(jnp.float32), vi.astype(jnp.float32)
                )
                dsv = p * (dp - di[..., None]) * scale
                if FLAGS["attn_bf16_probs"]:
                    pc, dc = p.astype(jnp.bfloat16), dsv.astype(jnp.bfloat16)
                    dvj = dvj + jnp.einsum("bhgqk,bqhgd->bkhd", pc, doi.astype(jnp.bfloat16),
                                           preferred_element_type=jnp.float32)
                    dkj = dkj + jnp.einsum("bhgqk,bqhgd->bkhd", dc, qi.astype(jnp.bfloat16),
                                           preferred_element_type=jnp.float32)
                    dqi = jnp.einsum("bhgqk,bkhd->bqhgd", dc, ki.astype(jnp.bfloat16),
                                     preferred_element_type=jnp.float32)
                else:
                    dvj = dvj + jnp.einsum("bhgqk,bqhgd->bkhd", p, doi.astype(jnp.float32))
                    dkj = dkj + jnp.einsum("bhgqk,bqhgd->bkhd", dsv, qi.astype(jnp.float32))
                    dqi = jnp.einsum("bhgqk,bkhd->bqhgd", dsv, ki.astype(jnp.float32))
                return (dkj, dvj), dqi_prev + dqi

            dk0 = jnp.zeros((b, kv_chunk, kvh, hd), jnp.float32)
            dv0 = jnp.zeros((b, kv_chunk, kvh, hd), jnp.float32)
            (dkj, dvj), dq_new = jax.lax.scan(
                q_step, (dk0, dv0), (qs, dos, ms, ls, ds_stat, qps, dq_acc)
            )
            return dq_new, (dkj, dvj)

        dq0 = jnp.zeros((nq, b, q_chunk, kvh, g, hd), jnp.float32)
        dq, (dks, dvs) = jax.lax.scan(kv_step, dq0, (ks, vs, kps, valid))
        dq = dq.swapaxes(0, 1).reshape(b, sq, kvh, g, hd).astype(q.dtype)
        dk = dks.swapaxes(0, 1).reshape(b, sk, kvh, hd).astype(k.dtype)
        dv = dvs.swapaxes(0, 1).reshape(b, sk, kvh, hd).astype(v.dtype)
        f0 = lambda a: _np.zeros(a.shape, dtype=jax.dtypes.float0)
        return dq, dk, dv, f0(q_pos), f0(k_pos), f0(kv_valid)

    flash.defvjp(fwd, bwd)
    return flash


def _direct_attend(
    q: jax.Array,  # (B, 1, KVH, G, hd) — single decode token
    k: jax.Array,  # (B, Sk, KVH, hd)
    v: jax.Array,  # (B, Sk, KVH, hd)
    mask: MaskSpec,
    q_pos: jax.Array,  # (1,)
    k_pos: jax.Array,  # (Sk,)
    kv_valid: jax.Array,  # (Sk,)
) -> jax.Array:
    """Unchunked decode attention (beyond-paper perf path).

    Why not the flash scan for decode: chunking reshapes the cache's seq dim,
    and under a seq-sharded KV cache GSPMD must all-gather the whole cache to
    re-chunk it (~GBs per token).  Computed directly, seq stays a *free* dim
    in the QK einsum and a *contracted* dim in the AV einsum, so the only
    collectives are the tiny (B,H,1) softmax reductions and the (B,H,1,hd)
    partial-sum all-reduce — bytes drop by ~3 orders of magnitude."""
    scale = q.shape[-1] ** -0.5
    s = jnp.einsum("bqhgd,bkhd->bhgqk", q, k, preferred_element_type=jnp.float32) * scale
    allow = mask(q_pos, k_pos) & kv_valid[None, :]
    s = jnp.where(allow[None, None, None], s, _NEG_INF)
    m = s.max(axis=-1, keepdims=True)
    p = jnp.exp(s - m)
    l = p.sum(axis=-1, keepdims=True)
    out = jnp.einsum("bhgqk,bkhd->bhgqd", (p / jnp.maximum(l, 1e-30)), v.astype(jnp.float32))
    return out.transpose(0, 3, 1, 2, 4).astype(q.dtype)  # (B, 1, KVH, G, hd)


# --------------------------------------------------------------------------
# Attention apply (train/prefill + decode-with-cache)
# --------------------------------------------------------------------------


def _project_qkv(p, x, cfg, positions, wmm=None):
    """QKV projection.  ``wmm`` optionally overrides the weight matmuls:
    ``wmm(name, x) -> x @ W_name`` on the flattened head dim — the hook the
    VUSA-packed decode path (serve/packed.py) uses to run the projections
    through the row-packed kernel without forking the rope/bias/norm glue."""
    b, s, d = x.shape
    nh, kvh, hd = cfg.n_heads, cfg.kv_heads, cfg.hd
    if wmm is None:
        q = jnp.einsum("bsd,dnh->bsnh", x, p["wq"].astype(x.dtype))
        k = jnp.einsum("bsd,dnh->bsnh", x, p["wk"].astype(x.dtype))
        v = jnp.einsum("bsd,dnh->bsnh", x, p["wv"].astype(x.dtype))
    else:
        q = wmm("wq", x).reshape(b, s, nh, hd).astype(x.dtype)
        k = wmm("wk", x).reshape(b, s, kvh, hd).astype(x.dtype)
        v = wmm("wv", x).reshape(b, s, kvh, hd).astype(x.dtype)
    if cfg.qkv_bias:
        q = q + p["bq"].astype(x.dtype)[None, None]
        k = k + p["bk"].astype(x.dtype)[None, None]
        v = v + p["bv"].astype(x.dtype)[None, None]
    if cfg.qk_norm:
        q = rms_norm(q, p["q_norm"])
        k = rms_norm(k, p["k_norm"])
    sin, cos = rope(positions, hd, cfg.rope_theta)
    q = apply_rope(q, sin, cos)
    k = apply_rope(k, sin, cos)
    q = shard(q, "batch", None, "kv_heads", None)
    return q, k, v




def _maybe_seq_sharded_attention(q, k, v, mask, positions, cfg):
    """Sequence-parallel attention (perf flag "attn_seq_shard").

    When the head count does not divide the model axis, GSPMD either shards
    sub-head (collective storm inside the flash loops) or replicates the
    whole score computation.  Instead: shard_map over the model axis on the
    q-sequence dim — each device runs flash attention for its contiguous
    q-slice against the (small, replicated) K/V.  Returns None when not
    applicable (no mesh / divisible heads / indivisible shapes)."""
    from .common import current_mesh_rules

    mesh, _ = current_mesh_rules()
    b, s, kvh, g, hd = q.shape
    nh = kvh * g
    if (
        not FLAGS["attn_seq_shard"]
        or mesh is None
        or "model" not in mesh.shape
        or mesh.shape["model"] == 1
        # head TP handles it better only when BOTH q-heads and kv-heads
        # shard cleanly; a GQA reshape that splits heads across devices
        # (e.g. kvh=8 on tp=16) reintroduces per-chunk collectives
        or (nh % mesh.shape["model"] == 0 and kvh % mesh.shape["model"] == 0)
        or s % mesh.shape["model"] != 0
    ):
        return None
    tp = mesh.shape["model"]
    dp = [a for a in ("pod", "data") if a in mesh.shape]
    if b % int(np.prod([mesh.shape[a] for a in dp])) != 0:
        return None
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    dp_spec = tuple(dp) if len(dp) > 1 else (dp[0] if dp else None)
    s_loc = s // tp
    chunk = min(512, s_loc)

    def local_attn(q_l, k_l, v_l, qpos_l, kpos_l):
        flash = _make_flash_vjp(mask, chunk, min(512, s))
        valid = jnp.ones((s,), bool)
        return flash(q_l, k_l, v_l, qpos_l, kpos_l, valid)

    fn = shard_map(
        local_attn,
        mesh=mesh,
        in_specs=(
            P(dp_spec, "model", None, None, None),
            P(dp_spec, None, None, None),
            P(dp_spec, None, None, None),
            P("model"),
            P(None),
        ),
        out_specs=P(dp_spec, "model", None, None, None),
        check_rep=False,  # scan carries start as unvarying constants
    )
    return fn(q, k, v, positions, positions).astype(q.dtype)


def attention(
    p: dict,
    x: jax.Array,  # (B, S, d)
    cfg,
    mask: MaskSpec,
    positions: Optional[jax.Array] = None,  # (S,) token positions
    kv_valid: Optional[jax.Array] = None,  # (B, S) bool; padded keys under bucketed prefill
) -> jax.Array:
    """Full-sequence self-attention (train / prefill).

    ``kv_valid`` marks real (non-padding) keys per batch row for masked
    bucketed prefill (DESIGN.md §6).  With right-padding the causal mask
    already hides padding from every valid query, so this is defence in
    depth (and load-bearing for non-causal mask kinds); it is an
    inference-only path and skips the custom-VJP / seq-sharded variants."""
    b, s, _ = x.shape
    nh, kvh, hd = cfg.n_heads, cfg.kv_heads, cfg.hd
    if positions is None:
        positions = jnp.arange(s)
    q, k, v = _project_qkv(p, x, cfg, positions)
    q = q.reshape(b, s, kvh, nh // kvh, hd)
    if kv_valid is not None:
        out = _flash_attend(q, k, v, mask, positions, positions, kv_valid=kv_valid)
        out = out.reshape(b, s, nh, hd)
        return jnp.einsum("bsnh,nhd->bsd", out, p["wo"].astype(x.dtype))
    out = _maybe_seq_sharded_attention(q, k, v, mask, positions, cfg)
    if out is not None:
        pass
    elif FLAGS["flash_custom_vjp"]:
        chunk = min(512, s)
        pad = (-s) % chunk
        qp = jnp.pad(q, ((0, 0), (0, pad), (0, 0), (0, 0), (0, 0))) if pad else q
        kp = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0))) if pad else k
        vp = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0))) if pad else v
        pos = jnp.pad(positions, (0, pad)) if pad else positions
        kv_valid = (
            jnp.pad(jnp.ones((s,), bool), (0, pad)) if pad else jnp.ones((s,), bool)
        )
        flash = _make_flash_vjp(mask, chunk, chunk)
        out = flash(qp, kp, vp, pos, pos, kv_valid)[:, :s].astype(x.dtype)
    else:  # baseline: scan autodiff stores per-chunk residuals (see §Perf)
        out = _flash_attend(q, k, v, mask, positions, positions)
    out = out.reshape(b, s, nh, hd)
    return jnp.einsum("bsnh,nhd->bsd", out, p["wo"].astype(x.dtype))


def attention_decode(
    p: dict,
    x: jax.Array,  # (B, s, d) — s = 1 normal decode; s > 1 speculative verify
    cfg,
    cache: dict,  # {"k": (B, S_max, kvh, hd), "v": ..., "pos": int32 scalar}
    window: int = 0,  # >0: ring cache of this size (local attention)
    chunked: bool = False,  # True = paper-baseline flash scan (see DECODE_CHUNKED)
    wmm=None,  # optional weight-matmul override (see _project_qkv)
) -> tuple[jax.Array, dict]:
    """Decode against a (ring) KV cache.

    The cache may also be *paged* (DESIGN.md §11): ``{"k": (n_blocks, page,
    kvh, hd), "v": ..., "table": (n_pages,) int32, "pos": scalar}``.  The
    block-table gather reconstructs exactly the contiguous ``(1, max_len,
    ...)`` view the slot pool holds (``page`` divides ``max_len``), so from
    here down the math — update slice, validity mask, attend — is the same
    compiled program and tokens stay bit-identical.  Paged mode returns the
    new K/V row as pending writes (``k_new``/``v_new``) instead of a full
    cache: the caller scatters them into the shared arena outside its slot
    vmap.  Ring caches (``window > 0``) are never paged — recurrent/local
    families keep the dense per-slot pool.

    With ``s > 1`` (speculative verify, DESIGN.md §13) the ``s`` tokens
    occupy positions ``pos .. pos+s-1`` and their K/V rows are all written
    before attending.  The attend itself runs one query row at a time with
    exactly the single-token shapes: a batched multi-row attend accumulates
    its contractions in a different order than the Sq=1 dispatch and is NOT
    bitwise against sequential decode (measured: last-ulp drift at Sq=6).
    Per row ``i`` the causal mask at ``q_pos = pos+i`` intersects the
    shared ``slots <= pos+s-1`` validity down to ``slots <= pos+i`` —
    exactly the sequential step's allow set — and masked-but-already-
    written future rows contribute exact zeros (``exp(-inf - m) == 0``),
    so each row is bit-identical to the sequential single-token step.
    Bit-parity of the *surrounding* matmuls is the caller's contract:
    ``wmm`` must be row-stable across row counts (the VUSA Pallas appliers
    are; XLA gemms in general are not — the dense path chains single-token
    steps instead, see ``lm_decode_step``).  Multi-token mode requires a
    contiguous cache (``window == 0``, not paged); the paged scheduler
    gathers a contiguous view first."""
    b, s, d = x.shape
    nh, kvh, hd = cfg.n_heads, cfg.kv_heads, cfg.hd
    pos = cache["pos"]  # scalar int32: number of tokens already in cache
    paged = "table" in cache
    if paged:
        assert window == 0, "paged cache does not support ring/local attention"
        table = cache["table"]  # (n_pages,) int32 block ids
        kb, vb = cache["k"], cache["v"]  # (n_blocks, page, kvh, hd)
        s_max = table.shape[0] * kb.shape[1]
        k_cache = kb[table].reshape(1, s_max, *kb.shape[2:])
        v_cache = vb[table].reshape(1, s_max, *vb.shape[2:])
    else:
        k_cache, v_cache = cache["k"], cache["v"]
        s_max = k_cache.shape[1]
    if s > 1:
        assert window == 0 and not paged, (
            "multi-token decode needs a contiguous full-attention cache"
        )
        positions = pos + jnp.arange(s)
        q, k_new, v_new = _project_qkv(p, x, cfg, positions, wmm=wmm)
        # row-index writes (drop past max_len) — a clamped dynamic slice near
        # the cache end would silently shift the whole write window
        k = k_cache.at[:, positions].set(k_new.astype(k_cache.dtype), mode="drop")
        v = v_cache.at[:, positions].set(v_new.astype(v_cache.dtype), mode="drop")
        slots = jnp.arange(s_max)
        q = q.reshape(b, s, kvh, nh // kvh, hd)
        mask = MaskSpec("causal")
        rows = []
        for i in range(s):  # s = draft_k + 1: small, static — unroll is free
            qi = q[:, i : i + 1]
            pi = positions[i][None]
            valid_i = slots <= pos + i
            if chunked or not FLAGS["decode_direct"]:
                rows.append(_flash_attend(
                    qi, k, v, mask, pi, slots, kv_valid=valid_i,
                    q_chunk=1, kv_chunk=min(512, s_max),
                ))
            else:
                rows.append(_direct_attend(qi, k, v, mask, pi, slots, valid_i))
        out = jnp.concatenate(rows, axis=1)
        out = out.reshape(b, s, nh, hd)
        if wmm is None:
            y = jnp.einsum("bsnh,nhd->bsd", out, p["wo"].astype(x.dtype))
        else:
            y = wmm("wo", out.reshape(b, s, nh * hd)).astype(x.dtype)
        return y, {"k": k, "v": v, "pos": pos + s}
    q, k_new, v_new = _project_qkv(p, x, cfg, pos[None], wmm=wmm)
    slot = jnp.where(window > 0, pos % s_max, pos)
    k = jax.lax.dynamic_update_slice(k_cache, k_new.astype(k_cache.dtype), (0, slot, 0, 0))
    v = jax.lax.dynamic_update_slice(v_cache, v_new.astype(v_cache.dtype), (0, slot, 0, 0))
    # absolute positions of each cache slot
    slots = jnp.arange(s_max)
    if window > 0:
        # ring: slot i holds position p where p % s_max == i and p <= pos
        k_pos = pos - ((pos - slots) % s_max)
        valid = k_pos >= 0
    else:
        k_pos = slots
        valid = slots <= pos
    q = q.reshape(b, 1, kvh, nh // kvh, hd)
    mask = MaskSpec("causal") if window == 0 else MaskSpec("local", window=window)
    if chunked or not FLAGS["decode_direct"]:  # paper-baseline flash path
        out = _flash_attend(
            q, k, v, mask, pos[None], k_pos, kv_valid=valid, q_chunk=1, kv_chunk=min(512, s_max)
        )
    else:
        out = _direct_attend(q, k, v, mask, pos[None], k_pos, valid)
    out = out.reshape(b, 1, nh, hd)
    if wmm is None:
        y = jnp.einsum("bsnh,nhd->bsd", out, p["wo"].astype(x.dtype))
    else:
        y = wmm("wo", out.reshape(b, 1, nh * hd)).astype(x.dtype)
    if paged:
        return y, {
            "k_new": k_new.astype(cache["k"].dtype),
            "v_new": v_new.astype(cache["v"].dtype),
            "pos": pos + 1,
        }
    return y, {"k": k, "v": v, "pos": pos + 1}


def attention_chunk(
    p: dict,
    x: jax.Array,  # (1, C, d) — one prefill chunk of a single request
    cfg,
    arena_k: jax.Array,  # (n_blocks, page, kvh, hd)
    arena_v: jax.Array,
    table: jax.Array,  # (n_pages,) int32 — the request's block table
    start: jax.Array,  # scalar int32: absolute position of the chunk's first token
    true_len: jax.Array,  # scalar int32: real (non-padding) tokens in the chunk
) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Chunked-prefill attention (Sarathi-style, DESIGN.md §11): one chunk of
    a long prompt attends causally over everything already resident in the
    request's block table plus itself.  ``start`` is traced, so one compiled
    program serves every chunk of every prompt at a given static ``C``.

    The chunk's K/V splice into the gathered table view by *row index*
    (padding rows past ``s_max`` drop) rather than a dynamic slice — a
    clamped slice near the cache end would silently shift the write window.
    Returns ``(y, k_chunk, v_chunk)``; the caller scatters the chunk rows
    into the arena (masking padding and prefix-shared rows)."""
    b, c, d = x.shape
    nh, kvh, hd = cfg.n_heads, cfg.kv_heads, cfg.hd
    positions = start + jnp.arange(c)
    q, k_new, v_new = _project_qkv(p, x, cfg, positions)
    s_max = table.shape[0] * arena_k.shape[1]
    k_all = arena_k[table].reshape(1, s_max, *arena_k.shape[2:])
    v_all = arena_v[table].reshape(1, s_max, *arena_v.shape[2:])
    k_all = k_all.at[:, positions].set(k_new.astype(k_all.dtype), mode="drop")
    v_all = v_all.at[:, positions].set(v_new.astype(v_all.dtype), mode="drop")
    rows = jnp.arange(s_max)
    valid = rows < start + true_len
    q = q.reshape(b, c, kvh, nh // kvh, hd)
    out = _flash_attend(
        q, k_all, v_all, MaskSpec("causal"), positions, rows,
        kv_valid=valid, kv_chunk=min(512, s_max),
    )
    out = out.reshape(b, c, nh, hd)
    y = jnp.einsum("bsnh,nhd->bsd", out, p["wo"].astype(x.dtype))
    return y, k_new, v_new


def cross_attention(
    p: dict,
    x: jax.Array,  # (B, Sq, d) decoder states
    enc_kv: tuple[jax.Array, jax.Array],  # precomputed (k, v): (B, Sk, kvh, hd)
    cfg,
) -> jax.Array:
    b, s, _ = x.shape
    nh, kvh, hd = cfg.n_heads, cfg.kv_heads, cfg.hd
    q = jnp.einsum("bsd,dnh->bsnh", x, p["wq"].astype(x.dtype))
    k, v = enc_kv
    q = q.reshape(b, s, kvh, nh // kvh, hd)
    sk = k.shape[1]
    out = _flash_attend(
        q, k, v, MaskSpec("full"), jnp.arange(s), jnp.arange(sk)
    )
    out = out.reshape(b, s, nh, hd)
    return jnp.einsum("bsnh,nhd->bsd", out, p["wo"].astype(x.dtype))


def encode_cross_kv(p: dict, enc_out: jax.Array, cfg):
    """Precompute cross-attention K/V from encoder output (done once)."""
    b, s, _ = enc_out.shape
    kvh, hd = cfg.kv_heads, cfg.hd
    k = jnp.einsum("bsd,dnh->bsnh", enc_out, p["wk"].astype(enc_out.dtype))
    v = jnp.einsum("bsd,dnh->bsnh", enc_out, p["wv"].astype(enc_out.dtype))
    return k, v


# --------------------------------------------------------------------------
# MLP / MoE
# --------------------------------------------------------------------------


def mlp(p: dict, x: jax.Array) -> jax.Array:
    h = jax.nn.silu(x @ p["w_gate"].astype(x.dtype)) * (x @ p["w_up"].astype(x.dtype))
    h = shard(h, "batch", None, "ff")
    return h @ p["w_down"].astype(x.dtype)


def moe(p: dict, x: jax.Array, cfg, capacity_factor: float | None = None):
    """Top-k MoE with capacity-bounded scatter dispatch (token-dropping).

    Returns (y, aux_loss).  Expert dim shards over "model" (EP); the
    scatter/gather pair is what GSPMD turns into all-to-alls.
    """
    b, s, d = x.shape
    e, k = cfg.n_experts, cfg.top_k
    t = b * s
    xf = x.reshape(t, d)
    logits = (xf @ p["router"].astype(jnp.float32)).astype(jnp.float32)  # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_w, eids = jax.lax.top_k(probs, k)  # (T, k)
    gate_w = gate_w / jnp.maximum(gate_w.sum(-1, keepdims=True), 1e-9)

    # load-balance aux loss (Switch): E * sum_e f_e * p_e
    me = probs.mean(0)
    ce = jnp.zeros((e,), jnp.float32).at[eids.reshape(-1)].add(1.0) / (t * k)
    aux = e * jnp.sum(me * ce)

    cf = cfg.moe_cf if capacity_factor is None else capacity_factor
    cap = int(cf * t * k / e) + 1
    flat_e = eids.reshape(-1)  # (T*k,)
    if FLAGS["moe_sort_positions"]:
        # position-in-expert via stable sort: O(T log T) int32 traffic vs the
        # O(T*E) one-hot cumsum of the baseline
        order = jnp.argsort(flat_e, stable=True)  # (T*k,)
        sorted_e = flat_e[order]
        run_start = jnp.searchsorted(sorted_e, sorted_e, side="left")
        pos_sorted = jnp.arange(flat_e.shape[0], dtype=jnp.int32) - run_start.astype(jnp.int32)
        pos = jnp.zeros_like(pos_sorted).at[order].set(pos_sorted)
    else:
        onehot = jax.nn.one_hot(flat_e, e, dtype=jnp.int32)  # (T*k, E)
        pos = (jnp.cumsum(onehot, axis=0) - 1)  # running count per expert
        pos = jnp.take_along_axis(pos, flat_e[:, None], axis=1)[:, 0]  # (T*k,)
    if FLAGS["moe_shard_capacity"]:
        # round the buffer so the capacity dim shards over the data axis —
        # otherwise every data-row recomputes all experts (16x waste); the
        # final 256-slot block is dump space for dropped tokens
        cap = ((cap + 255) // 256) * 256
        n_slots = cap + 256
    else:
        n_slots = cap + 1  # baseline: single dump slot (indivisible!)
    dropped = pos >= cap
    pos = jnp.where(dropped, cap, pos)  # dump slot

    buf = jnp.zeros((e, n_slots, d), x.dtype)
    xk = jnp.repeat(xf, k, axis=0)  # (T*k, d)
    if FLAGS["moe_shard_capacity"]:
        # two-step dispatch: scatter into model-sharded per-expert partials
        # (local, no comm), then constrain to (experts x capacity) sharding —
        # GSPMD lowers the transition as a reduce-scatter over data instead
        # of materialising full replicas
        xk = shard(xk, "batch", None)
        buf = buf.at[flat_e, pos].add(xk)
        buf = shard(buf, "experts", None, None)
        # barrier stops GSPMD from propagating the 2-D sharding back into
        # the scatter (which would materialise full replicas + all-reduce);
        # the transition below is then a *local slice* per data-row
        buf = jax.lax.optimization_barrier(buf)
        buf = shard(buf, "experts", "batch", None)
    else:
        buf = buf.at[flat_e, pos].add(xk)
        buf = shard(buf, "experts", None, None)

    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", buf, p["w_gate"].astype(x.dtype)))
    h = h * jnp.einsum("ecd,edf->ecf", buf, p["w_up"].astype(x.dtype))
    if FLAGS["moe_shard_capacity"]:
        h = shard(h, "experts", "batch", "ff")
    out = jnp.einsum("ecf,efd->ecd", h, p["w_down"].astype(x.dtype))  # (E, slots, d)
    if FLAGS["moe_shard_capacity"]:
        out = jax.lax.optimization_barrier(out)
        out = shard(out, "experts", None, None)  # all-gather once for the token gather

    y = out[flat_e, pos]  # (T*k, d)
    w = jnp.where(dropped, 0.0, gate_w.reshape(-1)).astype(x.dtype)
    y = (y * w[:, None]).reshape(t, k, d).sum(axis=1)
    return y.reshape(b, s, d), aux
