"""Serve-stack hardening: deadlines, cancellation, backpressure shed
policies, priority admission, the stats counters/TTFT satellite, and prompt
token-id validation.  Differential style throughout: every path that touches
one request must leave its neighbours' tokens bit-identical to a clean run."""

import dataclasses

import numpy as np
import pytest

import jax

from repro.configs import get_smoke_config
from repro.models import build_model
from repro.serve import Engine, Request, Scheduler, ServeConfig, Status


@pytest.fixture(scope="module")
def llama():
    cfg = get_smoke_config("llama3_2_1b")
    params = build_model(cfg).init(jax.random.key(0))
    return cfg, params


def _one_shot(cfg, params, req: Request, sc: ServeConfig):
    eng = Engine(cfg, params, dataclasses.replace(sc, seed=req.seed))
    return eng.generate(np.asarray(req.prompt)[None], max_new=req.max_new)["tokens"][0]


def _req(rng, seed, max_new=8, **kw):
    return Request(
        prompt=rng.integers(1, 100, 6).astype(np.int32), max_new=max_new, seed=seed, **kw
    )


class FakeClock:
    """Injectable monotonic clock: deadlines fire exactly when a test says,
    not when the wall clock happens to."""

    def __init__(self):
        self.t = 0.0

    def __call__(self) -> float:
        return self.t

    def sleep(self, s: float) -> None:
        self.t += s


# ---------------------------------------------------------------------------
# deadlines
# ---------------------------------------------------------------------------


def test_deadline_sheds_before_admission(llama):
    """A request whose queue wait already blew its deadline is shed at
    admission (TIMEOUT, no tokens, never primed); its neighbour's tokens
    stay bit-identical to a clean run."""
    cfg, params = llama
    sc = ServeConfig(max_len=64)
    sched = Scheduler(Engine(cfg, params, dataclasses.replace(sc)), slots=2, segment=4)
    rng = np.random.default_rng(0)
    reqs = [_req(rng, 0), _req(rng, 1, deadline_s=0.0)]
    done = sched.run(reqs)
    assert done[1].status is Status.TIMEOUT and len(done[1].tokens) == 0
    assert np.isnan(done[1].admit_s)  # never held a slot
    assert done[0].status is Status.OK
    np.testing.assert_array_equal(done[0].tokens, _one_shot(cfg, params, reqs[0], sc))
    st = sched.stats()
    assert st["timed_out"] == 1 and st["requests"] == 2


def test_deadline_in_flight_timeout(llama):
    """An in-flight request whose deadline passes mid-decode retires TIMEOUT
    at the segment sync with its partial tokens — a prefix of the clean
    run's tokens — while an undeadlined neighbour is untouched."""
    cfg, params = llama
    sc = ServeConfig(max_len=96)
    clk = FakeClock()
    sched = Scheduler(
        Engine(cfg, params, dataclasses.replace(sc)),
        slots=2, segment=4, clock=clk, sleep=clk.sleep,
    )
    rng = np.random.default_rng(1)
    reqs = [_req(rng, 0, max_new=24), _req(rng, 1, max_new=24, deadline_s=5.0)]

    def advance(s):  # fires after each sync: second sync sees t > 5
        clk.t += 10.0

    done = sched.run(reqs, on_sync=advance)
    one1 = _one_shot(cfg, params, reqs[1], sc)
    assert done[1].status is Status.TIMEOUT
    assert 0 < len(done[1].tokens) < 24
    np.testing.assert_array_equal(done[1].tokens, one1[: len(done[1].tokens)])
    assert done[0].status is Status.OK
    np.testing.assert_array_equal(done[0].tokens, _one_shot(cfg, params, reqs[0], sc))
    assert sched.stats()["timed_out"] == 1


# ---------------------------------------------------------------------------
# cancellation
# ---------------------------------------------------------------------------


def test_cancel_queued(llama):
    cfg, params = llama
    sc = ServeConfig(max_len=64)
    sched = Scheduler(Engine(cfg, params, dataclasses.replace(sc)), slots=1, segment=4)
    rng = np.random.default_rng(2)
    reqs = [_req(rng, 0), _req(rng, 1)]
    for r in reqs:
        sched.submit(r)
    assert sched.cancel(1) is True
    assert sched.cancel(99) is False  # unknown rid never raises
    done = sched.run()
    assert done[1].status is Status.CANCELLED and len(done[1].tokens) == 0
    np.testing.assert_array_equal(done[0].tokens, _one_shot(cfg, params, reqs[0], sc))
    assert sched.stats()["cancelled"] == 1


def test_cancel_in_flight(llama):
    """Cancelling an in-flight request retires it at the next sync with the
    tokens it had (a prefix of its clean run); the surviving slot's tokens
    stay bit-identical."""
    cfg, params = llama
    sc = ServeConfig(max_len=96)
    sched = Scheduler(Engine(cfg, params, dataclasses.replace(sc)), slots=2, segment=4)
    rng = np.random.default_rng(3)
    reqs = [_req(rng, 0, max_new=24), _req(rng, 1, max_new=24)]
    fired = []

    def hook(s):
        if not fired:
            fired.append(True)
            assert s.cancel(1) is True

    done = sched.run(reqs, on_sync=hook)
    assert done[1].status is Status.CANCELLED
    assert 0 < len(done[1].tokens) < 24
    np.testing.assert_array_equal(
        done[1].tokens, _one_shot(cfg, params, reqs[1], sc)[: len(done[1].tokens)]
    )
    assert done[0].status is Status.OK
    np.testing.assert_array_equal(done[0].tokens, _one_shot(cfg, params, reqs[0], sc))


# ---------------------------------------------------------------------------
# backpressure
# ---------------------------------------------------------------------------


def test_backpressure_reject(llama):
    cfg, params = llama
    sc = ServeConfig(max_len=64)
    sched = Scheduler(
        Engine(cfg, params, dataclasses.replace(sc)), slots=1, segment=4, queue_cap=2
    )
    rng = np.random.default_rng(4)
    reqs = [_req(rng, i) for i in range(3)]
    rids = [sched.submit(r) for r in reqs]
    done = sched.run()
    assert done[rids[2]].status is Status.REJECTED and len(done[rids[2]].tokens) == 0
    for rid in rids[:2]:
        np.testing.assert_array_equal(
            done[rid].tokens, _one_shot(cfg, params, reqs[rid], sc)
        )
    st = sched.stats()
    assert st["rejected"] == 1 and st["shed"] == 0


def test_backpressure_shed_oldest(llama):
    cfg, params = llama
    sc = ServeConfig(max_len=64)
    sched = Scheduler(
        Engine(cfg, params, dataclasses.replace(sc)),
        slots=1, segment=4, queue_cap=2, shed_policy="shed-oldest",
    )
    rng = np.random.default_rng(5)
    reqs = [_req(rng, i) for i in range(3)]
    rids = [sched.submit(r) for r in reqs]
    done = sched.run()
    # the longest-waiting request paid; the newcomer got its place
    assert done[rids[0]].status is Status.REJECTED
    for rid in rids[1:]:
        assert done[rid].status is Status.OK
        np.testing.assert_array_equal(
            done[rid].tokens, _one_shot(cfg, params, reqs[rid], sc)
        )
    assert sched.stats()["shed"] == 1


def test_backpressure_shed_lowest_priority(llama):
    cfg, params = llama
    sc = ServeConfig(max_len=64)
    sched = Scheduler(
        Engine(cfg, params, dataclasses.replace(sc)),
        slots=1, segment=4, queue_cap=2, shed_policy="shed-lowest-priority",
    )
    rng = np.random.default_rng(6)
    r_hi = _req(rng, 0, priority=5)
    r_lo = _req(rng, 1, priority=1)
    r_mid = _req(rng, 2, priority=3)  # outranks r_lo: evicts it
    r_floor = _req(rng, 3, priority=0)  # outranks nobody: rejected itself
    rids = [sched.submit(r) for r in (r_hi, r_lo, r_mid, r_floor)]
    done = sched.run()
    assert done[rids[1]].status is Status.REJECTED  # shed victim
    assert done[rids[3]].status is Status.REJECTED  # rejected newcomer
    assert done[rids[0]].status is Status.OK and done[rids[2]].status is Status.OK
    st = sched.stats()
    assert st["shed"] == 1 and st["rejected"] == 1


def test_priority_admission_order(llama):
    """With one slot and both requests queued, the higher-priority one is
    admitted first even though it was submitted second."""
    cfg, params = llama
    sc = ServeConfig(max_len=64)
    sched = Scheduler(Engine(cfg, params, dataclasses.replace(sc)), slots=1, segment=4)
    rng = np.random.default_rng(7)
    reqs = [_req(rng, 0, priority=0), _req(rng, 1, priority=9)]
    done = sched.run(reqs)
    assert done[1].finish_s <= done[0].admit_s
    for rid in (0, 1):
        np.testing.assert_array_equal(
            done[rid].tokens, _one_shot(cfg, params, reqs[rid], sc)
        )


# ---------------------------------------------------------------------------
# stats counters + TTFT (satellite)
# ---------------------------------------------------------------------------


def test_stats_counters_and_ttft(llama):
    cfg, params = llama
    sc = ServeConfig(max_len=64)
    sched = Scheduler(Engine(cfg, params, dataclasses.replace(sc)), slots=2, segment=4)
    # empty epoch: percentiles NaN (not an infinitely fast server), counters 0
    st = sched.stats()
    for k in ("latency_p50_s", "latency_p95_s", "ttft_p50_s", "ttft_p95_s"):
        assert np.isnan(st[k])
    for k in ("rejected", "shed", "timed_out", "cancelled", "fallback", "failed",
              "quarantined"):
        assert st[k] == 0
    rng = np.random.default_rng(8)
    done = sched.run([_req(rng, i) for i in range(3)])
    st = sched.stats()
    assert st["requests"] == 3
    assert np.isfinite(st["ttft_p50_s"]) and st["ttft_p50_s"] >= 0
    assert st["ttft_p95_s"] >= st["ttft_p50_s"] - 1e-12
    assert all(np.isfinite(c.ttft_s) and c.ttft_s <= c.latency_s for c in done.values())


def test_epoch_reset_on_next_submit(llama):
    """A second run starts a fresh completions/counters epoch, but a
    submit-time rejection before that run survives into its results."""
    cfg, params = llama
    sc = ServeConfig(max_len=64)
    sched = Scheduler(
        Engine(cfg, params, dataclasses.replace(sc)), slots=1, segment=4, queue_cap=1
    )
    rng = np.random.default_rng(9)
    done1 = sched.run([_req(rng, 0)])
    assert set(done1) == {0}
    r1, r2 = _req(rng, 1), _req(rng, 2)
    rid1, rid2 = sched.submit(r1), sched.submit(r2)  # cap=1: rid2 rejected
    done2 = sched.run()
    assert set(done2) == {rid1, rid2}  # epoch reset dropped rid 0
    assert done2[rid2].status is Status.REJECTED
    assert sched.stats()["rejected"] == 1


# ---------------------------------------------------------------------------
# prompt token-id validation (satellite)
# ---------------------------------------------------------------------------


def test_generate_rejects_out_of_range_token_ids(llama):
    """Negative or >= vocab ids would silently wrap/clamp through the
    embedding gather — generate/prime must refuse them, naming the
    position."""
    cfg, params = llama
    eng = Engine(cfg, params, ServeConfig(max_len=64))
    bad_neg = np.array([[1, 2, -7, 3]], np.int32)
    with pytest.raises(ValueError, match=r"-7.*\(0, 2\)"):
        eng.generate(bad_neg, max_new=2)
    bad_big = np.array([[1, 2, 3, cfg.vocab]], np.int32)
    with pytest.raises(ValueError, match=r"\(0, 3\)"):
        eng.generate(bad_big, max_new=2)
    with pytest.raises(ValueError, match="vocab"):
        eng.prime_many(np.array([[1, cfg.vocab + 5]], np.int32), np.array([2], np.int32))
    # boundary ids are fine
    ok = np.array([[0, cfg.vocab - 1]], np.int32)
    out = eng.generate(ok, max_new=2)
    assert out["tokens"].shape == (1, 2) and out["finite"]
