"""The paper's own operating point: an Edge-AI scale LM whose linear layers
run VUSA-packed (N=3,M=6,A=3 semantics at block granularity M/A=2)."""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="vusa-edge", family="dense",
    n_layers=12, d_model=768, n_heads=12, kv_heads=12, d_ff=3072,
    vocab=32000, sparsity=0.85, vusa_m_over_a=2,
)

SMOKE = ArchConfig(
    name="vusa-edge-smoke", family="dense",
    n_layers=2, d_model=64, n_heads=4, kv_heads=4, d_ff=128,
    vocab=512, sparsity=0.85, vusa_m_over_a=2, dtype="float32", remat=False,
)
