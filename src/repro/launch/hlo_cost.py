"""Weighted HLO cost model, parsed from ``compiled.as_text()``.

XLA's ``compiled.cost_analysis()`` counts each while-loop *body once* —
useless for scanned-layer models where ~100% of compute sits inside loops.
This module re-derives roofline inputs from the optimized (post-SPMD) HLO:

  * ``dot_flops``         — 2*M*N*K per dot, weighted by loop trip counts
  * ``bytes``             — per-op (result + operands) bytes, fusion-level,
                            weighted by trip counts (XLA's own "bytes
                            accessed" convention, but loop-aware)
  * ``collectives``       — per-type {count, bytes} weighted by trip counts
  * ``transcendentals``   — weighted elementwise-transcendental element count

All numbers are per-device (the module is the SPMD per-device program).
"""

from __future__ import annotations

import re
from typing import Dict, List, Optional

__all__ = ["parse_hlo", "hlo_cost"]

_DTYPE_BYTES = {
    "f64": 8, "s64": 8, "u64": 8, "c64": 8,
    "f32": 4, "s32": 4, "u32": 4,
    "bf16": 2, "f16": 2, "s16": 2, "u16": 2,
    "f8e4m3fn": 1, "f8e5m2": 1, "s8": 1, "u8": 1, "pred": 1, "s4": 1, "u4": 1,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_COMP_HDR = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\((.*?)\)\s*->")
# result type is either a tuple "(...)" (may contain /*index=N*/ comments,
# so anything but parens) or a single shape token
_INSTR = re.compile(r"^\s*(?:ROOT\s+)?%([\w.\-]+)\s*=\s*(\([^()]*\)|\S+)\s+([\w\-]+)\(")
_TRIP = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
_CALLS = re.compile(r"(?:calls|body|to_apply)=%?([\w.\-]+)")
_COND = re.compile(r"condition=%?([\w.\-]+)")
_BRANCHES = re.compile(r"branch_computations=\{([^}]*)\}")
_OPERANDS = re.compile(r"%([\w.\-]+)")
_CONTRACT = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")
_GROUPS = re.compile(r"replica_groups=\{\{([\d,]+)\}")

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")
_SKIP_BYTES = {
    "parameter", "constant", "tuple", "get-tuple-element", "bitcast",
    "while", "call", "conditional", "get-dimension-size", "after-all",
    "bitcast-convert",
}
_TRANSCENDENTAL = {"exponential", "tanh", "log", "rsqrt", "sqrt", "power",
                   "logistic", "sine", "cosine", "exponential-minus-one"}


def _type_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _type_numel(type_str: str) -> int:
    n_total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        n_total += n
    return n_total


class _Op:
    __slots__ = ("name", "rtype", "opcode", "line")

    def __init__(self, name, rtype, opcode, line):
        self.name, self.rtype, self.opcode, self.line = name, rtype, opcode, line


def parse_hlo(text: str) -> Dict[str, List[_Op]]:
    """Split HLO text into computations: name -> [ops]."""
    comps: Dict[str, List[_Op]] = {}
    cur: Optional[List[_Op]] = None
    for raw in text.splitlines():
        line = raw.rstrip()
        hdr = _COMP_HDR.match(line)
        if hdr and line.endswith("{"):
            cur = comps.setdefault(hdr.group(1), [])
            continue
        if cur is None:
            continue
        if line.strip() == "}":
            cur = None
            continue
        m = _INSTR.match(line)
        if m:
            cur.append(_Op(m.group(1), m.group(2), m.group(3), line))
    return comps


_CONST_INT = re.compile(r"\bconstant\((-?\d+)\)")


def _while_trip(op: _Op, comps: Dict[str, List["_Op"]]) -> float:
    """Trip count of a while op.

    Prefer the explicit ``known_trip_count`` backend config; when the SPMD
    printer drops it, recover the bound from the loop condition: lax.scan
    always counts 0..N-1 against an s32 constant N, so the largest integer
    constant in the condition computation is the trip count."""
    m = _TRIP.search(op.line)
    if m:
        return float(m.group(1))
    cm = _COND.search(op.line)
    if cm:
        bounds = []
        for o in comps.get(cm.group(1), []):
            if o.opcode == "constant" and o.rtype.startswith("s32"):
                im = _CONST_INT.search(o.line)
                if im:
                    bounds.append(int(im.group(1)))
        if bounds:
            return float(max(max(bounds), 1))
    return 1.0


def _dot_flops(op: _Op, shapes: Dict[str, str]) -> float:
    """2 * numel(result) * prod(contracted dims of lhs)."""
    ops = _OPERANDS.findall(op.line[op.line.index("(") :])
    cm = _CONTRACT.search(op.line)
    if not ops or cm is None:
        return 0.0
    lhs_type = shapes.get(ops[0], "")
    sm = _SHAPE_RE.search(lhs_type)
    if not sm:
        return 0.0
    dims = [int(d) for d in sm.group(2).split(",") if d]
    k = 1
    for ci in cm.group(1).split(","):
        if ci:
            k *= dims[int(ci)]
    return 2.0 * _type_numel(op.rtype) * k


def hlo_cost(text: str, top_k: int = 0) -> dict:
    """Weighted costs; with ``top_k`` > 0 also returns the top byte-consuming
    op sites as (weighted_bytes, weight, opcode, result_type, op_name-hint)."""
    comps = parse_hlo(text)
    memo: Dict[str, dict] = {}
    sites: List[tuple] = []
    weights: Dict[str, float] = {}  # total invocation weight per computation

    def analyze(comp_name: str) -> dict:
        if comp_name in memo:
            return memo[comp_name]
        memo[comp_name] = res = {
            "dot_flops": 0.0, "bytes": 0.0, "transcendentals": 0.0,
            "collectives": {},
        }
        ops = comps.get(comp_name, [])
        shapes = {o.name: o.rtype for o in ops}
        for op in ops:
            oc = op.opcode
            # --- recursion into called computations -----------------------
            weight = 1.0
            called: List[str] = []
            if oc == "while":
                weight = _while_trip(op, comps)
                cm = _CALLS.search(op.line)
                if cm:
                    called.append(cm.group(1))
            elif oc in ("call", "async-start"):
                cm = _CALLS.search(op.line)
                if cm:
                    called.append(cm.group(1))
            elif oc == "conditional":
                bm = _BRANCHES.search(op.line)
                if bm:  # worst-case: max branch (approx: first branch)
                    called += [b.strip().lstrip("%") for b in bm.group(1).split(",")]
            elif oc == "fusion":
                cm = _CALLS.search(op.line)
                if cm:  # count dots/transcendentals inside, bytes at call site
                    sub = analyze(cm.group(1))
                    res["dot_flops"] += sub["dot_flops"]
                    res["transcendentals"] += sub["transcendentals"]
            for c in called:
                sub = analyze(c)
                for k in ("dot_flops", "bytes", "transcendentals"):
                    res[k] += weight * sub[k]
                for cname, ce in sub["collectives"].items():
                    e = res["collectives"].setdefault(cname, {"count": 0.0, "bytes": 0.0})
                    e["count"] += weight * ce["count"]
                    e["bytes"] += weight * ce["bytes"]

            # --- own costs -------------------------------------------------
            if oc == "dot":
                res["dot_flops"] += _dot_flops(op, shapes)
            if oc in _TRANSCENDENTAL:
                res["transcendentals"] += _type_numel(op.rtype)
            base = oc.replace("-start", "")
            if base in _COLLECTIVES:
                nbytes = _type_bytes(op.rtype)
                e = res["collectives"].setdefault(base, {"count": 0.0, "bytes": 0.0})
                e["count"] += 1
                e["bytes"] += nbytes
            if oc not in _SKIP_BYTES and not oc.endswith("-done"):
                if oc == "dynamic-slice":
                    # reads only the slice it extracts, not the whole input
                    res["bytes"] += 2.0 * _type_bytes(op.rtype)
                elif oc == "dynamic-update-slice":
                    # in-place on TPU: traffic = update read + slice write
                    ops_ = _OPERANDS.findall(op.line[op.line.index("(") :])
                    upd = _type_bytes(shapes.get(ops_[1], "")) if len(ops_) > 1 else 0
                    res["bytes"] += 2.0 * upd
                else:
                    nbytes = _type_bytes(op.rtype)
                    for operand in _OPERANDS.findall(op.line[op.line.index("(") :]):
                        if operand in shapes:
                            nbytes += _type_bytes(shapes[operand])
                    res["bytes"] += nbytes
        return res

    entry = None
    for name in comps:
        if re.search(r"^ENTRY\s+%?" + re.escape(name), text, re.M):
            entry = name
            break
    if entry is None:  # fall back: computation named main*
        entry = next((n for n in comps if n.startswith("main")), next(iter(comps)))
    result = analyze(entry)

    if top_k:
        # top-down weight propagation (HLO computations form a call tree)
        def propagate(comp_name: str, w: float, depth: int = 0):
            if depth > 50:
                return
            weights[comp_name] = weights.get(comp_name, 0.0) + w
            for op in comps.get(comp_name, []):
                mult = 1.0
                called = []
                if op.opcode == "while":
                    mult = _while_trip(op, comps)
                    cm = _CALLS.search(op.line)
                    if cm:
                        called.append(cm.group(1))
                elif op.opcode in ("call", "async-start", "fusion"):
                    cm = _CALLS.search(op.line)
                    if cm and op.opcode != "fusion":
                        called.append(cm.group(1))
                elif op.opcode == "conditional":
                    bm = _BRANCHES.search(op.line)
                    if bm:
                        called += [b.strip().lstrip("%") for b in bm.group(1).split(",")]
                for c in called:
                    propagate(c, w * mult, depth + 1)

        propagate(entry, 1.0)
        for comp_name, w in weights.items():
            ops = comps.get(comp_name, [])
            shapes = {o.name: o.rtype for o in ops}
            for op in ops:
                if op.opcode in _SKIP_BYTES or op.opcode.endswith("-done"):
                    continue
                if op.opcode == "dynamic-slice":
                    nbytes = 2.0 * _type_bytes(op.rtype)
                elif op.opcode == "dynamic-update-slice":
                    ops_ = _OPERANDS.findall(op.line[op.line.index("(") :])
                    nbytes = 2.0 * (_type_bytes(shapes.get(ops_[1], "")) if len(ops_) > 1 else 0)
                else:
                    nbytes = _type_bytes(op.rtype)
                    for operand in _OPERANDS.findall(op.line[op.line.index("(") :]):
                        if operand in shapes:
                            nbytes += _type_bytes(shapes[operand])
                if nbytes:
                    hint = ""
                    hm = re.search(r'op_name="([^"]*)"', op.line)
                    if hm:
                        hint = hm.group(1)[-90:]
                    sites.append((nbytes * w, w, op.opcode, op.rtype[:60], hint))
        sites.sort(key=lambda s: -s[0])
        result["top_sites"] = sites[:top_k]
    return result
