"""Asyncio streaming frontend over the Scheduler (DESIGN.md §12).

:class:`AsyncEngine` runs the blocking continuous-batching loop on a worker
thread and bridges it to an asyncio event loop:

* ``submit()`` returns a :class:`TokenStream` — an ``AsyncIterator[int]``
  that yields tokens as segment syncs surface them (tokens are only
  *observable* at syncs; the per-sync push costs zero extra device traffic
  because the scheduler's token lists already live on the host).
* Every externally visible event is journaled through
  :class:`~.journal.JournalTap` (submit / admit / token-batch / retire),
  fsync'd once per segment sync.  :meth:`recover` rebuilds a crashed
  engine from its journal: proven completions come back verbatim, in-flight
  requests re-execute under their ORIGINAL rids and seeds, so the token
  streams are bit-identical to a crash-free run.
* A watchdog task converts a wedged segment (real, or injected via
  ``FaultConfig.decode_hang_rids``) into a fail-fast ``STALLED`` abort
  instead of hanging the event loop: the scheduler re-queues each in-flight
  request once (re-execution is bit-identical; consumers just see the tail
  late) and terminally retires repeat offenders.
* ``drain()`` stops admission and waits for in-flight work; ``hot_swap()``
  drains, rebuilds the VUSA pack via ``Engine.reload_packed``, re-jits the
  scheduler's segment dispatchers, and resumes — zero dropped requests.

Threading model: the event loop owns submission and consumption; the worker
thread owns the scheduler.  Submissions are buffered under a lock and
injected into the scheduler only from the worker (at syncs, or between
runs), so the scheduler itself is never touched from two threads — the only
cross-thread calls into it are the documented flag-setters ``drain`` /
``resume_admission`` / ``abort``.
"""

from __future__ import annotations

import asyncio
import threading
from typing import AsyncIterator, Dict, List, Optional, Tuple

import numpy as np

from .journal import Journal, JournalTap, recover_into
from .scheduler import Completion, Request, Scheduler, Status

__all__ = ["AsyncEngine", "TokenStream"]

_EOS = object()  # stream sentinel


class TokenStream:
    """Async iterator over one request's tokens, ending with its Completion.

    Tokens arrive in segment-sync batches; iteration yields them one at a
    time.  After exhaustion :meth:`completion` returns immediately (it can
    also be awaited without iterating — a non-streaming caller's one-shot)."""

    def __init__(self, rid: int, loop: asyncio.AbstractEventLoop):
        self.rid = rid
        self._q: asyncio.Queue = asyncio.Queue()
        self._loop = loop
        self._done: asyncio.Future = loop.create_future()

    def __aiter__(self) -> AsyncIterator[int]:
        return self

    async def __anext__(self) -> int:
        if self._done.done() and self._q.empty():
            raise StopAsyncIteration
        item = await self._q.get()
        if item is _EOS:
            raise StopAsyncIteration
        return item

    async def completion(self) -> Completion:
        """The request's terminal Completion (status + full token array)."""
        return await asyncio.shield(self._done)

    # -- worker-thread side (called via call_soon_threadsafe) ----------------

    def _feed(self, toks: List[int]) -> None:
        for t in toks:
            self._q.put_nowait(t)

    def _finish(self, comp: Completion) -> None:
        self._q.put_nowait(_EOS)
        if not self._done.done():
            self._done.set_result(comp)


class AsyncEngine:
    """Crash-safe asyncio driver around a :class:`Scheduler`.

    ``watchdog_s`` arms the stall watchdog: a running scheduler that has not
    completed a segment sync for this long is aborted ``STALLED``.  ``None``
    disarms it (trust the device).  ``journal`` persists every request event
    for :meth:`recover`; ``None`` serves memory-only.
    """

    def __init__(
        self,
        sched: Scheduler,
        journal: Optional[Journal] = None,
        watchdog_s: Optional[float] = None,
        completed: Optional[Dict[int, Completion]] = None,
    ):
        self.sched = sched
        self.journal = journal
        self.watchdog_s = watchdog_s
        self._loop: Optional[asyncio.AbstractEventLoop] = None
        self._lock = threading.Lock()
        self._pending: List[Tuple[int, Request]] = []  # event loop -> worker
        self._wake = threading.Event()
        self._idle = threading.Event()
        self._idle.set()
        self._stop = False
        self._thread: Optional[threading.Thread] = None
        self._watchdog_task: Optional[asyncio.Task] = None
        self._streams: Dict[int, TokenStream] = {}
        # completions the journal proved before this process started
        # (recovery), merged with everything retired since
        self._completed: Dict[int, Completion] = dict(completed or {})
        self._next_rid = (
            max(self._completed, default=-1) + 1 if self._completed else 0
        )
        self._tap = JournalTap(
            journal, on_new_tokens=self._on_tokens, on_retire=self._on_retire
        )
        # lifetime SLO series (scheduler stats reset per run epoch; a
        # long-lived server wants the union)
        self._ttft: List[float] = []
        self._latency: List[float] = []
        self._itl_all: List[float] = []  # finished epochs' ITL samples
        self._last_sync = sched._clock()
        self._recovered_rids: List[int] = []

    # -- lifecycle -----------------------------------------------------------

    async def start(self) -> "AsyncEngine":
        """Bind to the running event loop and start the worker thread (and
        the watchdog, if armed).  Idempotent per engine."""
        if self._thread is not None:
            return self
        self._loop = asyncio.get_running_loop()
        self._thread = threading.Thread(
            target=self._worker, name="async-engine", daemon=True
        )
        self._thread.start()
        if self.watchdog_s is not None:
            self._watchdog_task = self._loop.create_task(self._watchdog())
        # recovered requests are already queued in the scheduler: kick the
        # worker so their re-execution starts without waiting for traffic
        if self.sched.has_work:
            self._wake.set()
        return self

    async def __aenter__(self) -> "AsyncEngine":
        return await self.start()

    async def __aexit__(self, *exc) -> None:
        await self.close()

    async def close(self, clean: bool = True) -> None:
        """Stop the worker and close the journal.  ``clean`` appends the
        close marker — a journal without one reads as a crash (which is
        exactly right for tests that simulate one)."""
        self._stop = True
        self.sched.drain()
        self._wake.set()
        if self._watchdog_task is not None:
            self._watchdog_task.cancel()
            self._watchdog_task = None
        if self._thread is not None:
            await asyncio.get_running_loop().run_in_executor(
                None, self._thread.join
            )
            self._thread = None
        if self.journal is not None:
            self.journal.close(clean=clean)
        self.sched.resume_admission()  # leave the scheduler reusable

    @classmethod
    def recover(
        cls,
        path,
        sched: Scheduler,
        watchdog_s: Optional[float] = None,
    ) -> "AsyncEngine":
        """Rebuild an engine from a crashed journal: proven completions are
        served from the journal verbatim (no recompute), every non-retired
        request is re-queued under its original rid/seed, and the journal is
        reopened (torn tail truncated, ``recover`` marker fsync'd).  Start
        the returned engine with :meth:`start`; re-executed streams are
        journaled and streamed from token 0."""
        journal, completed, recovered = recover_into(path, sched)
        eng = cls(sched, journal=journal, watchdog_s=watchdog_s, completed=completed)
        eng._recovered_rids = recovered
        eng._next_rid = max(
            [eng._next_rid] + [r + 1 for r in recovered]
        )
        return eng

    # -- submission / streaming ----------------------------------------------

    def submit(self, req: Request, rid: Optional[int] = None) -> TokenStream:
        """Queue a request; returns its :class:`TokenStream` immediately.
        The submit record is journaled now (durable at the next segment
        sync — an ack that races a crash is re-submitted by the client,
        classic WAL semantics); the scheduler sees the request at the next
        sync boundary or idle wakeup."""
        if self._loop is None:
            raise RuntimeError("AsyncEngine.submit before start()")
        if self._stop:
            raise RuntimeError("AsyncEngine is closed")
        if self.sched.draining:
            raise RuntimeError("AsyncEngine is draining — admission is closed")
        with self._lock:
            if rid is None:
                rid = self._next_rid
            self._next_rid = max(self._next_rid, rid + 1)
            stream = TokenStream(rid, self._loop)
            self._streams[rid] = stream
            self._pending.append((rid, req))
        self._tap.note_submit(rid, req)
        self._wake.set()
        return stream

    def stream_for(self, rid: int) -> Optional[TokenStream]:
        """Re-attach to a live request's stream (e.g. one recovered from the
        journal, whose original consumer died with the process)."""
        if self._loop is None:
            raise RuntimeError("AsyncEngine.stream_for before start()")
        with self._lock:
            if rid in self._streams:
                return self._streams[rid]
            if rid in self._completed:
                stream = TokenStream(rid, self._loop)
                comp = self._completed[rid]
                stream._feed([int(t) for t in comp.tokens])
                stream._finish(comp)
                self._streams[rid] = stream
                return stream
            # live in the scheduler (recovered, or submitted earlier):
            # tokens already streamed are gone with the old consumer; the
            # tap's emitted counts make the new stream carry the rest.
            # Recovery resets those counts, so a recovered rid's stream
            # re-plays from token 0.
            stream = TokenStream(rid, self._loop)
            self._streams[rid] = stream
            return stream

    @property
    def recovered_rids(self) -> List[int]:
        return list(self._recovered_rids)

    def completion_for(self, rid: int) -> Optional[Completion]:
        return self._completed.get(rid)

    # -- drain / hot swap ----------------------------------------------------

    async def drain(self, timeout_s: Optional[float] = None) -> bool:
        """Stop admission and wait for in-flight work to finish (queued
        requests survive for after :meth:`resume`).  On timeout the stuck
        work is aborted ``CANCELLED`` (bounded re-queue first, as always)
        and False is returned — drain never hangs shutdown."""
        self.sched.drain()
        self._wake.set()
        deadline = (
            None if timeout_s is None else self.sched._clock() + timeout_s
        )
        while True:
            busy = not self._idle.is_set() or any(
                s.active for s in self.sched._slot
            )
            if not busy:
                return True
            if deadline is not None and self.sched._clock() > deadline:
                self.sched.abort(Status.CANCELLED)
                while not self._idle.is_set():
                    await asyncio.sleep(0.005)
                return False
            await asyncio.sleep(0.005)

    def resume(self) -> None:
        """Re-open admission after :meth:`drain`."""
        self.sched.resume_admission()
        self._wake.set()

    async def hot_swap(
        self, params=None, timeout_s: Optional[float] = None
    ) -> bool:
        """Zero-downtime pack swap: drain in-flight work, rebuild the VUSA
        pack (``Engine.reload_packed``), re-jit the scheduler's segment
        dispatchers so the new pack binds, journal the swap fingerprint, and
        resume admission.  Queued requests ride through untouched — nothing
        is dropped.  Returns True if a pack was actually swapped (False on a
        dense engine; admission still cycles cleanly)."""
        await self.drain(timeout_s)
        try:
            swapped = self.sched.eng.reload_packed(params)
            if swapped:
                self.sched.refresh_decode()
                if self.journal is not None:
                    from .packed import pack_fingerprint

                    self.journal.append(
                        {"t": "swap", "fp": pack_fingerprint(self.sched.eng._packed)}
                    )
                    self.journal.sync()
        finally:
            self.resume()
        return swapped

    # -- stats ---------------------------------------------------------------

    def stats(self) -> Dict[str, float]:
        """Lifetime SLO view: TTFT / end-to-end latency / ITL percentiles
        over every completion this engine has seen (scheduler ``stats()``
        covers only the latest run epoch), plus journal and recovery
        counters.  NaN on empty series — an idle server must not read as an
        infinitely fast one."""

        def pct(vals: List[float], q: float) -> float:
            a = np.asarray(vals, np.float64)
            a = a[np.isfinite(a)]
            return float(np.percentile(a, q)) if a.size else float("nan")

        itl = list(self._itl_all)
        if not self._idle.is_set():
            # mid-run: the current epoch's samples are not yet harvested
            itl += self.sched.itl_samples()
        out = {
            "requests_completed": float(len(self._completed)),
            "recovered_requests": float(len(self._recovered_rids)),
            "ttft_p50_s": pct(self._ttft, 50),
            "ttft_p95_s": pct(self._ttft, 95),
            "ttft_p99_s": pct(self._ttft, 99),
            "latency_p50_s": pct(self._latency, 50),
            "latency_p95_s": pct(self._latency, 95),
            "latency_p99_s": pct(self._latency, 99),
            "itl_p50_s": pct(itl, 50),
            "itl_p95_s": pct(itl, 95),
            "itl_p99_s": pct(itl, 99),
            "journal_records": float(
                self.journal.records_written if self.journal else 0
            ),
            "journal_syncs": float(self.journal.syncs if self.journal else 0),
        }
        for k, v in self.sched.stats().items():
            out.setdefault(k, v)
        return out

    # -- worker thread --------------------------------------------------------

    def _drain_pending(self) -> None:
        """Inject buffered submissions into the scheduler (worker thread
        only — the scheduler is single-threaded by design)."""
        with self._lock:
            pending, self._pending = self._pending, []
        for rid, req in pending:
            self.sched.submit(req, rid=rid)

    def _on_sync(self, sched: Scheduler) -> None:
        self._drain_pending()
        self._tap.on_sync(sched)
        self._last_sync = sched._clock()

    def _worker(self) -> None:
        while not self._stop:
            self._drain_pending()
            if self.sched.has_work and not (
                self.sched.draining
                and not any(s.active for s in self.sched._slot)
            ):
                self._idle.clear()
                self._last_sync = self.sched._clock()
                try:
                    self.sched.run(on_sync=self._on_sync)
                finally:
                    # harvest retirements that landed without a trailing
                    # sync (rejections, abort retirements, deadline sheds)
                    # and this epoch's ITL series before the next epoch
                    # resets it
                    self._drain_pending()
                    self._tap.on_sync(self.sched)
                    self._itl_all.extend(self.sched.itl_samples())
                    self._idle.set()
            else:
                self._idle.set()
                self._wake.wait(timeout=0.02)
                self._wake.clear()

    def _on_tokens(self, rid: int, toks: List[int]) -> None:
        stream = self._streams.get(rid)
        if stream is not None and self._loop is not None:
            self._loop.call_soon_threadsafe(stream._feed, list(toks))

    def _on_retire(self, rid: int, comp: Completion) -> None:
        # lock pairs with stream_for: a re-attach racing this retirement
        # either sees the live stream (finished below) or the completion
        with self._lock:
            self._completed[rid] = comp
            stream = self._streams.get(rid)
        if np.isfinite(comp.ttft_s):
            self._ttft.append(float(comp.ttft_s))
        if np.isfinite(comp.latency_s):
            self._latency.append(float(comp.latency_s))
        if stream is not None and self._loop is not None:
            self._loop.call_soon_threadsafe(stream._finish, comp)

    # -- watchdog -------------------------------------------------------------

    async def _watchdog(self) -> None:
        """Fail-fast stall detection: while the worker is mid-run, a sync
        gap longer than ``watchdog_s`` means the segment (or an injected
        hang) is wedged — abort ``STALLED`` so the run loop's interruptible
        waits bail out instead of hanging every consumer."""
        assert self.watchdog_s is not None
        tick = max(self.watchdog_s / 4, 0.005)
        while not self._stop:
            await asyncio.sleep(tick)
            busy = not self._idle.is_set()
            if busy and self.sched._clock() - self._last_sync > self.watchdog_s:
                self.sched.abort(Status.STALLED)
                self._last_sync = self.sched._clock()  # rearm for the retry
