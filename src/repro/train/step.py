"""Training step: loss -> grads -> AdamW, with optional gradient-accumulation
microbatching and int8 gradient compression (distributed-optimization trick;
stochastic rounding keeps it unbiased).

The step is a pure function of (params, opt_state, batch, step#) so it jits /
lowers AOT for the dry-run exactly as it runs in the trainer.
"""

from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp

from ..optim import AdamState, adamw_update
from ..optim.schedule import cosine_schedule

__all__ = ["TrainHParams", "make_train_step"]


@dataclasses.dataclass(frozen=True)
class TrainHParams:
    lr: float = 3e-4
    warmup: int = 100
    total_steps: int = 10_000
    weight_decay: float = 0.1
    max_grad_norm: float = 1.0
    microbatches: int = 1  # gradient accumulation over the leading batch dim
    grad_compress: bool = False  # int8 + stochastic rounding before reduce


def _compress_grads(grads, key):
    """int8-quantize per-tensor (symmetric, stochastic rounding), dequantize.

    Under DP the quantized tensor is what crosses the network; XLA sees the
    small dtype on the all-reduce input when this runs inside shard_map-less
    GSPMD too (the rounding happens before the psum insertion point)."""

    def q(g, k):
        g32 = g.astype(jnp.float32)
        scale = jnp.maximum(jnp.max(jnp.abs(g32)), 1e-12) / 127.0
        x = g32 / scale
        noise = jax.random.uniform(k, g.shape, jnp.float32) - 0.5
        xi = jnp.clip(jnp.round(x + noise), -127, 127).astype(jnp.int8)
        return xi.astype(jnp.float32) * scale

    leaves, treedef = jax.tree_util.tree_flatten(grads)
    keys = jax.random.split(key, len(leaves))
    return jax.tree_util.tree_unflatten(treedef, [q(g, k) for g, k in zip(leaves, keys)])


def make_train_step(loss_fn: Callable, hp: TrainHParams):
    """loss_fn(params, batch) -> scalar.  Returns step(params, opt, batch)."""

    def step(params, opt: AdamState, batch):
        lr = cosine_schedule(opt.step, hp.warmup, hp.total_steps, hp.lr)

        if hp.microbatches > 1:
            def micro(carry, mb):
                gsum = carry
                loss, g = jax.value_and_grad(loss_fn)(params, mb)
                return jax.tree_util.tree_map(jnp.add, gsum, g), loss

            mbs = jax.tree_util.tree_map(
                lambda x: x.reshape((hp.microbatches, x.shape[0] // hp.microbatches) + x.shape[1:]),
                batch,
            )
            zeros = jax.tree_util.tree_map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params
            )
            gsum, losses = jax.lax.scan(micro, zeros, mbs)
            grads = jax.tree_util.tree_map(lambda g: g / hp.microbatches, gsum)
            loss = losses.mean()
        else:
            loss, grads = jax.value_and_grad(loss_fn)(params, batch)

        if hp.grad_compress:
            grads = _compress_grads(grads, jax.random.fold_in(jax.random.key(0), opt.step))

        params, opt, gnorm = adamw_update(
            params,
            grads,
            opt,
            lr,
            weight_decay=hp.weight_decay,
            max_grad_norm=hp.max_grad_norm,
        )
        metrics = {"loss": loss, "gnorm": gnorm, "lr": lr}
        return params, opt, metrics

    return step
