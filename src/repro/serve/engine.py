"""Serving engine: batched prefill + decode with per-family caches, greedy /
temperature sampling, and optional VUSA-packed MLP execution (the paper's
technique on the inference path, where weight-byte savings pay off).
"""

from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..configs.base import ArchConfig
from ..models import build_model

__all__ = ["ServeConfig", "Engine"]


@dataclasses.dataclass
class ServeConfig:
    max_len: int = 256
    temperature: float = 0.0  # 0 => greedy
    seed: int = 0
    packed_mlp: bool = False  # run MLP matmuls VUSA-packed (dense family)
    vusa_m: int = 128  # window lanes (kernel tile)
    vusa_a: int = 16   # physical slots per row per job


class Engine:
    def __init__(self, cfg: ArchConfig, params, sc: ServeConfig = ServeConfig()):
        self.cfg, self.sc = cfg, sc
        self.model = build_model(cfg)
        self.params = params
        self._packed = None
        if sc.packed_mlp:
            from .packed import pack_lm_mlps  # local import: needs kernels

            self._packed = pack_lm_mlps(cfg, params, sc.vusa_m, sc.vusa_a)
        self._decode = jax.jit(self._decode_fn)
        self._prefill = jax.jit(self._prefill_fn) if cfg.family in (
            "dense", "moe", "vlm", "encdec") else None

    # -- jitted bodies --------------------------------------------------------
    def _decode_fn(self, params, token, cache, key):
        if self._packed is not None:
            from .packed import lm_decode_step_packed

            logits, cache = lm_decode_step_packed(
                params, self._packed, token, cache, self.cfg
            )
        else:
            logits, cache = self.model.decode_step(params, token, cache)
        logits = logits[:, -1].astype(jnp.float32)
        if self.sc.temperature > 0:
            nxt = jax.random.categorical(key, logits / self.sc.temperature)
        else:
            nxt = jnp.argmax(logits, axis=-1)
        return nxt.astype(jnp.int32)[:, None], cache

    def _prefill_fn(self, params, batch):
        return self.model.prefill(params, batch, self.sc.max_len)

    # -- public API -----------------------------------------------------------
    def generate(self, prompts: np.ndarray, max_new: int = 32, extras: Optional[Dict] = None):
        """prompts: (B, S) int32.  Returns dict with tokens and timing."""
        b, s = prompts.shape
        key = jax.random.key(self.sc.seed)
        t0 = time.time()
        batch = {"tokens": jnp.asarray(prompts)}
        if extras:
            batch.update({k: jnp.asarray(v) for k, v in extras.items()})
        if self._prefill is not None:
            logits, cache = self._prefill(self.params, batch)
            nxt = jnp.argmax(logits.astype(jnp.float32), axis=-1)[:, None].astype(jnp.int32)
        else:
            # recurrent families: prime the state by stepping through the prompt
            cache = self.model.init_cache(b, self.sc.max_len)
            nxt = prompts[:, :1]
            for t in range(s):
                key, sub = jax.random.split(key)
                nxt, cache = self._decode(self.params, jnp.asarray(prompts[:, t : t + 1]), cache, sub)
        t_prefill = time.time() - t0

        out = [np.asarray(nxt)]
        t0 = time.time()
        for _ in range(max_new - 1):
            key, sub = jax.random.split(key)
            nxt, cache = self._decode(self.params, nxt, cache, sub)
            out.append(np.asarray(nxt))
        jax.block_until_ready(nxt)
        t_decode = time.time() - t0
        tokens = np.concatenate(out, axis=1)
        return {
            "tokens": tokens,
            "prefill_s": t_prefill,
            "decode_s": t_decode,
            "tok_per_s": b * max_new / max(t_decode, 1e-9),
        }
