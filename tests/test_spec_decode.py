"""Self-speculative decoding via sparsity tiers (DESIGN.md §13).

Parity is the contract: greedy speculative decode must be token-bit-identical
to non-speculative decode in every serve mode (dense, whole-model packed,
int8 values, slot-pool and paged scheduling), because the verifier re-derives
every emitted token on the exact non-speculative path.  The multi-token
verify dispatch is covered at the unit level too — the dense chain and the
packed batched path must reproduce sequential single-token steps bitwise.

Also here: the latency-accounting regressions this PR fixed — ITL percentile
samples are per emission *event* (one interval per sync, however many tokens
surfaced together), proven with an injected deterministic clock.
"""

import dataclasses

import numpy as np
import pytest

import jax

from repro.configs import get_smoke_config
from repro.core.pruning import prune_tree
from repro.models import build_model
from repro.serve import Engine, FaultConfig, Request, Scheduler, ServeConfig, Status
from repro.serve.packed import lm_decode_step_packed, pack_lm_weights


def _tiered(params, detail=0.03):
    """Weights with the tier structure the drafter exploits (the paper's
    unstructured-sparsity regime): a dense core (top 1% of magnitudes), a
    low-magnitude detail tier (next 14%, scaled by ``detail``), zeros
    elsewhere.  A 99%-sparsity magnitude prune keeps exactly the core, so
    the drafter agrees with the verifier on most greedy argmaxes."""

    def leaf(w):
        w = np.asarray(w)
        if w.ndim < 2:
            return w
        a = np.abs(w)
        srt = np.sort(a.ravel())[::-1]
        t1 = srt[max(int(0.01 * a.size) - 1, 0)]
        t2 = srt[max(int(0.15 * a.size) - 1, 0)]
        return np.where(a >= t1, w, np.where(a >= t2, w * detail, 0.0)).astype(w.dtype)

    return jax.tree_util.tree_map(leaf, params)


@pytest.fixture(scope="module")
def vusa():
    cfg = get_smoke_config("vusa_edge")
    return cfg, build_model(cfg).init(jax.random.key(0))


@pytest.fixture(scope="module")
def vusa_tiered(vusa):
    cfg, params = vusa
    return cfg, _tiered(params)


@pytest.fixture(scope="module")
def vusa_pruned(vusa):
    cfg, params = vusa
    return cfg, prune_tree(params, 0.85)


def _prompt(seed=0, n=6, lo=1, hi=100):
    return np.random.default_rng(seed).integers(lo, hi, (1, n)).astype(np.int32)


# ---------------------------------------------------------------------------
# multi-token verify dispatch: bitwise vs sequential single-token steps
# ---------------------------------------------------------------------------


def test_multitoken_dense_chain_bitwise(vusa):
    """families.lm_decode_step with an (1, S) token runs as a chain of exact
    single-token steps inside one dispatch — logits and KV bitwise equal to
    S sequential calls, under jit (XLA gemms are not row-stable across row
    counts, which is why the dense path chains instead of batching)."""
    cfg, params = vusa
    model = build_model(cfg)
    rng = np.random.default_rng(1)
    toks = rng.integers(1, cfg.vocab, (1, 5)).astype(np.int32)

    multi = jax.jit(model.decode_step)
    lg_m, c_m = multi(params, toks, model.init_cache(1, 16))
    single = jax.jit(model.decode_step)
    c_s = model.init_cache(1, 16)
    parts = []
    for i in range(toks.shape[1]):
        lg, c_s = single(params, toks[:, i : i + 1], c_s)
        parts.append(np.asarray(lg))
    np.testing.assert_array_equal(np.asarray(lg_m), np.concatenate(parts, axis=1))
    for name in ("k", "v"):
        np.testing.assert_array_equal(np.asarray(c_m[name]), np.asarray(c_s[name]))
    assert int(c_m["pos"]) == toks.shape[1]


def test_multitoken_packed_batched_bitwise(vusa_pruned):
    """lm_decode_step_packed with a FULL pack (scope='all', untied head)
    genuinely batches the S rows through the Pallas appliers — which, unlike
    XLA gemms, are row-bitwise across row counts — so the batched verify
    must equal S sequential packed steps bit for bit, under jit."""
    cfg, params = vusa_pruned
    assert not cfg.tie_embeddings  # full pack needs the untied head
    model = build_model(cfg)
    packed = pack_lm_weights(cfg, params, scope="all")
    rng = np.random.default_rng(2)
    toks = rng.integers(1, cfg.vocab, (1, 4)).astype(np.int32)

    step = jax.jit(
        lambda p, t, c: lm_decode_step_packed(p, packed, t, c, cfg)
    )
    lg_m, c_m = step(params, toks, model.init_cache(1, 16))
    c_s = model.init_cache(1, 16)
    parts = []
    for i in range(toks.shape[1]):
        lg, c_s = step(params, toks[:, i : i + 1], c_s)
        parts.append(np.asarray(lg))
    np.testing.assert_array_equal(np.asarray(lg_m), np.concatenate(parts, axis=1))
    for name in ("k", "v"):
        np.testing.assert_array_equal(np.asarray(c_m[name]), np.asarray(c_s[name]))


# ---------------------------------------------------------------------------
# engine-level parity: speculative generate == non-speculative generate
# ---------------------------------------------------------------------------


def _pair(cfg, params, temp, mode, **spec_kw):
    """(base, speculative) engines for one serve mode; identical seeds."""
    base_sc = ServeConfig(
        max_len=96,
        temperature=temp,
        packed_weights=False if mode == "dense" else "all",
        packed_values="int8" if mode == "int8" else "bf16",
    )
    spec_sc = dataclasses.replace(
        base_sc,
        **{"speculative": True, "draft_k": 4, "draft_sparsity": 0.99, **spec_kw},
    )
    return (
        Engine(cfg, params, base_sc),
        Engine(cfg, params, spec_sc),
    )


@pytest.mark.parametrize("temp", [0.0, 1.0])
@pytest.mark.parametrize("mode", ["dense", "all", "int8"])
def test_generate_spec_parity(vusa_tiered, temp, mode):
    """Speculative generate must be token-bit-identical to the plain fused
    loop — greedy AND sampled (the PRNG key advances once per emitted token,
    exactly the non-speculative split sequence), dense, whole-model packed
    and int8-valued packs alike."""
    cfg, params = vusa_tiered
    base, spec = _pair(cfg, params, temp, mode)
    prompt = _prompt(3)
    want = base.generate(prompt, max_new=24)
    got = spec.generate(prompt, max_new=24)
    np.testing.assert_array_equal(got["tokens"], want["tokens"])
    assert got["spec_rounds"] >= 1
    assert got["spec_proposed"] == got["spec_rounds"] * 4
    assert 0.0 <= got["acceptance_rate"] <= 1.0


def test_k1_degenerate(vusa_tiered):
    """draft_k=1 is the smallest legal draft: one drafted token per round,
    still bit-identical, still at least one emission per round."""
    cfg, params = vusa_tiered
    base, spec = _pair(cfg, params, 0.0, "all", draft_k=1)
    prompt = _prompt(4)
    want = base.generate(prompt, max_new=16)["tokens"]
    got = spec.generate(prompt, max_new=16)
    np.testing.assert_array_equal(got["tokens"], want)
    assert got["spec_rounds"] <= 15  # every round emits >= 1 token


def test_all_accept_when_drafter_is_verifier(vusa_pruned):
    """draft_sparsity=0 packs the verifier's own weights as the drafter —
    every greedy draft must be accepted (acceptance exactly 1.0) and each
    round must emit the full k+1 tokens."""
    cfg, params = vusa_pruned
    base, spec = _pair(cfg, params, 0.0, "all", draft_sparsity=0.0)
    prompt = _prompt(5)
    want = base.generate(prompt, max_new=21)["tokens"]
    got = spec.generate(prompt, max_new=21)
    np.testing.assert_array_equal(got["tokens"], want)
    assert got["acceptance_rate"] == 1.0
    assert got["spec_rounds"] == 4  # 20 decode tokens / (k+1)=5 per round


def test_mostly_reject_still_bit_identical(vusa_pruned):
    """Random-init magnitude tiers carry no structure, so a 99%-sparsity
    drafter is mostly wrong — acceptance collapses but the output is STILL
    bit-identical: rejection costs speed, never correctness."""
    cfg, params = vusa_pruned
    base, spec = _pair(cfg, params, 0.0, "all")
    prompt = _prompt(6)
    want = base.generate(prompt, max_new=20)["tokens"]
    got = spec.generate(prompt, max_new=20)
    np.testing.assert_array_equal(got["tokens"], want)
    assert got["acceptance_rate"] <= 0.3


# ---------------------------------------------------------------------------
# scheduler integration: spec rounds through the fused segment scan
# ---------------------------------------------------------------------------


def _reqs(n=5, seed=0, max_new=10, **kw):
    rng = np.random.default_rng(seed)
    return [
        Request(prompt=rng.integers(1, 100, 6).astype(np.int32), max_new=max_new,
                seed=i, **kw)
        for i in range(n)
    ]


def _spec_sc(**kw):
    return ServeConfig(
        max_len=160, packed_weights="all",
        speculative=True, draft_k=4, draft_sparsity=0.99, **kw
    )


def test_scheduler_spec_parity_slot_pool(vusa_tiered):
    """Speculative continuous batching over the slot pool: every completion
    bit-identical to the non-speculative scheduler, and the acceptance
    counters live in stats()."""
    cfg, params = vusa_tiered
    base_sc = ServeConfig(max_len=160, packed_weights="all")
    want = Scheduler(Engine(cfg, params, base_sc), slots=4, segment=3).run(_reqs())
    sched = Scheduler(Engine(cfg, params, _spec_sc()), slots=4, segment=3)
    got = sched.run(_reqs())
    assert set(got) == set(want)
    for rid in want:
        np.testing.assert_array_equal(got[rid].tokens, want[rid].tokens)
    st = sched.stats()
    assert st["spec_proposed"] > 0
    assert 0.0 < st["acceptance_rate"] <= 1.0
    assert st["tok_per_s"] > 0


def test_scheduler_spec_parity_paged(vusa_tiered):
    """Paged twin: each slot gathers its block view, runs the round, and the
    verifier rows scatter back through paged_scatter_rows — tokens must stay
    bit-identical to the slot-pool speculative run (hence to non-spec)."""
    cfg, params = vusa_tiered
    want = Scheduler(Engine(cfg, params, _spec_sc()), slots=4, segment=3).run(_reqs())
    sched = Scheduler(
        Engine(cfg, params, _spec_sc(page_size=16)), slots=4, segment=3
    )
    got = sched.run(_reqs())
    assert set(got) == set(want)
    for rid in want:
        np.testing.assert_array_equal(got[rid].tokens, want[rid].tokens)
    assert sched.verify_paged_mirror()


@pytest.mark.slow
@pytest.mark.parametrize("page", [0, 16])
def test_scheduler_spec_parity_sampled(vusa_tiered, page):
    """Sampled speculative serving (temperature 1.0): greedy drafts almost
    never match the sampled stream, so this is the all-reject regime at
    scheduler scale — parity must hold anyway, in both pool modes."""
    cfg, params = vusa_tiered
    base_sc = ServeConfig(max_len=160, packed_weights="all", temperature=1.0)
    want = Scheduler(Engine(cfg, params, base_sc), slots=4, segment=3).run(_reqs())
    sched = Scheduler(
        Engine(cfg, params, _spec_sc(temperature=1.0, page_size=page)),
        slots=4, segment=3,
    )
    got = sched.run(_reqs())
    for rid in want:
        np.testing.assert_array_equal(got[rid].tokens, want[rid].tokens)


def test_eos_mid_draft_stops_stream(vusa_tiered):
    """EOS landing mid-round: the host consumes the round's tokens in order
    and retires at the first EOS — nothing past it may leak into the
    completion, and the stream matches the non-speculative EOS run."""
    cfg, params = vusa_tiered
    # find a token the greedy stream actually emits, away from position 0,
    # so EOS falls inside a speculative round's accepted window
    probe = Engine(cfg, params, ServeConfig(max_len=160, packed_weights="all"))
    stream = probe.generate(_prompt(7), max_new=12)["tokens"][0]
    eos = int(stream[5])
    req = lambda: [Request(prompt=_prompt(7)[0], max_new=12, seed=0, eos_id=eos)]
    base_sc = ServeConfig(max_len=160, packed_weights="all")
    want = Scheduler(Engine(cfg, params, base_sc), slots=2, segment=3).run(req())
    got = Scheduler(Engine(cfg, params, _spec_sc()), slots=2, segment=3).run(req())
    np.testing.assert_array_equal(got[0].tokens, want[0].tokens)
    toks = np.asarray(got[0].tokens)
    hits = np.flatnonzero(toks == eos)
    assert hits.size >= 1 and hits[0] == len(toks) - 1, (
        "tokens past the first EOS leaked out of a speculative round"
    )


def test_spec_quarantine_falls_back_dense(vusa_pruned):
    """NaN corruption in the verifier pack under speculative serving: the
    pack quarantines, rounds continue with the dense verifier (drafter keeps
    its own validated pack), and every request finishes FAILED_FALLBACK_OK
    bit-identical to a clean dense run."""
    cfg, params = vusa_pruned
    sc = _spec_sc(faults=FaultConfig(seed=0, pack_value_nans=2))
    eng = Engine(cfg, params, sc)
    assert eng.packed_active
    sched = Scheduler(eng, slots=3, segment=3)
    done = sched.run(_reqs(3, seed=2))
    assert eng.quarantined and not eng.packed_active
    dense_sc = ServeConfig(max_len=160)
    clean = Scheduler(
        Engine(cfg, params, dense_sc), slots=3, segment=3
    ).run(_reqs(3, seed=2))
    for rid, c in done.items():
        assert c.status is Status.FAILED_FALLBACK_OK, (rid, c.status)
        np.testing.assert_array_equal(c.tokens, clean[rid].tokens, err_msg=f"rid {rid}")
    st = sched.stats()
    assert st["quarantined"] == 1 and st["failed"] == 0


# ---------------------------------------------------------------------------
# ITL accounting (the latency bugfix this feature depends on)
# ---------------------------------------------------------------------------


class _FakeClock:
    def __init__(self):
        self.t = 0.0

    def __call__(self):
        return self.t

    def sleep(self, dt):
        self.t += dt


@pytest.mark.parametrize("speculative", [False, True])
def test_itl_one_sample_per_emission_event(vusa_tiered, speculative):
    """Tokens surface only at segment syncs, so each sync's emission is ONE
    observable event: with an injected clock that advances exactly 1.0 s per
    sync, every ITL sample must be exactly 1.0 — the seed recorded k copies
    of (gap / k) per sync (fabricating sub-second percentiles out of a
    1-second cadence), and under speculation k varies per round, which made
    the fabricated percentiles meaningless."""
    cfg, params = vusa_tiered
    sc = _spec_sc() if speculative else ServeConfig(max_len=160, packed_weights="all")
    clk = _FakeClock()
    # speculative rounds emit up to draft_k+1 tokens per sync — segment=1
    # keeps the 12-token stream spanning several syncs in both modes
    sched = Scheduler(
        Engine(cfg, params, sc), slots=1, segment=1 if speculative else 3,
        clock=clk, sleep=clk.sleep,
    )
    for r in _reqs(1, max_new=12):
        sched.submit(r)
    done = sched.run(on_sync=lambda s: clk.sleep(1.0))
    assert len(done[0].tokens) == 12
    samples = sched.itl_samples()
    assert samples, "a multi-sync stream must contribute interval samples"
    assert set(samples) == {1.0}, (
        f"per-emission-event sampling must yield whole sync gaps, got {samples}"
    )
    st = sched.stats()
    assert st["itl_p50_s"] == 1.0 and st["itl_p99_s"] == 1.0
