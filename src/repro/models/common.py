"""Model-framework plumbing: abstract parameter specs (single source of truth
for shapes, dtypes, logical sharding axes), init, and activation-sharding
helpers.

Every layer builds a pytree of :class:`ParamSpec` leaves.  From that one tree
we derive (a) randomly-initialised parameters, (b) ``ShapeDtypeStruct`` trees
for AOT lowering, and (c) ``NamedSharding`` trees via the logical-axis rules
in :mod:`repro.dist.sharding`.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

__all__ = [
    "ParamSpec",
    "init_params",
    "abstract_params",
    "rms_norm",
    "layer_norm",
    "rope",
    "apply_rope",
    "shard",
    "mesh_context",
]


@dataclasses.dataclass(frozen=True)
class ParamSpec:
    """Abstract description of one parameter tensor."""

    shape: Tuple[int, ...]
    axes: Tuple[Optional[str], ...]  # logical axis names, len == len(shape)
    dtype: jnp.dtype = jnp.float32
    init: str = "normal"  # normal | zeros | ones
    scale: float = 1.0

    def __post_init__(self):
        assert len(self.shape) == len(self.axes), (self.shape, self.axes)


def _init_leaf(spec: ParamSpec, key: jax.Array) -> jax.Array:
    if spec.init == "zeros":
        return jnp.zeros(spec.shape, spec.dtype)
    if spec.init == "ones":
        return jnp.ones(spec.shape, spec.dtype)
    fan_in = spec.shape[-2] if len(spec.shape) >= 2 else spec.shape[-1]
    std = spec.scale / math.sqrt(max(fan_in, 1))
    return (std * jax.random.normal(key, spec.shape, jnp.float32)).astype(spec.dtype)


def _is_spec(x) -> bool:
    return isinstance(x, ParamSpec)


def init_params(spec_tree, key: jax.Array):
    """Materialise random parameters from a ParamSpec tree."""
    leaves, treedef = jax.tree_util.tree_flatten(spec_tree, is_leaf=_is_spec)
    keys = jax.random.split(key, len(leaves))
    return jax.tree_util.tree_unflatten(
        treedef, [_init_leaf(s, k) for s, k in zip(leaves, keys)]
    )


def abstract_params(spec_tree):
    """ShapeDtypeStruct tree (for AOT lowering — no allocation)."""
    return jax.tree_util.tree_map(
        lambda s: jax.ShapeDtypeStruct(s.shape, s.dtype), spec_tree, is_leaf=_is_spec
    )


# --------------------------------------------------------------------------
# Activation sharding context
# --------------------------------------------------------------------------

import contextlib  # noqa: E402  (section-local deps, kept with their code)
import threading  # noqa: E402

_ctx = threading.local()


@contextlib.contextmanager
def mesh_context(mesh, rules):
    """Install a (mesh, logical-rules) context; ``shard()`` becomes active."""
    prev = getattr(_ctx, "state", None)
    _ctx.state = (mesh, rules)
    try:
        yield
    finally:
        _ctx.state = prev


def current_mesh_rules():
    """(mesh, rules) of the innermost mesh_context, or (None, None)."""
    state = getattr(_ctx, "state", None)
    return state if state is not None else (None, None)


def shard(x: jax.Array, *axes: Optional[str]) -> jax.Array:
    """Constrain activation sharding by logical axis names (no-op w/o mesh)."""
    state = getattr(_ctx, "state", None)
    if state is None:
        return x
    mesh, rules = state
    from jax.sharding import NamedSharding, PartitionSpec

    used = set()
    spec = []
    for dim, name in zip(x.shape, axes):
        mesh_axis = rules.get(name) if name else None
        names = (mesh_axis,) if isinstance(mesh_axis, str) else (mesh_axis or ())
        names = tuple(a for a in names if a in mesh.shape and a not in used)
        size = 1
        for a in names:
            size *= mesh.shape[a]
        if names and dim % size == 0:
            spec.append(names if len(names) > 1 else names[0])
            used.update(names)
        else:
            spec.append(None)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, PartitionSpec(*spec)))


# --------------------------------------------------------------------------
# Norms / RoPE
# --------------------------------------------------------------------------


def rms_norm(x: jax.Array, scale: jax.Array, eps: float = 1e-6) -> jax.Array:
    dtype = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    return ((x * jax.lax.rsqrt(var + eps)) * (1.0 + scale.astype(jnp.float32))).astype(dtype)


def layer_norm(x: jax.Array, scale: jax.Array, bias: jax.Array, eps: float = 1e-5) -> jax.Array:
    dtype = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    y = (x - mu) * jax.lax.rsqrt(var + eps)
    return (y * scale.astype(jnp.float32) + bias.astype(jnp.float32)).astype(dtype)


def rope(positions: jax.Array, head_dim: int, theta: float = 10000.0):
    """Rotary embedding tables for integer ``positions`` (..., seq)."""
    freqs = theta ** (-jnp.arange(0, head_dim // 2, dtype=jnp.float32) / (head_dim // 2))
    angles = positions[..., None].astype(jnp.float32) * freqs  # (..., seq, hd/2)
    return jnp.sin(angles), jnp.cos(angles)


def apply_rope(x: jax.Array, sin: jax.Array, cos: jax.Array) -> jax.Array:
    """x: (..., seq, heads, head_dim); sin/cos: (..., seq, head_dim/2)."""
    dtype = x.dtype
    x = x.astype(jnp.float32)
    x1, x2 = jnp.split(x, 2, axis=-1)
    sin = sin[..., :, None, :]
    cos = cos[..., :, None, :]
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(dtype)
