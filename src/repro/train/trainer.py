"""Production trainer: jit'd step with explicit shardings, iterative
magnitude pruning (the paper's sparsity source) as a first-class schedule,
checkpoint/restart with exact data resume, preemption handling, and a
straggler watchdog.

Fault-tolerance model (designed for 1000+ nodes, exercised here in-process):
  * checkpoints are topology-agnostic -> restart may change pod count
    (elastic re-shard happens in checkpoint.restore via target shardings);
  * the data pipeline is a pure function of step -> restart resumes the
    exact stream (``SyntheticDataset.skip_to``);
  * SIGTERM/SIGINT trigger a final checkpoint before exit (preemption);
  * a watchdog flags steps slower than ``straggler_factor`` x the rolling
    median — on real fleets this feeds the scheduler; here it logs.
"""

from __future__ import annotations

import dataclasses
import signal
import time
from typing import Dict, List, Optional

import jax
import numpy as np

from ..checkpoint.ckpt import Checkpointer
from ..configs.base import ArchConfig
from ..core.pruning import apply_masks, masks_tree, polynomial_sparsity, tree_sparsity
from ..data.pipeline import SyntheticDataset
from ..dist.sharding import act_rules, batch_shardings, params_shardings
from ..models import build_model
from ..models.common import mesh_context
from ..optim import AdamState, adamw_init
from .step import TrainHParams, make_train_step

__all__ = ["TrainConfig", "Trainer"]


@dataclasses.dataclass
class TrainConfig:
    steps: int = 100
    global_batch: int = 8
    seq_len: int = 128
    hp: TrainHParams = dataclasses.field(default_factory=TrainHParams)
    # pruning schedule (VUSA): ramp to cfg.sparsity between these steps
    prune_begin: int = 20
    prune_end: int = 80
    prune_every: int = 10
    ckpt_dir: Optional[str] = None
    ckpt_every: int = 50
    log_every: int = 10
    straggler_factor: float = 2.0
    seed: int = 0
    token_range: int = 0  # >0: narrow token distribution (learnable synthetic)


class Trainer:
    def __init__(self, cfg: ArchConfig, tc: TrainConfig, mesh=None):
        self.cfg, self.tc = cfg, tc
        self.mesh = mesh or jax.make_mesh((1, 1), ("data", "model"))
        self.rules = act_rules(self.mesh)
        self.model = build_model(cfg)
        self.p_shard = params_shardings(self.model.specs(), self.mesh)
        self.step_fn = make_train_step(self.model.loss, tc.hp)
        self.ckpt = Checkpointer(tc.ckpt_dir) if tc.ckpt_dir else None
        self._preempted = False
        self.metrics_log: List[Dict] = []

    # -- fault tolerance hooks ------------------------------------------------
    def _install_signal_handlers(self):
        def handler(signum, frame):
            self._preempted = True

        for sig in (signal.SIGTERM, signal.SIGINT):
            try:
                signal.signal(sig, handler)
            except ValueError:
                pass  # not on main thread (tests)

    # -- setup ----------------------------------------------------------------
    def init_state(self):
        with jax.default_device(jax.devices()[0]):
            params = self.model.init(jax.random.key(self.tc.seed))
        params = jax.device_put(params, self.p_shard)
        opt = adamw_init(params)
        return params, opt

    def train(self) -> Dict:
        tc, cfg = self.tc, self.cfg
        self._install_signal_handlers()
        params, opt = self.init_state()
        start_step = 0
        if self.ckpt:
            got, restored = self.ckpt.restore_latest(
                {"params": params, "opt": opt},
                {
                    "params": self.p_shard,
                    "opt": AdamState(step=None, mu=self.p_shard, nu=self.p_shard),
                },
            )
            if got is not None:
                params, opt = restored["params"], restored["opt"]
                start_step = got
        data = SyntheticDataset(
            cfg, tc.global_batch, tc.seq_len, seed=tc.seed, token_range=tc.token_range
        ).skip_to(start_step)

        jit_step = jax.jit(self.step_fn, donate_argnums=(0, 1))
        jit_mask = jax.jit(apply_masks, donate_argnums=0)
        times: List[float] = []
        it = iter(data)
        final_loss = float("nan")
        masks = None  # persistent keep-masks once pruning starts
        with mesh_context(self.mesh, self.rules):
            for step in range(start_step, tc.steps):
                batch = {
                    k: jax.device_put(v, batch_shardings(self.mesh, {k: v})[k])
                    for k, v in next(it).items()
                }
                t0 = time.time()
                params, opt, metrics = jit_step(params, opt, batch)
                jax.block_until_ready(metrics["loss"])
                dt = time.time() - t0

                # straggler watchdog
                times.append(dt)
                med = float(np.median(times[-20:]))
                if len(times) > 5 and dt > tc.straggler_factor * med:
                    print(f"[straggler] step {step}: {dt:.2f}s vs median {med:.2f}s", flush=True)

                # iterative magnitude pruning toward cfg.sparsity: refresh the
                # keep-masks on the schedule, re-apply them every step so the
                # optimizer cannot resurrect pruned weights
                if (
                    cfg.sparsity > 0
                    and step >= tc.prune_begin
                    and step % tc.prune_every == 0
                ):
                    target = polynomial_sparsity(step, tc.prune_begin, tc.prune_end, cfg.sparsity)
                    masks = jax.jit(lambda p: masks_tree(p, target))(params)
                if masks is not None:
                    params = jit_mask(params, masks)

                final_loss = float(metrics["loss"])
                if step % tc.log_every == 0 or step == tc.steps - 1:
                    rec = {"step": step, "loss": final_loss, "dt": dt,
                           "lr": float(metrics["lr"]), "gnorm": float(metrics["gnorm"])}
                    self.metrics_log.append(rec)
                    print(f"step {step:5d} loss {final_loss:.4f} dt {dt*1e3:.0f}ms", flush=True)

                if self.ckpt and ((step + 1) % tc.ckpt_every == 0 or self._preempted):
                    self.ckpt.save(step + 1, {"params": params, "opt": opt})
                if self._preempted:
                    print(f"[preempt] checkpointed at step {step + 1}, exiting", flush=True)
                    break

        if self.ckpt:
            self.ckpt.wait()
        return {
            "params": params,
            "opt": opt,
            "final_loss": final_loss,
            "sparsity": tree_sparsity(params),
            "steps_run": step + 1 - start_step,
        }
