"""qwen3-8b [dense]: 36L d_model=4096 32H (GQA kv=8) d_ff=12288
vocab=151936; qk_norm [hf:Qwen/Qwen3-8B]."""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="qwen3-8b", family="dense",
    n_layers=36, d_model=4096, n_heads=32, kv_heads=8, d_ff=12288,
    vocab=151936, qk_norm=True, rope_theta=1000000.0, sparsity=0.85,
)

SMOKE = ArchConfig(
    name="qwen3-smoke", family="dense",
    n_layers=2, d_model=64, n_heads=4, kv_heads=2, d_ff=128,
    vocab=512, qk_norm=True, sparsity=0.85, dtype="float32", remat=False,
)
