"""Serving launcher: load a checkpoint (or random init), optionally prune +
VUSA-pack, and serve batched synthetic requests.

    PYTHONPATH=src python -m repro.launch.serve --arch vusa_edge --smoke --packed

With ``--requests N`` the launcher drives the continuous-batching Scheduler
instead of one-shot generate, exposing the reliability knobs: per-request
``--deadline-s``, a bounded queue via ``--queue-cap`` with ``--shed-policy``,
and a seeded chaos mode (``--fault-rate``) that NaN-poisons that fraction of
requests' slot caches to exercise the guard + dense-fallback path.

Streaming / crash-safety (DESIGN.md §12): ``--stream`` serves the same
requests through the asyncio AsyncEngine; ``--journal PATH`` write-ahead
journals every request event (implies ``--stream``), ``--recover`` replays a
crashed journal first — proven completions come back verbatim, in-flight
requests re-execute bit-identically — and ``--watchdog-s`` arms stall
detection.  SIGINT/SIGTERM drain instead of dying mid-segment: admission
stops, in-flight requests finish (bounded by ``--drain-timeout-s``), final
stats print, and the journal closes clean.
"""

import argparse
import asyncio
import signal

import jax
import numpy as np

from ..checkpoint import latest_step, restore
from ..configs import get_config, get_smoke_config
from ..core.pruning import prune_tree
from ..models import build_model
from ..serve import (
    AsyncEngine,
    Engine,
    FaultConfig,
    Journal,
    Request,
    Scheduler,
    ServeConfig,
)


async def _serve_streaming(args, cfg, sched):
    """Drive the synthetic workload through the AsyncEngine: streaming
    consumption, optional journaling/recovery, and signal-driven drain.
    The workload is a pure function of the rng seed, so a recovered run
    submits exactly the requests the journal does not already prove."""
    import os

    if args.recover and os.path.exists(args.journal):
        engine = AsyncEngine.recover(args.journal, sched, watchdog_s=args.watchdog_s)
        print(f"recovered journal {args.journal}: "
              f"{len(engine._completed)} completions proven, "
              f"{len(engine.recovered_rids)} requests re-queued")
    else:
        journal = Journal(args.journal) if args.journal else None
        engine = AsyncEngine(sched, journal=journal, watchdog_s=args.watchdog_s)

    stop = asyncio.Event()
    loop = asyncio.get_running_loop()
    for sig in (signal.SIGINT, signal.SIGTERM):
        # drain instead of dying mid-segment: admission stops, in-flight
        # work finishes (bounded), stats print, the journal closes clean
        loop.add_signal_handler(sig, stop.set)

    async with engine:
        known = set(engine.recovered_rids) | set(
            rid for rid in range(args.requests) if engine.completion_for(rid) is not None
        )
        rng = np.random.default_rng(0)
        streams = []
        for r in range(args.requests):
            prompt = rng.integers(1, cfg.vocab, size=args.prompt_len).astype(np.int32)
            if r in known:  # journal already owns this rid (done or re-queued)
                streams.append(engine.stream_for(r))
            else:
                streams.append(engine.submit(Request(
                    prompt=prompt, max_new=args.max_new, seed=r,
                    deadline_s=args.deadline_s,
                ), rid=r))

        async def consume():
            total = 0
            for s in streams:
                async for _ in s:
                    total += 1
            return total

        work = asyncio.ensure_future(consume())
        interrupt = asyncio.ensure_future(stop.wait())
        done, _ = await asyncio.wait(
            {work, interrupt}, return_when=asyncio.FIRST_COMPLETED
        )
        if interrupt in done:
            print("signal: draining...")
            clean = await engine.drain(args.drain_timeout_s)
            print("drained clean" if clean
                  else f"drain blew {args.drain_timeout_s}s; in-flight work aborted")
            work.cancel()
        else:
            interrupt.cancel()
            print(f"streamed {work.result()} tokens")
        st = engine.stats()
        print(f"{st['requests_completed']:.0f} completions  "
              f"ttft p50/p99 {st['ttft_p50_s']*1e3:.0f}/{st['ttft_p99_s']*1e3:.0f}ms  "
              f"itl p50/p99 {st['itl_p50_s']*1e3:.0f}/{st['itl_p99_s']*1e3:.0f}ms  "
              f"journal records={st['journal_records']:.0f} syncs={st['journal_syncs']:.0f}")
    for sig in (signal.SIGINT, signal.SIGTERM):
        loop.remove_signal_handler(sig)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--ckpt", default=None)
    ap.add_argument(
        "--packed", nargs="?", const="mlp", default=False, choices=("mlp", "all"),
        help="VUSA-pack the decode step: bare flag or 'mlp' = MLP trio only "
        "(the pre-§7 behaviour), 'all' = + qkv/o and untied LM head",
    )
    ap.add_argument(
        "--packed-values", default="bf16", choices=("bf16", "int8", "int4"),
        help="packed value precision (DESIGN.md §10): bf16 = native float "
        "values (default), int8/int4 = quantized value slots with "
        "per-window fp32 scales and dequant fused into the kernels",
    )
    ap.add_argument("--sparsity", type=float, default=None)
    ap.add_argument(
        "--speculative", action="store_true",
        help="self-speculative decoding (DESIGN.md §13): a ~99%%-sparsity "
        "pack of the SAME weights drafts --draft-k greedy tokens per round "
        "and one batched dispatch of the configured path verifies them; "
        "greedy output is bit-identical to non-speculative decode",
    )
    ap.add_argument(
        "--draft-k", type=int, default=4,
        help="speculative draft length (tokens drafted per verify round)",
    )
    ap.add_argument(
        "--draft-sparsity", type=float, default=0.99,
        help="magnitude-pruning sparsity of the drafter pack",
    )
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--max-new", type=int, default=32)
    ap.add_argument(
        "--mesh", default=None, metavar="DP,TP",
        help="serve on a data x model device mesh (e.g. '2,4'): params/KV "
        "shard over 'data', packed-weight windows over 'model'; '1,1' (or "
        "omitting the flag) is the single-device path",
    )
    ap.add_argument(
        "--requests", type=int, default=0,
        help="serve N synthetic requests through the continuous-batching "
        "Scheduler (0 = one-shot batched generate)",
    )
    ap.add_argument("--slots", type=int, default=4, help="scheduler slot pool size")
    ap.add_argument(
        "--deadline-s", type=float, default=None,
        help="per-request deadline (from arrival); blown deadlines finish TIMEOUT",
    )
    ap.add_argument(
        "--queue-cap", type=int, default=None,
        help="bound the scheduler queue; overflow handled per --shed-policy",
    )
    ap.add_argument(
        "--shed-policy", default="reject",
        choices=("reject", "shed-oldest", "shed-lowest-priority"),
        help="who pays when the queue is full",
    )
    ap.add_argument(
        "--fault-rate", type=float, default=0.0,
        help="chaos mode: seeded fraction of requests whose slot cache gets "
        "NaN-poisoned at admission (exercises guard + dense fallback)",
    )
    ap.add_argument(
        "--page-size", type=int, default=0,
        help="KV block size in tokens; >0 switches the scheduler to the paged "
        "arena pool with prefix sharing (DESIGN.md §11), 0 = slot pool",
    )
    ap.add_argument(
        "--arena-blocks", type=int, default=0,
        help="total paged-arena blocks (0 = auto: enough for every slot at "
        "max_len); smaller arenas admit lazily and preempt under pressure",
    )
    ap.add_argument(
        "--prefix-cache", action=argparse.BooleanOptionalAction, default=True,
        help="share identical prompt-prefix pages between requests "
        "(--no-prefix-cache disables; only meaningful with --page-size)",
    )
    ap.add_argument(
        "--prefill-chunk", type=int, default=0,
        help="chunk long prompt prefills to this many tokens and co-schedule "
        "the chunks with decode segments (0 = whole-prompt prefill; "
        "requires --page-size)",
    )
    ap.add_argument(
        "--stream", action="store_true",
        help="serve through the asyncio AsyncEngine (token streaming, "
        "watchdog, clean drain on SIGINT/SIGTERM); requires --requests",
    )
    ap.add_argument(
        "--journal", default=None, metavar="PATH",
        help="write-ahead journal every request event to PATH (CRC32-framed, "
        "fsync'd at segment syncs); implies --stream",
    )
    ap.add_argument(
        "--recover", action="store_true",
        help="replay --journal before serving: journaled completions are "
        "honoured, in-flight requests re-execute under their original seeds",
    )
    ap.add_argument(
        "--watchdog-s", type=float, default=None,
        help="abort a segment that syncs nothing for this long as STALLED "
        "(default: watchdog off)",
    )
    ap.add_argument(
        "--drain-timeout-s", type=float, default=30.0,
        help="on SIGINT/SIGTERM, give in-flight requests this long to finish "
        "before aborting them (CANCELLED)",
    )
    args = ap.parse_args()
    if args.recover and not args.journal:
        ap.error("--recover requires --journal")
    if args.journal:
        args.stream = True
    if args.stream and args.requests <= 0:
        ap.error("--stream/--journal require --requests N")
    if args.speculative and args.requests == 0 and args.batch != 1:
        ap.error("--speculative one-shot generate serves --batch 1 "
                 "(use --requests N for batched speculative serving)")

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    if args.ckpt:
        step = latest_step(args.ckpt)
        if step is not None:
            params = restore(args.ckpt, step, {"params": params})["params"]
            print(f"restored step {step} from {args.ckpt}")
    sp = cfg.sparsity if args.sparsity is None else args.sparsity
    if sp > 0:
        params = prune_tree(params, sp)
    mesh = None
    if args.mesh:
        from .mesh import make_serve_mesh

        mesh = make_serve_mesh(args.mesh)
        print(f"mesh {dict(mesh.shape)} over {len(mesh.devices.flat)} devices")
    faults = FaultConfig(cache_nan_rate=args.fault_rate) if args.fault_rate > 0 else None
    max_len = args.prompt_len + args.max_new + 8
    if args.speculative:
        # speculative rounds write up to draft_k rows past the emission
        # budget before rejection masks them; the scheduler additionally
        # budgets a full segment span (segment * (draft_k + 1) rows) of
        # worst-case growth per sync
        max_len += 8 * args.draft_k if args.requests > 0 else args.draft_k
    if args.page_size > 0:  # §11: page size must divide max_len
        max_len = -(-max_len // args.page_size) * args.page_size
    eng = Engine(cfg, params, ServeConfig(max_len=max_len,
                                          packed_weights=args.packed,
                                          packed_values=args.packed_values,
                                          page_size=args.page_size,
                                          arena_blocks=args.arena_blocks,
                                          prefix_cache=args.prefix_cache,
                                          prefill_chunk=args.prefill_chunk,
                                          speculative=args.speculative,
                                          draft_k=args.draft_k,
                                          draft_sparsity=args.draft_sparsity,
                                          faults=faults),
                 mesh=mesh)
    if args.requests > 0:
        sched = Scheduler(
            eng, slots=args.slots, queue_cap=args.queue_cap,
            shed_policy=args.shed_policy,
        )
        if args.stream:
            asyncio.run(_serve_streaming(args, cfg, sched))
            return
        rng = np.random.default_rng(0)
        for r in range(args.requests):
            sched.submit(Request(
                prompt=rng.integers(1, cfg.vocab, size=args.prompt_len).astype(np.int32),
                max_new=args.max_new, seed=r, deadline_s=args.deadline_s,
            ))
        done = sched.run()
        st = sched.stats()
        print(f"{st['requests']} completions  {st['sustained_tok_per_s']:.0f} tok/s  "
              f"latency p50 {st['latency_p50_s']*1e3:.0f}ms  "
              f"ttft p50 {st['ttft_p50_s']*1e3:.0f}ms")
        if args.speculative:
            print(f"  speculative: acceptance {st['acceptance_rate']:.2f}  "
                  f"accepted tok/s {st['tok_per_s']:.0f}  "
                  f"proposed={st['spec_proposed']} accepted={st['spec_accepted']}")
        print("  " + "  ".join(
            f"{k}={st[k]}" for k in
            ("rejected", "shed", "timed_out", "cancelled", "fallback", "failed",
             "quarantined")
        ))
        if args.page_size > 0:
            print(f"  arena {st['kv_pool_bytes']/2**20:.1f}MiB "
                  f"blocks live={st['blocks_live']:.0f} free={st['blocks_free']:.0f} "
                  f"cached={st['blocks_cached']:.0f}  "
                  f"prefix hit rate {st['prefix_hit_rate']:.2f}  "
                  f"cow={st['cow_copies']}  preempted={st['preempted']}  "
                  f"hbm/req {st['hbm_bytes_per_active_request']/2**10:.1f}KiB")
        bad = sum(1 for c in done.values() if c.status.value not in ("OK", "FAILED_FALLBACK_OK"))
        if bad:
            print(f"  {bad} requests did not deliver tokens")
        return
    prompts = np.ones((args.batch, args.prompt_len), np.int32)
    out = eng.generate(prompts, max_new=args.max_new)
    print(f"prefill {out['prefill_s']*1e3:.1f}ms  decode {out['decode_s']*1e3:.1f}ms  "
          f"{out['tok_per_s']:.0f} tok/s")
    if args.speculative:
        print(f"speculative: acceptance {out['acceptance_rate']:.2f}  "
              f"rounds={out['spec_rounds']} proposed={out['spec_proposed']} "
              f"accepted={out['spec_accepted']}")


if __name__ == "__main__":
    main()
