"""recurrentgemma-9b [hybrid]: RG-LRU + local attention, 2:1 pattern.
38L d_model=4096 16H (GQA kv=1) d_ff=12288 vocab=256000 [arXiv:2402.19427]."""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="recurrentgemma-9b", family="hybrid",
    n_layers=38, d_model=4096, n_heads=16, kv_heads=1, d_ff=12288,
    vocab=256000, head_dim=256,
    block_pattern=("rglru", "rglru", "attn"), local_window=2048,
    rglru_dim=4096, sparsity=0.85,
)

SMOKE = ArchConfig(
    name="recurrentgemma-smoke", family="hybrid",
    n_layers=3, d_model=64, n_heads=4, kv_heads=1, d_ff=128,
    vocab=512, head_dim=16,
    block_pattern=("rglru", "rglru", "attn"), local_window=32,
    rglru_dim=64, sparsity=0.85, dtype="float32", remat=False,
)
