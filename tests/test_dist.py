"""Distribution-layer tests.  Sharding *rules* are pure functions of specs +
mesh shape, so most tests run against a multi-device mesh in a subprocess
(the main test process keeps the default single CPU device)."""

import subprocess
import sys
import textwrap
from pathlib import Path

import jax

REPO_ROOT = Path(__file__).resolve().parent.parent

from repro.dist.sharding import param_sharding  # noqa: E402
from repro.models.common import ParamSpec  # noqa: E402


def _run(code: str) -> str:
    out = subprocess.run(
        [sys.executable, "-c", code],
        capture_output=True,
        text=True,
        timeout=600,
        env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin",
             # pin the backend: without it, plugin discovery in the bare
             # subprocess env can stall for minutes probing accelerators
             "JAX_PLATFORMS": "cpu",
             "XLA_FLAGS": "--xla_force_host_platform_device_count=16"},
        cwd=str(REPO_ROOT),
    )
    assert out.returncode == 0, out.stderr[-3000:]
    return out.stdout


def test_param_rules_multi_device():
    code = textwrap.dedent("""
        import jax
        from repro.configs import get_config
        from repro.dist.sharding import params_shardings
        from repro.models import build_model

        mesh = jax.make_mesh((4, 4), ("data", "model"))
        for arch in ("qwen3_8b", "olmoe_1b_7b", "mamba2_2_7b"):
            cfg = get_config(arch)
            model = build_model(cfg)
            sh = params_shardings(model.specs(), mesh)
            leaves = jax.tree_util.tree_leaves(sh)
            def uses(spec, axis):
                return any(
                    e == axis or (isinstance(e, tuple) and axis in e)
                    for e in spec if e is not None
                )
            n_model = sum(1 for s in leaves if uses(s.spec, "model"))
            n_data = sum(1 for s in leaves if uses(s.spec, "data"))
            assert n_model > 0, arch  # TP actually engaged
            assert n_data > 0, arch   # FSDP actually engaged
            print(arch, "ok", n_model, "TP +", n_data, "FSDP of", len(leaves))
    """)
    out = _run(code)
    assert out.count("ok") == 3


def test_train_step_runs_sharded():
    """A real sharded train step on a 4x4 host-device mesh (tiny model)."""
    code = textwrap.dedent("""
        import jax, numpy as np
        from repro.configs import get_smoke_config
        from repro.train import TrainConfig, Trainer, TrainHParams
        mesh = jax.make_mesh((4, 4), ("data", "model"))
        cfg = get_smoke_config("llama3_2_1b")
        tc = TrainConfig(steps=3, global_batch=8, seq_len=32, prune_begin=100,
                         hp=TrainHParams(lr=1e-3, total_steps=3), log_every=100)
        out = Trainer(cfg, tc, mesh=mesh).train()
        assert np.isfinite(out["final_loss"])
        print("sharded loss", out["final_loss"])
    """)
    out = _run(code)
    assert "sharded loss" in out


def test_sharded_matches_single_device():
    """Same seed, same data: 16-device mesh loss == single-device loss."""
    code = textwrap.dedent("""
        import jax, numpy as np
        from repro.configs import get_smoke_config
        from repro.train import TrainConfig, Trainer, TrainHParams
        tc = TrainConfig(steps=2, global_batch=8, seq_len=16, prune_begin=100,
                         hp=TrainHParams(lr=1e-3, total_steps=2), log_every=100)
        cfg = get_smoke_config("qwen2_0_5b")
        from jax.sharding import Mesh
        mesh = jax.make_mesh((4, 4), ("data", "model"))
        l_multi = Trainer(cfg, tc, mesh=mesh).train()["final_loss"]
        mesh1 = Mesh(np.array(jax.devices()[:1]).reshape(1, 1), ("data", "model"))
        l_single = Trainer(cfg, tc, mesh=mesh1).train()["final_loss"]
        print("multi", l_multi, "single", l_single)
        assert abs(l_multi - l_single) < 2e-3, (l_multi, l_single)
    """)
    _run(code)


def test_param_sharding_divisibility_fallback():
    """Non-divisible dims must fall back to replication, never error."""
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    spec = ParamSpec((7, 13), ("embed", "ff"))  # nothing divides
    s = param_sharding(spec, mesh)
    assert s.spec == jax.sharding.PartitionSpec(None, None)


def test_batch_sharding_non_divisible_batch():
    from repro.dist.sharding import batch_sharding

    mesh = jax.make_mesh((1, 1), ("data", "model"))
    s = batch_sharding(mesh, batch_size=1, ndim=2)  # long_500k case
    assert s.spec[0] in (None, "data")  # batch=1 on 1-dev mesh: either is valid
