"""Property-based tests for the VUSA pack formats (core/packing.py):
pack/unpack roundtrips, window-count invariants and the shard_windows view,
across random shapes, sparsities in [0, 0.99] and non-divisible edges.

Uses the optional-hypothesis shim (tests/hypothesis_compat.py): with
hypothesis installed (CI) the @given tests fuzz; without it they skip and the
example-based edge tests below still pin the invariants.
"""

import numpy as np
from hypothesis_compat import given, settings, st

from repro.core.packing import (
    QMAX,
    dequantize_rows,
    nm_mask,
    pack_blocks,
    pack_exact,
    pack_nibbles,
    pack_rows,
    pack_rows_nm,
    pack_rows_t,
    quantize_rows,
    shard_windows,
    unpack_blocks,
    unpack_exact,
    unpack_nibbles,
    unpack_rows,
)


def _sparse(seed, k, c, sparsity):
    rng = np.random.default_rng(seed)
    w = rng.normal(size=(k, c)) * (rng.random((k, c)) > sparsity)
    return w.astype(np.float32)


# ---------------------------------------------------------------------------
# row format (the serving path's format)
# ---------------------------------------------------------------------------


@given(
    k=st.integers(1, 48),
    c=st.integers(1, 300),
    m=st.sampled_from([8, 32, 128]),
    a=st.sampled_from([4, 8, 16]),
    sp=st.floats(0.0, 0.99),
    seed=st.integers(0, 100),
)
@settings(max_examples=40, deadline=None)
def test_pack_rows_roundtrip_prop(k, c, m, a, sp, seed):
    """unpack(pack(w)) == w exactly, any shape/sparsity (c % m free)."""
    w = _sparse(seed, k, c, sp)
    p = pack_rows(w, m=m, a=a)
    np.testing.assert_array_equal(unpack_rows(p), w)
    # window-count invariant: windows tile the (padded) column dim
    assert p.values.shape[0] == -(-c // m)
    # job invariant: slots = a * ceil(max row-nnz per window / a)
    max_nnz = 1
    for t in range(p.values.shape[0]):
        blk = w[:, t * m : (t + 1) * m]
        max_nnz = max(max_nnz, int((blk != 0).sum(axis=1).max(initial=1)))
    assert p.values.shape[2] == a * -(-max_nnz // a)


@given(
    ff=st.integers(1, 200),
    d=st.integers(1, 48),
    m=st.sampled_from([8, 32]),
    sp=st.floats(0.0, 0.99),
    seed=st.integers(0, 100),
)
@settings(max_examples=30, deadline=None)
def test_pack_rows_t_roundtrip_prop(ff, d, m, sp, seed):
    """pack_rows_t windows the *leading* dim: unpack == w.T (the fused
    megakernel's w_down contract, DESIGN.md §7)."""
    w = _sparse(seed, ff, d, sp)
    p = pack_rows_t(w, m=m, a=4)
    np.testing.assert_array_equal(unpack_rows(p), w.T)
    assert p.values.shape[0] == -(-ff // m)  # windows cover ff


@given(
    k=st.integers(1, 32),
    c=st.integers(1, 200),
    n=st.integers(1, 8),
    sp=st.floats(0.0, 0.99),
    seed=st.integers(0, 50),
)
@settings(max_examples=30, deadline=None)
def test_shard_windows_prop(k, c, n, sp, seed):
    """shard_windows pads to a divisible window count with exact no-ops:
    unpack unchanged, pad windows all zero-value / -1-position."""
    p = pack_rows(_sparse(seed, k, c, sp), m=32, a=4)
    q = shard_windows(p, n)
    assert q.values.shape[0] % n == 0
    assert q.values.shape[0] - p.values.shape[0] < n
    np.testing.assert_array_equal(unpack_rows(q), unpack_rows(p))
    pad = q.values[p.values.shape[0] :]
    assert (pad == 0).all()
    assert (q.row_positions[p.values.shape[0] :] == -1).all()


# ---------------------------------------------------------------------------
# example-based edges (always run, hypothesis or not)
# ---------------------------------------------------------------------------


def test_pack_rows_roundtrip_edges():
    for k, c, m, a, sp in [
        (1, 1, 128, 16, 0.0),  # single scalar
        (7, 130, 128, 16, 0.85),  # c % m != 0 (the non-divisible ff edge)
        (16, 128, 128, 4, 0.0),  # dense fallback: J = ceil(m/a) jobs
        (5, 96, 32, 8, 0.99),  # near-empty
        (3, 64, 32, 8, 1.0),  # fully zero: one all-idle job
    ]:
        w = _sparse(0, k, c, sp) if sp < 1.0 else np.zeros((k, c), np.float32)
        p = pack_rows(w, m=m, a=a)
        np.testing.assert_array_equal(unpack_rows(p), w)
        assert p.values.shape[0] == -(-c // m)


def test_pack_rows_t_matches_transpose():
    w = _sparse(1, 80, 48, 0.85)  # ff=80 not divisible by m=32
    p = pack_rows_t(w, m=32, a=8)
    np.testing.assert_array_equal(unpack_rows(p), w.T)


def test_shard_windows_edges():
    p = pack_rows(_sparse(2, 8, 5 * 32 - 7, 0.8), m=32, a=8)  # 5 windows
    assert shard_windows(p, 1) is p  # divisible: view is the pack itself
    assert shard_windows(p, 5) is p
    q = shard_windows(p, 4)  # 5 -> 8 windows
    assert q.values.shape[0] == 8
    np.testing.assert_array_equal(unpack_rows(q), unpack_rows(p))
    try:
        shard_windows(p, 0)
    except ValueError:
        pass
    else:
        raise AssertionError("shard_windows(p, 0) must raise")


def test_shard_windows_twins_agree():
    """core.packing.shard_windows (host/numpy) and its device twin
    kernels.ops.shard_linear_windows must implement the *same* pad semantics
    (tail windows, value 0, position -1, k/c/m/a unchanged) — the serve path
    runs on the ops twin while the invariants are property-tested here, so
    drift between them must fail loudly."""
    from repro.kernels.ops import pack_linear_rows, shard_linear_windows

    w = _sparse(5, 12, 5 * 32 - 3, 0.8)  # 5 windows
    for n in (1, 2, 3, 4, 8):
        host = shard_windows(pack_rows(w, m=32, a=8), n)
        dev = shard_linear_windows(pack_linear_rows(w, m=32, a=8), n)
        np.testing.assert_array_equal(np.asarray(dev.values), host.values)
        np.testing.assert_array_equal(np.asarray(dev.positions), host.row_positions)
        assert (dev.k, dev.c, dev.m, dev.a) == (host.k, host.c, host.m, host.a)


def test_pack_blocks_roundtrip():
    w = _sparse(3, 64, 256, 0.9)
    p = pack_blocks(w, m_blk=16, a_blk=8, tile_n=128)
    np.testing.assert_array_equal(unpack_blocks(p), w)


def test_pack_exact_roundtrip():
    w = _sparse(4, 9, 12, 0.6)
    p = pack_exact(w, N=3, M=6, A=3)
    np.testing.assert_array_equal(unpack_exact(p), w)


# ---------------------------------------------------------------------------
# quantized row packs (DESIGN.md §10): int8 / int4-nibble values + scales
# ---------------------------------------------------------------------------


def _assert_quant_roundtrip(w, m, a, value_dtype):
    """quantize -> (nibble-pack) -> dequantize stays within the scale quantum
    of the original pack, positions survive exactly, zeros stay exact."""
    p = pack_rows(w, m=m, a=a)
    q = quantize_rows(p, value_dtype)
    assert q.value_dtype == value_dtype
    assert q.values.dtype == np.int8
    assert q.scales.dtype == np.float32
    assert q.scales.shape == p.values.shape[:2]
    assert np.isfinite(q.scales).all() and (q.scales > 0).all()
    d = dequantize_rows(q)
    s = p.values.shape[2]
    # positions: original prefix intact; int4 may append one -1 idle pad slot
    np.testing.assert_array_equal(d.row_positions[:, :, :s], p.row_positions)
    assert (d.row_positions[:, :, s:] == -1).all()
    # rint quantization error is at most half a quantum per element
    err = np.abs(d.values[:, :, :s] - p.values)
    quantum = q.scales[:, :, None] * 0.5
    assert (err <= quantum + 1e-6).all()
    assert (d.values[:, :, s:] == 0).all()
    # exact zeros quantize to exact zeros (idle slots stay silent)
    assert (d.values[:, :, :s][p.values == 0] == 0).all()
    # the full pipeline stays within quantum of the dense matrix too
    np.testing.assert_allclose(
        unpack_rows(d), w, atol=float(q.scales.max()) * 0.5 + 1e-6
    )


@given(
    k=st.integers(1, 32),
    c=st.integers(1, 200),
    m=st.sampled_from([8, 32, 128]),
    a=st.sampled_from([4, 8, 16]),
    sp=st.floats(0.0, 1.0),
    dt=st.sampled_from(["int8", "int4"]),
    seed=st.integers(0, 50),
)
@settings(max_examples=40, deadline=None)
def test_quantize_rows_roundtrip_prop(k, c, m, a, sp, dt, seed):
    w = _sparse(seed, k, c, sp) if sp < 1.0 else np.zeros((k, c), np.float32)
    _assert_quant_roundtrip(w, m, a, dt)


@given(
    shape=st.sampled_from([(4,), (2, 6), (3, 5, 8), (1, 2)]),
    seed=st.integers(0, 100),
)
@settings(max_examples=30, deadline=None)
def test_nibble_codec_exact_prop(shape, seed):
    """pack_nibbles/unpack_nibbles is a lossless codec over the int4 range."""
    rng = np.random.default_rng(seed)
    q = rng.integers(-QMAX["int4"], QMAX["int4"] + 1, size=shape).astype(np.int8)
    b = pack_nibbles(q)
    assert b.dtype == np.int8
    assert b.shape == shape[:-1] + (shape[-1] // 2,)
    np.testing.assert_array_equal(unpack_nibbles(b), q)


@given(
    dt=st.sampled_from(["int8", "int4"]),
    k=st.integers(1, 16),
    c=st.integers(1, 96),
    sp=st.floats(0.0, 0.99),
    seed=st.integers(0, 50),
)
@settings(max_examples=30, deadline=None)
def test_quantize_idempotent_prop(dt, k, c, sp, seed):
    """Quantizing a dequantized pack reproduces the same bytes and scales:
    the max-|v| entry maps to exactly +-qmax*scale, so the scale recomputes
    bit-identically and every grid point is a fixed point of rint."""
    p = pack_rows(_sparse(seed, k, c, sp), m=32, a=8)
    q1 = quantize_rows(p, dt)
    q2 = quantize_rows(dequantize_rows(q1), dt)
    np.testing.assert_array_equal(q1.values, q2.values)
    np.testing.assert_array_equal(q1.scales, q2.scales)


# --- always-run quantized edges ---


def test_quantize_rows_edges():
    for k, c, m, a, sp, dt in [
        (1, 1, 128, 16, 0.0, "int8"),  # single scalar
        (1, 1, 128, 16, 0.0, "int4"),
        (7, 130, 128, 16, 0.85, "int8"),  # c % m != 0
        (7, 130, 128, 16, 0.85, "int4"),
        (3, 64, 32, 8, 1.0, "int8"),  # fully zero
        (5, 96, 32, 4, 0.5, "int4"),  # odd slot count forces nibble padding
    ]:
        w = _sparse(0, k, c, sp) if sp < 1.0 else np.zeros((k, c), np.float32)
        _assert_quant_roundtrip(w, m, a, dt)


def test_quantize_all_zero_window_scale_is_one():
    """A window with no live values must still carry a finite positive scale
    (1.0 by convention) so kernel dequant never divides/multiplies by 0."""
    w = np.zeros((4, 64), np.float32)
    w[:, 32:] = _sparse(1, 4, 32, 0.5)  # window 0 all-zero, window 1 live
    q = quantize_rows(pack_rows(w, m=32, a=4), "int8")
    assert (q.scales[0] == 1.0).all()
    assert (q.values[0] == 0).all()
    d = dequantize_rows(q)
    assert (d.values[0] == 0).all()


def test_nibble_codec_edges():
    # full int4 two's-complement range [-8, 7] survives, not just [-7, 7]
    q = np.arange(-8, 8, dtype=np.int8).reshape(2, 8)
    np.testing.assert_array_equal(unpack_nibbles(pack_nibbles(q)), q)
    # odd last dim must refuse, not silently truncate
    try:
        pack_nibbles(np.zeros((2, 3), np.int8))
    except ValueError:
        pass
    else:
        raise AssertionError("pack_nibbles on odd last dim must raise")


def test_int4_slot_padding_even():
    """int4 packs always hold an even slot count: a=4 with max-nnz forcing an
    odd multiple would break nibble pairing, so quantize_rows pads one idle
    slot (value 0, position -1) before packing nibbles."""
    rng = np.random.default_rng(7)
    w = (rng.normal(size=(3, 32)) * (rng.random((3, 32)) < 0.4)).astype(np.float32)
    p = pack_rows(w, m=32, a=1)  # a=1 lets slot counts go odd
    q = quantize_rows(p, "int4")
    assert q.row_positions.shape[2] % 2 == 0
    assert q.values.shape[2] * 2 == q.row_positions.shape[2]
    np.testing.assert_allclose(
        unpack_rows(dequantize_rows(q)), w, atol=float(q.scales.max()) * 0.5 + 1e-6
    )


# ---------------------------------------------------------------------------
# N:M structured pack (S2TA DBB comparison arm)
# ---------------------------------------------------------------------------


def test_nm_mask_block_budget():
    w = _sparse(6, 8, 64, 0.0)  # dense input: every block must be cut to n
    for n, block in [(2, 4), (1, 4), (4, 8)]:
        mask = nm_mask(w, n=n, block=block)
        assert mask.shape == w.shape
        nnz = mask.reshape(8, -1, block).sum(axis=2)
        assert (nnz <= n).all()
        # kept entries are the top-|.| of each block
        kept = np.abs(np.where(mask, w, 0.0)).reshape(8, -1, block)
        dropped = np.abs(np.where(mask, 0.0, w)).reshape(8, -1, block)
        assert (kept.min(axis=2, initial=np.inf, where=kept > 0)
                >= dropped.max(axis=2, initial=0.0) - 1e-7).all()


def test_nm_mask_partial_trailing_block():
    w = _sparse(7, 4, 10, 0.0)  # 10 % 4 != 0: trailing partial block kept
    mask = nm_mask(w, n=2, block=4)
    assert (mask[:, 8:] == (w[:, 8:] != 0)).all()
    assert (mask[:, :8].reshape(4, 2, 4).sum(axis=2) <= 2).all()


def test_pack_rows_nm_slot_bound():
    """The N:M pack's slot count obeys the structural bound n*ceil(m/block)
    and unpacks to exactly the masked matrix."""
    w = _sparse(8, 12, 160, 0.0)
    n, block, m = 2, 4, 32
    p = pack_rows_nm(w, n=n, block=block, m=m, a=4)
    assert p.values.shape[2] <= -(-(n * -(-m // block)) // 4) * 4
    np.testing.assert_array_equal(
        unpack_rows(p), np.where(nm_mask(w, n, block), w, 0.0)
    )


# ---------------------------------------------------------------------------
# paged KV block allocator (models/cache.py BlockAllocator, DESIGN.md §11):
# the host-side invariants the paged scheduler leans on — free/cached/live
# partition the user pool, refcounts never go negative, allocation never
# hands out a live or reserved block, and a block freed to the plain free
# list is never still reachable from a live block table.
# ---------------------------------------------------------------------------

from repro.models.cache import (  # noqa: E402  (section-local import, as above)
    BlockAllocator,
    PagedLayout,
    prefix_page_digests,
    prefix_tail_digests,
)


def _alloc_layout(blocks, slots=2):
    return PagedLayout.build(slots, max_len=64, page=8, blocks=blocks)


def _assert_partition(al, lay):
    assert al.free_blocks + al.cached_blocks + al.live_blocks == lay.user_blocks


@given(seed=st.integers(0, 500), blocks=st.integers(2, 24))
@settings(max_examples=40, deadline=None)
def test_block_allocator_ops_soup_prop(seed, blocks):
    """Random alloc/free/register/match soup: after every operation the pool
    partition holds, live tables only reference refcounted blocks, and blocks
    that died (returned by ``free`` for zeroing) are unreachable from any
    live table."""
    rng = np.random.default_rng(seed)
    lay = _alloc_layout(blocks)
    al = BlockAllocator(lay)
    tables = []  # block-id lists held by simulated live requests
    digests = {}  # digest -> block we registered it on
    n_digests = 0
    for _ in range(80):
        op = rng.integers(0, 4)
        if op == 0:  # alloc
            n = int(rng.integers(1, 4))
            avail = al.available
            held = {b for t in tables for b in t}
            got = al.alloc(n)
            if n > avail:
                assert got is None
            else:
                ids, scrub = got
                assert len(ids) == n and len(set(ids)) == n
                for b in ids:
                    assert lay.reserved <= b < lay.n_blocks
                    assert b not in held  # never a block someone still holds
                    assert al.refcount(b) == 1
                assert set(scrub) <= set(ids)  # evictions are for our blocks
                tables.append(ids)
        elif op == 1 and tables:  # free one request's table
            t = tables.pop(int(rng.integers(0, len(tables))))
            dead = al.free(t)
            for b in dead:
                assert al.refcount(b) == 0
                assert all(b not in u for u in tables)  # unreachable
        elif op == 2 and tables:  # register a random held block
            t = tables[int(rng.integers(0, len(tables)))]
            b = t[int(rng.integers(0, len(t)))]
            n_digests += 1
            d = n_digests.to_bytes(16, "little")
            if al.register_page(d, b):
                digests[d] = b
        elif op == 3 and digests:  # match a registered digest (adds a ref)
            d = list(digests)[int(rng.integers(0, len(digests)))]
            got = al.match_pages([d])
            if got:  # may have been evicted since registration
                assert got == [digests[d]]
                assert al.refcount(got[0]) >= 1
                tables.append(got)
        _assert_partition(al, lay)
        for t in tables:
            assert all(al.refcount(b) >= 1 for b in t)
    for t in tables:
        al.free(t)
    assert al.live_blocks == 0
    _assert_partition(al, lay)


@given(seed=st.integers(0, 200), pages=st.integers(1, 5))
@settings(max_examples=30, deadline=None)
def test_prefix_share_roundtrip_prop(seed, pages):
    """register -> free -> match resurrects the *same* blocks: a shared
    prefix is one set of physical blocks no matter how many requests read it,
    refcount tracks the reader count exactly, and freeing all readers parks
    the bytes in the cached pool instead of killing them."""
    rng = np.random.default_rng(seed)
    lay = _alloc_layout(blocks=pages + 3)
    al = BlockAllocator(lay)
    prompt = rng.integers(1, 100, pages * 8).astype(np.int32)
    digs = prefix_page_digests(prompt, 8)
    assert len(digs) == pages
    ids, scrub = al.alloc(pages)
    assert not scrub
    for d, b in zip(digs, ids):
        assert al.register_page(d, b)
    # a second reader shares every page
    assert al.match_pages(digs) == ids
    assert all(al.refcount(b) == 2 for b in ids)
    # both readers leave: hashed blocks park in the cached pool, bytes kept
    assert al.free(ids) == []
    assert al.free(ids) == []
    assert al.live_blocks == 0 and al.cached_blocks == pages
    # a third reader resurrects them from cache — same physical blocks
    assert al.match_pages(digs) == ids
    assert all(al.refcount(b) == 1 for b in ids)
    assert al.hit_rate == 1.0


def test_block_allocator_refcount_underflow_raises():
    al = BlockAllocator(_alloc_layout(4))
    ids, _ = al.alloc(2)
    al.free(ids)
    try:
        al.free(ids)  # double free
    except ValueError:
        pass
    else:
        raise AssertionError("double free must raise, not underflow")


def test_block_allocator_eviction_scrub_contract():
    """When the free list runs dry, alloc evicts cached (hashed, refcount-0)
    blocks LRU-first and returns them in ``scrub`` — the caller's cue to zero
    bytes that still hold another prompt's KV.  Evicted digests no longer
    match."""
    lay = _alloc_layout(3)
    al = BlockAllocator(lay)
    ids, _ = al.alloc(3)
    digs = [bytes([i]) * 16 for i in range(3)]
    for d, b in zip(digs, ids):
        al.register_page(d, b)
    al.free(ids)
    assert al.cached_blocks == 3 and al.free_blocks == 0
    got, scrub = al.alloc(2)
    assert got == scrub == ids[:2]  # LRU order, both need zeroing
    assert al.evictions == 2
    assert al.match_pages([digs[0]]) == []  # evicted digest is gone
    assert al.match_pages([digs[2]]) == [ids[2]]  # survivor still matches


def test_tail_registry_cow_semantics():
    """Partial-tail registry: ``match_tail`` returns the *longest* registered
    match, counts a COW copy, and does not ref-bump the source (the caller
    copies bytes into a fresh block); ``forget`` makes a block unmatchable."""
    lay = _alloc_layout(6)
    al = BlockAllocator(lay)
    rng = np.random.default_rng(9)
    tail = rng.integers(1, 100, 5).astype(np.int32)
    digs = prefix_tail_digests(b"", tail)
    (b3,), _ = al.alloc(1)
    (b5,), _ = al.alloc(1)
    assert al.register_tail(digs[2], b3, rows=3)
    assert al.register_tail(digs[4], b5, rows=5)
    # probe with the full tail: the 5-row match wins over the 3-row one
    assert al.match_tail(digs) == (b5, 5)
    assert al.refcount(b5) == 1  # no ref bump — COW source only
    assert al.cow_copies == 1
    # probing only 4 tokens falls back to the 3-row match
    assert al.match_tail(digs[:4]) == (b3, 3)
    # forget kills matchability without touching the refcount
    assert al.forget(b5) == []  # still live: nothing to zero
    assert al.match_tail(digs) == (b3, 3)
    assert al.refcount(b5) == 1


def test_prefix_digests_are_prefix_dependent():
    """Chained digests: an identical page at a different position/prefix must
    NOT collide — equal digests mean equal full prefixes."""
    page = np.arange(8, dtype=np.int32)
    a = prefix_page_digests(np.concatenate([page, page]), 8)
    assert a[0] != a[1]  # same bytes, different chain position
    b = prefix_page_digests(np.concatenate([page + 1, page]), 8)
    assert a[1] != b[1]  # same page 1, different page 0
    # and the tail chain is seeded by the full-page chain
    t0 = prefix_tail_digests(a[0], page[:3])
    t1 = prefix_tail_digests(b[0], page[:3])
    assert t0[0] != t1[0] and len(t0) == 3
