from .async_engine import AsyncEngine, TokenStream  # noqa: F401
from .engine import Engine, ServeConfig  # noqa: F401
from .faults import FaultConfig  # noqa: F401
from .journal import Journal, JournalTap, recover_into, replay  # noqa: F401
from .metrics import acceptance_rate, tok_per_s  # noqa: F401
from .scheduler import Completion, Request, Scheduler, Status  # noqa: F401

# validate_packed lives in .packed, imported lazily there to keep the serve
# package importable without pulling the kernels module in.
