from .pipeline import Prefetcher, SyntheticDataset  # noqa: F401
