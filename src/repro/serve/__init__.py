from .engine import Engine, ServeConfig  # noqa: F401
from .scheduler import Completion, Request, Scheduler  # noqa: F401
