"""Architecture + run configuration.

Every assigned architecture is one ``ArchConfig`` in ``repro/configs/<id>.py``.
``--arch <id>`` anywhere in the launchers resolves through
:func:`repro.configs.get_config`.
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

__all__ = ["ArchConfig", "ShapeConfig", "SHAPES", "pad_vocab"]


def pad_vocab(v: int, multiple: int = 256) -> int:
    """Pad vocab to a shardable multiple (loss masks the padding ids)."""
    return ((v + multiple - 1) // multiple) * multiple


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str  # dense | moe | ssm | hybrid | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int
    kv_heads: int
    d_ff: int
    vocab: int
    head_dim: Optional[int] = None  # default d_model // n_heads
    # options
    qk_norm: bool = False
    qkv_bias: bool = False
    rope_theta: float = 10000.0
    tie_embeddings: bool = False
    # MoE
    n_experts: int = 0
    top_k: int = 0
    moe_cf: float = 1.25  # capacity factor; >= n_experts/top_k == dropless
    # SSM (mamba2)
    ssm_state: int = 0
    ssm_heads: int = 0
    ssm_chunk: int = 256
    d_conv: int = 4
    expand: int = 2
    # hybrid (recurrentgemma / griffin)
    block_pattern: Tuple[str, ...] = ()  # e.g. ("rglru", "rglru", "attn")
    local_window: int = 0  # sliding-window size for local attention
    rglru_dim: int = 0  # recurrent width (griffin: ~ d_model)
    # enc-dec (whisper)
    enc_layers: int = 0
    enc_frames: int = 1500  # stub audio frontend: precomputed frame embeddings
    # vlm (paligemma)
    patch_tokens: int = 0  # stub vision frontend: precomputed patch embeddings
    # sparsity (the paper's technique, first-class)
    sparsity: float = 0.0  # target unstructured weight sparsity
    vusa_m_over_a: int = 4  # block-VUSA max virtual growth M_blk/A_blk
    # misc
    dtype: str = "bfloat16"
    remat: bool = True

    @property
    def hd(self) -> int:
        if self.head_dim:
            return self.head_dim
        return self.d_model // self.n_heads if self.n_heads else 0

    @property
    def padded_vocab(self) -> int:
        return pad_vocab(self.vocab)

    def param_count(self) -> int:
        """Approximate parameter count (used for 6ND model-FLOPs)."""
        d, v, L = self.d_model, self.padded_vocab, self.n_layers
        hd = self.hd
        emb = v * d * (1 if self.tie_embeddings else 2)
        attn = d * (self.n_heads * hd) + 2 * d * (self.kv_heads * hd) + (self.n_heads * hd) * d
        if self.family == "moe":
            ffn = 3 * d * self.d_ff * self.n_experts
        else:
            ffn = 3 * d * self.d_ff
        if self.family == "ssm":
            din = self.expand * d
            # in_proj(z,x,B,C,dt) + out_proj + conv
            attn = 0
            ffn = d * (2 * din + 2 * self.ssm_state + self.ssm_heads) + din * d + din * self.d_conv
        body = L * (attn + ffn)
        if self.family == "hybrid":
            n_attn = sum(1 for b in self._pattern() if b == "attn")
            n_rec = L - n_attn
            rec = (
                d * (2 * self.rglru_dim) + self.rglru_dim * d
                + 2 * self.rglru_dim * self.rglru_dim // 1
            )
            body = n_attn * (attn + ffn) + n_rec * (rec + ffn)
        if self.family == "encdec":
            body = self.enc_layers * (attn + ffn) + L * (2 * attn + ffn)
        return emb + body

    def active_param_count(self) -> int:
        """Params touched per token (MoE: top_k of n_experts FFNs)."""
        if self.family != "moe":
            return self.param_count()
        d, L = self.d_model, self.n_layers
        hd = self.hd
        emb = self.padded_vocab * d * (1 if self.tie_embeddings else 2)
        attn = d * (self.n_heads * hd) + 2 * d * (self.kv_heads * hd) + (self.n_heads * hd) * d
        ffn = 3 * d * self.d_ff * self.top_k
        return emb + L * (attn + ffn)

    def _pattern(self) -> Tuple[str, ...]:
        if not self.block_pattern:
            return ()
        reps = (self.n_layers + len(self.block_pattern) - 1) // len(self.block_pattern)
        return (self.block_pattern * reps)[: self.n_layers]


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode


SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}
