"""Continuous-batching scheduler over the fused decode loop.

``Engine.generate`` serves one fixed batch of equal-length prompts for a
fixed ``max_new``; real traffic is ragged.  :class:`Scheduler` keeps a fixed
pool of in-flight *slots* and alternates two phases (DESIGN.md §5, §6):

  admission   free slots are filled with queued requests whose arrival time
              has passed, highest priority first (arrival order breaks
              ties).  Arrivals are coalesced per round and grouped into
              prompt-length buckets: each bucket is primed in ONE batched
              masked-prefill dispatch (``Engine.prime_many``) and scattered
              into its slots with ONE donated multi-slot write
              (``models.cache.write_slots``) — admission of N same-bucket
              requests costs O(1) dispatches and zero host syncs.
              Recurrent families (and ``admission="sequential"``, the
              measured baseline) fall back to per-request exact-length
              priming.
  decode      one jitted *segment* — ``segment`` fused ``lax.scan`` steps
              of the whole pool, vmapped over the slot axis — runs on
              device, then syncs once; finished slots (EOS or budget)
              retire and free up for the next admission round.  First-token
              EOS/budget checks are deferred to this sync too, so admission
              itself never blocks on a device->host transfer.

Each slot is an independent B=1 decode cache stacked on a leading slot axis
(:mod:`repro.models.cache`), with its own scalar ``pos`` and its own PRNG
key stream seeded from the request.  That makes every completed request's
tokens bit-identical to a one-shot ``Engine.generate`` of the same prompt,
seed and temperature at batch 1 — the scheduler changes *when* work runs,
never *what* it computes.  Bucketed prefill preserves this bit-for-bit:
right-padding keeps every real token's causal window unchanged and padded
keys are masked to exactly-zero probability (DESIGN.md §6).  Free slots
decode along with the pool (cheaper than masking the hot path); their
output is discarded and their state is replaced wholesale at the next
admission.

The segment length trades sync overhead against retirement latency: the
pool only retires/admits at segment boundaries, so a slot whose request
finished mid-segment decodes (and discards) at most ``segment - 1`` extra
tokens.  The segment shape is static — one compiled program serves the
whole run regardless of arrival pattern, and the bucketed prefill programs
(one per length bucket x batch bucket) serve any traffic shape without
recompiling.

Production hardening (DESIGN.md §9) rides the same sync points, so none of
it adds host transfers:

* **deadlines / cancellation** — ``Request.deadline_s`` is enforced at the
  segment sync (and at admission: a request whose queue wait already blew
  its deadline is shed without ever being primed); ``cancel(rid)`` removes
  queued requests immediately and flags in-flight ones for retirement at
  the next sync.  Every terminal path lands in ``Completion.status``.
* **backpressure** — ``queue_cap`` bounds the queue; ``shed_policy``
  decides who pays: ``"reject"`` the new request, ``"shed-oldest"`` the
  longest-waiting queued one, or ``"shed-lowest-priority"`` the lowest-
  priority queued one (only when the newcomer outranks it).
* **integrity guard + dense fallback** — the engine's per-row ``isfinite``
  flag is carried through the segment scan and fetched with the token grid
  in the same ``device_get``.  A slot that trips the guard truncates its
  tokens at the first bad step; under active packed weights the pack is
  quarantined (``Engine.quarantine_packed``) and the request is re-admitted
  ONCE on the dense path — completing as ``FAILED_FALLBACK_OK`` with tokens
  bit-identical to a clean dense run, since re-admission re-primes from the
  prompt with the request's own seed.  A second trip fails the request for
  good: the retry is bounded, never a loop.

Paged KV pool (DESIGN.md §11, ``ServeConfig.page_size > 0``): the
slot-stacked contiguous pool is replaced by a shared block arena plus
per-slot block tables (``models.cache``).  The run loop is unchanged —
admission, one fused segment, one sync — but admission allocates blocks
lazily (pages covering the prompt up front, decode pages extended at each
sync), retirement refcount-frees them, and identical prompt prefixes share
read-only blocks through the allocator's hash registry (full pages by
refcount, partial tail pages by copy-on-write).  Mid-flight arena
exhaustion preempts the latest-admitted slot (its request re-queues and
re-primes — same seed, identical tokens), so the earliest admission always
progresses.  With ``prefill_chunk > 0`` long prompts prefill in chunks
co-scheduled between decode segments: one chunk per round per admitting
slot, so decoding slots keep stepping through an arbitrarily long
admission.  The decode math is untouched — the gathered block view is
shape-identical to the slot-pool cache — so paged decode stays
bit-identical to the slot pool (tests/test_paged.py).
"""

from __future__ import annotations

import bisect
import dataclasses
import enum
import math
import time
from typing import Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from .engine import Engine
from .metrics import acceptance_rate, tok_per_s

__all__ = ["Request", "Completion", "Scheduler", "Status"]


class Status(str, enum.Enum):
    """Terminal state of a request (``Completion.status``)."""

    OK = "OK"
    TIMEOUT = "TIMEOUT"  # deadline blown — queued (never primed) or in flight
    CANCELLED = "CANCELLED"
    REJECTED = "REJECTED"  # backpressure: refused at submit, or shed from the queue
    FAILED_FALLBACK_OK = "FAILED_FALLBACK_OK"  # guard trip, dense retry delivered
    FAILED = "FAILED"  # guard trip, bounded retry also tripped
    STALLED = "STALLED"  # watchdog abort: segment hung past the stall timeout


@dataclasses.dataclass
class Request:
    """One generation request.  ``arrival_s`` is an offset from ``run()``
    start (0 = already queued); ``seed`` seeds this request's private PRNG
    stream, mirroring ``ServeConfig.seed`` in one-shot generate.
    ``deadline_s`` is relative to arrival (None = no deadline); higher
    ``priority`` admits first and survives ``shed-lowest-priority``."""

    prompt: np.ndarray  # (S,) int32
    max_new: int = 32
    eos_id: Optional[int] = None
    seed: int = 0
    arrival_s: float = 0.0
    deadline_s: Optional[float] = None
    priority: int = 0


@dataclasses.dataclass
class Completion:
    rid: int
    tokens: np.ndarray  # (<= max_new,) int32, truncated just after eos_id
    arrival_s: float
    admit_s: float
    finish_s: float
    status: Status = Status.OK
    ttft_s: float = float("nan")  # time to first token, from arrival

    @property
    def latency_s(self) -> float:
        return self.finish_s - self.arrival_s


@dataclasses.dataclass
class _PrefillJob:
    """Host state of an in-progress paged prefill (DESIGN.md §11): chunked
    long-prompt admission, prefix-suffix recompute after a partial prefix
    hit, or the 1-token re-peek of a fully prefix-matched prompt
    (``write_from == L``: attention over the shared blocks, zero writes)."""

    prompt: np.ndarray
    L: int
    start: int  # next chunk's first sequence position
    write_from: int  # first row this request may write (rows below are shared)
    chunk: int  # chunk width (one compiled chunk program per width)
    seed: int
    poisoned: bool = False  # fault plan: poison fires at completion, not admission


@dataclasses.dataclass
class _Slot:
    """Host-side bookkeeping for one in-flight slot."""

    rid: int = -1
    tokens: Optional[List[int]] = None
    first: Optional[jax.Array] = None  # deferred first token (device, (1, 1))
    remaining: int = 0
    eos_id: Optional[int] = None
    arrival_s: float = 0.0
    admit_s: float = 0.0
    deadline: float = float("inf")  # absolute run-relative deadline
    ttft_s: float = float("nan")
    req: Optional[Request] = None  # kept for the bounded dense-retry requeue
    prefill: Optional[_PrefillJob] = None  # paged: chunked admission in flight
    last_emit_t: float = float("nan")  # last sync that emitted tokens (ITL)

    @property
    def active(self) -> bool:
        return self.rid >= 0


_SHED_POLICIES = ("reject", "shed-oldest", "shed-lowest-priority")


class Scheduler:
    """Continuous-batching run loop over a fused-decode :class:`Engine`."""

    def __init__(
        self,
        engine: Engine,
        slots: int = 4,
        segment: int = 8,
        admission: str = "batched",
        queue_cap: Optional[int] = None,
        shed_policy: str = "reject",
        clock: Optional[Callable[[], float]] = None,
        sleep: Optional[Callable[[float], None]] = None,
    ):
        if not engine.sc.fused:
            raise ValueError("Scheduler requires a fused-decode engine (ServeConfig.fused)")
        if slots < 1 or segment < 1:
            raise ValueError(f"need slots >= 1 and segment >= 1, got {slots}, {segment}")
        if admission not in ("batched", "sequential"):
            raise ValueError(f"admission must be 'batched' or 'sequential', got {admission!r}")
        if queue_cap is not None and queue_cap < 1:
            raise ValueError(f"queue_cap must be None or >= 1, got {queue_cap}")
        if shed_policy not in _SHED_POLICIES:
            raise ValueError(f"shed_policy must be one of {_SHED_POLICIES}, got {shed_policy!r}")
        self.eng = engine
        # clock defaults to the ENGINE's injectable clock (monotonic unless a
        # test swapped it), so one injection point covers engine timings and
        # scheduler deadlines alike; an explicit `clock=` still wins
        clock = clock or engine._clock
        self.model = engine.model
        self.slots = slots
        self.segment = segment
        # "batched" coalesces arrivals into bucketed one-dispatch prefills
        # (when the family supports masked prefill); "sequential" keeps the
        # per-request exact-length path as the measured baseline
        self.admission = admission
        self.queue_cap = queue_cap
        self.shed_policy = shed_policy
        # injectable time sources: tests drive deadlines/cancellation with a
        # fake clock instead of real sleeps, keeping the suite fast and exact
        self._clock = clock or time.monotonic
        self._sleep = sleep or time.sleep
        # (arrival_s, rid, Request), kept sorted by (arrival_s, rid) at
        # submit time so arrived requests are always a front prefix —
        # admission pops O(k) per round instead of re-scanning the backlog
        self._queue: List[tuple] = []
        self._completions: Dict[int, Completion] = {}
        self._next_rid = 0
        self._slot: List[_Slot] = [_Slot() for _ in range(slots)]
        # device state: slot-stacked cache, per-slot tokens and raw key data.
        # Under a mesh the slot axis — the serve path's batch dim — is
        # sharded over the DP mesh axes (DESIGN.md §8): the KV pool's bytes
        # scale out with ``data`` while the packed weights scale out with
        # ``model`` inside the engine's decode step.
        kshape = jax.random.key_data(jax.random.key(0)).shape
        self._token = jnp.zeros((slots, 1, 1), jnp.int32)
        self._kdata = jnp.zeros((slots,) + kshape, jnp.uint32)
        # paged KV pool (DESIGN.md §11): page_size > 0 swaps the slot-stacked
        # contiguous pool for a block arena + per-slot tables.  Families the
        # paged layout can't host (recurrent state, vlm patch rows) silently
        # keep the slot pool — same knob, same scheduler, dense fallback.
        self.paged = bool(engine.sc.page_size) and engine.paged_supported
        self._prefix_on = self.paged and engine.sc.prefix_cache
        self._chunk_cfg = engine.sc.prefill_chunk if self.paged else 0
        if self.paged:
            from ..models.cache import (
                BlockAllocator,
                PagedLayout,
                paged_block_bytes,
                paged_pool_bytes,
            )

            self._layout = PagedLayout.build(
                slots, engine.sc.max_len, engine.sc.page_size, engine.sc.arena_blocks
            )
            self._alloc = BlockAllocator(self._layout)
            self._pstate = self.model.init_paged_pool(self._layout, engine.sc.max_len)
            if engine.mesh is not None:
                from ..models.cache import paged_shardings

                self._pstate = jax.device_put(
                    self._pstate, paged_shardings(self._pstate, engine.mesh)
                )
            self._arena_names = tuple(sorted(self._pstate["arena"].keys()))
            self._block_bytes = paged_block_bytes(self._pstate)
            self._arena_bytes = paged_pool_bytes(self._pstate)
            # host mirrors of the device tables/positions — kept exact (every
            # pos/table mutation happens at a host-driven event), so table
            # extension and page accounting never read the device back
            self._rows = np.stack(
                [np.full(self._layout.n_pages, self._layout.scratch_block(i), np.int32)
                 for i in range(slots)]
            )
            self._pos = [0] * slots
            self._slot_blocks: List[List[int]] = [[] for _ in range(slots)]
            self._slot_private: List[List[int]] = [[] for _ in range(slots)]
            self._slot_npages = [0] * slots
            self._seg_paged = jax.jit(
                self._segment_paged_fn, static_argnums=(4, 5), donate_argnums=(1, 2, 3)
            )
            self._bind = jax.jit(self._bind_fn, donate_argnums=(0, 1, 2))
            self._fill = jax.jit(self._fill_fn, donate_argnums=(0,))
            self._rebind = jax.jit(self._rebind_fn, donate_argnums=(0,))
            self._zero = jax.jit(self._zero_fn, donate_argnums=(0,))
            self._copyb = jax.jit(self._copy_fn, donate_argnums=(0,))
            self._poisonb = jax.jit(self._poison_blk_fn, donate_argnums=(0,))
            self._resetp = jax.jit(self._reset_fn, donate_argnums=(0,))
            self._cache = None
            self._batch_axes = None
            self._slot_bytes = self._arena_bytes // max(slots, 1)
        else:
            self._cache = self.model.init_slot_cache(slots, engine.sc.max_len)
            if engine.mesh is not None:
                from ..models.cache import slot_shardings

                self._cache = jax.device_put(
                    self._cache, slot_shardings(self._cache, engine.mesh)
                )
            self._batch_axes = self.model.cache_batch_axes(engine.sc.max_len)
            self._slot_bytes = sum(
                int(np.prod(leaf.shape)) * leaf.dtype.itemsize
                for leaf in jax.tree.leaves(self._cache)
            ) // max(slots, 1)
        if engine.mesh is not None:
            from ..dist.sharding import batch_sharding

            self._token = jax.device_put(
                self._token, batch_sharding(engine.mesh, slots, self._token.ndim)
            )
            self._kdata = jax.device_put(
                self._kdata, batch_sharding(engine.mesh, slots, self._kdata.ndim)
            )
        # self-speculative decoding (DESIGN.md §13): each scan step of the
        # segment dispatch becomes one draft/verify ROUND, advancing a slot
        # by 1..draft_k+1 tokens, so every worst-case KV-growth bound that
        # used ``segment`` must use ``span = segment * (draft_k + 1)``
        self.speculative = bool(engine.sc.speculative)
        self._draft_k = engine.sc.draft_k if self.speculative else 0
        self._span = segment * (self._draft_k + 1)
        # donate the pool state: segments and admissions update it in place.
        # ``dense`` is static: quarantining the pack flips it, forcing the
        # retrace that rebinds the decode step onto the dense path.
        self._seg = jax.jit(
            self._segment_fn, static_argnums=(4, 5), donate_argnums=(1, 2, 3)
        )
        if self.speculative:
            self._seg_spec = jax.jit(
                self._segment_spec_fn, static_argnums=(4, 5), donate_argnums=(1, 2, 3)
            )
            if self.paged:
                self._seg_spec_paged = jax.jit(
                    self._segment_spec_paged_fn,
                    static_argnums=(4, 5), donate_argnums=(1, 2, 3),
                )
        self._write = jax.jit(self._write_fn, donate_argnums=(0, 1, 2))
        self._write_many = jax.jit(self._write_many_fn, donate_argnums=(0, 1, 2))
        self._derive_keys = jax.jit(
            jax.vmap(lambda s: jax.random.key_data(jax.random.key(s)))
        )
        from ..models.cache import poison_slot

        self._poison = jax.jit(poison_slot, donate_argnums=(0,))
        # hardening state (reset per run epoch by _maybe_reset)
        self._cancel: set = set()  # in-flight rids to retire at the next sync
        self._retried: set = set()  # rids that used their bounded dense retry
        self._fallback_rids: set = set()  # rids currently on the dense retry
        self._fault_fired: set = set()  # rids whose one-shot cache fault ran
        self._counters: Dict[str, int] = dict(
            rejected=0, shed=0, timed_out=0, cancelled=0,
            fallback=0, failed=0, quarantined=0, preempted=0, stalled=0,
            # speculative accounting (host-consumed view): drafts proposed in
            # rounds a slot consumed from, and how many of them were accepted
            spec_proposed=0, spec_accepted=0,
        )
        # streaming/watchdog state (DESIGN.md §12).  `_abort_status` is the
        # fail-fast flag another thread (the async engine's watchdog) sets:
        # the run loop checks it at every sync and inside every injected
        # stall wait, retires or re-queues the in-flight work, and returns.
        # `_draining` stops admission — in-flight work finishes, the queue
        # survives — for clean shutdown and hot pack swaps.  `_stall_fired`
        # makes seeded decode stalls one-shot per rid; `_stall_retried`
        # bounds the watchdog re-queue exactly like `_retried` bounds the
        # dense retry: a rid aborted twice is terminal STALLED, never a loop.
        self._abort_status: Optional[Status] = None
        self._draining = False
        self._stall_fired: set = set()
        self._stall_retried: set = set()
        self._itl: List[float] = []  # per-token inter-token latency samples
        self._ran = False  # epoch flag: True after run() so the next
        # submit()/cancel()/run() starts a fresh completion/counter epoch
        self._run_now: Optional[Callable[[], float]] = None
        # run stats
        self._seg_steps = 0
        self._active_slot_steps = 0
        self._decode_s = 0.0
        self._admit_s = 0.0
        # cache observability (DESIGN.md §11): Σ used-KV bytes and Σ active
        # slots, sampled once per segment sync — their ratio is the
        # HBM-bytes-per-active-request gauge the paged bench gates on
        self._kv_used_acc = 0
        self._kv_active_acc = 0
        self._alloc_snap = (0, 0, 0, 0)  # (hits, lookups, cow, evictions) at epoch start

    # -- epoch ----------------------------------------------------------------

    def _maybe_reset(self) -> None:
        """Start a fresh stats/completions epoch on the first mutation after a
        finished run.  Resetting lazily (instead of at the top of ``run``)
        lets submit-time rejections land in the same epoch as the run that
        follows them — the REJECTED completion must survive into the
        ``run()`` result, not be wiped by it."""
        if not self._ran:
            return
        self._ran = False
        self._completions = {}
        self._cancel = set()
        self._retried = set()
        self._fallback_rids = set()
        self._fault_fired = set()
        # _stall_fired/_stall_retried deliberately survive the epoch reset: a
        # watchdog abort ENDS the run, so the bounded re-queue it leaves in
        # the queue is consumed by the NEXT run() — wiping the sets here
        # would re-fire one-shot stalls and unbound the stall retry.  Rids
        # never reuse, so stale entries can never collide.
        self._itl = []
        for k in self._counters:
            self._counters[k] = 0
        self._seg_steps = 0
        self._active_slot_steps = 0
        self._decode_s = self._admit_s = 0.0
        self._kv_used_acc = self._kv_active_acc = 0
        if self.paged:
            # the prefix registry itself persists across epochs (warm cache is
            # the point); only the rate counters snapshot per epoch
            self._alloc_snap = (
                self._alloc.hits, self._alloc.lookups,
                self._alloc.cow_copies, self._alloc.evictions,
            )

    # -- submission -----------------------------------------------------------

    def submit(self, req: Request, rid: Optional[int] = None) -> int:
        """Queue a request; returns its request id.  Under a full queue
        (``queue_cap``) the shed policy decides who pays: the newcomer is
        REJECTED, or a queued victim is shed (also REJECTED, counted under
        ``shed``) to make room.  ``rid`` pins the id explicitly — journal
        recovery re-queues crashed requests under their ORIGINAL rids so the
        journal stream stays contiguous across the crash (DESIGN.md §12)."""
        self._maybe_reset()
        prompt = np.asarray(req.prompt, np.int32).reshape(-1)
        if req.max_new < 1:  # before the budget check: a negative max_new
            raise ValueError("max_new must be >= 1")  # could slip past it
        # worst-case KV rows this request can occupy: a slot decodes whole
        # segments, and under speculation each segment step is a round that
        # can write up to draft_k+1 rows (self._span == segment otherwise)
        budget = prompt.shape[0] + req.max_new + self._span
        if budget > self.eng.sc.max_len:
            raise ValueError(
                f"prompt({prompt.shape[0]}) + max_new({req.max_new}) + "
                f"segment span({self._span}) = {budget} exceeds max_len "
                f"{self.eng.sc.max_len}"
            )
        if self.paged:
            worst = -(-budget // self._layout.page)
            if worst > self._layout.user_blocks:
                raise ValueError(
                    f"worst-case pages {worst} for this request exceed the "
                    f"arena's {self._layout.user_blocks} user blocks "
                    f"(page_size={self._layout.page}, "
                    f"arena_blocks={self.eng.sc.arena_blocks}) — even an "
                    "empty pool could never hold it"
                )
        if rid is None:
            rid = self._next_rid
            self._next_rid += 1
        else:
            if rid in self._completions or any(r == rid for _, r, _ in self._queue) or any(
                s.active and s.rid == rid for s in self._slot
            ):
                raise ValueError(f"rid {rid} is already live or terminal")
            self._next_rid = max(self._next_rid, rid + 1)
        req = dataclasses.replace(req, prompt=prompt)
        if self.queue_cap is not None and len(self._queue) >= self.queue_cap:
            if not self._make_room(req):
                self._finish_unadmitted(rid, req, Status.REJECTED)
                self._counters["rejected"] += 1
                return rid
        bisect.insort(self._queue, (req.arrival_s, rid, req))
        return rid

    def _make_room(self, req: Request) -> bool:
        """Apply the shed policy to a full queue; True if a slot was freed
        for ``req``.  ``shed-oldest`` evicts the longest-waiting entry;
        ``shed-lowest-priority`` evicts the lowest-priority one (latest
        arrival breaks ties — it would have been served last anyway) and
        only when the newcomer strictly outranks it, so equal-priority
        traffic cannot churn the queue."""
        if self.shed_policy == "reject":
            return False
        if self.shed_policy == "shed-oldest":
            j = 0
        else:  # shed-lowest-priority
            j = min(
                range(len(self._queue)),
                key=lambda t: (
                    self._queue[t][2].priority,
                    -self._queue[t][0],
                    -self._queue[t][1],
                ),
            )
            if self._queue[j][2].priority >= req.priority:
                return False
        _, vrid, vreq = self._queue.pop(j)
        self._finish_unadmitted(vrid, vreq, Status.REJECTED)
        self._counters["shed"] += 1
        return True

    def cancel(self, rid: int) -> bool:
        """Cancel a request: queued requests complete CANCELLED immediately;
        in-flight ones retire (with their partial tokens) at the next
        segment sync.  Returns False when ``rid`` is unknown or already
        terminal — cancellation never raises."""
        self._maybe_reset()
        for j, (_, r, req) in enumerate(self._queue):
            if r == rid:
                del self._queue[j]
                now = self._run_now() if self._run_now is not None else float("nan")
                self._finish_unadmitted(rid, req, Status.CANCELLED, finish=now)
                self._counters["cancelled"] += 1
                return True
        for s in self._slot:
            if s.active and s.rid == rid:
                self._cancel.add(rid)
                return True
        return False

    # -- streaming control plane (DESIGN.md §12) ------------------------------

    def drain(self) -> None:
        """Stop admission: in-flight requests finish normally, queued ones
        stay queued, and ``run()`` returns once no slot is active.  The
        clean-shutdown / hot-swap primitive — nothing is dropped.  Safe to
        call from another thread mid-run (a bool flag read at sync points)."""
        self._draining = True

    def resume_admission(self) -> None:
        """Re-open admission after :meth:`drain` (e.g. once a hot pack swap
        finished re-jitting)."""
        self._draining = False

    @property
    def draining(self) -> bool:
        return self._draining

    def abort(self, status: Status = Status.STALLED) -> None:
        """Fail-fast escape hatch, set by the watchdog when a segment stalls
        past its timeout: the run loop notices at the next interruptible
        point (sync boundaries and every injected stall/sleep wait), deals
        with the in-flight work and returns instead of hanging the caller.

        Each in-flight request gets ONE bounded re-queue (its re-execution
        under its own seed emits a bit-identical stream, so the consumer
        just sees the tail arrive late); a request caught in a second abort
        retires terminally with ``status`` — a persistent hang cannot loop.
        Safe to call from another thread."""
        self._abort_status = status

    @property
    def has_work(self) -> bool:
        """True while anything is queued or in flight."""
        return bool(self._queue) or any(s.active for s in self._slot)

    def inflight_tokens(self) -> Dict[int, List[int]]:
        """Host snapshot of every in-flight request's tokens emitted so far
        — what a streaming frontend diffs at each ``on_sync`` to push new
        tokens (zero device traffic: these lists are already on the host)."""
        return {
            s.rid: list(s.tokens)
            for s in self._slot
            if s.active and s.tokens is not None
        }

    def completions_so_far(self) -> Dict[int, Completion]:
        """Snapshot of this epoch's terminal completions (usable mid-run
        from ``on_sync``, unlike the dict ``run`` eventually returns)."""
        return dict(self._completions)

    def itl_samples(self) -> List[float]:
        """This epoch's inter-token-latency samples — one per *emission
        event*.  Tokens are observable only at segment syncs, so everything
        a slot emits at one sync surfaces at the same wall-clock instant:
        that event contributes exactly one ``t - last_emit_t`` interval (see
        :meth:`_note_emission`), never ``k`` copies of an average.  The
        first-ever emission sets the baseline and samples nothing — TTFT
        owns the first token."""
        return list(self._itl)

    def refresh_decode(self) -> None:
        """Re-jit the segment dispatchers after an ``Engine.reload_packed``
        hot swap.  The jitted segment bodies close over the engine's pack
        arrays as trace-time constants — the static ``dense`` flag only
        covers quarantine transitions, not a *new* pack — so without this a
        swapped engine would keep serving the old pack's trace.  Only call
        between runs or while drained (no segment in flight)."""
        self._seg = jax.jit(
            self._segment_fn, static_argnums=(4, 5), donate_argnums=(1, 2, 3)
        )
        if self.paged:
            self._seg_paged = jax.jit(
                self._segment_paged_fn, static_argnums=(4, 5), donate_argnums=(1, 2, 3)
            )
        if self.speculative:
            self._seg_spec = jax.jit(
                self._segment_spec_fn, static_argnums=(4, 5), donate_argnums=(1, 2, 3)
            )
            if self.paged:
                self._seg_spec_paged = jax.jit(
                    self._segment_spec_paged_fn,
                    static_argnums=(4, 5),
                    donate_argnums=(1, 2, 3),
                )

    def verify_paged_mirror(self) -> bool:
        """Recovery invariant check (DESIGN.md §12): the host-side block
        table / position mirrors must agree with the device arena's control
        plane.  One tiny device_get of table+pos — debug/test tool, never on
        the hot path.  True (or raises) on slot-pool schedulers."""
        if not self.paged:
            return True
        from ..models.cache import paged_host_mirror

        table, pos = paged_host_mirror(self._pstate)
        for i, s in enumerate(self._slot):
            if not (s.active and s.prefill is None):
                continue  # free/admitting slots legitimately drift
            if not np.array_equal(table[i], self._rows[i]) or int(pos[i]) != self._pos[i]:
                raise AssertionError(
                    f"paged host mirror diverged for slot {i}: "
                    f"host pos {self._pos[i]} vs device {int(pos[i])}"
                )
        return True

    def _finish_unadmitted(
        self, rid: int, req: Request, status: Status, finish: float = float("nan")
    ) -> None:
        """Record a terminal completion for a request that never held a slot
        (rejected / shed / queue-cancelled / deadline-shed).  Timing fields
        that never happened stay NaN, per the stats convention."""
        self._completions[rid] = Completion(
            rid=rid,
            tokens=np.zeros(0, np.int32),
            arrival_s=req.arrival_s,
            admit_s=float("nan"),
            finish_s=finish,
            status=status,
        )

    # -- jitted segment body --------------------------------------------------

    def _segment_fn(self, params, token, kdata, cache, steps: int, dense: bool):
        """``steps`` decode steps of all slots; returns the emitted token grid
        and per-step integrity flags, both ``(steps, slots)``, plus the
        advanced state.  Each slot splits its own key and samples at batch
        1, exactly as one-shot generate does.  ``dense`` (static) forces the
        dense decode path — flipped by pack quarantine, it keys a retrace so
        the packed/dense branch rebinds.

        Free slots decode along with the pool (their output is discarded and
        their whole state is replaced at the next admission), so the hot
        path carries no per-slot masking — a free slot's ``pos`` merely
        drifts until re-admission, and ``attention_decode`` clamps its cache
        writes at ``max_len``."""
        decode = self.eng._decode_dense_fn if dense else self.eng._decode_fn

        def body(carry, _):
            token, kdata, cache = carry

            def one(tok, kd, c):
                key = jax.random.wrap_key_data(kd)
                key, sub = jax.random.split(key)
                nxt, c2, ok = decode(params, tok, c, sub)
                return nxt, jax.random.key_data(key), c2, ok

            token, kdata, cache, ok = jax.vmap(one)(token, kdata, cache)
            return (token, kdata, cache), (token[:, 0, 0], ok[:, 0])

        (token, kdata, cache), (toks, okg) = jax.lax.scan(
            body, (token, kdata, cache), None, length=steps
        )
        return token, kdata, cache, toks, okg

    def _segment_paged_fn(self, params, token, kdata, pstate, steps: int, dense: bool):
        """Paged twin of :meth:`_segment_fn`.  Each slot decodes against the
        *shared* arena (a vmap constant — only its block table and ``pos``
        carry the slot axis) and returns its new KV row as a pending write;
        the conflict-free scatter into the arena happens once per step,
        outside the slot vmap.  The gathered block view inside the model is
        shape-identical to the slot-pool cache, so the math — and the emitted
        tokens — are bit-identical to :meth:`_segment_fn`."""
        from ..models.cache import paged_in_axes, paged_scatter_token, paged_view

        decode = self.eng._decode_dense_fn if dense else self.eng._decode_fn
        names = self._arena_names

        def body(carry, _):
            token, kdata, pstate = carry

            def one(tok, kd, c):
                key = jax.random.wrap_key_data(kd)
                key, sub = jax.random.split(key)
                nxt, c2, ok = decode(params, tok, c, sub)
                rows = {n + "_new": c2[n + "_new"] for n in names}
                return nxt, jax.random.key_data(key), rows, ok

            token, kdata, rows, ok = jax.vmap(one, in_axes=(0, 0, paged_in_axes(pstate)))(
                token, kdata, paged_view(pstate)
            )
            pstate = paged_scatter_token(pstate, rows)
            return (token, kdata, pstate), (token[:, 0, 0], ok[:, 0])

        (token, kdata, pstate), (toks, okg) = jax.lax.scan(
            body, (token, kdata, pstate), None, length=steps
        )
        return token, kdata, pstate, toks, okg

    def _segment_spec_fn(self, params, token, kdata, cache, steps: int, dense: bool):
        """Speculative twin of :meth:`_segment_fn` (DESIGN.md §13): each scan
        step runs one draft/verify ROUND per slot instead of one decode step,
        so a slot advances by 1..S tokens per step (S = draft_k+1).  Returns
        per-round grids: tokens (steps, slots, S), accepted counts ``nem``
        (steps, slots), and per-position integrity flags (steps, slots, S) —
        the host consumes ``tokens[r, i, :nem[r, i]]`` of each round.  The
        PRNG key advances once per EMITTED token inside the round, so the
        surviving key/token stream is bit-identical to :meth:`_segment_fn`'s
        one-split-per-step stream."""
        spec = self.eng._spec_round_dense_fn if dense else self.eng._spec_round_fn

        def body(carry, _):
            token, kdata, cache = carry

            def one(tok, kd, c):
                pending, c2, kd2, emit, nem, okp = spec(params, tok, c, kd)
                return pending, kd2, c2, emit, nem, okp

            token, kdata, cache, emit, nem, okp = jax.vmap(one)(token, kdata, cache)
            return (token, kdata, cache), (emit, nem, okp)

        (token, kdata, cache), (toks, nems, okg) = jax.lax.scan(
            body, (token, kdata, cache), None, length=steps
        )
        return token, kdata, cache, toks, nems, okg

    def _segment_spec_paged_fn(
        self, params, token, kdata, pstate, steps: int, dense: bool
    ):
        """Paged twin of :meth:`_segment_spec_fn`.  A speculative round needs
        a contiguous multi-token cache, so each slot first gathers its block
        table into exactly the ``(1, max_len)`` view the slot pool holds
        (same math as ``attention_decode``'s paged branch — bit-identical
        tokens), runs the round on it, and hands back the S verifier KV rows
        it wrote at ``pos..pos+S-1``; the conflict-free scatter into the
        shared arena happens once per round, outside the slot vmap
        (:func:`repro.models.cache.paged_scatter_rows`).  Rejected-tail rows
        are scattered too — they mirror the contiguous pool's
        stale-but-finite rows, masked past ``pos`` until overwritten."""
        from ..models.cache import paged_in_axes, paged_scatter_rows, paged_view

        spec = self.eng._spec_round_dense_fn if dense else self.eng._spec_round_fn
        names = self._arena_names
        S = self._draft_k + 1
        max_len = self.eng.sc.max_len

        def body(carry, _):
            token, kdata, pstate = carry
            start = pstate["pos"]  # (slots,) round-start positions

            def one(tok, kd, c):
                pos0 = c["pos"]
                row = c["table"]
                contig = {"pos": pos0}
                for n in names:
                    a = c[n]  # (L, n_blocks, page, ...) arena leaf (vmap const)
                    g = a[:, row]  # (L, n_pages, page, ...)
                    contig[n] = g.reshape(a.shape[0], 1, -1, *a.shape[3:])[
                        :, :, :max_len
                    ]
                pending, c2, kd2, emit, nem, okp = spec(params, tok, contig, kd)
                rows = {
                    n + "_new": jax.lax.dynamic_slice_in_dim(c2[n], pos0, S, axis=2)
                    for n in names
                }
                return pending, kd2, rows, emit, nem, okp

            token, kdata, rows, emit, nem, okp = jax.vmap(
                one, in_axes=(0, 0, paged_in_axes(pstate))
            )(token, kdata, paged_view(pstate))
            pstate = paged_scatter_rows(pstate, rows, start, nem)
            return (token, kdata, pstate), (emit, nem, okp)

        (token, kdata, pstate), (toks, nems, okg) = jax.lax.scan(
            body, (token, kdata, pstate), None, length=steps
        )
        return token, kdata, pstate, toks, nems, okg

    # -- jitted paged-pool mutations (all donate the pool state) --------------

    @staticmethod
    def _bind_fn(pstate, token, kdata, idx, rows, lengths, nxt, kds):
        """Donated one-dispatch bind of prefilled requests into slots ``idx``:
        block-table rows, positions, first tokens and PRNG key data.  Padding
        rows carry an out-of-range index and drop — the paged counterpart of
        ``_write_many_fn``."""
        from ..models.cache import bind_slot_pages

        table, pos = bind_slot_pages(pstate["table"], pstate["pos"], idx, rows, lengths)
        token = token.at[idx].set(nxt[:, :, None], mode="drop")
        kdata = kdata.at[idx].set(kds.astype(kdata.dtype), mode="drop")
        return {"arena": pstate["arena"], "table": table, "pos": pos}, token, kdata

    @staticmethod
    def _fill_fn(pstate, page_tables, primed):
        """Donated scatter of a primed contiguous cache into arena blocks
        (sentinel table entries — padding rows, shared pages — drop)."""
        from ..models.cache import write_prefill_pages

        return {**pstate, "arena": write_prefill_pages(pstate["arena"], page_tables, primed)}

    @staticmethod
    def _rebind_fn(pstate, idx, rows, lengths):
        """Donated table-row rewrite (lazy decode-page extension): repoint
        slots ``idx`` at ``rows`` without touching tokens or keys."""
        from ..models.cache import bind_slot_pages

        table, pos = bind_slot_pages(pstate["table"], pstate["pos"], idx, rows, lengths)
        return {"arena": pstate["arena"], "table": table, "pos": pos}

    @staticmethod
    def _zero_fn(pstate, ids):
        from ..models.cache import zero_blocks

        return {**pstate, "arena": zero_blocks(pstate["arena"], ids)}

    @staticmethod
    def _copy_fn(pstate, src, dst):
        from ..models.cache import copy_block

        return {**pstate, "arena": copy_block(pstate["arena"], src, dst)}

    @staticmethod
    def _poison_blk_fn(pstate, blk):
        from ..models.cache import paged_poison_block

        return {**pstate, "arena": paged_poison_block(pstate["arena"], blk)}

    @staticmethod
    def _reset_fn(pstate, i, scratch_id):
        from ..models.cache import paged_reset_slot

        return paged_reset_slot(pstate, i, scratch_id)

    def _zero_ids(self, ids) -> None:
        """Zero arena blocks ``ids`` host-side list, chunked to a fixed jit
        width (out-of-range padding entries are no-ops on device)."""
        w = self._layout.n_pages
        ids = list(ids)
        for j in range(0, len(ids), w):
            grp = ids[j : j + w]
            grp += [self._layout.oob] * (w - len(grp))
            self._pstate = self._zero(self._pstate, jnp.asarray(grp, jnp.int32))

    # -- admission / retirement ----------------------------------------------

    @staticmethod
    def _write_fn(cache, token, kdata, i, sub, nxt, kd):
        """Donated single-dispatch write of a primed request into slot ``i``
        (cache + first token + key data in one go); ``i`` is traced, so one
        compilation covers every slot."""
        from ..models.cache import write_slot

        return write_slot(cache, i, sub), token.at[i].set(nxt), kdata.at[i].set(kd)

    def _write_many_fn(self, cache, token, kdata, idx, sub, nxt, kds, lengths):
        """Donated one-dispatch scatter of a whole primed bucket into slots
        ``idx``: batched caches (per-slot true ``pos`` = lengths), first
        tokens, and per-request PRNG key data ``kds``.  Batch-bucket padding
        rows carry an out-of-range index and are dropped; one compilation
        covers every batch bucket."""
        from ..models.cache import write_slots

        cache = write_slots(cache, idx, sub, self._batch_axes, lengths)
        token = token.at[idx].set(nxt[:, :, None], mode="drop")
        kdata = kdata.at[idx].set(kds.astype(kdata.dtype), mode="drop")
        return cache, token, kdata

    def _kds_for(self, seeds, nb: int):
        """Per-request PRNG key data, padded to batch ``nb``: one vmapped
        derivation when every seed fits the uint32 word jax.random.key folds
        it into (bit-exact there, verified in tests); anything else — wide
        seeds an int32 array would overflow on, negative seeds whose x64
        folding differs from the uint32 cast — falls back to eager
        per-request key creation (still no host sync)."""
        seeds = list(seeds)
        if all(0 <= s < 2**32 for s in seeds):
            packed = np.asarray(seeds + [0] * (nb - len(seeds)), np.uint32)
            return self._derive_keys(jnp.asarray(packed))
        zero = jnp.zeros(self._kdata.shape[1:], self._kdata.dtype)
        return jnp.stack(
            [jax.random.key_data(jax.random.key(s)) for s in seeds]
            + [zero] * (nb - len(seeds))
        )

    def _bind_slot(self, i: int, rid: int, req: Request, first, now: float) -> None:
        slot = self._slot[i]
        slot.rid, slot.tokens, slot.first = rid, [], first
        slot.remaining = req.max_new - 1
        slot.arrival_s, slot.admit_s = req.arrival_s, now
        slot.eos_id = req.eos_id
        slot.deadline = (
            req.arrival_s + req.deadline_s if req.deadline_s is not None else float("inf")
        )
        slot.ttft_s = float("nan")
        slot.req = req
        slot.prefill = None

    def _admit(self, i: int, rid: int, req: Request, now: float) -> None:
        """Per-request exact-length admission (recurrent families, and the
        ``admission="sequential"`` baseline): B=1 prime + single-slot write.
        First-token EOS/budget checks are deferred to the segment sync, so
        no device->host transfer happens here."""
        t0 = self._clock()
        key = jax.random.key(req.seed)
        nxt, cache, key = self.eng.prime(req.prompt[None], key)
        self._cache, self._token, self._kdata = self._write(
            self._cache, self._token, self._kdata,
            jnp.int32(i), cache, nxt, jax.random.key_data(key),
        )
        self._bind_slot(i, rid, req, nxt, now)
        self._admit_s += self._clock() - t0

    def _admit_batched(self, free: List[int], picked, now: float) -> None:
        """Coalesced bucketed admission: group this round's arrivals by
        prompt-length bucket, prime each bucket in one batched masked
        prefill, scatter each into its slots in one donated write.  The
        batch dim is padded to a power of two so compile count stays
        O(len buckets x log2 slots), not O(distinct traffic shapes)."""
        t0 = self._clock()
        groups: Dict[int, list] = {}
        for i, (rid, req) in zip(free, picked):
            groups.setdefault(self.eng.bucket_len(len(req.prompt)), []).append((i, rid, req))
        for blen, group in groups.items():
            nb = 1 << (len(group) - 1).bit_length()
            tokens = np.zeros((nb, blen), np.int32)
            lengths = np.ones(nb, np.int32)  # padding rows: 1-token dummy
            idx = np.full(nb, self.slots, np.int32)  # OOB -> dropped by the scatter
            for j, (i, rid, req) in enumerate(group):
                tokens[j, : len(req.prompt)] = req.prompt
                lengths[j] = len(req.prompt)
                idx[j] = i
            kds = self._kds_for([req.seed for _, _, req in group], nb)
            nxt, cache = self.eng.prime_many(tokens, lengths)
            self._cache, self._token, self._kdata = self._write_many(
                self._cache, self._token, self._kdata,
                jnp.asarray(idx), cache, nxt, kds, jnp.asarray(lengths),
            )
            for j, (i, rid, req) in enumerate(group):
                self._bind_slot(i, rid, req, nxt[j : j + 1], now)
        self._admit_s += self._clock() - t0

    # -- paged admission (DESIGN.md §11) --------------------------------------

    def _admit_paged(self, free: List[int], picked, now: float) -> list:
        """Paged admission round: per request, consult the prefix cache,
        allocate the prompt's blocks, then either join this round's bucketed
        whole-prefill (no prefix hit, short prompt) or start a chunked
        prefill job (long prompt, or a prefix hit whose suffix must be
        recomputed against the shared blocks).  If the arena can't cover a
        request *right now* it re-queues — no admission-time preemption, so
        two big prompts can never thrash each other out; mid-flight
        extension is where preemption lives.  Returns the ``(slot, rid,
        req)`` triples actually admitted (fault injection targets only
        those)."""
        t0 = self._clock()
        admitted, whole = [], []
        pairs = list(zip(free, picked))
        for n_done, (i, (rid, req)) in enumerate(pairs):
            if not self._plan_paged_one(i, rid, req, now, whole):
                for j, (rid2, req2) in pairs[n_done:]:
                    bisect.insort(self._queue, (req2.arrival_s, rid2, req2))
                break
            admitted.append((i, rid, req))
        if whole:
            self._prime_whole_paged(whole)
        self._admit_s += self._clock() - t0
        return admitted

    def _plan_paged_one(self, i: int, rid: int, req: Request, now: float, whole) -> bool:
        """Allocate/share blocks for one request and decide its prefill path.
        False = arena cannot cover its prompt pages right now (matched
        references are returned before bailing)."""
        from ..models.cache import prefix_page_digests, prefix_tail_digests

        prompt, L = req.prompt, len(req.prompt)
        page = self._layout.page
        f = self.eng.sc.faults
        poisoned = (
            f is not None
            and f.wants_cache_nan(rid)
            and (not f.cache_nan_once or rid not in self._fault_fired)
        )
        full = prefix_page_digests(prompt, page) if self._prefix_on else []
        shared = self._alloc.match_pages(full) if self._prefix_on else []
        k = len(shared)
        cow = None
        if self._prefix_on and L % page and k == L // page:
            # every full page matched — probe the partial tail for a COW source
            seed = full[-1] if full else b""
            cow = self._alloc.match_tail(prefix_tail_digests(seed, prompt[k * page :]))
        n_prompt_pages = -(-L // page)
        got = self._alloc.alloc(n_prompt_pages - k)
        if got is None:
            if shared:
                self._alloc.free(shared)  # hashed: parked back in the cached pool
            return False
        priv, scrub = got
        if scrub:
            self._zero_ids(scrub)
        row = self._rows[i]
        row[:] = self._layout.scratch_block(i)
        row[:k] = shared
        row[k:n_prompt_pages] = priv
        self._slot_blocks[i] = list(shared) + list(priv)
        self._slot_private[i] = list(priv)
        self._slot_npages[i] = n_prompt_pages
        start = k * page
        if cow is not None:
            src, rows_m = cow
            # copy the matched tail rows into our private tail page; the
            # sharer keeps reading the original — divergence is free
            self._pstate = self._copyb(self._pstate, jnp.int32(src), jnp.int32(priv[0]))
            start += rows_m
        self._bind_slot(i, rid, req, None, now)
        if start == 0 and (self._chunk_cfg == 0 or L <= self._chunk_cfg):
            whole.append((i, rid, req, poisoned))
            return True
        if start >= L:
            # fully matched prompt: skip re-prefill entirely — one 1-token
            # "re-peek" chunk recomputes the last position's logits against
            # the shared blocks (write_from = L: zero arena writes)
            job = _PrefillJob(prompt, L, start=L - 1, write_from=L,
                              chunk=self._chunk_cfg or self.eng.bucket_len(1),
                              seed=req.seed, poisoned=poisoned)
        else:
            cw = self._chunk_cfg or self.eng.bucket_len(max(L - start, 1))
            job = _PrefillJob(prompt, L, start=start, write_from=start,
                              chunk=cw, seed=req.seed, poisoned=poisoned)
        self._slot[i].prefill = job
        return True

    def _prime_whole_paged(self, whole) -> None:
        """Bucketed one-dispatch whole-prompt prefill for this round's
        no-prefix-hit requests, scattered into their arena pages and bound in
        one donated write each — the paged mirror of ``_admit_batched``
        (bit-exact page scatter keeps slot-pool parity)."""
        groups: Dict[int, list] = {}
        for i, rid, req, poisoned in whole:
            groups.setdefault(self.eng.bucket_len(len(req.prompt)), []).append(
                (i, rid, req, poisoned)
            )
        n_pages = self._layout.n_pages
        for blen, group in groups.items():
            nb = 1 << (len(group) - 1).bit_length()
            tokens = np.zeros((nb, blen), np.int32)
            lengths = np.ones(nb, np.int32)
            idx = np.full(nb, self.slots, np.int32)  # OOB -> dropped binds
            # the primed cache spans max_len rows (right-padded); pages past
            # the prompt carry the sentinel and drop in the scatter
            pt = np.full((nb, n_pages), self._layout.oob, np.int32)
            rows_arr = np.zeros((nb, n_pages), np.int32)
            for j, (i, rid, req, poisoned) in enumerate(group):
                tokens[j, : len(req.prompt)] = req.prompt
                lengths[j] = len(req.prompt)
                idx[j] = i
                npp = self._slot_npages[i]
                pt[j, :npp] = self._rows[i][:npp]
                rows_arr[j] = self._rows[i]
            kds = self._kds_for([req.seed for _, _, req, _ in group], nb)
            nxt, cache = self.eng.prime_many(tokens, lengths)
            primed = {name: cache[name] for name in self._arena_names}
            self._pstate = self._fill(self._pstate, jnp.asarray(pt), primed)
            self._pstate, self._token, self._kdata = self._bind(
                self._pstate, self._token, self._kdata,
                jnp.asarray(idx), jnp.asarray(rows_arr), jnp.asarray(lengths),
                nxt, kds,
            )
            for j, (i, rid, req, poisoned) in enumerate(group):
                self._slot[i].first = nxt[j : j + 1]
                self._pos[i] = len(req.prompt)
                if not poisoned:
                    self._register_prompt(i, req.prompt)

    def _register_prompt(self, i: int, prompt: np.ndarray) -> None:
        """Hash-register slot ``i``'s prompt pages for future prefix sharing
        (first writer wins; already-shared pages re-register as no-ops).
        Never called for fault-poisoned requests — a poisoned block must not
        be matchable."""
        if not self._prefix_on:
            return
        from ..models.cache import prefix_page_digests, prefix_tail_digests

        page = self._layout.page
        full = prefix_page_digests(prompt, page)
        row = self._rows[i]
        for p, d in enumerate(full):
            self._alloc.register_page(d, int(row[p]))
        tail_len = len(prompt) % page
        if tail_len:
            seed = full[-1] if full else b""
            td = prefix_tail_digests(seed, prompt[len(full) * page :])
            self._alloc.register_tail(td[-1], int(row[len(full)]), tail_len)

    def _step_prefills(self) -> None:
        """Advance every in-flight prefill job by ONE chunk — co-scheduled
        between decode segments, so a long admission never stalls decoding
        slots (Sarathi-style chunked prefill, DESIGN.md §11).  A completed
        job binds its slot (table row, position, deferred first token, PRNG
        stream) and registers its prefix hashes."""
        if not self.paged:
            return
        t0 = self._clock()
        for i, slot in enumerate(self._slot):
            job = slot.prefill
            if job is None or not slot.active:
                continue
            s = job.start
            n = min(job.chunk, job.L - s)
            toks = np.zeros((1, job.chunk), np.int32)
            toks[0, :n] = job.prompt[s : s + n]
            logits, arena = self.eng.prefill_chunk(
                toks, self._pstate["arena"], jnp.asarray(self._rows[i]),
                s, n, job.write_from,
            )
            self._pstate = {**self._pstate, "arena": arena}
            job.start = s + n
            if job.start >= job.L:
                first = (
                    jnp.argmax(logits.astype(jnp.float32), axis=-1)[:, None]
                    .astype(jnp.int32)
                )
                self._complete_prefill(i, job, first)
        self._admit_s += self._clock() - t0

    def _complete_prefill(self, i: int, job: _PrefillJob, first) -> None:
        slot = self._slot[i]
        self._pstate, self._token, self._kdata = self._bind(
            self._pstate, self._token, self._kdata,
            jnp.asarray([i], jnp.int32), jnp.asarray(self._rows[i][None]),
            jnp.asarray([job.L], jnp.int32), first,
            self._kds_for([job.seed], 1),
        )
        self._pos[i] = job.L
        slot.first = first
        slot.prefill = None
        if job.poisoned:
            self._fault_fired.add(slot.rid)
            self._apply_paged_poison(i)
        else:
            self._register_prompt(i, job.prompt)

    def _extend_paged(self) -> None:
        """Lazy decode-page extension before each segment: make sure every
        decoding slot's table covers the rows this segment will write.
        Arena exhaustion preempts the latest-admitted other slot — its
        request re-queues and re-primes later with its own seed (identical
        tokens), and the earliest admission is never the victim, so the pool
        always makes progress."""
        if not self.paged:
            return
        t0 = self._clock()
        for i in range(self.slots):
            slot = self._slot[i]
            if not slot.active or slot.prefill is not None:
                continue
            needed = min(
                # span, not segment: a speculative round writes up to
                # draft_k+1 rows per step (DESIGN.md §13)
                -(-(self._pos[i] + self._span) // self._layout.page),
                self._layout.n_pages,
            )
            cur = self._slot_npages[i]
            if needed <= cur:
                continue
            ids = self._alloc_or_preempt(needed - cur, protect=i)
            self._rows[i][cur:needed] = ids
            self._slot_blocks[i] += list(ids)
            self._slot_private[i] += list(ids)
            self._slot_npages[i] = needed
            self._rebind_row(i)
        self._admit_s += self._clock() - t0

    def _alloc_or_preempt(self, n: int, protect: int) -> list:
        got = self._alloc.alloc(n)
        while got is None:
            cands = [j for j, s in enumerate(self._slot) if s.active and j != protect]
            if not cands:
                raise RuntimeError(
                    "paged arena exhausted with nothing left to preempt "
                    "(submit-time worst-case check should make this unreachable)"
                )
            victim = max(cands, key=lambda j: (self._slot[j].admit_s, j))
            self._preempt(victim)
            got = self._alloc.alloc(n)
        ids, scrub = got
        if scrub:
            self._zero_ids(scrub)
        return ids

    def _preempt(self, j: int) -> None:
        """Evict slot ``j`` mid-flight: free its blocks and re-queue its
        request.  Re-admission re-primes from the prompt with the request's
        own seed, so the eventual tokens are identical to an uninterrupted
        run — preemption changes *when*, never *what*."""
        slot = self._slot[j]
        rid, req = slot.rid, slot.req
        self._release_slot_pages(j)
        self._slot[j] = _Slot()
        self._counters["preempted"] += 1
        bisect.insort(self._queue, (req.arrival_s, rid, req))

    def _rebind_row(self, i: int) -> None:
        self._pstate = self._rebind(
            self._pstate, jnp.asarray([i], jnp.int32),
            jnp.asarray(self._rows[i][None]),
            jnp.asarray([self._pos[i]], jnp.int32),
        )

    def _release_slot_pages(self, i: int) -> None:
        """Return slot ``i``'s blocks to the allocator (hashed blocks park in
        the cached pool keeping their bytes; unhashed dead blocks are zeroed
        on the spot) and detach its table back to scratch."""
        blocks = self._slot_blocks[i]
        if blocks:
            dead = self._alloc.free(blocks)
            if dead:
                self._zero_ids(dead)
        self._slot_blocks[i] = []
        self._slot_private[i] = []
        self._slot_npages[i] = 0
        self._rows[i][:] = self._layout.scratch_block(i)
        self._pos[i] = 0
        self._pstate = self._resetp(
            self._pstate, jnp.int32(i), jnp.int32(self._layout.scratch_block(i))
        )

    def _apply_paged_poison(self, i: int) -> None:
        """§9 cache poisoning ported to the paged layout: NaN the slot's
        first PRIVATE block.  A fully prefix-shared prompt owns none, so one
        is privatized first (COW) — poison never reaches a block another
        request reads, keeping the blast radius at one request even under
        sharing.  The block's hash registration (if any) is dropped so no
        future prompt can match into the poisoned bytes."""
        if not self._slot_blocks[i]:
            return
        if self._slot_private[i]:
            blk = self._slot_private[i][0]
        else:
            [blk] = self._alloc_or_preempt(1, protect=i)
            old = int(self._rows[i][0])
            self._pstate = self._copyb(self._pstate, jnp.int32(old), jnp.int32(blk))
            self._rows[i][0] = blk
            bl = self._slot_blocks[i]
            bl[bl.index(old)] = blk
            self._slot_private[i].insert(0, blk)
            dead = self._alloc.free([old])
            if dead:
                self._zero_ids(dead)
            self._rebind_row(i)
        dead = self._alloc.forget(blk)
        if dead:
            self._zero_ids(dead)
        self._pstate = self._poisonb(self._pstate, jnp.int32(blk))

    def _inject_admission_faults(self, free: List[int], picked) -> None:
        """Apply the seeded fault plan to this admission round: admission
        stalls (slow-host model) and per-request slot-cache NaN poisoning
        (``models.cache.poison_slot``).  ``cache_nan_once`` makes a rid's
        fault fire only on its first admission, so its bounded dense retry
        runs clean; ``False`` re-fires on the retry, modelling a persistent
        fault the bounded retry cannot outrun."""
        f = self.eng.sc.faults
        if f is None:
            return
        t0 = self._clock()
        for i, (rid, req) in zip(free, picked):
            if f.wants_stall(rid):
                self._sleep(f.stall_s)
            if f.wants_cache_nan(rid) and (
                not f.cache_nan_once or rid not in self._fault_fired
            ):
                if self.paged:
                    if self._slot[i].prefill is not None:
                        # chunked admission: the chunks would overwrite poison
                        # injected now — the job carries the fault plan and
                        # fires it at completion (_complete_prefill)
                        continue
                    self._fault_fired.add(rid)
                    self._apply_paged_poison(i)
                else:
                    self._fault_fired.add(rid)
                    self._cache = self._poison(self._cache, jnp.int32(i))
        self._admit_s += self._clock() - t0

    def _stall_wait(self, secs: float) -> None:
        """Sleep ``secs`` (possibly inf — a hang) in small interruptible
        chunks, bailing the moment :meth:`abort` fires.  This is what makes
        an injected device stall escapable: the watchdog's abort lands
        between chunks instead of behind one long uninterruptible sleep."""
        t0 = self._clock()
        while self._abort_status is None:
            left = secs - (self._clock() - t0)
            if left <= 0:
                return
            self._sleep(min(left, 0.02) if math.isfinite(left) else 0.02)

    def _inject_decode_stall(self, active_idx: List[int]) -> None:
        """Seeded decode-segment stall/hang injection (DESIGN.md §12): if any
        active rid is selected by the fault plan, the segment dispatch is
        preceded by a host-visible stall — finite (``decode_stall_s``, the
        slow-device model) or infinite (``decode_hang_rids``, the hung-device
        model that only the watchdog's abort can end).  One-shot per rid by
        default (``decode_stall_once``), so the bounded re-queue after a
        watchdog abort runs clean — exactly like ``cache_nan_once``."""
        f = self.eng.sc.faults
        if f is None or not f.stalls_decode():
            return
        for i in active_idx:
            rid = self._slot[i].rid
            if f.decode_stall_once and rid in self._stall_fired:
                continue
            hang = f.wants_decode_hang(rid)
            if hang or f.wants_decode_stall(rid):
                self._stall_fired.add(rid)
                self._stall_wait(math.inf if hang else f.decode_stall_s)
                if self._abort_status is not None:
                    return

    def _abort_epilogue(self, now: float) -> None:
        """The fail-fast exit path: deal with every in-flight slot, then
        clear the flag so the next ``run`` starts clean.  First abort per
        rid re-queues it (same seed => the re-executed stream is
        bit-identical, consumers just see the tail late); second abort is
        terminal ``_abort_status`` — the retry is bounded, never a loop."""
        status = self._abort_status or Status.STALLED
        for i, slot in enumerate(self._slot):
            if not slot.active:
                continue
            if slot.rid in self._stall_retried:
                self._counters["stalled"] += 1
                self._retire(i, now, status)
            else:
                self._stall_retried.add(slot.rid)
                rid, req = slot.rid, slot.req
                if self.paged:
                    self._release_slot_pages(i)
                self._slot[i] = _Slot()
                self._counters["preempted"] += 1
                bisect.insort(self._queue, (req.arrival_s, rid, req))
        self._abort_status = None

    def _pop_arrived(self, k: int, now: float) -> list:
        """Take up to ``k`` queued requests whose arrival time has passed:
        highest priority first, earliest arrival breaking ties (a strict
        FIFO-by-submit pop would head-of-line block behind a queue head
        whose ``arrival_s`` is still in the future).  The queue is
        arrival-sorted, so the arrived set is a front prefix.  Requests
        whose queue wait already blew their deadline are shed here as
        TIMEOUT — priming a request that cannot finish in time would only
        steal a slot from one that can."""
        n = 0
        while n < len(self._queue) and self._queue[n][0] <= now:
            n += 1
        arrived, ready = self._queue[:n], []
        del self._queue[:n]
        for entry in arrived:
            _, rid, req = entry
            if req.deadline_s is not None and now > req.arrival_s + req.deadline_s:
                self._finish_unadmitted(rid, req, Status.TIMEOUT, finish=now)
                self._counters["timed_out"] += 1
                continue
            ready.append(entry)
        ready.sort(key=lambda e: (-e[2].priority, e[0], e[1]))
        take, leftover = ready[:k], ready[k:]
        for e in leftover:  # back into arrival order for the next round
            bisect.insort(self._queue, e)
        return [(rid, req) for _, rid, req in take]

    def _note_emission(self, slot: _Slot, n_before: int, t: float) -> None:
        """Record an ITL sample for this sync's emission event.  Tokens that
        surface together at one sync were observable at the same wall-clock
        instant, so the event contributes exactly ONE interval sample —
        ``t - last_emit_t`` — not ``emitted`` copies of its average, and
        nothing for same-instant followers (spreading one gap uniformly over
        a variable 1..k+1 speculative emission would make the percentiles
        meaningless).  The stream's first-ever emission only sets the
        baseline (TTFT owns the first token).  A ``_fail_slot`` truncation
        can shrink ``tokens`` below ``n_before`` — that is not an
        emission."""
        emitted = (len(slot.tokens) if slot.tokens is not None else 0) - n_before
        if emitted <= 0:
            return
        if not math.isnan(slot.last_emit_t):
            self._itl.append(t - slot.last_emit_t)
        slot.last_emit_t = t

    def _retire(self, i: int, now: float, status: Status = Status.OK) -> Completion:
        slot = self._slot[i]
        if status is Status.OK and slot.rid in self._fallback_rids:
            status = Status.FAILED_FALLBACK_OK
        done = Completion(
            rid=slot.rid,
            tokens=np.asarray(slot.tokens, np.int32),
            arrival_s=slot.arrival_s,
            admit_s=slot.admit_s,
            finish_s=now,
            status=status,
            ttft_s=slot.ttft_s,
        )
        self._completions[slot.rid] = done
        self._cancel.discard(slot.rid)
        if self.paged:
            self._release_slot_pages(i)
        self._slot[i] = _Slot()
        return done

    def _fail_slot(self, i: int, now: float) -> None:
        """Slot ``i`` tripped the non-finite guard.  Under active packed
        weights the pack is quarantined (the corrupt bytes may be anywhere
        in it — DESIGN.md §9) and the whole pool falls back dense.  The
        request gets ONE re-admission, re-primed from its prompt with its
        own seed so the retry's tokens are bit-identical to a clean dense
        run; a second trip is terminal FAILED — never an unbounded loop."""
        slot = self._slot[i]
        rid, req = slot.rid, slot.req
        if self.eng.packed_active and self.eng.quarantine_packed():
            self._counters["quarantined"] += 1
        if rid in self._retried:
            self._counters["failed"] += 1
            self._retire(i, now, Status.FAILED)
            return
        self._retried.add(rid)
        self._fallback_rids.add(rid)
        self._counters["fallback"] += 1
        if self.paged:
            # the poisoned private block dies unhashed here and is zeroed —
            # shared blocks just drop a reference, their bytes stay clean
            self._release_slot_pages(i)
        self._slot[i] = _Slot()  # slot cache is replaced wholesale on re-admission
        bisect.insort(self._queue, (req.arrival_s, rid, req))

    # -- run loop -------------------------------------------------------------

    def run(
        self,
        requests: Optional[List[Request]] = None,
        on_sync: Optional[Callable[["Scheduler"], None]] = None,
    ) -> Dict[int, Completion]:
        """Drain the queue (plus ``requests``), honouring arrival times.
        Returns ``{rid: Completion}`` — every submitted rid appears, whatever
        its terminal status; aggregate numbers via :meth:`stats`.
        ``on_sync`` (if given) fires after each segment sync — the hook
        tests use to cancel in-flight requests or advance an injected
        clock at a deterministic point."""
        self._maybe_reset()
        for r in requests or []:
            self.submit(r)
        t_start = self._clock()

        def now() -> float:
            return self._clock() - t_start

        self._run_now = now
        try:
            while self._queue or any(s.active for s in self._slot):
                if self._abort_status is not None:
                    self._abort_epilogue(now())
                    break
                if self._draining and not any(s.active for s in self._slot):
                    break  # drained: queued requests survive for the next run
                # admission: coalesce this round's arrived requests into free slots
                t = now()
                free = [i for i, s in enumerate(self._slot) if not s.active]
                if free and self._queue and not self._draining:
                    picked = self._pop_arrived(len(free), t)
                    if picked:
                        if self.paged:
                            admitted = self._admit_paged(free[: len(picked)], picked, t)
                            if admitted:
                                self._inject_admission_faults(
                                    [i for i, _, _ in admitted],
                                    [(rid, req) for _, rid, req in admitted],
                                )
                        else:
                            if self.admission == "batched" and self.eng.batched_prefill:
                                self._admit_batched(free[: len(picked)], picked, t)
                            else:
                                for i, (rid, req) in zip(free, picked):
                                    self._admit(i, rid, req, t)
                            self._inject_admission_faults(free, picked)
                if self.paged:
                    # one prefill chunk per admitting slot, then make sure
                    # every decoding slot's table covers this segment's rows
                    self._step_prefills()
                    self._extend_paged()
                active_idx = [i for i, s in enumerate(self._slot) if s.active]
                if not active_idx:
                    if not self._queue:
                        continue  # drained; loop condition exits
                    # nothing in flight: sleep until the next request arrives
                    # (the queue head, since the queue is arrival-sorted) —
                    # chunked so drain()/abort() from another thread can
                    # interrupt an arbitrarily long idle wait
                    wait = self._queue[0][0] - now()
                    while (
                        wait > 0
                        and self._abort_status is None
                        and not self._draining
                    ):
                        self._sleep(min(wait, 0.02))
                        wait = self._queue[0][0] - now()
                    continue
                # seeded decode stall/hang injection rides immediately before
                # the dispatch; a watchdog abort fired during the stall exits
                # here instead of dispatching the segment
                self._inject_decode_stall(active_idx)
                if self._abort_status is not None:
                    self._abort_epilogue(now())
                    break
                # decode one segment and sync once: tokens + integrity flags
                # come back in the same device_get — the guard costs no
                # extra host transfer
                t0 = self._clock()
                if self.speculative:
                    # each scan step is one draft/verify ROUND: grids come
                    # back S-wide (S = draft_k + 1) with per-round accepted
                    # counts — the host consumes tokens[r, i, :nem[r, i]]
                    if self.paged:
                        (
                            self._token, self._kdata, self._pstate,
                            toks, nems, okg,
                        ) = self._seg_spec_paged(
                            self.eng.params, self._token, self._kdata,
                            self._pstate, self.segment,
                            bool(self.eng.quarantined),
                        )
                    else:
                        (
                            self._token, self._kdata, self._cache,
                            toks, nems, okg,
                        ) = self._seg_spec(
                            self.eng.params, self._token, self._kdata,
                            self._cache, self.segment,
                            bool(self.eng.quarantined),
                        )
                    # (segment, slots, S), (segment, slots), (segment, slots, S)
                    toks_np, nem_np, ok_np = jax.device_get((toks, nems, okg))
                else:
                    if self.paged:
                        self._token, self._kdata, self._pstate, toks, okg = self._seg_paged(
                            self.eng.params, self._token, self._kdata, self._pstate,
                            self.segment, bool(self.eng.quarantined),
                        )
                    else:
                        self._token, self._kdata, self._cache, toks, okg = self._seg(
                            self.eng.params, self._token, self._kdata, self._cache,
                            self.segment, bool(self.eng.quarantined),
                        )
                    toks_np, ok_np = jax.device_get((toks, okg))  # (segment, slots) x2
                    # present the non-speculative grids as degenerate S=1
                    # rounds so one consumption loop serves both modes
                    toks_np = toks_np[:, :, None]
                    ok_np = ok_np[:, :, None]
                    nem_np = np.ones(toks_np.shape[:2], np.int64)
                self._decode_s += self._clock() - t0
                self._seg_steps += self.segment
                self._active_slot_steps += len(active_idx) * self.segment
                if self.paged:
                    # each slot advanced by its own accepted-token total
                    # (uniformly ``segment`` when not speculative)
                    self._pos = [
                        p + int(nem_np[:, i].sum()) for i, p in enumerate(self._pos)
                    ]
                self._kv_active_acc += len(active_idx)
                self._kv_used_acc += (
                    self._alloc.live_blocks * self._block_bytes
                    if self.paged
                    else len(active_idx) * self._slot_bytes
                )
                t = now()
                for i in active_idx:
                    slot = self._slot[i]
                    n_before = len(slot.tokens) if slot.tokens is not None else 0
                    if slot.prefill is not None:
                        # mid-chunked-prefill: no tokens yet; only deadlines
                        # and cancellation apply at this sync
                        if slot.rid in self._cancel:
                            self._counters["cancelled"] += 1
                            self._retire(i, t, Status.CANCELLED)
                        elif t > slot.deadline:
                            self._counters["timed_out"] += 1
                            self._retire(i, t, Status.TIMEOUT)
                        continue
                    if slot.rid in self._cancel:
                        self._counters["cancelled"] += 1
                        self._retire(i, t, Status.CANCELLED)
                        continue
                    if slot.first is not None:
                        # deferred first token: EOS/budget checked here, at the
                        # segment sync, never in the admission path
                        first = int(np.asarray(slot.first).reshape(-1)[0])
                        slot.tokens.append(first)
                        slot.first = None
                        slot.ttft_s = t - slot.arrival_s
                        if slot.remaining == 0 or (
                            slot.eos_id is not None and first == slot.eos_id
                        ):
                            self._note_emission(slot, n_before, t)
                            self._retire(i, t)
                            continue
                    stop = False
                    for step in range(self.segment):
                        if stop or slot.remaining <= 0:
                            break
                        used = 0
                        for j in range(int(nem_np[step, i])):
                            if not ok_np[step, i, j]:
                                # non-finite logits: every token from this
                                # position on is garbage — truncate and fail
                                self._fail_slot(i, t)
                                stop = True
                                break
                            tok = toks_np[step, i, j]
                            slot.tokens.append(int(tok))
                            slot.remaining -= 1
                            used += 1
                            if (
                                slot.eos_id is not None and tok == slot.eos_id
                            ) or slot.remaining == 0:
                                self._retire(i, t)
                                stop = True
                                break
                        if self.speculative and used:
                            # acceptance accounting per consumed round: the
                            # round proposed draft_k tokens and used-1 of
                            # them survived verification (the first emission
                            # is the round's pending token, not a draft)
                            self._counters["spec_proposed"] += self._draft_k
                            self._counters["spec_accepted"] += used - 1
                    self._note_emission(slot, n_before, t)
                    slot = self._slot[i]  # may have retired/failed above
                    if slot.active and t > slot.deadline:
                        self._counters["timed_out"] += 1
                        self._retire(i, t, Status.TIMEOUT)
                if on_sync is not None:
                    on_sync(self)
        finally:
            self._run_now = None
        self._ran = True
        return self._completions

    def stats(self) -> Dict[str, float]:
        """Aggregate serve metrics for the most recent :meth:`run` epoch.
        Latency/TTFT percentiles are computed over the completions that have
        the timing (NaN entries — never-admitted or never-emitted requests —
        are excluded) and are NaN when none do: an empty run must not read
        as an infinitely fast one.  The counters account every terminal
        path; ``quarantined`` counts pack-quarantine transitions (0 or 1 per
        engine lifetime)."""
        done = sorted(self._completions.values(), key=lambda c: c.rid)
        lat = np.asarray([c.latency_s for c in done], np.float64)
        lat = lat[np.isfinite(lat)]
        ttft = np.asarray([c.ttft_s for c in done], np.float64)
        ttft = ttft[np.isfinite(ttft)]
        itl = np.asarray(self._itl, np.float64)
        itl = itl[np.isfinite(itl)]
        decoded = sum(max(len(c.tokens) - 1, 0) for c in done)
        busy = self._decode_s + self._admit_s

        def pct(a, q):
            return float(np.percentile(a, q)) if a.size else float("nan")

        out = {
            "requests": len(done),
            "decoded_tokens": decoded,
            "sustained_tok_per_s": decoded / max(busy, 1e-9),
            "decode_s": self._decode_s,
            "admit_s": self._admit_s,
            "latency_p50_s": pct(lat, 50),
            "latency_p95_s": pct(lat, 95),
            "latency_p99_s": pct(lat, 99),
            "ttft_p50_s": pct(ttft, 50),
            "ttft_p95_s": pct(ttft, 95),
            "ttft_p99_s": pct(ttft, 99),
            "itl_p50_s": pct(itl, 50),
            "itl_p95_s": pct(itl, 95),
            "itl_p99_s": pct(itl, 99),
            "slot_occupancy": self._active_slot_steps / max(self.slots * self._seg_steps, 1),
            # unified accounting (DESIGN.md §13): accepted tokens over decode
            # wall time — the same definition Engine.generate reports, so
            # speculative and plain runs compare on one axis
            "tok_per_s": tok_per_s(decoded, self._decode_s),
            "acceptance_rate": acceptance_rate(
                self._counters["spec_accepted"], self._counters["spec_proposed"]
            ),
        }
        # cache observability (DESIGN.md §11) — always present, NaN where the
        # gauge doesn't apply (slot-pool mode, or an epoch with no traffic),
        # so an empty run never reads as an infinitely cheap one
        if self.paged:
            h0, l0, c0, e0 = self._alloc_snap
            hits = self._alloc.hits - h0
            lookups = self._alloc.lookups - l0
            out.update({
                "kv_pool_bytes": float(self._arena_bytes),
                "kv_block_bytes": float(self._block_bytes),
                "blocks_total": float(self._layout.user_blocks),
                "blocks_live": float(self._alloc.live_blocks),
                "blocks_free": float(self._alloc.free_blocks),
                "blocks_cached": float(self._alloc.cached_blocks),
                "prefix_lookups": float(lookups),
                "prefix_hits": float(hits),
                "prefix_hit_rate": hits / lookups if lookups else float("nan"),
                "cow_copies": float(self._alloc.cow_copies - c0),
                "cache_evictions": float(self._alloc.evictions - e0),
            })
        else:
            out.update({
                "kv_pool_bytes": float(self._slot_bytes * self.slots),
                "kv_block_bytes": float(self._slot_bytes),
                "blocks_total": float("nan"),
                "blocks_live": float("nan"),
                "blocks_free": float("nan"),
                "blocks_cached": float("nan"),
                "prefix_lookups": 0.0,
                "prefix_hits": 0.0,
                "prefix_hit_rate": float("nan"),
                "cow_copies": 0.0,
                "cache_evictions": 0.0,
            })
        out["hbm_bytes_per_active_request"] = (
            self._kv_used_acc / self._kv_active_acc
            if self._kv_active_acc
            else float("nan")
        )
        out.update(self._counters)
        return out
