"""Topology-agnostic sharded checkpointing.

Leaves are saved as individual ``.npy`` files keyed by their pytree path plus
a JSON manifest; restore re-shards onto *whatever mesh the restoring job
runs* (elastic: a 2-pod checkpoint restores onto 1 pod and vice versa,
because the on-disk format is logical, not device-local).

Writes are atomic (tmp dir + rename) and optionally asynchronous; a retention
policy keeps the newest K steps.  This is the orbax-shaped subset the trainer
needs, with zero external dependencies.

Integrity (DESIGN.md §9): the manifest records per-leaf CRC32 and byte
counts at save; restore re-verifies them, so a truncated or bit-flipped leaf
file raises a clear ``ValueError`` instead of silently yielding garbage
params.  Manifests written before this field existed still restore (no CRC
to check), so old checkpoints stay readable.
"""

from __future__ import annotations

import json
import shutil
import struct
import threading
import zlib
from pathlib import Path
from typing import Any, BinaryIO, List, Optional, Tuple

import jax
import numpy as np

__all__ = [
    "save",
    "restore",
    "latest_step",
    "Checkpointer",
    "append_record",
    "read_records",
]


# --------------------------------------------------------------------------
# CRC32-framed append-only record log (DESIGN.md §9, §12)
#
# The same integrity framing the checkpoint manifest applies per leaf file,
# packaged for *streams*: each record is ``<u32 length><u32 crc32>payload``.
# A crash can only ever leave a torn record at the tail — the reader stops
# cleanly at the first truncated or CRC-corrupt frame and reports how many
# bytes were good, so a writer reopening after a crash truncates back to the
# clean prefix and appends from there.  ``serve/journal.py`` builds the
# crash-safe request journal on top of this.
# --------------------------------------------------------------------------

_REC_HDR = struct.Struct("<II")  # payload byte length, crc32(payload)


def append_record(fh: BinaryIO, payload: bytes) -> None:
    """Append one CRC32-framed record.  Durability is the caller's business:
    this writes into the file object's buffer — flush/fsync where the
    consistency contract demands it (the journal does so at segment syncs)."""
    fh.write(_REC_HDR.pack(len(payload), zlib.crc32(payload)))
    fh.write(payload)


def read_records(path: str | Path) -> Tuple[List[bytes], int, bool]:
    """Read a CRC32-framed record log written by :func:`append_record`.

    Returns ``(payloads, clean_bytes, clean)``: every record up to (not
    including) the first truncated or CRC-corrupt frame, the byte offset of
    the end of the last good record, and whether the whole file was good.
    A torn tail is the *expected* crash artifact, not an error — the caller
    truncates to ``clean_bytes`` before appending again."""
    raw = Path(path).read_bytes()
    out: List[bytes] = []
    off = 0
    while off < len(raw):
        if off + _REC_HDR.size > len(raw):
            return out, off, False  # torn header
        n, crc = _REC_HDR.unpack_from(raw, off)
        payload = raw[off + _REC_HDR.size : off + _REC_HDR.size + n]
        if len(payload) < n:
            return out, off, False  # torn payload
        if zlib.crc32(payload) != crc:
            # a bit flip mid-file ends replay there too: every record after
            # it is untrustworthy (framing itself may be corrupt)
            return out, off, False
        out.append(payload)
        off += _REC_HDR.size + n
    return out, off, True


def _flatten(tree) -> dict:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(
            str(getattr(p, "key", getattr(p, "idx", getattr(p, "name", p)))) for p in path
        )
        flat[key] = leaf
    return flat


def save(path: str | Path, step: int, tree: Any) -> Path:
    """Atomically save a pytree under ``path/step_<N>/``."""
    path = Path(path)
    final = path / f"step_{step:08d}"
    tmp = path / f".tmp_step_{step:08d}"
    if tmp.exists():
        shutil.rmtree(tmp)
    tmp.mkdir(parents=True)
    flat = _flatten(tree)
    manifest = {}
    for i, (key, leaf) in enumerate(sorted(flat.items())):
        arr = np.asarray(jax.device_get(leaf))
        fname = f"leaf_{i:05d}.npy"
        np.save(tmp / fname, arr)
        raw = (tmp / fname).read_bytes()
        manifest[key] = {
            "file": fname,
            "shape": list(arr.shape),
            "dtype": str(arr.dtype),
            "nbytes": len(raw),
            "crc32": zlib.crc32(raw),
        }
    (tmp / "manifest.json").write_text(json.dumps({"step": step, "leaves": manifest}))
    if final.exists():
        shutil.rmtree(final)
    tmp.rename(final)
    return final


def latest_step(path: str | Path) -> Optional[int]:
    path = Path(path)
    if not path.exists():
        return None
    steps = [int(p.name[5:]) for p in path.glob("step_*") if (p / "manifest.json").exists()]
    return max(steps) if steps else None


def _load_leaf(d: Path, key: str, entry: dict) -> np.ndarray:
    """Load one leaf file, verifying it against its manifest entry.  Every
    corruption mode has a distinct, named error: a missing/truncated file,
    a CRC mismatch (bit flip), or a decoded array whose shape/dtype disagree
    with what was saved.  Pre-CRC manifests (no ``crc32``/``nbytes`` keys)
    skip the byte checks but still verify shape/dtype."""
    f = d / entry["file"]
    if not f.exists():
        raise ValueError(f"checkpoint leaf {key!r}: file {entry['file']} is missing")
    raw = f.read_bytes()
    if "nbytes" in entry and len(raw) != entry["nbytes"]:
        raise ValueError(
            f"checkpoint leaf {key!r}: file {entry['file']} is truncated or padded "
            f"({len(raw)} bytes, manifest says {entry['nbytes']})"
        )
    if "crc32" in entry and zlib.crc32(raw) != entry["crc32"]:
        raise ValueError(
            f"checkpoint leaf {key!r}: CRC mismatch in {entry['file']} "
            f"(on-disk corruption; re-fetch or fall back to an older step)"
        )
    try:
        arr = np.load(f)
    except Exception as e:
        raise ValueError(f"checkpoint leaf {key!r}: undecodable npy {entry['file']}: {e}") from e
    if list(arr.shape) != list(entry["shape"]) or str(arr.dtype) != entry["dtype"]:
        raise ValueError(
            f"checkpoint leaf {key!r}: decoded {arr.shape}/{arr.dtype}, manifest "
            f"says {tuple(entry['shape'])}/{entry['dtype']}"
        )
    return arr


def restore(path: str | Path, step: int, like: Any, shardings: Any = None) -> Any:
    """Restore into the structure of ``like``; place per ``shardings`` if given
    (this is where elastic re-sharding happens — the mesh of the restoring
    job decides placement, not the mesh that saved)."""
    d = Path(path) / f"step_{step:08d}"
    manifest = json.loads((d / "manifest.json").read_text())["leaves"]
    flat_like = _flatten(like)
    flat_shard = _flatten(shardings) if shardings is not None else {}
    out = {}
    for key in flat_like:
        if key not in manifest:
            raise KeyError(f"checkpoint missing leaf {key!r}")
        arr = _load_leaf(d, key, manifest[key])
        if key in flat_shard and flat_shard[key] is not None:
            out[key] = jax.device_put(arr, flat_shard[key])
        else:
            out[key] = jax.numpy.asarray(arr)
    # rebuild tree in `like`'s structure
    leaves, treedef = jax.tree_util.tree_flatten(like)
    keys = sorted(_flatten(like).keys())
    key_order = list(_flatten(like).keys())
    return jax.tree_util.tree_unflatten(treedef, [out[k] for k in key_order])


class Checkpointer:
    """Async checkpoint manager with retention."""

    def __init__(self, path: str | Path, keep: int = 3, async_save: bool = True):
        self.path = Path(path)
        self.keep = keep
        self.async_save = async_save
        self._thread: Optional[threading.Thread] = None

    def _gc(self):
        steps = sorted(
            int(p.name[5:]) for p in self.path.glob("step_*") if (p / "manifest.json").exists()
        )
        for s in steps[: -self.keep]:
            shutil.rmtree(self.path / f"step_{s:08d}", ignore_errors=True)

    def save(self, step: int, tree: Any):
        tree = jax.device_get(tree)  # snapshot before the step mutates state

        def work():
            save(self.path, step, tree)
            self._gc()

        self.wait()
        if self.async_save:
            self._thread = threading.Thread(target=work, daemon=True)
            self._thread.start()
        else:
            work()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def restore_latest(self, like: Any, shardings: Any = None) -> Tuple[Optional[int], Any]:
        step = latest_step(self.path)
        if step is None:
            return None, like
        return step, restore(self.path, step, like, shardings)
