"""Property-based tests for the crash-safe journal codec (DESIGN.md §12):
CRC32 record framing (checkpoint/ckpt.py append_record/read_records) and the
journal replay built on it (serve/journal.py).

Invariants pinned here:
  * append/read roundtrip is exact for arbitrary byte payloads;
  * a truncated tail (crash mid-write) is detected — the reader returns the
    clean prefix and the exact byte offset to truncate back to;
  * a bit flip anywhere in a record ends reading cleanly at the previous
    record, never raises, never yields corrupt payloads;
  * replay is idempotent and pure: same file, same state, every time.

Uses the optional-hypothesis shim (tests/hypothesis_compat.py): with
hypothesis installed (CI) the @given tests fuzz; without it they skip and
the example-based edge tests below still pin the invariants.
"""

import json
import tempfile
from pathlib import Path

import numpy as np
from hypothesis_compat import given, settings, st

from repro.checkpoint.ckpt import append_record, read_records
from repro.serve.journal import Journal, replay
from repro.serve.scheduler import Request, Status


def _write(path, payloads):
    with open(path, "wb") as fh:
        for p in payloads:
            append_record(fh, p)
    return path


# ---------------------------------------------------------------------------
# framing roundtrip + torn/corrupt tails
# ---------------------------------------------------------------------------


@given(
    payloads=st.lists(st.binary(min_size=0, max_size=200), max_size=20),
)
@settings(max_examples=60, deadline=None)
def test_roundtrip(payloads):
  with tempfile.TemporaryDirectory() as d:
    path = Path(d) / "log"
    _write(path, payloads)
    out, clean_bytes, clean = read_records(path)
    assert out == payloads
    assert clean
    assert clean_bytes == path.stat().st_size


@given(
    payloads=st.lists(st.binary(min_size=0, max_size=64), min_size=1, max_size=10),
    cut=st.integers(1, 1000),
)
@settings(max_examples=60, deadline=None)
def test_truncated_tail_detected(payloads, cut):
  """Chop any number of bytes off the end: the reader must return a prefix
  of the written records plus the offset where the file is still whole."""
  with tempfile.TemporaryDirectory() as d:
    path = Path(d) / "log"
    _write(path, payloads)
    raw = path.read_bytes()
    cut = min(cut, len(raw))
    path.write_bytes(raw[: len(raw) - cut])
    out, clean_bytes, clean = read_records(path)
    assert out == payloads[: len(out)]  # strict prefix of what was written
    if cut > 0:
        assert not clean
    assert clean_bytes <= len(raw) - cut
    # the contract recovery relies on: truncating to clean_bytes and
    # appending yields a readable log again
    with open(path, "r+b") as fh:
        fh.truncate(clean_bytes)
        append_record(fh, b"after-crash")
    out2, _, clean2 = read_records(path)
    assert clean2 and out2 == out + [b"after-crash"]


@given(
    payloads=st.lists(st.binary(min_size=1, max_size=64), min_size=1, max_size=10),
    flip_at=st.integers(0, 10_000),
    flip_bit=st.integers(0, 7),
)
@settings(max_examples=60, deadline=None)
def test_bitflip_detected(payloads, flip_at, flip_bit):
  """Flip one bit anywhere: reading never raises and every payload
  returned is byte-identical to one that was written, in order."""
  with tempfile.TemporaryDirectory() as d:
    path = Path(d) / "log"
    _write(path, payloads)
    raw = bytearray(path.read_bytes())
    i = flip_at % len(raw)
    raw[i] ^= 1 << flip_bit
    path.write_bytes(bytes(raw))
    out, clean_bytes, _ = read_records(path)
    # the flip can land in a length header and make later bytes parse as a
    # coincidentally-valid frame; CRC makes that astronomically unlikely,
    # and for a *prefix* guarantee it can't happen before the flip offset
    assert out[: len(out)] == payloads[: len(out)] or clean_bytes <= i


@given(
    payloads=st.lists(st.binary(min_size=0, max_size=64), max_size=10),
)
@settings(max_examples=30, deadline=None)
def test_read_idempotent(payloads):
  with tempfile.TemporaryDirectory() as d:
    path = Path(d) / "log"
    _write(path, payloads)
    assert read_records(path) == read_records(path)


# ---------------------------------------------------------------------------
# journal replay properties
# ---------------------------------------------------------------------------


def _mk_journal(path, events):
    """Build a journal from an abstract event list.  Events:
    ("submit", rid), ("tokens", rid, [..]), ("retire", rid, n),
    ("recover",)."""
    j = Journal(path)
    for ev in events:
        if ev[0] == "submit":
            j.append(Journal.submit_record(
                ev[1], Request(prompt=np.asarray([1, 2, 3], np.int32),
                               max_new=8, seed=ev[1])
            ))
        elif ev[0] == "tokens":
            j.append(Journal.tokens_record(ev[1], ev[2]))
        elif ev[0] == "retire":
            j.append(Journal.retire_record(ev[1], Status.OK, ev[2]))
        elif ev[0] == "recover":
            j.append({"t": "recover"})
    j.sync()
    j.close(clean=False)  # no close marker: models a crash
    return path


@given(
    rids=st.lists(st.integers(0, 5), min_size=1, max_size=6, unique=True),
    toks=st.lists(st.integers(0, 99), min_size=1, max_size=8),
    retire_first=st.booleans(),
)
@settings(max_examples=40, deadline=None)
def test_replay_idempotent_and_pure(rids, toks, retire_first):
  with tempfile.TemporaryDirectory() as d:
    path = Path(d) / "journal"
    events = [("submit", r) for r in rids]
    events += [("tokens", rids[0], toks)]
    if retire_first:
        events += [("retire", rids[0], len(toks))]
    _mk_journal(path, events)
    s1, s2 = replay(path), replay(path)
    assert sorted(s1.pending) == sorted(s2.pending)
    assert sorted(s1.completed) == sorted(s2.completed)
    assert s1.partial == s2.partial
    assert (s1.clean_bytes, s1.clean, s1.closed) == (s2.clean_bytes, s2.clean, s2.closed)
    if retire_first:
        assert rids[0] in s1.completed
        st_, t = s1.completed[rids[0]]
        assert list(t) == toks
    else:
        assert s1.partial[rids[0]] == toks
        assert rids[0] in s1.pending


# ---------------------------------------------------------------------------
# example-based edges (always run, shim or not)
# ---------------------------------------------------------------------------


def test_empty_log_reads_clean(tmp_path):
    path = tmp_path / "log"
    path.write_bytes(b"")
    assert read_records(path) == ([], 0, True)


def test_torn_header_example(tmp_path):
    path = tmp_path / "log"
    _write(path, [b"abc"])
    raw = path.read_bytes()
    path.write_bytes(raw + b"\x05\x00")  # 2 bytes of a next header
    out, clean_bytes, clean = read_records(path)
    assert out == [b"abc"] and not clean and clean_bytes == len(raw)


def test_crc_corrupt_payload_example(tmp_path):
    path = tmp_path / "log"
    _write(path, [b"abc", b"defg"])
    raw = bytearray(path.read_bytes())
    raw[-1] ^= 0xFF  # corrupt the last payload byte
    path.write_bytes(bytes(raw))
    out, _, clean = read_records(path)
    assert out == [b"abc"] and not clean


def test_recover_marker_resets_partials(tmp_path):
    """Post-crash re-execution restarts streams from token 0: the recover
    marker must stop replay from prepending pre-crash partial tokens."""
    path = tmp_path / "journal"
    _mk_journal(path, [
        ("submit", 0), ("submit", 1),
        ("tokens", 0, [1, 2, 3]), ("tokens", 1, [7]),
        ("retire", 1, 1),
        ("recover",),
        ("tokens", 0, [1, 2, 3, 4]),  # the re-executed (longer) stream
        ("retire", 0, 4),
    ])
    state = replay(path)
    assert sorted(state.completed) == [0, 1]
    _, t0 = state.completed[0]
    assert list(t0) == [1, 2, 3, 4]  # not [1,2,3] + [1,2,3,4]
    _, t1 = state.completed[1]
    assert list(t1) == [7]
    assert not state.closed  # crash artifact: no close marker


def test_tokens_for_unknown_rid_ignored(tmp_path):
    """A tokens/retire record whose submit died after the last fsync must be
    skipped — the journal can never prove more than it holds."""
    path = tmp_path / "journal"
    _mk_journal(path, [
        ("submit", 0),
        ("tokens", 7, [1, 2]),   # rid 7 was never submitted
        ("retire", 7, 2),
        ("tokens", 0, [5]),
    ])
    state = replay(path)
    assert sorted(state.pending) == [0]
    assert state.partial[0] == [5]
    assert not state.completed


def test_submit_record_roundtrips_request_fields(tmp_path):
    req = Request(prompt=np.asarray([4, 5, 6], np.int32), max_new=3,
                  eos_id=2, seed=9, deadline_s=1.5, priority=2)
    rec = Journal.submit_record(11, req)
    assert json.loads(json.dumps(rec)) == rec  # JSON-stable
    path = tmp_path / "journal"
    j = Journal(path)
    j.append(rec)
    j.sync()
    j.close()
    state = replay(path)
    got = state.pending[11]
    assert list(got.prompt) == [4, 5, 6]
    assert (got.max_new, got.eos_id, got.seed) == (3, 2, 9)
    assert (got.deadline_s, got.priority) == (1.5, 2)
    assert got.arrival_s == 0.0  # due immediately on recovery
    assert state.closed and state.clean
