from .sharding import (  # noqa: F401
    act_rules,
    batch_sharding,
    batch_shardings,
    param_sharding,
    params_shardings,
    serve_shardings,
)
