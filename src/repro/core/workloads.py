"""Benchmark workload definitions: ResNet-18 and MobileNetV1 layer shapes
(224x224 ImageNet), lowered to im2col GEMMs for the cycle simulator.

These mirror the SCALE-Sim topology files the paper used (Section V-C).
"""

from __future__ import annotations

from typing import List

from .simulator import Gemm, conv2d_gemm

__all__ = ["resnet18_gemms", "mobilenetv1_gemms"]


def resnet18_gemms() -> List[Gemm]:
    """ResNet-18, 224x224 input.  (out_h, out_w, in_ch, out_ch, k)."""
    layers = [
        # conv1: 7x7/2
        (112, 112, 3, 64, 7),
        # layer1: 2 blocks of [3x3 64 -> 3x3 64] @ 56
        (56, 56, 64, 64, 3), (56, 56, 64, 64, 3),
        (56, 56, 64, 64, 3), (56, 56, 64, 64, 3),
        # layer2: downsample block + identity block @ 28
        (28, 28, 64, 128, 3), (28, 28, 128, 128, 3), (28, 28, 64, 128, 1),
        (28, 28, 128, 128, 3), (28, 28, 128, 128, 3),
        # layer3 @ 14
        (14, 14, 128, 256, 3), (14, 14, 256, 256, 3), (14, 14, 128, 256, 1),
        (14, 14, 256, 256, 3), (14, 14, 256, 256, 3),
        # layer4 @ 7
        (7, 7, 256, 512, 3), (7, 7, 512, 512, 3), (7, 7, 256, 512, 1),
        (7, 7, 512, 512, 3), (7, 7, 512, 512, 3),
    ]
    gemms: List[Gemm] = []
    for i, (oh, ow, ic, oc, k) in enumerate(layers):
        gemms += conv2d_gemm(oh, ow, ic, oc, k, k, name=f"conv{i}")
    # final FC 512 -> 1000
    gemms.append(Gemm(B=1, K=512, C=1000, name="fc"))
    return gemms


def mobilenetv1_gemms() -> List[Gemm]:
    """MobileNetV1 1.0x, 224x224.  Depthwise layers lower to grouped GEMMs,
    but a 3x3 depthwise GEMM is K=9, C=1 per group — the paper (and
    SCALE-Sim) fold them as (out_pixels, 9, channels) depthwise blocks; we
    model each depthwise conv as one GEMM with K=9 and C=channels, which
    matches how a WS array processes channel-parallel depthwise filters.
    """
    # (out_hw, in_ch, out_ch, k, depthwise)
    layers = [
        (112, 3, 32, 3, False),
        (112, 32, 32, 3, True), (112, 32, 64, 1, False),
        (56, 64, 64, 3, True), (56, 64, 128, 1, False),
        (56, 128, 128, 3, True), (56, 128, 128, 1, False),
        (28, 128, 128, 3, True), (28, 128, 256, 1, False),
        (28, 256, 256, 3, True), (28, 256, 256, 1, False),
        (14, 256, 256, 3, True), (14, 256, 512, 1, False),
        # 5x repeated 512 dw+pw blocks @ 14
        (14, 512, 512, 3, True), (14, 512, 512, 1, False),
        (14, 512, 512, 3, True), (14, 512, 512, 1, False),
        (14, 512, 512, 3, True), (14, 512, 512, 1, False),
        (14, 512, 512, 3, True), (14, 512, 512, 1, False),
        (14, 512, 512, 3, True), (14, 512, 512, 1, False),
        (7, 512, 512, 3, True), (7, 512, 1024, 1, False),
        (7, 1024, 1024, 3, True), (7, 1024, 1024, 1, False),
    ]
    gemms: List[Gemm] = []
    for i, (hw, ic, oc, k, dw) in enumerate(layers):
        if dw:
            gemms.append(Gemm(B=hw * hw, K=k * k, C=oc, name=f"dw{i}"))
        else:
            gemms += conv2d_gemm(hw, hw, ic, oc, k, k, name=f"conv{i}")
    gemms.append(Gemm(B=1, K=1024, C=1000, name="fc"))
    return gemms
