"""End-to-end driver: train a ~100M-parameter LM with the VUSA pruning
schedule for a few hundred steps, with checkpointing and exact restart.

The default preset is CPU-sized; ``--preset full`` uses the paper-scale
vusa_edge config (~160M params) — the run used for EXPERIMENTS.md §Train.

Run:  PYTHONPATH=src python examples/train_sparse_lm.py --steps 200
"""

import argparse
import json
import time
from pathlib import Path

from repro.configs import get_config, get_smoke_config
from repro.train import TrainConfig, Trainer, TrainHParams


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--preset", default="full", choices=["smoke", "full"])
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--ckpt", default="experiments/train_run/ckpt")
    ap.add_argument("--out", default="experiments/train_run/metrics.json")
    args = ap.parse_args()

    cfg = get_config("vusa_edge") if args.preset == "full" else get_smoke_config("vusa_edge")
    n_params = cfg.param_count()
    print(f"arch {cfg.name}: ~{n_params/1e6:.0f}M params, target sparsity {cfg.sparsity:.0%}")

    tc = TrainConfig(
        steps=args.steps,
        global_batch=args.batch,
        seq_len=args.seq,
        token_range=256,  # learnable synthetic stream
        prune_begin=args.steps // 4,
        prune_end=3 * args.steps // 4,
        prune_every=max(args.steps // 40, 1),
        ckpt_dir=args.ckpt,
        ckpt_every=max(args.steps // 4, 10),
        log_every=10,
        hp=TrainHParams(lr=3e-4, warmup=args.steps // 10, total_steps=args.steps),
    )
    t0 = time.time()
    trainer = Trainer(cfg, tc)
    out = trainer.train()
    wall = time.time() - t0

    result = {
        "arch": cfg.name,
        "params_m": n_params / 1e6,
        "steps": out["steps_run"],
        "final_loss": out["final_loss"],
        "final_sparsity": out["sparsity"],
        "wall_s": wall,
        "tokens_per_s": out["steps_run"] * args.batch * args.seq / wall,
        "log": trainer.metrics_log,
    }
    Path(args.out).parent.mkdir(parents=True, exist_ok=True)
    Path(args.out).write_text(json.dumps(result, indent=1))
    print(
        f"done: {out['steps_run']} steps, loss {out['final_loss']:.3f}, "
        f"sparsity {out['sparsity']:.2%}, {result['tokens_per_s']:.0f} tok/s -> {args.out}"
    )


if __name__ == "__main__":
    main()
