"""VUSA-packed decode path for the dense LM family.

``pack_lm_mlps`` packs every layer's MLP matrices (the dominant weight bytes)
into the row-wise VUSA format; ``lm_decode_step_packed`` is a twin of
``families.lm_decode_step`` whose MLP matmuls run through the Pallas kernel.
Layer packs are stacked on a leading axis so the layer loop stays a scan.
"""

from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp
import numpy as np

from ..configs.base import ArchConfig
from ..kernels.ops import RowPackedLinear, apply_row_packed, pack_linear_rows
from ..models import families as F
from ..models.common import rms_norm

__all__ = ["pack_lm_mlps", "lm_decode_step_packed"]


def pack_lm_mlps(cfg: ArchConfig, params, m: int = 128, a: int = 16) -> Dict:
    """Pack per-layer MLP weights; returns stacked (L, ...) device arrays.

    Jobs are padded to the max across layers so the stack is rectangular
    (padded jobs are exact no-ops: value 0, position -1)."""
    layers = params["layers"]["ffn"]
    n_layers = cfg.n_layers
    packed = {"w_gate": [], "w_up": [], "w_down": []}
    for name in packed:
        for l in range(n_layers):
            w = np.asarray(layers[name][l])
            packed[name].append(pack_linear_rows(w, m=m, a=a))
    out = {}
    for name, packs in packed.items():
        smax = max(p.values.shape[2] for p in packs)

        def pad(p: RowPackedLinear):
            t, k, s = p.values.shape
            v = jnp.pad(p.values, ((0, 0), (0, 0), (0, smax - s)))
            q = jnp.pad(p.positions, ((0, 0), (0, 0), (0, smax - s)), constant_values=-1)
            return v, q

        vs, qs = zip(*(pad(p) for p in packs))
        out[name] = {
            "values": jnp.stack(vs),
            "positions": jnp.stack(qs),
            "k": packs[0].k,
            "c": packs[0].c,
            "m": packs[0].m,
            "a": a,
        }
    return out


def lm_decode_step_packed(params, packed, token, cache, cfg):
    """One-token decode with VUSA-packed MLPs (dense family only)."""
    assert cfg.family == "dense", "packed decode path targets the dense family"
    x = F._embed_tokens(params, token, cfg)
    pos = cache["pos"]

    from ..models.layers import attention_decode  # noqa: PLC0415

    meta = {
        n: (packed[n]["k"], packed[n]["c"], packed[n]["m"], packed[n]["a"])
        for n in ("w_gate", "w_up", "w_down")
    }

    def papply(name, vals, poss, x2):
        k, c, m, a = meta[name]
        p = RowPackedLinear(values=vals, positions=poss, k=k, c=c, a=a, m=m)
        return apply_row_packed(x2, p)

    def body(x, layer_in):
        lp, cache_l, gv, gp, uv, up_, dv, dp = layer_in
        h = rms_norm(x, lp["norm1"])
        y, new_cache = attention_decode(lp["attn"], h, cfg, {**cache_l, "pos": pos})
        x = x + y
        h = rms_norm(x, lp["norm2"])
        b, s, d = h.shape
        hf = h.reshape(b * s, d)
        gate = jax.nn.silu(papply("w_gate", gv, gp, hf))
        up = papply("w_up", uv, up_, hf)
        y2 = papply("w_down", dv, dp, (gate * up).astype(hf.dtype))
        x = x + y2.reshape(b, s, d).astype(x.dtype)
        return x, {"k": new_cache["k"], "v": new_cache["v"]}

    x, new_kv = jax.lax.scan(
        body,
        x,
        (
            params["layers"],
            {"k": cache["k"], "v": cache["v"]},
            packed["w_gate"]["values"], packed["w_gate"]["positions"],
            packed["w_up"]["values"], packed["w_up"]["positions"],
            packed["w_down"]["values"], packed["w_down"]["positions"],
        ),
    )
    x = rms_norm(x, params["final_norm"])
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = jnp.einsum("bsd,dv->bsv", x, head.astype(x.dtype))
    return logits, {**new_kv, "pos": pos + 1}
