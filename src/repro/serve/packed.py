"""VUSA-packed decode path for the dense LM family (DESIGN.md §7).

``pack_lm_weights`` packs the decode-step weights into the row-wise VUSA
format: per-layer MLP matrices (``w_gate``/``w_up`` plain, ``w_down``
*transposed* so the fused megakernel can window its reduction dim), and —
with ``scope="all"`` — the attention projections ``wq/wk/wv/wo`` and the
untied LM head.  One static sparse format serves every GEMM of the decode
step, the paper's application-independence claim on the serving path.

``lm_decode_step_packed`` is a twin of ``families.lm_decode_step`` whose
packed matmuls run through the Pallas kernels: the MLP through the fused
megakernel (``kernels.ops.apply_fused_mlp`` — one dispatch per layer, the
``(B, ff)`` intermediate never leaves VMEM) or, with ``fused_mlp=False``,
through the measured 3-dispatch baseline; attention projections and the
vocab-wide head reuse the multi-window row-packed kernel.  Layer packs are
stacked on a leading axis so the layer loop stays a scan.

``pack_lm_mlps`` survives as the legacy MLP-only packer (flat dict, dense
``w_down``); ``lm_decode_step_packed`` accepts both layouts.
"""

from __future__ import annotations

from typing import Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..configs.base import ArchConfig
from ..kernels.ops import (
    RowPackedLinear,
    apply_fused_mlp,
    apply_fused_mlp_sharded,
    apply_row_packed,
    apply_row_packed_sharded,
    mesh_axis_size,
    pack_linear_rows,
    pack_linear_rows_t,
    shard_linear_windows,
)
from ..models import families as F
from ..models.common import rms_norm

__all__ = [
    "pack_lm_mlps",
    "pack_lm_weights",
    "shard_packed",
    "lm_decode_step_packed",
    "packed_byte_ratios",
    "validate_packed",
    "pack_fingerprint",
    "qdq_lm_params",
]

ATTN_NAMES = ("wq", "wk", "wv", "wo")


# --------------------------------------------------------------------------
# packers
# --------------------------------------------------------------------------


def _stack_packs(packs) -> Dict:
    """Stack per-layer RowPackedLinear into one (L, ...) device dict.

    Jobs are padded to the max across layers so the stack is rectangular
    (padded jobs are exact no-ops: value 0, position -1).  Quantized packs
    pad the (possibly nibble-packed) value bytes with zeros — an idle
    position never scatters, so the byte content there is ignored — and
    stack the (T, K) scales unpadded."""
    smax = max(p.positions.shape[2] for p in packs)
    nib = 2 if packs[0].value_dtype == "int4" else 1
    vmax = smax // nib

    def pad(p: RowPackedLinear):
        v = jnp.pad(p.values, ((0, 0), (0, 0), (0, vmax - p.values.shape[2])))
        q = jnp.pad(
            p.positions, ((0, 0), (0, 0), (0, smax - p.positions.shape[2])),
            constant_values=-1,
        )
        return v, q

    vs, qs = zip(*(pad(p) for p in packs))
    out = {
        "values": jnp.stack(vs),
        "positions": jnp.stack(qs),
        "k": packs[0].k,
        "c": packs[0].c,
        "m": packs[0].m,
        "a": packs[0].a,
    }
    if packs[0].value_dtype != "dense":
        out["scales"] = jnp.stack([p.scales for p in packs])
        out["value_dtype"] = packs[0].value_dtype
        out["dense_itemsize"] = packs[0].dense_itemsize
    return out


def _stack_layers(
    ws: np.ndarray,
    m: int,
    a: int,
    pack_fn=pack_linear_rows,
    shards: int = 1,
    value_dtype: str = "dense",
) -> Dict:
    """Pack every layer of a stacked (L, K, C) weight and stack the packs.
    ``shards`` pads each pack's window axis to a multiple (no-op windows) so
    the stacked window axis splits evenly over a TP mesh axis."""
    return _stack_packs([
        shard_linear_windows(pack_fn(ws[layer], m=m, a=a, value_dtype=value_dtype), shards)
        for layer in range(ws.shape[0])
    ])


def _pack_one(p: RowPackedLinear) -> Dict:
    out = {
        "values": p.values,
        "positions": p.positions,
        "k": p.k,
        "c": p.c,
        "m": p.m,
        "a": p.a,
    }
    if p.value_dtype != "dense":
        out["scales"] = p.scales
        out["value_dtype"] = p.value_dtype
        out["dense_itemsize"] = p.dense_itemsize
    return out


def _as_linear(entry: Dict, values, positions, scales=None) -> RowPackedLinear:
    """Rebuild a RowPackedLinear from scanned per-layer leaves + static meta."""
    return RowPackedLinear(
        values=values, positions=positions,
        k=entry["k"], c=entry["c"], a=entry["a"], m=entry["m"],
        scales=scales,
        value_dtype=entry.get("value_dtype", "dense"),
        dense_itemsize=entry.get("dense_itemsize"),
    )


def pack_lm_mlps(cfg: ArchConfig, params, m: int = 128, a: int = 16) -> Dict:
    """Legacy MLP-only pack (flat dict, dense-orientation ``w_down``): the
    operands of the 3-dispatch baseline path."""
    layers = params["layers"]["ffn"]
    return {
        name: _stack_layers(np.asarray(layers[name]), m, a)
        for name in ("w_gate", "w_up", "w_down")
    }


def pack_lm_weights(
    cfg: ArchConfig,
    params,
    m: int = 128,
    a: int = 16,
    scope: str = "all",
    fused_mlp: bool = True,
    shards: int = 1,
    value_dtype: str = "dense",
) -> Dict:
    """Pack the dense-family decode-step weights; returns a structured dict.

    ``scope="mlp"`` packs only the per-layer MLP trio; ``scope="all"`` adds
    the attention projections (head dims flattened to 2-D) and the untied
    LM head (tied embeddings stay a gather + transpose-einsum — there is no
    separate weight to pack).  ``fused_mlp`` selects the megakernel operand
    layout (``w_down`` packed transposed via ``pack_linear_rows_t``) vs the
    3-dispatch baseline layout (``w_down`` packed plain).  ``shards`` pads
    every window axis to a multiple (no-op windows, exact) so the packs can
    be split over a TP mesh axis of that size — place them with
    :func:`shard_packed` (DESIGN.md §8).  ``value_dtype="int8"``/``"int4"``
    quantizes every pack's value slots with per-(window, row) fp32 scales
    (DESIGN.md §10); ``"dense"`` keeps the native float dtype."""
    assert cfg.family == "dense", "packed decode path targets the dense family"
    assert scope in ("mlp", "all"), scope
    ffn = params["layers"]["ffn"]
    mlp: Dict = {
        name: _stack_layers(np.asarray(ffn[name]), m, a, shards=shards, value_dtype=value_dtype)
        for name in ("w_gate", "w_up")
    }
    if fused_mlp:
        mlp["w_down_t"] = _stack_layers(
            np.asarray(ffn["w_down"]), m, a, pack_linear_rows_t, shards=shards,
            value_dtype=value_dtype,
        )
    else:
        mlp["w_down"] = _stack_layers(
            np.asarray(ffn["w_down"]), m, a, shards=shards, value_dtype=value_dtype
        )
    out: Dict = {
        "mlp": mlp,
        "attn": None,
        "head": None,
        "scope": scope,
        "fused_mlp": fused_mlp,
    }
    if scope == "all":
        attn_p = params["layers"]["attn"]
        attn: Dict = {}
        for name in ATTN_NAMES:
            w = np.asarray(attn_p[name])  # (L, d, nh, hd) or (L, nh, hd, d)
            flat = (
                w.reshape(w.shape[0], -1, w.shape[-1])  # wo: (L, nh*hd, d)
                if name == "wo"
                else w.reshape(w.shape[0], w.shape[1], -1)  # q/k/v: (L, d, nh*hd)
            )
            attn[name] = _stack_layers(flat, m, a, shards=shards, value_dtype=value_dtype)
        out["attn"] = attn
        if not cfg.tie_embeddings:
            out["head"] = _pack_one(
                shard_linear_windows(
                    pack_linear_rows(
                        np.asarray(params["lm_head"]), m=m, a=a, value_dtype=value_dtype
                    ),
                    shards,
                )
            )
    validate_packed(out)  # pack-time guard: never hand out a malformed pack
    return out


def shard_packed(packed: Dict, mesh) -> Dict:
    """Place a ``pack_lm_weights`` dict on a mesh: window axes split over the
    ``model`` mesh axis via ``dist.sharding.window_sharding`` (values *and*
    the int8 positions metadata — identical specs, a positions array sharded
    differently from its values would index the wrong shard's lanes).  Layer
    stacks ``(L, T, K, S)`` shard axis 1, the single LM-head pack ``(T, K,
    S)`` axis 0.  Window counts the axis does not divide (pack without
    ``shards=tp``) replicate — never an error.  Degenerate meshes return the
    dict as-is."""
    if mesh_axis_size(mesh, "model") == 1:
        return packed
    from ..dist.sharding import window_sharding

    def place(entry: Dict, axis: int) -> Dict:
        t = entry["values"].shape[axis]
        out = dict(entry)
        # scales share the window axis and must split identically — a scale
        # sharded differently from its values would rescale the wrong windows
        leaves = ("values", "positions") + (("scales",) if "scales" in entry else ())
        for leaf in leaves:
            sh = window_sharding(mesh, t, entry[leaf].ndim, axis=axis)
            out[leaf] = jax.device_put(entry[leaf], sh)
        return out

    out = dict(packed)
    if "mlp" not in packed:  # legacy flat pack_lm_mlps layout
        return {name: place(entry, 1) for name, entry in packed.items()}
    out["mlp"] = {name: place(e, 1) for name, e in packed["mlp"].items()}
    if packed.get("attn"):
        out["attn"] = {name: place(e, 1) for name, e in packed["attn"].items()}
    if packed.get("head") is not None:
        out["head"] = place(packed["head"], 0)
    return out


def _flat_entries(packed: Dict) -> Dict[str, Dict]:
    """Flatten a pack dict (structured ``pack_lm_weights`` or legacy flat
    ``pack_lm_mlps``) into ``{name: entry}``."""
    flat: Dict[str, Dict] = {}
    if "mlp" in packed:
        flat.update(packed["mlp"])
        if packed.get("attn"):
            flat.update(packed["attn"])
        if packed.get("head") is not None:
            flat["lm_head"] = packed["head"]
    else:
        flat.update(packed)
    return flat


def pack_fingerprint(packed: Dict) -> int:
    """CRC32 over every pack entry's arrays and geometry — a cheap identity
    for a loaded pack.  Hot swaps journal it (DESIGN.md §12) so an operator
    can tell from the journal alone *which* pack served which tokens; two
    packs built from the same params at the same config fingerprint the
    same.  One host fetch per entry; never on the decode path."""
    import zlib

    crc = 0
    flat = _flat_entries(packed)
    for name in sorted(flat):
        e = flat[name]
        crc = zlib.crc32(name.encode(), crc)
        crc = zlib.crc32(
            repr((e["m"], e["a"], e["k"], e["c"], e.get("value_dtype", "dense"))).encode(),
            crc,
        )
        for leaf in ("values", "positions", "scales"):
            if leaf in e:
                arr = np.asarray(jax.device_get(e[leaf]))
                crc = zlib.crc32(np.ascontiguousarray(arr).tobytes(), crc)
    return crc


def validate_packed(packed: Dict) -> None:
    """Check every pack entry's structural invariants at pack/load time;
    raise ``ValueError`` naming the entry and the first violation.

    Position metadata is the pack's wiring diagram: a corrupt byte silently
    reconstructs weight values into the wrong lanes — finite, plausible, and
    wrong — which the runtime ``isfinite`` guard cannot see.  Bounds, dtype
    and shape are checkable *before* serving, so the Engine refuses a pack
    that fails here (DESIGN.md §9).  The scan runs on device with one scalar
    sync per entry; the offending index is fetched only on failure."""
    flat = _flat_entries(packed)
    if not flat:
        raise ValueError("empty pack: no entries to serve")
    for name, e in flat.items():
        v, q = e["values"], e["positions"]
        m, a, k, c = e["m"], e["a"], e["k"], e["c"]
        vdt = e.get("value_dtype", "dense")
        nib = 2 if vdt == "int4" else 1
        if vdt == "dense":
            if tuple(v.shape) != tuple(q.shape):
                raise ValueError(
                    f"{name}: values shape {tuple(v.shape)} != positions {tuple(q.shape)}"
                )
        else:
            # quantized: values are raw bytes (nibble-packed for int4); they
            # must decode to exactly the position slots
            if v.dtype != jnp.int8:
                raise ValueError(f"{name}: quantized values dtype must be int8, got {v.dtype}")
            if tuple(v.shape[:-1]) != tuple(q.shape[:-1]) or v.shape[-1] * nib != q.shape[-1]:
                raise ValueError(
                    f"{name}: {vdt} values shape {tuple(v.shape)} does not decode to "
                    f"positions {tuple(q.shape)}"
                )
            s = e.get("scales")
            if s is None:
                raise ValueError(f"{name}: {vdt} pack is missing its scales")
            if tuple(s.shape) != tuple(q.shape[:-1]):
                raise ValueError(
                    f"{name}: scales shape {tuple(s.shape)} != window/row "
                    f"shape {tuple(q.shape[:-1])}"
                )
            if not bool(jnp.isfinite(s).all()):
                i = tuple(int(x) for x in np.argwhere(~np.isfinite(np.asarray(s)))[0])
                raise ValueError(f"{name}: non-finite dequant scale at {i}")
            if bool((s <= 0).any()):
                i = tuple(int(x) for x in np.argwhere(np.asarray(s) <= 0)[0])
                raise ValueError(f"{name}: non-positive dequant scale at {i}")
        if q.dtype != jnp.int8:
            raise ValueError(f"{name}: positions dtype must be int8, got {q.dtype}")
        if v.ndim not in (3, 4):
            raise ValueError(f"{name}: expected (T, K, S) or (L, T, K, S), got {tuple(v.shape)}")
        if m < 1 or a < 1 or m > 128:
            raise ValueError(f"{name}: window m={m} / slots a={a} out of range (int8 lanes)")
        if v.shape[-2] != k:
            raise ValueError(f"{name}: pack rows {v.shape[-2]} != declared k={k}")
        # int4 pads the slot axis to even at quantize time, which can break
        # the a-multiple; the kernel never consumes ``a``, so only dense and
        # int8 packs (slot count unchanged by quantization) keep the check
        if vdt != "int4" and v.shape[-1] % a:
            raise ValueError(f"{name}: slot count {v.shape[-1]} not a multiple of a={a}")
        if v.shape[-3] * m < c:
            raise ValueError(
                f"{name}: {v.shape[-3]} windows of {m} lanes cover "
                f"{v.shape[-3] * m} < c={c} columns"
            )
        # widen before comparing: m=128 does not fit int8, and int8 promotion
        # would wrap it to -128, flagging every position
        qw = q.astype(jnp.int32)
        bad_pos = (qw < -1) | (qw >= m)
        if bool(bad_pos.any()):
            qn = np.asarray(q)
            i = tuple(int(x) for x in np.argwhere(np.asarray(bad_pos))[0])
            raise ValueError(
                f"{name}: position {int(qn[i])} at {i} outside [-1, {m}) — corrupt metadata"
            )
        if vdt == "dense" and not bool(jnp.isfinite(v).all()):
            i = tuple(int(x) for x in np.argwhere(~np.isfinite(np.asarray(v)))[0])
            raise ValueError(f"{name}: non-finite packed value at {i}")


def packed_byte_ratios(packed: Dict, value_bytes: Optional[int] = None) -> Dict[str, float]:
    """Per-weight and total packed/dense HBM byte ratios (int8 positions).

    Accepts both the structured ``pack_lm_weights`` dict and the legacy flat
    ``pack_lm_mlps`` dict.  ``value_bytes`` defaults to the packed value
    itemsize.  Quantized entries count their real bytes — nibble-packed
    value bytes, full int8 positions, fp32 scales — against the *original*
    dense weight's bytes (``dense_itemsize``), not the quantized itemsize:
    the dense baseline being displaced did not shrink when the pack did."""
    flat = _flat_entries(packed)
    ratios: Dict[str, float] = {}
    tot_packed = tot_dense = 0
    for name, e in flat.items():
        v = e["values"]
        n_layers = v.shape[0] if v.ndim == 4 else 1
        if e.get("value_dtype", "dense") == "dense":
            vb = v.dtype.itemsize if value_bytes is None else value_bytes
            pb = v.size * (vb + 1)  # values + int8 positions
            db = n_layers * e["k"] * e["c"] * vb
        else:
            pb = (
                v.size * v.dtype.itemsize
                + e["positions"].size
                + e["scales"].size * e["scales"].dtype.itemsize
            )
            dense_b = e["dense_itemsize"] if value_bytes is None else value_bytes
            db = n_layers * e["k"] * e["c"] * dense_b
        ratios[name] = pb / db
        tot_packed += pb
        tot_dense += db
    ratios["total"] = tot_packed / max(tot_dense, 1)
    return ratios


# --------------------------------------------------------------------------
# quantize-dequantize dense oracle
# --------------------------------------------------------------------------


def _qdq_matrix(w2d: np.ndarray, m: int, a: int, value_dtype: str, transposed: bool = False):
    """Quantize->dequantize one 2-D matrix under the *same* window geometry
    the packer uses (``pack_rows_t`` for transposed-orientation packs), so
    the roundtripped values are bitwise the fp32 products the kernel's fused
    dequant reconstructs in VMEM."""
    from ..core.packing import dequantize_rows, pack_rows, pack_rows_t, quantize_rows, unpack_rows

    pack = (pack_rows_t if transposed else pack_rows)(w2d, m=m, a=a)
    dense = unpack_rows(dequantize_rows(quantize_rows(pack, value_dtype)))
    return np.ascontiguousarray(dense.T) if transposed else dense


def qdq_lm_params(
    cfg: ArchConfig,
    params,
    m: int = 128,
    a: int = 16,
    scope: str = "all",
    fused_mlp: bool = True,
    value_dtype: str = "int8",
):
    """Dense-oracle params: every matrix ``pack_lm_weights`` would quantize
    is replaced by its quantize-dequantize roundtrip under identical window
    geometry and orientation.  Running the *dense* decode path on these
    params is the correctness oracle for the quantized packed path: the
    kernel's VMEM dequant computes the same ``q * scale`` fp32 values, so
    greedy token streams must match."""
    assert scope in ("mlp", "all"), scope

    def qdq_stack(ws: np.ndarray, transposed: bool = False) -> jnp.ndarray:
        out = np.stack([
            _qdq_matrix(ws[layer], m, a, value_dtype, transposed)
            for layer in range(ws.shape[0])
        ])
        return jnp.asarray(out.astype(ws.dtype))

    ffn = dict(params["layers"]["ffn"])
    for name in ("w_gate", "w_up", "w_down"):
        w = np.asarray(ffn[name])
        ffn[name] = qdq_stack(w, transposed=(name == "w_down" and fused_mlp))
    layers = {**params["layers"], "ffn": ffn}
    out = {**params, "layers": layers}
    if scope == "all":
        attn = dict(params["layers"]["attn"])
        for name in ATTN_NAMES:
            w = np.asarray(attn[name])
            flat = (
                w.reshape(w.shape[0], -1, w.shape[-1])
                if name == "wo"
                else w.reshape(w.shape[0], w.shape[1], -1)
            )
            attn[name] = jnp.asarray(
                np.asarray(qdq_stack(flat)).reshape(w.shape).astype(w.dtype)
            )
        layers["attn"] = attn
        if not cfg.tie_embeddings:
            w = np.asarray(params["lm_head"])
            out["lm_head"] = jnp.asarray(_qdq_matrix(w, m, a, value_dtype).astype(w.dtype))
    return out


# --------------------------------------------------------------------------
# decode step
# --------------------------------------------------------------------------


def lm_decode_step_packed(params, packed, token, cache, cfg, mesh=None):
    """Decode step with VUSA-packed weights (dense family only).  ``token``
    is (B, 1) for normal decode or (B, s) for a speculative multi-token
    verify (contiguous cache only).  A *fully* packed step (scope="all"
    with an untied, packed LM head) runs the s-row verify genuinely
    batched: every matmul goes through the VUSA Pallas appliers, which are
    row-bitwise across row counts AND ~flat-cost in rows (the grid scans
    jobs, not rows), and ``attention_decode`` attends per query row — so
    the batched verify is bit-identical to s sequential steps at roughly
    single-step cost, which is where the speculative speedup comes from
    (DESIGN.md §13).  A partial pack (scope="mlp" or tied embeddings)
    still routes rows through XLA gemms, which are NOT row-stable, so it
    falls back to chaining s single-token steps inside the one dispatch —
    same bit-parity argument as :func:`repro.models.families.lm_decode_step`.

    ``packed`` is a ``pack_lm_weights`` dict (fused megakernel MLP and,
    with ``scope="all"``, packed attention projections + LM head) or a
    legacy ``pack_lm_mlps`` flat dict (MLP-only, 3-dispatch baseline).

    ``mesh`` routes every packed matmul through the window-sharded appliers
    (``kernels.ops.apply_*_sharded``): each device of the ``model`` axis
    reconstructs only its windows and the partial outputs are reassembled
    with a psum (fused MLP — ff is the reduction dim) or a tiled all-gather
    (column windows: gate/up/qkv/o/head).  A mesh whose ``model`` axis is
    absent or size 1 is the degenerate case — identical program to
    ``mesh=None`` (DESIGN.md §8)."""
    assert cfg.family == "dense", "packed decode path targets the dense family"
    if token.shape[1] > 1:
        assert "table" not in cache, (
            "multi-token decode needs a contiguous cache; gather the paged "
            "view first (serve/scheduler.py)"
        )
        full = (
            "mlp" in packed
            and packed.get("attn") is not None
            and packed.get("head") is not None
        )
        if not full:  # partial pack: XLA gemms are not row-stable — chain
            logits = []
            for i in range(token.shape[1]):
                lg, cache = lm_decode_step_packed(
                    params, packed, token[:, i : i + 1], cache, cfg, mesh=mesh
                )
                logits.append(lg)
            return jnp.concatenate(logits, axis=1), cache
    if "mlp" not in packed:  # legacy flat layout
        packed = {"mlp": packed, "attn": None, "head": None, "fused_mlp": False}
    mlp = packed["mlp"]
    attn = packed["attn"]
    fused = packed.get("fused_mlp", "w_down_t" in mlp)
    if mesh_axis_size(mesh, "model") == 1:
        mesh = None  # degenerate: plain single-device appliers

    x = F._embed_tokens(params, token, cfg)
    pos = cache["pos"]
    # paged per-slot view (DESIGN.md §11): same contract as lm_decode_step —
    # arena leaves scan per layer, the step returns pending k_new/v_new rows
    table = cache.get("table")

    from ..models.layers import attention_decode  # noqa: PLC0415

    def papply(entry, vals, poss, x2, scales=None):
        lin = _as_linear(entry, vals, poss, scales)
        if mesh is not None:
            return apply_row_packed_sharded(x2, lin, mesh)
        return apply_row_packed(x2, lin)

    def arrays(group):  # scanned leaves only; meta stays static
        return {
            n: {
                leaf: e[leaf]
                for leaf in ("values", "positions", "scales")
                if leaf in e
            }
            for n, e in group.items()
        }

    xs = (
        params["layers"],
        {"k": cache["k"], "v": cache["v"]},
        arrays(mlp),
        arrays(attn) if attn is not None else {},
    )

    def body(x, layer_in):
        lp, cache_l, mlp_l, attn_l = layer_in
        if table is not None:
            cache_l = {**cache_l, "table": table}
        h = rms_norm(x, lp["norm1"])
        wmm = (
            (
                lambda name, x2: papply(
                    attn[name], attn_l[name]["values"], attn_l[name]["positions"], x2,
                    attn_l[name].get("scales"),
                )
            )
            if attn is not None
            else None
        )
        y, new_cache = attention_decode(
            lp["attn"], h, cfg, {**cache_l, "pos": pos}, wmm=wmm
        )
        x = x + y
        h = rms_norm(x, lp["norm2"])
        b, s, d = h.shape
        hf = h.reshape(b * s, d)
        if fused:

            def lin(name):
                return _as_linear(
                    mlp[name], mlp_l[name]["values"], mlp_l[name]["positions"],
                    mlp_l[name].get("scales"),
                )

            if mesh is not None:
                y2 = apply_fused_mlp_sharded(
                    hf, lin("w_gate"), lin("w_up"), lin("w_down_t"), mesh
                )
            else:
                y2 = apply_fused_mlp(hf, lin("w_gate"), lin("w_up"), lin("w_down_t"))
        else:  # 3-dispatch baseline: gate/up/down round-trip the (B, ff)

            def pap(name, x2):
                return papply(
                    mlp[name], mlp_l[name]["values"], mlp_l[name]["positions"], x2,
                    mlp_l[name].get("scales"),
                )

            gate = jax.nn.silu(pap("w_gate", hf))
            up = pap("w_up", hf)
            y2 = pap("w_down", (gate * up).astype(hf.dtype))
        x = x + y2.reshape(b, s, d).astype(x.dtype)
        if "k_new" in new_cache:
            return x, {"k_new": new_cache["k_new"], "v_new": new_cache["v_new"]}
        return x, {"k": new_cache["k"], "v": new_cache["v"]}

    x, new_kv = jax.lax.scan(body, x, xs)
    x = rms_norm(x, params["final_norm"])
    if packed.get("head") is not None:
        b, s, d = x.shape
        head = packed["head"]
        logits = papply(
            head, head["values"], head["positions"], x.reshape(b * s, d), head.get("scales")
        )
        logits = logits.reshape(b, s, -1)
    else:
        head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
        logits = jnp.einsum("bsd,dv->bsv", x, head.astype(x.dtype))
    if table is not None:
        return logits, {**new_kv, "table": table, "pos": pos + 1}
    return logits, {**new_kv, "pos": pos + token.shape[1]}
