"""Per-kernel allclose tests: Pallas (interpret mode) vs the pure-jnp oracle
in ref.py, swept over shapes, dtypes and sparsity levels (+ hypothesis)."""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis_compat import given, settings, st

from repro.kernels.ops import (
    apply_packed,
    apply_packed_ref,
    apply_row_packed,
    apply_row_packed_ref,
    matmul,
    pack_linear,
    pack_linear_rows,
)
from repro.kernels.ref import dense_matmul_ref


def _sparse(rng, k, c, sparsity, dtype):
    w = rng.normal(size=(k, c)) * (rng.random((k, c)) > sparsity)
    return w.astype(dtype)


# ---------------------------------------------------------------------------
# dense baseline kernel
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("m,k,n", [(8, 128, 128), (128, 256, 384), (16, 64, 256)])
@pytest.mark.parametrize("dtype", [np.float32, jnp.bfloat16])
def test_dense_matmul_vs_ref(m, k, n, dtype):
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(m, k)), dtype=dtype)
    w = jnp.asarray(rng.normal(size=(k, n)), dtype=dtype)
    got = matmul(x, w)
    want = dense_matmul_ref(x, w).astype(jnp.float32)
    tol = 1e-5 if dtype == np.float32 else 5e-2
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=tol, atol=tol)


# ---------------------------------------------------------------------------
# block-gated kernel (vusa_spmm)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize(
    "b,k,c,sp,m_blk,a_blk",
    [
        (8, 256, 384, 0.9, 32, 8),
        (4, 100, 130, 0.85, 32, 8),  # unaligned -> padding path
        (16, 512, 256, 0.0, 32, 8),  # fully dense still exact
        (2, 64, 128, 0.99, 16, 8),
    ],
)
def test_vusa_spmm_vs_dense(b, k, c, sp, m_blk, a_blk):
    rng = np.random.default_rng(1)
    w = _sparse(rng, k, c, sp, np.float32)
    x = jnp.asarray(rng.normal(size=(b, k)), dtype=jnp.float32)
    p = pack_linear(w, m_blk, a_blk, 128)
    got = apply_packed(x, p)
    ref = apply_packed_ref(x, p)
    dense = np.asarray(x) @ w
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref), rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(got), dense, rtol=1e-3, atol=1e-3)


# ---------------------------------------------------------------------------
# row-wise packed kernel (vusa_packed) — the paper's format
# ---------------------------------------------------------------------------


@pytest.mark.parametrize(
    "b,k,c,sp,a",
    [
        (8, 256, 384, 0.85, 16),
        (4, 128, 130, 0.9, 8),
        (16, 256, 128, 0.0, 64),  # dense fallback
        (2, 512, 256, 0.97, 8),
        (1, 64, 128, 0.5, 32),  # B=1 decode
    ],
)
@pytest.mark.parametrize("dtype", [np.float32, jnp.bfloat16])
def test_vusa_packed_vs_dense(b, k, c, sp, a, dtype):
    rng = np.random.default_rng(2)
    w = jnp.asarray(_sparse(rng, k, c, sp, np.float32), dtype=dtype)
    x = jnp.asarray(rng.normal(size=(b, k)), dtype=dtype)
    p = pack_linear_rows(np.asarray(w, np.float32), a=a)
    got = np.asarray(apply_row_packed(x, p), np.float32)
    ref = np.asarray(apply_row_packed_ref(x, p), np.float32)
    dense = np.asarray(x, np.float32) @ np.asarray(w, np.float32)
    tol = 1e-4 if dtype == np.float32 else 0.5
    np.testing.assert_allclose(got, ref, rtol=tol, atol=tol)
    np.testing.assert_allclose(got, dense, rtol=tol, atol=tol)


@given(
    b=st.integers(1, 8),
    kt=st.integers(1, 4),
    sp=st.floats(0.0, 0.99),
    seed=st.integers(0, 10),
)
@settings(max_examples=15, deadline=None)
def test_vusa_packed_property(b, kt, sp, seed):
    """Property: packed execution == dense matmul for any sparsity pattern."""
    rng = np.random.default_rng(seed)
    k, c = 32 * kt, 128
    w = _sparse(rng, k, c, sp, np.float32)
    x = jnp.asarray(rng.normal(size=(b, k)), dtype=jnp.float32)
    p = pack_linear_rows(w, a=8)
    got = np.asarray(apply_row_packed(x, p))
    np.testing.assert_allclose(got, np.asarray(x) @ w, rtol=1e-4, atol=1e-4)


def test_byte_ratio_vs_sparsity_tracks_growth_model():
    """Kernel-format byte savings follow the paper's virtual-growth math:
    at sparsity s, jobs ~ ceil(max_row_nnz/A) so bytes shrink ~ (1-s)."""
    rng = np.random.default_rng(3)
    ratios = []
    for sp in (0.5, 0.85, 0.95):
        w = _sparse(rng, 512, 512, sp, np.float32)
        ratios.append(pack_linear_rows(w, a=8).byte_ratio)
    assert ratios[0] > ratios[1] > ratios[2]
