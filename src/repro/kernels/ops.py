"""Jit'd public wrappers around the Pallas kernels.

* auto-selects interpret mode off-TPU (this container is CPU-only);
* hosts the pack/apply glue so a model layer can swap a dense matmul for a
  VUSA-packed one in a single call.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from ..core.packing import BlockPacked, pack_blocks
from .dense_matmul import dense_matmul
from .ref import vusa_spmm_ref
from .vusa_spmm import vusa_spmm

__all__ = [
    "on_tpu",
    "PackedLinear",
    "pack_linear",
    "apply_packed",
    "apply_packed_ref",
    "matmul",
    "RowPackedLinear",
    "pack_linear_rows",
    "pack_linear_rows_t",
    "pack_linear_rows_nm",
    "dequantize_linear_values",
    "apply_row_packed",
    "apply_row_packed_ref",
    "choose_k_blk",
    "autotune_row_packed",
    "apply_fused_mlp",
    "apply_fused_mlp_ref",
    "autotune_fused_mlp",
    "shard_linear_windows",
    "mesh_axis_size",
    "apply_row_packed_sharded",
    "apply_fused_mlp_sharded",
]


def on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@dataclasses.dataclass
class PackedLinear:
    """Device-resident VUSA-packed weight (K, C) -> jobs of a_blk rows."""

    values: jax.Array  # (T, J, A, Tn)
    row_idx: jax.Array  # (T, J, A) int32
    k: int  # logical K (pre-padding)
    c: int  # logical C (pre-padding)
    k_padded: int = 0

    @property
    def compression(self) -> float:
        dense = self.k * self.c * self.values.dtype.itemsize
        packed = self.values.size * self.values.dtype.itemsize + self.row_idx.size * 4
        return packed / dense


def pack_linear(
    w: np.ndarray, m_blk: int = 32, a_blk: int = 8, tile_n: int = 128
) -> PackedLinear:
    """Host-side pack of a sparse (K, C) weight matrix (pads C to tile_n)."""
    k, c = w.shape
    w = np.asarray(w)
    c_pad = (-c) % tile_n
    k_pad = (-k) % m_blk
    if c_pad or k_pad:
        w = np.pad(w, ((0, k_pad), (0, c_pad)))
    bp: BlockPacked = pack_blocks(w, m_blk=m_blk, a_blk=a_blk, tile_n=tile_n)
    return PackedLinear(
        values=jnp.asarray(bp.values),
        row_idx=jnp.asarray(bp.row_idx),
        k=k,
        c=c,
        k_padded=k + k_pad,
    )


def apply_packed(x: jax.Array, p: PackedLinear, *, interpret: bool | None = None) -> jax.Array:
    """y = x @ W for packed W.  x: (..., K) -> (..., C)."""
    interp = (not on_tpu()) if interpret is None else interpret
    lead = x.shape[:-1]
    xf = x.reshape(-1, x.shape[-1])
    if p.k_padded > p.k:  # weight was K-padded at pack time
        xf = jnp.pad(xf, ((0, 0), (0, p.k_padded - p.k)))
    y = vusa_spmm(xf, p.values, p.row_idx, interpret=interp)
    y = y[..., : p.c]
    return y.reshape(*lead, p.c)


def apply_packed_ref(x: jax.Array, p: PackedLinear) -> jax.Array:
    lead = x.shape[:-1]
    xf = x.reshape(-1, x.shape[-1])
    if p.k_padded > p.k:
        xf = jnp.pad(xf, ((0, 0), (0, p.k_padded - p.k)))
    y = vusa_spmm_ref(xf, p.values, p.row_idx)[..., : p.c]
    return y.reshape(*lead, p.c)


def matmul(x: jax.Array, w: jax.Array, *, interpret: bool | None = None) -> jax.Array:
    """Dense baseline kernel wrapper (pads to MXU-aligned tiles)."""
    interp = (not on_tpu()) if interpret is None else interpret
    m, k = x.shape
    _, n = w.shape
    bm = 128 if m % 128 == 0 else (8 if m % 8 == 0 else 1)
    y = dense_matmul(x, w, bm=bm, interpret=interp)
    return y


# --------------------------------------------------------------------------
# Row-wise (paper-format) packed linear
# --------------------------------------------------------------------------

import os  # noqa: E402
import time  # noqa: E402

from ..core.packing import (  # noqa: E402
    QUANT_DTYPES,
    RowPacked,
    pack_rows,
    pack_rows_nm,
    pack_rows_t,
    quantize_rows,
)
from .ref import vusa_fused_mlp_ref, vusa_packed_ref  # noqa: E402
from .vusa_packed import (  # noqa: E402
    DEFAULT_SLOT_CHUNK,
    vusa_fused_mlp_matmul,
    vusa_packed_matmul,
)


@dataclasses.dataclass
class RowPackedLinear:
    """Device-resident row-wise VUSA pack (see kernels/vusa_packed.py).

    ``value_dtype="dense"`` (default) keeps values in their native float
    dtype.  ``"int8"``/``"int4"`` carry raw quantized bytes (int4 nibble-
    packed, two slots per byte) plus per-(window, row) fp32 ``scales``;
    ``dense_itemsize`` remembers the original dense weight's element size so
    byte-ratio accounting keeps the honest denominator."""

    values: jax.Array  # (T, K, J*A) float, or (T, K, Sb) int8 when quantized
    positions: jax.Array  # (T, K, J*A) int8
    k: int
    c: int
    a: int
    m: int = 128  # window width (lanes)
    scales: jax.Array | None = None  # (T, K) fp32, quantized packs only
    value_dtype: str = "dense"
    dense_itemsize: int | None = None

    @property
    def slots(self) -> int:
        """Logical slot count — positions are never nibble-packed."""
        return self.positions.shape[2]

    @property
    def byte_ratio(self) -> float:
        t = self.values.shape[0]
        vb = self.values.dtype.itemsize
        dense_b = self.dense_itemsize if self.dense_itemsize else vb
        dense = self.k * t * self.m * dense_b
        packed = self.values.size * vb + self.positions.size
        if self.scales is not None:
            packed += self.scales.size * self.scales.dtype.itemsize
        return packed / dense


def _linear_from_pack(rp: RowPacked, value_dtype: str) -> RowPackedLinear:
    if value_dtype == "dense":
        return RowPackedLinear(
            values=jnp.asarray(rp.values),
            positions=jnp.asarray(rp.row_positions),
            k=rp.k, c=rp.c, a=rp.a, m=rp.m,
        )
    if value_dtype not in QUANT_DTYPES:
        raise ValueError(
            f"value_dtype must be 'dense' or one of {QUANT_DTYPES}, got {value_dtype!r}"
        )
    q = quantize_rows(rp, value_dtype)
    return RowPackedLinear(
        values=jnp.asarray(q.values),
        positions=jnp.asarray(q.row_positions),
        k=q.k, c=q.c, a=q.a, m=q.m,
        scales=jnp.asarray(q.scales),
        value_dtype=value_dtype,
        dense_itemsize=q.dense_itemsize,
    )


def pack_linear_rows(
    w: np.ndarray, m: int = 128, a: int = 16, value_dtype: str = "dense"
) -> RowPackedLinear:
    return _linear_from_pack(pack_rows(np.asarray(w), m=m, a=a), value_dtype)


def pack_linear_rows_t(
    w: np.ndarray, m: int = 128, a: int = 16, value_dtype: str = "dense"
) -> RowPackedLinear:
    """Row-pack ``w`` *transposed* — windows cover ``w``'s leading (reduction)
    dim, the operand shape ``vusa_fused_mlp_matmul`` wants for ``w_down``."""
    return _linear_from_pack(pack_rows_t(np.asarray(w), m=m, a=a), value_dtype)


def pack_linear_rows_nm(
    w: np.ndarray,
    n: int = 2,
    block: int = 4,
    m: int = 128,
    a: int = 16,
    value_dtype: str = "dense",
) -> RowPackedLinear:
    """Prune to N:M structure (S2TA DBB blocks) then row-pack — the
    structured-sparsity comparison arm, same kernel interface."""
    return _linear_from_pack(pack_rows_nm(np.asarray(w), n=n, block=block, m=m, a=a), value_dtype)


def dequantize_linear_values(p: RowPackedLinear) -> jax.Array:
    """fp32 (T, K, S) value slots of any pack — the jnp twin of the kernel's
    VMEM dequant (int4 nibbles decoded with the same arithmetic shifts), used
    by the reference appliers and fault tooling."""
    raw = p.values
    if p.value_dtype == "dense":
        return raw.astype(jnp.float32)
    if p.value_dtype == "int4":
        lo = jnp.right_shift(jnp.left_shift(raw, 4), 4)
        hi = jnp.right_shift(raw, 4)
        raw = jnp.stack([lo, hi], axis=-1).reshape(raw.shape[:-1] + (raw.shape[-1] * 2,))
    return raw.astype(jnp.float32) * p.scales.astype(jnp.float32)[..., None]


# -- k_blk / m tuning ------------------------------------------------------
#
# The kernel's only free parameters are the K block (bounds the one-hot
# scratch: k_blk * min(slots, slot_chunk) * m * 4 bytes) and the window
# width m (fixed at pack time, <= 128).  ``choose_k_blk`` is the heuristic;
# ``autotune_row_packed`` measures the candidates once per shape and caches
# the winner so subsequent ``apply_row_packed`` calls use it.

_KBLK_CACHE: dict = {}  # (k, slots, m, b, backend) -> k_blk
_VMEM_SCRATCH_BUDGET = 2 * 1024 * 1024  # bytes for the one-hot scatter tensor


def _kblk_candidates(k: int):
    c = [blk for blk in (64, 128, 256, 512, 1024) if k % blk == 0 and blk <= k]
    if k <= 2048 and k not in c:
        c.append(k)
    return c or [k]


def _largest_divisor_leq(k: int, blk: int) -> int:
    """Largest divisor of ``k`` that is <= ``blk``, in O(sqrt k).

    The seed snapped ``REPRO_VUSA_KBLK`` down one step at a time
    (``while k % blk: blk -= 1``) — O(k) when the override lands just above
    a small divisor of a large prime-ish K."""
    blk = max(1, min(blk, k))
    best = 1
    for i in range(1, int(k**0.5) + 1):
        if k % i == 0:
            if i <= blk:
                best = max(best, i)
            if k // i <= blk:
                best = max(best, k // i)
    return best


def choose_k_blk(k: int, slots: int, m: int) -> int:
    """Pick the K block without measuring.

    On TPU the one-hot scatter scratch — k_blk * min(slots, slot_chunk) *
    m * 4 bytes, since reconstruction runs at most slot_chunk slots per
    pass — must fit VMEM, so take the largest candidate under the budget.
    Off-TPU (interpret mode) there is no VMEM wall and fewer, larger grid
    steps win (measured in benchmarks/run.py kernel_vusa_packed), so take
    the largest candidate outright.
    """
    env = os.environ.get("REPRO_VUSA_KBLK")
    if env:
        try:
            blk = int(env)
        except ValueError as e:
            raise ValueError(f"REPRO_VUSA_KBLK must be an integer, got {env!r}") from e
        return _largest_divisor_leq(k, blk)  # snap down to a divisor of k
    cands = _kblk_candidates(k)
    if not on_tpu():
        return cands[-1]
    best = 1
    for blk in cands:
        if blk * min(slots, DEFAULT_SLOT_CHUNK) * m * 4 <= _VMEM_SCRATCH_BUDGET:
            best = max(best, blk)
    return best


def _tune_key(
    xf: jax.Array, p: RowPackedLinear, interp: bool, reconstruct: str, slot_chunk: int
):
    # reconstruct/slot_chunk are part of the key: a k_blk tuned for the
    # one-pass "onehot" reconstruction is generally wrong for the per-slot
    # "loop" baseline (and vice versa) — the seed omitted both, so a cache
    # entry from one mode silently drove the other
    # value_dtype must be explicit: int8 and int4 packs share the jnp int8
    # array dtype, so str(dtype) alone would collide their cache entries
    # The REPRO_VUSA_KBLK override is part of the key: an entry tuned while
    # the override was set (or cleared) must not be served after the env
    # changes mid-process
    return (
        xf.shape[-1], p.values.shape[2], p.m, xf.shape[0],
        str(p.values.dtype), p.value_dtype, interp, jax.default_backend(),
        reconstruct, slot_chunk, os.environ.get("REPRO_VUSA_KBLK", ""),
    )


def autotune_row_packed(
    x: jax.Array,
    p: RowPackedLinear,
    *,
    interpret: bool | None = None,
    iters: int = 5,
    reconstruct: str = "onehot",
    slot_chunk: int = DEFAULT_SLOT_CHUNK,
) -> int:
    """Time the kernel over k_blk candidates; cache + return the winner."""
    interp = (not on_tpu()) if interpret is None else interpret
    xf = x.reshape(-1, x.shape[-1])
    key = _tune_key(xf, p, interp, reconstruct, slot_chunk)
    if key in _KBLK_CACHE:
        return _KBLK_CACHE[key]
    best_blk, best_t = None, float("inf")
    for blk in _kblk_candidates(xf.shape[-1]):
        f = lambda a: vusa_packed_matmul(
            a, p.values, p.positions, p.scales, m=p.m, k_blk=blk, interpret=interp,
            reconstruct=reconstruct, slot_chunk=slot_chunk, value_dtype=p.value_dtype,
        )
        f(xf).block_until_ready()  # compile
        t0 = time.perf_counter()
        for _ in range(iters):
            f(xf).block_until_ready()
        dt = (time.perf_counter() - t0) / iters
        if dt < best_t:
            best_blk, best_t = blk, dt
    _KBLK_CACHE[key] = best_blk
    return best_blk


def apply_row_packed(
    x: jax.Array,
    p: RowPackedLinear,
    *,
    interpret: bool | None = None,
    k_blk: int | None = None,
    reconstruct: str = "onehot",
    slot_chunk: int = DEFAULT_SLOT_CHUNK,
) -> jax.Array:
    """y = x @ W for row-packed W.  x: (..., K) -> (..., C).

    ``k_blk=None`` consults the autotune cache (populated by
    ``autotune_row_packed``), falling back to the ``choose_k_blk`` heuristic.
    """
    interp = (not on_tpu()) if interpret is None else interpret
    lead = x.shape[:-1]
    xf = x.reshape(-1, x.shape[-1])
    k = xf.shape[-1]
    slots = p.slots  # logical slots: the scratch bound sees decoded nibbles
    if k_blk is None:
        if os.environ.get("REPRO_VUSA_KBLK"):  # explicit override beats the cache
            k_blk = choose_k_blk(k, slots, p.m)
        else:
            k_blk = _KBLK_CACHE.get(
                _tune_key(xf, p, interp, reconstruct, slot_chunk)
            ) or choose_k_blk(k, slots, p.m)
    k_blk = min(k_blk, k)
    while k % k_blk:
        k_blk //= 2
    y = vusa_packed_matmul(
        xf,
        p.values,
        p.positions,
        p.scales,
        m=p.m,
        k_blk=max(k_blk, 1),
        interpret=interp,
        reconstruct=reconstruct,
        slot_chunk=slot_chunk,
        value_dtype=p.value_dtype,
    )
    return y[..., : p.c].reshape(*lead, p.c).astype(x.dtype)


def apply_row_packed_ref(x: jax.Array, p: RowPackedLinear) -> jax.Array:
    lead = x.shape[:-1]
    xf = x.reshape(-1, x.shape[-1])
    y = vusa_packed_ref(xf, dequantize_linear_values(p), p.positions)
    return y[..., : p.c].reshape(*lead, p.c).astype(x.dtype)


# --------------------------------------------------------------------------
# Fused packed MLP (DESIGN.md §7): silu(x@Wg) * (x@Wu) @ Wd in one kernel
# --------------------------------------------------------------------------


def _check_fused_packs(
    k: int, gate: RowPackedLinear, up: RowPackedLinear, down_t: RowPackedLinear
) -> None:
    assert gate.k == k and up.k == k, (gate.k, up.k, k)
    assert gate.m == up.m == down_t.m, (gate.m, up.m, down_t.m)
    assert gate.c == up.c == down_t.c, (gate.c, up.c, down_t.c)  # all windowed over ff
    t = gate.values.shape[0]
    assert up.values.shape[0] == t and down_t.values.shape[0] == t
    assert gate.value_dtype == up.value_dtype == down_t.value_dtype, (
        gate.value_dtype, up.value_dtype, down_t.value_dtype,
    )


def _fused_tune_key(
    xf: jax.Array,
    gate: RowPackedLinear,
    up: RowPackedLinear,
    down_t: RowPackedLinear,
    interp: bool,
    reconstruct: str,
    slot_chunk: int,
):
    return (
        "fused", xf.shape[-1], down_t.k, xf.shape[0],
        gate.values.shape[2], up.values.shape[2], down_t.values.shape[2], gate.m,
        str(gate.values.dtype), gate.value_dtype, interp, jax.default_backend(),
        reconstruct, slot_chunk, os.environ.get("REPRO_VUSA_KBLK", ""),
    )


def autotune_fused_mlp(
    x: jax.Array,
    gate: RowPackedLinear,
    up: RowPackedLinear,
    down_t: RowPackedLinear,
    *,
    interpret: bool | None = None,
    iters: int = 5,
    reconstruct: str = "onehot",
    slot_chunk: int = DEFAULT_SLOT_CHUNK,
) -> int:
    """Time the fused megakernel over k_blk candidates; cache the winner.

    The fused shape is its own tuning problem — its k_blk chunks *both* the
    d_model reduction of gate/up and the d_model output rows of the down
    accumulation, so the row-packed winner does not transfer."""
    interp = (not on_tpu()) if interpret is None else interpret
    xf = x.reshape(-1, x.shape[-1])
    _check_fused_packs(xf.shape[-1], gate, up, down_t)
    key = _fused_tune_key(xf, gate, up, down_t, interp, reconstruct, slot_chunk)
    if key in _KBLK_CACHE:
        return _KBLK_CACHE[key]
    best_blk, best_t = None, float("inf")
    for blk in sorted(set(_kblk_candidates(xf.shape[-1]) + _kblk_candidates(down_t.k))):
        f = lambda a: vusa_fused_mlp_matmul(
            a, gate.values, gate.positions, up.values, up.positions,
            down_t.values, down_t.positions,
            gate.scales, up.scales, down_t.scales, m=gate.m, k_blk=blk,
            interpret=interp, reconstruct=reconstruct, slot_chunk=slot_chunk,
            value_dtype=gate.value_dtype,
        )
        f(xf).block_until_ready()  # compile
        t0 = time.perf_counter()
        for _ in range(iters):
            f(xf).block_until_ready()
        dt = (time.perf_counter() - t0) / iters
        if dt < best_t:
            best_blk, best_t = blk, dt
    _KBLK_CACHE[key] = best_blk
    return best_blk


def apply_fused_mlp(
    x: jax.Array,
    gate: RowPackedLinear,
    up: RowPackedLinear,
    down_t: RowPackedLinear,
    *,
    interpret: bool | None = None,
    k_blk: int | None = None,
    reconstruct: str = "onehot",
    slot_chunk: int = DEFAULT_SLOT_CHUNK,
) -> jax.Array:
    """Whole SwiGLU MLP through the fused megakernel.

    ``gate``/``up`` row-pack (K, ff); ``down_t`` row-packs ``w_down``
    transposed (``pack_linear_rows_t``) so the ff reduction is windowed.
    x: (..., K) -> (..., D) where D = ``down_t.k``.  One ``pallas_call``
    replaces the gate/up/down dispatch triple and the (..., ff) intermediate
    stays in VMEM.  ``k_blk=None`` consults the autotune cache (populated by
    ``autotune_fused_mlp``), falling back to ``choose_k_blk``; unlike the
    plain row-packed kernel the chunk size need not divide K."""
    interp = (not on_tpu()) if interpret is None else interpret
    lead = x.shape[:-1]
    xf = x.reshape(-1, x.shape[-1])
    k = xf.shape[-1]
    _check_fused_packs(k, gate, up, down_t)
    if k_blk is None:
        slots = max(gate.slots, up.slots, down_t.slots)
        if os.environ.get("REPRO_VUSA_KBLK"):  # explicit override beats the cache
            k_blk = choose_k_blk(k, slots, gate.m)
        else:
            k_blk = _KBLK_CACHE.get(
                _fused_tune_key(xf, gate, up, down_t, interp, reconstruct, slot_chunk)
            ) or choose_k_blk(k, slots, gate.m)
    y = vusa_fused_mlp_matmul(
        xf,
        gate.values,
        gate.positions,
        up.values,
        up.positions,
        down_t.values,
        down_t.positions,
        gate.scales,
        up.scales,
        down_t.scales,
        m=gate.m,
        k_blk=max(int(k_blk), 1),
        interpret=interp,
        reconstruct=reconstruct,
        slot_chunk=slot_chunk,
        value_dtype=gate.value_dtype,
    )
    return y.reshape(*lead, down_t.k).astype(x.dtype)


def apply_fused_mlp_ref(
    x: jax.Array, gate: RowPackedLinear, up: RowPackedLinear, down_t: RowPackedLinear
) -> jax.Array:
    lead = x.shape[:-1]
    xf = x.reshape(-1, x.shape[-1])
    _check_fused_packs(xf.shape[-1], gate, up, down_t)
    y = vusa_fused_mlp_ref(
        xf, dequantize_linear_values(gate), gate.positions,
        dequantize_linear_values(up), up.positions,
        dequantize_linear_values(down_t), down_t.positions, m=gate.m,
    )
    return y.reshape(*lead, down_t.k).astype(x.dtype)


# --------------------------------------------------------------------------
# Mesh-sharded appliers (DESIGN.md §8): the pack's window axis is split over
# the `model` mesh axis and each device runs the *single-device* kernel on
# its window shard — the virtually upscaled array spans devices, not just
# one chip's lanes.  mesh=None (or a size-1 model axis) is the degenerate
# case and routes straight to the plain appliers, byte-identical program.
# --------------------------------------------------------------------------

from jax.experimental.shard_map import shard_map  # noqa: E402
from jax.sharding import PartitionSpec as _P  # noqa: E402


def mesh_axis_size(mesh, axis_name: str = "model") -> int:
    """Size of a mesh axis; 1 for no mesh / absent axis (degenerate case)."""
    if mesh is None or axis_name not in mesh.shape:
        return 1
    return int(mesh.shape[axis_name])


def shard_linear_windows(p: RowPackedLinear, n_shards: int) -> RowPackedLinear:
    """Pad the window axis to a multiple of ``n_shards`` with no-op windows
    (value 0, position -1) — the device-array twin of
    ``core.packing.shard_windows``.  ``k``/``c`` metadata is unchanged: pad
    windows reconstruct zero tiles past the real column range.  Quantized
    packs pad scales with 1.0 so the no-op windows dequant to exact zeros
    while staying finite."""
    t = p.values.shape[0]
    pad = (-t) % n_shards
    if pad == 0:
        return p
    values = jnp.pad(p.values, ((0, pad), (0, 0), (0, 0)))
    positions = jnp.pad(p.positions, ((0, pad), (0, 0), (0, 0)), constant_values=-1)
    scales = None
    if p.scales is not None:
        scales = jnp.pad(p.scales, ((0, pad), (0, 0)), constant_values=1.0)
    return RowPackedLinear(
        values=values, positions=positions, k=p.k, c=p.c, a=p.a, m=p.m,
        scales=scales, value_dtype=p.value_dtype, dense_itemsize=p.dense_itemsize,
    )


def _local_view(p: RowPackedLinear, values, positions, t_local: int, scales=None) -> RowPackedLinear:
    """Per-shard view: same geometry, ``c`` covering only the local windows."""
    return RowPackedLinear(
        values=values, positions=positions, k=p.k, c=t_local * p.m, a=p.a, m=p.m,
        scales=scales, value_dtype=p.value_dtype, dense_itemsize=p.dense_itemsize,
    )


def apply_row_packed_sharded(
    x: jax.Array,
    p: RowPackedLinear,
    mesh=None,
    axis_name: str = "model",
    *,
    interpret: bool | None = None,
) -> jax.Array:
    """``apply_row_packed`` with the window axis sharded over ``axis_name``.

    Windows tile the *output* columns, so each shard's kernel emits a
    contiguous ``(B, T_loc*m)`` column slice; a tiled all-gather over the
    mesh axis reassembles the full width on every device (column-parallel
    output, the tensor-parallel twin of the fused kernel's psum).  Values
    and positions enter the shard_map split on their leading window axis —
    pre-placing them with ``dist.sharding.window_sharding`` makes that split
    free.  Degenerate mesh (None or size-1 axis) runs the plain kernel."""
    tp = mesh_axis_size(mesh, axis_name)
    if tp == 1:
        return apply_row_packed(x, p, interpret=interpret)
    p = shard_linear_windows(p, tp)
    t = p.values.shape[0]
    t_local = t // tp
    lead = x.shape[:-1]
    xf = x.reshape(-1, x.shape[-1])
    quant = p.scales is not None

    def local(xf, values, positions, scales=None):
        y = apply_row_packed(
            xf, _local_view(p, values, positions, t_local, scales), interpret=interpret
        )
        return jax.lax.all_gather(y, axis_name, axis=1, tiled=True)

    # scales share the leading window axis, so they ride the same spec
    operands = (xf, p.values, p.positions) + ((p.scales,) if quant else ())
    y = shard_map(
        local,
        mesh=mesh,
        in_specs=(_P(),) + (_P(axis_name),) * (3 if quant else 2),
        out_specs=_P(),
        check_rep=False,
    )(*operands)
    return y[..., : p.c].reshape(*lead, p.c).astype(x.dtype)


def apply_fused_mlp_sharded(
    x: jax.Array,
    gate: RowPackedLinear,
    up: RowPackedLinear,
    down_t: RowPackedLinear,
    mesh=None,
    axis_name: str = "model",
    *,
    interpret: bool | None = None,
) -> jax.Array:
    """``apply_fused_mlp`` with the ff-window axis sharded over ``axis_name``.

    All three packs window the same ff dim, so one shard owns a slab of ff:
    it reconstructs its ``w_gate``/``w_up`` windows, forms that slab of
    ``silu(gate) * up`` in VMEM, and folds it through its ``w_down`` rows
    into a *partial* ``(B, d_model)`` output; a psum over the mesh axis sums
    the shards — ff is ``w_down``'s reduction dim, so partial outputs add.
    Degenerate mesh runs the plain megakernel."""
    tp = mesh_axis_size(mesh, axis_name)
    if tp == 1:
        return apply_fused_mlp(x, gate, up, down_t, interpret=interpret)
    _check_fused_packs(x.shape[-1], gate, up, down_t)
    gate = shard_linear_windows(gate, tp)
    up = shard_linear_windows(up, tp)
    down_t = shard_linear_windows(down_t, tp)
    t_local = gate.values.shape[0] // tp
    lead = x.shape[:-1]
    xf = x.reshape(-1, x.shape[-1])
    quant = gate.scales is not None

    def local(xf, gv, gp, uv, upp, dv, dp, gs=None, us=None, ds=None):
        y = apply_fused_mlp(
            xf,
            _local_view(gate, gv, gp, t_local, gs),
            _local_view(up, uv, upp, t_local, us),
            _local_view(down_t, dv, dp, t_local, ds),
            interpret=interpret,
        )
        return jax.lax.psum(y.astype(jnp.float32), axis_name)

    operands = (
        xf, gate.values, gate.positions, up.values, up.positions,
        down_t.values, down_t.positions,
    ) + ((gate.scales, up.scales, down_t.scales) if quant else ())
    y = shard_map(
        local,
        mesh=mesh,
        in_specs=(_P(),) + (_P(axis_name),) * (9 if quant else 6),
        out_specs=_P(),
        check_rep=False,
    )(*operands)
    return y.reshape(*lead, down_t.k).astype(x.dtype)
