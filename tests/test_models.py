"""Per-architecture smoke tests (reduced same-family configs, one forward +
one train-grad step on CPU, output shapes + finiteness) and decode-vs-
teacher-forced consistency for every cache family."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_smoke_config
from repro.models import build_model
from repro.models.opt_flags import FLAGS


@pytest.fixture(autouse=True)
def _fp32_attention_probs():
    """Cache-semantics tests compare the flash (train) path against the
    direct (decode) path; pin the bf16-probs perf flag off so both run the
    same fp32 pipeline and equality is exact.  Precision of the bf16 flag is
    covered separately by test_bf16_probs_precision."""
    prev = FLAGS["attn_bf16_probs"]
    FLAGS["attn_bf16_probs"] = False
    yield
    FLAGS["attn_bf16_probs"] = prev



def _batch(cfg, b=2, s=16, seed=0):
    rng = np.random.default_rng(seed)
    out = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab, (b, s)), jnp.int32)}
    if cfg.family == "vlm":
        patches = rng.normal(0, 0.02, (b, cfg.patch_tokens, cfg.d_model))
        out["patches"] = jnp.asarray(patches, jnp.float32)
    if cfg.family == "encdec":
        frames = rng.normal(0, 0.02, (b, cfg.enc_frames, cfg.d_model))
        out["frames"] = jnp.asarray(frames, jnp.float32)
    return out


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_forward_and_grad(arch):
    cfg = get_smoke_config(arch)
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    batch = _batch(cfg)
    logits, _ = jax.jit(model.forward)(params, batch)
    assert logits.shape == (2, batch["tokens"].shape[1], cfg.padded_vocab)
    assert bool(jnp.isfinite(logits).all())
    loss, grads = jax.jit(jax.value_and_grad(model.loss))(params, batch)
    assert np.isfinite(float(loss))
    gnorm = sum(float(jnp.sum(jnp.square(g))) for g in jax.tree_util.tree_leaves(grads))
    assert np.isfinite(gnorm) and gnorm > 0


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_decode_step(arch):
    cfg = get_smoke_config(arch)
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    cache = model.init_cache(2, 32)
    logits, cache2 = jax.jit(model.decode_step)(params, jnp.ones((2, 1), jnp.int32), cache)
    assert logits.shape == (2, 1, cfg.padded_vocab)
    assert bool(jnp.isfinite(logits).all())
    assert int(cache2["pos"]) == int(cache["pos"]) + 1


@pytest.mark.parametrize(
    "arch",
    ["llama3_2_1b", "qwen3_8b", "mamba2_2_7b", "recurrentgemma_9b", "olmoe_1b_7b", "paligemma_3b"],
)
def test_decode_matches_teacher_forcing(arch):
    """Stepwise decode must reproduce the teacher-forced logits — validates
    KV/ring caches, SSD chunking-vs-recurrence, and RG-LRU scan-vs-step."""
    cfg = get_smoke_config(arch)
    model = build_model(cfg)
    params = model.init(jax.random.key(1))
    b, s = 2, 12
    batch = _batch(cfg, b, s, seed=2)
    logits_tf, _ = jax.jit(model.forward)(params, batch)

    if cfg.family == "vlm":
        # prefill consumes patches+prompt; compare decode continuation instead
        logits_pf, cache = jax.jit(lambda p, bt: model.prefill(p, bt, s + 8))(params, batch)
        np.testing.assert_allclose(
            np.asarray(logits_pf), np.asarray(logits_tf[:, -1]), rtol=2e-4, atol=2e-4
        )
        return

    cache = model.init_cache(b, s + 4)
    step = jax.jit(model.decode_step)
    outs = []
    for t in range(s):
        lg, cache = step(params, batch["tokens"][:, t : t + 1], cache)
        outs.append(lg[:, 0])
    dec = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(dec), np.asarray(logits_tf), rtol=2e-4, atol=2e-4)


def test_prefill_matches_stepwise_decode():
    """Bulk prefill cache == cache built by stepping token by token."""
    cfg = get_smoke_config("llama3_2_1b")
    model = build_model(cfg)
    params = model.init(jax.random.key(3))
    b, s = 2, 8
    batch = _batch(cfg, b, s, seed=4)
    logits_pf, cache_pf = jax.jit(lambda p, bt: model.prefill(p, bt, s + 8))(params, batch)

    cache = model.init_cache(b, s + 8)
    step = jax.jit(model.decode_step)
    for t in range(s):
        lg, cache = step(params, batch["tokens"][:, t : t + 1], cache)
    np.testing.assert_allclose(np.asarray(logits_pf), np.asarray(lg[:, 0]), rtol=2e-4, atol=2e-4)
    # continuing from either cache must agree
    nxt = jnp.argmax(logits_pf, -1).astype(jnp.int32)[:, None]
    l1, _ = step(params, nxt, cache_pf)
    l2, _ = step(params, nxt, cache)
    np.testing.assert_allclose(np.asarray(l1), np.asarray(l2), rtol=2e-4, atol=2e-4)


def test_long_context_ring_cache():
    """recurrentgemma decode beyond the local window: ring cache wraps and
    state stays finite (the long_500k mechanism at smoke scale)."""
    cfg = get_smoke_config("recurrentgemma_9b")  # window 32
    model = build_model(cfg)
    params = model.init(jax.random.key(5))
    cache = model.init_cache(1, cfg.local_window)  # ring == window
    step = jax.jit(model.decode_step)
    tok = jnp.ones((1, 1), jnp.int32)
    for _ in range(cfg.local_window + 10):  # wrap the ring
        lg, cache = step(params, tok, cache)
    assert bool(jnp.isfinite(lg).all())
    assert int(cache["pos"]) == cfg.local_window + 10


def test_input_specs_cover_all_cells():
    from repro.configs import SHAPES, get_config

    for arch in ARCH_IDS:
        cfg = get_config(arch)
        model = build_model(cfg)
        for shape in SHAPES.values():
            if shape.kind in ("train", "prefill"):
                kind = shape.kind
            else:
                kind = "decode"
            spec = model.input_specs(shape.global_batch, shape.seq_len, kind)
            assert all(hasattr(v, "shape") for v in spec.values())


def test_bf16_probs_precision():
    """The attn_bf16_probs perf flag must stay within bf16 rounding of fp32."""
    cfg = get_smoke_config("llama3_2_1b")
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    batch = _batch(cfg, 2, 16)
    FLAGS["attn_bf16_probs"] = False
    ref, _ = jax.jit(model.forward)(params, batch)
    FLAGS["attn_bf16_probs"] = True
    try:
        got, _ = jax.jit(model.forward)(params, batch)
    finally:
        FLAGS["attn_bf16_probs"] = False
    err = float(jnp.max(jnp.abs(got.astype(jnp.float32) - ref.astype(jnp.float32))))
    scale = float(jnp.max(jnp.abs(ref)))
    assert err < 0.02 * max(scale, 1.0), (err, scale)
