"""Deterministic synthetic data pipeline with sharded host loading.

Offline container => synthetic token streams, but the machinery is the real
thing: per-host sharding (each host materialises only its slice), double-
buffered prefetch, and O(1) ``skip_to`` for exact checkpoint resume.
"""

from __future__ import annotations

import dataclasses
import queue
import threading
from typing import Dict, Iterator

import numpy as np

from ..configs.base import ArchConfig

__all__ = ["SyntheticDataset", "Prefetcher"]


@dataclasses.dataclass
class SyntheticDataset:
    """Deterministic LM batches: batch at step s is a pure function of
    (seed, s) — restart at any step reproduces the exact stream."""

    cfg: ArchConfig
    global_batch: int
    seq_len: int
    seed: int = 0
    # host slice (multi-host data loading: each host loads its rows only)
    host_index: int = 0
    host_count: int = 1
    step: int = 0
    token_range: int = 0  # >0: draw tokens from [0, token_range) (learnable)

    def __post_init__(self):
        assert self.global_batch % self.host_count == 0
        self.local_batch = self.global_batch // self.host_count

    def skip_to(self, step: int) -> "SyntheticDataset":
        self.step = step
        return self

    def _batch(self, step: int) -> Dict[str, np.ndarray]:
        rng = np.random.default_rng((self.seed, step, self.host_index))
        b, s = self.local_batch, self.seq_len
        cfg = self.cfg
        hi = self.token_range or cfg.vocab
        out: Dict[str, np.ndarray] = {}
        if cfg.family == "vlm":
            out["tokens"] = rng.integers(0, hi, (b, s - cfg.patch_tokens), dtype=np.int32)
            out["patches"] = rng.normal(0, 0.02, (b, cfg.patch_tokens, cfg.d_model)).astype(
                np.float32
            )
        elif cfg.family == "encdec":
            out["tokens"] = rng.integers(0, hi, (b, s), dtype=np.int32)
            out["frames"] = rng.normal(0, 0.02, (b, min(s, cfg.enc_frames), cfg.d_model)).astype(
                np.float32
            )
        else:
            out["tokens"] = rng.integers(0, hi, (b, s), dtype=np.int32)
        return out

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        while True:
            yield self._batch(self.step)
            self.step += 1


class Prefetcher:
    """Background-thread double buffering (host -> device overlap)."""

    def __init__(self, it: Iterator, depth: int = 2, device_put=None):
        self._q: queue.Queue = queue.Queue(maxsize=depth)
        self._put = device_put or (lambda x: x)
        self._stop = False

        def worker():
            for item in it:
                if self._stop:
                    return
                self._q.put(self._put(item))

        self._t = threading.Thread(target=worker, daemon=True)
        self._t.start()

    def __iter__(self):
        return self

    def __next__(self):
        return self._q.get()

    def close(self):
        self._stop = True
