"""Analytical growth-probability model (paper Section IV, Eq. 1-4, Fig. 6).

Truly unstructured sparsity == iid Bernoulli weights: each weight is non-zero
with probability ``p1`` and the count of non-zeros in a window of ``w``
columns is Binomial(w, p1).
"""

from __future__ import annotations

from math import comb

import numpy as np

__all__ = [
    "p_row_gain",
    "p_grow",
    "growth_curves",
    "expected_width_distribution",
]


def p_row_gain(w: int, A: int, p1: float) -> float:
    """Eq. 1+3: P(#non-zeros in a w-wide row window <= A) = Binom CDF."""
    p1 = float(p1)
    return float(sum(comb(w, i) * p1**i * (1.0 - p1) ** (w - i) for i in range(0, min(A, w) + 1)))


def p_grow(N: int, w: int, A: int, p1: float) -> float:
    """Eq. 2+4: P(an N-row tile virtually grows to an N x w window)."""
    return p_row_gain(w, A, p1) ** N


def growth_curves(N: int, M: int, A: int, sparsity: np.ndarray) -> dict:
    """Fig. 6: P(grow to N x w) for each w in (A, M] over a sparsity sweep.

    ``sparsity`` is P0 = 1 - P1 (the paper's x-axis).  Returns
    ``{w: probabilities}`` for w = A+1 .. M (w = A has probability 1).
    """
    sparsity = np.asarray(sparsity, dtype=np.float64)
    out = {}
    for w in range(A + 1, M + 1):
        out[w] = np.array([p_grow(N, w, A, 1.0 - s) for s in sparsity])
    return out


def expected_width_distribution(N: int, M: int, A: int, p1: float) -> np.ndarray:
    """Stationary distribution over *achieved* window widths for the greedy
    scheduler under iid sparsity.

    ``dist[w]`` = probability the scheduler's next window has width ``w``.
    Greedy picks the widest feasible w in [A, M]:
      P(width = M)  = p_grow(N, M, A, p1)
      P(width = w)  = p_grow(N, w, ...) - P(already feasible at w+1)  is only
    an approximation (feasibility is not nested across *different* column
    sets), but for iid weights windows share the leading columns, and
    feasibility at width w+1 implies feasibility of its w-prefix, so nesting
    holds exactly for the greedy left-anchored scheduler (dropping the last
    column can only reduce per-row counts).
    """
    dist = np.zeros(M + 1)
    prev = 0.0  # P(feasible at any width > w)
    for w in range(M, A, -1):
        p = p_grow(N, w, A, p1)
        dist[w] = max(p - prev, 0.0)
        prev = max(prev, p)
    dist[A] = max(1.0 - prev, 0.0)
    return dist
