"""Tensor-parallel sharded serving (DESIGN.md §8): differential tests of the
mesh-aware decode/serve stack against the single-device path.

The whole suite runs on a forced 8-device CPU backend (tests/conftest.py), so
every mesh here — 1x1, 2x1 (DP), 1x2 (TP), 2x4 (DP x TP) — is a real
multi-device mesh exercising real collectives.  The correctness bar is the
one the serve stack has pinned since §5: sharding changes *where* work runs,
never *what* it computes — per-request tokens bit-identical (fp32) to the
single-device engine, logits allclose at bf16.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from conftest import requires_devices

from repro.configs import get_smoke_config
from repro.core.pruning import prune_tree
from repro.launch.mesh import make_serve_mesh
from repro.models import build_model
from repro.serve import Engine, Request, Scheduler, ServeConfig

MESHES = ["1,1", "2,1", "1,2", "2,4"]
# vusa_m=32 so the smoke shapes span several windows per matmul (d_ff=128 ->
# 4 ff windows, vocab head -> 16) and the 1x2 / 2x4 meshes genuinely split
# windows across devices instead of degenerating to one window per mesh
PACK = dict(vusa_m=32, vusa_a=8)


def _sc(**kw):
    return ServeConfig(max_len=48, **PACK, **kw)


@pytest.fixture(scope="module")
def cfg():
    return get_smoke_config("vusa_edge")


@pytest.fixture(scope="module")
def params(cfg):
    return prune_tree(build_model(cfg).init(jax.random.key(0)), 0.85)


@pytest.fixture(scope="module")
def prompts():
    return np.arange(12, dtype=np.int32).reshape(2, 6) % 500


@pytest.fixture(scope="module")
def reference(cfg, params, prompts):
    """Single-device (mesh=None) token streams per (packed, temperature)."""
    out = {}

    def get(packed, temp):
        if (packed, temp) not in out:
            eng = Engine(cfg, params, _sc(packed_weights=packed, temperature=temp))
            out[(packed, temp)] = eng.generate(prompts, max_new=10)["tokens"]
        return out[(packed, temp)]

    return get


# ---------------------------------------------------------------------------
# Engine: sharded == single-device, bit-identical tokens
# ---------------------------------------------------------------------------


@requires_devices(8)
@pytest.mark.parametrize("spec", MESHES)
@pytest.mark.parametrize("packed", [False, "mlp", "all"])
def test_engine_sharded_greedy(cfg, params, prompts, reference, spec, packed):
    mesh = make_serve_mesh(spec)
    eng = Engine(cfg, params, _sc(packed_weights=packed), mesh=mesh)
    toks = eng.generate(prompts, max_new=10)["tokens"]
    np.testing.assert_array_equal(toks, reference(packed, 0.0))


@requires_devices(8)
@pytest.mark.parametrize("spec,packed", [("1,2", False), ("1,2", "all"), ("2,4", "all")])
def test_engine_sharded_sampled(cfg, params, prompts, reference, spec, packed):
    """Temperature sampling: the sharded engine splits the same key stream,
    so even sampled streams are bit-identical at fp32."""
    mesh = make_serve_mesh(spec)
    eng = Engine(cfg, params, _sc(packed_weights=packed, temperature=0.8), mesh=mesh)
    toks = eng.generate(prompts, max_new=10)["tokens"]
    np.testing.assert_array_equal(toks, reference(packed, 0.8))


@requires_devices(1)
def test_engine_mesh1_degenerate(cfg, params, prompts, reference):
    """A 1x1 mesh must be the single-device path: same tokens, and the packs
    gain no padding windows (shards=1 pads nothing, shard_map is skipped)."""
    eng0 = Engine(cfg, params, _sc(packed_weights="all"))
    eng1 = Engine(cfg, params, _sc(packed_weights="all"), mesh=make_serve_mesh("1,1"))
    for a, b in zip(
        jax.tree_util.tree_leaves(eng0._packed), jax.tree_util.tree_leaves(eng1._packed)
    ):
        assert np.asarray(a).shape == np.asarray(b).shape
    toks = eng1.generate(prompts, max_new=10)["tokens"]
    np.testing.assert_array_equal(toks, reference("all", 0.0))


@requires_devices(8)
@pytest.mark.parametrize("packed", [False, "all"])
def test_bf16_logits_allclose(cfg, params, prompts, packed):
    """bf16 decode: psum/all-gather reassociate the low-precision sums, so
    the bar is allclose logits (and it holds one full decode step)."""
    bcfg = dataclasses.replace(cfg, dtype="bfloat16")
    mesh = make_serve_mesh("2,4")
    engines = [
        Engine(bcfg, params, _sc(packed_weights=packed)),
        Engine(bcfg, params, _sc(packed_weights=packed), mesh=mesh),
    ]
    logits = []
    for eng in engines:
        nxt, cache, _ = eng.prime(prompts, jax.random.key(0))
        if eng._packed is not None:
            from repro.serve.packed import lm_decode_step_packed

            lg, _ = lm_decode_step_packed(
                eng.params, eng._packed, nxt, cache, bcfg, mesh=eng.mesh
            )
        else:
            lg, _ = eng.model.decode_step(eng.params, nxt, cache)
        logits.append(np.asarray(lg, np.float32))
    np.testing.assert_allclose(logits[0], logits[1], rtol=5e-2, atol=5e-2)


# ---------------------------------------------------------------------------
# Scheduler: sharded slot pool == single-device slot pool
# ---------------------------------------------------------------------------


def _requests(cfg):
    rng = np.random.default_rng(7)
    return [
        Request(
            prompt=rng.integers(0, cfg.vocab, 3 + i % 4).astype(np.int32),
            max_new=5 + i % 4,
            seed=i,
            eos_id=3 if i % 3 == 0 else None,
        )
        for i in range(6)
    ]


@pytest.mark.slow
@requires_devices(8)
@pytest.mark.parametrize("spec", ["2,1", "1,2", "2,4"])
@pytest.mark.parametrize("temp", [0.0, 0.8])
def test_scheduler_sharded(cfg, params, spec, temp):
    """Continuous batching over a sharded slot pool: every completion must be
    bit-identical to the single-device scheduler — ragged admission, EOS
    retirement and all (packed 'all', greedy and sampled)."""
    sc = _sc(packed_weights="all", temperature=temp)
    base = Scheduler(Engine(cfg, params, dataclasses.replace(sc)), slots=4, segment=3)
    want = base.run(_requests(cfg))
    mesh = make_serve_mesh(spec)
    sched = Scheduler(
        Engine(cfg, params, dataclasses.replace(sc), mesh=mesh), slots=4, segment=3
    )
    got = sched.run(_requests(cfg))
    assert set(got) == set(want)
    for rid in want:
        np.testing.assert_array_equal(got[rid].tokens, want[rid].tokens)


@requires_devices(8)
def test_scheduler_sharded_dense(cfg, params):
    """Dense (unpacked) family through the sharded slot pool."""
    sc = _sc()
    base = Scheduler(Engine(cfg, params, dataclasses.replace(sc)), slots=4, segment=3)
    want = base.run(_requests(cfg))
    sched = Scheduler(
        Engine(cfg, params, dataclasses.replace(sc), mesh=make_serve_mesh("2,4")),
        slots=4, segment=3,
    )
    got = sched.run(_requests(cfg))
    for rid in want:
        np.testing.assert_array_equal(got[rid].tokens, want[rid].tokens)


# ---------------------------------------------------------------------------
# Kernel-level: sharded appliers vs plain appliers vs dense oracle
# ---------------------------------------------------------------------------


def _sparse(rng, k, c, sp):
    return (rng.normal(size=(k, c)) * (rng.random((k, c)) > sp)).astype(np.float32)


@requires_devices(8)
@pytest.mark.parametrize("tp", [1, 2, 4])
@pytest.mark.parametrize("t_windows", [4, 5])  # 5 % 4 != 0 -> pad-window path
def test_apply_row_packed_sharded(tp, t_windows):
    from repro.kernels.ops import apply_row_packed, apply_row_packed_sharded, pack_linear_rows

    rng = np.random.default_rng(0)
    m = 32
    w = _sparse(rng, 40, t_windows * m - 7, 0.8)  # c % m != 0 too
    x = jnp.asarray(rng.normal(size=(3, 40)), jnp.float32)
    p = pack_linear_rows(w, m=m, a=8)
    mesh = make_serve_mesh(f"{8 // tp if tp < 8 else 1},{tp}")
    got = np.asarray(apply_row_packed_sharded(x, p, mesh))
    np.testing.assert_allclose(got, np.asarray(x) @ w, rtol=1e-4, atol=1e-4)
    if tp == 1:  # degenerate: exactly the plain applier
        np.testing.assert_array_equal(got, np.asarray(apply_row_packed(x, p)))


@requires_devices(8)
@pytest.mark.parametrize("tp", [2, 4])
def test_apply_fused_mlp_sharded(tp):
    import jax.nn

    from repro.kernels.ops import (
        apply_fused_mlp,
        apply_fused_mlp_sharded,
        pack_linear_rows,
        pack_linear_rows_t,
    )

    rng = np.random.default_rng(1)
    k, ff, m = 48, 80, 32  # ff % m != 0 and windows % tp != 0
    wg, wu = _sparse(rng, k, ff, 0.8), _sparse(rng, k, ff, 0.8)
    wd = _sparse(rng, ff, k, 0.8)
    x = jnp.asarray(rng.normal(size=(2, k)), jnp.float32)
    gate, up = pack_linear_rows(wg, m=m, a=8), pack_linear_rows(wu, m=m, a=8)
    down_t = pack_linear_rows_t(wd, m=m, a=8)
    mesh = make_serve_mesh(f"1,{tp}")
    got = np.asarray(apply_fused_mlp_sharded(x, gate, up, down_t, mesh))
    want = np.asarray(apply_fused_mlp(x, gate, up, down_t))
    dense = (np.asarray(jax.nn.silu(x @ wg)) * np.asarray(x @ wu)) @ wd
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(got, dense, rtol=1e-3, atol=1e-3)


@requires_devices(8)
def test_sharded_applier_replicated_fallback():
    """A mesh whose model axis the window count cannot use still computes the
    right answer (shard_linear_windows pads on the fly) — and a mesh with no
    model axis at all degenerates to the plain path."""
    from jax.sharding import Mesh

    from repro.kernels.ops import apply_row_packed_sharded, pack_linear_rows

    rng = np.random.default_rng(2)
    w = _sparse(rng, 16, 33, 0.5)  # 2 windows of m=32 after padding
    x = jnp.asarray(rng.normal(size=(2, 16)), jnp.float32)
    p = pack_linear_rows(w, m=32, a=8)
    data_only = Mesh(np.array(jax.devices()[:2]).reshape(2), ("data",))
    got = np.asarray(apply_row_packed_sharded(x, p, data_only))
    np.testing.assert_allclose(got, np.asarray(x) @ w, rtol=1e-4, atol=1e-4)
    got3 = np.asarray(apply_row_packed_sharded(x, p, make_serve_mesh("1,4")))
    np.testing.assert_allclose(got3, np.asarray(x) @ w, rtol=1e-4, atol=1e-4)
